"""Put src/ on sys.path so the suite runs without PYTHONPATH plumbing."""
import pathlib
import sys

SRC = str(pathlib.Path(__file__).resolve().parents[1] / "src")
if SRC not in sys.path:
    sys.path.insert(0, SRC)
