"""End-to-end behaviour tests for the paper's system (VDBB core + models).

Covers the functional claims of the paper:
  - DBB encode/decode round trip, compression ratio accounting
  - variable NNZ with identical call shapes ("constant utilization")
  - magnitude pruning = projection (idempotent, monotone)
  - energy model reproduces Table V/Fig 12 (see also benchmarks/)
  - compressed serving == dense-masked forward on a real model
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import make_batch, smoke_config
from repro.core import (
    DBBFormat,
    PAPER_TABLE_V_16NM,
    PARETO_DESIGN,
    dbb_decode,
    dbb_encode,
    dbb_gemm_costs,
    dbb_prune,
    fmt_for_sparsity,
    satisfies_dbb,
)
from repro.models.model import LM


class TestVDBBCore:
    @pytest.mark.parametrize("nnz", [1, 2, 3, 4, 5, 6, 7, 8])
    @pytest.mark.parametrize("group", [None, 8, "matrix"])
    def test_roundtrip_all_densities(self, nnz, group):
        w = jax.random.normal(jax.random.PRNGKey(0), (64, 32))
        fmt = DBBFormat(8, nnz, group)
        wp = dbb_prune(w, fmt)
        assert satisfies_dbb(wp, fmt)
        np.testing.assert_allclose(
            dbb_decode(dbb_encode(w, fmt, prune=True)), wp, atol=1e-6
        )

    def test_projection_idempotent_and_monotone(self):
        w = jax.random.normal(jax.random.PRNGKey(1), (64, 16))
        for nnz in (2, 4, 6):
            fmt = DBBFormat(8, nnz)
            wp = dbb_prune(w, fmt)
            np.testing.assert_allclose(dbb_prune(wp, fmt), wp)  # idempotent
            # looser bound keeps a pruned matrix unchanged
            np.testing.assert_allclose(dbb_prune(wp, DBBFormat(8, nnz + 2)), wp)
        # energy kept is monotone in nnz
        e = [float(jnp.sum(dbb_prune(w, DBBFormat(8, k)) ** 2)) for k in range(1, 9)]
        assert all(b >= a for a, b in zip(e, e[1:]))

    def test_compression_ratio_paper_formula(self):
        # paper SII-A: ratio = 8*BZ / (8*NNZ + BZ)
        assert DBBFormat(8, 2).compression_ratio(8) == pytest.approx(64 / 24)
        assert DBBFormat(8, 8).compression_ratio(8) == pytest.approx(64 / 72)
        c = dbb_gemm_costs(64, 512, 128, DBBFormat(8, 2))
        assert c["speedup"] == 4.0
        assert c["executed_macs"] == 64 * 128 * 128

    def test_trailing_partial_k_block_accounting(self):
        """Regression pin for the PR-2 trailing-partial-K fix: dense-format
        GEMMs whose K is not bz-blockable (the C=3 conv stem, K = kh·kw·3)
        must count — and store — the remainder positions, not drop them."""
        from repro.core import dbb_conv_costs

        m, k, n = 16, 27, 32  # 3x3x3 stem as a GEMM: K = 27 = 3 blocks + 3
        fmt = DBBFormat(8, 8)  # dense bound (the only legal partial-K case)
        c = dbb_gemm_costs(m, k, n, fmt)
        assert c["executed_macs"] == m * k * n  # every position executes
        nb, rem = divmod(k, fmt.bz)
        assert (nb, rem) == (3, 3)
        # full blocks stream values+mask; the rem positions stream
        # uncompressed (8-bit value + 1 mask bit each)
        assert c["weight_bytes"] == int((nb * (8 * 8 + 8) + rem * (8 + 1)) * n / 8)
        assert c["act_bytes"] == m * k  # 8-bit operands, K *includes* rem
        # the real stem layer shape end-to-end through the conv accounting
        cc = dbb_conv_costs(1, 16, 16, 3, 32, 3, 3, fmt)
        assert cc["executed_macs"] == cc["dense_macs"]
        # a sparse bound over a non-blockable K must refuse, not undercount
        with pytest.raises(ValueError):
            dbb_gemm_costs(m, 27, n, DBBFormat(8, 4))

    def test_dense_bound_is_exact_dense(self):
        w = jax.random.normal(jax.random.PRNGKey(2), (32, 16))
        dw = dbb_encode(w, DBBFormat(8, 8), prune=True)
        np.testing.assert_allclose(dbb_decode(dw), w, atol=1e-6)

    def test_variable_nnz_constant_shapes(self):
        """Time unrolling: storage shape scales with nnz, API is constant."""
        w = jax.random.normal(jax.random.PRNGKey(3), (64, 32))
        for nnz in (1, 4, 8):
            dw = dbb_encode(w, DBBFormat(8, nnz, "matrix"), prune=True)
            assert dw.values.shape == (8, nnz, 32)
            assert dw.nbytes_compressed() < dw.nbytes_dense() or nnz == 8


class TestEnergyModel:
    def test_table_v_within_5pct(self):
        for sp, (tw, tm) in PAPER_TABLE_V_16NM.items():
            f = fmt_for_sparsity(sp)
            assert PARETO_DESIGN.tops_per_w(f) == pytest.approx(tw, rel=0.05)
            assert PARETO_DESIGN.tops_per_mm2(f) == pytest.approx(tm, rel=0.05)

    def test_vdbb_beats_fixed_dbb_above_design_point(self):
        from repro.core.energy_model import STAConfig

        vdbb = STAConfig(4, 8, 4, 8, 8, mode="vdbb")
        dbb = STAConfig(4, 8, 4, 4, 8, mode="dbb", hw_nnz=4)
        hi = fmt_for_sparsity(0.875)
        assert vdbb.effective_tops(hi) > dbb.effective_tops(hi) * 1.9
        lo = fmt_for_sparsity(0.25)
        assert dbb.effective_tops(lo) == dbb.peak_tops()  # dense fallback
        assert vdbb.effective_tops(lo) > dbb.effective_tops(lo)


class TestCompressedServing:
    def test_forward_equivalence_dense_vs_compressed(self):
        cfg = smoke_config("qwen2-72b", sparsity=0.625)
        model = LM(cfg)
        params = model.constrain(model.init(jax.random.PRNGKey(0)))
        batch = make_batch(cfg, batch=2, seq=16, kind="serve")
        dense_logits = model.forward(params, batch)
        comp_logits = model.forward(model.compress(params), batch)
        np.testing.assert_allclose(
            np.asarray(dense_logits, np.float32),
            np.asarray(comp_logits, np.float32),
            rtol=2e-2, atol=2e-2,
        )

    def test_compressed_bytes_shrink(self):
        cfg = smoke_config("codeqwen1.5-7b", sparsity=0.625)
        model = LM(cfg)
        params = model.init(jax.random.PRNGKey(0))
        comp = model.compress(params)

        def nbytes(t):
            return sum(
                x.size * x.dtype.itemsize for x in jax.tree_util.tree_leaves(t)
            )

        assert nbytes(comp) < nbytes(params) * 0.75  # 3/8 values + idx + dense rest

    def test_anneal_schedule_reaches_target(self):
        from repro.core.sparse_linear import PruneSchedule

        cfg = smoke_config("internvl2-2b", sparsity=0.75)
        model = LM(cfg)
        params = model.init(jax.random.PRNGKey(0))
        # start from a DENSE weight so the anneal is visible
        params = jax.tree_util.tree_map(
            lambda x: jnp.abs(x) + 0.01 if x.ndim >= 2 and x.dtype != jnp.int32 else x,
            params,
        )
        sched = PruneSchedule(0, 100)
        p_mid = model.constrain(params, 50, sched)
        p_end = model.constrain(params, 100, sched)
        from repro.models.common import dbb_leaves, tree_get

        path, pdef = next(iter(dbb_leaves(model.defs())))
        d_mid = float(jnp.mean(tree_get(p_mid, path) != 0))
        d_end = float(jnp.mean(tree_get(p_end, path) != 0))
        assert d_end <= pdef.dbb.density + 1e-6
        assert d_mid > d_end  # annealing: mid-schedule is denser
