"""Self-healing serving lifecycle tests (DESIGN.md §15).

Unit tests drive the ``Supervisor``'s backoff/breaker arithmetic with an
injected clock and seeded RNG (no threads, no sleeps — the §14
``MicroBatcher`` style), and integration tests run real supervised
restarts, hot reloads, and bucket demotion on the ref-kernel smoke CNN
through the deterministic ``FaultInjector`` seams — never by
monkeypatching server internals.
"""
import dataclasses
import threading
import time

import jax
import numpy as np
import pytest

from repro.checkpoint.store import CorruptCheckpointError, save as ckpt_save
from repro.configs import smoke_cnn_config
from repro.launch.faults import FaultInjector, corrupt_checkpoint
from repro.launch.server import CNNServer, ServerCrashed
from repro.launch.supervisor import Supervisor
from repro.models.cnn import SparseCNN


@pytest.fixture(scope="module")
def served():
    """Ref-kernel quantized model + a max_batch=4 bucketed plan set."""
    cfg = dataclasses.replace(
        smoke_cnn_config("sparse-cnn-tiny", sparsity=0.625), kernel_mode="ref"
    )
    model = SparseCNN(cfg)
    params = model.compress(model.init(jax.random.PRNGKey(0)))
    x = jax.random.normal(
        jax.random.PRNGKey(1),
        (12, cfg.image_size, cfg.image_size, cfg.in_channels),
    )
    _, stats = model.apply(params, x[:4], collect_act_stats=True)
    qparams = model.quantize(params, stats)
    plan_set = model.plan_set(qparams, max_batch=4, tune="off")
    return model, qparams, np.asarray(x), plan_set


def _supervised(plan_set, *, inj=None, **sup_kw):
    srv = CNNServer(plan_set, max_wait_ms=2.0, faults=inj)
    sup_kw.setdefault("backoff_s", 0.01)
    sup_kw.setdefault("backoff_max_s", 0.05)
    return Supervisor(srv, **sup_kw)


def _submit_retrying(sup, x, *, tries=2000):
    """Offer a request again through a restart gap, never dropping it."""
    for _ in range(tries):
        try:
            return sup.submit(x)
        except (ServerCrashed, RuntimeError):
            time.sleep(0.002)
    raise AssertionError("restart gap never closed")


# ------------------------------------------------- backoff/breaker units
def test_backoff_bounded_exponential_with_jitter(served):
    _, _, _, ps = served
    sup = Supervisor(CNNServer(ps), backoff_s=0.05, backoff_max_s=2.0,
                     jitter=0.25, seed=3)
    delays = [sup._next_backoff(n) for n in range(1, 12)]
    for n, d in enumerate(delays, start=1):
        base = min(2.0, 0.05 * 2 ** (n - 1))
        assert base <= d <= base * 1.25, (n, d)  # jittered, never shrunk
    assert max(delays) <= 2.0 * 1.25             # bounded at the cap
    # deterministic: the same seed replays the same jitter sequence
    sup2 = Supervisor(CNNServer(ps), backoff_s=0.05, backoff_max_s=2.0,
                      jitter=0.25, seed=3)
    assert delays == [sup2._next_backoff(n) for n in range(1, 12)]


def test_breaker_counts_only_crashes_inside_window(served):
    _, _, _, ps = served
    sup = Supervisor(CNNServer(ps), max_restarts=2, window_s=10.0)
    for t in (0.0, 1.0):
        sup._crash_times.append(t)
        assert not sup._breaker_open(t)  # 1st, 2nd crash: restart
    sup._crash_times.append(2.0)
    assert sup._breaker_open(2.0)        # 3rd inside the window: open
    # crashes older than the window no longer count against the budget
    sup2 = Supervisor(CNNServer(ps), max_restarts=2, window_s=10.0)
    for t in (0.0, 1.0, 100.0):
        sup2._crash_times.append(t)
    assert not sup2._breaker_open(100.0)
    assert sup2._crash_times == [100.0]  # pruned to the window


def test_supervisor_validates_config(served):
    _, _, _, ps = served
    with pytest.raises(ValueError, match="max_restarts"):
        Supervisor(CNNServer(ps), max_restarts=0)
    with pytest.raises(ValueError, match="backoff"):
        Supervisor(CNNServer(ps), backoff_s=1.0, backoff_max_s=0.5)


# --------------------------------------------------- supervised restart
def test_restart_requeues_and_books_span_the_crash(served):
    """One transient dispatcher kill: the supervisor restarts, requeues
    the admitted-but-undispatched requests, every future resolves
    bit-identical, and a single ServerStats balances the accounting
    identity across the whole supervised run."""
    _, _, x, ps = served
    inj = FaultInjector(kill_after_dispatches=1, kills=1)
    sup = _supervised(ps, inj=inj)
    ref = [np.asarray(ps.plans[1].serve(x[i : i + 1])) for i in range(10)]
    with sup:
        sup.warmup()
        futs = []
        for i in range(10):
            futs.append(_submit_retrying(sup, x[i : i + 1]))
            time.sleep(0.004)  # spaced past max_wait: several dispatcher
            # ticks run, so the kill seam fires with work queued behind it
        timeout = sup.request_timeout_s()
        for i, f in enumerate(futs):
            np.testing.assert_array_equal(
                np.asarray(f.result(timeout=timeout)), ref[i])
        sup.stats.assert_accounting()
        assert sup.health()["status"] == "ready"
    assert sup.stats.restarts == 1
    assert sup.stats.requeued >= 1        # the kill fires with queued work
    assert inj.restarts == 1              # recovery went through supervision
    assert sup.retraces_after_warmup == 0  # plans stayed compiled


def test_crash_loop_opens_breaker_and_fails_typed(served):
    """An unbounded kill loop: after max_restarts crashes inside the
    window the breaker opens — health() is 'failed' with a reason, the
    stranded requests fail typed ServerCrashed, and the books balance."""
    _, _, x, ps = served
    inj = FaultInjector(kill_after_dispatches=0)  # every tick kills
    sup = _supervised(ps, inj=inj, max_restarts=2, backoff_s=0.005,
                      backoff_max_s=0.01)
    with sup:
        fut = _submit_retrying(sup, x[:1])
        deadline = time.monotonic() + 10
        while sup.health()["status"] != "failed":
            assert time.monotonic() < deadline, "breaker never opened"
            try:
                sup.submit(x[:1])
            except Exception:
                pass
            time.sleep(0.002)
        h = sup.health()
        assert h["status"] == "failed" and "crash loop" in h["reason"]
        assert sup.stats.restarts == 2    # restarted twice, then held down
        with pytest.raises(ServerCrashed):
            fut.result(timeout=5)
        sup.stats.assert_accounting()


def test_stop_during_backoff_interrupts_and_cancels(served):
    """stop() landing mid-backoff returns immediately (no sleep-out of
    the delay) and the crash-stranded futures get CancelledError."""
    _, _, x, ps = served
    inj = FaultInjector(kill_after_dispatches=0, kills=1)
    sup = _supervised(ps, inj=inj, backoff_s=30.0, backoff_max_s=30.0)
    sup.start()
    fut = sup.submit(x[:1])
    deadline = time.monotonic() + 5
    while sup.health()["status"] != "restarting":
        assert time.monotonic() < deadline, "kill never delivered"
        time.sleep(0.002)
    t0 = time.monotonic()
    sup.stop()
    assert time.monotonic() - t0 < 5.0    # did not sleep out the 30s backoff
    with pytest.raises(Exception) as ei:
        fut.result(timeout=1)
    assert "Cancelled" in type(ei.value).__name__
    sup.stats.assert_accounting()


def test_stop_is_idempotent(served):
    _, _, x, ps = served
    sup = _supervised(ps)
    with sup:
        f = sup.submit(x[:1])
        f.result(timeout=30)
    sup.stop()   # second stop after the context exit: no-op, no raise
    sup.stop()
    sup.stats.assert_accounting()


def test_at_most_once_inflight_fails_typed_undispatched_requeues(served):
    """The §15 at-most-once split: a request *inside a dispatch* when the
    dispatcher dies fails typed ServerCrashed (never re-executed), while
    an admitted-but-undispatched request rides the requeue and completes
    after the restart."""
    _, _, x, ps = served

    class _MidDispatchKill:
        """Duck-typed injector: the first pre_serve (inside _run, with
        the batch already marked in-flight) holds the dispatcher long
        enough for a second request to queue behind it, then dies with a
        BaseException — which skips the Exception-level bisect isolation
        and crashes the loop itself."""

        def __init__(self):
            self.armed = True
            self.restarts = 0

        def on_tick(self, n):
            pass

        def on_restart(self, restarts):
            self.restarts = restarts

        def pre_bucket(self, b):
            pass

        def pre_dispatch(self, pendings):
            pass

        def pre_serve(self, pendings, xb):
            if self.armed:
                self.armed = False
                time.sleep(0.08)  # let the co-test request get admitted
                raise KeyboardInterrupt("dispatcher died mid-dispatch")
            return xb

        def post_serve(self, pendings, y):
            return y

    inj = _MidDispatchKill()
    sup = _supervised(ps, inj=inj)
    ref = np.asarray(ps.plans[1].serve(x[1:2]))
    with sup:
        sup.warmup()
        f_inflight = sup.submit(x[:1])
        time.sleep(0.03)              # f_inflight is inside the dispatch…
        f_queued = sup.submit(x[1:2])  # …while this one is still queued
        with pytest.raises(ServerCrashed):
            f_inflight.result(timeout=30)
        np.testing.assert_array_equal(
            np.asarray(f_queued.result(timeout=30)), ref)
        sup.stats.assert_accounting()
    assert sup.stats.restarts == 1 and inj.restarts == 1
    assert sup.stats.requeued == 1    # exactly the undispatched request
    assert sup.stats.failed >= 1      # exactly the in-flight one, typed


def test_requeue_rejects_crashed_unreaped_server(served):
    """requeue() into a crashed-but-unreaped server is a bug (the dead
    dispatcher would never drain it) — typed RuntimeError; after stop()
    reaps the thread the pre-start requeue path is allowed."""
    _, _, x, ps = served
    inj = FaultInjector(kill_after_dispatches=0, kills=1)
    srv = CNNServer(ps, max_wait_ms=2.0, faults=inj)
    stranded = []
    srv.on_crash = lambda exc, pend: stranded.extend(pend)
    with srv:
        srv.submit(x[:1])
        deadline = time.monotonic() + 5
        while not stranded:
            assert time.monotonic() < deadline, "kill never delivered"
            time.sleep(0.002)
        with pytest.raises(RuntimeError, match="reap"):
            srv.requeue(stranded)
        srv.stop(drain=False)             # reap the dead dispatcher
        assert srv.requeue(stranded) == 1  # pre-start requeue allowed
        srv.start(fresh_stats=False)
        np.testing.assert_array_equal(
            np.asarray(stranded[0].future.result(timeout=30)),
            np.asarray(ps.plans[1].serve(x[:1])))
    srv.stats.assert_accounting()


# ------------------------------------------------------------ hot reload
def test_hot_reload_swaps_atomically_and_corrupt_leaves_old(served, tmp_path):
    """reload(): a verified checkpoint swaps the plan set mid-traffic
    with zero retraces; a corrupted latest checkpoint fails typed with
    the old plan still serving bit-identical; fallback=True walks back
    to the newest verifiable step."""
    model, qparams, x, ps = served
    ckpt_save(tmp_path, 1, qparams)
    ckpt_save(tmp_path, 2, qparams)
    srv = CNNServer(ps, max_wait_ms=2.0)
    sup = Supervisor(
        srv,
        rebuild=lambda tree: model.plan_set(tree, max_batch=4, tune="off"),
        template=qparams,
    )
    with sup:
        sup.warmup()
        y0 = np.asarray(sup.submit(x[:1]).result(timeout=30))
        step, fp = sup.reload(tmp_path)
        assert step == 2 and fp == ps.fingerprint
        np.testing.assert_array_equal(
            np.asarray(sup.submit(x[:1]).result(timeout=30)), y0)
        assert sup.retraces_after_warmup == 0  # warmed before the swap
        corrupt_checkpoint(tmp_path, step=2, mode="flip")
        with pytest.raises(CorruptCheckpointError):
            sup.reload(tmp_path)
        assert sup.reload_failures == 1
        np.testing.assert_array_equal(  # old plan kept serving
            np.asarray(sup.submit(x[:1]).result(timeout=30)), y0)
        step3, _ = sup.reload(tmp_path, fallback=True)
        assert step3 == 1 and sup.stats.reloads == 2
        sup.stats.assert_accounting()


def test_reload_requires_rebuild_and_template(served, tmp_path):
    _, _, _, ps = served
    sup = Supervisor(CNNServer(ps))
    with pytest.raises(RuntimeError, match="rebuild"):
        sup.reload(tmp_path)


def test_swap_plan_set_validates_ladder(served):
    """The atomic swap refuses a plan set whose bucket ladder differs —
    the micro-batcher's aggregation targets would dangle."""
    model, qparams, _, ps = served
    other = model.plan_set(qparams, max_batch=2, tune="off")
    srv = CNNServer(ps, max_wait_ms=2.0)
    with srv:
        with pytest.raises(ValueError, match="ladder"):
            srv.swap_plan_set(other)


# ------------------------------------------------- kernel-fallback demote
def test_demote_after_strikes_probe_repromotes(served):
    """Per-bucket degradation: demote_after consecutive compiled-path
    faults demote exactly that bucket to its bit-compatible fallback
    (health 'degraded' with the reason), a transient single fault does
    NOT demote, and after the backend heals the probe_every-th dispatch
    re-promotes."""
    model, qparams, x, ps = served
    fallback = model.fallback_plan_set(qparams, ps)
    inj = FaultInjector()
    srv = CNNServer(ps, max_wait_ms=2.0, faults=inj, fallback=fallback,
                    demote_after=2, probe_every=2)
    ref3 = np.asarray(ps.serve(x[:3]))

    def roundtrip():
        return np.asarray(srv.submit(x[:3]).result(timeout=30))

    with srv:
        srv.warmup()
        inj.fail_bucket(4)
        with pytest.raises(Exception):  # strike 1: below the threshold —
            roundtrip()                 # bubbles to isolation, fails typed
        np.testing.assert_array_equal(roundtrip(), ref3)  # strike 2: demoted
        assert list(srv.demoted_buckets()) == [4]
        h = srv.health()
        assert h["status"] == "degraded" and 4 in h["demoted"]
        assert "bucket-4" in srv.demoted_buckets()[4]
        assert srv.stats.demotions == 1
        # innocent bucket keeps its compiled plan, bit-identical
        np.testing.assert_array_equal(
            np.asarray(srv.submit(x[:1]).result(timeout=30)),
            np.asarray(ps.plans[1].serve(x[:1])))
        # heal: the next probe (every 2nd demoted dispatch) re-promotes
        inj.heal_bucket(4)
        for _ in range(4):
            np.testing.assert_array_equal(roundtrip(), ref3)
            if not srv.demoted_buckets():
                break
        assert not srv.demoted_buckets()
        assert srv.stats.promotions == 1
        assert srv.health()["status"] == "ready"
        srv.stats.assert_accounting()
    assert inj.bucket_faults_fired >= 2


def test_fallback_closures_pin_fingerprint(served):
    """Degradation closures are pinned to the serving weights: building
    them against a differently-quantized model raises StalePlanError
    (serving different numbers under 'degraded' is corruption, not
    degradation)."""
    from repro.models.plan import StalePlanError, fallback_closures

    model, qparams, x, ps = served
    # same structure, different content: perturb one float leaf so the
    # params fingerprint no longer matches the serving plan set's
    flat, treedef = jax.tree_util.tree_flatten(qparams)
    for i, leaf in enumerate(flat):
        if hasattr(leaf, "dtype") and leaf.dtype == np.float32 and leaf.size:
            flat[i] = leaf + 1.0
            break
    other_q = jax.tree_util.tree_unflatten(treedef, flat)
    ref_model = SparseCNN(dataclasses.replace(model.cfg, kernel_mode="ref"))
    other_set = ref_model.plan_set(other_q, buckets=ps.buckets, tune="off")
    with pytest.raises(StalePlanError):
        fallback_closures(ps, other_set)
