"""Exact-math tests for the recurrent mixers.

The chunked/parallel training forms must match the naive sequential
recurrences to fp32 precision — these are the trickiest numerics in the
zoo (per-dimension data-dependent decay).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.recurrent import _causal_conv1d, wkv_chunked


def naive_wkv(r, k, v, wlog, u):
    """Literal sequential RWKV6 recurrence (fp64 for a tight oracle)."""
    b, s, h, d = r.shape
    r, k, v, w = [np.asarray(x, np.float64) for x in (r, k, v, jnp.exp(wlog))]
    u = np.asarray(u, np.float64)
    S = np.zeros((b, h, d, d))
    ys = np.zeros((b, s, h, d))
    for t in range(s):
        kt = k[:, t]  # (b,h,d)
        vt = v[:, t]
        rt = r[:, t]
        kv = kt[..., :, None] * vt[..., None, :]  # (b,h,d,d)
        ys[:, t] = np.einsum("bhk,bhkv->bhv", rt, S + u[None, :, :, None] * kv)
        S = w[:, t][..., :, None] * S + kv
    return ys, S


@pytest.mark.parametrize("s,chunk", [(16, 4), (17, 8), (32, 32), (7, 16)])
def test_wkv_chunked_matches_naive(s, chunk):
    b, h, d = 2, 3, 8
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 4)
    r = jax.random.normal(ks[0], (b, s, h, d))
    k = jax.random.normal(ks[1], (b, s, h, d))
    v = jax.random.normal(ks[2], (b, s, h, d))
    wlog = -jnp.exp(jax.random.normal(ks[3], (b, s, h, d)))  # <= 0
    u = 0.3 * jnp.ones((h, d))
    y, S = wkv_chunked(r, k, v, wlog, u, chunk=chunk)
    y_ref, S_ref = naive_wkv(r, k, v, wlog, u)
    np.testing.assert_allclose(np.asarray(y), y_ref, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(S), S_ref, rtol=2e-4, atol=2e-4)


def test_wkv_extreme_decay_stable():
    """Paper-of-record stability: huge decays must not produce inf/nan
    (all chunk exponents are <= 0 by construction)."""
    b, s, h, d = 1, 64, 2, 4
    key = jax.random.PRNGKey(1)
    r = jax.random.normal(key, (b, s, h, d))
    k = jax.random.normal(key, (b, s, h, d))
    v = jax.random.normal(key, (b, s, h, d))
    wlog = jnp.full((b, s, h, d), -50.0)  # near-instant forget
    u = jnp.ones((h, d))
    y, S = wkv_chunked(r, k, v, wlog, u, chunk=16)
    assert np.isfinite(np.asarray(y)).all() and np.isfinite(np.asarray(S)).all()
    # with total forgetting the state holds only the newest kv (it enters
    # un-decayed; decay applies on the *next* step): y_t = bonus_t + prev term
    y_diag = jnp.einsum("bshd,bshd->bsh", r, u[None, None] * k)[..., None] * v
    prev = jnp.einsum("bshd,bshd->bsh", r[:, 1:], k[:, :-1])[..., None] * v[:, :-1]
    want = np.array(y_diag)
    want[:, 1:] += np.asarray(prev)
    np.testing.assert_allclose(np.asarray(y), want, rtol=1e-4, atol=1e-4)


def test_causal_conv1d_matches_numpy():
    b, s, d, w = 2, 10, 3, 4
    key = jax.random.PRNGKey(2)
    u = jax.random.normal(key, (b, s, d))
    kern = jax.random.normal(jax.random.PRNGKey(3), (w, d))
    got = np.asarray(_causal_conv1d(u, kern))
    un = np.asarray(u)
    kn = np.asarray(kern)
    want = np.zeros_like(un)
    for t in range(s):
        for i in range(w):
            ti = t - (w - 1) + i
            if ti >= 0:
                want[:, t] += un[:, ti] * kn[i]
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_rglru_assoc_scan_matches_sequential():
    """Full-seq associative scan == step-by-step decode recurrence."""
    import dataclasses

    from repro.configs import smoke_config
    from repro.models.recurrent import RGLRUBlock

    cfg = smoke_config("recurrentgemma-2b")
    blk = RGLRUBlock(cfg)
    from repro.models.common import init_params

    p = init_params(blk.defs(), jax.random.PRNGKey(0))
    x = 0.5 * jax.random.normal(jax.random.PRNGKey(1), (2, 12, cfg.d_model))
    y_full, state = blk(p, x)
    # sequential: feed one token at a time through decode
    cache = blk.init_cache(2, 12, jnp.float32)
    ys = []
    for t in range(12):
        y_t, cache = blk.decode(p, x[:, t : t + 1], cache, t)
        ys.append(y_t)
    y_seq = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(
        np.asarray(y_full, np.float32), np.asarray(y_seq, np.float32),
        rtol=5e-3, atol=5e-3,
    )
    np.testing.assert_allclose(
        np.asarray(state["h"]), np.asarray(cache["h"]), rtol=5e-3, atol=5e-3
    )


def test_rwkv_block_decode_matches_timemix():
    """RWKV time-mix full-seq == sequential decode through the same params."""
    from repro.configs import smoke_config
    from repro.models.common import init_params
    from repro.models.recurrent import RWKV6Block

    cfg = smoke_config("rwkv6-3b")
    blk = RWKV6Block(cfg)
    p = init_params(blk.defs(), jax.random.PRNGKey(0))
    x = 0.5 * jax.random.normal(jax.random.PRNGKey(1), (1, 8, cfg.d_model))
    y_full, tm_cache = blk.time_mix(p["tm"], x, jnp.zeros((1, cfg.d_model)))
    cache = blk.init_cache(1, 8, jnp.float32)
    ys = []
    for t in range(8):
        y_t, cache = blk.time_mix_decode(p["tm"], x[:, t : t + 1], cache)
        ys.append(y_t)
    y_seq = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(
        np.asarray(y_full, np.float32), np.asarray(y_seq, np.float32),
        rtol=5e-3, atol=5e-3,
    )
    np.testing.assert_allclose(
        np.asarray(tm_cache["s"]), np.asarray(cache["s"]), rtol=5e-3, atol=5e-3
    )
