"""Distributed-correctness tests on a small multi-device host mesh.

These run in a SUBPROCESS with --xla_force_host_platform_device_count=8 so
the main test process keeps its single-device view (per the dry-run spec,
the device-count override must never leak into other tests).

Checks, numerically (not just compile):
  - sharded train_step == single-device train_step (DP+TP equivalence)
  - sharded decode_step == single-device decode_step
  - the dry-run harness itself succeeds end-to-end on a small mesh
"""
import json
import os
import pathlib
import subprocess
import sys
import textwrap

import pytest

# Multi-device subprocess checks: each test compiles a sharded program in a
# fresh 8-device interpreter — the slowest tier-1 block (see pyproject slow
# marker). CI runs `-m "not slow"`; the full tier-1 suite still runs these.
pytestmark = pytest.mark.slow

REPO = pathlib.Path(__file__).resolve().parents[1]


def run_sub(code: str) -> dict:
    """Run python code with 8 fake host devices; return parsed last line."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = str(REPO / "src")
    out = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True, env=env,
        timeout=540,
    )
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr[-3000:]}"
    return json.loads(out.stdout.strip().splitlines()[-1])


COMMON = """
import json
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import make_batch, smoke_config
from repro.models.common import sharding_rules
from repro.models.model import LM
from repro.optim.adamw import OptConfig, init_state
from repro.sharding.rules import make_rules
from repro.train.step import make_serve_step, make_train_step
assert len(jax.devices()) == 8
mesh = jax.make_mesh((2, 4), ("data", "model"))
"""


@pytest.mark.parametrize("arch", ["codeqwen1.5-7b", "internvl2-2b"])
def test_sharded_train_step_matches_single_device(arch):
    code = COMMON + textwrap.dedent(f"""
    cfg = smoke_config("{arch}")
    import dataclasses
    cfg = dataclasses.replace(cfg, d_model=64, d_ff=128, vocab_size=512, num_layers=2)
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    opt = init_state(params, OptConfig())
    batch = make_batch(cfg, batch=4, seq=32)
    fn = make_train_step(model, OptConfig())
    # single device reference
    p_ref, _, m_ref = jax.jit(fn)(params, opt, batch, jnp.int32(0))
    # sharded
    rules = make_rules(cfg, tp=4, mode="train")
    pspecs = model.pspecs(rules)
    psh = jax.tree_util.tree_map(lambda s: NamedSharding(mesh, s), pspecs,
                                 is_leaf=lambda x: isinstance(x, P))
    osh = {{"m": psh, "v": psh, "count": NamedSharding(mesh, P())}}
    if "master" in opt:
        osh["master"] = psh
    bsh = {{k: NamedSharding(mesh, P(("data",), *([None]*(v.ndim-1)))) for k, v in batch.items()}}
    with mesh, sharding_rules(rules):
        p_sh, _, m_sh = jax.jit(fn, in_shardings=(psh, osh, bsh, NamedSharding(mesh, P())))(
            params, opt, batch, jnp.int32(0))
    diffs = [float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32))))
             for a, b in zip(jax.tree_util.tree_leaves(p_ref), jax.tree_util.tree_leaves(p_sh))]
    print(json.dumps({{"loss_ref": float(m_ref["loss"]), "loss_sh": float(m_sh["loss"]),
                       "max_param_diff": max(diffs)}}))
    """)
    r = run_sub(code)
    assert abs(r["loss_ref"] - r["loss_sh"]) < 5e-3, r
    assert r["max_param_diff"] < 5e-3, r


def test_sharded_decode_matches_single_device():
    code = COMMON + textwrap.dedent("""
    cfg = smoke_config("qwen2-72b")
    model = LM(cfg)
    params = model.constrain(model.init(jax.random.PRNGKey(0)))
    served = model.compress(params)
    cache = model.init_cache(batch_size=4, max_len=32)
    batch = make_batch(cfg, batch=4, seq=1, kind="serve")
    fn = make_serve_step(model)
    lg_ref, _ = jax.jit(fn)(served, cache, batch, jnp.int32(7))
    rules = make_rules(cfg, tp=4, mode="decode")
    with mesh, sharding_rules(rules):
        lg_sh, _ = jax.jit(fn)(served, cache, batch, jnp.int32(7))
    d = float(jnp.max(jnp.abs(lg_ref.astype(jnp.float32) - lg_sh.astype(jnp.float32))))
    print(json.dumps({"max_logit_diff": d}))
    """)
    r = run_sub(code)
    assert r["max_logit_diff"] < 5e-2, r  # bf16 reduction-order noise


def test_dryrun_harness_small_mesh():
    """The dry-run lowering path works end-to-end (tiny config, 2x4 mesh)."""
    code = COMMON + textwrap.dedent("""
    import dataclasses
    from repro.launch import dryrun as dr
    cfg = smoke_config("qwen2.5-32b")
    rules = make_rules(cfg, tp=4, mode="train")
    compiled = dr._lower(cfg, "train_4k", mesh, rules, seq_len=64, global_batch=4)
    cost = dr.cost_analysis_dict(compiled)
    coll = dr.collective_bytes(compiled.as_text())
    mem = compiled.memory_analysis()
    print(json.dumps({"flops": cost.get("flops", 0),
                      "coll": coll["total_bytes"],
                      "temp": getattr(mem, "temp_size_in_bytes", 0)}))
    """)
    r = run_sub(code)
    assert r["flops"] > 0
    assert r["coll"] > 0  # TP on a 4-way model axis must emit collectives
