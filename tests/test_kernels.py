"""Per-kernel validation: sweep shapes/dtypes, assert_allclose vs ref.py.

Pallas kernels run in interpret mode on CPU (the kernel body executes in
Python), so these tests validate the exact code that compiles for TPU.
"""
import itertools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.quant import dynamic_act_scale, quantize, quantize_dbb
from repro.core.vdbb import DBBFormat, dbb_encode
from repro.kernels import ops, ref
from repro.kernels.vdbb_matmul import vdbb_matmul_bw, vdbb_matmul_tc
from repro.xla_utils import cost_analysis_dict


def _mk(m, k, n, nnz, group, dtype, seed=0):
    """Operands for one sweep point. dtype=int8 quantizes both operands
    (per-tensor act, per-channel weight — DESIGN.md §8); the kernels then
    run the exact int32-accumulator path."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    a = jax.random.normal(k1, (m, k), jnp.float32)
    w = jax.random.normal(k2, (k, n), jnp.float32)
    fmt = DBBFormat(8, nnz, group)
    dw = dbb_encode(w, fmt, prune=True)
    if dtype == jnp.int8:
        return quantize(a, dynamic_act_scale(a)), quantize_dbb(dw).as_dbb(), fmt
    dw = jax.tree_util.tree_map(
        lambda x: x.astype(dtype) if x.dtype == jnp.float32 else x, dw
    )
    return a.astype(dtype), dw, fmt


TOLS = {jnp.float32: dict(rtol=1e-4, atol=1e-4), jnp.bfloat16: dict(rtol=2e-2, atol=2e-2)}


def _assert_matches_ref(got, a, dw, idx, fmt, dtype):
    """fp dtypes: allclose vs the fp oracle; int8: bit-exact vs the exact
    int32 integer oracle."""
    if dtype == jnp.int8:
        assert got.dtype == jnp.int32
        want = ref.vdbb_matmul_int_ref(a, dw.values, idx, fmt)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    else:
        want = ref.vdbb_matmul_ref(a, dw.values, idx, fmt)
        np.testing.assert_allclose(
            np.asarray(got, np.float32), np.asarray(want, np.float32), **TOLS[dtype]
        )


class TestVDBBMatmulTC:
    @pytest.mark.parametrize(
        "m,k,n,nnz",
        [
            (8, 64, 32, 1),
            (16, 128, 64, 3),
            (128, 256, 256, 4),
            (32, 512, 128, 8),  # dense bound — must equal plain matmul
            (64, 64, 32, 7),
        ],
    )
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.int8])
    def test_allclose_vs_ref(self, m, k, n, nnz, dtype):
        a, dw, fmt = _mk(m, k, n, nnz, "matrix", dtype)
        got = vdbb_matmul_tc(a, dw.values, dw.indices[:, :, 0], fmt, bm=32, bn=32, kb=2)
        _assert_matches_ref(got, a, dw, dw.indices[:, :, 0], fmt, dtype)

    @pytest.mark.slow
    @pytest.mark.parametrize("bm,bn,kb", [(8, 16, 1), (16, 32, 4), (64, 64, 8)])
    def test_tiling_sweep(self, bm, bn, kb):
        a, dw, fmt = _mk(64, 512, 128, 3, "matrix", jnp.float32, seed=7)
        got = vdbb_matmul_tc(a, dw.values, dw.indices[:, :, 0], fmt, bm=bm, bn=bn, kb=kb)
        want = ref.vdbb_matmul_ref(a, dw.values, dw.indices[:, :, 0], fmt)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    @pytest.mark.slow
    def test_flop_scaling_property(self):
        """Time-unrolled occupancy: executed FLOPs scale as nnz/bz."""
        m, k, n = 32, 256, 64
        flops = {}
        for nnz in (1, 2, 4, 8):
            a, dw, fmt = _mk(m, k, n, nnz, "matrix", jnp.float32)
            fn = lambda a, v, i: vdbb_matmul_tc(a, v, i, fmt, bm=32, bn=32, kb=2)
            compiled = jax.jit(fn).lower(a, dw.values, dw.indices[:, :, 0]).compile()
            flops[nnz] = cost_analysis_dict(compiled)["flops"]
        # main term 2*m*(k*nnz/8)*n dominates; allow the one-hot mux overhead
        for nnz in (1, 2, 4):
            ratio = flops[8] / flops[nnz]
            assert ratio > 8 / nnz * 0.55, (nnz, flops)
            assert flops[nnz] < flops[8], flops


class TestVDBBMatmulBW:
    @pytest.mark.parametrize(
        "m,k,n,nnz,group",
        [
            (8, 64, 32, 2, None),
            (16, 128, 64, 3, None),
            (64, 256, 128, 5, None),
            (16, 64, 64, 4, 8),  # grouped pattern goes through bw with repeat
            (8, 64, 32, 8, None),
        ],
    )
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.int8])
    def test_allclose_vs_ref(self, m, k, n, nnz, group, dtype):
        a, dw, fmt = _mk(m, k, n, nnz, group, dtype)
        got = ops.vdbb_matmul(a, dw, bm=8, bn=16, kb=2, interpret=True)
        g = fmt.group_size(n)
        idx = jnp.repeat(dw.indices, g, axis=2) if g > 1 else dw.indices
        _assert_matches_ref(got, a, dw, idx, fmt, dtype)

    def test_weight_bytes_compressed(self):
        """The kernel consumes the compressed stream: HBM weight operand is
        (nnz/bz + index) of the dense bytes."""
        a, dw, fmt = _mk(32, 512, 128, 2, None, jnp.float32)
        dense_bytes = 512 * 128 * 4
        vals_bytes = dw.values.size * 4
        assert vals_bytes == dense_bytes * fmt.nnz // fmt.bz


class TestDispatchAndProperties:
    def test_dispatch_matches_decode_matmul(self):
        for group, nnz, seed in itertools.product(["matrix", None], [1, 4, 6], [0, 3]):
            a, dw, fmt = _mk(16, 128, 32, nnz, group, jnp.float32, seed)
            got = ops.vdbb_matmul(a, dw, bm=16, bn=16, kb=2, interpret=True)
            want = ref.dbb_matmul_ref(a, dw)
            np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    @pytest.mark.slow
    def test_property_random_sweep(self):
        """Seeded property sweep (hypothesis unavailable offline): for random
        shapes/nnz, kernel == oracle and output is finite."""
        rng = np.random.RandomState(0)
        for trial in range(10):
            m = int(rng.choice([4, 8, 16]))
            kblocks = int(rng.randint(2, 9))
            n = int(rng.choice([16, 32]))
            nnz = int(rng.randint(1, 9))
            group = rng.choice(["matrix", None])
            a, dw, fmt = _mk(m, kblocks * 8, n, nnz, group, jnp.float32, seed=trial)
            got = ops.vdbb_matmul(a, dw, bm=m, bn=16, kb=1, interpret=True)
            want = ref.dbb_matmul_ref(a, dw)
            assert np.isfinite(np.asarray(got)).all()
            np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


class TestIm2colConv:
    @pytest.mark.parametrize(
        "n,h,w,c,f,kh", [(1, 8, 8, 8, 16, 3), (2, 6, 10, 4, 8, 3), (1, 12, 12, 8, 32, 5)]
    )
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.int8])
    def test_allclose_vs_refs(self, n, h, w, c, f, kh, dtype):
        k1, k2 = jax.random.split(jax.random.PRNGKey(1))
        x = jax.random.normal(k1, (n, h, w, c), jnp.float32)
        wk = jax.random.normal(k2, (kh, kh, c, f), jnp.float32)
        if dtype == jnp.int8:
            # int8 operand path: exact int32 accumulate vs the dtype-
            # preserving explicit-im2col integer oracle
            x = quantize(x, dynamic_act_scale(x))
            wk = quantize(wk, dynamic_act_scale(wk))
            got = ops.fused_im2col_conv(x, wk, bf=8, interpret=True)
            assert got.dtype == jnp.int32
            cols = ref.im2col_explicit(x, kh, kh)
            want = jnp.einsum(
                "nhwk,kf->nhwf",
                cols.astype(jnp.int32),
                wk.reshape(kh * kh * c, f).astype(jnp.int32),
            )
            np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
            return
        x, wk = x.astype(dtype), wk.astype(dtype)
        got = ops.fused_im2col_conv(x, wk, bf=8, interpret=True)
        want = ref.conv_lax_ref(x, wk)
        want2 = ref.im2col_conv_ref(x, wk)
        np.testing.assert_allclose(
            np.asarray(got, np.float32), np.asarray(want, np.float32), **TOLS[dtype]
        )
        np.testing.assert_allclose(
            np.asarray(want2, np.float32), np.asarray(want, np.float32), **TOLS[dtype]
        )

    def test_bandwidth_magnification(self):
        """The fused kernel's HBM activation bytes ~= raw tile (1x), vs kh*kw
        duplication for explicit im2col — the paper's magnifier effect."""
        x = jnp.zeros((1, 16, 16, 32), jnp.float32)
        cols = ref.im2col_explicit(x, 3, 3)
        assert cols.size == 9 * x.size  # footprint blow-up the unit avoids
