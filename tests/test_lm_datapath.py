"""LM VDBB datapath: compressed/quantized routing, plans, parity (§13).

The PR-8 contract: an LM forward over DBB-compressed params must execute
the *compressed* matmul formulation — ``dbb_decode`` never runs on the
hot path (asserted with a decode spy, mirroring the jnp.pad spy in
test_fused_epilogue.py) — and a frozen ``LM.plan()`` must serve
bit-identical to the jitted unplanned forward.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.quant import QuantDBBWeight
from repro.core.vdbb import DBBFormat, DBBWeight, dbb_encode
from repro.models import common
from repro.models.model import LM


def _rel(a, b):
    return float(jnp.linalg.norm(a - b) / jnp.linalg.norm(b))


@pytest.fixture(scope="module")
def tiny():
    """qwen2-tiny: params, constrained + compressed + calibrated forms."""
    cfg = get_config("qwen2-tiny")
    model = LM(cfg)
    params = model.constrain(model.init(jax.random.PRNGKey(0)))
    tokens = jax.random.randint(
        jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab_size)
    batch = {"tokens": tokens}
    cparams = model.compress(params)
    _, stats = model.forward(batch=batch, params=cparams,
                             collect_act_stats=True)
    qparams = model.quantize(cparams, stats)
    return dict(cfg=cfg, model=model, params=params, cparams=cparams,
                qparams=qparams, stats=stats, tokens=tokens, batch=batch)


# ---------------------------------------------------------------------------
# the bugfix: no dense materialization on the compressed hot path
# ---------------------------------------------------------------------------


class TestNoDenseFallback:
    def test_compressed_forward_never_decodes(self, tiny, monkeypatch):
        """A compressed ('matrix'-group) LM forward must route every
        projection through the gather formulation — the pre-PR-8
        ``x @ dbb_decode(w)`` fallback is a silent densification."""
        calls = []
        real = common.dbb_decode
        monkeypatch.setattr(
            common, "dbb_decode",
            lambda *a, **k: (calls.append(1), real(*a, **k))[1])
        logits = tiny["model"].forward(tiny["cparams"], tiny["batch"])
        assert logits.shape[-1] == tiny["cfg"].padded_vocab
        assert not calls, "compressed forward materialized a dense weight"

    def test_quantized_forward_never_decodes(self, tiny, monkeypatch):
        calls = []
        real = common.dbb_decode
        monkeypatch.setattr(
            common, "dbb_decode",
            lambda *a, **k: (calls.append(1), real(*a, **k))[1])
        tiny["model"].forward(tiny["qparams"], tiny["batch"])
        assert not calls

    def test_bw_weight_decodes(self, monkeypatch):
        """Positive control: a per-column ('bw') pattern has no shared
        gather layout, so apply_linear documents dbb_decode as its only
        ref formulation — the spy must fire there."""
        calls = []
        real = common.dbb_decode
        monkeypatch.setattr(
            common, "dbb_decode",
            lambda *a, **k: (calls.append(1), real(*a, **k))[1])
        w = jax.random.normal(jax.random.PRNGKey(0), (32, 16))
        dw = dbb_encode(w, DBBFormat(8, 3, None), prune=True)
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 32))
        common.apply_linear(x, dw)
        assert calls


# ---------------------------------------------------------------------------
# forward parity: compressed / quantized / ref-vs-pallas
# ---------------------------------------------------------------------------


class TestForwardParity:
    def test_compressed_matches_dense(self, tiny):
        """The gather formulation contracts nnz-per-block instead of the
        zero-padded K — same MACs in a different order, so fp32 parity is
        tight but not bitwise."""
        dense = tiny["model"].forward(tiny["params"], tiny["batch"])
        comp = tiny["model"].forward(tiny["cparams"], tiny["batch"])
        assert _rel(comp, dense) < 1e-5

    def test_quantized_within_5pct(self, tiny):
        """Same end-to-end INT8 accuracy gate as the CNN (test_quant)."""
        dense = tiny["model"].forward(tiny["params"], tiny["batch"])
        q = tiny["model"].forward(tiny["qparams"], tiny["batch"])
        assert _rel(q, dense) < 0.05

    def test_pallas_matches_ref(self, tiny):
        pcfg = dataclasses.replace(tiny["cfg"], kernel_mode="pallas")
        pmodel = LM(pcfg)
        ref_c = tiny["model"].forward(tiny["cparams"], tiny["batch"])
        pal_c = pmodel.forward(tiny["cparams"], tiny["batch"])
        assert _rel(pal_c, ref_c) < 1e-5
        # quantized: both formulations sum the same int32 products
        ref_q = tiny["model"].forward(tiny["qparams"], tiny["batch"])
        pal_q = pmodel.forward(tiny["qparams"], tiny["batch"])
        np.testing.assert_array_equal(np.asarray(pal_q), np.asarray(ref_q))


# ---------------------------------------------------------------------------
# quantize lifecycle
# ---------------------------------------------------------------------------


class TestQuantizeLifecycle:
    def test_leaves_quantized_with_act_scales(self, tiny):
        """Every compressed projection leaf becomes QuantDBBWeight and the
        calibration attaches an ``<leaf>_aq`` sibling (stacked leaves get
        one scale per layer group)."""
        lp = tiny["qparams"]["layers"]["b0"]
        for name in ("wq", "wk", "wv", "wo"):
            assert isinstance(lp["mixer"][name], QuantDBBWeight)
            aq = lp["mixer"][f"{name}_aq"]
            assert aq.shape == (tiny["cfg"].num_groups,)
        for name in ("w_up", "w_gate", "w_down"):
            assert isinstance(lp["mlp"][name], QuantDBBWeight)
            assert lp["mlp"][f"{name}_aq"].shape == (tiny["cfg"].num_groups,)
        # embeddings and lm_head are not DBB-tagged: they stay dense fp
        assert isinstance(tiny["qparams"]["lm_head"], jnp.ndarray)
        assert "lm_head_aq" not in tiny["qparams"]

    def test_quantize_without_stats_is_dynamic(self, tiny):
        """No calibration → no ``_aq`` siblings; forward still works
        (dynamic per-call act scales)."""
        qp = tiny["model"].quantize(tiny["cparams"])
        assert "lm_head_aq" not in qp
        assert "wq_aq" not in qp["layers"]["b0"]["mixer"]
        dense = tiny["model"].forward(tiny["params"], tiny["batch"])
        q = tiny["model"].forward(qp, tiny["batch"])
        assert _rel(q, dense) < 0.05

    def test_act_stat_names_are_scoped(self, tiny):
        names = {s.name for s in tiny["stats"]}
        assert "g0.b0.mixer.wq" in names
        assert "g0.b0.mlp.w_down" in names
        assert "lm_head" in names


# ---------------------------------------------------------------------------
# frozen LM plans
# ---------------------------------------------------------------------------


class TestLMPlan:
    def test_plan_bit_identical_to_forward(self, tiny):
        model, tokens = tiny["model"], tiny["tokens"]
        f = jax.jit(lambda p, t: model.forward(p, {"tokens": t}))
        for params in (tiny["cparams"], tiny["qparams"]):
            plan = model.plan(params, batch=2, seq=16, tune="off")
            np.testing.assert_array_equal(
                np.asarray(plan(tokens)), np.asarray(f(params, tokens)))

    def test_plan_stages(self, tiny):
        plan = tiny["model"].plan(tiny["cparams"], batch=2, seq=16,
                                  tune="off")
        names = [lp.name for lp in plan.layers]
        assert names[0] == "embed" and names[-1] == "head"
        assert "g0.b0" in names and "g1.b0" in names

    def test_stale_plan_raises(self, tiny):
        from repro.models.plan import StalePlanError

        plan = tiny["model"].plan(tiny["cparams"], batch=2, seq=16,
                                  tune="off")
        plan.check(tiny["cparams"])  # same params: fine
        with pytest.raises(StalePlanError):
            plan.check(tiny["qparams"])

    def test_unsupported_configs_raise(self, tiny):
        xcfg = dataclasses.replace(tiny["cfg"], cross_attn=True)
        with pytest.raises(NotImplementedError):
            LM(xcfg).plan(tiny["cparams"], batch=2, seq=16, tune="off")


# ---------------------------------------------------------------------------
# satellite bugfixes
# ---------------------------------------------------------------------------


class TestRaggedKPlan:
    def test_make_plan_rejects_ragged_k(self):
        """in_features not a multiple of bz used to silently floor-divide
        into a wrong frozen kb; it must be a clear error."""
        from repro.core.sparse_linear import DBBLinear

        fmt = DBBFormat(8, 3, "matrix")
        lin = DBBLinear(24, 32, fmt, kernel_mode="pallas")
        dw = lin.compress_params(lin.init(jax.random.PRNGKey(0)))
        ragged = dataclasses.replace(lin, in_features=20)
        with pytest.raises(ValueError, match="not a multiple"):
            ragged.make_plan(dw, batch=16, tune="off")
        run, tiles = lin.make_plan(dw, batch=16, tune="off")  # exact K: fine
        assert tiles

    def test_ref_mode_unaffected(self):
        from repro.core.sparse_linear import DBBLinear

        fmt = DBBFormat(8, 3, "matrix")
        lin = DBBLinear(24, 32, fmt, kernel_mode="ref")
        dw = lin.compress_params(lin.init(jax.random.PRNGKey(0)))
        ragged = dataclasses.replace(lin, in_features=20)
        run, tiles = ragged.make_plan(dw, batch=16, tune="off")
        assert tiles == {}  # ref mode never freezes pallas tiles


class TestMoEAuxLoss:
    def test_uniform_router_pins_one(self):
        """The importance loss ``E · Σ frac²`` is minimized at exactly 1.0
        by a uniform router (the docstring used to claim it was an entropy
        regularizer)."""
        from repro.models.mlp import MoEMLP

        cfg = dataclasses.replace(
            get_config("qwen2-tiny"), num_experts=8, top_k=2)
        moe = MoEMLP(cfg)
        p = {"router": jnp.zeros((cfg.d_model, cfg.num_experts))}
        x = jax.random.normal(jax.random.PRNGKey(0), (2, 16, cfg.d_model))
        np.testing.assert_allclose(
            float(moe.aux_loss(p, x)), 1.0, rtol=1e-6)

    def test_concentrated_router_exceeds_one(self):
        from repro.models.mlp import MoEMLP

        cfg = dataclasses.replace(
            get_config("qwen2-tiny"), num_experts=8, top_k=2)
        moe = MoEMLP(cfg)
        w = jnp.zeros((cfg.d_model, cfg.num_experts)).at[:, 0].set(50.0)
        x = jnp.ones((2, 16, cfg.d_model))
        assert float(moe.aux_loss({"router": w}, x)) > 4.0
