"""Per-architecture smoke tests: reduced config of the same family, one
forward + one train(grad) step + one decode step on CPU; asserts output
shapes and finiteness (no NaNs), and that the DBB constraint holds.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, make_batch, smoke_config
from repro.core.vdbb import satisfies_dbb
from repro.models import LM

ARCH_NAMES = list(ARCHS)
# grad through the scan/recurrent archs dominates suite runtime; keep their
# forward/decode coverage in the fast subset but push the grad step to slow.
_HEAVY_GRAD = {"recurrentgemma-2b", "rwkv6-3b"}
ARCH_GRAD_PARAMS = [
    pytest.param(n, marks=pytest.mark.slow) if n in _HEAVY_GRAD else n
    for n in ARCH_NAMES
]


@pytest.fixture(scope="module")
def built():
    cache = {}

    def get(name):
        if name not in cache:
            cfg = smoke_config(name)
            m = LM(cfg)
            cache[name] = (cfg, m, m.init(jax.random.PRNGKey(0)))
        return cache[name]

    return get


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_forward_and_loss(built, name):
    cfg, m, params = built(name)
    batch = make_batch(cfg, batch=2, seq=32)
    logits = m.forward(params, batch)
    if cfg.frontend == "audio":
        assert logits.shape == (2, 32, cfg.num_codebooks * cfg.codebook_vocab)
    else:
        assert logits.shape == (2, 32, cfg.padded_vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    loss, _ = m.loss(params, batch)
    assert np.isfinite(float(loss))


@pytest.mark.parametrize("name", ARCH_GRAD_PARAMS)
def test_grad_step(built, name):
    cfg, m, params = built(name)
    batch = make_batch(cfg, batch=2, seq=32)
    g = jax.grad(lambda p: m.loss(p, batch)[0])(params)
    flat = jax.tree_util.tree_leaves(g)
    assert all(np.isfinite(np.asarray(x, np.float32)).all() for x in flat)
    # at least the embedding and one projection get nonzero grads
    assert any(float(jnp.abs(x).max()) > 0 for x in flat)


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_decode_step(built, name):
    cfg, m, params = built(name)
    cache = m.init_cache(batch_size=2, max_len=64)
    batch = make_batch(cfg, batch=2, seq=1, kind="serve")
    logits, new_cache = m.decode_step(params, cache, batch, jnp.int32(5))
    assert logits.shape[0:2] == (2, 1)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    # cache structure preserved
    jax.tree_util.tree_map(
        lambda a, b: (_ for _ in ()).throw(AssertionError((a.shape, b.shape)))
        if a.shape != b.shape
        else None,
        cache,
        new_cache,
    )


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_dbb_constraint_holds(built, name):
    """constrain() projects every tagged weight onto the 3/8 block bound."""
    cfg, m, params = built(name)
    params = m.constrain(params)
    from repro.models.common import dbb_leaves, tree_get

    n_checked = 0
    for path, pdef in dbb_leaves(m.defs()):
        w = tree_get(params, path)
        w2 = np.asarray(w).reshape(-1, *pdef.shape[-2:])
        for i in range(min(2, w2.shape[0])):  # spot-check stacked layers
            assert satisfies_dbb(jnp.asarray(w2[i]), pdef.dbb), (name, path)
        n_checked += 1
    assert n_checked > 0, f"{name}: no DBB-tagged weights found"


def test_prefill_matches_decode_gqa():
    """Prefill-then-decode == full forward on the next token (qwen2 family)."""
    cfg = smoke_config("codeqwen1.5-7b")  # MHA: simplest cache semantics
    import dataclasses

    cfg = dataclasses.replace(cfg, dbb=None)
    m = LM(cfg)
    params = m.init(jax.random.PRNGKey(1))
    batch = make_batch(cfg, batch=1, seq=16)
    logits_full = m.forward(params, batch)
    # build cache from prefill of first 15 tokens, decode token 15
    pre = {"tokens": batch["tokens"][:, :15]}
    _, caches = m.forward(params, pre, return_cache=True)

    # prefill caches hold k/v of length 15; pad to decode capacity 16
    def pad_cache(a):
        if a.ndim >= 2 and a.shape[-3] == 15:  # (..., seq, kv, hd)
            pad = [(0, 0)] * a.ndim
            pad[-3] = (0, 1)
            return jnp.pad(a, pad)
        return a

    cache = jax.tree_util.tree_map(pad_cache, caches)
    step = {"tokens": batch["tokens"][:, 15:16]}
    logits_dec, _ = m.decode_step(params, cache, step, jnp.int32(15))
    np.testing.assert_allclose(
        np.asarray(logits_dec[0, 0], np.float32),
        np.asarray(logits_full[0, 15], np.float32),
        rtol=3e-2, atol=3e-2,
    )
