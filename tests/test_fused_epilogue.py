"""Fused epilogue + INT8-resident activations (DESIGN.md §9).

Bottom-up: the ``quant_epilogue_ref`` integer oracle; every bias/ReLU/
out_scale combination of the fused epilogue bit-exact against it across
the tc/bw matmul and fused conv kernels (interpret mode — the code that
compiles for TPU); the dense-stem epilogue; ``pick_tile`` default-tile
fallback; the head GEMM following ``cfg.kernel_mode`` with the tiny-M
reference fallback; the int8-resident SparseCNN chain (inter-layer
dtypes + agreement with the PR-3 per-layer-dequant path); and the
``epilogue_fused`` cost accounting.
"""
import dataclasses
import itertools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import quant
from repro.core.quant import QuantDBBWeight
from repro.core.sparse_linear import DBBLinear
from repro.core.vdbb import (
    DBBFormat,
    dbb_conv_costs,
    dbb_encode,
    dbb_encode_conv,
    dbb_gemm_costs,
)
from repro.kernels import core, ops, ref


def _gemm_case(group, m=16, k=64, n=32, nnz=3, seed=0):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    a = jax.random.normal(k1, (m, k))
    w = jax.random.normal(k2, (k, n))
    b = jax.random.normal(k3, (n,))
    fmt = DBBFormat(8, nnz, group)
    qw = quant.quantize_dbb(dbb_encode(w, fmt, prune=True))
    s_a = quant.dynamic_act_scale(a)
    return a, quant.quantize(a, s_a), s_a, b, qw


# ---------------------------------------------------------------------------
# the oracle itself
# ---------------------------------------------------------------------------


class TestEpilogueRef:
    def test_dataflow_order_and_dtypes(self):
        acc = jnp.array([[-300, 100], [50, -50]], jnp.int32)
        scale = jnp.array([0.01, 0.02], jnp.float32)
        bias = jnp.array([1.0, -1.0], jnp.float32)
        # dequant only
        y = ref.quant_epilogue_ref(acc, scale)
        np.testing.assert_allclose(np.asarray(y), [[-3.0, 2.0], [0.5, -1.0]])
        # + bias + relu
        y = ref.quant_epilogue_ref(acc, scale, bias=bias, relu=True)
        np.testing.assert_allclose(np.asarray(y), [[0.0, 1.0], [1.5, 0.0]])
        # + requant: int8 codes in ±127
        q = ref.quant_epilogue_ref(acc, scale, bias=bias, relu=True, out_scale=0.5)
        assert q.dtype == jnp.int8
        np.testing.assert_array_equal(np.asarray(q), [[0, 2], [3, 0]])

    def test_requant_clips_to_qmax(self):
        acc = jnp.array([[10_000_000, -10_000_000]], jnp.int32)
        q = ref.quant_epilogue_ref(acc, jnp.float32(1.0), out_scale=1.0)
        np.testing.assert_array_equal(np.asarray(q), [[127, -127]])


# ---------------------------------------------------------------------------
# fused kernels bit-exact against the oracle, all epilogue combinations
# ---------------------------------------------------------------------------

COMBOS = [
    (has_b, relu, has_q)
    for has_b, relu, has_q in itertools.product([False, True], repeat=3)
    if has_b or relu or has_q  # the bare-scales case is PR-3 coverage
]


def _check(got, want):
    if want.dtype == jnp.int8:
        assert got.dtype == jnp.int8
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    else:
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(want), rtol=1e-6, atol=1e-7
        )


class TestMatmulEpilogue:
    @pytest.mark.parametrize("group", ["matrix", None])
    @pytest.mark.parametrize("has_b,relu,has_q", COMBOS)
    def test_bit_exact_vs_oracle(self, group, has_b, relu, has_q):
        a, aq, s_a, b, qw = _gemm_case(group)
        bias = b if has_b else None
        out_s = 0.07 if has_q else None
        got = ops.quant_matmul(
            a, qw, s_a, bias=bias, relu=relu, out_scale=out_s,
            bm=8, bn=16, kb=2, interpret=True,
        )
        acc = quant.int_matmul_ref(aq, ref.dbb_decode(qw.as_dbb()))
        want = ref.quant_epilogue_ref(
            acc, s_a * qw.scales, bias=bias, relu=relu, out_scale=out_s
        )
        _check(got, want)

    def test_int8_resident_input_matches_fp_input(self):
        """Passing the already-quantized codes + scale == quantizing inside."""
        a, aq, s_a, b, qw = _gemm_case("matrix", seed=3)
        kw = dict(bias=b, relu=True, out_scale=0.05, bm=8, bn=16, kb=2,
                  interpret=True)
        np.testing.assert_array_equal(
            np.asarray(ops.quant_matmul(aq, qw, s_a, **kw)),
            np.asarray(ops.quant_matmul(a, qw, s_a, **kw)),
        )

    def test_int8_input_requires_scale(self):
        _, aq, _, _, qw = _gemm_case("matrix")
        with pytest.raises(ValueError, match="act_scale"):
            ops.quant_matmul(aq, qw, interpret=True)

    def test_fp_path_bias_relu_fused(self):
        """The fp (non-quantized) kernels fuse bias/ReLU too."""
        k1, k2, k3 = jax.random.split(jax.random.PRNGKey(1), 3)
        a = jax.random.normal(k1, (16, 64))
        w = jax.random.normal(k2, (64, 32))
        b = jax.random.normal(k3, (32,))
        dw = dbb_encode(w, DBBFormat(8, 4, "matrix"), prune=True)
        got = ops.vdbb_matmul(a, dw, bias=b, relu=True, bm=8, bn=16, kb=2,
                              interpret=True)
        want = jnp.maximum(ref.dbb_matmul_ref(a, dw) + b, 0)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-4)


class TestConvEpilogue:
    @pytest.mark.parametrize("group,stride", [("matrix", 1), (None, 2)])
    @pytest.mark.parametrize("has_b,relu,has_q", COMBOS)
    def test_bit_exact_vs_oracle(self, group, stride, has_b, relu, has_q):
        k1, k2, k3 = jax.random.split(jax.random.PRNGKey(5), 3)
        x = jax.random.normal(k1, (2, 8, 8, 8))
        w4 = jax.random.normal(k2, (3, 3, 8, 16))
        b = jax.random.normal(k3, (16,))
        qw = quant.quantize_dbb(
            dbb_encode_conv(w4, DBBFormat(8, 3, group), prune=True)
        )
        s_a = quant.dynamic_act_scale(x)
        xq = quant.quantize(x, s_a)
        bias = b if has_b else None
        out_s = 0.05 if has_q else None
        got = ops.quant_conv(
            x, qw, 3, 3, s_a, bias=bias, relu=relu, out_scale=out_s,
            stride=stride, bf=8, interpret=True,
        )
        acc = ref.sparse_conv_int_ref(xq, qw.as_dbb(), 3, 3, stride=stride)
        want = ref.quant_epilogue_ref(
            acc, s_a * qw.scales, bias=bias, relu=relu, out_scale=out_s
        )
        _check(got, want)

    def test_dense_stem_epilogue(self):
        """The dense im2col kernel's fused epilogue == its own fp32 output
        pushed through the same (standalone) epilogue ops — bit-exact."""
        k1, k2, k3 = jax.random.split(jax.random.PRNGKey(7), 3)
        x = jax.random.normal(k1, (2, 8, 8, 3))
        w4 = jax.random.normal(k2, (3, 3, 3, 16))
        b = jax.random.normal(k3, (16,))
        base = ops.fused_im2col_conv(x, w4, bf=8, interpret=True)
        got = ops.fused_im2col_conv(
            x, w4, bias=b, relu=True, out_scale=0.04, bf=8, interpret=True
        )
        want = quant.quantize(jnp.maximum(base + b, 0), 0.04)
        assert got.dtype == jnp.int8
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_sparse_conv_fp_bias_relu(self):
        x, k2 = jax.random.normal(jax.random.PRNGKey(8), (1, 8, 8, 8)), None
        w4 = jax.random.normal(jax.random.PRNGKey(9), (3, 3, 8, 16))
        b = jax.random.normal(jax.random.PRNGKey(10), (16,))
        dw = dbb_encode_conv(w4, DBBFormat(8, 4, "matrix"), prune=True)
        got = ops.sparse_conv(x, dw, 3, 3, bias=b, relu=True, bf=8, interpret=True)
        want = jnp.maximum(ref.sparse_conv_ref(x, dw, 3, 3) + b, 0)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# pick_tile: default tiles fall back to the largest dividing size
# ---------------------------------------------------------------------------


class TestPickTile:
    def test_values(self):
        assert core.pick_tile(200, 128) == 100
        assert core.pick_tile(96, 128) == 96
        assert core.pick_tile(128, 128) == 128
        assert core.pick_tile(7, 4) == 1
        assert core.pick_tile(320, 256) == 160
        # prime dim: one full tile, never a pathological 1-wide grid
        assert core.pick_tile(257, 128) == 257

    def test_resolve_tile_stays_strict(self):
        with pytest.raises(ValueError, match="does not tile"):
            core.resolve_tile(48, 32, "bm")

    def test_default_tiles_on_odd_shapes(self):
        """Shapes that used to raise at the default tiles now run."""
        k1, k2 = jax.random.split(jax.random.PRNGKey(0))
        a = jax.random.normal(k1, (200, 64))  # bm=128 did not divide 200
        w = jax.random.normal(k2, (64, 320))  # bn=256 did not divide 320
        dw = dbb_encode(w, DBBFormat(8, 4, "matrix"), prune=True)
        got = ops.vdbb_matmul(a, dw, interpret=True)
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(ref.dbb_matmul_ref(a, dw)),
            rtol=1e-4, atol=1e-4,
        )

    def test_explicit_bad_tile_pads_at_ops_strict_in_kernel(self):
        """§10 pad-to-tile: a non-dividing explicit tile no longer raises
        at the ops layer — the ragged M edge is zero-padded and sliced
        back off, bit-identically (int8 path: exact int32 accumulation).
        The kernel-level wrappers keep the strict contract."""
        a, aq, _, _, qw = _gemm_case("matrix")
        got = ops.vdbb_matmul(aq, qw.as_dbb(), bm=5, interpret=True)
        want = ops.vdbb_matmul(aq, qw.as_dbb(), interpret=True)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

        from repro.kernels.vdbb_matmul import vdbb_matmul_tc

        with pytest.raises(ValueError, match="does not tile"):
            vdbb_matmul_tc(aq, qw.values, qw.indices[:, :, 0], qw.fmt, bm=5)


# ---------------------------------------------------------------------------
# ragged shapes: pad-and-slice stays bit-exact, and never fires when the
# shapes already divide (DESIGN.md §12)
# ---------------------------------------------------------------------------


class _SpyJnp:
    """Forwards every attribute to the real jnp, counting ``pad`` calls —
    installed over ``ops.jnp`` so a trace through the dispatch layer
    reveals whether the pad-and-slice escape hatch actually fired."""

    def __init__(self):
        self.pad_calls = 0

    def __getattr__(self, name):
        attr = getattr(jnp, name)
        if name == "pad":
            def counted(*a, **k):
                self.pad_calls += 1
                return attr(*a, **k)
            return counted
        return attr


class TestRaggedShapes:
    @pytest.mark.parametrize("m,n", [(7, 10), (7, 130), (67, 10), (67, 130)])
    def test_fused_matmul_ragged_mn_bit_exact(self, m, n):
        """Non-dividing M and N with the full fused epilogue: the padded
        rows/columns (including the padded out_scale columns) slice away
        bit-exactly against the integer oracle."""
        k = 56  # 7 K-blocks at bz=8: the default kb must handle it too
        k1, k2, k3 = jax.random.split(jax.random.PRNGKey(11), 3)
        a = jax.random.normal(k1, (m, k))
        w = jax.random.normal(k2, (k, n))
        b = jax.random.normal(k3, (n,))
        fmt = DBBFormat(8, 3, "matrix")
        qw = quant.quantize_dbb(dbb_encode(w, fmt, prune=True))
        s_a = quant.dynamic_act_scale(a)
        got = ops.quant_matmul(
            a, qw, s_a, bias=b, relu=True, out_scale=0.06,
            bm=16, bn=32, interpret=True,  # neither divides m/n
        )
        acc = quant.int_matmul_ref(quant.quantize(a, s_a),
                                   ref.dbb_decode(qw.as_dbb()))
        want = ref.quant_epilogue_ref(acc, s_a * qw.scales, bias=b,
                                      relu=True, out_scale=0.06)
        assert got.shape == (m, n) and got.dtype == jnp.int8
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    @pytest.mark.parametrize("stride", [1, 2])
    def test_fused_conv_odd_spatial_bit_exact(self, stride):
        """Odd spatial dims (15x15, stride 1/2) through the fused conv
        epilogue: conv tiles resolve to exact divisors (no padding path)
        and stay bit-exact against the oracle."""
        k1, k2, k3 = jax.random.split(jax.random.PRNGKey(12), 3)
        x = jax.random.normal(k1, (2, 15, 15, 8))
        w4 = jax.random.normal(k2, (3, 3, 8, 16))
        b = jax.random.normal(k3, (16,))
        qw = quant.quantize_dbb(
            dbb_encode_conv(w4, DBBFormat(8, 3, "matrix"), prune=True))
        s_a = quant.dynamic_act_scale(x)
        got = ops.quant_conv(x, qw, 3, 3, s_a, bias=b, relu=True,
                             out_scale=0.05, stride=stride, interpret=True)
        acc = ref.sparse_conv_int_ref(quant.quantize(x, s_a), qw.as_dbb(),
                                      3, 3, stride=stride)
        want = ref.quant_epilogue_ref(acc, s_a * qw.scales, bias=b,
                                      relu=True, out_scale=0.05)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_pad_tile_unit_contract(self):
        # dividing: no padding, requested tile honored
        assert core.pad_tile(64, 32, 128) == (32, 64)
        assert core.pad_tile(64, None, 128) == (64, 64)
        assert core.pick_tile_padded(128, 128) == (128, 128)
        # ragged: padded up to the next tile multiple
        assert core.pad_tile(67, 16, 128) == (16, 80)
        # oversized explicit tile clamps to the dimension
        assert core.pad_tile(10, 64, 128) == (10, 10)

    def test_no_pad_when_shapes_divide(self, monkeypatch):
        """When every launch dim divides its tile, the dispatch layer must
        not touch ``jnp.pad`` at all — fresh shapes force a retrace with a
        spy installed over ``ops.jnp``."""
        spy = _SpyJnp()
        monkeypatch.setattr(ops, "jnp", spy)
        k1, k2 = jax.random.split(jax.random.PRNGKey(13))
        a = jax.random.normal(k1, (24, 64))
        w = jax.random.normal(k2, (64, 48))
        fmt = DBBFormat(8, 3, "matrix")
        qw = quant.quantize_dbb(dbb_encode(w, fmt, prune=True))
        s_a = quant.dynamic_act_scale(a)
        y = ops.quant_matmul(a, qw, s_a, bias=jnp.zeros(48), relu=True,
                             out_scale=0.05, bm=8, bn=16, kb=2,
                             interpret=True)
        assert y.shape == (24, 48)
        assert spy.pad_calls == 0

        # positive control on another fresh shape: a ragged M does pad
        a2 = jax.random.normal(k1, (23, 64))
        y2 = ops.quant_matmul(a2, qw, s_a, bm=8, bn=16, kb=2, interpret=True)
        assert y2.shape == (23, 48)
        assert spy.pad_calls > 0


# ---------------------------------------------------------------------------
# model: head kernel mode + the int8-resident chain
# ---------------------------------------------------------------------------


def _model(kernel_mode="ref", batch=8):
    from repro.configs import smoke_cnn_config
    from repro.models.cnn import SparseCNN

    cfg = smoke_cnn_config("sparse-cnn-tiny", sparsity=0.625)
    # two convs per stage so compressed→compressed int8 edges exist
    cfg = dataclasses.replace(cfg, convs_per_stage=2, kernel_mode=kernel_mode)
    model = SparseCNN(cfg)
    params = model.compress(model.init(jax.random.PRNGKey(0)))
    x = jax.random.normal(
        jax.random.PRNGKey(1),
        (batch, cfg.image_size, cfg.image_size, cfg.in_channels),
    )
    return model, params, x


def _unfused_reference(model, qparams, x):
    """The PR-3 per-layer path: fp32 dequant → ReLU between every layer."""
    layers = model.layers()
    for i, m in enumerate(layers[:-1]):
        x = jax.nn.relu(m(qparams[f"l{i}"], x))
    return layers[-1](qparams[f"l{len(layers) - 1}"], x.mean(axis=(1, 2)))


class TestHeadKernelMode:
    def test_head_follows_cfg(self):
        model, _, _ = _model("pallas")
        assert model.layers()[-1].kernel_mode == "pallas"

    def test_tiny_m_falls_back_to_ref(self):
        """Below the MXU sublane the pallas head uses the jnp reference —
        bit-identical to an explicit ref layer."""
        fmt = DBBFormat(8, 3, "matrix")
        ref_layer = DBBLinear(64, 10, fmt=fmt, use_bias=True, kernel_mode="ref")
        pl_layer = dataclasses.replace(ref_layer, kernel_mode="pallas")
        params = ref_layer.compress_params(ref_layer.init(jax.random.PRNGKey(0)))
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 64))  # M=4 < 8
        np.testing.assert_array_equal(
            np.asarray(pl_layer(params, x)), np.asarray(ref_layer(params, x))
        )

    def test_pallas_head_matches_ref_at_mxu_m(self):
        fmt = DBBFormat(8, 3, "matrix")
        ref_layer = DBBLinear(64, 16, fmt=fmt, use_bias=True, kernel_mode="ref")
        pl_layer = dataclasses.replace(ref_layer, kernel_mode="pallas")
        params = ref_layer.compress_params(ref_layer.init(jax.random.PRNGKey(0)))
        x = jax.random.normal(jax.random.PRNGKey(1), (16, 64))
        np.testing.assert_allclose(
            np.asarray(pl_layer(params, x)), np.asarray(ref_layer(params, x)),
            rtol=1e-4, atol=1e-4,
        )


class TestInt8ResidentCNN:
    @pytest.mark.parametrize("mode", ["ref", "pallas"])
    def test_matches_per_layer_dequant_path(self, mode):
        """The one-kernel-per-layer chain agrees with the PR-3 unfused
        path within the documented 1% relative L2 (identical fp32 math →
        in practice bit-near-exact)."""
        model, params, x = _model(mode)
        _, stats = model.apply(params, x, collect_act_stats=True)
        qparams = model.quantize(params, stats)
        got = model.apply(qparams, x)
        want = _unfused_reference(model, qparams, x)
        rel = float(jnp.linalg.norm(got - want) / jnp.linalg.norm(want))
        assert rel < 0.01, rel

    def test_inter_layer_activations_are_int8(self):
        """Acceptance: zero standalone fp32 tensors between compressed
        layers — every inter-layer activation (stem→l1, l1→l2, ...) is
        int8 codes; only the last conv flushes fp32 into the pooling."""
        model, params, x = _model("ref")
        _, stats = model.apply(params, x, collect_act_stats=True)
        qparams = model.quantize(params, stats)
        seen = []
        logits = model.apply(qparams, x, intermediates=seen)
        n_convs = len(model.layers()) - 1
        assert len(seen) == n_convs
        for t in seen[:-1]:  # every edge that feeds a compressed conv
            assert t.dtype == jnp.int8, t.dtype
        assert seen[-1].dtype == jnp.float32  # fp32 flush into GAP
        assert logits.dtype == jnp.float32

    def test_uncalibrated_params_fall_back(self):
        """Dynamic quantization (no ``aq``) cannot chain statically — the
        fp per-layer path runs and intermediates stay fp32."""
        model, params, x = _model("ref")
        qdyn = model.quantize(params)  # no calibration
        seen = []
        logits = model.apply(qdyn, x, intermediates=seen)
        assert all(t.dtype == jnp.float32 for t in seen)
        assert bool(jnp.all(jnp.isfinite(logits)))

    def test_chain_matches_fp32_within_tolerance(self):
        """End-to-end sanity at the documented §8 bound."""
        model, params, x = _model("ref")
        logits_fp, stats = model.apply(params, x, collect_act_stats=True)
        logits_q = model.apply(model.quantize(params, stats), x)
        rel = float(
            jnp.linalg.norm(logits_q - logits_fp) / jnp.linalg.norm(logits_fp)
        )
        assert rel < 0.05, rel


# ---------------------------------------------------------------------------
# cost accounting
# ---------------------------------------------------------------------------


class TestEpilogueCosts:
    def test_fused_drops_epilogue_traffic(self):
        fmt = DBBFormat(8, 3, "matrix")
        unfused = dbb_gemm_costs(256, 288, 64, fmt, bits=8, act_bits=8)
        fused = dbb_gemm_costs(256, 288, 64, fmt, bits=8, act_bits=8,
                               epilogue_fused=True)
        assert unfused["epilogue_bytes"] > 0 and not unfused["epilogue_fused"]
        assert fused["epilogue_bytes"] == 0 and fused["epilogue_fused"]
        # int8 flush is a quarter of the fp32/int32 one
        assert fused["out_bytes"] * 4 == unfused["out_bytes"]

    def test_conv_layer_total_reduction(self):
        """Acceptance: ≥25% lower modeled HBM bytes per conv layer."""
        fmt = DBBFormat(8, 3, "matrix")
        kw = dict(bits=8, act_bits=8)
        for shape in [(4, 16, 16, 32, 64, 3, 3), (2, 32, 32, 64, 128, 3, 3)]:
            unf = dbb_conv_costs(*shape, fmt, **kw)
            fus = dbb_conv_costs(*shape, fmt, epilogue_fused=True, **kw)

            def total(c):
                return (c["act_bytes"] + c["weight_bytes"] + c["out_bytes"]
                        + c["epilogue_bytes"])

            assert total(fus) <= 0.75 * total(unf), (total(fus), total(unf))

    def test_conv_workload_surfaces_epilogue_traffic(self):
        """The flag reaches the energy-model tables: conv_workload carries
        out/epilogue bytes and a total that shrinks when fused."""
        from repro.core.energy_model import PARETO_DESIGN, conv_workload

        fmt = DBBFormat(8, 3, "matrix")
        unf = conv_workload(
            PARETO_DESIGN, dbb_conv_costs(4, 16, 16, 32, 64, 3, 3, fmt), fmt
        )
        fus = conv_workload(
            PARETO_DESIGN,
            dbb_conv_costs(4, 16, 16, 32, 64, 3, 3, fmt, epilogue_fused=True),
            fmt,
        )
        assert fus["epilogue_fused"] and not unf["epilogue_fused"]
        assert fus["epilogue_bytes"] == 0 < unf["epilogue_bytes"]
        assert fus["hbm_bytes_total"] < 0.75 * unf["hbm_bytes_total"]
