"""Substrate tests: optimizer, data pipeline, checkpointing, fault
tolerance (kill/resume equivalence), elastic reshard-on-load, gradient
compression, DBB training integration (loss decreases under constraint).
"""
import dataclasses
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import store
from repro.configs import smoke_config
from repro.core.sparse_linear import PruneSchedule
from repro.core.vdbb import satisfies_dbb
from repro.data.pipeline import DataConfig, Prefetcher, SyntheticTokens
from repro.models.model import LM
from repro.optim.adamw import OptConfig, apply_updates, init_state, schedule
from repro.train.loop import LoopConfig, Trainer
from repro.train.step import make_train_step


def small_model(name="codeqwen1.5-7b", **over):
    cfg = smoke_config(name)
    cfg = dataclasses.replace(
        cfg, num_layers=2, d_model=64, d_ff=128, vocab_size=256, **over
    )
    return LM(cfg)


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------


class TestOptimizer:
    def test_schedule_warmup_and_decay(self):
        cfg = OptConfig(peak_lr=1.0, warmup_steps=10, decay_steps=100, min_lr_frac=0.1)
        assert float(schedule(0, cfg)) == 0.0
        assert float(schedule(10, cfg)) == pytest.approx(1.0, rel=1e-3)
        assert float(schedule(100, cfg)) == pytest.approx(0.1, rel=1e-3)

    def test_adamw_descends_quadratic(self):
        cfg = OptConfig(peak_lr=0.1, warmup_steps=0, decay_steps=100, weight_decay=0.0, clip_norm=1e9)
        params = {"w": jnp.array([3.0, -2.0])}
        st = init_state(params, cfg)
        for step in range(200):
            g = {"w": 2 * params["w"]}
            params, st, _ = apply_updates(params, g, st, step, cfg)
        assert float(jnp.abs(params["w"]).max()) < 0.05

    def test_grad_compression_error_feedback(self):
        cfg = OptConfig(peak_lr=0.05, warmup_steps=0, decay_steps=500,
                        weight_decay=0.0, clip_norm=1e9, grad_compression=True)
        params = {"w": jnp.array([3.0, -2.0, 0.5])}
        st = init_state(params, cfg)
        assert "ef" in st
        for step in range(300):
            g = {"w": 2 * params["w"]}
            params, st, _ = apply_updates(params, g, st, step, cfg)
        # int8+EF still converges on the quadratic
        assert float(jnp.abs(params["w"]).max()) < 0.1


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------


class TestData:
    def test_deterministic_and_host_sharded(self):
        cfg = smoke_config("codeqwen1.5-7b")
        d0 = SyntheticTokens(cfg, DataConfig(seq_len=32, global_batch=4, host_index=0, host_count=2))
        d1 = SyntheticTokens(cfg, DataConfig(seq_len=32, global_batch=4, host_index=1, host_count=2))
        b0a, b0b = d0.batch(7), d0.batch(7)
        np.testing.assert_array_equal(b0a["tokens"], b0b["tokens"])  # pure fn of step
        assert not np.array_equal(d0.batch(7)["tokens"], d1.batch(7)["tokens"])
        assert b0a["tokens"].shape == (2, 32)
        # labels are next-token shifted
        np.testing.assert_array_equal(
            d0.batch(3)["tokens"][:, 1:], d0.batch(3)["labels"][:, :-1]
        )

    def test_prefetcher_resumes_at_step(self):
        cfg = smoke_config("codeqwen1.5-7b")
        src = SyntheticTokens(cfg, DataConfig(seq_len=16, global_batch=2))
        pf = Prefetcher(src, start_step=5)
        step, batch = pf.next()
        pf.stop()
        assert step == 5
        np.testing.assert_array_equal(batch["tokens"], src.batch(5)["tokens"])


# ---------------------------------------------------------------------------
# checkpointing + fault tolerance
# ---------------------------------------------------------------------------


class TestCheckpoint:
    def test_atomic_roundtrip(self, tmp_path):
        tree = {"a": jnp.arange(6).reshape(2, 3), "b": {"c": jnp.ones(4, jnp.bfloat16)}}
        store.save(tmp_path, 3, tree, extra={"note": "x"})
        out, manifest = store.restore(tmp_path, tree)
        assert manifest["step"] == 3
        np.testing.assert_array_equal(out["a"], tree["a"])
        assert out["b"]["c"].dtype == jnp.bfloat16

    def test_latest_and_gc(self, tmp_path):
        tree = {"a": jnp.zeros(2)}
        ck = store.AsyncCheckpointer(tmp_path, keep=2)
        for s in (1, 2, 3):
            ck.save_async(s, tree)
        ck.wait()
        assert store.list_steps(tmp_path) == [2, 3]
        assert store.latest_step(tmp_path) == 3

    def test_structure_mismatch_rejected(self, tmp_path):
        store.save(tmp_path, 0, {"a": jnp.zeros(2)})
        with pytest.raises(AssertionError):
            store.restore(tmp_path, {"a": jnp.zeros(2), "b": jnp.zeros(1)})

    @pytest.mark.parametrize("mode", ["flip", "truncate", "manifest", "missing"])
    def test_corruption_corpus_fails_typed(self, tmp_path, mode):
        """§15 integrity: every kind of on-disk damage — a flipped byte,
        a torn (truncated) write, a manifest edited without re-digesting,
        a deleted arrays file — surfaces as CorruptCheckpointError at
        restore, never silent garbage."""
        from repro.launch.faults import corrupt_checkpoint

        tree = {"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8),
                "b": jnp.ones(8, jnp.bfloat16)}
        store.save(tmp_path, 1, tree)
        corrupt_checkpoint(tmp_path, mode=mode)
        with pytest.raises(store.CorruptCheckpointError):
            store.restore(tmp_path, tree)

    @pytest.mark.parametrize("mode", ["flip", "truncate", "manifest", "missing"])
    def test_fallback_walks_back_to_verifiable_step(self, tmp_path, mode):
        """``fallback=True`` recovers the newest step whose checksums
        still verify when the latest is damaged — and still fails typed
        when *every* step is damaged."""
        from repro.launch.faults import corrupt_checkpoint

        tree = {"w": jnp.arange(12, dtype=jnp.float32)}
        store.save(tmp_path, 1, jax.tree_util.tree_map(lambda a: a + 1, tree))
        store.save(tmp_path, 2, tree)
        corrupt_checkpoint(tmp_path, step=2, mode=mode)
        out, manifest = store.restore(tmp_path, tree, fallback=True)
        assert manifest["step"] == 1
        np.testing.assert_array_equal(out["w"], np.arange(12) + 1)
        corrupt_checkpoint(tmp_path, step=1, mode=mode)
        with pytest.raises(store.CorruptCheckpointError, match="no verifiable"):
            store.restore(tmp_path, tree, fallback=True)

    def test_shape_mismatch_reports_path_and_step(self, tmp_path):
        """A leaf shape mismatch at restore names the tree path and the
        checkpoint step — not just a bare index."""
        store.save(tmp_path, 5, {"enc": {"w": jnp.zeros((2, 3))}})
        with pytest.raises(ValueError, match=r"'w'.*step 5.*\(2, 3\)"):
            store.restore(tmp_path, {"enc": {"w": jnp.zeros((3, 3))}})

    @pytest.mark.slow
    def test_kill_resume_equivalence(self, tmp_path):
        """Train 6 steps straight == train 3, 'crash', resume, train 3."""
        model = small_model()
        opt = OptConfig(peak_lr=1e-3, warmup_steps=0, decay_steps=10)
        data = DataConfig(seq_len=16, global_batch=2)

        def train(total, ckpt_dir, ckpt_every=100):
            loop = LoopConfig(total_steps=total, ckpt_dir=str(ckpt_dir),
                              ckpt_every=ckpt_every, log_every=100)
            t = Trainer(model, opt, data, loop)
            return t.run()

        pA, _, _ = train(6, tmp_path / "a", ckpt_every=100)
        # run B: 3 steps with a checkpoint at 2... use ckpt_every=2 then resume
        loopB = LoopConfig(total_steps=3, ckpt_dir=str(tmp_path / "b"), ckpt_every=2, log_every=100)
        tB = Trainer(model, opt, data, loopB)
        tB.run()
        # "crash" after step 2's checkpoint; resume to 6
        # resume path reads latest (step 2), continues at 3
        loopB2 = LoopConfig(total_steps=6, ckpt_dir=str(tmp_path / "b"), ckpt_every=100, log_every=100)
        tB2 = Trainer(model, opt, data, loopB2)
        pB, _, _ = tB2.run()
        for a, b in zip(jax.tree_util.tree_leaves(pA), jax.tree_util.tree_leaves(pB)):
            np.testing.assert_allclose(np.asarray(a, np.float32), np.asarray(b, np.float32),
                                       rtol=2e-4, atol=2e-5)

    def test_elastic_reshard_on_load(self, tmp_path):
        """Checkpoints store logical shapes; restore lays out on any mesh
        (here: 1-device 'mesh' vs plain arrays — shapes preserved)."""
        from jax.sharding import NamedSharding, PartitionSpec as P

        mesh = jax.make_mesh((1,), ("data",))
        tree = {"w": jnp.arange(8.0).reshape(4, 2)}
        store.save(tmp_path, 1, tree)
        sh = {"w": NamedSharding(mesh, P("data", None))}
        out, _ = store.restore(tmp_path, tree, shardings=sh)
        assert out["w"].sharding == sh["w"]
        np.testing.assert_array_equal(np.asarray(out["w"]), np.asarray(tree["w"]))


# ---------------------------------------------------------------------------
# end-to-end: DBB-constrained training descends
# ---------------------------------------------------------------------------


class TestTrainingIntegration:
    def test_loss_decreases_with_dbb_constraint(self):
        model = small_model()
        assert model.cfg.dbb is not None
        opt = OptConfig(peak_lr=3e-3, warmup_steps=5, decay_steps=60)
        data = DataConfig(seq_len=32, global_batch=4)
        loop = LoopConfig(total_steps=60, ckpt_dir=None, log_every=59)
        t = Trainer(model, opt, data, loop, PruneSchedule(0, 20))
        params, _, history = t.run()
        assert history[-1][1] < history[0][1] - 0.2, history
        # final weights satisfy the DBB bound exactly
        from repro.models.common import dbb_leaves, tree_get

        for path, pdef in dbb_leaves(model.defs()):
            w = np.asarray(tree_get(params, path)).reshape(-1, *pdef.shape[-2:])
            assert satisfies_dbb(jnp.asarray(w[0]), pdef.dbb), path

    def test_preemption_flushes_checkpoint(self, tmp_path):
        model = small_model()
        opt = OptConfig()
        data = DataConfig(seq_len=16, global_batch=2)
        loop = LoopConfig(total_steps=50, ckpt_dir=str(tmp_path), ckpt_every=1000, log_every=100)
        t = Trainer(model, opt, data, loop)
        params, opt_state, start = t.init_or_resume()
        t._preempted = True  # simulate SIGTERM delivery
        t.run(params, opt_state, 0)
        assert store.latest_step(tmp_path) is not None  # flushed before exit
