"""Per-backend calibrated roofline cost model (DESIGN.md §12).

Covers :mod:`repro.kernels.calibrate`: the pure least-squares fit
(synthetic-coefficient recovery, non-negative clamping, unidentifiable
fallbacks), the cache entry round-trip and version/validity invalidation
(mirroring test_autotune's TuneCache contracts — the calibration rides in
the same file), resolution precedence (active → cached → default), the
cost-model plumbing (``modeled_*_cost`` consult the calibration), and one
measured integration check: after fitting on real probes, the model must
rank an extreme grid-step pair the same way the measurements do.
"""
import json
import math

import pytest

from repro.core.vdbb import DBBFormat
from repro.kernels import autotune, calibrate
from repro.kernels.calibrate import Calibration

FMT = DBBFormat(8, 3, "matrix")

TRUE = dict(peak_macs=1e12, hbm_bw=1e10, step_overhead_s=5e-6)


def _synthetic_probes(n=8):
    """Probes whose times follow the linear surrogate exactly."""
    probes = []
    for i in range(n):
        macs = 1e7 * (i + 1)
        bytes_ = 3e5 * ((i % 4) + 1)
        steps = 4 ** (i % 4)
        t = (macs / TRUE["peak_macs"] + bytes_ / TRUE["hbm_bw"]
             + steps * TRUE["step_overhead_s"])
        probes.append({"macs": macs, "bytes": bytes_, "steps": steps, "t_s": t})
    return probes


@pytest.fixture(autouse=True)
def _clean_active():
    calibrate.clear_active()
    yield
    calibrate.clear_active()


class TestFit:
    def test_recovers_synthetic_coefficients(self):
        cal = calibrate.fit_calibration(_synthetic_probes(), backend="cpu")
        assert cal.source == "fit"
        assert cal.peak_macs == pytest.approx(TRUE["peak_macs"], rel=1e-6)
        assert cal.hbm_bw == pytest.approx(TRUE["hbm_bw"], rel=1e-6)
        assert cal.step_overhead_s == pytest.approx(
            TRUE["step_overhead_s"], rel=1e-6)
        assert cal.residual == pytest.approx(0.0, abs=1e-9)

    def test_unidentifiable_terms_keep_defaults(self):
        """Times driven purely by grid steps: the macs/bytes coefficients
        are ~0, get clamped, and fall back to the datasheet defaults while
        the step term fits."""
        probes = [
            {"macs": 1e7, "bytes": 1e5, "steps": s, "t_s": s * 7e-6}
            for s in (1, 4, 16, 64, 128, 32)
        ]
        cal = calibrate.fit_calibration(probes, backend="cpu")
        assert cal.step_overhead_s == pytest.approx(7e-6, rel=1e-3)
        assert cal.peak_macs == calibrate.DEFAULT_PEAK_MACS
        assert cal.hbm_bw == calibrate.DEFAULT_HBM_BW

    def test_too_few_probes_falls_back_to_default(self):
        cal = calibrate.fit_calibration(_synthetic_probes(2), backend="cpu")
        assert cal.source == "default"

    def test_nonfinite_probe_falls_back(self):
        probes = _synthetic_probes()
        probes[0]["t_s"] = float("nan")
        assert calibrate.fit_calibration(probes, backend="cpu").source == "default"


class TestCacheRoundTrip:
    def _fit(self):
        return calibrate.fit_calibration(_synthetic_probes(), backend="cpu")

    def test_entry_round_trip(self):
        cal = self._fit()
        back = calibrate.from_entry(calibrate.to_entry(cal))
        assert back is not None and back.source == "cache"
        assert back.peak_macs == cal.peak_macs
        assert back.hbm_bw == cal.hbm_bw
        assert back.step_overhead_s == cal.step_overhead_s

    def test_persists_in_tune_cache_file(self, tmp_path):
        path = tmp_path / "autotune.json"
        cache = autotune.TuneCache(path)
        cache.calibration["cpu"] = calibrate.to_entry(self._fit())
        cache.save()
        # reload through a fresh cache object, then through get_calibration
        again = autotune.TuneCache(path)
        cal = calibrate.from_entry(again.calibration["cpu"])
        assert cal is not None and cal.peak_macs == pytest.approx(1e12)
        resolved = calibrate.get_calibration(backend="cpu", cache=path)
        assert resolved.source == "cache"
        assert resolved.step_overhead_s == pytest.approx(5e-6)

    def test_version_mismatch_invalidates(self):
        entry = calibrate.to_entry(self._fit())
        entry["version"] = calibrate.CALIBRATION_VERSION + 1
        assert calibrate.from_entry(entry) is None

    @pytest.mark.parametrize("bad", [float("nan"), float("inf"), 0.0, -1.0,
                                     None, "fast"])
    def test_invalid_constants_invalidate(self, bad):
        entry = calibrate.to_entry(self._fit())
        entry["hbm_bw"] = bad
        assert calibrate.from_entry(entry) is None

    def test_corrupt_entry_shapes(self):
        assert calibrate.from_entry(None) is None
        assert calibrate.from_entry({"version": calibrate.CALIBRATION_VERSION}) is None

    def test_tile_entries_survive_next_to_calibration(self, tmp_path):
        """The calibration section must not clobber tile entries (and vice
        versa) — they share one file under independent versions."""
        path = tmp_path / "autotune.json"
        cache = autotune.TuneCache(path)
        cache.put("cpu|matmul_tc|64x128", {"tiles": {"bm": 64}})
        cache.calibration["cpu"] = calibrate.to_entry(self._fit())
        cache.save()
        data = json.loads(path.read_text())
        assert "entries" in data and "calibration" in data
        again = autotune.TuneCache(path)
        assert again.get("cpu|matmul_tc|64x128") == {"tiles": {"bm": 64}}
        assert calibrate.from_entry(again.calibration["cpu"]) is not None


class TestResolution:
    def test_active_wins_over_cache_and_default(self, tmp_path):
        path = tmp_path / "autotune.json"
        cache = autotune.TuneCache(path)
        cache.calibration["cpu"] = calibrate.to_entry(
            calibrate.fit_calibration(_synthetic_probes(), backend="cpu"))
        cache.save()
        active = Calibration(backend="cpu", peak_macs=1.0, hbm_bw=1.0,
                             step_overhead_s=1.0, source="fit")
        calibrate.set_active(active)
        assert calibrate.get_calibration(backend="cpu", cache=path) is active
        calibrate.clear_active()
        assert calibrate.get_calibration(
            backend="cpu", cache=path).source == "cache"

    def test_default_when_nothing_else(self, tmp_path):
        cal = calibrate.get_calibration(
            backend="cpu", cache=tmp_path / "missing.json")
        assert cal.source == "default"
        assert cal.peak_macs == calibrate.DEFAULT_PEAK_MACS

    def test_modeled_cost_consults_calibration(self):
        """Same shape, two calibrations with wildly different step
        overhead: the modeled ranking of a 1-step vs many-step config must
        flip with the calibration — the §12 point of the fit."""
        tiles_1step = {"bm": 64, "bn": 128, "kb": 32}   # grid = 1
        tiles_many = {"bm": 16, "bn": 32, "kb": 4}      # grid = 128
        compute_bound = Calibration(  # steps are free -> smaller tiles fine
            backend="cpu", peak_macs=1e9, hbm_bw=1e12, step_overhead_s=1e-12)
        overhead_bound = Calibration(  # steps dominate -> 1 big step wins
            backend="cpu", peak_macs=1e15, hbm_bw=1e15, step_overhead_s=1e-3)

        def cost(tiles, cal):
            return autotune.modeled_matmul_cost(64, 256, 128, FMT, tiles,
                                                4.0, cal=cal)

        delta_cb = cost(tiles_many, compute_bound) - cost(tiles_1step, compute_bound)
        delta_ob = cost(tiles_many, overhead_bound) - cost(tiles_1step, overhead_bound)
        assert abs(delta_cb) < 1e-6          # compute-bound: ~indifferent
        assert delta_ob > 0.1                # overhead-bound: 127 extra ms

    def test_cost_terms_are_finite_and_scale(self):
        macs, bytes_, steps = autotune.matmul_cost_terms(
            64, 256, 128, FMT, {"bm": 64, "bn": 128, "kb": 32}, 4.0)
        assert all(math.isfinite(v) and v > 0 for v in (macs, bytes_, steps))
        assert steps == 1
        _, _, steps_many = autotune.matmul_cost_terms(
            64, 256, 128, FMT, {"bm": 16, "bn": 32, "kb": 4}, 4.0)
        assert steps_many == 128


@pytest.mark.slow
class TestMeasuredOrdering:
    def test_model_ranks_extreme_pair_like_measurements(self, tmp_path):
        """Integration: fit on real probes, then the calibrated model must
        order the probe set's own extreme pair (fastest vs slowest
        measured) the same way the measurements did. Interpret-mode grid
        overhead differs by >100x across the pair, so the ordering is
        robust even on a noisy host."""
        probes = calibrate.measure_probes(reps=3, warmup=1)
        cal = calibrate.fit_calibration(probes, backend="cpu")
        assert cal.source == "fit"
        lo = min(probes, key=lambda p: p["t_s"])
        hi = max(probes, key=lambda p: p["t_s"])
        assert hi["t_s"] > 2 * lo["t_s"], "probe spread collapsed"

        def modeled(p):
            return max(p["macs"] / cal.peak_macs, p["bytes"] / cal.hbm_bw) \
                + p["steps"] * cal.step_overhead_s

        assert modeled(hi) > modeled(lo)
