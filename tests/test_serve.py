"""Continuous-batching serving tier (DESIGN.md §11).

Covers the four legs of the tier: bucket selection (`make_buckets` /
`PlanSet.bucket_for`), ragged-tail pad/slice bit-exactness vs per-request
`plan.serve`, queue aggregation under max-batch/max-wait (pure
`MicroBatcher` logic with an injectable clock + the threaded `CNNServer`
end to end), and data-parallel mesh serving on a 2x2 `make_test_mesh`
matching single-device logits bit for bit (subprocess, like
test_distributed, so the fake-device override never leaks).
"""
import dataclasses
import json
import os
import pathlib
import subprocess
import sys
import textwrap
import time
from concurrent.futures import CancelledError, Future

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_cnn_config
from repro.launch.server import CNNServer, MicroBatcher, _Pending, auto_rate, \
    burst_arrivals, poisson_arrivals
from repro.models.cnn import SparseCNN
from repro.models.plan import PlanSet, StalePlanError, make_buckets

REPO = pathlib.Path(__file__).resolve().parents[1]


# ------------------------------------------------------------- fixtures
def _quantized_model(kernel_mode: str):
    cfg = dataclasses.replace(
        smoke_cnn_config("sparse-cnn-tiny", sparsity=0.625),
        kernel_mode=kernel_mode,
    )
    model = SparseCNN(cfg)
    params = model.compress(model.init(jax.random.PRNGKey(0)))
    x = jax.random.normal(
        jax.random.PRNGKey(1),
        (12, cfg.image_size, cfg.image_size, cfg.in_channels),
    )
    _, stats = model.apply(params, x[:4], collect_act_stats=True)
    return model, model.quantize(params, stats), x


@pytest.fixture(scope="module")
def ref_served():
    """Ref-kernel model + quantized params + a bucketed plan set."""
    model, qparams, x = _quantized_model("ref")
    plan_set = model.plan_set(qparams, max_batch=8, tune="off")
    return model, qparams, x, plan_set


@pytest.fixture(scope="module")
def pallas_served():
    model, qparams, x = _quantized_model("pallas")
    plan_set = model.plan_set(qparams, max_batch=4, tune="off")
    return model, qparams, x, plan_set


# ------------------------------------------------------ bucket selection
def test_make_buckets_ladder():
    assert make_buckets(8) == (1, 2, 4, 8)
    assert make_buckets(1) == (1,)
    assert make_buckets(5) == (1, 2, 4, 8)  # first bucket >= max_batch
    assert make_buckets(6, dp=2) == (2, 4, 8)
    assert make_buckets(4, dp=4) == (4,)


def test_make_buckets_validates():
    with pytest.raises(ValueError):
        make_buckets(0)
    with pytest.raises(ValueError):
        make_buckets(4, dp=0)


def test_bucket_for(ref_served):
    _, _, _, ps = ref_served
    assert ps.buckets == (1, 2, 4, 8)
    assert ps.bucket_for(1) == 1
    assert ps.bucket_for(3) == 4
    assert ps.bucket_for(8) == 8
    assert ps.bucket_for(9) is None  # serve() chunks at the largest bucket


def test_plan_set_validates(ref_served):
    model, qparams, _, ps = ref_served
    with pytest.raises(ValueError):
        PlanSet(ps.model, ps.fingerprint, (4, 2), dict(ps.plans))
    with pytest.raises(ValueError):
        PlanSet(ps.model, ps.fingerprint, (1, 2), dict(ps.plans))
    with pytest.raises(ValueError):
        model.plan_set(qparams, buckets=(2, 3), dp=2)  # 3 not a dp multiple
    with pytest.raises(ValueError):
        model.plan_set(qparams)  # needs max_batch or buckets


# ------------------------------------- ragged pad/slice bit-exactness
@pytest.mark.parametrize("n", [1, 2, 3, 5, 7, 8, 11])
def test_ragged_serve_matches_per_request(ref_served, n):
    """Padding to the bucket and slicing back == serving each request
    alone (n=11 > the largest bucket also exercises chunking)."""
    _, _, x, ps = ref_served
    got = ps.serve(x[:n])
    per = jnp.concatenate([ps.plans[1].serve(x[i : i + 1]) for i in range(n)])
    np.testing.assert_array_equal(np.asarray(got), np.asarray(per))


def test_ragged_serve_matches_per_request_pallas_int8(pallas_served):
    """Same bit-exactness through the §9 int8-resident Pallas chain."""
    _, _, x, ps = pallas_served
    got = ps.serve(x[:3])
    per = jnp.concatenate([ps.plans[1].serve(x[i : i + 1]) for i in range(3)])
    np.testing.assert_array_equal(np.asarray(got), np.asarray(per))


def test_host_path_matches_device_path(ref_served):
    """numpy input (the serving tier's host-assembly fast path) returns
    numpy and matches the on-device path bit for bit."""
    _, _, x, ps = ref_served
    host = ps.serve(np.asarray(x[:5]))
    assert isinstance(host, np.ndarray)
    np.testing.assert_array_equal(host, np.asarray(ps.serve(x[:5])))


def test_serve_matches_unplanned_apply(ref_served):
    """The whole bucketed path stays bit-identical to plain apply."""
    model, qparams, x, ps = ref_served
    np.testing.assert_array_equal(
        np.asarray(ps.serve(x[:6])), np.asarray(model.apply(qparams, x[:6]))
    )


def test_serve_rejects_empty(ref_served):
    _, _, x, ps = ref_served
    with pytest.raises(ValueError):
        ps.serve(x[:0])


# ----------------------------------------------- zero-retrace contract
def test_no_retrace_after_warmup(ref_served):
    _, _, x, ps = ref_served
    base = ps.warmup(x.shape[1:])
    assert base >= len(ps.buckets)
    for n in (1, 2, 3, 5, 8, 11):          # every ragged size pads to a bucket
        ps.serve(np.asarray(x[:n]))
        ps.serve(x[:n])
    assert ps.trace_count == base


def test_trace_count_counts_new_shapes(ref_served):
    _, _, x, ps = ref_served
    ps.warmup(x.shape[1:])
    before = ps.plans[2].trace_count
    ps.plans[2].serve(x[:2])               # warmed: no new trace
    assert ps.plans[2].trace_count == before
    ps.plans[2].serve(x[:3])               # off-bucket direct use: retrace
    assert ps.plans[2].trace_count == before + 1


def test_plan_set_staleness(ref_served):
    model, qparams, x, ps = ref_served
    ps.check(qparams)                      # matching params pass
    _, stats = model.apply(qparams, x[:2], collect_act_stats=True)
    requant = model.quantize(
        model.compress(model.constrain(model.init(jax.random.PRNGKey(3)))),
        stats,
    )
    with pytest.raises(StalePlanError):
        ps.check(requant)


# --------------------------------------------------- queue aggregation
def _pending(n=1, arrival=0.0, deadline=None):
    return _Pending(x=np.zeros((n, 4)), n=n, arrival=arrival, future=Future(),
                    deadline=deadline)


def test_microbatcher_flushes_at_max_batch():
    mb = MicroBatcher(max_batch=4, max_wait_s=10.0)
    assert mb.add(_pending()) == []
    assert mb.add(_pending()) == []
    assert mb.add(_pending()) == []
    flushed = mb.add(_pending())
    assert len(flushed) == 1 and len(flushed[0]) == 4
    assert len(mb) == 0


def test_microbatcher_max_wait_deadline():
    mb = MicroBatcher(max_batch=8, max_wait_s=0.5)
    assert mb.deadline() is None and not mb.due(99.0)
    mb.add(_pending(arrival=10.0))
    mb.add(_pending(arrival=10.3))
    assert mb.deadline() == pytest.approx(10.5)  # oldest arrival governs
    assert not mb.due(10.4)
    assert mb.due(10.5)
    batch = mb.take()
    assert len(batch) == 2 and mb.deadline() is None


def test_microbatcher_never_splits_requests():
    mb = MicroBatcher(max_batch=4, max_wait_s=10.0)
    mb.add(_pending(n=3))
    flushed = mb.add(_pending(n=2))        # would overflow: prior flushes alone
    assert [len(b) for b in flushed] == [1]
    assert flushed[0][0].n == 3 and len(mb) == 2


def test_microbatcher_oversize_request_is_own_batch():
    mb = MicroBatcher(max_batch=4, max_wait_s=10.0)
    flushed = mb.add(_pending(n=6))        # > max_batch: flushes immediately
    assert [len(b) for b in flushed] == [1] and flushed[0][0].n == 6


def test_microbatcher_validates():
    with pytest.raises(ValueError):
        MicroBatcher(0, 1.0)
    with pytest.raises(ValueError):
        MicroBatcher(4, -1.0)


def test_microbatcher_request_deadline_tightens_flush():
    """A pending request deadline pulls the flush time earlier than the
    max-wait, less the caller's service estimate — so queue wait is
    charged against the request's budget, not ignored."""
    mb = MicroBatcher(max_batch=8, max_wait_s=5.0)
    mb.add(_pending(arrival=10.0))                      # max-wait: 15.0
    mb.add(_pending(arrival=10.1, deadline=12.0))
    assert mb.deadline() == pytest.approx(12.0)         # deadline governs
    assert mb.deadline(service_est_s=0.5) == pytest.approx(11.5)
    assert not mb.due(11.0, service_est_s=0.5)
    assert mb.due(11.5, service_est_s=0.5)
    mb.take()
    assert mb.deadline() is None


def test_microbatcher_expired_deadline_coexists_with_batch_full():
    """An already-expired pending plus a batch-full flush in one add():
    the full flush carries the expired request along (ordering
    preserved), leaving the dispatcher to expire it — the batcher never
    drops or reorders requests."""
    mb = MicroBatcher(max_batch=2, max_wait_s=5.0)
    expired = _pending(arrival=0.0, deadline=1.0)
    mb.add(expired)
    assert mb.due(2.0)                                  # past its deadline
    flushed = mb.add(_pending(arrival=2.0))             # and batch-full now
    assert len(flushed) == 1 and flushed[0][0] is expired
    assert [p.deadline for p in flushed[0]] == [1.0, None]
    assert len(mb) == 0 and not mb.due(99.0)


# ------------------------------------------------- threaded server e2e
def test_server_end_to_end(ref_served):
    """5 single-sample requests, max_batch=4: one full flush + one
    max-wait flush; results bit-identical to direct bucketed serving."""
    _, _, x, ps = ref_served
    pool = np.asarray(x)
    srv = CNNServer(ps, max_batch=4, max_wait_ms=50.0)
    with srv:
        srv.warmup(x.shape[1:])
        futures = [srv.submit(pool[i : i + 1]) for i in range(5)]
        results = [f.result(timeout=30) for f in futures]
    direct = ps.serve(pool[:5])
    np.testing.assert_array_equal(np.concatenate(results), direct)
    assert srv.retraces_after_warmup == 0
    s = srv.stats.summary()
    assert s["completed"] == s["offered"] == 5
    assert s["bucket_counts"] == {"1": 1, "4": 1}
    assert s["p50_us"] > 0 and s["p99_us"] >= s["p50_us"]
    assert s["accounting_ok"] and s["rejected"] == s["failed"] == s["expired"] == 0
    srv.stats.assert_accounting()


def test_server_mixed_request_sizes(ref_served):
    _, _, x, ps = ref_served
    pool = np.asarray(x)
    srv = CNNServer(ps, max_batch=8, max_wait_ms=30.0)
    with srv:
        srv.warmup(x.shape[1:])
        futures = [srv.submit(pool[0:2]), srv.submit(pool[2:3]),
                   srv.submit(pool[3:6])]
        results = [f.result(timeout=30) for f in futures]
    assert [r.shape[0] for r in results] == [2, 1, 3]
    np.testing.assert_array_equal(np.concatenate(results), ps.serve(pool[:6]))
    assert srv.stats.summary()["padded_frac"] > 0  # 6 samples in an 8-bucket
    srv.stats.assert_accounting()


def test_server_max_wait_bounds_latency(ref_served):
    """A lone request must not wait for a full batch: it dispatches
    once max_wait expires."""
    _, _, x, ps = ref_served
    srv = CNNServer(ps, max_batch=8, max_wait_ms=40.0)
    with srv:
        srv.warmup(x.shape[1:])
        t0 = time.monotonic()
        fut = srv.submit(np.asarray(x[:1]))
        fut.result(timeout=30)
        elapsed = time.monotonic() - t0
    assert elapsed >= 0.040 * 0.5           # it did wait (scheduler slack)
    assert srv.stats.summary()["bucket_counts"] == {"1": 1}


def test_server_drains_on_stop(ref_served):
    _, _, x, ps = ref_served
    srv = CNNServer(ps, max_batch=8, max_wait_ms=10_000.0)  # never self-flush
    srv.start()
    srv.warmup(x.shape[1:])
    futures = [srv.submit(np.asarray(x[i : i + 1])) for i in range(3)]
    srv.stop()                              # drain=True serves the remainder
    assert all(f.done() for f in futures)
    np.testing.assert_array_equal(
        np.concatenate([f.result() for f in futures]),
        ps.serve(np.asarray(x[:3])),
    )


def test_server_stop_no_drain_cancels(ref_served):
    """stop(drain=False): queued-but-undispatched futures are cancelled
    (CancelledError for waiters, never a hang), and the accounting
    identity still closes — cancellations count as failed."""
    _, _, x, ps = ref_served
    srv = CNNServer(ps, max_batch=8, max_wait_ms=10_000.0)  # never self-flush
    srv.start()
    srv.warmup(x.shape[1:])
    futures = [srv.submit(np.asarray(x[i : i + 1])) for i in range(3)]
    srv.stop(drain=False)
    for f in futures:
        assert f.cancelled()
        with pytest.raises(CancelledError):
            f.result(timeout=1)
    s = srv.stats.summary()
    assert s["failed"] == 3 and s["completed"] == 0
    srv.stats.assert_accounting()


def test_server_restart_resets_run_state(ref_served):
    """Pins the restart bug: start() after stop() must not reuse the
    previous run's stats or warmup-trace snapshot — the accounting
    identity and the zero-retrace contract are per-run."""
    _, _, x, ps = ref_served
    srv = CNNServer(ps, max_batch=4, max_wait_ms=20.0)
    srv.start()
    srv.warmup(x.shape[1:])
    srv.submit(np.asarray(x[:2])).result(timeout=30)
    srv.stop()
    first = srv.stats.summary()
    assert first["completed"] == first["offered"] == 2

    srv.start()                       # second run: fresh books, no re-warmup
    assert srv.stats.summary()["offered"] == 0
    assert srv.retraces_after_warmup == 0     # re-baselined, buckets warm
    out = srv.submit(np.asarray(x[2:3])).result(timeout=30)
    srv.stop()
    np.testing.assert_array_equal(out, np.asarray(ps.serve(np.asarray(x[2:3]))))
    s = srv.stats.summary()
    assert s["completed"] == s["offered"] == 1  # not 3: stats were reset
    assert srv.retraces_after_warmup == 0
    srv.stats.assert_accounting()


def test_server_rejects_when_not_running(ref_served):
    _, _, x, ps = ref_served
    srv = CNNServer(ps)
    with pytest.raises(RuntimeError):
        srv.submit(np.asarray(x[:1]))
    with pytest.raises(ValueError):
        with srv:
            srv.submit(np.asarray(x[:0]))   # empty batch


# ------------------------------------------------------------ load gen
def test_poisson_arrivals_deterministic_and_rate():
    a = poisson_arrivals(100.0, 500, seed=3)
    b = poisson_arrivals(100.0, 500, seed=3)
    np.testing.assert_array_equal(a, b)
    assert (np.diff(a) > 0).all()
    assert a[-1] == pytest.approx(5.0, rel=0.3)  # ~500 arrivals at 100 rps
    with pytest.raises(ValueError):
        poisson_arrivals(0.0, 4)


def test_burst_arrivals_shape():
    a = burst_arrivals(10, burst=4, gap_s=0.1)
    assert list(a[:4]) == [0.0] * 4
    assert list(a[4:8]) == [pytest.approx(0.1)] * 4
    assert list(a[8:]) == [pytest.approx(0.2)] * 2
    with pytest.raises(ValueError):
        burst_arrivals(4, burst=0, gap_s=0.1)


def test_auto_rate(ref_served):
    _, _, x, ps = ref_served
    rate, unit_us = auto_rate(ps, x.shape[1:], utilization=0.5, reps=3)
    assert unit_us > 0
    assert rate == pytest.approx(0.5 * ps.buckets[-1] / (unit_us / 1e6))


# ------------------------------------------- data-parallel mesh serving
@pytest.mark.slow
def test_mesh_data_parallel_serve_matches_single_device():
    """2x2 make_test_mesh: the server's batch-axis-sharded dispatch is
    bit-identical to single-device serving (subprocess with 8 fake host
    devices, like test_distributed)."""
    code = textwrap.dedent("""
    import dataclasses, json
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import smoke_cnn_config
    from repro.launch.mesh import make_test_mesh
    from repro.launch.server import CNNServer
    from repro.models.cnn import SparseCNN

    assert len(jax.devices()) == 8
    cfg = dataclasses.replace(
        smoke_cnn_config("sparse-cnn-tiny", sparsity=0.625), kernel_mode="pallas"
    )
    model = SparseCNN(cfg)
    params = model.compress(model.init(jax.random.PRNGKey(0)))
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 16, 16, 3))
    _, stats = model.apply(params, x[:4], collect_act_stats=True)
    qparams = model.quantize(params, stats)

    # dp=2 (the mesh's data axis): every bucket shards evenly
    plan_set = model.plan_set(qparams, max_batch=8, dp=2, tune="off")
    assert plan_set.buckets == (2, 4, 8)
    single = np.asarray(plan_set.serve(x))          # single-device reference

    mesh = make_test_mesh((2, 2))
    pool = np.asarray(x)
    srv = CNNServer(plan_set, max_wait_ms=50.0, mesh=mesh)
    with srv:
        srv.warmup(x.shape[1:])
        futs = [srv.submit(pool[i:i+1]) for i in range(8)]
        out = np.concatenate([f.result(timeout=120) for f in futs])
        ragged = srv.serve_batch(pool[:5])          # pads 5 -> bucket 8, DP-sharded
    identical = bool((out == single).all()) and bool(
        (np.asarray(ragged) == single[:5]).all())
    print(json.dumps({
        "identical": identical,
        "retraces": srv.retraces_after_warmup,
        "buckets": list(plan_set.buckets),
    }))
    """)
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = str(REPO / "src")
    out = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True, env=env,
        timeout=540,
    )
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr[-3000:]}"
    r = json.loads(out.stdout.strip().splitlines()[-1])
    assert r["identical"], r
    assert r["retraces"] == 0, r
