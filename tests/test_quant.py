"""INT8 quantized VDBB datapath tests (DESIGN.md §8).

Layers of the pyramid, bottom-up: quantize→dequantize round-trip bounds
and scale-shape invariants; int8 tc/bw/conv Pallas kernels bit-exact
against the exact-int32 integer references (interpret mode on CPU — the
code that compiles for TPU); the fused dequant-on-flush path; the
quantized SparseCNN lifecycle (calibrate → quantize → apply) against its
fp32 logits across three density bounds; and QuantDBBWeight checkpoint
round-trip through the npz store.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import quant
from repro.core.act_sparsity import combine, measure_activation
from repro.core.vdbb import DBBFormat, dbb_decode, dbb_encode, dbb_encode_conv
from repro.kernels import ops, ref


def _mk(m, k, n, nnz, group, seed=0):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    a = jax.random.normal(k1, (m, k))
    w = jax.random.normal(k2, (k, n))
    fmt = DBBFormat(8, nnz, group)
    dw = dbb_encode(w, fmt, prune=True)
    qw = quant.quantize_dbb(dw)
    s_a = quant.dynamic_act_scale(a)
    return a, quant.quantize(a, s_a), s_a, dw, qw, fmt


# ---------------------------------------------------------------------------
# quantize / dequantize round trip
# ---------------------------------------------------------------------------


class TestRoundTrip:
    def test_weight_round_trip_error_bound(self):
        """Round-to-nearest: per-channel |W - deq(q(W))| <= scale/2."""
        _, _, _, dw, qw, _ = _mk(8, 64, 32, 4, None)
        back = quant.dequantize_dbb(qw)
        err = jnp.abs(back.values - dw.values)
        bound = qw.scales[None, None, :] / 2 + 1e-7
        assert bool(jnp.all(err <= bound)), float((err - bound).max())

    def test_weight_round_trip_decoded_dense(self):
        """The bound survives decode: dense |W - deq| <= scale/2 per column."""
        _, _, _, dw, qw, _ = _mk(8, 64, 32, 3, "matrix")
        err = jnp.abs(dbb_decode(quant.dequantize_dbb(qw)) - dbb_decode(dw))
        assert bool(jnp.all(err <= qw.scales[None, :] / 2 + 1e-7))

    def test_act_round_trip_error_bound(self):
        x = jax.random.normal(jax.random.PRNGKey(3), (16, 64))
        s = quant.dynamic_act_scale(x)
        back = quant.dequantize(quant.quantize(x, s), s)
        assert bool(jnp.all(jnp.abs(back - x) <= s / 2 + 1e-7))

    def test_scale_shape_invariants(self):
        _, _, _, dw, qw, fmt = _mk(8, 64, 32, 4, None)
        assert qw.values.shape == dw.values.shape and qw.values.dtype == jnp.int8
        assert qw.indices.shape == dw.indices.shape
        assert qw.scales.shape == (32,) and qw.scales.dtype == jnp.float32
        assert qw.shape == dw.shape and qw.fmt == fmt
        assert bool(jnp.all(qw.scales > 0))
        # full int8 range is used: some channel hits ±127
        assert int(jnp.max(jnp.abs(qw.values))) == quant.QMAX

    def test_compressed_bytes_quarter_of_fp32(self):
        _, _, _, dw, qw, _ = _mk(8, 512, 128, 2, None)
        vals_fp = dw.values.size * 4
        # int8 values are exactly 1/4 of the fp32 value stream
        assert qw.nbytes_compressed() < dw.nbytes_compressed()
        assert qw.values.size == vals_fp // 4

    def test_quantize_rejects_integer_values(self):
        _, _, _, _, qw, _ = _mk(8, 64, 32, 4, None)
        with pytest.raises(ValueError):
            quant.quantize_dbb(qw.as_dbb())

    def test_act_scale_from_stats(self):
        x = 3.0 * jax.random.normal(jax.random.PRNGKey(4), (8, 32))
        st = measure_activation(x, name="t")
        assert st.absmax == pytest.approx(float(jnp.abs(x).max()))
        assert quant.act_scale_from_stats(st) == pytest.approx(st.absmax / 127)
        # combine keeps the max range (calibration over layers/batches)
        st2 = measure_activation(0.1 * x, name="t2")
        assert combine([st, st2]).absmax == pytest.approx(st.absmax)
        with pytest.raises(ValueError):
            quant.act_scale_from_stats(measure_activation(jnp.zeros((4, 8))))


# ---------------------------------------------------------------------------
# int8 kernels vs exact integer references (bit-exact)
# ---------------------------------------------------------------------------


class TestInt8KernelsBitExact:
    @pytest.mark.parametrize("nnz", [2, 4, 8])
    def test_tc_matches_int_ref(self, nnz):
        _, aq, _, _, qw, fmt = _mk(16, 64, 32, nnz, "matrix")
        got = ops.vdbb_matmul(aq, qw.as_dbb(), bm=8, bn=16, kb=2, interpret=True)
        want = ref.vdbb_matmul_int_ref(aq, qw.values, qw.indices[:, :, 0], fmt)
        assert got.dtype == jnp.int32
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    @pytest.mark.parametrize("nnz", [2, 4, 8])
    def test_bw_matches_int_ref(self, nnz):
        _, aq, _, _, qw, fmt = _mk(16, 64, 32, nnz, None, seed=1)
        got = ops.vdbb_matmul(aq, qw.as_dbb(), bm=8, bn=16, kb=2, interpret=True)
        want = ref.vdbb_matmul_int_ref(aq, qw.values, qw.indices, fmt)
        assert got.dtype == jnp.int32
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_grouped_expansion_matches_int_ref(self):
        _, aq, _, _, qw, fmt = _mk(8, 64, 32, 3, 8, seed=2)
        got = ops.vdbb_matmul(aq, qw.as_dbb(), bm=8, bn=16, kb=2, interpret=True)
        idx = jnp.repeat(qw.indices, 8, axis=2)
        want = ref.vdbb_matmul_int_ref(aq, qw.values, idx, fmt)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_fused_dequant_matches_ref_exactly(self):
        """scales-on-flush == int32 accumulate then scale (same fp op)."""
        a, aq, s_a, _, qw, _ = _mk(16, 64, 32, 4, "matrix", seed=3)
        got = ops.quant_matmul(a, qw, s_a, bm=8, bn=16, kb=2, interpret=True)
        want = quant.quant_matmul_ref(aq, qw, s_a)
        assert got.dtype == jnp.float32
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6, atol=1e-7)

    @pytest.mark.parametrize("group,stride", [("matrix", 1), (None, 2), (None, 1)])
    def test_conv_matches_int_ref(self, group, stride):
        k1, k2 = jax.random.split(jax.random.PRNGKey(5))
        x = jax.random.normal(k1, (2, 8, 8, 8))
        wt = jax.random.normal(k2, (3, 3, 8, 16))
        fmt = DBBFormat(8, 3, group)
        qw = quant.quantize_dbb(dbb_encode_conv(wt, fmt, prune=True))
        xq = quant.quantize(x, quant.dynamic_act_scale(x))
        got = ops.sparse_conv(xq, qw.as_dbb(), 3, 3, stride=stride, bf=8, interpret=True)
        want = ref.sparse_conv_int_ref(xq, qw.as_dbb(), 3, 3, stride=stride)
        assert got.dtype == jnp.int32
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_conv_fused_dequant_matches_ref(self):
        k1, k2 = jax.random.split(jax.random.PRNGKey(6))
        x = jax.random.normal(k1, (1, 8, 8, 8))
        wt = jax.random.normal(k2, (3, 3, 8, 16))
        qw = quant.quantize_dbb(dbb_encode_conv(wt, DBBFormat(8, 2, "matrix"), prune=True))
        s_a = quant.dynamic_act_scale(x)
        got = ops.quant_conv(x, qw, 3, 3, s_a, bf=8, interpret=True)
        want = quant.quant_conv_ref(quant.quantize(x, s_a), qw, 3, 3, s_a)
        assert got.dtype == jnp.float32
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6, atol=1e-7)

    def test_int32_accumulator_no_overflow_margin(self):
        """Extreme-valued int8 operands over a long K stay exact in int32."""
        k = 512
        aq = jnp.full((4, k), quant.QMAX, jnp.int8)
        w = jnp.ones((k, 16), jnp.float32)
        qw = quant.quantize_dbb(dbb_encode(w, DBBFormat(8, 8, "matrix"), prune=True))
        got = ops.vdbb_matmul(aq, qw.as_dbb(), bm=4, bn=16, kb=2, interpret=True)
        assert int(got[0, 0]) == k * quant.QMAX * quant.QMAX


# ---------------------------------------------------------------------------
# quantized model lifecycle
# ---------------------------------------------------------------------------


def _smoke_model(sparsity, kernel_mode="ref"):
    from repro.configs import smoke_cnn_config
    from repro.models.cnn import SparseCNN

    cfg = smoke_cnn_config("sparse-cnn-tiny", sparsity=sparsity)
    cfg = dataclasses.replace(cfg, kernel_mode=kernel_mode)
    model = SparseCNN(cfg)
    params = model.compress(model.init(jax.random.PRNGKey(0)))
    x = jax.random.normal(
        jax.random.PRNGKey(1), (4, cfg.image_size, cfg.image_size, cfg.in_channels)
    )
    return model, params, x


class TestQuantizedModel:
    # nnz ∈ {2, 4, 8}: sparsity 0.75 → 2/8, 0.5 → 4/8; "dense" → the 8/8
    # bound, which stays uncompressed (and therefore fp32) end to end —
    # the documented fall-through for the dense density bound.
    @pytest.mark.parametrize("sparsity", [0.75, 0.5, "dense"])
    def test_quantized_logits_close_to_fp32(self, sparsity):
        model, params, x = _smoke_model(sparsity)
        logits_fp, stats = model.apply(params, x, collect_act_stats=True)
        qparams = model.quantize(params, stats)
        logits_q = model.apply(qparams, x)
        rel = float(jnp.linalg.norm(logits_q - logits_fp) / jnp.linalg.norm(logits_fp))
        # documented tolerance (DESIGN.md §8): < 5% relative L2 on logits
        assert rel < 0.05, rel
        if sparsity == "dense":
            np.testing.assert_array_equal(  # fp fall-through is exact
                np.asarray(logits_q), np.asarray(logits_fp)
            )

    def test_pallas_path_matches_ref_path(self):
        model_r, params, x = _smoke_model(0.625, "ref")
        model_p, _, _ = _smoke_model(0.625, "pallas")
        _, stats = model_r.apply(params, x, collect_act_stats=True)
        qparams = model_r.quantize(params, stats)
        got = model_p.apply(qparams, x)
        want = model_r.apply(qparams, x)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-6)

    def test_calibrated_scales_are_static(self):
        model, params, x = _smoke_model(0.625)
        _, stats = model.apply(params, x, collect_act_stats=True)
        qparams = model.quantize(params, stats)
        # compressed layers carry a static per-tensor act scale...
        quantized = [
            p for p in qparams.values()
            if isinstance(p.get("w"), quant.QuantDBBWeight)
        ]
        assert quantized and all("aq" in p for p in quantized)
        # ...and without calibration, quantization is dynamic but still works
        qdyn = model.quantize(params)
        assert all("aq" not in p for p in qdyn.values())
        logits = model.apply(qdyn, x)
        assert bool(jnp.all(jnp.isfinite(logits)))

    def test_quantize_is_idempotent_and_preserves_stem(self):
        model, params, x = _smoke_model(0.625)
        qparams = model.quantize(params)
        # stem (C=3, dense fmt) stays fp32
        assert not isinstance(qparams["l0"]["w"], quant.QuantDBBWeight)
        assert qparams["l0"]["w"].dtype == jnp.float32
        again = model.quantize(qparams)
        assert again["l1"]["w"] is qparams["l1"]["w"]

    def test_requantize_updates_calibration_only(self):
        """quantize() on already-quantized params with fresh stats must
        refresh the static act scales without touching the int8 weights."""
        model, params, x = _smoke_model(0.625)
        _, stats = model.apply(params, x, collect_act_stats=True)
        qparams = model.quantize(params)  # dynamic (no aq)
        recal = model.quantize(qparams, stats)
        assert recal["l1"]["w"] is qparams["l1"]["w"]
        assert "aq" in recal["l1"]
        assert float(recal["l1"]["aq"]) == pytest.approx(
            quant.act_scale_from_stats(stats[1])
        )


# ---------------------------------------------------------------------------
# checkpoint round-trip (satellite: store._BITCAST + int8 leaves)
# ---------------------------------------------------------------------------


class TestQuantCheckpoint:
    def test_quant_dbb_weight_roundtrip(self, tmp_path):
        from repro.checkpoint import store

        _, _, _, _, qw, _ = _mk(8, 64, 32, 3, None, seed=7)
        tree = {"l1": {"w": qw, "b": jnp.ones((32,), jnp.bfloat16)}}
        store.save(tmp_path, 5, tree)
        out, manifest = store.restore(tmp_path, tree)
        qr = out["l1"]["w"]
        assert isinstance(qr, quant.QuantDBBWeight)
        assert qr.values.dtype == jnp.int8 and qr.indices.dtype == jnp.int8
        assert qr.scales.dtype == jnp.float32
        np.testing.assert_array_equal(np.asarray(qr.values), np.asarray(qw.values))
        np.testing.assert_array_equal(np.asarray(qr.indices), np.asarray(qw.indices))
        np.testing.assert_array_equal(np.asarray(qr.scales), np.asarray(qw.scales))
        assert qr.fmt == qw.fmt and qr.shape == qw.shape
        assert "int8" in manifest["dtypes"]

    def test_quantized_model_params_roundtrip(self, tmp_path):
        from repro.checkpoint import store

        model, params, x = _smoke_model(0.625)
        _, stats = model.apply(params, x, collect_act_stats=True)
        qparams = model.quantize(params, stats)
        store.save(tmp_path, 1, qparams)
        out, _ = store.restore(tmp_path, qparams)
        np.testing.assert_array_equal(
            np.asarray(model.apply(out, x)), np.asarray(model.apply(qparams, x))
        )

    def test_int4_bitcast_roundtrip(self, tmp_path):
        """_BITCAST covers the sub-byte formats too (int4 via uint8 view)."""
        from repro.checkpoint import store

        tree = {"v": jnp.arange(-8, 8, dtype=jnp.int4)}
        store.save(tmp_path, 0, tree)
        out, _ = store.restore(tmp_path, tree)
        assert out["v"].dtype == jnp.int4
        np.testing.assert_array_equal(
            np.asarray(out["v"], np.int32), np.asarray(tree["v"], np.int32)
        )
