"""Sparse-conv path validation: the fused IM2COL × VDBB kernel vs
``lax.conv_general_dilated(x, dbb_decode(w))``, conv edge cases for the
generalized dense kernel, the DBBConv2d layer lifecycle, and the
grouped-pattern encode/decode round-trip.

Pallas kernels run in interpret mode on CPU (the kernel body executes in
Python), so these validate the exact code that compiles for TPU.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.sparse_conv import DBBConv2d
from repro.core.vdbb import (
    DBBFormat,
    dbb_conv_costs,
    dbb_decode,
    dbb_decode_conv,
    dbb_encode,
    dbb_encode_conv,
    satisfies_dbb,
)
from repro.kernels import ops, ref
from repro.kernels.im2col_conv import im2col_conv
from repro.kernels.vdbb_im2col_conv import vdbb_im2col_conv

TOLS = {jnp.float32: dict(rtol=1e-4, atol=1e-4), jnp.bfloat16: dict(rtol=2e-2, atol=2e-2)}


def _mk_conv(n, h, w, c, f, kh, kw, nnz, group, dtype=jnp.float32, seed=0):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    x = jax.random.normal(k1, (n, h, w, c), jnp.float32).astype(dtype)
    w4 = jax.random.normal(k2, (kh, kw, c, f), jnp.float32)
    fmt = DBBFormat(8, nnz, group)
    dw = dbb_encode_conv(w4, fmt, prune=True)
    dw = jax.tree_util.tree_map(
        lambda a: a.astype(dtype) if a.dtype == jnp.float32 else a, dw
    )
    return x, dw, fmt


class TestDenseConvEdgeCases:
    """Generalized im2col_conv vs lax for every lifted restriction."""

    @pytest.mark.parametrize(
        "n,h,w,c,f,kh,kw,stride,padding,tiles",
        [
            (1, 8, 8, 8, 16, 3, 3, 1, "SAME", None),      # baseline
            (2, 9, 7, 4, 8, 3, 3, 2, "SAME", None),       # stride 2, odd map
            (1, 8, 8, 8, 8, 2, 2, 2, "VALID", None),      # even 2x2 kernel
            (1, 10, 10, 24, 8, 3, 3, 1, "SAME", None),    # non-128 channels
            (1, 12, 12, 8, 16, 5, 3, 1, "SAME", (6, 4)),  # spatial tiling
            (2, 16, 16, 3, 8, 3, 3, 2, "SAME", (4, 4)),   # tiling + stride
            (1, 7, 7, 5, 8, 4, 4, 3, ((1, 2), (2, 1)), None),  # explicit pad
        ],
    )
    def test_allclose_vs_lax(self, n, h, w, c, f, kh, kw, stride, padding, tiles):
        k1, k2 = jax.random.split(jax.random.PRNGKey(1))
        x = jax.random.normal(k1, (n, h, w, c), jnp.float32)
        wk = jax.random.normal(k2, (kh, kw, c, f), jnp.float32)
        th, tw = tiles or (None, None)
        got = im2col_conv(
            x, wk, stride=stride, padding=padding, bf=8, tile_h=th, tile_w=tw
        )
        want = ref.conv_lax_ref(x, wk, stride=stride, padding=padding)
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(want), **TOLS[jnp.float32]
        )

    @pytest.mark.parametrize(
        "stride,kh", [(2, 3), (1, 2)]  # strided + even kernel, bf16 numerics
    )
    def test_allclose_vs_lax_bf16(self, stride, kh):
        k1, k2 = jax.random.split(jax.random.PRNGKey(1))
        x = jax.random.normal(k1, (1, 8, 8, 8), jnp.float32).astype(jnp.bfloat16)
        wk = jax.random.normal(k2, (kh, kh, 8, 8), jnp.float32).astype(jnp.bfloat16)
        got = im2col_conv(x, wk, stride=stride, bf=8)
        want = ref.conv_lax_ref(x, wk, stride=stride)
        np.testing.assert_allclose(
            np.asarray(got, np.float32), np.asarray(want, np.float32), **TOLS[jnp.bfloat16]
        )

    def test_explicit_im2col_ref_matches_lax(self):
        k1, k2 = jax.random.split(jax.random.PRNGKey(2))
        x = jax.random.normal(k1, (2, 9, 9, 4), jnp.float32)
        wk = jax.random.normal(k2, (3, 3, 4, 8), jnp.float32)
        got = ref.im2col_conv_ref(x, wk, stride=2, padding="SAME")
        want = ref.conv_lax_ref(x, wk, stride=2, padding="SAME")
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


class TestFusedSparseConv:
    """Acceptance sweep: vdbb_im2col_conv == lax.conv(dbb_decode(w)) across
    pattern-sharing modes × nnz × strided and spatially-tiled shapes."""

    @pytest.mark.parametrize("group", ["matrix", None])
    @pytest.mark.parametrize("nnz", [1, 4, 8])
    @pytest.mark.parametrize(
        "n,h,w,c,f,kh,kw,stride,tiles",
        [
            (1, 8, 8, 8, 16, 3, 3, 1, None),      # baseline SAME stride-1
            (2, 9, 9, 16, 8, 3, 3, 2, None),      # strided
            (1, 12, 12, 8, 16, 3, 3, 1, (4, 6)),  # spatially tiled
        ],
    )
    def test_allclose_vs_decode_conv(self, group, nnz, n, h, w, c, f, kh, kw, stride, tiles):
        x, dw, fmt = _mk_conv(n, h, w, c, f, kh, kw, nnz, group)
        th, tw = tiles or (None, None)
        got = vdbb_im2col_conv(x, dw, kh, kw, stride=stride, bf=8, tile_h=th, tile_w=tw)
        want = ref.conv_lax_ref(
            x, dbb_decode_conv(dw, kh, kw).astype(x.dtype), stride=stride
        )
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4
        )

    @pytest.mark.parametrize("group", ["matrix", None, 4])
    def test_even_kernel_valid_bf16(self, group):
        x, dw, fmt = _mk_conv(1, 8, 8, 8, 8, 2, 2, 3, group, dtype=jnp.bfloat16)
        got = ops.sparse_conv(x, dw, 2, 2, stride=2, padding="VALID", bf=8, interpret=True)
        want = ref.sparse_conv_ref(x, dw, 2, 2, stride=2, padding="VALID")
        np.testing.assert_allclose(
            np.asarray(got, np.float32), np.asarray(want, np.float32), **TOLS[jnp.bfloat16]
        )

    @pytest.mark.slow
    def test_tiling_sweep(self):
        """Interpret-mode sweep over tile shapes (DESIGN.md §6 tiling)."""
        x, dw, fmt = _mk_conv(1, 12, 12, 16, 16, 3, 3, 4, "matrix", seed=7)
        want = ref.sparse_conv_ref(x, dw, 3, 3)
        for th, tw in [(2, 2), (3, 12), (12, 4), (6, 6)]:
            got = vdbb_im2col_conv(x, dw, 3, 3, bf=8, tile_h=th, tile_w=tw)
            np.testing.assert_allclose(
                np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4,
                err_msg=f"tile {(th, tw)}",
            )

    def test_rejects_block_straddling_taps(self):
        x, dw, _ = _mk_conv(1, 8, 8, 8, 8, 3, 3, 4, "matrix")
        bad = dataclasses.replace(dw, shape=(9 * 4, 8))  # C=4 not % bz=8
        with pytest.raises(ValueError, match="straddle"):
            vdbb_im2col_conv(x, bad, 3, 3)


class TestGroupedRoundTrip:
    """dbb_encode/dbb_decode round-trip with grouped (int g) patterns."""

    @pytest.mark.parametrize("group", [2, 4, "matrix", None])
    def test_round_trip(self, group):
        fmt = DBBFormat(8, 3, group)
        k, n = 64, 16
        w = jax.random.normal(jax.random.PRNGKey(0), (k, n))
        from repro.core.vdbb import dbb_prune

        pruned = dbb_prune(w, fmt)
        assert satisfies_dbb(pruned, fmt)
        dw = dbb_encode(pruned, fmt)
        back = dbb_decode(dw)
        np.testing.assert_allclose(np.asarray(back), np.asarray(pruned), rtol=0, atol=0)

    def test_conv_round_trip(self):
        fmt = DBBFormat(8, 4, None)
        w4 = jax.random.normal(jax.random.PRNGKey(3), (3, 3, 16, 8))
        dw = dbb_encode_conv(w4, fmt, prune=True)
        back = dbb_decode_conv(dw, 3, 3)
        assert back.shape == w4.shape
        # decoded weight satisfies the constraint and keeps kept values exact
        assert satisfies_dbb(back.reshape(-1, 8), fmt)
        mask = np.asarray(back) != 0
        np.testing.assert_allclose(np.asarray(back)[mask], np.asarray(w4)[mask])


class TestDBBConv2dLayer:
    @pytest.mark.parametrize("group", ["matrix", None])
    def test_lifecycle_constrain_compress_serve(self, group):
        fmt = DBBFormat(8, 3, group)
        layer = DBBConv2d(16, 8, kernel_size=3, stride=2, fmt=fmt, use_bias=True)
        params = layer.init(jax.random.PRNGKey(0))
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 9, 9, 16))
        params = layer.constrain(params)
        kh, kw = layer.kh, layer.kw
        w2 = params["w"].reshape(kh * kw * 16, 8)
        assert satisfies_dbb(w2, fmt)
        y_dense = layer(params, x)
        served = layer.compress_params(params)
        y_ref = layer(served, x)
        y_pallas = dataclasses.replace(layer, kernel_mode="pallas")(served, x)
        np.testing.assert_allclose(
            np.asarray(y_ref), np.asarray(y_dense), rtol=1e-4, atol=1e-4
        )
        np.testing.assert_allclose(
            np.asarray(y_pallas), np.asarray(y_ref), rtol=1e-4, atol=1e-4
        )

    def test_sparse_cnn_end_to_end(self):
        from repro.configs import smoke_cnn_config
        from repro.models.cnn import SparseCNN

        cfg = smoke_cnn_config("sparse-cnn-tiny")
        model = SparseCNN(cfg)
        params = model.constrain(model.init(jax.random.PRNGKey(0)))
        x = jax.random.normal(jax.random.PRNGKey(1), (2, cfg.image_size, cfg.image_size, 3))
        served = model.compress(params)
        y_ref = model(served, x)
        y_pl = SparseCNN(dataclasses.replace(cfg, kernel_mode="pallas"))(served, x)
        assert y_ref.shape == (2, cfg.num_classes)
        np.testing.assert_allclose(
            np.asarray(y_pl), np.asarray(y_ref), rtol=1e-4, atol=1e-4
        )


class TestConvCosts:
    def test_combined_accounting(self):
        fmt = DBBFormat(8, 2, "matrix")
        c = dbb_conv_costs(1, 32, 32, 64, 128, 3, 3, fmt)
        assert c["speedup"] == 4.0
        assert c["im2col_magnification"] == pytest.approx(9.0)
        assert c["combined_reduction"] == pytest.approx(36.0)
        assert c["act_bytes"] == c["act_bytes_raw"]
        stored = dbb_conv_costs(1, 32, 32, 64, 128, 3, 3, fmt, im2col_unit=False)
        assert stored["act_bytes"] == stored["act_bytes_expanded"]
        # strided conv: expansion ratio shrinks with the output map
        s2 = dbb_conv_costs(1, 32, 32, 64, 128, 3, 3, fmt, stride=2)
        assert s2["im2col_magnification"] < c["im2col_magnification"]

    def test_conv_roofline_row(self):
        from benchmarks.roofline import conv_roofline_row

        fmt = DBBFormat(8, 3, "matrix")
        row = conv_roofline_row(8, 32, 32, 64, 128, 3, 3, fmt)
        assert row["bound_reduction"] > 1.0
        assert row["dominant"] in ("compute", "memory")
