"""Robustness layer of the serving tier (DESIGN.md §14).

Chaos-style tests through the deterministic fault injector
(`repro.launch.faults`) installed at the server's hook seams — no
monkeypatching of internals. Covers admission validation (every
`bad_input` kind rejected alone), blast-radius isolation (poison in a
full co-batch: innocents bit-identical, exactly the poison typed-failed,
zero bisect retraces), overload shedding (reject with measured
retry-after / block backpressure), deadline expiry before dispatch,
dispatcher-crash supervision (`ServerCrashed`, clean restart), health
reporting, and the `completed+rejected+failed+expired == offered`
accounting identity on every path.
"""
import dataclasses
import time
from concurrent.futures import CancelledError

import jax
import numpy as np
import pytest

from repro.configs import smoke_cnn_config
from repro.launch.faults import FaultInjected, FaultInjector, bad_input
from repro.launch.server import CNNServer, DeadlineExceeded, InvalidRequest, \
    NumericalFault, Overloaded, ServerCrashed, validate_request
from repro.models.cnn import SparseCNN


@pytest.fixture(scope="module")
def served():
    """Ref-kernel quantized model + a max_batch=4 bucketed plan set."""
    cfg = dataclasses.replace(
        smoke_cnn_config("sparse-cnn-tiny", sparsity=0.625), kernel_mode="ref"
    )
    model = SparseCNN(cfg)
    params = model.compress(model.init(jax.random.PRNGKey(0)))
    x = jax.random.normal(
        jax.random.PRNGKey(1),
        (12, cfg.image_size, cfg.image_size, cfg.in_channels),
    )
    _, stats = model.apply(params, x[:4], collect_act_stats=True)
    qparams = model.quantize(params, stats)
    plan_set = model.plan_set(qparams, max_batch=4, tune="off")
    return model, qparams, np.asarray(x), plan_set


# ------------------------------------------------------------ admission
def test_sample_spec_plumbed_from_config(served):
    _, _, x, ps = served
    assert ps.sample_spec == (tuple(x.shape[1:]), "float32")


@pytest.mark.parametrize("kind", ["shape", "rank", "dtype", "nan", "inf"])
def test_validate_request_rejects_bad_inputs(served, kind):
    _, _, x, ps = served
    with pytest.raises(InvalidRequest):
        validate_request(bad_input(kind, x.shape[1:]), ps.sample_spec)
    validate_request(x[:1], ps.sample_spec)  # a good request passes


@pytest.mark.parametrize("kind", ["shape", "dtype", "nan"])
def test_submit_rejects_bad_input_alone(served, kind):
    """A malformed request is rejected at admission — counted, typed,
    and without touching the innocent request served beside it."""
    _, _, x, ps = served
    srv = CNNServer(ps, max_wait_ms=20.0)
    with srv:
        srv.warmup()
        with pytest.raises(InvalidRequest):
            srv.submit(bad_input(kind, x.shape[1:]))
        good = srv.submit(x[:1]).result(timeout=30)
    np.testing.assert_array_equal(good, np.asarray(ps.serve(x[:1])))
    s = srv.stats.summary()
    assert s["rejected"] == 1 and s["completed"] == 1 and s["offered"] == 2
    srv.stats.assert_accounting()
    assert srv.retraces_after_warmup == 0


def test_submit_rejects_nonpositive_deadline(served):
    _, _, x, ps = served
    with CNNServer(ps) as srv:
        with pytest.raises(InvalidRequest):
            srv.submit(x[:1], deadline_s=0.0)
    srv.stats.assert_accounting()


# ------------------------------------------------- blast-radius isolation
def _co_batch(srv, inj_or_none, reqs, max_wait_ms):
    """Submit reqs[0] as a plug, let it dispatch alone, then submit the
    rest quickly so they co-batch behind the (slow) plug."""
    futures = [srv.submit(reqs[0])]
    time.sleep(3 * max_wait_ms / 1e3)
    futures += [srv.submit(r) for r in reqs[1:]]
    return futures


def test_bisect_isolates_raise_poison(served):
    """One raise-poison in a full co-batch: every innocent completes
    bit-identical to a fault-free per-request serve, exactly the poison
    future carries FaultInjected, and bisection (halves pad to warmed
    buckets) adds zero retraces."""
    _, _, x, ps = served
    inj = FaultInjector(slow_s=0.08)
    reqs = [x[i : i + 1] for i in range(5)]  # plug + a full 4-batch
    inj.poison(reqs[2], "raise")
    ref = {i: np.asarray(ps.plans[1].serve(r))
           for i, r in enumerate(reqs) if i != 2}
    srv = CNNServer(ps, max_wait_ms=5.0, faults=inj)
    with srv:
        srv.warmup()
        futures = _co_batch(srv, inj, reqs, 5.0)
        for i, f in enumerate(futures):
            if i == 2:
                with pytest.raises(FaultInjected):
                    f.result(timeout=30)
            else:
                np.testing.assert_array_equal(f.result(timeout=30), ref[i])
    assert srv.retraces_after_warmup == 0
    srv.stats.assert_accounting()
    s = srv.stats.summary()
    assert s["completed"] == 4 and s["failed"] == 1


def test_nan_poison_fails_only_its_request(served):
    """NaN activations (injected past the datapath — NaN *inputs* are
    already rejected at admission) fail exactly the poisoned request
    with NumericalFault; its co-batch is untouched."""
    _, _, x, ps = served
    inj = FaultInjector(slow_s=0.08)
    reqs = [x[i : i + 1] for i in range(5)]
    inj.poison(reqs[3], "nan")
    srv = CNNServer(ps, max_wait_ms=5.0, faults=inj)
    with srv:
        srv.warmup()
        futures = _co_batch(srv, inj, reqs, 5.0)
        for i, f in enumerate(futures):
            if i == 3:
                with pytest.raises(NumericalFault):
                    f.result(timeout=30)
            else:
                np.testing.assert_array_equal(
                    f.result(timeout=30), np.asarray(ps.plans[1].serve(reqs[i]))
                )
    assert srv.retraces_after_warmup == 0
    srv.stats.assert_accounting()


# ------------------------------------------------------------- overload
def test_overload_reject_sheds_with_retry_after(served):
    _, _, x, ps = served
    inj = FaultInjector(slow_s=0.15)          # hold the dispatcher busy
    srv = CNNServer(ps, max_wait_ms=1.0, max_queue=2, shed="reject",
                    faults=inj)
    with srv:
        srv.warmup()
        f1 = srv.submit(x[:1])                # in system: depth 1
        time.sleep(0.02)                      # f1 dispatched (slowly)
        f2 = srv.submit(x[1:2])               # depth 2 == max_queue
        with pytest.raises(Overloaded) as ei:
            srv.submit(x[:1])                 # over the bound: shed
        assert ei.value.retry_after_s > 0
        assert srv.health()["status"] == "degraded"  # at capacity
        f1.result(timeout=30)
        f2.result(timeout=30)
    s = srv.stats.summary()
    assert s["rejected"] == 1 and s["shed_rate"] > 0
    srv.stats.assert_accounting()


def test_overload_block_backpressures(served):
    """shed='block': the submitter waits for space instead of a raise,
    and is admitted once the in-flight request completes."""
    _, _, x, ps = served
    inj = FaultInjector(slow_s=0.1)
    srv = CNNServer(ps, max_wait_ms=1.0, max_queue=1, shed="block",
                    faults=inj)
    with srv:
        srv.warmup()
        f1 = srv.submit(x[:1])
        time.sleep(0.02)
        t0 = time.monotonic()
        f2 = srv.submit(x[1:2])               # blocks until f1 resolves
        blocked = time.monotonic() - t0
        f1.result(timeout=30)
        f2.result(timeout=30)
    assert blocked > 0.02                     # it actually waited
    assert srv.stats.summary()["rejected"] == 0
    srv.stats.assert_accounting()


# ------------------------------------------------------------- deadlines
def test_deadline_expires_before_dispatch(served):
    """A request whose deadline passes while the dispatcher is held busy
    fails with DeadlineExceeded without wasting a bucket dispatch."""
    _, _, x, ps = served
    inj = FaultInjector(slow_s=0.2)
    srv = CNNServer(ps, max_wait_ms=1.0, faults=inj)
    with srv:
        srv.warmup()
        plug = srv.submit(x[:1])
        time.sleep(0.02)                      # plug dispatched, 0.2s serve
        doomed = srv.submit(x[1:2], deadline_s=0.05)
        dispatches_before = inj.dispatches
        with pytest.raises(DeadlineExceeded):
            doomed.result(timeout=30)
        plug.result(timeout=30)
    # the expired request never reached pre_serve: only the plug dispatched
    assert inj.dispatches == dispatches_before
    s = srv.stats.summary()
    assert s["expired"] == 1 and s["completed"] == 1
    srv.stats.assert_accounting()


def test_deadline_met_flushes_early(served):
    """With a huge max_wait, a deadline request still completes: the
    batcher tightens the flush time by deadline - service estimate."""
    _, _, x, ps = served
    srv = CNNServer(ps, max_wait_ms=10_000.0)
    with srv:
        srv.warmup()
        t0 = time.monotonic()
        out = srv.submit(x[:1], deadline_s=1.0).result(timeout=30)
        elapsed = time.monotonic() - t0
    np.testing.assert_array_equal(out, np.asarray(ps.serve(x[:1])))
    assert elapsed < 5.0                      # nowhere near the 10s max-wait
    srv.stats.assert_accounting()


# ----------------------------------------------------------- supervision
def test_dispatcher_crash_fails_pending_and_restart_recovers(served):
    _, _, x, ps = served
    inj = FaultInjector(kill_after_dispatches=0)  # first tick with work dies
    srv = CNNServer(ps, max_wait_ms=5.0, faults=inj)
    srv.start()
    srv.warmup()
    fut = srv.submit(x[:1])
    with pytest.raises(ServerCrashed):
        fut.result(timeout=30)
    with pytest.raises(ServerCrashed):
        srv.submit(x[:1])                     # submit is poisoned too
    h = srv.health()
    assert h["status"] == "stopped" and h["crashed"]
    assert srv.stats.summary()["failed"] == 1
    srv.stats.assert_accounting()
    srv.stop()

    inj.kill_after_dispatches = None          # operator fixed the fault
    srv.start()                               # restart: fresh books
    assert srv.stats.summary()["offered"] == 0
    assert srv.health()["status"] == "ready"
    out = srv.submit(x[:1]).result(timeout=30)
    np.testing.assert_array_equal(out, np.asarray(ps.serve(x[:1])))
    assert srv.retraces_after_warmup == 0     # buckets stayed compiled
    srv.stop()
    srv.stats.assert_accounting()


def test_health_degrades_on_fault_and_recovers(served):
    _, _, x, ps = served
    inj = FaultInjector()
    poison = inj.poison(np.array(x[5:6]))     # lone poison: no co-batch
    srv = CNNServer(ps, max_wait_ms=5.0, faults=inj)
    with srv:
        srv.warmup()
        assert srv.health()["status"] == "ready"
        with pytest.raises(FaultInjected):
            srv.submit(poison).result(timeout=30)
        assert srv.health()["status"] == "degraded"
        srv.submit(x[:1]).result(timeout=30)  # a clean batch clears it
        assert srv.health()["status"] == "ready"
    assert srv.health()["status"] == "stopped"
    srv.stats.assert_accounting()


def test_stop_timeout_abandons_drain(served):
    """stop(timeout_s=) bounds the drain: past it, the remaining queue is
    cancelled (CancelledError for waiters — never a hang) and the books
    still balance."""
    _, _, x, ps = served
    inj = FaultInjector(slow_s=0.4)           # each dispatch outlives the
    srv = CNNServer(ps, max_wait_ms=1.0, faults=inj)  # 0.2s drain budget
    srv.start()
    srv.warmup()
    futures = [srv.submit(x[i : i + 1]) for i in range(8)]
    t0 = time.monotonic()
    srv.stop(timeout_s=0.2)
    # one in-flight 0.4s dispatch finishes; everything after is cancelled
    assert time.monotonic() - t0 < 2.0        # nowhere near 8 x 0.4s
    outcomes = {"done": 0, "cancelled": 0}
    for f in futures:
        try:
            f.result(timeout=1)
            outcomes["done"] += 1
        except CancelledError:
            outcomes["cancelled"] += 1
    assert outcomes["cancelled"] > 0 and outcomes["done"] > 0
    assert sum(outcomes.values()) == 8
    srv.stats.assert_accounting()
