"""Measured-wall-time regression gates + bench-harness exit contract
(DESIGN.md §12).

Covers ``benchmarks/check_regression.py``: the artifact schema validation
(required keys, finite positive numbers — a truncated or hand-edited
artifact must fail loudly), the fused wall-time gates with their
self-calibrating noise-widened margins, and ``benchmarks/run.py``'s
exit-code contract via a real subprocess with a deliberately failing
bench module injected through ``REPRO_BENCH_EXTRA``.
"""
import json
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

from benchmarks import check_regression as cr

ROOT = Path(__file__).resolve().parents[1]


def _fused_artifact(**wall_overrides):
    wall = {
        "layer_fused": 1000.0, "layer_unfused": 1100.0,
        "cnn_int8_resident": 950.0, "cnn_per_layer_dequant": 960.0,
    }
    wall.update(wall_overrides)
    return {
        "layers": [
            {"name": "l0", "saved_frac": 0.93, "hbm_bytes_fused": 36812,
             "hbm_bytes_unfused": 561100},
            {"name": "l1", "saved_frac": 0.82, "hbm_bytes_fused": 58368,
             "hbm_bytes_unfused": 320512},
        ],
        "wall_time_us": wall,
        "noise_frac": {"layer": 0.05, "cnn": 0.05},
        "harness": {"stat": "min", "reps": 25, "warmup": 2,
                    "interleaved": True, "backend": "cpu"},
    }


class TestSchema:
    def test_valid_artifact_passes(self):
        assert cr.schema_errors("BENCH_fused.json", _fused_artifact()) == []

    def test_missing_key(self):
        art = _fused_artifact()
        del art["wall_time_us"]["cnn_int8_resident"]
        errs = cr.schema_errors("BENCH_fused.json", art)
        assert any("cnn_int8_resident" in e for e in errs)

    @pytest.mark.parametrize("bad", [float("nan"), float("inf"), 0, -3.0,
                                     "1000", True, None])
    def test_non_finite_or_non_positive_number(self, bad):
        art = _fused_artifact(layer_fused=bad)
        errs = cr.schema_errors("BENCH_fused.json", art)
        assert any("layer_fused" in e for e in errs), (bad, errs)

    def test_empty_layers_list(self):
        art = _fused_artifact()
        art["layers"] = []
        errs = cr.schema_errors("BENCH_fused.json", art)
        assert any("layers" in e for e in errs)

    def test_unknown_artifact_has_no_schema(self):
        assert cr.schema_errors("BENCH_other.json", {}) == []

    def test_serve_schema(self):
        ok = {"plan_us": 10.0, "unplanned_jit_us": 12.0, "bit_identical": True}
        assert cr.schema_errors("BENCH_serve.json", ok) == []
        errs = cr.schema_errors("BENCH_serve.json",
                                {"plan_us": 10.0, "unplanned_jit_us": 12.0})
        assert any("bit_identical" in e for e in errs)


class TestWallGates:
    def _check(self, art, tmp_path, monkeypatch):
        (tmp_path / "BENCH_fused.json").write_text(json.dumps(art))
        monkeypatch.setattr(cr, "ROOT", tmp_path)
        return cr.check_fused()

    def test_clean_artifact_passes(self, tmp_path, monkeypatch):
        assert self._check(_fused_artifact(), tmp_path, monkeypatch) == []

    def test_fused_layer_regression_trips(self, tmp_path, monkeypatch):
        # margin at noise 0.05 = 1.1 * 1.05 = 1.155; 1300 > 1100 * 1.155
        art = _fused_artifact(layer_fused=1300.0)
        errs = self._check(art, tmp_path, monkeypatch)
        assert any("layer_fused" in e for e in errs)

    def test_chain_regression_trips(self, tmp_path, monkeypatch):
        art = _fused_artifact(cnn_int8_resident=1200.0)
        errs = self._check(art, tmp_path, monkeypatch)
        assert any("cnn_int8_resident" in e for e in errs)

    def test_noise_widens_margin_but_cap_bounds_it(self, tmp_path, monkeypatch):
        # 1250/1100 = 1.136 fails at noise 0 (margin 1.1) but passes once
        # the measured noise widens the margin to 1.1 * 1.3 = 1.43
        art = _fused_artifact(layer_fused=1250.0)
        art["noise_frac"]["layer"] = 0.0
        assert self._check(art, tmp_path, monkeypatch) != []
        art["noise_frac"]["layer"] = 0.3
        assert self._check(art, tmp_path, monkeypatch) == []
        # ...but a pathologically noisy artifact cannot gate itself
        # vacuously: the cap bounds the margin at 1.1 * (1 + cap) = 1.65
        art = _fused_artifact(layer_fused=2000.0)
        art["noise_frac"]["layer"] = 50.0
        assert self._check(art, tmp_path, monkeypatch) != []

    def test_schema_failure_short_circuits(self, tmp_path, monkeypatch):
        art = _fused_artifact()
        del art["noise_frac"]
        errs = self._check(art, tmp_path, monkeypatch)
        assert errs and all("schema" in e for e in errs)

    def test_saved_frac_floor_still_enforced(self, tmp_path, monkeypatch):
        art = _fused_artifact()
        art["layers"][0]["saved_frac"] = 0.10
        errs = self._check(art, tmp_path, monkeypatch)
        assert any("hard floor" in e for e in errs)

    def test_baselines_carry_wall_margins(self):
        base = json.loads((ROOT / "benchmarks" / "bench_baselines.json").read_text())
        assert base["fused_wall_margin"] >= 1.0
        assert 0 < base["fused_noise_cap"] <= 1.0


@pytest.mark.slow
class TestRunExitCode:
    """benchmarks/run.py must exit nonzero when *any* module fails."""

    def _run(self, tmp_path, body, only):
        (tmp_path / "fake_bench.py").write_text(textwrap.dedent(body))
        env = dict(os.environ)
        env["REPRO_BENCH_EXTRA"] = "fake_bench"
        env["PYTHONPATH"] = os.pathsep.join(
            [str(tmp_path), str(ROOT), env.get("PYTHONPATH", "")])
        return subprocess.run(
            [sys.executable, "-m", "benchmarks.run", "--smoke",
             "--only", only],
            capture_output=True, text=True, env=env, cwd=ROOT, timeout=120,
        )

    def test_failing_module_exits_nonzero_with_summary(self, tmp_path):
        proc = self._run(tmp_path, """
            def run(report):
                report("fake/ok", 1.0)
                raise AssertionError("deliberate gate failure")
        """, only="fake_bench")
        assert proc.returncode == 1, proc.stderr
        assert "FAILED 1/1" in proc.stderr
        assert "deliberate gate failure" in proc.stderr
        assert "fake_bench/FAILED" in proc.stdout

    def test_passing_module_exits_zero(self, tmp_path):
        proc = self._run(tmp_path, """
            def run(report):
                report("fake/ok", 1.0)
        """, only="fake_bench")
        assert proc.returncode == 0, proc.stderr
        assert "fake/ok" in proc.stdout
