"""Measured-wall-time regression gates + bench-harness exit contract
(DESIGN.md §12).

Covers ``benchmarks/check_regression.py``: the artifact schema validation
(required keys, finite positive numbers — a truncated or hand-edited
artifact must fail loudly), the fused wall-time gates with their
self-calibrating noise-widened margins, and ``benchmarks/run.py``'s
exit-code contract via a real subprocess with a deliberately failing
bench module injected through ``REPRO_BENCH_EXTRA``.
"""
import json
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

from benchmarks import check_regression as cr

ROOT = Path(__file__).resolve().parents[1]


def _fused_artifact(**wall_overrides):
    wall = {
        "layer_fused": 1000.0, "layer_unfused": 1100.0,
        "cnn_int8_resident": 950.0, "cnn_per_layer_dequant": 960.0,
    }
    wall.update(wall_overrides)
    return {
        "layers": [
            {"name": "l0", "saved_frac": 0.93, "hbm_bytes_fused": 36812,
             "hbm_bytes_unfused": 561100},
            {"name": "l1", "saved_frac": 0.82, "hbm_bytes_fused": 58368,
             "hbm_bytes_unfused": 320512},
        ],
        "wall_time_us": wall,
        "noise_frac": {"layer": 0.05, "cnn": 0.05},
        "harness": {"stat": "min", "reps": 25, "warmup": 2,
                    "interleaved": True, "backend": "cpu"},
    }


def _serve_artifact(**overrides):
    """Minimal BENCH_serve.json passing schema + check_chaos (§14)."""
    art = {
        "plan_us": 10.0, "unplanned_jit_us": 12.0, "bit_identical": True,
        "patterns": {"poisson": {"completed": 48, "offered": 48,
                                 "retraces_after_warmup": 0,
                                 "p99_us": 9000.0, "p99_bound_us": 230000.0}},
        "chaos": {"innocent_survival": 1.0, "poison_typed": True,
                  "retraces_after_warmup": 0, "accounting_ok": True,
                  "goodput_rps": 50.0},
        "overload": {"goodput_rps": 1400.0, "capacity_rps": 3600.0,
                     "shed_rate": 0.7, "rejected": 67, "completed": 29,
                     "offered": 96, "accounting_ok": True,
                     "p99_us": 6000.0, "p99_bound_us": 100000.0},
        "selfheal": {
            "restart": {"restarts": 1, "requeued": 2, "survival": 1.0,
                        "hung": 0, "accounting_ok": True},
            "reload": {"corrupt_typed": True, "old_plan_served": True,
                       "fallback_recovered": True, "reloads": 2},
            "degraded": {"survival": 1.0, "demoted_exact": True,
                         "innocents_bit_identical": True, "repromoted": True,
                         "healthy_sps": 400.0, "degraded_sps": 800.0,
                         "accounting_ok": True},
        },
    }
    for key, val in overrides.items():
        sect, _, leaf = key.partition("__")
        if leaf:
            art[sect][leaf] = val
        else:
            art[sect] = val
    return art


class TestSchema:
    def test_valid_artifact_passes(self):
        assert cr.schema_errors("BENCH_fused.json", _fused_artifact()) == []

    def test_missing_key(self):
        art = _fused_artifact()
        del art["wall_time_us"]["cnn_int8_resident"]
        errs = cr.schema_errors("BENCH_fused.json", art)
        assert any("cnn_int8_resident" in e for e in errs)

    @pytest.mark.parametrize("bad", [float("nan"), float("inf"), 0, -3.0,
                                     "1000", True, None])
    def test_non_finite_or_non_positive_number(self, bad):
        art = _fused_artifact(layer_fused=bad)
        errs = cr.schema_errors("BENCH_fused.json", art)
        assert any("layer_fused" in e for e in errs), (bad, errs)

    def test_empty_layers_list(self):
        art = _fused_artifact()
        art["layers"] = []
        errs = cr.schema_errors("BENCH_fused.json", art)
        assert any("layers" in e for e in errs)

    def test_unknown_artifact_has_no_schema(self):
        assert cr.schema_errors("BENCH_other.json", {}) == []

    def test_serve_schema(self):
        ok = _serve_artifact()
        assert cr.schema_errors("BENCH_serve.json", ok) == []
        bad = _serve_artifact()
        del bad["bit_identical"]
        errs = cr.schema_errors("BENCH_serve.json", bad)
        assert any("bit_identical" in e for e in errs)
        bad = _serve_artifact()
        del bad["chaos"]["poison_typed"]
        errs = cr.schema_errors("BENCH_serve.json", bad)
        assert any("poison_typed" in e for e in errs)


class TestWallGates:
    def _check(self, art, tmp_path, monkeypatch):
        (tmp_path / "BENCH_fused.json").write_text(json.dumps(art))
        monkeypatch.setattr(cr, "ROOT", tmp_path)
        return cr.check_fused()

    def test_clean_artifact_passes(self, tmp_path, monkeypatch):
        assert self._check(_fused_artifact(), tmp_path, monkeypatch) == []

    def test_fused_layer_regression_trips(self, tmp_path, monkeypatch):
        # margin at noise 0.05 = 1.1 * 1.05 = 1.155; 1300 > 1100 * 1.155
        art = _fused_artifact(layer_fused=1300.0)
        errs = self._check(art, tmp_path, monkeypatch)
        assert any("layer_fused" in e for e in errs)

    def test_chain_regression_trips(self, tmp_path, monkeypatch):
        art = _fused_artifact(cnn_int8_resident=1200.0)
        errs = self._check(art, tmp_path, monkeypatch)
        assert any("cnn_int8_resident" in e for e in errs)

    def test_noise_widens_margin_but_cap_bounds_it(self, tmp_path, monkeypatch):
        # 1250/1100 = 1.136 fails at noise 0 (margin 1.1) but passes once
        # the measured noise widens the margin to 1.1 * 1.3 = 1.43
        art = _fused_artifact(layer_fused=1250.0)
        art["noise_frac"]["layer"] = 0.0
        assert self._check(art, tmp_path, monkeypatch) != []
        art["noise_frac"]["layer"] = 0.3
        assert self._check(art, tmp_path, monkeypatch) == []
        # ...but a pathologically noisy artifact cannot gate itself
        # vacuously: the cap bounds the margin at 1.1 * (1 + cap) = 1.65
        art = _fused_artifact(layer_fused=2000.0)
        art["noise_frac"]["layer"] = 50.0
        assert self._check(art, tmp_path, monkeypatch) != []

    def test_schema_failure_short_circuits(self, tmp_path, monkeypatch):
        art = _fused_artifact()
        del art["noise_frac"]
        errs = self._check(art, tmp_path, monkeypatch)
        assert errs and all("schema" in e for e in errs)

    def test_saved_frac_floor_still_enforced(self, tmp_path, monkeypatch):
        art = _fused_artifact()
        art["layers"][0]["saved_frac"] = 0.10
        errs = self._check(art, tmp_path, monkeypatch)
        assert any("hard floor" in e for e in errs)

    def test_baselines_carry_wall_margins(self):
        base = json.loads((ROOT / "benchmarks" / "bench_baselines.json").read_text())
        assert base["fused_wall_margin"] >= 1.0
        assert 0 < base["fused_noise_cap"] <= 1.0


@pytest.mark.slow
class TestChaosGate:
    """check_chaos (DESIGN.md §14): the blast-radius + overload gates on
    the chaos/overload scenarios recorded in BENCH_serve.json."""

    def _check(self, art, tmp_path, monkeypatch):
        (tmp_path / "BENCH_serve.json").write_text(json.dumps(art))
        monkeypatch.setattr(cr, "ROOT", tmp_path)
        return cr.check_chaos()

    def test_clean_artifact_passes(self, tmp_path, monkeypatch):
        assert self._check(_serve_artifact(), tmp_path, monkeypatch) == []

    def test_missing_scenarios_trip(self, tmp_path, monkeypatch):
        art = _serve_artifact()
        del art["chaos"]
        errs = self._check(art, tmp_path, monkeypatch)
        assert any("missing" in e for e in errs)

    def test_innocent_casualty_trips(self, tmp_path, monkeypatch):
        errs = self._check(_serve_artifact(chaos__innocent_survival=0.857),
                           tmp_path, monkeypatch)
        assert any("survival" in e for e in errs)

    def test_untyped_poison_trips(self, tmp_path, monkeypatch):
        errs = self._check(_serve_artifact(chaos__poison_typed=False),
                           tmp_path, monkeypatch)
        assert any("typed" in e for e in errs)

    def test_bisect_retrace_trips(self, tmp_path, monkeypatch):
        errs = self._check(_serve_artifact(chaos__retraces_after_warmup=2),
                           tmp_path, monkeypatch)
        assert any("retraced" in e for e in errs)

    def test_accounting_leak_trips(self, tmp_path, monkeypatch):
        errs = self._check(_serve_artifact(overload__accounting_ok=False),
                           tmp_path, monkeypatch)
        assert any("leaked" in e for e in errs)

    def test_inert_admission_trips(self, tmp_path, monkeypatch):
        errs = self._check(_serve_artifact(overload__shed_rate=0.0),
                           tmp_path, monkeypatch)
        assert any("shed" in e for e in errs)

    def test_overload_p99_over_bound_trips(self, tmp_path, monkeypatch):
        errs = self._check(_serve_artifact(overload__p99_us=200000.0),
                           tmp_path, monkeypatch)
        assert any("p99" in e for e in errs)

    def test_goodput_collapse_trips(self, tmp_path, monkeypatch):
        # floor = chaos_goodput_floor (0.1) x capacity 3600 = 360 rps
        errs = self._check(_serve_artifact(overload__goodput_rps=100.0),
                           tmp_path, monkeypatch)
        assert any("goodput" in e for e in errs)

    # ----------------------------------------- §15 self-healing gates
    def test_selfheal_missing_trips(self, tmp_path, monkeypatch):
        art = _serve_artifact()
        del art["selfheal"]
        errs = self._check(art, tmp_path, monkeypatch)
        assert any("selfheal" in e and "missing" in e for e in errs)

    def test_no_restart_trips(self, tmp_path, monkeypatch):
        art = _serve_artifact()
        art["selfheal"]["restart"]["restarts"] = 0
        errs = self._check(art, tmp_path, monkeypatch)
        assert any("never exercised supervision" in e for e in errs)

    def test_hung_future_trips(self, tmp_path, monkeypatch):
        art = _serve_artifact()
        art["selfheal"]["restart"]["hung"] = 1
        errs = self._check(art, tmp_path, monkeypatch)
        assert any("hung" in e for e in errs)

    def test_cross_restart_accounting_trips(self, tmp_path, monkeypatch):
        art = _serve_artifact()
        art["selfheal"]["restart"]["accounting_ok"] = False
        errs = self._check(art, tmp_path, monkeypatch)
        assert any("across" in e and "restart" in e for e in errs)

    def test_untyped_corrupt_reload_trips(self, tmp_path, monkeypatch):
        art = _serve_artifact()
        art["selfheal"]["reload"]["corrupt_typed"] = False
        errs = self._check(art, tmp_path, monkeypatch)
        assert any("CorruptCheckpointError" in e for e in errs)

    def test_old_plan_not_serving_trips(self, tmp_path, monkeypatch):
        art = _serve_artifact()
        art["selfheal"]["reload"]["old_plan_served"] = False
        errs = self._check(art, tmp_path, monkeypatch)
        assert any("old plan" in e for e in errs)

    def test_unisolated_demotion_trips(self, tmp_path, monkeypatch):
        art = _serve_artifact()
        art["selfheal"]["degraded"]["demoted_exact"] = False
        errs = self._check(art, tmp_path, monkeypatch)
        assert any("exactly the faulty bucket" in e for e in errs)

    def test_degraded_goodput_collapse_trips(self, tmp_path, monkeypatch):
        # floor = selfheal_goodput_floor (0.1) x healthy 400 = 40 samples/s
        art = _serve_artifact()
        art["selfheal"]["degraded"]["degraded_sps"] = 10.0
        errs = self._check(art, tmp_path, monkeypatch)
        assert any("fallback collapsed" in e for e in errs)

    def test_never_repromoted_trips(self, tmp_path, monkeypatch):
        art = _serve_artifact()
        art["selfheal"]["degraded"]["repromoted"] = False
        errs = self._check(art, tmp_path, monkeypatch)
        assert any("re-promoted" in e for e in errs)


class TestRunExitCode:
    """benchmarks/run.py must exit nonzero when *any* module fails."""

    def _run(self, tmp_path, body, only):
        (tmp_path / "fake_bench.py").write_text(textwrap.dedent(body))
        env = dict(os.environ)
        env["REPRO_BENCH_EXTRA"] = "fake_bench"
        env["PYTHONPATH"] = os.pathsep.join(
            [str(tmp_path), str(ROOT), env.get("PYTHONPATH", "")])
        return subprocess.run(
            [sys.executable, "-m", "benchmarks.run", "--smoke",
             "--only", only],
            capture_output=True, text=True, env=env, cwd=ROOT, timeout=120,
        )

    def test_failing_module_exits_nonzero_with_summary(self, tmp_path):
        proc = self._run(tmp_path, """
            def run(report):
                report("fake/ok", 1.0)
                raise AssertionError("deliberate gate failure")
        """, only="fake_bench")
        assert proc.returncode == 1, proc.stderr
        assert "FAILED 1/1" in proc.stderr
        assert "deliberate gate failure" in proc.stderr
        assert "fake_bench/FAILED" in proc.stdout

    def test_passing_module_exits_zero(self, tmp_path):
        proc = self._run(tmp_path, """
            def run(report):
                report("fake/ok", 1.0)
        """, only="fake_bench")
        assert proc.returncode == 0, proc.stderr
        assert "fake/ok" in proc.stdout
