"""The shared wall-time measurement harness (DESIGN.md §12).

Deterministic fake-clock tests of :mod:`repro.xla_utils` — median-of-k
semantics, warmup exclusion, every supported statistic, interleaved
(A, B, A, B, …) sample alternation, the noise estimator — plus the
autotuner's confirmation-pass demotion logic driven through fake timers.
No real timing: the clock is a scripted ``perf_counter`` and
``jax.block_until_ready`` is a recorder, so the tests pin the harness
*contract* without inheriting host noise.
"""
import pytest

from repro import xla_utils
from repro.kernels import autotune, core


class FakeTime:
    """Scripted ``perf_counter``: each timed sample consumes one duration
    (µs) from the queue — first call opens the sample, second closes it."""

    def __init__(self, durations_us):
        self.durations = list(durations_us)
        self._now = 0.0
        self._open = None

    def perf_counter(self):
        if self._open is None:
            self._open = self.durations.pop(0) * 1e-6
            return self._now
        self._now += self._open
        self._open = None
        return self._now


class Recorder:
    """Counts ``jax.block_until_ready`` calls (and passes values through)."""

    def __init__(self):
        self.calls = 0

    def __call__(self, value):
        self.calls += 1
        return value


@pytest.fixture()
def clock(monkeypatch):
    def install(durations_us):
        fake = FakeTime(durations_us)
        monkeypatch.setattr(xla_utils, "time", fake)
        return fake

    return install


@pytest.fixture()
def block(monkeypatch):
    import jax

    rec = Recorder()
    monkeypatch.setattr(jax, "block_until_ready", rec)
    return rec


class TestTimeSamples:
    def test_median_of_k_and_warmup_exclusion(self, clock, block):
        fake = clock([100.0, 300.0, 200.0])
        calls = []
        t = xla_utils.median_time_us(lambda: calls.append(1), warmup=2, reps=3)
        assert t == pytest.approx(200.0)          # median of {100, 300, 200}
        assert len(calls) == 5                    # warmup runs the fn...
        assert block.calls == 5                   # ...and blocks on it
        assert fake.durations == []               # ...but consumes no sample

    def test_min_stat(self, clock, block):
        clock([500.0, 90.0, 400.0])
        t = xla_utils.median_time_us(lambda: None, warmup=0, reps=3, stat="min")
        assert t == pytest.approx(90.0)

    def test_p25_and_mean(self, clock, block):
        clock([400.0, 100.0, 300.0, 200.0])
        samples = xla_utils.time_samples_us(lambda: None, warmup=0, reps=4)
        assert samples == pytest.approx([400.0, 100.0, 300.0, 200.0])
        assert xla_utils._reduce(samples, "p25") == pytest.approx(100.0)
        assert xla_utils._reduce(samples, "mean") == pytest.approx(250.0)

    def test_unknown_stat_raises(self):
        with pytest.raises(ValueError, match="stat"):
            xla_utils._reduce([1.0], "p999")

    def test_args_forwarded(self, clock, block):
        clock([10.0])
        got = []
        xla_utils.time_samples_us(lambda a, b: got.append((a, b)),
                                  "x", 7, warmup=0, reps=1)
        assert got == [("x", 7)]


class TestInterleaved:
    def test_alternation_and_sample_routing(self, clock, block):
        """Samples are taken A, B, A, B, … and land in the right batch."""
        clock([10.0, 20.0, 30.0, 40.0])
        order = []
        sa, sb = xla_utils.interleaved_samples_us(
            lambda: order.append("a"), lambda: order.append("b"),
            warmup=1, reps=2,
        )
        assert order == ["a", "b", "a", "b", "a", "b"]  # warmup pair first
        assert sa == pytest.approx([10.0, 30.0])
        assert sb == pytest.approx([20.0, 40.0])

    def test_stat_reduction(self, clock, block):
        clock([10.0, 20.0, 30.0, 40.0])
        a, b = xla_utils.interleaved_time_us(
            lambda: None, lambda: None, warmup=0, reps=2, stat="min")
        assert (a, b) == (pytest.approx(10.0), pytest.approx(20.0))

    def test_autotune_alias_delegates(self, clock, block):
        clock([100.0, 300.0, 200.0, 400.0, 150.0, 350.0])
        a, b = autotune.interleaved_medians(lambda: None, lambda: None,
                                            warmup=0, reps=3)
        assert a == pytest.approx(150.0)  # median{100, 200, 150}
        assert b == pytest.approx(350.0)  # median{300, 400, 350}


class TestNoiseFrac:
    def test_quiet_host_is_zero(self):
        assert xla_utils.noise_frac([100.0, 100.0, 100.0, 100.0]) == 0.0

    def test_contaminated_batch(self):
        # min 100, p25 of 8 sorted samples -> index 1 -> 150
        samples = [100.0, 150.0, 200.0, 250.0, 300.0, 350.0, 400.0, 450.0]
        assert xla_utils.noise_frac(samples) == pytest.approx(0.5)

    def test_nonpositive_min_guard(self):
        assert xla_utils.noise_frac([0.0, 10.0]) == 0.0


# ---------------------------------------------------------------------------
# confirmation-pass demotion (_search) through fake timers
# ---------------------------------------------------------------------------


@pytest.fixture(autouse=True)
def _clean_registry():
    core.clear_tuned()
    yield
    core.clear_tuned()


def _run_search(monkeypatch, *, confirm):
    """Drive ``autotune._search`` with fake timers: candidate {bm: 1}
    measures faster than the default {bm: 2}; ``confirm`` scripts the
    interleaved head-to-head (winner_us, default_us)."""
    sig = core.matmul_sig(64, 128, 96, 8, 3, "float32")
    monkeypatch.setattr(
        autotune, "median_time_us",
        lambda fn, *a, **k: 50.0 if fn() == {"bm": 1} else 100.0)
    monkeypatch.setattr(
        autotune, "interleaved_medians", lambda *a, **k: confirm)
    return autotune._search(
        core.KIND_MATMUL_TC, sig, [{"bm": 1}, {"bm": 2}],
        cost_fn=lambda t: t["bm"], build=lambda t: (lambda: t),
        default_tiles={"bm": 2}, top_k=2, reps=3, warmup=1,
        cache=None, save=False,
    )


class TestSearchDemotion:
    def test_replicating_winner_is_kept(self, monkeypatch):
        res = _run_search(monkeypatch, confirm=(50.0, 100.0))
        assert res.tiles == {"bm": 1}
        assert res.measured_us == 50.0 and res.default_us == 100.0

    def test_non_replicating_winner_demoted_to_default(self, monkeypatch):
        """An apparent win that does not replicate beyond CONFIRM_MARGIN in
        the interleaved pass must never be persisted."""
        res = _run_search(monkeypatch, confirm=(98.0, 100.0))  # a tie
        assert res.tiles == {"bm": 2}
        assert res.measured_us == res.default_us == 100.0

    def test_margin_boundary(self, monkeypatch):
        # exactly at the margin: 95.2 * 1.05 = 99.96 <= 100 -> kept
        res = _run_search(monkeypatch, confirm=(95.0, 100.0))
        assert res.tiles == {"bm": 1}
