"""Activation-sparsity subsystem (DESIGN.md §7): measurement vs hand-built
oracles, structural pruning round-trips through the tc kernel, and the
energy model's monotone response to measured sparsity.
"""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    DBBFormat,
    PARETO_DESIGN,
    act_dbb_decode,
    act_dbb_encode,
    act_dbb_prune,
    act_fmt,
    block_nnz_histogram,
    combine,
    dbb_conv_costs,
    dbb_encode,
    dbb_gemm_costs,
    dbb_matmul_gather_ref,
    measure_activation,
    model_workload,
)
from repro.core.act_sparsity import ActStats


# ---------------------------------------------------------------------------
# measure
# ---------------------------------------------------------------------------


class TestMeasurement:
    def test_zero_fraction_matches_oracle(self):
        """Plant an exact number of zeros and compare against the count."""
        rng = np.random.default_rng(0)
        x = rng.normal(size=(13, 7, 24)).astype(np.float32)
        flat = x.reshape(-1)
        kill = rng.choice(flat.size, size=500, replace=False)
        flat[kill] = 0.0
        x = flat.reshape(13, 7, 24)
        st = measure_activation(jnp.asarray(x), name="oracle")
        assert st.zero_frac == pytest.approx(500 / x.size, abs=1e-7)
        assert st.numel == x.size and st.shape == (13, 7, 24)

    def test_threshold_variant(self):
        x = jnp.asarray([0.0, 0.05, -0.2, 1.0])
        st = measure_activation(x, threshold=0.1)
        assert st.zero_frac == pytest.approx(0.25)
        assert st.near_zero_frac == pytest.approx(0.5)  # 0.0 and 0.05
        # with no threshold the two coincide
        st0 = measure_activation(x)
        assert st0.near_zero_frac == st0.zero_frac

    def test_block_histogram_oracle(self):
        """Each bz-block's occupancy lands in the right histogram bin."""
        x = np.zeros((2, 16), np.float32)
        x[0, :3] = 1.0   # block 0: 3 nnz
        x[0, 8:8 + 7] = 1.0  # block 1: 7 nnz
        x[1, 0] = 1.0    # block 2: 1 nnz; block 3: 0 nnz
        hist = np.asarray(block_nnz_histogram(jnp.asarray(x), bz=8))
        want = np.zeros(9, np.int64)
        want[[3, 7, 1, 0]] += 1
        np.testing.assert_array_equal(hist, want)

    def test_unblockable_feature_dim_is_nan(self):
        st = measure_activation(jnp.ones((4, 3)))  # K=3 not bz-blockable
        assert math.isnan(st.block_nnz_mean)

    def test_combine_is_mac_weighted(self):
        a = ActStats(name="a", numel=10, zero_frac=0.0, macs=100)
        b = ActStats(name="b", numel=10, zero_frac=1.0, macs=300)
        assert combine([a, b]).zero_frac == pytest.approx(0.75)
        # numel fallback when no MAC weights are given
        a2 = ActStats(name="a", numel=10, zero_frac=0.0)
        b2 = ActStats(name="b", numel=30, zero_frac=1.0)
        assert combine([a2, b2]).zero_frac == pytest.approx(0.75)


# ---------------------------------------------------------------------------
# gate (structural pruning) — round-trip through the tc kernel
# ---------------------------------------------------------------------------


class TestStructuralPruning:
    def test_prune_satisfies_block_bound(self):
        x = jax.random.normal(jax.random.PRNGKey(0), (32, 64))
        fmt = DBBFormat(8, 3)
        xp = act_dbb_prune(x, fmt)
        counts = np.asarray((np.asarray(xp).reshape(32, 8, 8) != 0).sum(-1))
        assert counts.max() <= 3
        # shared pattern: the same K positions survive on every row
        mask = np.asarray(xp != 0)
        nz_cols = mask.any(axis=0)
        assert (mask == nz_cols[None, :] & np.asarray(x != 0)).all()

    def test_encode_decode_roundtrip_bit_exact(self):
        x = jax.random.normal(jax.random.PRNGKey(1), (16, 64))
        fmt = DBBFormat(8, 3)
        xp = act_dbb_prune(x, fmt)
        assert bool((act_dbb_decode(act_dbb_encode(x, fmt)) == xp).all())

    def test_pruned_activations_through_tc_kernel_bit_exact(self):
        """A structurally pruned activation runs the tc kernel's
        compressed-K contraction unchanged: kernel == jnp reference,
        bit for bit (single K-step, full output tile)."""
        from repro.kernels import ops

        key = jax.random.PRNGKey(2)
        a = jax.nn.relu(jax.random.normal(key, (16, 64)))
        fmt = DBBFormat(8, 3, "matrix")
        ap = act_dbb_prune(a, fmt)
        w = jax.random.normal(jax.random.PRNGKey(3), (64, 32))
        dw = dbb_encode(w, fmt, prune=True)
        got = ops.vdbb_matmul(ap, dw, bm=16, bn=32, kb=8, interpret=True)
        want = dbb_matmul_gather_ref(ap, dw)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_ops_sparse_matmul_gates_activations(self):
        from repro.kernels import ops

        a = jax.nn.relu(jax.random.normal(jax.random.PRNGKey(4), (16, 64)))
        fmt = DBBFormat(8, 3, "matrix")
        dw = dbb_encode(jax.random.normal(jax.random.PRNGKey(5), (64, 32)), fmt, prune=True)
        afmt = DBBFormat(8, 4)
        got = ops.sparse_matmul(a, dw, act_fmt=afmt, bm=16, bn=32, kb=8, interpret=True)
        want = ops.vdbb_matmul(act_dbb_prune(a, afmt), dw, bm=16, bn=32, kb=8, interpret=True)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
        # no gating -> plain vdbb_matmul
        ungated = ops.sparse_matmul(a, dw, bm=16, bn=32, kb=8, interpret=True)
        np.testing.assert_array_equal(
            np.asarray(ungated),
            np.asarray(ops.vdbb_matmul(a, dw, bm=16, bn=32, kb=8, interpret=True)),
        )

    def test_act_fmt_covers_measured_density(self):
        st = ActStats(zero_frac=0.6)
        fmt = act_fmt(st, bz=8)
        assert fmt.nnz == 4 and fmt.group == "matrix"  # ceil(0.4 * 8) = 4
        assert act_fmt(ActStats(zero_frac=0.0)).nnz == 8
        assert act_fmt(ActStats(zero_frac=1.0)).nnz == 1


# ---------------------------------------------------------------------------
# account — cost layer and energy model take ActStats
# ---------------------------------------------------------------------------


class TestAccounting:
    def test_costs_record_measured_sparsity(self):
        fmt = DBBFormat(8, 3)
        st = ActStats(zero_frac=0.7)
        c = dbb_gemm_costs(64, 128, 32, fmt, act=st)
        assert c["act_measured"] and c["act_sparsity"] == pytest.approx(0.7)
        assert c["act_nonzero_bytes"] == int(c["act_bytes"] * 0.3)
        c0 = dbb_gemm_costs(64, 128, 32, fmt)
        assert not c0["act_measured"] and c0["act_sparsity"] == 0.5
        cc = dbb_conv_costs(1, 16, 16, 64, 32, 3, 3, fmt, act=st)
        assert cc["act_measured"]
        assert cc["act_nonzero_bytes"] == int(cc["act_bytes_raw"] * 0.3)

    def test_power_monotone_in_act_sparsity(self):
        """More measured activation sparsity -> more clock gating -> less
        power, monotonically; TOPS/W monotone the other way."""
        fmt = DBBFormat(8, 3)
        sweep = [ActStats(zero_frac=s) for s in (0.0, 0.25, 0.5, 0.75, 1.0)]
        powers = [PARETO_DESIGN.power_mw(fmt, st) for st in sweep]
        assert all(a > b for a, b in zip(powers, powers[1:])), powers
        effs = [PARETO_DESIGN.tops_per_w(fmt, st) for st in sweep]
        assert all(a < b for a, b in zip(effs, effs[1:])), effs
        # ActStats and its scalar sparsity are interchangeable
        assert PARETO_DESIGN.power_mw(fmt, sweep[2]) == pytest.approx(
            PARETO_DESIGN.power_mw(fmt, 0.5)
        )

    def test_model_workload_composes_per_layer(self):
        fmt = DBBFormat(8, 3)
        c = dbb_conv_costs(1, 16, 16, 64, 64, 3, 3, fmt)
        sparse, dense = ActStats(zero_frac=0.9), ActStats(zero_frac=0.1)
        wl_sparse = model_workload(PARETO_DESIGN, [(c, fmt, sparse)] * 2)
        wl_mixed = model_workload(PARETO_DESIGN, [(c, fmt, sparse), (c, fmt, dense)])
        assert wl_sparse["tops_per_w"] > wl_mixed["tops_per_w"]
        assert wl_mixed["mean_act_sparsity"] == pytest.approx(0.5)
        # act=None falls back to what the costs dict recorded
        c_meas = dbb_conv_costs(1, 16, 16, 64, 64, 3, 3, fmt, act=sparse)
        wl = model_workload(PARETO_DESIGN, [(c_meas, fmt, None)])
        assert wl["mean_act_sparsity"] == pytest.approx(0.9)


# ---------------------------------------------------------------------------
# lifecycle — collection wired into both model families
# ---------------------------------------------------------------------------


class TestCollection:
    def test_cnn_collect_matches_direct_measurement(self):
        from repro.configs import smoke_cnn_config
        from repro.models.cnn import SparseCNN

        cfg = smoke_cnn_config("sparse-cnn-tiny")
        model = SparseCNN(cfg)
        key = jax.random.PRNGKey(0)
        params = model.compress(model.init(key))
        x = jax.random.normal(key, (2, cfg.image_size, cfg.image_size, 3))
        logits, stats = model.apply(params, x, collect_act_stats=True)
        # collection must not perturb the forward
        assert bool((model(params, x) == logits).all())
        assert len(stats) == len(model.layers())
        # stem input is a dense random image; interior layers are post-ReLU
        assert stats[0].zero_frac == pytest.approx(float(jnp.mean(x == 0)))
        assert stats[1].zero_frac > 0.3, "post-ReLU activations should be zero-heavy"
        assert all(s.macs > 0 for s in stats)
        # per-layer stats drive per-layer costs
        layers = model.layer_costs(2, stats=stats)
        assert all(c["act_measured"] for _, c, _ in layers)
        assert layers[1][1]["act_sparsity"] == pytest.approx(stats[1].zero_frac)

    def test_lm_collect_smoke(self):
        from repro.configs import make_batch, smoke_config
        from repro.models import LM

        cfg = smoke_config("starcoder2-7b")
        m = LM(cfg)
        params = m.init(jax.random.PRNGKey(0))
        batch = make_batch(cfg, batch=2, seq=16)
        logits, stats = m.forward(params, batch, collect_act_stats=True)
        assert len(stats) > 0 and all(isinstance(s, ActStats) for s in stats)
        assert sum(s.macs for s in stats) > 0
        # collection bypasses scan/remat; against the same unrolled path it
        # must not perturb the forward at all
        import dataclasses

        m_unrolled = LM(dataclasses.replace(cfg, scan_layers=False))
        plain = m_unrolled.forward(params, batch)
        assert bool((plain == logits).all())
        combined = combine(list(stats))
        assert 0.0 <= combined.zero_frac <= 1.0

    def test_collector_skips_traced_values(self):
        from repro.core.act_sparsity import collect_activations, record_activation

        with collect_activations() as col:
            jax.jit(lambda x: (record_activation(x), x * 2)[1])(jnp.ones(4))
            record_activation(jnp.zeros(4), name="eager")
        assert [s.name for s in col.stats] == ["eager"]
        assert col.stats[0].zero_frac == 1.0
