"""Tile autotuner, persistent cache, pad-to-tile, and frozen serving plans
(DESIGN.md §10).

Covers the §10 contracts: deterministic cache keys, cache round-trip
(write → reload → no re-search), version-mismatch invalidation, the
ops-layer pad-to-tile path (bit-exact vs the references for fp and the
int8 epilogue chain), registry-driven default tiles, and plan semantics
(bit-identical serving, immutability, staleness detection).
"""
import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import quant
from repro.core.vdbb import DBBFormat, dbb_decode, dbb_encode
from repro.kernels import autotune, core, ops, ref

FMT = DBBFormat(8, 3, "matrix")


@pytest.fixture(autouse=True)
def _clean_registry():
    """Every test sees (and leaves) an empty tuned-tile registry."""
    core.clear_tuned()
    yield
    core.clear_tuned()


# ---------------------------------------------------------------------------
# cache keys + persistence
# ---------------------------------------------------------------------------


class TestCacheKeys:
    def test_deterministic(self):
        sig = core.matmul_sig(64, 128, 96, 8, 3, jnp.float32)
        a = autotune.cache_key(core.KIND_MATMUL_TC, sig, backend="cpu")
        b = autotune.cache_key(core.KIND_MATMUL_TC, sig, backend="cpu")
        assert a == b

    def test_distinguishes_everything(self):
        base = autotune.cache_key(
            core.KIND_MATMUL_TC, core.matmul_sig(64, 128, 96, 8, 3, jnp.float32),
            backend="cpu",
        )
        variants = [
            autotune.cache_key(  # kernel kind
                core.KIND_MATMUL_BW,
                core.matmul_sig(64, 128, 96, 8, 3, jnp.float32), backend="cpu"),
            autotune.cache_key(  # shape
                core.KIND_MATMUL_TC,
                core.matmul_sig(65, 128, 96, 8, 3, jnp.float32), backend="cpu"),
            autotune.cache_key(  # nnz
                core.KIND_MATMUL_TC,
                core.matmul_sig(64, 128, 96, 8, 4, jnp.float32), backend="cpu"),
            autotune.cache_key(  # dtype
                core.KIND_MATMUL_TC,
                core.matmul_sig(64, 128, 96, 8, 3, jnp.int8), backend="cpu"),
            autotune.cache_key(  # backend
                core.KIND_MATMUL_TC,
                core.matmul_sig(64, 128, 96, 8, 3, jnp.float32), backend="tpu"),
        ]
        assert len({base, *variants}) == len(variants) + 1

    def test_conv_sig_includes_geometry(self):
        a = core.conv_sig(2, 16, 16, 32, 64, 3, 3, 1, 1, 8, 3, jnp.float32)
        b = core.conv_sig(2, 8, 8, 32, 64, 3, 3, 2, 2, 8, 3, jnp.float32)
        assert a != b


class TestTuneCache:
    def test_round_trip_no_research(self, tmp_path, monkeypatch):
        path = tmp_path / "cache.json"
        res = autotune.tune_matmul(
            64, 128, 96, FMT, top_k=2, reps=1, cache=autotune.TuneCache(path)
        )
        assert res.source == "search" and path.exists()

        # a reloaded cache must answer without searching at all
        def boom(*a, **k):
            raise AssertionError("search ran despite a cache hit")

        monkeypatch.setattr(autotune, "_search", boom)
        replay = autotune.tune_matmul(
            64, 128, 96, FMT, top_k=2, reps=1, cache=autotune.TuneCache(path)
        )
        assert replay.source == "cache"
        assert replay.tiles == res.tiles
        assert replay.measured_us == res.measured_us

    def test_version_mismatch_invalidates(self, tmp_path):
        path = tmp_path / "cache.json"
        cache = autotune.TuneCache(path)
        cache.put("k", {"tiles": {"bm": 8}})
        cache.save()
        data = json.loads(path.read_text())
        data["version"] = autotune.CACHE_VERSION + 1
        path.write_text(json.dumps(data))
        assert autotune.TuneCache(path).get("k") is None

    def test_corrupt_file_is_empty_cache(self, tmp_path):
        path = tmp_path / "cache.json"
        path.write_text("{not json")
        assert autotune.TuneCache(path).entries == {}

    def test_search_installs_registry(self, tmp_path):
        res = autotune.tune_matmul(
            64, 128, 96, FMT, top_k=2, reps=1,
            cache=autotune.TuneCache(tmp_path / "c.json"),
        )
        sig = core.matmul_sig(64, 128, 96, 8, 3, jnp.float32)
        assert core.lookup_tiles(core.KIND_MATMUL_TC, sig) == res.tiles

    def test_default_always_measured(self, tmp_path):
        """The pick_tile baseline is in every search's candidate set, so
        measured-best ≤ measured-default and modeled-best ≤ modeled-default
        hold by construction."""
        res = autotune.tune_matmul(
            64, 128, 96, FMT, top_k=1, reps=1,
            cache=autotune.TuneCache(tmp_path / "c.json"),
        )
        assert res.measured_us <= res.default_us
        assert res.modeled_best_us <= res.modeled_default_us


# ---------------------------------------------------------------------------
# pad-to-tile (the pick_tile-pathology fix)
# ---------------------------------------------------------------------------


class TestPadToTile:
    def test_pick_tile_padded(self):
        assert core.pick_tile_padded(200, 128) == (100, 200)  # good divisor
        assert core.pick_tile_padded(96, 128) == (96, 96)     # whole dim
        # 2·prime beyond 2x the default: pad instead of one huge tile
        assert core.pick_tile_padded(514, 128) == (128, 640)

    def test_pad_tile_explicit(self):
        assert core.pad_tile(130, 64, 128) == (64, 192)  # non-divisor pads
        assert core.pad_tile(130, 130, 128) == (130, 130)
        assert core.pad_tile(100, 128, 128) == (100, 100)  # clamped, no pad
        assert core.pad_tile(200, None, 128) == (100, 200)  # None → pick path

    @pytest.mark.parametrize("m,k,n", [(127, 64, 96), (130, 128, 150), (64, 64, 257)])
    @pytest.mark.parametrize("group", ["matrix", None, 4])
    def test_fp_bit_exact_vs_unpadded(self, m, k, n, group):
        """Padded launches return exactly what the reference computes —
        zero rows/columns contribute nothing."""
        if group == 4 and n % 4:
            n -= n % 4
        fmt = DBBFormat(8, 3, group)
        k1, k2 = jax.random.split(jax.random.PRNGKey(0))
        a = jax.random.normal(k1, (m, k))
        dw = dbb_encode(jax.random.normal(k2, (k, n)), fmt, prune=True)
        got = ops.vdbb_matmul(a, dw, bm=64, bn=64, kb=2, interpret=True)
        assert got.shape == (m, n)
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(ref.dbb_matmul_ref(a, dw)),
            rtol=1e-4, atol=1e-4,
        )

    def test_quant_epilogue_padded_bit_exact(self):
        """int8 datapath + full fused epilogue through the pad path matches
        the integer oracle bit-for-bit."""
        m, k, n = 100, 64, 72
        k1, k2, k3 = jax.random.split(jax.random.PRNGKey(1), 3)
        a = jax.random.normal(k1, (m, k))
        qw = quant.quantize_dbb(
            dbb_encode(jax.random.normal(k2, (k, n)), FMT, prune=True)
        )
        b = jax.random.normal(k3, (n,))
        s_a = quant.dynamic_act_scale(a)
        out_s = jnp.float32(0.05)
        got = ops.quant_matmul(a, qw, s_a, bias=b, relu=True, out_scale=out_s,
                               bm=64, bn=64, kb=4, interpret=True)
        acc = quant.int_matmul_ref(quant.quantize(a, s_a), dbb_decode(qw.as_dbb()))
        want = ref.quant_epilogue_ref(acc, s_a * qw.scales, bias=b, relu=True,
                                      out_scale=out_s)
        assert got.dtype == jnp.int8
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_registry_defaults_flow_through_ops(self):
        """An installed tuned config changes the default-tile launch and
        stays bit-close to the reference."""
        m, k, n = 64, 128, 96
        k1, k2 = jax.random.split(jax.random.PRNGKey(2))
        a = jax.random.normal(k1, (m, k))
        dw = dbb_encode(jax.random.normal(k2, (k, n)), FMT, prune=True)
        want = ref.dbb_matmul_ref(a, dw)
        sig = core.matmul_sig(m, k, n, 8, 3, jnp.float32)
        autotune.install(core.KIND_MATMUL_TC, sig, {"bm": 32, "bn": 48, "kb": 4})
        got = ops.vdbb_matmul(a, dw, interpret=True)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-4)
        # non-dividing tuned tiles take the pad path instead of raising
        autotune.install(core.KIND_MATMUL_TC, sig, {"bm": 60, "bn": 50, "kb": 3})
        got = ops.vdbb_matmul(a, dw, interpret=True)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-4)

    def test_registry_change_invalidates_live_traces(self, monkeypatch):
        """Default-tile traces capture the registry lookup at trace time;
        ``set_tuned``/``clear_tuned`` must force a retrace so the new
        config is actually consulted — an unchanged re-install must not."""
        calls = []
        orig = core.lookup_tiles
        monkeypatch.setattr(core, "lookup_tiles",
                            lambda *a: calls.append(a) or orig(*a))
        m, k, n = 32, 64, 48
        k1, k2 = jax.random.split(jax.random.PRNGKey(3))
        a = jax.random.normal(k1, (m, k))
        dw = dbb_encode(jax.random.normal(k2, (k, n)), FMT, prune=True)
        ops.vdbb_matmul(a, dw, interpret=True)   # traces, consults registry
        n_trace = len(calls)
        assert n_trace > 0
        ops.vdbb_matmul(a, dw, interpret=True)   # cached: no new lookup
        assert len(calls) == n_trace
        sig = core.matmul_sig(m, k, n, 8, 3, jnp.float32)
        core.set_tuned(core.KIND_MATMUL_TC, sig, {"bm": 16, "bn": 16, "kb": 2})
        ops.vdbb_matmul(a, dw, interpret=True)   # invalidated: re-consults
        assert len(calls) > n_trace
        n_trace = len(calls)
        # identical re-install is a no-op: live traces stay valid
        core.set_tuned(core.KIND_MATMUL_TC, sig, {"bm": 16, "bn": 16, "kb": 2})
        ops.vdbb_matmul(a, dw, interpret=True)
        assert len(calls) == n_trace


# ---------------------------------------------------------------------------
# conv tuning
# ---------------------------------------------------------------------------


class TestTuneConv:
    def test_search_and_replay(self, tmp_path):
        cache = autotune.TuneCache(tmp_path / "c.json")
        res = autotune.tune_conv(1, 8, 8, 16, 32, 3, 3, FMT, top_k=1, reps=1,
                                 cache=cache)
        assert res.source == "search"
        assert res.measured_us <= res.default_us
        replay = autotune.tune_conv(1, 8, 8, 16, 32, 3, 3, FMT, top_k=1, reps=1,
                                    cache=autotune.TuneCache(cache.path))
        assert replay.source == "cache" and replay.tiles == res.tiles

    def test_tuned_conv_tiles_guard_divisibility(self):
        sig = core.conv_sig(1, 8, 8, 16, 32, 3, 3, 1, 1, 8, 3, jnp.float32)
        core.set_tuned(core.KIND_CONV_TC, sig, {"bf": 5, "tile_h": 4, "tile_w": 3})
        bf, th, tw = core.tuned_conv_tiles(core.KIND_CONV_TC, sig, 8, 8, 32)
        assert (bf, th, tw) == (None, 4, None)  # only dividing components used


# ---------------------------------------------------------------------------
# frozen serving plans
# ---------------------------------------------------------------------------


def _quantized_smoke_cnn(kernel_mode="pallas"):
    from repro.configs import smoke_cnn_config
    from repro.models.cnn import SparseCNN

    cfg = dataclasses.replace(
        smoke_cnn_config("sparse-cnn-tiny", sparsity=0.625),
        kernel_mode=kernel_mode,
    )
    model = SparseCNN(cfg)
    params = model.compress(model.init(jax.random.PRNGKey(0)))
    xb = jax.random.normal(
        jax.random.PRNGKey(1), (4, cfg.image_size, cfg.image_size, cfg.in_channels)
    )
    _, stats = model.apply(params, xb, collect_act_stats=True)
    return model, model.quantize(params, stats), xb


class TestModelPlan:
    def test_bit_identical_to_unplanned(self, tmp_path):
        model, qparams, xb = _quantized_smoke_cnn()
        want = model.apply(qparams, xb)
        plan = model.plan(qparams, batch=4, tune="off")
        np.testing.assert_array_equal(np.asarray(plan.serve(xb)), np.asarray(want))
        np.testing.assert_array_equal(  # checked apply(plan=) form
            np.asarray(model.apply(qparams, xb, plan=plan)), np.asarray(want)
        )

    def test_bit_identical_with_searched_tiles(self, tmp_path):
        model, qparams, xb = _quantized_smoke_cnn()
        want = model.apply(qparams, xb)
        plan = model.plan(qparams, batch=4, tune="search",
                          cache=tmp_path / "c.json", top_k=1, reps=1)
        np.testing.assert_array_equal(np.asarray(plan.serve(xb)), np.asarray(want))

    def test_plan_tiles_frozen_into_closures(self, tmp_path, monkeypatch):
        """A plan's tile configs are pinned at build time — its first trace
        must not consult the ambient registry (which may have been cleared
        or re-tuned by another model since the plan was built)."""
        model, qparams, xb = _quantized_smoke_cnn()
        want = model.apply(qparams, xb)
        plan = model.plan(qparams, batch=4, tune="search",
                          cache=tmp_path / "c.json", top_k=1, reps=1)
        assert plan.tiles  # searched configs recorded
        core.clear_tuned()  # ambient state changes before the first trace

        def no_lookup(*a):
            raise AssertionError(f"plan trace consulted the registry: {a}")

        monkeypatch.setattr(core, "lookup_tiles", no_lookup)
        np.testing.assert_array_equal(np.asarray(plan.serve(xb)), np.asarray(want))

    def test_ref_mode_plan_matches(self):
        model, qparams, xb = _quantized_smoke_cnn(kernel_mode="ref")
        want = model.apply(qparams, xb)
        plan = model.plan(qparams, batch=4, tune="off")
        np.testing.assert_array_equal(np.asarray(plan.serve(xb)), np.asarray(want))

    def test_fp_model_plan_matches(self):
        """Plans also stage the non-quantized (fp compressed) chain."""
        from repro.configs import smoke_cnn_config
        from repro.models.cnn import SparseCNN

        cfg = smoke_cnn_config("sparse-cnn-tiny", sparsity=0.625)
        model = SparseCNN(cfg)
        params = model.compress(model.init(jax.random.PRNGKey(0)))
        xb = jax.random.normal(jax.random.PRNGKey(1), (4, 16, 16, 3))
        want = model.apply(params, xb)
        plan = model.plan(params, batch=4, tune="off")
        np.testing.assert_array_equal(np.asarray(plan.serve(xb)), np.asarray(want))

    def test_stale_plan_after_requantize_raises(self):
        from repro.models.plan import StalePlanError

        model, qparams, xb = _quantized_smoke_cnn()
        plan = model.plan(qparams, batch=4, tune="off")
        # re-quantize with different calibration: the plan's staged weight
        # buffers no longer match the params — serving must refuse
        params = model.compress(model.init(jax.random.PRNGKey(0)))
        _, stats2 = model.apply(params, xb * 2.0, collect_act_stats=True)
        q2 = model.quantize(params, stats2)
        with pytest.raises(StalePlanError):
            model.apply(q2, xb, plan=plan)

    def test_plan_is_immutable(self):
        model, qparams, xb = _quantized_smoke_cnn()
        plan = model.plan(qparams, batch=4, tune="off")
        with pytest.raises(dataclasses.FrozenInstanceError):
            plan.fingerprint = "tampered"
        with pytest.raises(dataclasses.FrozenInstanceError):
            plan.layers[0].tiles = ()

    def test_plan_rejects_stats_collection(self):
        model, qparams, xb = _quantized_smoke_cnn()
        plan = model.plan(qparams, batch=4, tune="off")
        with pytest.raises(ValueError, match="frozen hot path"):
            model.apply(qparams, xb, plan=plan, collect_act_stats=True)

    def test_linear_make_plan_honors_out_scale_fallback(self):
        """The fp/unfused fallback branch requantizes at out_scale, like
        the conv twin (the staged chain may feed an int8 consumer)."""
        from repro.core.quant import quantize as quantize_array
        from repro.core.sparse_linear import DBBLinear
        from repro.core.vdbb import DBBFormat

        lin = DBBLinear(32, 16, fmt=DBBFormat(8, 3, "matrix"))
        params = lin.init(jax.random.PRNGKey(0))
        x = jax.random.normal(jax.random.PRNGKey(1), (8, 32))
        out_s = jnp.float32(0.07)
        run, tiles = lin.make_plan(params, batch=8, relu=True, out_scale=out_s,
                                   tune="off")
        got = run(x)
        want = quantize_array(jax.nn.relu(lin(params, x)), out_s)
        assert got.dtype == jnp.int8
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_fingerprint_tracks_content(self):
        from repro.models.plan import params_fingerprint

        model, qparams, xb = _quantized_smoke_cnn()
        f1 = params_fingerprint(qparams)
        assert f1 == params_fingerprint(qparams)  # deterministic
        bumped = dict(qparams)
        bumped["l0"] = dict(qparams["l0"], b=qparams["l0"]["b"] + 1.0)
        assert params_fingerprint(bumped) != f1
