"""Tile autotuner + frozen serving plans: measured win vs pick_tile
defaults (DESIGN.md §10).

Two workloads, written machine-readable to ``BENCH_autotune.json``:

1. **non-power-of-two GEMM layer set** — shapes whose dimensions have no
   divisor near the default tiles, so the static ``pick_tile`` heuristic
   is furthest from optimal. The artifact's ``tuned_us``/``default_us``
   are the search's interleaved head-to-head **confirmation pass**
   numbers — real measurements, with ``tuned ≤ default`` enforced as the
   autotuner's contract (a winner that does not replicate its win is
   demoted back to the default, so "no win found" records speedup 1.0
   rather than a regression). An additional independent re-measurement
   is recorded and sanity-bounded loosely (a shared throttled CPU swings
   medians by tens of percent between batches; the loose bound still
   catches a tuner installing catastrophically bad configs).

2. **smoke SparseCNN serving** — the frozen-plan path
   (``SparseCNN.plan()`` → ``plan.serve``; tuned tiles + staged weight
   buffers + one jit dispatch) vs the unplanned per-call path
   (``model.apply(qparams, x)`` at pick_tile defaults: per-call layer
   rebuild, tile re-resolution, per-op dispatch with the full param
   tree), measured interleaved. Asserted: plan logits are
   **bit-identical** to the unplanned §9 int8-resident chain, and the
   plan — which does strictly less per-call work — is not slower beyond
   the noise margin.
"""
import json
import pathlib
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.vdbb import DBBFormat, dbb_encode
from repro.kernels import autotune, core, ops
from repro.kernels.autotune import interleaved_medians

OUT_PATH = pathlib.Path(__file__).resolve().parents[1] / "BENCH_autotune.json"
# Margins shared with benchmarks/check_regression.py via the committed
# baselines file, so the bench and the CI gate can never silently disagree.
_BASELINES = json.loads(
    (pathlib.Path(__file__).resolve().parent / "bench_baselines.json").read_text()
)
NOISE_MARGIN = _BASELINES["autotune_noise_margin"]   # plan vs unplanned slack
SANITY_MARGIN = _BASELINES["autotune_sanity_margin"]  # independent re-measure bound

# Non-power-of-two GEMM layer set: no divisor near the 128/256 defaults.
ODD_GEMMS = [
    (200, 192, 320),
    (130, 512, 144),
    (96, 256, 224),
]


def run(report):
    with tempfile.TemporaryDirectory(prefix="repro_autotune_") as tmp:
        _run(report, autotune.TuneCache(pathlib.Path(tmp) / "cache.json"))


def _run(report, cache):
    core.clear_tuned()  # measure the true pick_tile baseline
    results = {
        "backend": jax.default_backend(),
        "cache_version": autotune.CACHE_VERSION,
        "odd_gemms": [],
        "smoke_cnn": {},
    }

    # --- 1. non-power-of-two GEMM layer set ------------------------------
    fmt = DBBFormat(8, 3, "matrix")
    for m, k, n in ODD_GEMMS:
        res = autotune.tune_matmul(m, k, n, fmt, top_k=3, reps=3, cache=cache)
        # the contract the confirmation pass enforces (measured, not assumed)
        assert res.measured_us <= res.default_us
        assert res.modeled_best_us <= res.modeled_default_us
        # independent re-measurement of winner vs default, interleaved —
        # loosely bounded (this box swings medians by tens of percent)
        k1, k2 = jax.random.split(jax.random.PRNGKey(7))
        a = jax.random.normal(k1, (m, k))
        dw = dbb_encode(jax.random.normal(k2, (k, n)), fmt, prune=True)
        rm_tuned, rm_default = interleaved_medians(
            lambda: ops.vdbb_matmul(a, dw, **res.tiles),
            lambda: ops.vdbb_matmul(a, dw, **res.default_tiles),
            warmup=2, reps=9,
        )
        assert rm_tuned <= rm_default * SANITY_MARGIN, \
            (res.tiles, rm_tuned, rm_default)
        results["odd_gemms"].append(dict(
            m=m, k=k, n=n, tiles=res.tiles,
            tuned_us=round(res.measured_us, 1),
            default_tiles=res.default_tiles,
            default_us=round(res.default_us, 1),
            speedup=round(res.speedup, 3),
            remeasured_tuned_us=round(rm_tuned, 1),
            remeasured_default_us=round(rm_default, 1),
            n_candidates=res.n_candidates,
        ))
        report(f"autotune/gemm_{m}x{k}x{n}", res.measured_us,
               f"default {res.default_us:.0f}us ({res.speedup:.2f}x, "
               f"confirmed head-to-head; re-measured {rm_tuned:.0f} vs "
               f"{rm_default:.0f}us), tiles {res.tiles} vs {res.default_tiles}")

    # cache replay: the same query must be search-free and identical
    replay = autotune.tune_matmul(*ODD_GEMMS[0], fmt, top_k=3, reps=3,
                                  cache=autotune.TuneCache(cache.path))
    assert replay.source == "cache" and replay.tiles == results["odd_gemms"][0]["tiles"]

    # --- 2. smoke SparseCNN: frozen plan vs unplanned serving ------------
    import dataclasses

    from repro.configs import smoke_cnn_config
    from repro.models.cnn import SparseCNN

    core.clear_tuned()
    cfg = dataclasses.replace(
        smoke_cnn_config("sparse-cnn-tiny", sparsity=0.625), kernel_mode="pallas"
    )
    model = SparseCNN(cfg)
    batch = 4
    params = model.compress(model.init(jax.random.PRNGKey(0)))
    xb = jax.random.normal(
        jax.random.PRNGKey(1), (batch, cfg.image_size, cfg.image_size, cfg.in_channels)
    )
    _, stats = model.apply(params, xb, collect_act_stats=True)
    qparams = model.quantize(params, stats)

    ref_logits = model.apply(qparams, xb)  # traced at pick_tile defaults
    plan = model.plan(qparams, batch=batch, tune="search", cache=cache,
                      top_k=2, reps=3)
    got = plan.serve(xb)  # traces the plan while its tuned tiles are installed
    np.testing.assert_array_equal(  # acceptance: bit-identical serving
        np.asarray(got), np.asarray(ref_logits)
    )
    # the plan carries its tiles frozen in its closures; resetting the
    # registry (which drops the ops jit caches itself) only makes the
    # unplanned path really re-resolve pick_tile defaults
    core.clear_tuned()
    t_plan, t_default = interleaved_medians(
        lambda: plan.serve(xb), lambda: model.apply(qparams, xb),
        warmup=2, reps=9,
    )
    assert t_plan <= t_default * NOISE_MARGIN, (t_plan, t_default)
    results["smoke_cnn"] = dict(
        batch=batch, plan_us=round(t_plan, 1), default_us=round(t_default, 1),
        speedup=round(t_default / max(t_plan, 1e-9), 3),
        tiles=plan.tiles, bit_identical=True,
    )
    report("autotune/smoke_cnn_plan", t_plan,
           f"unplanned apply {t_default:.0f}us "
           f"({t_default / max(t_plan, 1e-9):.2f}x, re-measured interleaved), "
           "bit-identical logits")

    OUT_PATH.write_text(json.dumps(results, indent=2))
    report("autotune/json", 0.0, f"wrote {OUT_PATH.name}")


if __name__ == "__main__":
    run(lambda name, us, derived="": print(f"{name},{us:.1f},{derived}"))
