"""Fused epilogue vs unfused per-layer traffic + wall time (DESIGN.md §9/§12).

Three measurements, written machine-readable to ``BENCH_fused.json`` so
the perf trajectory has data points across PRs:

1. **modeled HBM bytes per conv layer** — `dbb_conv_costs` with and
   without `epilogue_fused` over every compressed layer of the smoke
   SparseCNN (acceptance: the fused datapath models ≥25% less traffic
   per layer: int8 flush instead of fp32, zero standalone
   dequant→bias/ReLU→requant passes);
2. **compiled-HLO breakdown** — `jax.jit(...).compile()` cost analysis +
   per-opcode instruction counts of one quantized conv layer, fused
   epilogue vs the PR-3 kernel + standalone XLA epilogue ops (the
   launch-level attribution: the unfused program carries extra
   fusion/elementwise passes the fused one folds into the flush);
3. **wall time** — the same two programs end to end, plus the
   int8-resident SparseCNN forward vs the per-layer-dequant path, both
   in ``kernel_mode='pallas'`` (interpret-mode on CPU: relative, not
   absolute, numbers).

Measurement policy (§12): every paired claim is sampled *interleaved*
(A, B, A, B, …) and reduced with ``min`` over generous reps — on shared
CI hosts scheduling noise is additive, and non-interleaved medians of a
few samples routinely invert comparisons (the PR-6-era
``BENCH_fused.json`` "regression" was exactly this artifact). The raw
batches also yield :func:`repro.xla_utils.noise_frac`, persisted next to
the numbers so ``check_regression.py`` can widen its margins on noisy
hosts instead of flaking.
"""
import dataclasses
import json
import pathlib

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.timing import interleaved_samples_us, noise_frac
from repro.core import quant
from repro.core.vdbb import DBBFormat, dbb_encode_conv
from repro.kernels import ops
from repro.xla_utils import cost_analysis_dict, hlo_op_breakdown

OUT_PATH = pathlib.Path(__file__).resolve().parents[1] / "BENCH_fused.json"

# the shared harness settings for every paired wall-time claim below
WARMUP = 2
REPS = 25
STAT = "min"


def _paired(fn_a, fn_b):
    """min-of-k interleaved wall times + the batch noise estimate."""
    sa, sb = interleaved_samples_us(fn_a, fn_b, warmup=WARMUP, reps=REPS)
    return min(sa), min(sb), max(noise_frac(sa), noise_frac(sb))


def run(report):
    results = {
        "layers": [], "xla": {}, "wall_time_us": {}, "noise_frac": {},
        "harness": {"stat": STAT, "reps": REPS, "warmup": WARMUP,
                    "interleaved": True, "backend": jax.default_backend()},
    }

    # --- 1. modeled per-layer HBM bytes (the acceptance criterion) --------
    from repro.configs import smoke_cnn_config
    from repro.models.cnn import SparseCNN

    cfg = smoke_cnn_config("sparse-cnn-tiny", sparsity=0.625)
    model = SparseCNN(cfg)
    batch = 4
    unfused = model.layer_costs(batch, bits=8, act_bits=8)
    fused = model.layer_costs(batch, bits=8, act_bits=8, epilogue_fused=True)

    def total(c):
        return c["act_bytes"] + c["weight_bytes"] + c["out_bytes"] + c["epilogue_bytes"]

    for (name, cu, fmt), (_, cf, _) in zip(unfused, fused):
        saved = 1.0 - total(cf) / total(cu)
        assert saved >= 0.25, (name, saved)  # acceptance: ≥25% per layer
        results["layers"].append(
            dict(name=name, hbm_bytes_unfused=total(cu), hbm_bytes_fused=total(cf),
                 saved_frac=round(saved, 4), nnz=fmt.nnz, bz=fmt.bz)
        )
        report(f"fused/{name}_hbm_bytes", 0.0,
               f"fused {total(cf)} vs unfused {total(cu)} (-{saved:.0%} modeled)")

    # --- one quantized conv layer, fused kernel vs PR-3 + XLA epilogue ---
    n, h, w, c, f = 2, 16, 16, 32, 64
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 3)
    x = jax.random.normal(k1, (n, h, w, c))
    w4 = jax.random.normal(k2, (3, 3, c, f))
    b = jax.random.normal(k3, (f,))
    fmt = DBBFormat(8, 3, "matrix")
    qw = quant.quantize_dbb(dbb_encode_conv(w4, fmt, prune=True))
    s_a = quant.dynamic_act_scale(x)
    out_s = jnp.float32(0.05)
    xq = quant.quantize(x, s_a)

    def fused_layer(xq):
        return ops.quant_conv(xq, qw, 3, 3, s_a, bias=b, relu=True,
                              out_scale=out_s, bf=f, interpret=True)

    def unfused_layer(xq):
        y = ops.quant_conv(xq, qw, 3, 3, s_a, bf=f, interpret=True)
        return quant.quantize(jax.nn.relu(y + b), out_s)

    np.testing.assert_array_equal(  # same int8 codes either way
        np.asarray(fused_layer(xq)), np.asarray(unfused_layer(xq))
    )

    # --- 2. compiled-HLO traffic + launch breakdown (best effort) --------
    for label, fn in (("fused", fused_layer), ("unfused", unfused_layer)):
        cost = cost_analysis_dict(jax.jit(fn).lower(xq).compile())
        hlo = hlo_op_breakdown(fn, xq)
        results["xla"][label] = {
            "bytes_accessed": cost.get("bytes accessed"),
            "flops": cost.get("flops"),
            "n_instructions": hlo["n_instructions"],
            "n_fusions": hlo["n_fusions"],
            "n_custom_calls": hlo["n_custom_calls"],
        }
    ba_f = results["xla"]["fused"]["bytes_accessed"]
    ba_u = results["xla"]["unfused"]["bytes_accessed"]
    derived = (
        f"hlo bytes {ba_f:.3g} vs {ba_u:.3g}" if ba_f and ba_u
        else "hlo bytes unavailable on this backend"
    )

    # --- 3. wall time (interleaved min-of-k; relative only on CPU) --------
    jf, ju = jax.jit(fused_layer), jax.jit(unfused_layer)
    t_f, t_u, nz = _paired(lambda: jf(xq), lambda: ju(xq))
    results["wall_time_us"] = {"layer_fused": t_f, "layer_unfused": t_u}
    results["noise_frac"]["layer"] = round(nz, 4)
    report("fused/conv_layer", t_f,
           f"unfused {t_u:.0f}us (noise {nz:.0%}); {derived}")

    # int8-resident model forward vs the per-layer-dequant path, on the
    # Pallas serving datapath — the chain the fused epilogue exists for
    # (ref mode is a structural tie: both sides are the same XLA convs)
    pmodel = SparseCNN(dataclasses.replace(cfg, kernel_mode="pallas"))
    params = pmodel.compress(pmodel.init(jax.random.PRNGKey(0)))
    xb = jax.random.normal(
        jax.random.PRNGKey(1), (batch, cfg.image_size, cfg.image_size, cfg.in_channels)
    )
    _, stats = pmodel.apply(params, xb, collect_act_stats=True)
    qparams = pmodel.quantize(params, stats)

    @jax.jit
    def chained(xb):
        return pmodel.apply(qparams, xb)

    @jax.jit
    def per_layer(xb):
        layers = pmodel.layers()
        y = xb
        for i, m in enumerate(layers[:-1]):
            y = jax.nn.relu(m(qparams[f"l{i}"], y))
        return layers[-1](qparams[f"l{len(layers) - 1}"], y.mean(axis=(1, 2)))

    rel = float(
        jnp.linalg.norm(chained(xb) - per_layer(xb))
        / jnp.linalg.norm(per_layer(xb))
    )
    assert rel < 0.01, rel
    t_c, t_p, nz = _paired(lambda: chained(xb), lambda: per_layer(xb))
    results["wall_time_us"]["cnn_int8_resident"] = t_c
    results["wall_time_us"]["cnn_per_layer_dequant"] = t_p
    results["noise_frac"]["cnn"] = round(nz, 4)
    report("fused/cnn_forward", t_c,
           f"per-layer-dequant {t_p:.0f}us (noise {nz:.0%}), rel l2 {rel:.2e} "
           "(int8-resident chain, pallas mode)")

    OUT_PATH.write_text(json.dumps(results, indent=2))
    report("fused/json", 0.0, f"wrote {OUT_PATH.name}")


if __name__ == "__main__":
    run(lambda name, us, derived="": print(f"{name},{us:.1f},{derived}"))
