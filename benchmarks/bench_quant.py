"""INT8 quantized datapath smoke (DESIGN.md §8) — runs in CI (--smoke).

Three fast checks that keep the quantized path from rotting:

1. kernel integrity — the int8 tc Pallas kernel (interpret mode) against
   the exact int32 integer reference, bit-exact, on a tiny shape;
2. operand-stream accounting — `dbb_gemm_costs` at int8 vs bf16 widths:
   activation bytes halve, the compressed weight stream shrinks by the
   (nnz·8 + bz) / (nnz·16 + bz) values+mask ratio;
3. end-to-end numerics — the smoke SparseCNN quantized via the
   ActStats-calibrated `quantize()` lifecycle agrees with its fp32
   logits (relative L2 reported, asserted < 5%).
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import quant
from repro.core.vdbb import DBBFormat, dbb_encode, dbb_gemm_costs
from repro.kernels import ops, ref


def run(report):
    t0 = time.time()
    # 1. bit-exact int8 kernel (tiny, interpret mode)
    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    m, k, n = 16, 64, 32
    a = jax.random.normal(k1, (m, k))
    w = jax.random.normal(k2, (k, n))
    fmt = DBBFormat(8, 3, "matrix")
    qw = quant.quantize_dbb(dbb_encode(w, fmt, prune=True))
    aq = quant.quantize(a, quant.dynamic_act_scale(a))
    got = ops.vdbb_matmul(aq, qw.as_dbb(), bm=8, bn=16, kb=2, interpret=True)
    want = ref.vdbb_matmul_int_ref(aq, qw.values, qw.indices[:, :, 0], fmt)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    report("quant/int8_tc_bit_exact", (time.time() - t0) * 1e6,
           f"int32 accumulator max {int(jnp.abs(got).max())}")

    # 2. operand stream widths
    c8 = dbb_gemm_costs(256, 2048, 2048, fmt, bits=8, act_bits=8)
    c16 = dbb_gemm_costs(256, 2048, 2048, fmt, bits=16, act_bits=16)
    assert c8["act_bytes"] * 2 == c16["act_bytes"]
    assert c8["weight_bytes"] < c16["weight_bytes"]
    report(
        "quant/operand_bytes", 0.0,
        f"int8/bf16: act x{c8['act_bytes'] / c16['act_bytes']:.2f} "
        f"weight x{c8['weight_bytes'] / c16['weight_bytes']:.2f}",
    )

    # 3. calibrated end-to-end numerics on the smoke CNN
    from repro.configs import smoke_cnn_config
    from repro.models.cnn import SparseCNN

    t1 = time.time()
    cfg = smoke_cnn_config("sparse-cnn-tiny", sparsity=0.625)
    model = SparseCNN(cfg)
    params = model.compress(model.init(jax.random.PRNGKey(0)))
    x = jax.random.normal(
        jax.random.PRNGKey(1), (4, cfg.image_size, cfg.image_size, cfg.in_channels)
    )
    logits_fp, stats = model.apply(params, x, collect_act_stats=True)
    logits_q = model.apply(model.quantize(params, stats), x)
    rel = float(
        jnp.linalg.norm(logits_q - logits_fp) / jnp.linalg.norm(logits_fp)
    )
    assert rel < 0.05, f"quantized logits off by {rel:.1%} (> 5%)"
    report(
        "quant/cnn_int8_vs_fp32", (time.time() - t1) * 1e6,
        f"rel l2 {rel:.4f} (calibrated act scales from ActStats absmax)",
    )
