"""Generate EXPERIMENTS.md from the dry-run caches.

Static method text + dynamic tables (§Dry-run, §Roofline, §Perf
before/after from dryrun_baseline/ vs dryrun/).

  PYTHONPATH=src:. python -m benchmarks.write_experiments
"""
from __future__ import annotations

import json
import pathlib

from benchmarks import roofline as rl

ROOT = pathlib.Path(__file__).resolve().parents[1]
RESULTS = ROOT / "benchmarks" / "results"

HILLCLIMB = [
    ("qwen2-72b", "train_4k", "worst step bound + most collective-bound"),
    ("deepseek-v3-671b", "train_4k", "memory-dominated; lowest useful-FLOP ratio (MoE dispatch)"),
    ("codeqwen1.5-7b", "decode_32k", "most representative of the paper: weight-bandwidth-bound serving"),
]

HEADER = """# EXPERIMENTS

Reproduction of *Sparse Systolic Tensor Array for Efficient CNN Hardware
Acceleration* (Liu, Whatmough, Mattina, 2020) as a multi-pod JAX framework.
All numbers below are generated from cached artifacts under
`benchmarks/results/` (regenerate: `python -m benchmarks.write_experiments`).

## Paper-claim validation (benchmarks/, CPU-run)

| paper artifact | result | where |
|---|---|---|
| Table V: 16.8 / 21.9 / 31.3 / 55.7 TOPS/W @ 50/62.5/75/87.5% (16nm) | model matches all rows within 3.2% (65nm rows within 2%) | `bench_table_v` |
| Fig 9/10 design space groupings | VDBB+IM2C pareto: rel power 0.199, rel area 0.316 vs SA baseline (paper: >2x / >2.5x) | `bench_design_space` |
| Fig 12 throughput/energy vs sparsity | VDBB 4.1→32.8 eff TOPS, 8.4→55 TOPS/W; fixed-DBB step at 50%; SA flat (paper: ~30 TOPS, 55.7 TOPS/W @87.5%) | `bench_sparsity_scaling` |
| Table I: DBB pruning ≈ dense accuracy | dense .803 vs 4/8 .818, 3/8 .821, 2/8 .835 (synthetic task; sparsity regularizes) | `bench_dbb_pruning` |
| Table II: larger BZ better at equal ratio | 1/4 .824 ≤ 2/8 .833, 4/16 .832 (3-seed mean) | `bench_dbb_pruning` |
| Fig 8 IM2COL 3x magnification | fused kernel datapath reads 7.97x fewer activation bytes (full tile; paper line buffer: 3x avg) | `bench_im2col` |
| Time-unrolled occupancy | compiled HLO FLOPs of the compressed matmul scale 4.00x from nnz=8→2; CPU wall time 36.5→6.8 ms (nnz 8→1) | `bench_kernels`, `fig12/kernel_flops` |

## Method notes (read before the tables)

- **Scan-body accounting.** XLA cost analysis counts `lax.scan` bodies once,
  so every per-step FLOP/byte/collective figure comes from unrolled
  micro-compiles at L=1 and L=2 pattern-groups, extrapolated
  `base + delta*(groups + tail/len(pattern))` (launch/dryrun.py). Validated
  at 1.04x of analytic 6ND on internvl2-2b before optimization.
- **CPU f32 normalization.** The CPU backend upcasts every bf16 dot and the
  collectives around it to f32 (verified: all JAX-level tensors are bf16).
  Collective terms therefore use *TPU-equivalent* bytes (f32 counted at 2
  bytes); raw bytes are retained in the JSON records. The HBM-bytes term is
  NOT corrected and is an upper bound (conservative roofline).
- **Decode DUS caveat.** `cost_analysis` charges dynamic-update-slice a full
  cache rewrite; with buffer donation TPU updates in place, so decode
  memory terms are upper bounds dominated by the (real) cache read.
- Hardware constants: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM,
  ~50 GB/s/link ICI. compute = flops/chip/197e12; memory = bytes/chip/819e9;
  collective = coll-bytes/chip/50e9; roofline fraction = compute / max-term.
- MODEL_FLOPS = 6·N_active·tokens (train; + logits matmul), 2·N_active·B
  (decode). MODEL/HLO > 1 for sparse serving is the VDBB FLOP reduction
  (ideal 8/3 ≈ 2.67 at 3/8 when GEMMs dominate).

"""


def fmt_bytes(x):
    return f"{x/1e9:.1f}G" if x else "—"


def dryrun_section(rows):
    out = ["## §Dry-run (multi-pod)\n\n"]
    ok1 = [r for r in rows if r["status"] == "ok"]
    out.append(
        f"Single pod 16x16 (256 chips): **{len(ok1)} cells compiled OK, "
        f"{sum(r['status']=='skipped' for r in rows)} documented skips** "
        "(long_500k on the 8 pure full-attention archs — DESIGN.md §5). "
        "Multi-pod 2x16x16 (512 chips, 'pod' axis = pure DP): same counts — "
        "see `benchmarks/results/dryrun/*pod2*.json`.\n\n"
    )
    out.append("| arch | shape | kind | attn mode | compile s | args GB/chip | temp GB/chip |\n|---|---|---|---|---|---|---|\n")
    for r in rows:
        if r["status"] == "skipped":
            out.append(f"| {r['arch']} | {r['shape']} | — | — | SKIP | — | — |\n")
            continue
        m = r["memory"]
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['kind']} | {r['attn_mode']} "
            f"| {r['compile_s']} | {m['argument_bytes']/1e9:.2f} | {m['temp_bytes']/1e9:.2f} |\n"
        )
    out.append(
        "\nPer-arch parallelization (sharding/rules.py): kv_sharded = classic "
        "head TP; q_sharded = query-head TP with replicated KV; context = "
        "context-parallel attention (q-seq on 'model'); feature = RWKV "
        "projections TP'd as features, WKV data-parallel. All training cells "
        "run TP x FSDP (ZeRO-3 'w_embed'→data) with sequence-parallel "
        "residuals and bf16 params + fp32 master in the optimizer.\n\n"
    )
    return "".join(out)


def roofline_section(rows):
    out = ["## §Roofline (single pod, per-step terms)\n\n"]
    out.append(rl.render_md(rows))
    out.append(
        "\nPer-cell one-liners (what would move the dominant term) are in the "
        "per-cell JSON (`notes` below for the hillclimbed cells); across the "
        "table: train cells are bound by TP/SP activation collectives and "
        "remat HBM traffic (lever: fewer/smaller resharding points, "
        "selective remat); decode cells are KV-cache/weight bandwidth bound "
        "(lever: the paper's compression — see the sparsity A/B below); "
        "prefill cells are bound by the one-shot cache write + logits.\n\n"
    )
    return "".join(out)


def _metrics(rec):
    t = rl.roofline_row(rec).get("terms") or {}
    return t


def perf_section():
    out = ["## §Perf — hillclimb log (3 cells)\n\n"]
    out.append(
        "Baseline = paper-faithful first implementation (archived in "
        "`benchmarks/results/dryrun_baseline/`); optimized = current code. "
        "Both lowered through the same accounting.\n\n"
    )
    for arch, shape, why in HILLCLIMB:
        key = f"{arch}__{shape}__pod1__s0.625.json"
        base = json.loads((RESULTS / "dryrun_baseline" / key).read_text())
        cur = json.loads((RESULTS / "dryrun" / key).read_text())
        tb, tc = _metrics(base), _metrics(cur)
        out.append(f"### {arch} × {shape} — chosen: {why}\n\n")
        out.append("| metric | baseline | optimized | Δ |\n|---|---|---|---|\n")
        for k, label in [
            ("compute_s", "compute term (s)"),
            ("memory_s", "memory term (s)"),
            ("collective_s", "collective term (s)"),
            ("step_time_bound_s", "step bound (s)"),
            ("roofline_fraction", "roofline fraction"),
            ("useful_ratio", "MODEL/HLO flops"),
        ]:
            b, c = tb.get(k), tc.get(k)
            if b is None or c is None:
                continue
            d = (c / b - 1) * 100 if b else 0.0
            out.append(f"| {label} | {b:.3g} | {c:.3g} | {d:+.0f}% |\n")
        out.append("\n")
    out.append(PERF_LOG)
    return "".join(out)


PERF_LOG = """### Iteration log (hypothesis → change → measured → verdict)

All measurements: per-device collective bytes of a 1-group unrolled compile
(`benchmarks/perf/inspect_collectives.py`), raw CPU-HLO bytes.

**H1 — grouped-GQA replication (qwen2-72b).** *Hypothesis:* the grouped
attention reshape heads→(kv=8, g=8) is unshardable at TP=16 (neither factor
divisible), so SPMD replicates the (B,64,S_q,S_k) f32 score tensors in the
rematted q-chunk scan backward (two 17.2 GB all-gathers visible, plus SPMD
"involuntary full rematerialization" warnings). *Change:* expand KV to the
full query-head count before attention (repeat, 67 MB) so the head dim
shards 16-way; pin score/prob shardings inside `_attend`. *Measured:*
94.3 → 42.6 GB/group (−55%). **Confirmed** — and it also removed the SPMD
warnings. *Lesson:* shardability of every reshape factor is a design
constraint, not an optimization detail.

**H2 — embedding gather (all archs).** *Hypothesis:* `jnp.take` on the
vocab-sharded table makes GSPMD all-gather the full fp32 table (4.98 GB) and
all-reduce its full gradient (5.55 GB). *Change:* shard_map masked local
lookup + psum of the (B,S,d) bf16 result. *Measured:* table/table-grad
collectives gone; replaced by one 1.07 GB (bf16-equiv) psum. **Confirmed**
(≈ −8 GB/step base).

**H3 — params don't fit (qwen2-72b, fp32+TP-only).** *Hypothesis:* TP-only
fp32 params+optimizer = 54 GB/chip (> v5e 16 GB); FSDP over 'data' is
required, and fp32 FSDP gathers would double the wire bytes. *Change:*
'w_embed' logical axis → 'data' (ZeRO-3), params in bf16 with the fp32
master copy in the (sharded, never-gathered) optimizer state. *Measured:*
params+opt ≈ 3.4 GB/chip; weight gathers move bf16. **Confirmed** — this is
a runnability fix that the collective-bytes metric alone would never force.

**H4 — MoE global dispatch (deepseek-v3).** *Hypothesis:* expert-choice
routing over the *global* token set gathers across the data axis — ~15 GB
(bf16) of token tensor all-gathered per MoE layer. *Change:* GShard-style
grouped dispatch (experts pick top-C within each example; dispatch indices
born expert-sharded; un-SP the block input before the seq-dim gather).
*Measured:* dispatch all-gather eliminated; residual 15 GB gather/all-reduce
pair remains in the combine backward (next lever: scatter via
per-expert-shard partial sums). Dispatched tensor shrank 16x
((E,32768,d) global → (B,E,128,d) per-example). **Partially confirmed.**

**H5 — CPU f32 normalization (accounting).** *Hypothesis:* remaining
collectives are exactly 2x inflated because the CPU backend upcasts every
bf16 dot/collective to f32 (JAX-level dtypes verified bf16). *Change:*
TPU-equivalent accounting (f32 collectives counted at 2 B/elem), raw bytes
retained. *Measured:* 50.0 raw = 25.0 equiv GB/group on qwen2-72b.
**Confirmed** (calibration, not a speedup).

**H6 — q-chunk stack sharding.** *Hypothesis:* the stacked q tensor in
`attend_chunked` loses head sharding in the scan backward (2.68 GB gather).
*Change:* explicit constraint on the stacked layout. *Measured:* the
dynamic-slice gather persists at ~2.7 GB (it is the saved-activation
restore of the scan, not the stack itself). **Refuted** — kept the
constraint (harmless), logged the lesson: remat-saved scan carries are
resharded at restore, so the fix must target the checkpoint policy, not
the forward annotation.

**Sparsity lever (codeqwen1.5-7b decode_32k — the paper's own axis).**
Dense vs VDBB 3/8 vs VDBB 1/8 on the identical cell (measured per device):
HLO FLOPs 7.87e10 → 7.21e10 → 6.94e10; HBM bytes 1.10e11 → 1.06e11 →
1.05e11; resident params+cache 9.61 → 9.03 → 8.80 GB. The weight stream
compresses exactly as the paper predicts (Δ = 0.58 GB at 3/8 == 8/3
compression of the 1 GB bf16 weight shard), but at global batch 128 this
cell is KV-cache-bound (≈8 GB cache vs 1 GB weights per chip), so the
end-to-end bound moves only ~5%. *Refined hypothesis, confirmed
analytically:* the VDBB win on TPU decode concentrates in the low-batch
latency regime — at batch ≤16 the weight stream dominates (1 GB vs
≤0.5 GB cache per chip) and the decode bound scales ≈ nnz/8, the direct
re-expression of Fig 12. This mirrors the paper's own positioning (mobile,
effectively batch-1 inference). For cache-bound serving the same block
machinery applies to the KV cache (DBB-compressed cache is future work,
noted in DESIGN.md).

**End-to-end training evidence.** `examples/train_sparse_lm.py` (97M-param
qwen2-family LM, DBB 3/8 constraint projected every step, annealed dense→3/8
over the first third): loss 10.73 → 4.59 by step 60 on the synthetic
pipeline (log: steady descent, constraint verified exactly satisfied at
every checkpoint); `examples/quickstart.py` trains its smoke model
6.66 → 3.40 in 40 steps and verifies compressed serving matches the
dense-masked forward bit-for-bit (max |Δlogit| = 0).

**Stopping criterion:** after H4/H6 the last three changes moved the
dominant terms of their cells by <5% — stopped per the §Perf protocol.
"""


def main():
    rows = rl.table(multi_pod=False)
    md = HEADER + dryrun_section(rows) + roofline_section(rows) + perf_section()
    (ROOT / "EXPERIMENTS.md").write_text(md)
    print(f"wrote EXPERIMENTS.md ({len(md)} chars)")


if __name__ == "__main__":
    main()
