"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV. ``python -m benchmarks.run``.
``--smoke`` runs the fast analytic subset (what CI runs so benchmark
modules can't silently rot); the interpret-mode Pallas sweeps stay out.
``--json <path>`` additionally writes every reported row as JSON for
trajectory tracking (CI uploads the smoke results as an artifact).

Every sub-benchmark failure is caught, reported inline, and re-listed in
a ``FAILED n/m`` summary at the end; the process exits 1 if *any* module
failed (not just the last one), so CI cannot green-wash a mid-run
assertion. ``REPRO_BENCH_EXTRA`` (colon-separated module names) appends
extra bench modules — the hook the subprocess test uses to prove the
exit-code contract with a deliberately failing module.
"""
from __future__ import annotations

import argparse
import json
import os
import pathlib
import sys
import traceback

# Runnable from a bare checkout: put src/ on the path (mirrors
# tests/conftest.py, so CI needs no PYTHONPATH plumbing).
_SRC = str(pathlib.Path(__file__).resolve().parents[1] / "src")
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)


def report(name: str, us_per_call: float, derived: str = ""):
    print(f"{name},{us_per_call:.1f},{derived}")


# (label, module, in the --smoke subset)
BENCHES = [
    ("table_v (Table V headline TOPS/W)", "benchmarks.bench_table_v", True),
    ("design_space (Fig 9/10)", "benchmarks.bench_design_space", True),
    ("sparsity_scaling (Fig 12)", "benchmarks.bench_sparsity_scaling", True),
    ("dbb_pruning (Table I/II)", "benchmarks.bench_dbb_pruning", False),
    ("im2col (IM2COL unit, Fig 8)", "benchmarks.bench_im2col", False),
    ("sparse_conv (IM2COL x VDBB fused)", "benchmarks.bench_sparse_conv", False),
    ("kernels (VDBB matmul)", "benchmarks.bench_kernels", False),
    ("quant (INT8 datapath, DESIGN §8)", "benchmarks.bench_quant", True),
    ("fused (epilogue fusion, DESIGN §9)", "benchmarks.bench_fused", True),
    ("autotune (tile search + frozen plans, DESIGN §10)", "benchmarks.bench_autotune", True),
    ("serve (continuous-batching tier + chaos, DESIGN §11/§14)", "benchmarks.bench_serve", True),
    ("lm (LM VDBB routing + plans, DESIGN §13)", "benchmarks.bench_lm", True),
    ("roofline (EXPERIMENTS §Roofline)", "benchmarks.roofline", True),
]


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, help="substring filter")
    ap.add_argument(
        "--smoke", action="store_true",
        help="fast analytic subset (CI): energy model + measured-act benches",
    )
    ap.add_argument(
        "--json", default=None, metavar="PATH",
        help="also write every reported row as JSON (trajectory tracking)",
    )
    args = ap.parse_args()
    print("name,us_per_call,derived")
    failures = []
    rows = []

    def record(name: str, us_per_call: float, derived: str = ""):
        report(name, us_per_call, derived)
        rows.append(dict(name=name, us_per_call=us_per_call, derived=derived))

    import importlib

    benches = list(BENCHES)
    extra = os.environ.get("REPRO_BENCH_EXTRA", "")
    benches += [(m, m, True) for m in extra.split(":") if m]
    ran = 0
    for label, mod, smoke_ok in benches:
        if args.only and args.only not in mod:
            continue
        if args.smoke and not smoke_ok:
            continue
        ran += 1
        try:
            importlib.import_module(mod).run(record)
        except Exception as e:  # noqa: BLE001
            failures.append((label, e))
            traceback.print_exc()
            record(f"{mod}/FAILED", 0.0, f"{type(e).__name__}: {e}")
    if args.json:
        pathlib.Path(args.json).write_text(json.dumps({"rows": rows}, indent=2))
    if failures:
        print(f"FAILED {len(failures)}/{ran} benchmarks:", file=sys.stderr)
        for label, e in failures:
            print(f"  {label}: {type(e).__name__}: {e}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
