"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV. ``python -m benchmarks.run``.
"""
from __future__ import annotations

import argparse
import sys
import traceback


def report(name: str, us_per_call: float, derived: str = ""):
    print(f"{name},{us_per_call:.1f},{derived}")


BENCHES = [
    ("table_v (Table V headline TOPS/W)", "benchmarks.bench_table_v"),
    ("design_space (Fig 9/10)", "benchmarks.bench_design_space"),
    ("sparsity_scaling (Fig 12)", "benchmarks.bench_sparsity_scaling"),
    ("dbb_pruning (Table I/II)", "benchmarks.bench_dbb_pruning"),
    ("im2col (IM2COL unit, Fig 8)", "benchmarks.bench_im2col"),
    ("sparse_conv (IM2COL x VDBB fused)", "benchmarks.bench_sparse_conv"),
    ("kernels (VDBB matmul)", "benchmarks.bench_kernels"),
    ("roofline (EXPERIMENTS §Roofline)", "benchmarks.roofline"),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, help="substring filter")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    failures = []
    import importlib

    for label, mod in BENCHES:
        if args.only and args.only not in mod:
            continue
        try:
            importlib.import_module(mod).run(report)
        except Exception as e:  # noqa: BLE001
            failures.append((label, e))
            traceback.print_exc()
            report(f"{mod}/FAILED", 0.0, f"{type(e).__name__}: {e}")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
