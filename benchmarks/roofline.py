"""Roofline report: derive compute / memory / collective terms per
(arch x shape) cell from the dry-run cache and emit the EXPERIMENTS.md
§Roofline table.

Terms (TPU v5e):
  compute    = per-device HLO FLOPs / 197 TFLOP/s (bf16)
  memory     = per-device HLO bytes / 819 GB/s HBM
  collective = per-device collective bytes / 50 GB/s ICI

Per-device FLOPs/bytes come from the unrolled micro-compile extrapolation
(see launch/dryrun.py: XLA cost analysis counts scan bodies once, so the
full-program numbers are floors, not step costs).

MODEL_FLOPS uses the standard 6*N*D (train) / 2*N*B (decode) with N =
active non-embedding params (MoE: shared + top_k/E of routed), D = tokens
per step. The ratio MODEL_FLOPS / HLO_FLOPS shows how much compiled
compute is 'useful' (catches remat and resharding waste); with VDBB
serving, HLO FLOPs *should* drop below dense MODEL_FLOPS by ~nnz/bz.
"""
from __future__ import annotations

import json
import pathlib

from repro.core.energy_model import TPU_V5E

RESULTS = pathlib.Path(__file__).resolve().parent / "results"
DRYRUN = RESULTS / "dryrun"


def model_flops(arch: str, shape: dict, kind: str, sparsity) -> dict:
    from repro.configs import get_config
    from repro.models.model import LM

    cfg = get_config(arch, sparsity=sparsity)
    n_total = cfg.param_count()
    n_active = cfg.active_param_count()
    # exclude embedding table rows from the '6ND' core count
    n_embed = cfg.padded_vocab * cfg.d_model
    if not cfg.tie_embeddings:
        n_embed *= 2
    if cfg.frontend == "audio":
        n_embed = (
            cfg.num_codebooks * cfg.codebook_vocab * cfg.d_model * 2
        )
    n_core = max(n_active - n_embed, 1)
    b, s = shape["global_batch"], shape["seq_len"]
    if kind == "train":
        mf = 6 * n_core * b * s + 2 * b * s * cfg.padded_vocab * cfg.d_model
    elif kind == "prefill":
        mf = 2 * n_core * b * s
    else:  # decode: one token/step, attention reads the cache
        mf = 2 * n_core * b
    return dict(n_total=n_total, n_active=n_active, n_core=n_core, model_flops=mf)


def load_cells(multi_pod=False):
    pod = "pod2" if multi_pod else "pod1"
    out = []
    for p in sorted(DRYRUN.glob(f"*__{pod}__*.json")):
        out.append(json.loads(p.read_text()))
    return out


def roofline_row(rec: dict) -> dict:
    from repro.configs import SHAPES

    if rec["status"] != "ok":
        return dict(rec, terms=None)
    chips = rec["chips"]
    micro = rec.get("micro") or {}
    flops_pd = micro.get("per_device_flops") or rec["cost"]["flops"]
    bytes_pd = micro.get("per_device_bytes") or rec["cost"]["bytes_accessed"]
    coll_pd = micro.get("per_device_collective_bytes_tpu_equiv")
    if coll_pd is None:
        coll_pd = micro.get("per_device_collective_bytes")
    if coll_pd is None:
        coll_pd = rec["collectives"].get(
            "tpu_equiv_total_bytes", rec["collectives"]["total_bytes"]
        )
    t_c = flops_pd / TPU_V5E["peak_bf16_flops"]
    t_m = bytes_pd / TPU_V5E["hbm_bw"]
    t_x = coll_pd / TPU_V5E["ici_bw"]
    dom = max((t_c, "compute"), (t_m, "memory"), (t_x, "collective"))[1]
    sh = SHAPES[rec["shape"]]
    mf = model_flops(rec["arch"], sh, rec["kind"], rec["sparsity"])
    hlo_global = flops_pd * chips
    return dict(
        rec,
        terms=dict(
            compute_s=t_c,
            memory_s=t_m,
            collective_s=t_x,
            dominant=dom,
            step_time_bound_s=max(t_c, t_m, t_x),
            roofline_fraction=t_c / max(t_c, t_m, t_x),
            model_flops=mf["model_flops"],
            hlo_flops_global=hlo_global,
            useful_ratio=mf["model_flops"] / max(hlo_global, 1),
            n_active=mf["n_active"],
        ),
    )


def table(multi_pod=False):
    return [roofline_row(r) for r in load_cells(multi_pod)]


def render_md(rows) -> str:
    hdr = (
        "| arch | shape | sp | attn | compute s | memory s | collective s | "
        "dominant | roofline frac | MODEL/HLO flops |\n|---|---|---|---|---|---|---|---|---|---|\n"
    )
    lines = [hdr]
    for r in rows:
        if r["status"] == "skipped":
            lines.append(
                f"| {r['arch']} | {r['shape']} | {r['sparsity']} | — | — | — | — | "
                f"SKIP | — | — |\n"
            )
            continue
        if r["status"] != "ok" or not r.get("terms"):
            lines.append(f"| {r['arch']} | {r['shape']} | {r['sparsity']} | — | ERROR | | | | | |\n")
            continue
        t = r["terms"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['sparsity']} | {r.get('attn_mode','')} "
            f"| {t['compute_s']:.3e} | {t['memory_s']:.3e} | {t['collective_s']:.3e} "
            f"| **{t['dominant']}** | {t['roofline_fraction']:.2f} | {t['useful_ratio']:.2f} |\n"
        )
    return "".join(lines)


# ---------------------------------------------------------------------------
# Conv workloads (the paper's native CNN inference path)
# ---------------------------------------------------------------------------


def conv_roofline_row(n, h, w, c, f, kh, kw, fmt, *, stride=1, dtype_bytes=1,
                      im2col_unit=True) -> dict:
    """Per-layer TPU roofline terms for the fused IM2COL × VDBB conv.

    compute_s uses *executed* FLOPs (nnz/bz occupancy for tc-mode group
    sharing); memory_s uses compressed weight bytes + the raw (im2col_unit)
    or expanded activation stream — the two effects the fused kernel
    composes. ``bound_reduction`` is the step-time bound vs the dense,
    pre-expanded baseline.
    """
    from repro.core.vdbb import DBBFormat, dbb_conv_costs

    bits = dtype_bytes * 8
    costs = dbb_conv_costs(n, h, w, c, f, kh, kw, fmt, stride=stride, bits=bits,
                           im2col_unit=im2col_unit)
    dense = dbb_conv_costs(n, h, w, c, f, kh, kw, DBBFormat(fmt.bz, fmt.bz),
                           stride=stride, bits=bits, im2col_unit=False)

    def terms(cc, use_executed, dense_weights=False):
        macs = cc["executed_macs"] if use_executed else cc["dense_macs"]
        t_c = 2 * macs / TPU_V5E["peak_bf16_flops"]
        wb = cc["dense_weight_bytes"] if dense_weights else cc["weight_bytes"]
        t_m = (cc["act_bytes"] + wb + cc["out_bytes"]) / TPU_V5E["hbm_bw"]
        return t_c, t_m

    # tc mode shrinks compute; bw mode keeps it dense (per-column patterns).
    executed = fmt.group_size(f) == f
    t_c, t_m = terms(costs, executed)
    # baseline streams the true dense weights, not the nnz=bz DBB container
    # (which still carries the bz-bit mask per block).
    d_c, d_m = terms(dense, False, dense_weights=True)
    return dict(
        shape=dict(n=n, h=h, w=w, c=c, f=f, kh=kh, kw=kw, stride=stride),
        compute_s=t_c,
        memory_s=t_m,
        dominant="compute" if t_c >= t_m else "memory",
        step_time_bound_s=max(t_c, t_m),
        dense_bound_s=max(d_c, d_m),
        bound_reduction=max(d_c, d_m) / max(t_c, t_m),
        im2col_magnification=costs["im2col_magnification"],
        weight_compression=costs["weight_compression"],
        speedup=costs["speedup"],
    )


def conv_table(arch: str = "sparse-cnn-s", sparsity: float = 0.625, batch: int = 8):
    """Roofline rows for every conv layer of a registered CNN config."""
    from repro.configs import get_cnn_config
    from repro.core.sparse_conv import DBBConv2d
    from repro.models.cnn import SparseCNN

    cfg = get_cnn_config(arch, sparsity=sparsity)
    model = SparseCNN(cfg)
    h = w = cfg.image_size
    rows = []
    for i, layer in enumerate(model.layers()):
        if not isinstance(layer, DBBConv2d):
            continue
        rows.append(
            dict(
                layer=f"l{i}",
                fmt=f"{layer.fmt.nnz}/{layer.fmt.bz}",
                **conv_roofline_row(
                    batch, h, w, layer.in_channels, layer.out_channels,
                    layer.kh, layer.kw, layer.fmt, stride=layer.stride,
                ),
            )
        )
        h, w = layer.out_hw(h, w)
    return cfg, rows


def render_conv_md(arch, rows) -> str:
    hdr = (
        f"## Conv roofline — {arch}\n\n"
        "| layer | fmt | compute s | memory s | dominant | bound vs dense | "
        "im2col mag | w compress |\n|---|---|---|---|---|---|---|---|\n"
    )
    lines = [hdr]
    for r in rows:
        lines.append(
            f"| {r['layer']} | {r['fmt']} | {r['compute_s']:.3e} | {r['memory_s']:.3e} "
            f"| **{r['dominant']}** | {r['bound_reduction']:.2f}x "
            f"| {r['im2col_magnification']:.2f}x | {r['weight_compression']:.2f}x |\n"
        )
    return "".join(lines)


def run(report):
    cfg, conv_rows = conv_table()
    RESULTS.mkdir(parents=True, exist_ok=True)
    (RESULTS / "roofline_conv.md").write_text(render_conv_md(cfg.name, conv_rows))
    total = sum(r["step_time_bound_s"] for r in conv_rows)
    dense_total = sum(r["dense_bound_s"] for r in conv_rows)
    report(
        f"roofline/conv/{cfg.name}", total * 1e6,
        f"{len(conv_rows)} conv layers, {dense_total / total:.2f}x bound reduction "
        "vs dense+pre-expanded -> results/roofline_conv.md",
    )
    rows = table(multi_pod=False)
    ok = [r for r in rows if r["status"] == "ok" and r.get("terms")]
    skip = [r for r in rows if r["status"] == "skipped"]
    (RESULTS / "roofline.md").write_text(render_md(rows))
    for r in ok:
        t = r["terms"]
        report(
            f"roofline/{r['arch']}/{r['shape']}",
            t["step_time_bound_s"] * 1e6,
            f"dom={t['dominant']} frac={t['roofline_fraction']:.2f} useful={t['useful_ratio']:.2f}",
        )
    report("roofline/summary", 0.0, f"{len(ok)} cells, {len(skip)} documented skips -> results/roofline.md")
