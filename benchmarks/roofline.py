"""Roofline report: derive compute / memory / collective terms per
(arch x shape) cell from the dry-run cache and emit the EXPERIMENTS.md
§Roofline table.

Terms (TPU v5e):
  compute    = per-device HLO FLOPs / 197 TFLOP/s (bf16)
  memory     = per-device HLO bytes / 819 GB/s HBM
  collective = per-device collective bytes / 50 GB/s ICI

Per-device FLOPs/bytes come from the unrolled micro-compile extrapolation
(see launch/dryrun.py: XLA cost analysis counts scan bodies once, so the
full-program numbers are floors, not step costs).

MODEL_FLOPS uses the standard 6*N*D (train) / 2*N*B (decode) with N =
active non-embedding params (MoE: shared + top_k/E of routed), D = tokens
per step. The ratio MODEL_FLOPS / HLO_FLOPS shows how much compiled
compute is 'useful' (catches remat and resharding waste); with VDBB
serving, HLO FLOPs *should* drop below dense MODEL_FLOPS by ~nnz/bz.
"""
from __future__ import annotations

import json
import pathlib

from repro.core.energy_model import TPU_V5E

RESULTS = pathlib.Path(__file__).resolve().parent / "results"
DRYRUN = RESULTS / "dryrun"


def model_flops(arch: str, shape: dict, kind: str, sparsity) -> dict:
    from repro.configs import get_config
    from repro.models.model import LM

    cfg = get_config(arch, sparsity=sparsity)
    n_total = cfg.param_count()
    n_active = cfg.active_param_count()
    # exclude embedding table rows from the '6ND' core count
    n_embed = cfg.padded_vocab * cfg.d_model
    if not cfg.tie_embeddings:
        n_embed *= 2
    if cfg.frontend == "audio":
        n_embed = (
            cfg.num_codebooks * cfg.codebook_vocab * cfg.d_model * 2
        )
    n_core = max(n_active - n_embed, 1)
    b, s = shape["global_batch"], shape["seq_len"]
    if kind == "train":
        mf = 6 * n_core * b * s + 2 * b * s * cfg.padded_vocab * cfg.d_model
    elif kind == "prefill":
        mf = 2 * n_core * b * s
    else:  # decode: one token/step, attention reads the cache
        mf = 2 * n_core * b
    return dict(n_total=n_total, n_active=n_active, n_core=n_core, model_flops=mf)


def load_cells(multi_pod=False):
    pod = "pod2" if multi_pod else "pod1"
    out = []
    for p in sorted(DRYRUN.glob(f"*__{pod}__*.json")):
        out.append(json.loads(p.read_text()))
    return out


def roofline_row(rec: dict) -> dict:
    from repro.configs import SHAPES

    if rec["status"] != "ok":
        return dict(rec, terms=None)
    chips = rec["chips"]
    micro = rec.get("micro") or {}
    flops_pd = micro.get("per_device_flops") or rec["cost"]["flops"]
    bytes_pd = micro.get("per_device_bytes") or rec["cost"]["bytes_accessed"]
    coll_pd = micro.get("per_device_collective_bytes_tpu_equiv")
    if coll_pd is None:
        coll_pd = micro.get("per_device_collective_bytes")
    if coll_pd is None:
        coll_pd = rec["collectives"].get(
            "tpu_equiv_total_bytes", rec["collectives"]["total_bytes"]
        )
    t_c = flops_pd / TPU_V5E["peak_bf16_flops"]
    t_m = bytes_pd / TPU_V5E["hbm_bw"]
    t_x = coll_pd / TPU_V5E["ici_bw"]
    dom = max((t_c, "compute"), (t_m, "memory"), (t_x, "collective"))[1]
    sh = SHAPES[rec["shape"]]
    mf = model_flops(rec["arch"], sh, rec["kind"], rec["sparsity"])
    hlo_global = flops_pd * chips
    return dict(
        rec,
        terms=dict(
            compute_s=t_c,
            memory_s=t_m,
            collective_s=t_x,
            dominant=dom,
            step_time_bound_s=max(t_c, t_m, t_x),
            roofline_fraction=t_c / max(t_c, t_m, t_x),
            model_flops=mf["model_flops"],
            hlo_flops_global=hlo_global,
            useful_ratio=mf["model_flops"] / max(hlo_global, 1),
            n_active=mf["n_active"],
        ),
    )


def table(multi_pod=False):
    return [roofline_row(r) for r in load_cells(multi_pod)]


def render_md(rows) -> str:
    hdr = (
        "| arch | shape | sp | attn | compute s | memory s | collective s | "
        "dominant | roofline frac | MODEL/HLO flops |\n|---|---|---|---|---|---|---|---|---|---|\n"
    )
    lines = [hdr]
    for r in rows:
        if r["status"] == "skipped":
            lines.append(
                f"| {r['arch']} | {r['shape']} | {r['sparsity']} | — | — | — | — | "
                f"SKIP | — | — |\n"
            )
            continue
        if r["status"] != "ok" or not r.get("terms"):
            lines.append(f"| {r['arch']} | {r['shape']} | {r['sparsity']} | — | ERROR | | | | | |\n")
            continue
        t = r["terms"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['sparsity']} | {r.get('attn_mode','')} "
            f"| {t['compute_s']:.3e} | {t['memory_s']:.3e} | {t['collective_s']:.3e} "
            f"| **{t['dominant']}** | {t['roofline_fraction']:.2f} | {t['useful_ratio']:.2f} |\n"
        )
    return "".join(lines)


def run(report):
    rows = table(multi_pod=False)
    ok = [r for r in rows if r["status"] == "ok" and r.get("terms")]
    skip = [r for r in rows if r["status"] == "skipped"]
    (RESULTS / "roofline.md").write_text(render_md(rows))
    for r in ok:
        t = r["terms"]
        report(
            f"roofline/{r['arch']}/{r['shape']}",
            t["step_time_bound_s"] * 1e6,
            f"dom={t['dominant']} frac={t['roofline_fraction']:.2f} useful={t['useful_ratio']:.2f}",
        )
    report("roofline/summary", 0.0, f"{len(ok)} cells, {len(skip)} documented skips -> results/roofline.md")
