"""Benchmark-artifact regression gate (CI).

Compares the freshly-written ``BENCH_fused.json`` against the *committed*
baseline floors in ``benchmarks/bench_baselines.json`` (the generated
artifacts themselves are gitignored): per-layer fused-epilogue savings
fractions must not regress below the baseline (small tolerance for
rounding) and must in any case stay above the §9 acceptance floor of 25%.

``BENCH_fused.json`` is additionally gated on **measured wall time**
(DESIGN.md §12 — wall time is the perf contract, not the modeled bytes):
the fused conv layer must not lose to the kernel + standalone-XLA-epilogue
program, and the int8-resident CNN chain must not lose to the
per-layer-dequant path. Both pairs are measured interleaved min-of-k by
``bench_fused.py``; the gate margin is ``fused_wall_margin`` widened by
the measured host noise of the same sample batch
(``× (1 + min(noise_frac, fused_noise_cap))``) — host-speed-relative, so
a contended CI box widens its own tolerance instead of flaking, while a
genuine fusion regression still trips it.

Every artifact is first checked against a minimal schema (required keys
present, numbers finite and positive) so a truncated or hand-edited file
fails loudly instead of silently passing vacuous gates.

``BENCH_autotune.json`` is validated as a second-line gate: the
confirmation-pass contract (``tuned_us ≤ default_us`` — enforced by the
search's interleaved head-to-head, with non-replicating winners demoted
to the default) must hold in the artifact, the independent re-measured
numbers must stay within a loose sanity margin, and plan serving must
have been bit-identical. The bench asserts the same things first; this
gate catches a stale or hand-edited artifact.

``BENCH_serve.json`` gates the §11 serving tier on **measured wall
time** (the first slice of the ROADMAP "wall time is the contract"
item): the frozen bucket plan must not lose to the jitted-once
unplanned path beyond ``serve_plan_margin``, every load pattern must
complete all offered requests with **zero retraces after warmup**, and
p99 latency must stay under its self-calibrated bound
(``serve_p99_margin × (max_wait + (queue depth + 2) × measured bucket
time)`` — host-speed-relative, so the gate catches order-of-magnitude
tail-latency regressions without hardcoding microseconds). Bucketed
serving must also have been bit-identical to per-request serving. The
§14 robustness scenarios in the same artifact are gated by
``check_chaos``: blast-radius isolation (innocent survival exactly 1.0,
typed poison failures, zero bisect retraces), overload shedding
(``shed_rate > 0`` at 2x capacity, admitted p99 within the bounded-queue
bound), the ``completed+rejected+failed+expired == offered`` accounting
identity, and a goodput floor of ``chaos_goodput_floor`` x measured
capacity (both sides measured in the same run — noise-aware without a
separate margin). The §15 self-healing scenarios in the same artifact
are gated alongside: the dispatcher-kill run must show ``restarts >= 1``
with requeued requests, survival exactly 1.0, zero hung futures, and
the accounting identity intact across the restart; the corrupt-reload
run must fail typed with the old plan still serving and the step
walk-back recovering; the kernel-degradation run must demote exactly
the faulty bucket (innocents bit-identical), re-promote after the heal,
and hold ``selfheal_goodput_floor`` x the healthy path's goodput.

``BENCH_lm.json`` gates the §13 LM datapath: compressed projection
GEMMs must not lose to the dense matmul the pre-PR-8 ``apply_linear``
fallback silently ran (``lm_wall_margin``, noise-widened like the fused
gate), and the frozen ``LM.plan()`` prefill must be bit-identical to —
and no slower than — the jitted unplanned forward. The int8 GEMM
numbers are recorded but not gated (XLA:CPU has no native int8 path).

Exit code 1 on any regression — run after ``python -m benchmarks.run
--smoke`` (which rewrites all four artifacts).
"""
from __future__ import annotations

import json
import math
import pathlib
import sys

ROOT = pathlib.Path(__file__).resolve().parents[1]
BASELINES = pathlib.Path(__file__).resolve().parent / "bench_baselines.json"
_BASE = json.loads(BASELINES.read_text())
TOLERANCE = 0.02   # absolute saved_frac slack for rounding
# wall-time margins shared with bench_autotune via the baselines file
NOISE_MARGIN = _BASE["autotune_noise_margin"]
SANITY_MARGIN = _BASE["autotune_sanity_margin"]
WALL_MARGIN = _BASE["fused_wall_margin"]
NOISE_CAP = _BASE["fused_noise_cap"]
HARD_FLOOR = 0.25  # the §9 acceptance criterion, regardless of baseline


# ---------------------------------------------------------------------------
# Artifact schemas: {dotted.path: check} where check is 'num' (finite > 0),
# 'frac' (finite ≥ 0), or a type. A path ending in '[]' descends into every
# element of a non-empty list.
# ---------------------------------------------------------------------------

SCHEMAS = {
    "BENCH_fused.json": {
        "layers[].name": str,
        "layers[].saved_frac": "frac",
        "layers[].hbm_bytes_fused": "num",
        "layers[].hbm_bytes_unfused": "num",
        "wall_time_us.layer_fused": "num",
        "wall_time_us.layer_unfused": "num",
        "wall_time_us.cnn_int8_resident": "num",
        "wall_time_us.cnn_per_layer_dequant": "num",
        "noise_frac.layer": "frac",
        "noise_frac.cnn": "frac",
        "harness.reps": "num",
        "harness.stat": str,
    },
    "BENCH_autotune.json": {
        "odd_gemms[].tuned_us": "num",
        "odd_gemms[].default_us": "num",
        "smoke_cnn.plan_us": "num",
        "smoke_cnn.default_us": "num",
    },
    "BENCH_serve.json": {
        "plan_us": "num",
        "unplanned_jit_us": "num",
        "bit_identical": bool,
        "chaos.innocent_survival": "frac",
        "chaos.poison_typed": bool,
        "chaos.accounting_ok": bool,
        "overload.goodput_rps": "num",
        "overload.capacity_rps": "num",
        "overload.shed_rate": "frac",
        "overload.accounting_ok": bool,
        "overload.p99_us": "num",
        "overload.p99_bound_us": "num",
        "selfheal.restart.restarts": "num",
        "selfheal.restart.survival": "frac",
        "selfheal.restart.requeued": "num",
        "selfheal.restart.hung": "frac",
        "selfheal.restart.accounting_ok": bool,
        "selfheal.reload.corrupt_typed": bool,
        "selfheal.reload.old_plan_served": bool,
        "selfheal.reload.fallback_recovered": bool,
        "selfheal.reload.reloads": "num",
        "selfheal.degraded.survival": "frac",
        "selfheal.degraded.demoted_exact": bool,
        "selfheal.degraded.innocents_bit_identical": bool,
        "selfheal.degraded.repromoted": bool,
        "selfheal.degraded.healthy_sps": "num",
        "selfheal.degraded.degraded_sps": "num",
        "selfheal.degraded.accounting_ok": bool,
    },
    "BENCH_lm.json": {
        "gemms[].name": str,
        "gemms[].dense_us": "num",
        "gemms[].compressed_us": "num",
        "gemms[].int8_us": "num",
        "plan.plan_us": "num",
        "plan.unplanned_us": "num",
        "plan.bit_identical": bool,
        "noise_frac.plan": "frac",
        "harness.reps": "num",
        "harness.stat": str,
    },
}


def _walk(data, parts):
    """Yield every value at a dotted path, descending lists at '[]'."""
    if not parts:
        yield data
        return
    head, rest = parts[0], parts[1:]
    if head.endswith("[]"):
        items = data.get(head[:-2], []) if isinstance(data, dict) else []
        if not isinstance(items, list) or not items:
            yield None  # an empty/missing list fails the leaf check below
            return
        for item in items:
            yield from _walk(item, rest)
    else:
        yield from _walk(data.get(head) if isinstance(data, dict) else None, rest)


def schema_errors(name: str, data) -> list:
    """Validate one artifact dict against its schema (see SCHEMAS)."""
    errors = []
    for path, check in SCHEMAS.get(name, {}).items():
        for v in _walk(data, path.split(".")):
            if check == "num":
                ok = isinstance(v, (int, float)) and not isinstance(v, bool) \
                    and math.isfinite(v) and v > 0
                want = "finite positive number"
            elif check == "frac":
                ok = isinstance(v, (int, float)) and not isinstance(v, bool) \
                    and math.isfinite(v) and v >= 0
                want = "finite non-negative number"
            else:
                ok = isinstance(v, check)
                want = check.__name__
            if not ok:
                errors.append(f"{name}: schema: {path} = {v!r} (want {want})")
    return errors


def _wall_margin(noise) -> float:
    """Self-calibrating gate margin: the committed ``fused_wall_margin``
    widened by the measured host noise of the same sample batch, capped so
    a pathologically noisy artifact cannot gate itself vacuously."""
    noise = noise if isinstance(noise, (int, float)) and math.isfinite(noise) \
        else NOISE_CAP
    return WALL_MARGIN * (1.0 + min(max(noise, 0.0), NOISE_CAP))


def check_fused() -> list:
    errors = []
    path = ROOT / "BENCH_fused.json"
    if not path.exists():
        return [f"{path.name} missing (run `python -m benchmarks.run --smoke`)"]
    fresh = json.loads(path.read_text())
    errors += schema_errors(path.name, fresh)
    if errors:
        return errors  # gates below would read garbage
    base = _BASE.get("fused_saved_frac", {})
    for layer in fresh.get("layers", []):
        name, saved = layer["name"], layer["saved_frac"]
        if saved < HARD_FLOOR:
            errors.append(f"fused/{name}: saved_frac {saved:.3f} < hard floor {HARD_FLOOR}")
        ref = base.get(name)
        if ref is not None and saved < ref - TOLERANCE:
            errors.append(
                f"fused/{name}: saved_frac regressed {ref:.3f} -> {saved:.3f} "
                f"(tolerance {TOLERANCE}; committed baseline {BASELINES.name})"
            )
    # measured-wall-time gates (§12): fused must not lose to unfused
    wall, noise = fresh["wall_time_us"], fresh["noise_frac"]
    pairs = (
        ("layer_fused", "layer_unfused", "layer"),
        ("cnn_int8_resident", "cnn_per_layer_dequant", "cnn"),
    )
    for fast, slow, nkey in pairs:
        margin = _wall_margin(noise.get(nkey))
        if wall[fast] > wall[slow] * margin:
            errors.append(
                f"fused/{fast}: {wall[fast]:.0f}us > {wall[slow]:.0f}us "
                f"({slow}) x margin {margin:.2f} (= fused_wall_margin "
                f"{WALL_MARGIN} widened by measured noise "
                f"{noise.get(nkey)})"
            )
    return errors


def check_autotune() -> list:
    errors = []
    path = ROOT / "BENCH_autotune.json"
    if not path.exists():
        return []  # informational artifact; bench_autotune asserts on its own
    data = json.loads(path.read_text())
    errors += schema_errors(path.name, data)
    if errors:
        return errors
    for g in data.get("odd_gemms", []):
        name = f"autotune/gemm_{g['m']}x{g['k']}x{g['n']}"
        if g["tuned_us"] > g["default_us"]:
            errors.append(  # the confirmation-pass contract was violated
                f"{name}: tuned {g['tuned_us']}us > default {g['default_us']}us"
            )
        rt, rd = g.get("remeasured_tuned_us"), g.get("remeasured_default_us")
        if rt is not None and rd is not None and rt > rd * SANITY_MARGIN:
            errors.append(
                f"{name}: independent re-measure {rt}us > {rd}us "
                f"(sanity margin {SANITY_MARGIN}x)"
            )
    cnn = data.get("smoke_cnn") or {}
    if cnn and cnn["plan_us"] > cnn["default_us"] * NOISE_MARGIN:
        errors.append(
            f"autotune/smoke_cnn: plan {cnn['plan_us']}us > unplanned "
            f"{cnn['default_us']}us (margin {NOISE_MARGIN}x)"
        )
    if cnn and not cnn.get("bit_identical", False):
        errors.append("autotune/smoke_cnn: plan serving not bit-identical")
    return errors


def check_serve() -> list:
    errors = []
    path = ROOT / "BENCH_serve.json"
    if not path.exists():
        return [f"{path.name} missing (run `python -m benchmarks.run --smoke`)"]
    data = json.loads(path.read_text())
    errors += schema_errors(path.name, data)
    if errors:
        return errors
    if not data.get("bit_identical", False):
        errors.append("serve: bucketed/padded serving not bit-identical to "
                      "per-request plan.serve")
    plan_us, unplanned_us = data.get("plan_us"), data.get("unplanned_jit_us")
    if plan_us is not None and unplanned_us is not None \
            and plan_us > unplanned_us * _BASE["serve_plan_margin"]:
        errors.append(  # the measured-wall-time contract (ROADMAP)
            f"serve: bucket plan {plan_us}us > jitted-once unplanned "
            f"{unplanned_us}us (margin {_BASE['serve_plan_margin']}x)"
        )
    for name, p in data.get("patterns", {}).items():
        if p.get("completed") != p.get("offered"):
            errors.append(f"serve/{name}: completed {p.get('completed')} != "
                          f"offered {p.get('offered')}")
        if p.get("retraces_after_warmup", 1) != 0:
            errors.append(f"serve/{name}: "
                          f"{p.get('retraces_after_warmup')} retraces under "
                          "load (bucketed plans must serve retrace-free)")
        p99, bound = p.get("p99_us"), p.get("p99_bound_us")
        if p99 is not None and bound is not None and p99 > bound:
            errors.append(f"serve/{name}: p99 {p99}us > self-calibrated "
                          f"bound {bound}us")
    if not data.get("patterns"):
        errors.append("serve: no load patterns recorded")
    return errors


def check_chaos() -> list:
    """Gate the §14 robustness scenarios recorded in BENCH_serve.json:
    blast-radius isolation (every innocent in a poisoned co-batch must
    have completed bit-identical — survival exactly 1.0 — with the
    poisons typed-failed and zero bisect retraces) and overload shedding
    (books balanced, shed under 2x capacity, admitted p99 within its
    self-calibrated bound, goodput above ``chaos_goodput_floor`` x
    measured capacity — noise-aware by construction: both sides of the
    ratio are measured on the same host in the same run)."""
    errors = []
    path = ROOT / "BENCH_serve.json"
    if not path.exists():
        return []  # check_serve already reports the missing artifact
    data = json.loads(path.read_text())
    chaos, over = data.get("chaos"), data.get("overload")
    if not chaos or not over:
        return ["serve: chaos/overload scenarios missing from "
                f"{path.name} (stale artifact? rerun benchmarks)"]
    if chaos.get("innocent_survival") != 1.0:
        errors.append(
            f"chaos: innocent survival {chaos.get('innocent_survival')} != "
            "1.0 — a poisoned co-batch damaged innocent requests")
    if not chaos.get("poison_typed", False):
        errors.append("chaos: poison futures did not fail with their typed "
                      "exceptions (FaultInjected / NumericalFault)")
    if chaos.get("retraces_after_warmup", 1) != 0:
        errors.append(f"chaos: bisect isolation retraced "
                      f"{chaos.get('retraces_after_warmup')}x (halves must "
                      "land on warmed buckets)")
    for name, d in (("chaos", chaos), ("overload", over)):
        if not d.get("accounting_ok", False):
            errors.append(f"{name}: completed+rejected+failed+expired != "
                          "offered (requests leaked)")
    if not over.get("shed_rate", 0) > 0:
        errors.append("overload: 2x capacity offered but nothing shed "
                      "(admission control inert)")
    p99, bound = over.get("p99_us"), over.get("p99_bound_us")
    if p99 is not None and bound is not None and p99 > bound:
        errors.append(f"overload: admitted p99 {p99}us > bounded-queue "
                      f"bound {bound}us")
    floor = _BASE["chaos_goodput_floor"] * over.get("capacity_rps", 0)
    if over.get("goodput_rps", 0) < floor:
        errors.append(
            f"overload: goodput {over.get('goodput_rps')} rps < "
            f"{_BASE['chaos_goodput_floor']} x capacity "
            f"{over.get('capacity_rps')} rps — shedding collapsed service")
    errors += _check_selfheal(data)
    return errors


def _check_selfheal(data) -> list:
    """Gate the §15 self-healing scenarios recorded in BENCH_serve.json:
    the dispatcher-kill run must actually have gone through supervision
    (``restarts >= 1`` with requests requeued), every request must have
    completed bit-identical (survival exactly 1.0, zero hung futures)
    with the accounting identity spanning the restart; a corrupt
    checkpoint must have failed typed with the old plan still serving
    and the step walk-back recovering; and the kernel-degradation run
    must have demoted exactly the faulty bucket (innocents
    bit-identical), re-promoted after the heal, and sustained
    ``selfheal_goodput_floor`` x the healthy path's goodput."""
    errors = []
    sh = data.get("selfheal")
    if not sh:
        return ["serve: selfheal scenarios missing from BENCH_serve.json "
                "(stale artifact? rerun benchmarks)"]
    r = sh.get("restart", {})
    if not r.get("restarts", 0) >= 1:
        errors.append("selfheal/restart: restarts == 0 — the kill never "
                      "exercised supervision")
    if not r.get("requeued", 0) >= 1:
        errors.append("selfheal/restart: nothing requeued across the "
                      "restart (at-most-once handoff inert)")
    if r.get("survival") != 1.0 or r.get("hung", 1) != 0:
        errors.append(
            f"selfheal/restart: survival {r.get('survival')} with "
            f"{r.get('hung')} hung futures (want 1.0 with 0) — the "
            "restart dropped or stranded requests")
    if not r.get("accounting_ok", False):
        errors.append("selfheal/restart: accounting identity broke across "
                      "the supervised restart")
    rl = sh.get("reload", {})
    if not rl.get("corrupt_typed", False):
        errors.append("selfheal/reload: corrupt checkpoint did not fail "
                      "with typed CorruptCheckpointError")
    if not rl.get("old_plan_served", False):
        errors.append("selfheal/reload: old plan not serving bit-identical "
                      "after the failed reload")
    if not rl.get("fallback_recovered", False):
        errors.append("selfheal/reload: step walk-back did not recover a "
                      "verifiable checkpoint")
    d = sh.get("degraded", {})
    if d.get("survival") != 1.0:
        errors.append(f"selfheal/degraded: survival {d.get('survival')} != "
                      "1.0 — demotion dropped requests")
    if not d.get("demoted_exact", False) \
            or not d.get("innocents_bit_identical", False):
        errors.append("selfheal/degraded: demotion was not isolated to "
                      "exactly the faulty bucket with innocent buckets "
                      "bit-identical")
    if not d.get("repromoted", False):
        errors.append("selfheal/degraded: recovery probe never re-promoted "
                      "the healed bucket")
    floor = _BASE["selfheal_goodput_floor"] * d.get("healthy_sps", 0)
    if d.get("degraded_sps", 0) < floor:
        errors.append(
            f"selfheal/degraded: goodput {d.get('degraded_sps')} < "
            f"{_BASE['selfheal_goodput_floor']} x healthy "
            f"{d.get('healthy_sps')} samples/s — fallback collapsed")
    return errors


def check_lm() -> list:
    errors = []
    path = ROOT / "BENCH_lm.json"
    if not path.exists():
        return [f"{path.name} missing (run `python -m benchmarks.run --smoke`)"]
    data = json.loads(path.read_text())
    errors += schema_errors(path.name, data)
    if errors:
        return errors
    noise = data.get("noise_frac", {})
    # the §13 contract: compressed projections must not lose to the dense
    # matmul the pre-PR-8 fallback silently ran (nnz/bz of the MACs)
    margin_base = _BASE["lm_wall_margin"]
    cap = _BASE["lm_noise_cap"]
    for g in data.get("gemms", []):
        nz = noise.get(g["name"])
        nz = nz if isinstance(nz, (int, float)) and math.isfinite(nz) else cap
        margin = margin_base * (1.0 + min(max(nz, 0.0), cap))
        if g["compressed_us"] > g["dense_us"] * margin:
            errors.append(
                f"lm/{g['name']}: compressed {g['compressed_us']:.0f}us > "
                f"dense {g['dense_us']:.0f}us x margin {margin:.2f} "
                f"(= lm_wall_margin {margin_base} widened by noise {nz})"
            )
    plan = data.get("plan") or {}
    if not plan.get("bit_identical", False):
        errors.append("lm/plan: frozen plan not bit-identical to the "
                      "unplanned forward")
    nz = noise.get("plan")
    nz = nz if isinstance(nz, (int, float)) and math.isfinite(nz) else cap
    margin = margin_base * (1.0 + min(max(nz, 0.0), cap))
    if plan and plan["plan_us"] > plan["unplanned_us"] * margin:
        errors.append(
            f"lm/plan: plan {plan['plan_us']:.0f}us > unplanned "
            f"{plan['unplanned_us']:.0f}us x margin {margin:.2f}"
        )
    return errors


def main() -> int:
    errors = check_fused() + check_autotune() + check_serve() \
        + check_chaos() + check_lm()
    for e in errors:
        print(f"REGRESSION: {e}", file=sys.stderr)
    if not errors:
        print("benchmark artifacts: no regressions")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
