"""Fig 9/10 reproduction: iso-throughput (~4 TOPS nominal) design space.

Enumerates A x B x C _ M x N arrays with {dense, fixed-DBB, VDBB} x
{IM2COL on/off}, computes normalized power & area vs the 1x1x1_32x64
TPU-like baseline, and checks the paper's three groupings:
  (1) dense STA configs   — top right (no sparsity benefit)
  (2) fixed-DBB designs   — >2x area reduction vs baseline
  (3) VDBB + IM2C designs — pareto-front bottom-left (>2.5x area, >2x power)
"""
import time

from repro.core.energy_model import STAConfig, fmt_for_sparsity

MODEL_FMT = fmt_for_sparsity(0.625)  # 3/8 DBB as in Fig 9
ACT_SP = 0.5


def candidates():
    out = []
    # baseline systolic array
    out.append(("1x1x1_32x64", STAConfig(1, 1, 1, 32, 64, mode="dense", im2col=False)))
    out.append(("1x1x1_32x64_IM2C", STAConfig(1, 1, 1, 32, 64, mode="dense", im2col=True)))
    # dense STA variants (iso ~2048 MACs)
    out.append(("2x8x2_8x8", STAConfig(2, 8, 2, 8, 8, mode="dense", im2col=False)))
    out.append(("4x8x4_4x4", STAConfig(4, 8, 4, 4, 4, mode="dense", im2col=False)))
    # fixed 4/8 DBB (2048 executed MACs)
    out.append(("4x8x4dbb_4x8_IM2C", STAConfig(4, 8, 4, 4, 8, mode="dbb", hw_nnz=4, im2col=True)))
    out.append(("2x8x4dbb_8x8", STAConfig(2, 8, 4, 8, 8, mode="dbb", hw_nnz=4, im2col=False)))
    # VDBB (2048 MAC-equivalents)
    out.append(("4x8x8_4x8_VDBB_IM2C", STAConfig(4, 8, 8, 4, 8, mode="vdbb", im2col=True)))
    out.append(("4x8x4_8x8_VDBB_IM2C", STAConfig(4, 8, 4, 8, 8, mode="vdbb", im2col=True)))
    out.append(("4x8x8_4x8_VDBB", STAConfig(4, 8, 8, 4, 8, mode="vdbb", im2col=False)))
    return out


def run(report):
    t0 = time.time()
    base = STAConfig(1, 1, 1, 32, 64, mode="dense", im2col=False)
    base_p = base.power_mw(MODEL_FMT, ACT_SP)
    base_a = base.area_mm2()
    rows = {}
    for name, d in candidates():
        # effective power/area per effective op (Fig 10 axes)
        s = d.speedup(MODEL_FMT)
        rows[name] = (
            d.power_mw(MODEL_FMT, ACT_SP) / base_p / s,
            d.area_mm2() / base_a / s,
            d.peak_tops(),
        )
    # groupings
    best = rows["4x8x8_4x8_VDBB_IM2C"]
    assert best[1] < 1 / 2.5, f"pareto VDBB area not >2.5x better: {best}"
    assert best[0] < 1 / 2.0, f"pareto VDBB power not >2x better: {best}"
    dbb = rows["4x8x4dbb_4x8_IM2C"]
    assert dbb[1] < 0.5, f"fixed DBB area not >2x better: {dbb}"
    for name in ("2x8x2_8x8", "4x8x4_4x4"):
        assert rows[name][0] > best[0] and rows[name][1] > best[1], (
            "dense STA should be dominated by VDBB designs"
        )
    us = (time.time() - t0) * 1e6
    for name, (p, a, tops) in sorted(rows.items(), key=lambda kv: kv[1][0]):
        report(f"design_space/{name}", us / len(rows),
               f"rel_power {p:.3f} rel_area {a:.3f} peak {tops:.1f} TOPS")
