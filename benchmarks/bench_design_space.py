"""Fig 9/10 reproduction: iso-throughput (~4 TOPS nominal) design space.

Enumerates A x B x C _ M x N arrays with {dense, fixed-DBB, VDBB} x
{IM2COL on/off}, computes normalized power & area vs the 1x1x1_32x64
TPU-like baseline, and checks the paper's three groupings:
  (1) dense STA configs   — top right (no sparsity benefit)
  (2) fixed-DBB designs   — >2x area reduction vs baseline
  (3) VDBB + IM2C designs — pareto-front bottom-left (>2.5x area, >2x power)

The paper draws the figure at an assumed 50% activation sparsity; the
corrected grid at the *measured* activation sparsity of a real forward
pass (DESIGN.md §7) is emitted to ``results/design_space.md``.
"""
import pathlib
import time

from repro.core.energy_model import STAConfig, fmt_for_sparsity

RESULTS = pathlib.Path(__file__).resolve().parent / "results"

MODEL_FMT = fmt_for_sparsity(0.625)  # 3/8 DBB as in Fig 9
ACT_SP = 0.5


def candidates():
    out = []
    # baseline systolic array
    out.append(("1x1x1_32x64", STAConfig(1, 1, 1, 32, 64, mode="dense", im2col=False)))
    out.append(("1x1x1_32x64_IM2C", STAConfig(1, 1, 1, 32, 64, mode="dense", im2col=True)))
    # dense STA variants (iso ~2048 MACs)
    out.append(("2x8x2_8x8", STAConfig(2, 8, 2, 8, 8, mode="dense", im2col=False)))
    out.append(("4x8x4_4x4", STAConfig(4, 8, 4, 4, 4, mode="dense", im2col=False)))
    # fixed 4/8 DBB (2048 executed MACs)
    out.append(("4x8x4dbb_4x8_IM2C", STAConfig(4, 8, 4, 4, 8, mode="dbb", hw_nnz=4, im2col=True)))
    out.append(("2x8x4dbb_8x8", STAConfig(2, 8, 4, 8, 8, mode="dbb", hw_nnz=4, im2col=False)))
    # VDBB (2048 MAC-equivalents)
    out.append(("4x8x8_4x8_VDBB_IM2C", STAConfig(4, 8, 8, 4, 8, mode="vdbb", im2col=True)))
    out.append(("4x8x4_8x8_VDBB_IM2C", STAConfig(4, 8, 4, 8, 8, mode="vdbb", im2col=True)))
    out.append(("4x8x8_4x8_VDBB", STAConfig(4, 8, 8, 4, 8, mode="vdbb", im2col=False)))
    return out


def grid(act_sp):
    """Normalized (rel power, rel area, peak TOPS) per design at one
    activation sparsity (scalar or measured ActStats) — the Fig 10 axes."""
    base = STAConfig(1, 1, 1, 32, 64, mode="dense", im2col=False)
    base_p = base.power_mw(MODEL_FMT, act_sp)
    base_a = base.area_mm2()
    rows = {}
    for name, d in candidates():
        # effective power/area per effective op (Fig 10 axes)
        s = d.speedup(MODEL_FMT)
        rows[name] = (
            d.power_mw(MODEL_FMT, act_sp) / base_p / s,
            d.area_mm2() / base_a / s,
            d.peak_tops(),
        )
    return rows


def measured_grid(report):
    """Re-draw the Fig 9/10 grid at the measured activation sparsity of a
    real forward pass and emit assumed-vs-measured to results/."""
    from benchmarks.bench_sparsity_scaling import measured_cnn_layers
    from repro.core.act_sparsity import combine

    cfg, stats, _ = measured_cnn_layers()
    comb = combine(list(stats), name=cfg.name)
    assumed, measured = grid(ACT_SP), grid(comb)
    lines = [
        "# Fig 9/10 design space: assumed vs measured activation sparsity\n\n",
        f"3/8 DBB weights; measured activations from `{cfg.name}` "
        f"(MAC-weighted zero frac {comb.sparsity:.3f} vs the paper's "
        f"{ACT_SP}). Power/area normalized per effective op vs the "
        "1x1x1_32x64 baseline *at the same activation sparsity*. "
        "Regenerate: `python -m benchmarks.run --only design_space`.\n\n",
        "| design | rel power (50% act) | rel power (measured) | delta | "
        "rel area | peak TOPS |\n|---|---|---|---|---|---|\n",
    ]
    for name in sorted(assumed, key=lambda n: assumed[n][0]):
        pa, ar, tops = assumed[name]
        pm = measured[name][0]
        lines.append(
            f"| {name} | {pa:.3f} | {pm:.3f} | {pm / pa - 1:+.1%} "
            f"| {ar:.3f} | {tops:.1f} |\n"
        )
    RESULTS.mkdir(parents=True, exist_ok=True)
    (RESULTS / "design_space.md").write_text("".join(lines))
    # groupings must be stable under the measured correction
    best_m = measured["4x8x8_4x8_VDBB_IM2C"]
    assert best_m[0] < 1 / 2.0 and best_m[1] < 1 / 2.5, (
        f"measured act sparsity broke the pareto grouping: {best_m}"
    )
    report(
        "design_space/measured_act", 0.0,
        f"act {comb.sparsity:.3f} vs {ACT_SP}: pareto rel power "
        f"{assumed['4x8x8_4x8_VDBB_IM2C'][0]:.3f} -> {best_m[0]:.3f} "
        "-> results/design_space.md",
    )


def run(report):
    t0 = time.time()
    rows = grid(ACT_SP)
    # groupings
    best = rows["4x8x8_4x8_VDBB_IM2C"]
    assert best[1] < 1 / 2.5, f"pareto VDBB area not >2.5x better: {best}"
    assert best[0] < 1 / 2.0, f"pareto VDBB power not >2x better: {best}"
    dbb = rows["4x8x4dbb_4x8_IM2C"]
    assert dbb[1] < 0.5, f"fixed DBB area not >2x better: {dbb}"
    for name in ("2x8x2_8x8", "4x8x4_4x4"):
        assert rows[name][0] > best[0] and rows[name][1] > best[1], (
            "dense STA should be dominated by VDBB designs"
        )
    us = (time.time() - t0) * 1e6
    for name, (p, a, tops) in sorted(rows.items(), key=lambda kv: kv[1][0]):
        report(f"design_space/{name}", us / len(rows),
               f"rel_power {p:.3f} rel_area {a:.3f} peak {tops:.1f} TOPS")
    measured_grid(report)
