"""Table I/II analogue: DBB pruning preserves task accuracy.

Offline container -> synthetic separable classification task (random conv
feature planted targets), a small conv+MLP net trained with the paper's
recipe: dense pretrain -> progressive magnitude DBB pruning -> fine-tune.
Reproduces the paper's two findings:
  Table I: DBB at 2/8..4/8 costs ~1% accuracy vs dense.
  Table II: at equal compression, larger blocks lose less accuracy
            (1/4 worse than 2/8 worse than 4/16 — monotone in BZ).
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.vdbb import DBBFormat, dbb_prune


def make_task(key, n=4096, d=64, classes=10):
    """Synthetic task whose ground truth is SPARSE (few important inputs),
    so DBB block placement binds: with nnz=1 per block of 4, two important
    inputs landing in one block can't both be kept — the mechanism behind
    the paper's Table II block-size effect."""
    k1, k3 = jax.random.split(key, 2)
    x = jax.random.normal(k1, (n, d))
    kw = jax.random.PRNGKey(42)
    wtrue = jax.random.normal(kw, (d, classes))
    keep = jax.random.bernoulli(jax.random.PRNGKey(43), 0.25, (d, 1))
    wtrue = wtrue * keep  # ~25% informative input dims, clustered at random
    y = jnp.argmax(x @ wtrue + 0.3 * jax.random.normal(k3, (n, classes)), -1)
    return x, y


def init_net(key, d=64, h=128, classes=10):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w1": jax.random.normal(k1, (d, h)) / jnp.sqrt(d),
        "w2": jax.random.normal(k2, (h, h)) / jnp.sqrt(h),
        "w3": jax.random.normal(k3, (h, classes)) / jnp.sqrt(h),
    }


def fwd(p, x):
    h = jax.nn.relu(x @ p["w1"])
    h = jax.nn.relu(h @ p["w2"])
    return h @ p["w3"]


def loss_fn(p, x, y):
    lg = fwd(p, x)
    return -jnp.mean(jax.nn.log_softmax(lg)[jnp.arange(y.size), y])


def accuracy(p, x, y):
    return float(jnp.mean(jnp.argmax(fwd(p, x), -1) == y))


@jax.jit
def sgd(p, x, y, lr=0.3):
    g = jax.grad(loss_fn)(p, x, y)
    return jax.tree_util.tree_map(lambda w, gw: w - lr * gw, p, g)


def train(p, x, y, steps, fmt=None, prune_from=0):
    for s in range(steps):
        i = (s * 256) % (x.shape[0] - 256)
        p = sgd(p, x[i : i + 256], y[i : i + 256])
        if fmt is not None and s >= prune_from:
            p = {k: dbb_prune(w, fmt) if k != "w3" else w for k, w in p.items()}
    return p


def run(report):
    t0 = time.time()
    key = jax.random.PRNGKey(0)
    xtr, ytr = make_task(key)
    xte, yte = make_task(jax.random.PRNGKey(1))
    dense = train(init_net(jax.random.PRNGKey(2)), xtr, ytr, 300)
    acc_dense = accuracy(dense, xte, yte)

    # Table I analogue: accuracy at decreasing density (prune + finetune)
    table1 = {}
    for nnz in (4, 3, 2):
        p = train(dict(dense), xtr, ytr, 200, fmt=DBBFormat(8, nnz), prune_from=0)
        table1[f"{nnz}/8"] = accuracy(p, xte, yte)
        assert table1[f"{nnz}/8"] > acc_dense - 0.05, (nnz, table1, acc_dense)

    # Table II analogue: same compression (25% density), BZ in {4, 8, 16},
    # averaged over 3 pruning/finetune seeds to get above task noise.
    table2 = {}
    for bz, nnz in ((4, 1), (8, 2), (16, 4)):
        accs = []
        for seed in range(3):
            p0 = train(init_net(jax.random.PRNGKey(10 + seed)), xtr, ytr, 300)
            p = train(p0, xtr, ytr, 200, fmt=DBBFormat(bz, nnz), prune_from=0)
            accs.append(accuracy(p, xte, yte))
        table2[f"{nnz}/{bz}"] = float(np.mean(accs))
    assert table2["4/16"] >= table2["1/4"] - 0.015, (
        f"larger blocks should not be worse at equal ratio: {table2}"
    )
    us = (time.time() - t0) * 1e6
    report("dbb_pruning/dense", us / 7, f"acc {acc_dense:.3f}")
    for k, v in table1.items():
        report(f"dbb_pruning/table1_{k}", us / 7, f"acc {v:.3f} (Δ {v-acc_dense:+.3f})")
    for k, v in table2.items():
        report(f"dbb_pruning/table2_{k}", us / 7, f"acc {v:.3f}")
