"""LM VDBB datapath wall time + plan parity (DESIGN.md §13/§12).

Three measurements, written machine-readable to ``BENCH_lm.json``:

1. **compressed vs dense GEMM wall time** — the transformer projection
   shapes (attention proj and MLP up, qwen2-like K:N ratios) through
   ``dbb_matmul_gather_ref`` vs the dense ``x @ W`` it replaced. This is
   the gate: before PR 8 ``apply_linear`` silently densified compressed
   LM weights, so the compressed path MUST now be no slower than dense
   (it computes nnz/bz of the MACs).
2. **int8 vs fp32 GEMM wall time** — the same shapes through
   ``quant_matmul_gather_ref``. Report-only: XLA:CPU has no native int8
   MXU path so int8 loses on this backend; the number is recorded for
   the trajectory, not gated. The gather-form vs decode-form quantized
   GEMM is asserted bit-identical (integer sums are order-independent).
3. **plan vs unplanned LM prefill** — the registered ``qwen2-tiny``
   config, compressed + INT8-calibrated, served through a frozen
   ``LM.plan()`` vs the jitted unplanned forward, asserted bit-identical
   (gated in check_regression).

Measurement policy (§12): paired claims sampled interleaved, reduced
with ``min`` over generous reps; ``noise_frac`` persisted so
``check_regression.py`` widens margins on noisy hosts.
"""
import json
import pathlib

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.timing import interleaved_samples_us, noise_frac
from repro.core import quant
from repro.core.vdbb import DBBFormat, dbb_decode, dbb_encode, \
    dbb_matmul_gather_ref

OUT_PATH = pathlib.Path(__file__).resolve().parents[1] / "BENCH_lm.json"

WARMUP = 2
REPS = 25
STAT = "min"

# (label, m, k, n): qwen2-like projection shapes at CPU-benchable size —
# attention out-proj (square) and MLP up-proj (K:N = 1:2)
GEMM_SHAPES = (
    ("attn_proj", 256, 512, 512),
    ("mlp_up", 256, 512, 1024),
)


def _paired(fn_a, fn_b):
    """min-of-k interleaved wall times + the batch noise estimate."""
    sa, sb = interleaved_samples_us(fn_a, fn_b, warmup=WARMUP, reps=REPS)
    return min(sa), min(sb), max(noise_frac(sa), noise_frac(sb))


def run(report):
    results = {
        "gemms": [], "plan": {}, "noise_frac": {},
        "harness": {"stat": STAT, "reps": REPS, "warmup": WARMUP,
                    "interleaved": True, "backend": jax.default_backend()},
    }
    fmt = DBBFormat(8, 3, "matrix")

    # --- 1/2. projection GEMMs: dense vs compressed vs int8 --------------
    for label, m, k, n in GEMM_SHAPES:
        kx, kw = jax.random.split(jax.random.PRNGKey(0))
        x = jax.random.normal(kx, (m, k), jnp.float32)
        dw = dbb_encode(jax.random.normal(kw, (k, n), jnp.float32),
                        fmt, prune=True)
        wd = dbb_decode(dw)  # dense-with-zeros: what the old path matmul'd
        qw = quant.quantize_dbb(dw)
        s_a = quant.dynamic_act_scale(x)
        xq = quant.quantize(x, s_a)

        # gather-form == decode-form quantized GEMM, bitwise (int32 sums)
        np.testing.assert_array_equal(
            np.asarray(quant.quant_matmul_gather_ref(xq, qw, s_a)),
            np.asarray(quant.quant_matmul_ref(xq, qw, s_a)),
        )

        dense = jax.jit(lambda x, wd=wd: x @ wd)
        comp = jax.jit(lambda x, dw=dw: dbb_matmul_gather_ref(x, dw))
        qgemm = jax.jit(
            lambda xq, qw=qw, s=s_a: quant.quant_matmul_gather_ref(xq, qw, s))
        t_d, t_c, nz = _paired(lambda: dense(x), lambda: comp(x))
        t_q, _, nz_q = _paired(lambda: qgemm(xq), lambda: dense(x))
        results["gemms"].append(dict(
            name=label, m=m, k=k, n=n, nnz=fmt.nnz, bz=fmt.bz,
            dense_us=t_d, compressed_us=t_c, int8_us=t_q,
        ))
        results["noise_frac"][label] = round(max(nz, nz_q), 4)
        report(f"lm/{label}", t_c,
               f"dense {t_d:.0f}us int8 {t_q:.0f}us (noise {nz:.0%}; "
               f"{m}x{k}x{n}, nnz {fmt.nnz}/{fmt.bz})")

    # --- 3. qwen2-tiny prefill: frozen plan vs unplanned forward ---------
    from repro.configs import get_config
    from repro.models.model import LM

    cfg = get_config("qwen2-tiny")
    model = LM(cfg)
    batch, seq = 2, 32
    params = model.compress(model.constrain(model.init(jax.random.PRNGKey(0))))
    tokens = jax.random.randint(
        jax.random.PRNGKey(1), (batch, seq), 0, cfg.vocab_size)
    _, stats = model.forward(
        params, {"tokens": tokens}, collect_act_stats=True)
    qparams = model.quantize(params, stats)

    unplanned = jax.jit(lambda t: model.forward(qparams, {"tokens": t}))
    plan = model.plan(qparams, batch=batch, seq=seq, tune="off")
    bit = bool((plan(tokens) == unplanned(tokens)).all())
    assert bit, "frozen plan diverged from the unplanned forward"
    t_p, t_u, nz = _paired(lambda: plan.serve(tokens), lambda: unplanned(tokens))
    results["plan"] = {
        "model": cfg.name, "batch": batch, "seq": seq,
        "stages": len(plan.layers), "bit_identical": bit,
        "plan_us": t_p, "unplanned_us": t_u,
    }
    results["noise_frac"]["plan"] = round(nz, 4)
    report("lm/plan_prefill", t_p,
           f"unplanned {t_u:.0f}us (noise {nz:.0%}), bit-identical, "
           f"{len(plan.layers)} stages, {cfg.name} {batch}x{seq}")

    OUT_PATH.write_text(json.dumps(results, indent=2))
    report("lm/json", 0.0, f"wrote {OUT_PATH.name}")


if __name__ == "__main__":
    run(lambda name, us, derived="": print(f"{name},{us:.1f},{derived}"))
