"""IM2COL bandwidth-magnifier reproduction (Fig 8).

The paper's point: if IM2COL happens *before* the memory (im2col tensor
stored, datapath streams it), the datapath consumes kh*kw x the activation
bytes; the hardware unit moves the expansion *after* the memory so only
the raw tile is ever read. We measure exactly that boundary: the bytes the
compiled datapath program reads as *inputs*:

  A) GEMM over a precomputed im2col tensor  -> reads 9*H*W*C
  B) fused Pallas im2col+GEMM kernel        -> reads (H+2)(W+2)C once

and verify A == 9x B (minus halo), plus numerics A == B == lax.conv.
"""
import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.timing import median_time_us
from repro.kernels import ops, ref


def run(report):
    n, h, w, c, f = 2, 32, 32, 64, 128
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (n, h, w, c), jnp.float32)
    wk = jax.random.normal(key, (3, 3, c, f), jnp.float32)

    # A) datapath consuming a pre-expanded im2col tensor from memory
    cols = ref.im2col_explicit(x, 3, 3)  # (N,H,W,9C) — the stored expansion

    def gemm(cols, wk):
        return cols.reshape(-1, 9 * c) @ wk.reshape(9 * c, f)

    ca = jax.jit(gemm).lower(cols, wk).compile()
    act_bytes_a = cols.size * 4

    # B) fused kernel: raw tile in, expansion only in VMEM
    act_bytes_b = n * (h + 2) * (w + 2) * c * 4
    magnification = act_bytes_a / act_bytes_b
    assert magnification > 7.5, magnification  # ~9x minus halo overhead

    ya = np.asarray(gemm(cols, wk)).reshape(n, h, w, f)
    yb = np.asarray(ops.fused_im2col_conv(x, wk, bf=f, interpret=True))
    yr = np.asarray(ref.conv_lax_ref(x, wk))
    np.testing.assert_allclose(ya, yr, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(yb, yr, rtol=2e-4, atol=2e-4)

    ta = median_time_us(jax.jit(gemm), cols, wk, reps=10)
    report(
        "im2col/pre_expanded_gemm", ta,
        f"datapath reads {act_bytes_a/1e6:.1f}MB activations (stored im2col)",
    )
    # interpret-mode (CPU validation) timing
    tb = median_time_us(
        lambda: ops.fused_im2col_conv(x, wk, bf=f, interpret=True), reps=3
    )
    report(
        "im2col/fused_late_kernel", tb,
        f"datapath reads {act_bytes_b/1e6:.1f}MB ({magnification:.2f}x magnification; "
        "paper: 3x avg line-buffer, 9x full-tile; time is interpret-mode)",
    )
