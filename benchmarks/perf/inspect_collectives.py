import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import sys, re, dataclasses, collections
import jax
from repro.configs import get_config
from repro.launch import dryrun as dr
from repro.launch.mesh import make_production_mesh
from repro.sharding.rules import make_rules

arch = sys.argv[1] if len(sys.argv) > 1 else "qwen2-72b"
shape = sys.argv[2] if len(sys.argv) > 2 else "train_4k"
groups = int(sys.argv[3]) if len(sys.argv) > 3 else 1
sparsity = float(sys.argv[4]) if len(sys.argv) > 4 else 0.625

cfg = get_config(arch, sparsity=sparsity)
cfg = dataclasses.replace(cfg, num_layers=groups * len(cfg.pattern), scan_layers=False)
mesh = make_production_mesh()
mode = {"train":"train","prefill":"prefill","decode":"decode"}[dr.SHAPES[shape]["kind"]]
rules = make_rules(cfg, tp=16, mode=mode)
compiled = dr._lower(cfg, shape, mesh, rules)
txt = compiled.as_text()
rows = []
for line in txt.splitlines():
    s = line.strip()
    m = re.match(r"%?[\w.\-]+ = (.*?) (\w[\w\-]*)\(", s)
    if not m: continue
    op = m.group(2)
    for c in dr.COLLECTIVES:
        if op == c or op.startswith(c + "-"):
            b = dr._shape_bytes(m.group(1))
            beq = dr._shape_bytes(m.group(1), tpu_equiv=True)
            meta = re.search(r'op_name="([^"]+)"', s)
            rows.append((b, op, ((meta.group(1) if meta else "?") + " ||| " + m.group(1)[:120])[:260], beq))
            break
rows.sort(key=lambda r: r[0], reverse=True)
total = sum(r[0] for r in rows)
teq = sum(r[3] for r in rows)
print(f"TOTAL collective bytes/device: {total/1e9:.1f} GB raw | {teq/1e9:.1f} GB tpu-equiv | {len(rows)} ops")
agg = collections.Counter()
for b, op, name, _ in rows:
    key = re.sub(r"\d+", "#", name.split("/")[-1])[:60] + " :: " + op
    agg[key] += b
for k, v in agg.most_common(18):
    print(f"  {v/1e9:8.2f} GB  {k}")
print("--- top 12 individual ops ---")
for b, op, name, _ in rows[:12]:
    print(f"  {b/1e9:8.2f} GB  {op:20s} {name}")
