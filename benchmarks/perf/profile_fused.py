"""Attribute fused-path wall time to its components (DESIGN.md §12).

``python -m benchmarks.perf.profile_fused [reps]`` — the diagnosis
harness behind the measured-wall-time gates. Four sections, written
machine-readable to ``PROF_fused.json``:

1. **epilogue ablation ladder** — one quantized conv layer measured at
   every epilogue depth (bare kernel → +bias → +bias+relu → full fused
   requant) plus the unfused kernel + standalone-XLA-epilogue program,
   each timed *interleaved against the bare kernel* (min-of-k, so the
   deltas are drift-free). If the fused flush serialized the epilogue,
   it would show here as a ladder step far above the XLA cost of the
   same op; profiling on CPU shows the steps are noise-level — the
   PR-6-era "fused slower than unfused" artifact was measurement
   methodology, not kernel structure.
2. **compiled-HLO breakdown** — per-opcode instruction counts, fusion
   and custom-call (≈ kernel launch) totals, and cost-analysis
   bytes/flops for the fused vs unfused layer programs: *where* the
   wall-time delta comes from without a hardware profiler.
3. **per-stage chain attribution** — the int8-resident smoke-CNN serving
   chain (pallas mode), each frozen stage closure timed in isolation on
   its actual intermediate input, vs the end-to-end chain: which layer
   dominates, and how much dispatch overhead the single-jit chain saves
   over the sum of stages.
4. **pad_tile audit** — for every conv/matmul shape the chain launches,
   whether the ops-level pad-and-slice escape hatch would actually pad
   (on the evenly-divisible smoke shapes it must not; ragged-shape
   correctness is covered by tests/test_fused_epilogue.py).
"""
import dataclasses
import json
import pathlib
import sys

import jax
import jax.numpy as jnp

from benchmarks.timing import interleaved_samples_us, noise_frac
from repro.core import quant
from repro.core.vdbb import DBBFormat, dbb_encode_conv
from repro.kernels import ops
from repro.kernels.core import pad_tile, pick_tile
from repro.xla_utils import hlo_op_breakdown

OUT_PATH = pathlib.Path(__file__).resolve().parents[2] / "PROF_fused.json"

WARMUP = 2
REPS = 15


def _vs_base(base_fn, fn, reps):
    """(base_us, fn_us, noise) — min-of-k, interleaved against the base."""
    sb, sf = interleaved_samples_us(base_fn, fn, warmup=WARMUP, reps=reps)
    return min(sb), min(sf), max(noise_frac(sb), noise_frac(sf))


def ablation_ladder(reps):
    """Section 1+2: one quantized conv layer at every epilogue depth."""
    n, h, w, c, f = 2, 16, 16, 32, 64
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 3)
    x = jax.random.normal(k1, (n, h, w, c))
    w4 = jax.random.normal(k2, (3, 3, c, f))
    b = jax.random.normal(k3, (f,))
    fmt = DBBFormat(8, 3, "matrix")
    qw = quant.quantize_dbb(dbb_encode_conv(w4, fmt, prune=True))
    s_a = quant.dynamic_act_scale(x)
    out_s = jnp.float32(0.05)
    xq = quant.quantize(x, s_a)

    def conv(**kw):
        return lambda xq: ops.quant_conv(xq, qw, 3, 3, s_a, bf=f,
                                         interpret=True, **kw)

    variants = {
        "kernel_only": conv(),
        "fused_bias": conv(bias=b),
        "fused_bias_relu": conv(bias=b, relu=True),
        "fused_full": conv(bias=b, relu=True, out_scale=out_s),
    }
    kernel = conv()

    def unfused_full(xq):
        return quant.quantize(jax.nn.relu(kernel(xq) + b), out_s)

    variants["unfused_full"] = unfused_full

    base = jax.jit(variants["kernel_only"])
    jax.block_until_ready(base(xq))
    ladder = {}
    for name, fn in variants.items():
        jf = jax.jit(fn)
        t_base, t_fn, nz = _vs_base(lambda: base(xq), lambda: jf(xq), reps)
        ladder[name] = {
            "us": t_fn,
            "delta_vs_kernel_us": t_fn - t_base,
            "noise_frac": round(nz, 4),
        }
    hlo = {
        label: hlo_op_breakdown(fn, xq)
        for label, fn in (("fused_full", variants["fused_full"]),
                          ("unfused_full", unfused_full))
    }
    return ladder, hlo


def chain_attribution(reps):
    """Section 3: per-stage wall time of the int8-resident serving chain."""
    from repro.configs import smoke_cnn_config
    from repro.models.cnn import SparseCNN

    cfg = dataclasses.replace(
        smoke_cnn_config("sparse-cnn-tiny", sparsity=0.625),
        kernel_mode="pallas")
    model = SparseCNN(cfg)
    params = model.compress(model.constrain(model.init(jax.random.PRNGKey(0))))
    xb = jax.random.normal(
        jax.random.PRNGKey(1), (4, cfg.image_size, cfg.image_size, cfg.in_channels))
    _, stats = model.apply(params, xb, collect_act_stats=True)
    qparams = model.quantize(params, stats)
    plan = model.plan(qparams, batch=4, tune="off")

    e2e = jax.jit(plan.serve)
    jax.block_until_ready(e2e(xb))
    stages = []
    x = xb
    for lp in plan.layers:
        run = jax.jit(lp.run)
        y = jax.block_until_ready(run(x))
        se, sr = interleaved_samples_us(lambda: e2e(xb), lambda: run(x),
                                        warmup=1, reps=reps)
        stages.append({
            "name": lp.name, "kind": lp.kind, "tiles": dict(lp.tiles),
            "us": min(sr), "in_dtype": str(x.dtype), "out_dtype": str(y.dtype),
            "noise_frac": round(max(noise_frac(se), noise_frac(sr)), 4),
        })
        x = y
    t_e2e = min(interleaved_samples_us(lambda: e2e(xb), lambda: e2e(xb),
                                       warmup=1, reps=reps)[0])
    return {
        "stages": stages,
        "e2e_us": t_e2e,
        "sum_of_stages_us": sum(s["us"] for s in stages),
    }


def pad_audit():
    """Section 4: would pad_tile actually pad on the chain's shapes?"""
    # (dim, requested-or-None, default) for the launch dims the smoke chain
    # resolves through the ops-level pad-and-slice entry points
    cases = [
        ("conv_bf_32", 32, None, 128),
        ("conv_bf_64", 64, None, 128),
        ("matmul_bm_4", 4, None, 128),   # head GEMM rows = batch
        ("matmul_bn_10", 10, None, 128),  # head GEMM cols = classes
    ]
    out = []
    for name, dim, tile, default in cases:
        t, padded = pad_tile(dim, tile, default)
        out.append({
            "case": name, "dim": dim, "tile": t, "padded_dim": padded,
            "pads": padded != dim, "pick_tile": pick_tile(dim, default),
        })
    return out


def main(reps: int = REPS) -> None:
    ladder, hlo = ablation_ladder(reps)
    chain = chain_attribution(reps)
    pads = pad_audit()
    results = {
        "harness": {"stat": "min", "reps": reps, "warmup": WARMUP,
                    "interleaved": True, "backend": jax.default_backend()},
        "ablation_us": ladder,
        "hlo": hlo,
        "chain": chain,
        "pad_audit": pads,
    }
    OUT_PATH.write_text(json.dumps(results, indent=2))

    print(f"== epilogue ablation (min of {reps}, interleaved vs bare kernel) ==")
    for name, r in ladder.items():
        print(f"  {name:18s} {r['us']:9.1f}us  (+{r['delta_vs_kernel_us']:7.1f}us"
              f" vs kernel, noise {r['noise_frac']:.0%})")
    print("== compiled HLO ==")
    for label, h in hlo.items():
        print(f"  {label:14s} instrs={h['n_instructions']:4d} "
              f"fusions={h['n_fusions']:3d} custom_calls={h['n_custom_calls']}"
              f" bytes={h['bytes_accessed']} flops={h['flops']}")
    print("== int8-resident chain, per stage ==")
    for s in chain["stages"]:
        print(f"  {s['name']:8s} {s['kind']:6s} {s['us']:9.1f}us  "
              f"{s['in_dtype']}->{s['out_dtype']}  tiles={s['tiles']}")
    print(f"  e2e {chain['e2e_us']:.1f}us vs sum-of-stages "
          f"{chain['sum_of_stages_us']:.1f}us")
    print("== pad_tile audit ==")
    for p in pads:
        mark = "PADS" if p["pads"] else "exact"
        print(f"  {p['case']:14s} dim={p['dim']:4d} tile={p['tile']:4d} "
              f"padded={p['padded_dim']:4d}  {mark}")
    print(f"wrote {OUT_PATH.name}")


if __name__ == "__main__":
    main(int(sys.argv[1]) if len(sys.argv) > 1 else REPS)
