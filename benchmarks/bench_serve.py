"""Continuous-batching serving tier under load (DESIGN.md §11).

Drives the §11 queue → bucketer → frozen-plan pipeline with a load
generator and writes ``BENCH_serve.json`` (a CI artifact gated by
``benchmarks/check_regression.py``). Six claims, all measured:

1. **Bit-exactness**: bucketed/padded serving of every ragged batch size
   (including one larger than the biggest bucket, which chunks) equals
   per-request ``plan.serve`` exactly.
2. **Wall time is the contract** (first slice of the ROADMAP item): the
   frozen bucket plan is not slower than the *jitted-once* unplanned
   ``model.apply`` beyond the committed noise margin — a fair baseline,
   unlike comparing against an unjitted per-call lambda.
3. **Zero retraces after warmup**: sustained variable-batch Poisson and
   burst traffic dispatches only pre-compiled bucket plans; the plans'
   own trace counters must not move during the load run.
4. **Latency under load**: p50/p99 request latency (arrival → result
   ready) and sustained throughput per arrival pattern, with a
   self-calibrating p99 bound — ``margin × (max_wait + (depth+2) ×
   measured_bucket_time)`` — so the gate tracks the host's speed
   instead of hardcoding microseconds (what it catches is the failure
   mode that matters: a retrace or batching regression inflating tail
   latency by orders of magnitude).
5. **Blast radius** (DESIGN.md §14): a full co-batch carrying a
   raise-poison and a nan-poison completes every innocent request
   bit-identical to a fault-free per-request serve; exactly the poisons
   get their typed exceptions; bisect isolation adds zero retraces.
6. **Overload**: 2x measured capacity into a bounded queue with reject
   shedding — sheds with a measured retry-after, admitted p99 stays
   within the (now exactly known: the admission cap) depth bound,
   goodput holds above ``chaos_goodput_floor`` x capacity, and the
   ``completed+rejected+failed+expired == offered`` books balance.
7. **Self-healing lifecycle** (DESIGN.md §15): three chaos scenarios
   through the ``Supervisor``. (a) a dispatcher kill under Poisson load
   — the supervised restart requeues every undispatched request and all
   of them complete bit-identical (survival 1.0, zero hung futures, one
   ``ServerStats`` balancing the books across the restart); (b) hot
   reload — a verified checkpoint swaps the plan set atomically
   mid-traffic, a *corrupted* latest checkpoint fails typed
   (``CorruptCheckpointError``) with the old plan still serving
   bit-identical, and ``fallback=True`` walks back to the newest
   verifiable step; (c) kernel degradation — a persistent compiled-path
   fault on one bucket demotes exactly that bucket to its bit-compatible
   ref fallback (innocent buckets untouched, every result still
   bit-identical), degraded-mode goodput holds above
   ``selfheal_goodput_floor`` x the healthy path's, and after the fault
   heals a recovery probe re-promotes the bucket.

Offered load is auto-picked at ~25% of measured capacity (conservative:
on the CPU smoke model, thread/GIL overhead per dispatch is comparable
to the 3–4ms compute itself, so higher offered fractions saturate the
interpreter, not the datapath).
"""
import json
import pathlib
import sys
import time
from concurrent.futures import TimeoutError as FutureTimeout

# Standalone-runnable (`python -m benchmarks.bench_serve --smoke`, the CI
# one-liner): put src/ on the path like benchmarks/run.py does.
_SRC = str(pathlib.Path(__file__).resolve().parents[1] / "src")
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import core
from repro.kernels.autotune import interleaved_medians
from repro.launch.faults import FaultInjected, FaultInjector, \
    corrupt_checkpoint
from repro.launch.server import CNNServer, NumericalFault, Overloaded, \
    ServerCrashed, auto_rate, burst_arrivals, poisson_arrivals
from repro.launch.supervisor import Supervisor
from repro.xla_utils import median_time_us

OUT_PATH = pathlib.Path(__file__).resolve().parents[1] / "BENCH_serve.json"
# Margins shared with benchmarks/check_regression.py via the committed
# baselines file — bench and CI gate can never silently disagree.
_BASELINES = json.loads(
    (pathlib.Path(__file__).resolve().parent / "bench_baselines.json").read_text()
)
PLAN_MARGIN = _BASELINES["serve_plan_margin"]   # plan vs jitted-unplanned
P99_MARGIN = _BASELINES["serve_p99_margin"]     # p99 vs self-calibrated bound
GOODPUT_FLOOR = _BASELINES["chaos_goodput_floor"]  # overload goodput/capacity
SELFHEAL_FLOOR = _BASELINES["selfheal_goodput_floor"]  # degraded vs healthy


def _drive(server, arrivals, xpool, sizes):
    """Submit per the arrival schedule (real sleeps), resolve all futures.

    The pool is sliced as numpy: a client hands the server host data, and
    on a single device a jax slice per submit would enqueue onto the same
    stream the serving batches run on and contend with them.
    """
    xpool = np.asarray(xpool)
    futures = []
    t0 = time.monotonic()
    pool = xpool.shape[0]
    for i, t_arr in enumerate(arrivals):
        lag = t_arr - (time.monotonic() - t0)
        if lag > 0:
            time.sleep(lag)
        j = i % (pool - 1)  # keep room for 2-sample requests at the edge
        futures.append(server.submit(xpool[j : j + sizes[i]]))
    return [f.result(timeout=300) for f in futures]


def run(report, smoke: bool = True):
    import dataclasses

    from repro.configs import smoke_cnn_config
    from repro.models.cnn import SparseCNN

    core.clear_tuned()
    cfg = dataclasses.replace(
        smoke_cnn_config("sparse-cnn-tiny", sparsity=0.625), kernel_mode="pallas"
    )
    model = SparseCNN(cfg)
    params = model.compress(model.init(jax.random.PRNGKey(0)))
    sample_shape = (cfg.image_size, cfg.image_size, cfg.in_channels)
    xpool = jax.random.normal(jax.random.PRNGKey(1), (16,) + sample_shape)
    _, stats = model.apply(params, xpool[:4], collect_act_stats=True)
    qparams = model.quantize(params, stats)

    max_batch = 8
    plan_set = model.plan_set(qparams, max_batch=max_batch, tune="off")
    plan_set.warmup(sample_shape)
    results = {
        "backend": jax.default_backend(),
        "buckets": list(plan_set.buckets),
        "patterns": {},
    }

    # --- 1. bucketed/padded serving == per-request plan.serve, exactly --
    for n in (1, 2, 3, 5, 8, 11):  # 11 > max bucket: exercises chunking
        got = plan_set.serve(xpool[:n])
        per = jnp.concatenate(
            [plan_set.plans[1].serve(xpool[i : i + 1]) for i in range(n)]
        )
        np.testing.assert_array_equal(np.asarray(got), np.asarray(per))
    results["bit_identical"] = True
    report("serve/bit_exact", 0.0,
           "ragged n in {1,2,3,5,8,11} pad/slice == per-request plan.serve")

    # --- 2. frozen bucket plan vs *jitted-once* unplanned apply ---------
    xb = xpool[:max_batch]
    unplanned = jax.jit(lambda x: model.apply(qparams, x))
    jax.block_until_ready(unplanned(xb))  # compile outside the timing
    plan_us, unplanned_us = interleaved_medians(
        lambda: plan_set.plans[max_batch].serve(xb), lambda: unplanned(xb),
        warmup=2, reps=9,
    )
    assert plan_us <= unplanned_us * PLAN_MARGIN, (plan_us, unplanned_us)
    results["plan_us"] = round(plan_us, 1)
    results["unplanned_jit_us"] = round(unplanned_us, 1)
    report("serve/plan_vs_jitted_unplanned", plan_us,
           f"jitted-once unplanned {unplanned_us:.0f}us "
           f"({unplanned_us / max(plan_us, 1e-9):.2f}x, interleaved; "
           f"margin {PLAN_MARGIN}x is the wall-time contract)")

    # --- 3+4. load patterns through the server --------------------------
    rate, unit_us = auto_rate(plan_set, sample_shape, utilization=0.25)
    max_wait_ms = max(2.0, unit_us / 1e3)
    results["unit_us"] = round(unit_us, 1)
    results["max_wait_ms"] = round(max_wait_ms, 2)
    n_req = 48 if smoke else 192
    burst = 2 * max_batch
    patterns = {
        "poisson": (poisson_arrivals(rate, n_req, seed=7), 1),
        "burst": (burst_arrivals(n_req, burst=burst, gap_s=4 * unit_us / 1e6),
                  -(-burst // max_batch)),  # queue depth in buckets
    }
    rng = np.random.default_rng(11)
    for name, (arrivals, depth) in patterns.items():
        # mostly single-sample requests, a few 2-sample ones: the
        # aggregator must mix request sizes without splitting any
        sizes = np.where(rng.random(n_req) < 0.15, 2, 1)
        server = CNNServer(plan_set, max_wait_ms=max_wait_ms)
        with server:
            server.warmup(sample_shape)
            _drive(server, arrivals, xpool, sizes)
        retraces = server.retraces_after_warmup
        assert retraces == 0, f"{name}: {retraces} retraces under load"
        s = server.stats.summary()
        assert s["completed"] == s["offered"] == int(sizes.sum()), s
        bound_us = P99_MARGIN * (max_wait_ms * 1e3 + (depth + 2) * unit_us)
        assert s["p99_us"] <= bound_us, (name, s["p99_us"], bound_us)
        s.update(rate_rps=round(float(rate), 2),
                 retraces_after_warmup=retraces,
                 p99_bound_us=round(bound_us, 1))
        results["patterns"][name] = s
        report(f"serve/{name}_p99", s["p99_us"],
               f"p50 {s['p50_us']:.0f}us, {s['throughput_rps']:.1f} req/s "
               f"sustained, {s['batches']} batches {s['bucket_counts']}, "
               f"0 retraces after warmup")

    # --- 5. chaos: poison in a full co-batch, innocents survive ---------
    results["chaos"] = _chaos(report, plan_set, xpool, sample_shape,
                              max_batch, max_wait_ms)

    # --- 6. overload: 2x capacity offered, bounded queue sheds ----------
    results["overload"] = _overload(report, plan_set, xpool, sample_shape,
                                    max_batch, max_wait_ms, unit_us,
                                    smoke=smoke)

    # --- 7. self-healing lifecycle (§15): restart / reload / degrade ----
    results["selfheal"] = {
        "restart": _selfheal_restart(report, plan_set, xpool, sample_shape,
                                     rate, max_wait_ms),
        "reload": _selfheal_reload(report, model, qparams, plan_set, xpool,
                                   sample_shape, max_batch, max_wait_ms),
        "degraded": _selfheal_degraded(report, model, qparams, plan_set,
                                       xpool, sample_shape, max_wait_ms),
    }

    OUT_PATH.write_text(json.dumps(results, indent=2))
    report("serve/json", 0.0, f"wrote {OUT_PATH.name}")


def _selfheal_restart(report, plan_set, xpool, sample_shape, rate,
                      max_wait_ms):
    """§15 scenario (a): a dispatcher kill under Poisson load, recovered
    by a supervised restart. The kill seam fires mid-run with requests
    queued; the supervisor must restart the dispatcher, requeue every
    admitted-but-undispatched request, and *all* of them must complete
    bit-identical to a fault-free per-request serve — survival 1.0, zero
    hung futures, zero retraces (the plan set stays compiled across the
    restart), and one ``ServerStats`` whose
    ``completed+rejected+failed+expired == offered`` identity spans the
    whole supervised run."""
    pool = np.asarray(xpool)
    n_req = 32
    arrivals = poisson_arrivals(rate, n_req, seed=17)
    inj = FaultInjector(kill_after_dispatches=3, kills=1)
    srv = CNNServer(plan_set, max_wait_ms=max_wait_ms, faults=inj)
    sup = Supervisor(srv, backoff_s=0.01, backoff_max_s=0.1)
    ref = {i: np.asarray(plan_set.plans[1].serve(pool[i % pool.shape[0]][None]))
           for i in range(n_req)}
    futures, resubmits = [], 0
    t0 = time.monotonic()
    with sup:
        sup.warmup(sample_shape)
        for i, t_arr in enumerate(arrivals):
            lag = t_arr - (time.monotonic() - t0)
            if lag > 0:
                time.sleep(lag)
            while True:  # the restart gap: offered again, never dropped
                try:
                    futures.append(sup.submit(pool[i % pool.shape[0]][None]))
                    break
                except (ServerCrashed, RuntimeError):
                    resubmits += 1
                    assert resubmits < 2000, "restart gap never closed"
                    time.sleep(0.002)
        timeout_s = sup.request_timeout_s(floor_s=60.0)
        hung = survived = 0
        for i, f in enumerate(futures):
            try:
                y = np.asarray(f.result(timeout=timeout_s))
                survived += int(np.array_equal(y, ref[i]))
            except FutureTimeout:
                hung += 1
        elapsed = time.monotonic() - t0
        health = sup.health()
    sup.stats.assert_accounting()
    s = sup.stats.summary()
    out = {
        "restarts": s["restarts"],
        "requeued": s["requeued"],
        "survival": survived / n_req,      # bit-identical completions
        "hung": hung,
        "resubmits": resubmits,
        "accounting_ok": bool(s["accounting_ok"]),
        "retraces_after_warmup": sup.retraces_after_warmup,
        "injector_restarts": inj.restarts,
        "health": health["status"],
        "goodput_rps": round(s["completed"] / max(elapsed, 1e-9), 2),
    }
    assert out["restarts"] == 1 and inj.restarts == 1, out
    assert out["requeued"] >= 1, "kill with queued work requeued nothing"
    assert out["survival"] == 1.0 and hung == 0, out
    assert out["retraces_after_warmup"] == 0, out
    report("serve/selfheal_restart", 0.0,
           f"dispatcher killed mid-load: 1 supervised restart, "
           f"{s['requeued']} requeued, {n_req}/{n_req} bit-identical, "
           f"books balanced across the restart")
    return out


def _selfheal_reload(report, model, qparams, plan_set, xpool, sample_shape,
                     max_batch, max_wait_ms):
    """§15 scenario (b): hot checkpoint reload mid-traffic. A verified
    checkpoint swaps the plan set atomically (zero dropped requests,
    zero retraces after the swap — the supervisor warms off-thread); a
    *corrupted* latest checkpoint fails typed with the old plan still
    serving bit-identical; ``fallback=True`` walks back to the newest
    verifiable step and recovers."""
    import tempfile

    from repro.checkpoint.store import CorruptCheckpointError, save

    pool = np.asarray(xpool)
    ckpt_dir = tempfile.mkdtemp(prefix="bench-selfheal-ckpt-")
    save(ckpt_dir, 1, qparams)
    save(ckpt_dir, 2, qparams)
    srv = CNNServer(plan_set, max_wait_ms=max_wait_ms)
    sup = Supervisor(
        srv,
        rebuild=lambda tree: model.plan_set(tree, max_batch=max_batch,
                                            tune="off"),
        template=qparams,
    )
    out = {"hung": 0}
    with sup:
        sup.warmup(sample_shape)

        def probe():  # live traffic around every reload step
            ys = []
            for i in range(4):
                f = sup.submit(pool[i : i + 1])
                try:
                    ys.append(np.asarray(f.result(timeout=60)))
                except FutureTimeout:
                    out["hung"] += 1
            return ys

        y0 = probe()
        step, fp = sup.reload(ckpt_dir)         # clean: swap to step 2
        out["reload_step"] = step
        out["swap_bit_identical"] = all(
            np.array_equal(a, b) for a, b in zip(y0, probe()))
        corrupt_checkpoint(ckpt_dir, step=2, mode="flip")
        try:
            sup.reload(ckpt_dir)
            out["corrupt_typed"] = False        # must be unreachable
        except CorruptCheckpointError:
            out["corrupt_typed"] = True
        # the failed reload must leave the old plan serving, bit-identical
        out["old_plan_served"] = all(
            np.array_equal(a, b) for a, b in zip(y0, probe()))
        fb_step, _ = sup.reload(ckpt_dir, fallback=True)  # walk back
        out["fallback_step"] = fb_step
        out["fallback_recovered"] = bool(
            fb_step == 1
            and all(np.array_equal(a, b) for a, b in zip(y0, probe())))
        out["reloads"] = sup.stats.reloads
        out["reload_failures"] = sup.reload_failures
        out["retraces_after_warmup"] = sup.retraces_after_warmup
        out["health"] = sup.health()["status"]
    sup.stats.assert_accounting()
    out["accounting_ok"] = True
    assert out["reload_step"] == 2 and out["swap_bit_identical"], out
    assert out["corrupt_typed"] and out["old_plan_served"], out
    assert out["fallback_recovered"] and out["reloads"] == 2, out
    assert out["retraces_after_warmup"] == 0 and out["hung"] == 0, out
    report("serve/selfheal_reload", 0.0,
           "hot swap to step 2 (bit-identical, 0 retraces), corrupt step "
           "fails typed with old plan serving, fallback recovers step 1")
    return out


def _selfheal_degraded(report, model, qparams, plan_set, xpool, sample_shape,
                       max_wait_ms):
    """§15 scenario (c): persistent compiled-path fault on one bucket.
    With ``demote_after=1`` the first fault demotes exactly that bucket
    to its bit-compatible ref fallback — the faulted request itself is
    rescued (survival stays 1.0), innocent buckets keep their compiled
    plans bit-identical, degraded-mode goodput holds above
    ``selfheal_goodput_floor`` x the healthy path's (both measured in
    this run), and once the fault heals a recovery probe re-promotes."""
    pool = np.asarray(xpool)
    fallback = model.fallback_plan_set(qparams, plan_set)  # bit-compat asserted
    inj = FaultInjector()
    bad = 4  # the faulty bucket: 3-sample requests pad into it
    inj.fail_bucket(bad)
    srv = CNNServer(plan_set, max_wait_ms=max_wait_ms, faults=inj,
                    fallback=fallback, demote_after=1, probe_every=4)
    ref1 = [np.asarray(plan_set.plans[1].serve(pool[i : i + 1]))
            for i in range(8)]
    ref3 = [np.asarray(plan_set.serve(pool[i : i + 3])) for i in range(8)]

    def drive(n_samples, refs, count):  # serial: one request per dispatch
        ok = 0
        t0 = time.monotonic()
        for i in range(count):
            f = srv.submit(pool[i : i + n_samples])
            y = np.asarray(f.result(timeout=60))
            ok += int(np.array_equal(y, refs[i]))
        return ok, count * n_samples / max(time.monotonic() - t0, 1e-9)

    out = {}
    with srv:
        srv.warmup(sample_shape)
        # healthy baseline on an innocent bucket (compiled path)
        ok1, healthy_sps = drive(1, ref1, 8)
        # the faulty bucket: first dispatch faults -> demoted -> fallback
        ok3, degraded_sps = drive(3, ref3, 8)
        health_mid = srv.health()
        out["demoted"] = {str(b): r for b, r in srv.demoted_buckets().items()}
        # innocent bucket again while degraded: still compiled, bit-identical
        ok1b, _ = drive(1, ref1, 8)
        # heal the backend; the next recovery probe must re-promote
        inj.heal_bucket(bad)
        for i in range(8):
            f = srv.submit(pool[i : i + 3])
            np.testing.assert_array_equal(np.asarray(f.result(timeout=60)),
                                          ref3[i])
            if not srv.demoted_buckets():
                break
        health_end = srv.health()
    srv.stats.assert_accounting()
    s = srv.stats.summary()
    out.update({
        "survival": (ok1 + ok3 + ok1b) / 24,   # bit-identical completions
        "demoted_exact": list(out["demoted"]) == [str(bad)],
        "innocents_bit_identical": ok1 + ok1b == 16,
        "demotions": s["demotions"],
        "promotions": s["promotions"],
        "repromoted": not srv.demoted_buckets() and s["promotions"] == 1,
        "health_degraded": health_mid["status"],
        "health_recovered": health_end["status"],
        "bucket_faults_fired": inj.bucket_faults_fired,
        "healthy_sps": round(healthy_sps, 1),
        "degraded_sps": round(degraded_sps, 1),
        "accounting_ok": bool(s["accounting_ok"]),
    })
    assert out["survival"] == 1.0, out       # the faulted request is rescued
    assert out["demoted_exact"] and out["demotions"] == 1, out
    assert health_mid["status"] == "degraded" and str(bad) in out["demoted"], \
        health_mid
    assert out["repromoted"] and health_end["status"] == "ready", out
    assert degraded_sps >= SELFHEAL_FLOOR * healthy_sps, \
        f"degraded goodput {degraded_sps:.1f} < {SELFHEAL_FLOOR} x " \
        f"healthy {healthy_sps:.1f} samples/s"
    report("serve/selfheal_degraded", 0.0,
           f"bucket {bad} demoted to ref fallback on first fault "
           f"(reason recorded), 24/24 bit-identical, degraded "
           f"{degraded_sps:.0f} vs healthy {healthy_sps:.0f} samples/s, "
           f"probe re-promoted after heal")
    return out


def _chaos(report, plan_set, xpool, sample_shape, max_batch, max_wait_ms):
    """DESIGN.md §14 blast-radius gate: a slow plug request holds the
    dispatcher while a full ``max_batch`` co-batch queues up behind it,
    containing one raise-poison (plan exception at dispatch) and one
    nan-poison (NaN logits past the datapath). The bisect re-dispatch
    must complete every innocent **bit-identical** to a fault-free
    per-request serve, typed-fail exactly the two poisons, and add zero
    retraces (bisect halves land on already-warmed buckets)."""
    pool = np.asarray(xpool)
    inj = FaultInjector(slow_s=0.05)
    reqs = [pool[i : i + 1] for i in range(1 + max_batch)]  # plug + batch
    poison_raise = 1 + 2          # index 2 of the co-batch
    poison_nan = 1 + max_batch - 3
    inj.poison(reqs[poison_raise], "raise")
    inj.poison(reqs[poison_nan], "nan")
    # fault-free reference, served per-request outside the chaos server
    ref = {i: np.asarray(plan_set.plans[1].serve(r))
           for i, r in enumerate(reqs)
           if i not in (poison_raise, poison_nan)}

    server = CNNServer(plan_set, max_wait_ms=max_wait_ms, faults=inj)
    t0 = time.monotonic()
    with server:
        server.warmup(sample_shape)
        futures = [server.submit(reqs[0])]
        time.sleep(2 * max_wait_ms / 1e3)  # plug dispatches alone, slowly
        futures += [server.submit(r) for r in reqs[1:]]
        outcomes = {}
        for i, f in enumerate(futures):
            try:
                outcomes[i] = np.asarray(f.result(timeout=60))
            except (FaultInjected, NumericalFault) as e:
                outcomes[i] = e
    elapsed = time.monotonic() - t0
    server.stats.assert_accounting()

    survival = sum(
        1 for i in ref
        if isinstance(outcomes[i], np.ndarray)
        and np.array_equal(outcomes[i], ref[i])
    ) / len(ref)
    poison_typed = (isinstance(outcomes[poison_raise], FaultInjected)
                    and isinstance(outcomes[poison_nan], NumericalFault))
    retraces = server.retraces_after_warmup
    s = server.stats.summary()
    chaos = {
        "innocent_survival": survival,       # bit-identical completions
        "poison_typed": bool(poison_typed),  # exactly the poisons, typed
        "retraces_after_warmup": retraces,
        "accounting_ok": bool(s["accounting_ok"]),
        "goodput_rps": round(s["completed"] / max(elapsed, 1e-9), 2),
        "faults_fired": inj.faults_fired,
        "batches": s["batches"],
    }
    assert survival == 1.0, f"innocent survival {survival} (want 1.0)"
    assert poison_typed, {i: type(o).__name__ for i, o in outcomes.items()}
    assert retraces == 0, f"chaos bisect retraced {retraces}x"
    report("serve/chaos", 0.0,
           f"{len(ref)}/{len(ref)} innocents bit-identical beside 2 poisons "
           f"(bisect, {s['batches']} dispatches, 0 retraces)")
    return chaos


def _overload(report, plan_set, xpool, sample_shape, max_batch, max_wait_ms,
              unit_us, *, smoke):
    """DESIGN.md §14 overload gate: offer 2x measured capacity into a
    bounded queue (``2 x max_batch``) with reject shedding. The server
    must shed (``Overloaded`` with a measured retry-after), keep the
    admitted requests' p99 under the self-calibrated bound (queue depth
    is now *known*: the admission cap), balance the books exactly, and
    sustain goodput above the committed floor fraction of capacity."""
    pool = np.asarray(xpool)
    cap_rps, _ = auto_rate(plan_set, sample_shape, utilization=1.0)
    rate = 2.0 * cap_rps
    n_req = 96 if smoke else 384
    max_queue = 2 * max_batch
    arrivals = poisson_arrivals(rate, n_req, seed=13)
    server = CNNServer(plan_set, max_wait_ms=max_wait_ms,
                       max_queue=max_queue, shed="reject")
    shed = 0
    retry_after = 0.0
    t0 = time.monotonic()
    with server:
        server.warmup(sample_shape)
        futures = []
        for i, t_arr in enumerate(arrivals):
            lag = t_arr - (time.monotonic() - t0)
            if lag > 0:
                time.sleep(lag)
            try:
                futures.append(server.submit(pool[i % pool.shape[0]][None]))
            except Overloaded as e:
                shed += 1
                retry_after = e.retry_after_s
        timeout_s = server.request_timeout_s(floor_s=60.0)
        for f in futures:
            f.result(timeout=timeout_s)
        elapsed = time.monotonic() - t0
    server.stats.assert_accounting()
    s = server.stats.summary()
    assert s["rejected"] == shed and shed > 0, \
        f"2x capacity never shed (rejected={s['rejected']})"
    assert retry_after > 0.0, "Overloaded carried no measured retry-after"
    # queue depth is the admission cap: the p99 bound stops being a guess
    depth = -(-max_queue // max_batch)
    bound_us = P99_MARGIN * (max_wait_ms * 1e3 + (depth + 2) * unit_us)
    assert s["p99_us"] <= bound_us, (s["p99_us"], bound_us)
    goodput = s["completed"] / max(elapsed, 1e-9)
    floor = GOODPUT_FLOOR * cap_rps
    assert goodput >= floor, f"goodput {goodput:.1f} < floor {floor:.1f} rps"
    over = {
        "offered_rps": round(rate, 2),
        "capacity_rps": round(cap_rps, 2),
        "goodput_rps": round(goodput, 2),
        "shed_rate": s["shed_rate"],
        "rejected": s["rejected"],
        "completed": s["completed"],
        "offered": s["offered"],
        "retry_after_ms": round(retry_after * 1e3, 2),
        "p99_us": s["p99_us"],
        "p99_bound_us": round(bound_us, 1),
        "accounting_ok": bool(s["accounting_ok"]),
        "retraces_after_warmup": server.retraces_after_warmup,
    }
    report("serve/overload", s["p99_us"],
           f"2x capacity: shed {s['shed_rate']:.2f}, goodput "
           f"{goodput:.0f}/{cap_rps:.0f} rps capacity, p99 within bound, "
           f"books balanced {s['completed']}+{s['rejected']}+"
           f"{s['failed']}+{s['expired']}=={s['offered']}")
    return over


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-scale load (48 requests; default 192)")
    args = ap.parse_args()
    run(lambda name, us, derived="": print(f"{name},{us:.1f},{derived}"),
        smoke=args.smoke)
