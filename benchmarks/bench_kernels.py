"""VDBB kernel benchmark: the two central properties measured from the
software artifact itself —

1. time-unrolled occupancy: executed FLOPs (compiled HLO) scale ~ nnz/bz
   at every sparsity level (the 'variable NNZ, constant utilization' claim);
2. compressed stream: weight operand bytes scale as (nnz*8 + bz/8)/
   (bz*8) of dense (values + bitmask), for both tc and bw layouts;
3. int8 vs fp32 (DESIGN.md §8): the quantized datapath halves the
   compressed-K operand bytes vs bf16 (4x vs fp32) at matching results
   (max |deviation| reported against the fp32 path).

Wall time on CPU (jnp reference path) is reported for completeness;
TPU-representative performance is the §Roofline analysis.
"""
import jax
import jax.numpy as jnp

from benchmarks.timing import median_time_us
from repro.core import quant
from repro.core.vdbb import DBBFormat, dbb_encode, dbb_gemm_costs
from repro.models.common import apply_linear
from repro.xla_utils import cost_analysis_dict


def run(report):
    m, k, n = 256, 2048, 2048
    key = jax.random.PRNGKey(0)
    a = jax.random.normal(key, (m, k), jnp.float32)
    w = jax.random.normal(key, (k, n), jnp.float32)

    dense_fn = jax.jit(lambda a, w: a @ w)
    t_dense = median_time_us(dense_fn, a, w, reps=5)
    report("vdbb_matmul/dense", t_dense, f"{2*m*k*n/1e9:.2f} GFLOP")

    for nnz in (8, 4, 2, 1):
        fmt = DBBFormat(8, nnz, "matrix")
        dw = dbb_encode(w, fmt, prune=True)
        fn = jax.jit(lambda a, dw: apply_linear(a, dw))
        c = cost_analysis_dict(fn.lower(a, dw).compile())
        t_us = median_time_us(fn, a, dw, reps=5)
        costs = dbb_gemm_costs(m, k, n, fmt)
        report(
            f"vdbb_matmul/nnz{nnz}_8",
            t_us,
            f"hlo_flops {c['flops']:.3g} (dense x{c['flops']/(2*m*k*n):.2f}) "
            f"wbytes x{costs['weight_compression']:.2f} speedup {costs['speedup']:.1f}",
        )

    # int8 vs fp32 rows (§8): same GEMM through the quantized integer path.
    for nnz in (4, 2):
        fmt = DBBFormat(8, nnz, "matrix")
        dw = dbb_encode(w, fmt, prune=True)
        qw = quant.quantize_dbb(dw)
        s_a = quant.dynamic_act_scale(a)

        def q_fn(a, qw, s_a):
            return quant.quant_matmul_ref(quant.quantize(a, s_a), qw, s_a)

        fn = jax.jit(q_fn)
        y_q = fn(a, qw, s_a)
        t_us = median_time_us(fn, a, qw, s_a, reps=5)
        y_fp = apply_linear(a, dw)
        dev = float(jnp.max(jnp.abs(y_q - y_fp)))
        c8 = dbb_gemm_costs(m, k, n, fmt, bits=8, act_bits=8)
        c16 = dbb_gemm_costs(m, k, n, fmt, bits=16, act_bits=16)
        report(
            f"vdbb_matmul/int8_nnz{nnz}_8",
            t_us,
            f"operand bytes int8/bf16 w x{c8['weight_bytes']/c16['weight_bytes']:.2f} "
            f"act x{c8['act_bytes']/c16['act_bytes']:.2f} "
            f"max|int8-fp32| {dev:.4f}",
        )
