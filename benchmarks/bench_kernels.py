"""VDBB kernel benchmark: the two central properties measured from the
software artifact itself —

1. time-unrolled occupancy: executed FLOPs (compiled HLO) scale ~ nnz/bz
   at every sparsity level (the 'variable NNZ, constant utilization' claim);
2. compressed stream: weight operand bytes scale as (nnz*8 + bz/8)/
   (bz*8) of dense (values + bitmask), for both tc and bw layouts.

Wall time on CPU (jnp reference path) is reported for completeness;
TPU-representative performance is the §Roofline analysis.
"""
import time

import jax
import jax.numpy as jnp

from repro.core.vdbb import DBBFormat, dbb_encode, dbb_gemm_costs
from repro.models.common import apply_linear
from repro.xla_utils import cost_analysis_dict


def run(report):
    m, k, n = 256, 2048, 2048
    key = jax.random.PRNGKey(0)
    a = jax.random.normal(key, (m, k), jnp.float32)
    w = jax.random.normal(key, (k, n), jnp.float32)

    dense_fn = jax.jit(lambda a, w: a @ w)
    dense_fn(a, w).block_until_ready()
    t0 = time.time()
    for _ in range(5):
        dense_fn(a, w).block_until_ready()
    t_dense = (time.time() - t0) / 5 * 1e6
    report("vdbb_matmul/dense", t_dense, f"{2*m*k*n/1e9:.2f} GFLOP")

    for nnz in (8, 4, 2, 1):
        fmt = DBBFormat(8, nnz, "matrix")
        dw = dbb_encode(w, fmt, prune=True)
        fn = jax.jit(lambda a, dw: apply_linear(a, dw))
        fn(a, dw).block_until_ready()
        c = cost_analysis_dict(fn.lower(a, dw).compile())
        t0 = time.time()
        for _ in range(5):
            fn(a, dw).block_until_ready()
        t_us = (time.time() - t0) / 5 * 1e6
        costs = dbb_gemm_costs(m, k, n, fmt)
        report(
            f"vdbb_matmul/nnz{nnz}_8",
            t_us,
            f"hlo_flops {c['flops']:.3g} (dense x{c['flops']/(2*m*k*n):.2f}) "
            f"wbytes x{costs['weight_compression']:.2f} speedup {costs['speedup']:.1f}",
        )
