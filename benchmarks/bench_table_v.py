"""Table V reproduction: headline TOPS/W and TOPS/mm2 of the pareto VDBB
design vs published numbers (16nm and 65nm), from the calibrated component
energy model. Asserts <5% error on every row."""
import time

from repro.core.energy_model import (
    PAPER_TABLE_V_16NM,
    PAPER_TABLE_V_65NM,
    PARETO_DESIGN,
    STAConfig,
    fmt_for_sparsity,
)


def run(report):
    t0 = time.time()
    worst = 0.0
    rows = []
    for sp, (tw, tm) in PAPER_TABLE_V_16NM.items():
        f = fmt_for_sparsity(sp)
        got_w = PARETO_DESIGN.tops_per_w(f)
        got_m = PARETO_DESIGN.tops_per_mm2(f)
        err = max(abs(got_w / tw - 1), abs(got_m / tm - 1))
        worst = max(worst, err)
        rows.append((f"16nm@{sp:.3f}", got_w, tw, got_m, tm, err))
    d65 = STAConfig(A=4, B=8, C=8, M=4, N=8, mode="vdbb", tech="65nm")
    for sp, (tw, tm) in PAPER_TABLE_V_65NM.items():
        f = fmt_for_sparsity(sp)
        err = max(abs(d65.tops_per_w(f) / tw - 1), abs(d65.tops_per_mm2(f) / tm - 1))
        worst = max(worst, err)
        rows.append((f"65nm@{sp:.3f}", d65.tops_per_w(f), tw, d65.tops_per_mm2(f), tm, err))
    assert worst < 0.06, f"energy model deviates {worst:.1%} from Table V"
    us = (time.time() - t0) * 1e6
    for name, gw, tw, gm, tm, err in rows:
        report(f"table_v/{name}", us / len(rows), f"TOPS/W {gw:.2f} vs {tw} | TOPS/mm2 {gm:.2f} vs {tm} | err {err:.1%}")
    report("table_v/max_error", us, f"{worst:.3%} (<5% target)")
