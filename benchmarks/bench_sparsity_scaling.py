"""Fig 12 reproduction: effective throughput & energy efficiency vs weight
sparsity for (a) SA baseline + act CG, (b) fixed 4/8 DBB, (c) VDBB —
from the energy model — PLUS the measured FLOP scaling of the actual VDBB
kernel from compiled HLO, tying the hardware claim to the software artifact.
"""
import time

import jax
import jax.numpy as jnp

from repro.core.energy_model import STAConfig, fmt_for_sparsity
from repro.core.vdbb import DBBFormat, dbb_encode
from repro.xla_utils import cost_analysis_dict

DESIGNS = {
    "SA+CG": STAConfig(1, 1, 1, 32, 64, mode="dense", im2col=True),
    "DBB4/8": STAConfig(4, 8, 4, 4, 8, mode="dbb", hw_nnz=4, im2col=True),
    "VDBB": STAConfig(4, 8, 4, 8, 8, mode="vdbb", im2col=True),
}
SPARSITIES = [0.0, 0.125, 0.25, 0.375, 0.5, 0.625, 0.75, 0.875]


def model_curves():
    rows = []
    for sp in SPARSITIES:
        f = fmt_for_sparsity(sp)
        for name, d in DESIGNS.items():
            for act in (0.5, 0.8):
                rows.append((name, sp, act, d.effective_tops(f), d.tops_per_w(f, act)))
    return rows


def kernel_flops_scaling():
    """Measured: compiled HLO FLOPs of the compressed matmul (the GSPMD
    einsum form the distributed model executes) scale ~ nnz/bz."""
    from repro.models.common import apply_linear

    m, k, n = 64, 512, 256
    key = jax.random.PRNGKey(0)
    a = jax.random.normal(key, (m, k))
    w = jax.random.normal(key, (k, n))
    out = {}
    for nnz in (1, 2, 4, 8):
        fmt = DBBFormat(8, nnz, "matrix")
        dw = dbb_encode(w, fmt, prune=True)
        c = jax.jit(apply_linear).lower(a, dw).compile()
        out[nnz] = cost_analysis_dict(c)["flops"]
    out["dense_equiv"] = 2 * m * k * n
    return out


def run(report):
    t0 = time.time()
    rows = model_curves()
    # assertions mirroring Fig 12's qualitative claims
    d = {(n, s, a): (t, e) for n, s, a, t, e in rows}
    assert d[("SA+CG", 0.875, 0.5)][0] == d[("SA+CG", 0.0, 0.5)][0]  # no speedup
    assert d[("DBB4/8", 0.25, 0.5)][0] == d[("DBB4/8", 0.0, 0.5)][0]  # below design pt
    assert d[("DBB4/8", 0.75, 0.5)][0] == d[("DBB4/8", 0.5, 0.5)][0]  # capped
    tv = [d[("VDBB", s, 0.5)][0] for s in SPARSITIES]
    assert all(b >= a for a, b in zip(tv, tv[1:])), "VDBB throughput must scale"
    assert d[("VDBB", 0.875, 0.5)][0] > 30, "≈32 eff TOPS at 87.5% (paper: ~30)"
    assert d[("VDBB", 0.875, 0.5)][1] > 50, "≈56 TOPS/W at 87.5% (paper: 55.7)"
    assert d[("VDBB", 0.5, 0.8)][1] > d[("VDBB", 0.5, 0.5)][1], "act sparsity helps energy"
    kf = kernel_flops_scaling()
    ratio = kf[8] / kf[2]
    assert ratio > 2.5, f"kernel FLOPs must scale with nnz (8/2 ratio {ratio:.2f})"
    us = (time.time() - t0) * 1e6
    for name in DESIGNS:
        curve = " ".join(f"{d[(name, s, 0.5)][0]:.1f}" for s in SPARSITIES)
        report(f"fig12a/{name}", us / 6, f"eff TOPS vs sparsity: {curve}")
        curve = " ".join(f"{d[(name, s, 0.5)][1]:.1f}" for s in SPARSITIES)
        report(f"fig12b/{name}", us / 6, f"TOPS/W vs sparsity: {curve}")
    report("fig12/kernel_flops", us, f"HLO flops by nnz {kf} (ratio 8/2 = {ratio:.2f})")
