"""Fig 12 reproduction: effective throughput & energy efficiency vs weight
sparsity for (a) SA baseline + act CG, (b) fixed 4/8 DBB, (c) VDBB —
from the energy model — PLUS the measured FLOP scaling of the actual VDBB
kernel from compiled HLO, tying the hardware claim to the software artifact,
PLUS the *measured* activation-sparsity correction (DESIGN.md §7): a real
forward pass of the compressed SparseCNN supplies per-layer ActStats, and
the TOPS/W it implies is tabulated against the paper's flat 50% assumption
in ``results/act_sparsity.md``.
"""
import functools
import pathlib
import time

import jax
import jax.numpy as jnp

from repro.core.energy_model import (
    PARETO_DESIGN,
    STAConfig,
    fmt_for_sparsity,
    model_workload,
)
from repro.core.vdbb import DBBFormat, dbb_encode
from repro.xla_utils import cost_analysis_dict

RESULTS = pathlib.Path(__file__).resolve().parent / "results"

DESIGNS = {
    "SA+CG": STAConfig(1, 1, 1, 32, 64, mode="dense", im2col=True),
    "DBB4/8": STAConfig(4, 8, 4, 4, 8, mode="dbb", hw_nnz=4, im2col=True),
    "VDBB": STAConfig(4, 8, 4, 8, 8, mode="vdbb", im2col=True),
}
SPARSITIES = [0.0, 0.125, 0.25, 0.375, 0.5, 0.625, 0.75, 0.875]


def model_curves():
    rows = []
    for sp in SPARSITIES:
        f = fmt_for_sparsity(sp)
        for name, d in DESIGNS.items():
            for act in (0.5, 0.8):
                rows.append((name, sp, act, d.effective_tops(f), d.tops_per_w(f, act)))
    return rows


def kernel_flops_scaling():
    """Measured: compiled HLO FLOPs of the compressed matmul (the GSPMD
    einsum form the distributed model executes) scale ~ nnz/bz."""
    from repro.models.common import apply_linear

    m, k, n = 64, 512, 256
    key = jax.random.PRNGKey(0)
    a = jax.random.normal(key, (m, k))
    w = jax.random.normal(key, (k, n))
    out = {}
    for nnz in (1, 2, 4, 8):
        fmt = DBBFormat(8, nnz, "matrix")
        dw = dbb_encode(w, fmt, prune=True)
        c = jax.jit(apply_linear).lower(a, dw).compile()
        out[nnz] = cost_analysis_dict(c)["flops"]
    out["dense_equiv"] = 2 * m * k * n
    return out


@functools.lru_cache(maxsize=None)  # bench_design_space reuses the same pass
def measured_cnn_layers(arch="sparse-cnn-tiny", sparsity=0.625, batch=4, seed=0):
    """Eager forward of the compressed SparseCNN with activation collection.

    Returns (cfg, stats, layers): per-layer ActStats (conv inputs + head)
    and the (name, costs, fmt) triples from ``SparseCNN.layer_costs`` with
    each layer's *measured* activation sparsity recorded in its cost dict.
    """
    from repro.configs import smoke_cnn_config
    from repro.models.cnn import SparseCNN

    cfg = smoke_cnn_config(arch, sparsity=sparsity)
    model = SparseCNN(cfg)
    params = model.compress(model.init(jax.random.PRNGKey(seed)))
    x = jax.random.normal(
        jax.random.PRNGKey(seed + 1),
        (batch, cfg.image_size, cfg.image_size, cfg.in_channels),
    )
    _, stats = model.apply(params, x, collect_act_stats=True)
    return cfg, stats, model.layer_costs(batch, stats=stats)


def measured_vs_assumed(report):
    """The honest Fig 12 point: TOPS/W from measured per-layer activation
    sparsity of a real forward pass vs the flat 50% assumption. Emits the
    per-layer delta table to ``results/act_sparsity.md``."""
    from repro.core.act_sparsity import combine

    cfg, stats, layers = measured_cnn_layers()
    conv_stats = stats[: len(layers)]
    measured = model_workload(PARETO_DESIGN, [(c, f, None) for _, c, f in layers])
    assumed = model_workload(PARETO_DESIGN, [(c, f, 0.5) for _, c, f in layers])
    comb = combine(list(stats), name=cfg.name)

    lines = [
        "# Activation sparsity: measured vs assumed (DESIGN.md §7)\n\n",
        f"Model `{cfg.name}` (compressed, eager forward, batch 4); design "
        f"`{PARETO_DESIGN.A}x{PARETO_DESIGN.B}x{PARETO_DESIGN.C}_"
        f"{PARETO_DESIGN.M}x{PARETO_DESIGN.N}` VDBB+IM2C. Regenerate: "
        "`python -m benchmarks.run --only sparsity_scaling`.\n\n",
        "## Per-layer\n\n",
        "| layer | act shape | measured zero frac | blk nnz (of 8) | "
        "TOPS/W measured | TOPS/W assumed (50%) | delta |\n"
        "|---|---|---|---|---|---|---|\n",
    ]
    for (name, costs, fmt), st in zip(layers, conv_stats):
        tw_m = PARETO_DESIGN.tops_per_w(fmt, st)
        tw_a = PARETO_DESIGN.tops_per_w(fmt, 0.5)
        lines.append(
            f"| {name} | {st.shape} | {st.zero_frac:.3f} | {st.block_nnz_mean:.2f} "
            f"| {tw_m:.2f} | {tw_a:.2f} | {tw_m / tw_a - 1:+.1%} |\n"
        )
    delta = measured["tops_per_w"] / assumed["tops_per_w"] - 1
    lines += [
        "\n## Whole model\n\n",
        "| | MAC-wtd act sparsity | TOPS/W | energy (J) |\n|---|---|---|---|\n",
        f"| measured | {measured['mean_act_sparsity']:.3f} | "
        f"{measured['tops_per_w']:.2f} | {measured['energy_j']:.3e} |\n",
        f"| assumed 50% | 0.500 | {assumed['tops_per_w']:.2f} | "
        f"{assumed['energy_j']:.3e} |\n",
        f"\nAssumed-vs-measured TOPS/W delta: **{delta:+.1%}** (the Fig 12 "
        "curves below shift by this much for this model's real "
        "activations).\n",
        "\n## Corrected Fig 12(b): VDBB TOPS/W vs weight sparsity\n\n",
        "| weight sparsity | assumed 50% act | measured "
        f"({comb.sparsity:.3f} act) |\n|---|---|---|\n",
    ]
    for sp in SPARSITIES:
        f = fmt_for_sparsity(sp)
        lines.append(
            f"| {sp:.3f} | {PARETO_DESIGN.tops_per_w(f, 0.5):.2f} "
            f"| {PARETO_DESIGN.tops_per_w(f, comb):.2f} |\n"
        )
    RESULTS.mkdir(parents=True, exist_ok=True)
    (RESULTS / "act_sparsity.md").write_text("".join(lines))

    # the stats must come from the forward pass, not the 0.5 constant
    per_layer = [st.zero_frac for st in conv_stats]
    assert any(abs(z - 0.5) > 0.02 for z in per_layer), (
        f"measured act sparsity suspiciously equals the assumption: {per_layer}"
    )
    assert max(per_layer) - min(per_layer) > 0.05, (
        "per-layer spread expected (dense stem input vs post-ReLU layers)"
    )
    assert abs(delta) > 1e-4, "measured correction should move TOPS/W"
    report(
        "fig12/measured_act/per_layer", 0.0,
        "zero frac by layer: " + " ".join(f"{z:.3f}" for z in per_layer),
    )
    report(
        "fig12/measured_act/tops_per_w", 0.0,
        f"measured {measured['tops_per_w']:.2f} vs assumed "
        f"{assumed['tops_per_w']:.2f} ({delta:+.1%}) -> results/act_sparsity.md",
    )


def run(report):
    t0 = time.time()
    rows = model_curves()
    # assertions mirroring Fig 12's qualitative claims
    d = {(n, s, a): (t, e) for n, s, a, t, e in rows}
    assert d[("SA+CG", 0.875, 0.5)][0] == d[("SA+CG", 0.0, 0.5)][0]  # no speedup
    assert d[("DBB4/8", 0.25, 0.5)][0] == d[("DBB4/8", 0.0, 0.5)][0]  # below design pt
    assert d[("DBB4/8", 0.75, 0.5)][0] == d[("DBB4/8", 0.5, 0.5)][0]  # capped
    tv = [d[("VDBB", s, 0.5)][0] for s in SPARSITIES]
    assert all(b >= a for a, b in zip(tv, tv[1:])), "VDBB throughput must scale"
    assert d[("VDBB", 0.875, 0.5)][0] > 30, "≈32 eff TOPS at 87.5% (paper: ~30)"
    assert d[("VDBB", 0.875, 0.5)][1] > 50, "≈56 TOPS/W at 87.5% (paper: 55.7)"
    assert d[("VDBB", 0.5, 0.8)][1] > d[("VDBB", 0.5, 0.5)][1], "act sparsity helps energy"
    kf = kernel_flops_scaling()
    ratio = kf[8] / kf[2]
    assert ratio > 2.5, f"kernel FLOPs must scale with nnz (8/2 ratio {ratio:.2f})"
    us = (time.time() - t0) * 1e6
    for name in DESIGNS:
        curve = " ".join(f"{d[(name, s, 0.5)][0]:.1f}" for s in SPARSITIES)
        report(f"fig12a/{name}", us / 6, f"eff TOPS vs sparsity: {curve}")
        curve = " ".join(f"{d[(name, s, 0.5)][1]:.1f}" for s in SPARSITIES)
        report(f"fig12b/{name}", us / 6, f"TOPS/W vs sparsity: {curve}")
    report("fig12/kernel_flops", us, f"HLO flops by nnz {kf} (ratio 8/2 = {ratio:.2f})")
    measured_vs_assumed(report)
