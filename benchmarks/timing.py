"""Shared wall-time measurement for every ``bench_*`` module.

One harness — warmup (absorbs compile/trace), ``jax.block_until_ready``
around each timed call, a configurable statistic over k repetitions. The
canonical implementation lives in :mod:`repro.xla_utils` so the tile
autotuner (``repro.kernels.autotune``) times its candidates through the
*same* code path and benchmark and tuner numbers are directly comparable.

Measurement policy (DESIGN.md §12): single numbers use
:func:`median_time_us`; any *paired* perf claim (fused vs unfused, plan
vs unplanned, tuned vs default) must use :func:`interleaved_time_us`
with ``stat='min'`` and generous reps — on shared CI hosts, scheduling
noise is additive and non-interleaved medians of a few samples routinely
invert comparisons (the PR-6-era ``BENCH_fused.json`` "regression" was
exactly this artifact).
"""
from repro.xla_utils import (  # noqa: F401
    interleaved_samples_us,
    interleaved_time_us,
    median_time_us,
    noise_frac,
    time_samples_us,
)
