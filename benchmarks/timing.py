"""Shared wall-time measurement for every ``bench_*`` module.

One harness — warmup (absorbs compile/trace), ``jax.block_until_ready``
around each timed call, median of k repetitions. The canonical
implementation lives in :func:`repro.xla_utils.median_time_us` so the
tile autotuner (``repro.kernels.autotune``) times its candidates through
the *same* code path and benchmark and tuner numbers are directly
comparable.
"""
from repro.xla_utils import median_time_us  # noqa: F401
