"""Sparse-conv end-to-end reproduction: IM2COL magnifier × VDBB compression.

The paper's headline composition (its Fig 8 + Table V pipeline): the
hardware IM2COL unit removes the kh·kw× activation duplication *and* the
VDBB array consumes an nnz/bz compressed weight stream at nnz/bz occupancy.
This benchmark measures both boundaries on the actual fused kernel
(kernels/vdbb_im2col_conv):

  activations:  explicit im2col GEMM reads M·K bytes; the fused kernel
                reads the raw (halo-padded) tile once
  weights:      compressed values+mask vs dense K·F
  compute:      compiled HLO FLOPs of the tc path scale ~ nnz/bz

and cross-checks the analytic accounting (core.vdbb.dbb_conv_costs +
benchmarks.roofline.conv_roofline_row) against those measurements.
"""
import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.timing import median_time_us
from repro.core.vdbb import DBBFormat, dbb_conv_costs, dbb_encode_conv
from repro.kernels import ops, ref
from repro.kernels.vdbb_im2col_conv import vdbb_im2col_conv_tc
from repro.xla_utils import cost_analysis_dict


def run(report):
    n, h, w, c, f, kh, kw = 2, 32, 32, 64, 128, 3, 3
    key = jax.random.PRNGKey(0)
    k1, k2 = jax.random.split(key)
    x = jax.random.normal(k1, (n, h, w, c), jnp.float32)
    w4 = jax.random.normal(k2, (kh, kw, c, f), jnp.float32)

    # --- boundary A: activation stream (IM2COL placement) -----------------
    cols = ref.im2col_explicit(x, kh, kw)  # stored expansion the unit avoids
    act_bytes_expanded = cols.size * 4
    act_bytes_raw = n * (h + kh - 1) * (w + kw - 1) * c * 4  # halo-padded tile
    magnification = act_bytes_expanded / act_bytes_raw
    assert magnification > 7.5, magnification  # ~9x for 3x3, minus halo

    flops = {}
    for nnz in (1, 2, 4, 8):
        fmt = DBBFormat(8, nnz, "matrix")
        dw = dbb_encode_conv(w4, fmt, prune=True)

        # --- numerics: fused kernel == lax conv over decoded weights ------
        got = ops.sparse_conv(x, dw, kh, kw, bf=f, interpret=True)
        want = ref.sparse_conv_ref(x, dw, kh, kw)
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4
        )

        # --- boundary B: weight stream -------------------------------------
        dense_wbytes = kh * kw * c * f * 4
        comp_wbytes = dw.values.size * 4
        assert comp_wbytes == dense_wbytes * nnz // 8

        # --- compute occupancy: compiled HLO FLOPs scale ~ nnz/bz ----------
        fn = jax.jit(
            lambda x, v, i, fmt=fmt: vdbb_im2col_conv_tc(
                x, v, i, fmt, kh, kw, bf=f, interpret=True
            )
        )
        compiled = fn.lower(x, dw.values, dw.indices[:, :, 0]).compile()
        flops[nnz] = cost_analysis_dict(compiled)["flops"]

        costs = dbb_conv_costs(n, h, w, c, f, kh, kw, fmt, bits=32)
        # interpret-mode (CPU validation) timing
        t_us = median_time_us(
            lambda dw=dw: ops.sparse_conv(x, dw, kh, kw, bf=f, interpret=True),
            reps=3,
        )
        report(
            f"sparse_conv/nnz{nnz}_8",
            t_us,
            f"act x{magnification:.1f} less, wbytes x{dense_wbytes / comp_wbytes:.1f} less, "
            f"combined x{costs['combined_reduction']:.1f} "
            f"(analytic; hlo_flops {flops[nnz]:.3g}; time is interpret-mode)",
        )

    # occupancy: the tc path's executed FLOPs must grow with nnz
    for a, b in ((1, 4), (4, 8)):
        assert flops[a] < flops[b], flops
    ratio = flops[8] / flops[1]
    assert ratio > 8 * 0.55, flops  # main GEMM term dominates the mux overhead

    # analytic accounting sanity: composition is the product of the parts
    fmt = DBBFormat(8, 3, "matrix")
    costs = dbb_conv_costs(n, h, w, c, f, kh, kw, fmt)
    np.testing.assert_allclose(
        costs["combined_reduction"], costs["im2col_magnification"] * (8 / 3)
    )
    from benchmarks.roofline import conv_roofline_row

    row = conv_roofline_row(n, h, w, c, f, kh, kw, fmt)
    report(
        "sparse_conv/roofline_3of8",
        row["step_time_bound_s"] * 1e6,
        f"dom={row['dominant']} bound_reduction={row['bound_reduction']:.2f}x "
        f"(im2col x{row['im2col_magnification']:.1f} * weights x{row['weight_compression']:.1f})",
    )

    # the same layer on the paper's pareto ASIC design point
    from repro.core.energy_model import PARETO_DESIGN, conv_workload

    hw = conv_workload(PARETO_DESIGN, costs, fmt)
    report(
        "sparse_conv/asic_pareto_3of8",
        hw["time_s"] * 1e6,
        f"{hw['cycles']:.3g} cycles, {hw['energy_j'] * 1e6:.1f} uJ, "
        f"eff {hw['effective_tops']:.1f} TOPS, sram reads saved x{hw['sram_reads_saved']:.1f}",
    )
