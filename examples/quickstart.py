"""Quickstart: the paper's technique end-to-end in 60 lines.

1. Build a (reduced) qwen2-style LM with VDBB 3/8 weight sparsity.
2. Train a few steps — the DBB constraint is projected every step
   (magnitude pruning within each block of 8, paper §V-A).
3. Compress weights into the VDBB layout (values + positional index)
   and serve — the compressed matmul executes nnz/bz of the dense work,
   exactly the time-unrolled occupancy of the paper's S8DP1 lanes.

Run: PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.configs import make_batch, smoke_config
from repro.core.vdbb import DBBWeight, dbb_gemm_costs
from repro.data.pipeline import DataConfig
from repro.models.model import LM
from repro.optim.adamw import OptConfig
from repro.train.loop import LoopConfig, Trainer


def main():
    cfg = smoke_config("qwen2-72b", sparsity=0.625)  # 3/8 DBB, block 8
    model = LM(cfg)
    print(f"arch={cfg.name}-smoke  dbb={cfg.dbb.nnz}/{cfg.dbb.bz} "
          f"(sparsity {cfg.dbb.sparsity:.1%}, compression x{cfg.dbb.compression_ratio():.2f})")

    # --- train under the DBB constraint -------------------------------
    trainer = Trainer(
        model,
        OptConfig(peak_lr=3e-3, warmup_steps=5, decay_steps=40),
        DataConfig(seq_len=64, global_batch=4),
        LoopConfig(total_steps=40, log_every=10),
    )
    params, _, history = trainer.run()
    print(f"loss {history[0][1]:.3f} -> {history[-1][1]:.3f} under DBB constraint")

    # --- compress for serving -----------------------------------------
    served = model.compress(params)
    n_compressed = sum(
        isinstance(x, DBBWeight)
        for x in jax.tree_util.tree_leaves(
            served, is_leaf=lambda x: isinstance(x, DBBWeight)
        )
    )
    print(f"{n_compressed} weight tensors now in compressed VDBB layout")

    batch = make_batch(cfg, batch=2, seq=32, kind="serve")
    logits_dense = model.forward(model.constrain(params), batch)
    logits_comp = model.forward(served, batch)
    err = float(jnp.max(jnp.abs(logits_dense.astype(jnp.float32) - logits_comp.astype(jnp.float32))))
    print(f"compressed serving matches dense-masked forward: max|Δlogit| = {err:.2e}")

    costs = dbb_gemm_costs(64, cfg.d_model, cfg.d_ff, cfg.dbb)
    print(f"per-GEMM: speedup x{costs['speedup']:.2f}, weight bytes x"
          f"{1/costs['weight_compression']:.2f} of dense — the paper's scaling, on the MXU")


if __name__ == "__main__":
    main()
