"""End-to-end driver: train a ~100M-param VDBB-sparse LM for a few hundred
steps on the synthetic pipeline, with progressive sparsity annealing
(dense -> 3/8 over the first third of training), checkpoints, auto-resume.

Run: PYTHONPATH=src python examples/train_sparse_lm.py [--steps 300]
"""
import argparse
import dataclasses

import jax.numpy as jnp

from repro.configs import get_config
from repro.core.sparse_linear import PruneSchedule
from repro.data.pipeline import DataConfig
from repro.models.model import LM
from repro.optim.adamw import OptConfig
from repro.train.loop import LoopConfig, Trainer


def hundred_m_config(sparsity=0.625):
    """~100M-param member of the qwen2 family (real vocab, 12 layers)."""
    base = get_config("qwen2-72b", sparsity=sparsity)
    return dataclasses.replace(
        base,
        name="qwen2-100m",
        num_layers=16,
        d_model=512,
        num_heads=8,
        num_kv_heads=4,
        d_ff=2048,
        vocab_size=32768,
        q_chunk=256,
        remat="none",
        param_dtype=jnp.float32,
    )


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_100m_ckpt")
    args = ap.parse_args(argv)

    cfg = hundred_m_config()
    model = LM(cfg)
    n = cfg.param_count()
    print(f"training {cfg.name}: {n/1e6:.0f}M params, DBB {cfg.dbb.nnz}/{cfg.dbb.bz}")
    trainer = Trainer(
        model,
        OptConfig(peak_lr=6e-4, warmup_steps=20, decay_steps=args.steps),
        DataConfig(seq_len=args.seq_len, global_batch=args.global_batch),
        LoopConfig(
            total_steps=args.steps,
            ckpt_dir=args.ckpt_dir,
            ckpt_every=100,
            log_every=20,
        ),
        prune_schedule=PruneSchedule(0, args.steps // 3),
    )
    params, _, history = trainer.run()
    print(f"final loss {history[-1][1]:.4f} (from {history[0][1]:.4f})")
    return history


if __name__ == "__main__":
    main()
