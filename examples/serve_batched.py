"""Batched serving example: prefill + greedy decode with VDBB-compressed
weights, across three different architecture families (GQA, hybrid
RG-LRU, attention-free RWKV6) to show the cache/state plumbing.

Run: PYTHONPATH=src python examples/serve_batched.py
"""
import jax

from repro.configs import make_batch, smoke_config
from repro.launch.serve import generate
from repro.models.model import LM


def serve_one(arch: str, batch=2, prompt_len=24, gen=8):
    cfg = smoke_config(arch)
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    if cfg.dbb is not None and cfg.serve_compressed:
        params = model.compress(params)
    prompt = make_batch(cfg, batch=batch, seq=prompt_len, kind="serve")
    toks, rate = generate(model, params, prompt, gen_len=gen, max_len=prompt_len + gen)
    print(f"{arch:>22}: generated {tuple(toks.shape)} at {rate:6.2f} tok-steps/s "
          f"(VDBB {cfg.dbb.nnz}/{cfg.dbb.bz} compressed)")


def main():
    for arch in ("codeqwen1.5-7b", "recurrentgemma-2b", "rwkv6-3b"):
        serve_one(arch)


if __name__ == "__main__":
    main()
