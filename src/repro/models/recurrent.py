"""Recurrent mixers: RG-LRU (RecurrentGemma/Griffin) and RWKV6 (Finch).

Both are linear recurrences, implemented with parallel forms for
train/prefill (associative scan for RG-LRU; exact chunked form for the
RWKV6 matrix state with per-dimension data-dependent decay) and O(1)
carried state for decode — which is why these archs run the long_500k cell.

Numerical note (RWKV6 chunked): every exponent used is a *non-positive*
cumulative log-decay difference, so exp() never overflows; underflow to 0
is the mathematically correct limit. Computed in fp32.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.common import Param, apply_linear, linear_def, rms_norm, shard


# ---------------------------------------------------------------------------
# RG-LRU block (Griffin recurrent block: proj -> conv1d -> RG-LRU, gated)
# ---------------------------------------------------------------------------

_C_RGLRU = 8.0  # Griffin's fixed recurrence sharpness


@dataclasses.dataclass(frozen=True)
class RGLRUBlock:
    cfg: "ModelConfig"  # noqa: F821

    def defs(self):
        c = self.cfg
        dr = c.d_rnn_
        dbb = c.dbb
        return {
            "w_x": linear_def(c.d_model, dr, "embed", "mlp", dbb=dbb),
            "w_gate": linear_def(c.d_model, dr, "embed", "mlp", dbb=dbb),
            "conv_k": Param((c.conv1d_width, dr), (None, "mlp"), "scaled"),
            "w_a": linear_def(dr, dr, "mlp", None, dbb=dbb),  # recurrence gate
            "w_i": linear_def(dr, dr, "mlp", None, dbb=dbb),  # input gate
            "log_lambda": Param((dr,), (None,), "ones", scale=0.5),
            "w_out": linear_def(dr, c.d_model, "mlp", "embed", dbb=dbb),
        }

    def _gates(self, p, u):
        a_exp = jax.nn.sigmoid(apply_linear(u, p["w_a"]))
        log_a = -_C_RGLRU * a_exp.astype(jnp.float32) * jax.nn.softplus(
            p["log_lambda"].astype(jnp.float32)
        )
        a = jnp.exp(log_a)
        gated_in = jax.nn.sigmoid(apply_linear(u, p["w_i"])) * u
        beta = jnp.sqrt(jnp.clip(1.0 - a * a, 1e-12))
        return a, (beta * gated_in.astype(jnp.float32))

    def __call__(self, p, x, positions=None, memory=None):
        """Full-sequence via associative scan. x: (B,S,d)."""
        c = self.cfg
        u = apply_linear(x, p["w_x"])
        u = shard(u, ("batch", None, "mlp"))
        u = _causal_conv1d(u, p["conv_k"])
        a, bx = self._gates(p, u)
        # h_t = a_t h_{t-1} + bx_t  via associative scan over time.
        def comb(l, r):
            return (l[0] * r[0], r[0] * l[1] + r[1])

        _, h = jax.lax.associative_scan(comb, (a, bx), axis=1)
        h = h.astype(x.dtype)
        gate = jax.nn.gelu(apply_linear(x, p["w_gate"]))
        y = apply_linear(h * gate, p["w_out"])
        state = {
            "h": h[:, -1].astype(jnp.float32),
            "conv": u[:, -(c.conv1d_width - 1) :, :] if c.conv1d_width > 1 else None,
        }
        return y, state

    def init_cache(self, batch, max_len, dtype):
        c = self.cfg
        dr = c.d_rnn_
        return {
            "h": jnp.zeros((batch, dr), jnp.float32),
            "conv": jnp.zeros((batch, c.conv1d_width - 1, dr), dtype),
        }

    def decode(self, p, x, cache, pos):
        c = self.cfg
        u = apply_linear(x, p["w_x"])  # (B,1,dr)
        hist = jnp.concatenate([cache["conv"].astype(u.dtype), u], axis=1)
        kern = p["conv_k"].astype(u.dtype)
        u_c = jnp.einsum("bwd,wd->bd", hist, kern)[:, None, :]
        a, bx = self._gates(p, u_c)
        h = a[:, 0] * cache["h"] + bx[:, 0]
        gate = jax.nn.gelu(apply_linear(x, p["w_gate"]))
        y = apply_linear(h[:, None, :].astype(x.dtype) * gate, p["w_out"])
        return y, {"h": h, "conv": hist[:, 1:, :]}


def _causal_conv1d(u, kernel):
    """Depthwise causal conv. u: (B,S,D); kernel: (W,D)."""
    w = kernel.shape[0]
    pad = jnp.pad(u, ((0, 0), (w - 1, 0), (0, 0)))
    out = jnp.zeros_like(u, dtype=jnp.float32)
    for i in range(w):
        out = out + pad[:, i : i + u.shape[1], :].astype(jnp.float32) * kernel[i].astype(
            jnp.float32
        )
    return out.astype(u.dtype)


# ---------------------------------------------------------------------------
# RWKV6 time-mix + channel-mix
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class RWKV6Block:
    cfg: "ModelConfig"  # noqa: F821

    def defs(self):
        c = self.cfg
        dm = c.d_model
        h, hd = c.rwkv_heads, c.rwkv_head_dim
        dbb = c.dbb
        lora = 64
        tm = {
            "mu": Param((5, dm), (None, "embed"), "zeros"),  # w,k,v,r,g ddlerp base
            "mu_x": Param((dm,), ("embed",), "zeros"),
            "w_r": linear_def(dm, h * hd, "embed", "heads", dbb=dbb),
            "w_k": linear_def(dm, h * hd, "embed", "heads", dbb=dbb),
            "w_v": linear_def(dm, h * hd, "embed", "heads", dbb=dbb),
            "w_g": linear_def(dm, h * hd, "embed", "heads", dbb=dbb),
            "w_o": linear_def(h * hd, dm, "heads", "embed", dbb=dbb),
            "decay_base": Param((h * hd,), ("heads",), "normal", scale=1.0),
            "w_decay_a": linear_def(dm, lora, "embed", None),
            "w_decay_b": linear_def(lora, h * hd, None, "heads"),
            "u": Param((h, hd), (None, None), "normal", scale=0.5),
            "ln_g": Param((h * hd,), ("heads",), "ones"),
            "ln_b": Param((h * hd,), ("heads",), "zeros"),
        }
        cm = {
            "mu_k": Param((dm,), ("embed",), "zeros"),
            "mu_r": Param((dm,), ("embed",), "zeros"),
            "w_k": linear_def(dm, c.d_ff, "embed", "mlp", dbb=dbb),
            "w_v": linear_def(c.d_ff, dm, "mlp", "embed", dbb=dbb),
            "w_r": linear_def(dm, dm, "embed", None, dbb=dbb),
        }
        return {"tm": tm, "cm": cm}

    # --------------------------------------------------------- time mix
    def _tm_inputs(self, p, x, x_prev):
        """ddlerp-lite: shifted mixing for w,k,v,r,g channels."""
        xx = x_prev - x
        mixed = x + xx * p["mu_x"].astype(x.dtype)
        outs = []
        for i in range(5):
            outs.append(x + xx * (p["mu"][i].astype(x.dtype)))
        xw, xk, xv, xr, xg = outs
        return mixed, xw, xk, xv, xr, xg

    def _decay(self, p, xw):
        dd = apply_linear(jnp.tanh(apply_linear(xw, p["w_decay_a"])), p["w_decay_b"])
        wlog = -jnp.exp(
            jnp.clip(p["decay_base"].astype(jnp.float32) + dd.astype(jnp.float32), -8.0, 8.0)
        )
        return wlog  # (B,S,H*hd) log-decay <= 0

    def time_mix(self, p, x, x_prev_tok):
        """x: (B,S,d); x_prev_tok: (B,d) carry (last token of prev segment)."""
        c = self.cfg
        b, s, dm = x.shape
        h, hd = c.rwkv_heads, c.rwkv_head_dim
        xs = jnp.concatenate([x_prev_tok[:, None, :], x[:, :-1, :]], axis=1)
        _, xw, xk, xv, xr, xg = self._tm_inputs(p, x, xs)
        r = apply_linear(xr, p["w_r"]).reshape(b, s, h, hd)
        k = apply_linear(xk, p["w_k"]).reshape(b, s, h, hd)
        v = apply_linear(xv, p["w_v"]).reshape(b, s, h, hd)
        g = jax.nn.silu(apply_linear(xg, p["w_g"]))
        wlog = self._decay(p, xw).reshape(b, s, h, hd)
        u = p["u"].astype(jnp.float32)
        y, state = wkv_chunked(r, k, v, wlog, u, chunk=c.wkv_chunk)
        y = y.reshape(b, s, h * hd)
        y = _group_norm(y, p["ln_g"], p["ln_b"], h)
        y = apply_linear(y.astype(x.dtype) * g, p["w_o"])
        return y, {"s": state, "shift": x[:, -1, :]}

    def time_mix_decode(self, p, x, cache):
        c = self.cfg
        b, _, dm = x.shape
        h, hd = c.rwkv_heads, c.rwkv_head_dim
        xs = cache["shift"][:, None, :].astype(x.dtype)
        _, xw, xk, xv, xr, xg = self._tm_inputs(p, x, xs)
        r = apply_linear(xr, p["w_r"]).reshape(b, h, hd).astype(jnp.float32)
        k = apply_linear(xk, p["w_k"]).reshape(b, h, hd).astype(jnp.float32)
        v = apply_linear(xv, p["w_v"]).reshape(b, h, hd).astype(jnp.float32)
        g = jax.nn.silu(apply_linear(xg, p["w_g"]))
        w = jnp.exp(self._decay(p, xw).reshape(b, h, hd))
        u = p["u"].astype(jnp.float32)
        s0 = cache["s"]  # (B,H,hd,hd) fp32
        kv = k[..., :, None] * v[..., None, :]  # (B,H,hd,hd)
        y = jnp.einsum("bhk,bhkv->bhv", r, s0 + u[None, :, :, None] * kv)
        s1 = w[..., :, None] * s0 + kv
        y = y.reshape(b, 1, h * hd)
        y = _group_norm(y, p["ln_g"], p["ln_b"], h)
        y = apply_linear(y.astype(x.dtype) * g, p["w_o"])
        return y, {"s": s1, "shift": x[:, -1, :]}

    # ------------------------------------------------------ channel mix
    def channel_mix(self, p, x, x_prev_tok):
        xs = jnp.concatenate([x_prev_tok[:, None, :], x[:, :-1, :]], axis=1)
        return self._cm(p, x, xs), x[:, -1, :]

    def channel_mix_decode(self, p, x, shift):
        return self._cm(p, x, shift[:, None, :].astype(x.dtype)), x[:, -1, :]

    def _cm(self, p, x, xs):
        xx = xs - x
        xk = x + xx * p["mu_k"].astype(x.dtype)
        xr = x + xx * p["mu_r"].astype(x.dtype)
        k = jnp.square(jax.nn.relu(apply_linear(xk, p["w_k"])))
        k = shard(k, ("batch", None, "mlp"))
        return jax.nn.sigmoid(apply_linear(xr, p["w_r"])) * apply_linear(k, p["w_v"])

    # ----------------------------------------------------------- caches
    def init_cache(self, batch, max_len, dtype):
        c = self.cfg
        h, hd = c.rwkv_heads, c.rwkv_head_dim
        return {
            "s": jnp.zeros((batch, h, hd, hd), jnp.float32),
            "shift": jnp.zeros((batch, c.d_model), dtype),
            "cm_shift": jnp.zeros((batch, c.d_model), dtype),
        }


def _group_norm(y, gamma, beta, groups):
    b, s, d = y.shape
    yg = y.reshape(b, s, groups, d // groups).astype(jnp.float32)
    mu = yg.mean(-1, keepdims=True)
    var = yg.var(-1, keepdims=True)
    yn = ((yg - mu) * jax.lax.rsqrt(var + 1e-5)).reshape(b, s, d)
    return yn * gamma.astype(jnp.float32) + beta.astype(jnp.float32)


def wkv_chunked(r, k, v, wlog, u, *, chunk=64):
    """Exact chunked RWKV6 WKV with per-dim data-dependent decay.

    r,k,v: (B,S,H,D); wlog: (B,S,H,D) log-decay (<=0); u: (H,D) bonus.
    Returns y: (B,S,H,D) fp32 and final state (B,H,D,D) fp32.

    Recurrence: S_t = diag(w_t) S_{t-1} + k_t v_t^T;
                y_t = r_t^T S_{t-1} + (r_t . (u*k_t)) v_t.
    All chunk exponents are <= 0 (see module docstring).
    """
    b, s, h, d = r.shape
    t = min(chunk, s)
    s_orig = s
    if s % t:  # pad tail: wlog=0 (decay 1) and k=0 leave the state untouched
        pad = t - s % t
        zpad = lambda x: jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        r, k, v, wlog = zpad(r), zpad(k), zpad(v), zpad(wlog)
        s = s + pad
    n = s // t
    f32 = jnp.float32

    def resh(x):
        return x.astype(f32).reshape(b, n, t, h, d).transpose(1, 0, 3, 2, 4)

    rr, kk, vv, ww = map(resh, (r, k, v, wlog))  # (n,B,H,T,D)

    def body(S, inp):
        rc, kc, vc, wc = inp  # (B,H,T,D)
        L = jnp.cumsum(wc, axis=2)  # inclusive cumulative log decay
        Lx = L - wc  # exclusive
        # inter-chunk: y_inter[t] = (r_t * exp(Lx_t)) @ S
        r_t = rc * jnp.exp(Lx)
        y_inter = jnp.einsum("bhtk,bhkv->bhtv", r_t, S)
        # intra-chunk: D[t,i,d] = exp(Lx_t - L_i) for i < t  (<= 0 exponent)
        expo = Lx[:, :, :, None, :] - L[:, :, None, :, :]  # (B,H,T,T,D)
        tri = (jnp.arange(t)[:, None] > jnp.arange(t)[None, :])[None, None, :, :, None]
        dec = jnp.where(tri, jnp.exp(jnp.minimum(expo, 0.0)), 0.0)
        a = jnp.einsum("bhtd,bhid,bhtid->bhti", rc, kc, dec)
        y_intra = jnp.einsum("bhti,bhiv->bhtv", a, vc)
        # bonus diagonal term
        y_bonus = jnp.einsum("bhtd,bhtd->bht", rc, u[None, :, None, :] * kc)[
            ..., None
        ] * vc
        # state update: S' = diag(exp(L_T)) S + sum_i (k_i * exp(L_T - L_i)) v_i^T
        last = L[:, :, -1:, :]
        k_t = kc * jnp.exp(last - L)
        S = jnp.exp(last[:, :, 0, :])[..., None] * S + jnp.einsum(
            "bhtk,bhtv->bhkv", k_t, vc
        )
        return S, y_inter + y_intra + y_bonus

    S0 = jnp.zeros((b, h, d, d), f32)
    S, ys = jax.lax.scan(body, S0, (rr, kk, vv, ww))
    y = ys.transpose(1, 0, 3, 2, 4).reshape(b, s, h, d)
    return y[:, :s_orig], S
