"""Functional-module plumbing shared by the model zoo.

Params are plain pytrees (nested dicts of arrays). Every module is described
once by a ``defs()`` tree of :class:`Param` leaves, from which we derive both
the initialized arrays and the logical-axis PartitionSpecs — one source of
truth for shapes and sharding.

Logical axes used across the zoo:
  'batch'   — data parallel (mesh: ('pod',) 'data')
  'seq'     — sequence parallel (mesh: 'model')
  'embed'   — residual/feature dim
  'heads'   — attention heads (mesh: 'model' when divisible)
  'kv'      — kv heads
  'mlp'     — FFN hidden (mesh: 'model')
  'experts' — MoE experts (mesh: 'model')
  'vocab'   — embedding rows / logits (mesh: 'model')
  None      — replicated
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

# dbb_decode is imported at module scope (not inside the fallback branch)
# so tests can monkeypatch ``repro.models.common.dbb_decode`` and assert the
# hot path never densifies (the decode-spy in tests/test_lm_datapath.py).
from repro.core.quant import QuantDBBWeight
from repro.core.vdbb import (
    DBBFormat,
    DBBWeight,
    dbb_decode,
    dbb_matmul_gather_ref,
    dbb_prune,
)


# ---------------------------------------------------------------------------
# Param defs
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Param:
    shape: tuple
    axes: tuple  # logical axis name (or None) per dim
    init: str = "normal"  # 'normal' | 'zeros' | 'ones' | 'scaled'
    scale: float = 1.0
    dtype: Any = None  # defaults to the model's param dtype
    # DBB sparsity: set for weights the paper's technique applies to.
    dbb: Optional[DBBFormat] = None

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def init_params(defs, key, default_dtype=jnp.float32):
    """Initialize arrays for a defs tree (dict-of-dicts with Param leaves)."""
    leaves, treedef = jax.tree_util.tree_flatten(
        defs, is_leaf=lambda x: isinstance(x, Param)
    )
    keys = jax.random.split(key, len(leaves))
    out = []
    for p, k in zip(leaves, keys):
        dtype = p.dtype or default_dtype
        if p.init == "zeros":
            w = jnp.zeros(p.shape, dtype)
        elif p.init == "ones":
            w = jnp.ones(p.shape, dtype)
        elif p.init == "scaled":  # fan-in scaled truncated normal
            # fan-in is the contraction dim: second-to-last, so stacked
            # layer-group weights (G, K, N) scale by K, not by G
            fan_in = p.shape[-2] if len(p.shape) >= 2 else max(p.shape[0], 1)
            std = p.scale / np.sqrt(fan_in)
            w = std * jax.random.truncated_normal(k, -2, 2, p.shape).astype(dtype)
        else:
            w = p.scale * jax.random.normal(k, p.shape).astype(dtype)
        if p.dbb is not None and not p.dbb.is_dense and len(p.shape) == 2:
            w = dbb_prune(w, p.dbb)
        out.append(w)
    return jax.tree_util.tree_unflatten(treedef, out)


def abstract_params(defs, default_dtype=jnp.float32):
    """ShapeDtypeStruct tree (no allocation) — used by the dry-run."""
    return jax.tree_util.tree_map(
        lambda p: jax.ShapeDtypeStruct(p.shape, p.dtype or default_dtype),
        defs,
        is_leaf=lambda x: isinstance(x, Param),
    )


def param_pspecs(defs, rules: dict):
    """PartitionSpec tree from logical axes via ``rules`` (axis -> mesh axis)."""
    from jax.sharding import PartitionSpec as P

    def spec(p: Param):
        return P(*(rules.get(a) for a in p.axes))

    return jax.tree_util.tree_map(spec, defs, is_leaf=lambda x: isinstance(x, Param))


def dbb_leaves(defs, prefix=()):
    """Yield (path, Param) for every DBB-tagged weight."""
    if isinstance(defs, Param):
        if defs.dbb is not None:
            yield prefix, defs
        return
    for k, v in defs.items():
        yield from dbb_leaves(v, prefix + (k,))


def tree_get(tree, path):
    for k in path:
        tree = tree[k]
    return tree


def tree_set(tree, path, val):
    """Functionally set (or insert — e.g. the ``<leaf>_aq`` calibration
    siblings ``LM.quantize`` adds) a leaf at ``path``."""
    if not path:
        return val
    out = dict(tree)
    out[path[0]] = tree_set(tree.get(path[0], {}), path[1:], val)
    return out


# ---------------------------------------------------------------------------
# Sharding-constraint context
# ---------------------------------------------------------------------------

_CTX = threading.local()


@contextlib.contextmanager
def sharding_rules(rules: Optional[dict], mesh=None):
    """Install logical->mesh axis rules so ``shard(x, axes)`` annotates.

    With no rules installed (unit tests, single device) shard() is a no-op.
    ``mesh`` (optional) enables shard_map-based ops (sharded embedding).
    """
    prev = getattr(_CTX, "rules", None)
    prev_mesh = getattr(_CTX, "mesh", None)
    _CTX.rules = rules
    _CTX.mesh = mesh
    try:
        yield
    finally:
        _CTX.rules = prev
        _CTX.mesh = prev_mesh


def current_mesh():
    return getattr(_CTX, "mesh", None)


def shard(x: jax.Array, axes: tuple) -> jax.Array:
    rules = getattr(_CTX, "rules", None)
    if rules is None:
        return x
    from jax.sharding import PartitionSpec as P

    spec = P(*(rules.get(a) for a in axes))
    return jax.lax.with_sharding_constraint(x, spec)


def current_rules() -> Optional[dict]:
    return getattr(_CTX, "rules", None)


# ---------------------------------------------------------------------------
# Math helpers
# ---------------------------------------------------------------------------


def rms_norm(x, gamma, eps=1e-6):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps)).astype(dt) * gamma.astype(dt)


def layer_norm(x, gamma, beta, eps=1e-5):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    return ((x32 - mu) * jax.lax.rsqrt(var + eps)).astype(dt) * gamma.astype(
        dt
    ) + beta.astype(dt)


def rope(x, positions, theta=10000.0):
    """Apply rotary embedding. x: (..., S, H, D); positions: (..., S)."""
    d = x.shape[-1]
    half = d // 2
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    ang = positions[..., :, None, None].astype(jnp.float32) * freqs  # (...,S,1,half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)


def _use_pallas(kernel_mode: str, m: int) -> bool:
    """Pallas kernels want at least one 8-row M tile; tiny-M calls (e.g.
    single-token decode) fall back to the ref formulation — the same
    policy as ``DBBLinear._use_pallas``."""
    return kernel_mode == "pallas" and m >= 8


def _compressed_linear(x: jax.Array, w: DBBWeight, kernel_mode: str) -> jax.Array:
    """Compressed matmul for a fp DBBWeight — never densifies for the
    group='matrix' (tc) formats the LM configs use."""
    fmt = w.fmt
    k, n = w.shape
    lead = x.shape[:-1]
    m = x.size // max(k, 1)
    tc = fmt.group_size(n) == n
    if _use_pallas(kernel_mode, m):
        from repro.kernels import ops

        y = ops.vdbb_matmul(x.reshape(m, k), w)
        return y.reshape(*lead, n).astype(x.dtype)
    if tc:
        if current_rules() is None:
            y = dbb_matmul_gather_ref(x.reshape(m, k), w)
            return y.reshape(*lead, n)
        # Under pjit keep the GSPMD-friendly einsum form of the same
        # compressed contraction: one-hot "mux" gather of the activations
        # into compressed-K, then a dense contraction whose FLOPs scale
        # with nnz/bz — XLA shards it; no dense weight is materialized.
        nb = k // fmt.bz
        xb = x.reshape(*lead, nb, fmt.bz)
        onehot = jax.nn.one_hot(
            w.indices[:, :, 0].astype(jnp.int32), fmt.bz, dtype=x.dtype
        )  # (nb, nnz, bz)
        ac = jnp.einsum("...bi,bji->...bj", xb, onehot)  # mux
        return jnp.einsum("...bj,bjn->...n", ac, w.values.astype(x.dtype))
    # per-column pattern (bw): no compressed ref form exists — expand and
    # contract dense (the Pallas bw kernel covers the compressed path).
    return x @ dbb_decode(w).astype(x.dtype)


def _quant_linear(x: jax.Array, qw: QuantDBBWeight, aq, kernel_mode: str) -> jax.Array:
    """INT8 matmul for a quantized compressed weight → fp32 (DESIGN.md §8).

    ``aq`` is the calibrated per-tensor activation scale (None → dynamic);
    an int8 ``x`` is the previous layer's requantized codes (int8-resident
    chaining, §9) and requires ``aq``.
    """
    from repro.core import quant

    k, n = qw.shape
    lead = x.shape[:-1]
    m = x.size // max(k, 1)
    x2 = x.reshape(m, k)
    if _use_pallas(kernel_mode, m):
        from repro.kernels import ops

        return ops.quant_matmul(x2, qw, aq).reshape(*lead, n)
    xq, s_a = quant.resolve_quant_input(x2, aq)
    if qw.fmt.group_size(n) == n:
        y = quant.quant_matmul_gather_ref(xq, qw, s_a)
    else:
        y = quant.quant_matmul_ref(xq, qw, s_a)
    return y.reshape(*lead, n)


def apply_linear(
    x: jax.Array, w, bias=None, *, aq=None, kernel_mode: str = "ref",
    name: str = "",
) -> jax.Array:
    """x @ w where w is dense, a compressed :class:`DBBWeight`, or an int8
    :class:`QuantDBBWeight` — the LM stack's single on-ramp to the VDBB
    datapath (DESIGN.md §13).

    Compressed weights dispatch to the compressed-K matmul — the gather
    ref (``dbb_matmul_gather_ref`` / ``quant_matmul_gather_ref``) or the
    Pallas kernels (``ops.vdbb_matmul`` / ``ops.quant_matmul``) per
    ``kernel_mode`` — never to ``x @ dbb_decode(w)`` on the hot path.
    Quantized outputs are fp32 from the int32 flush and are cast back to
    the activation dtype for floating inputs.

    While an activation collector is installed (DESIGN.md §7;
    ``LM.forward(collect_act_stats=True)``) the input activation is
    measured here under the current ``act_scope`` as ``<scope>.<name>``,
    MAC-weighted by this GEMM's executed occupancy — the address
    ``LM.quantize`` later uses to look up this layer's calibrated scale.
    """
    from repro.core import act_sparsity

    if act_sparsity.collecting():
        k = x.shape[-1]
        rows = x.size // max(k, 1)
        if isinstance(w, (DBBWeight, QuantDBBWeight)):
            k_eff = (w.shape[0] // w.fmt.bz) * w.fmt.nnz
            macs = rows * k_eff * w.shape[1]
        else:
            macs = rows * k * w.shape[-1]
        act_sparsity.record_activation(
            x, name=act_sparsity.scoped(name), macs=macs
        )
    if isinstance(w, QuantDBBWeight):
        y = _quant_linear(x, w, aq, kernel_mode)
        if jnp.issubdtype(x.dtype, jnp.floating) and y.dtype != x.dtype:
            y = y.astype(x.dtype)
    elif isinstance(w, DBBWeight):
        y = _compressed_linear(x, w, kernel_mode)
    else:
        y = x @ w.astype(x.dtype)
    if bias is not None:
        y = y + bias.astype(y.dtype)
    return y


def linear_def(k, n, k_axis, n_axis, *, dbb=None, scale=1.0, dtype=None) -> Param:
    """Weight matrices use 'w_embed' where activations use 'embed': the
    weight feature dim is FSDP-sharded over 'data' (ZeRO-3) in training —
    without it a 72B model's fp32 params+optimizer need 54 GB/chip (§Perf H3)
    — while activations never shard their feature dim."""
    remap = {"embed": "w_embed"}
    return Param(
        (k, n), (remap.get(k_axis, k_axis), remap.get(n_axis, n_axis)),
        "scaled", scale, dtype, dbb,
    )


def sharded_embed_lookup(table: jax.Array, ids: jax.Array, compute_dtype) -> jax.Array:
    """Embedding gather that stays sharded under pjit.

    Plain ``jnp.take`` on a vocab-sharded table makes GSPMD all-gather the
    full fp32 table (and all-reduce its full gradient): ~10 GB/step on a
    150k-vocab model (§Perf H2). This version does a masked local lookup
    per vocab shard inside shard_map and psums the (B,S,d) result in the
    compute dtype; the table and its gradient never leave their shards.
    """
    rules = current_rules()
    axis = rules.get("vocab") if rules else None
    mesh = current_mesh()
    if axis is None or mesh is None:
        return jnp.take(table, ids, axis=0).astype(compute_dtype)
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    dp = rules.get("batch")

    def local(tbl, ids_l):
        i = jax.lax.axis_index(axis)
        v_loc = tbl.shape[0]
        l = ids_l - i * v_loc
        ok = (l >= 0) & (l < v_loc)
        safe = jnp.clip(l, 0, v_loc - 1)
        out = jnp.take(tbl, safe, axis=0).astype(compute_dtype)
        out = jnp.where(ok[..., None], out, jnp.zeros((), compute_dtype))
        return jax.lax.psum(out, axis)

    fn = shard_map(
        local,
        mesh=mesh,
        in_specs=(P(axis, None), P(dp, *([None] * (ids.ndim - 1)))),
        out_specs=P(dp, *([None] * (ids.ndim - 1)), None),
    )
    return fn(table, ids)
