"""Generic LM covering all 10 assigned architectures.

One scan over homogeneous layer *groups* (a group = one tile of
cfg.pattern, e.g. ('rec','rec','attn') for recurrentgemma); leftover
layers run unrolled. Params for scanned groups are stacked along a
leading 'layers' axis, so compile time is O(1) in depth.

The paper's technique is integrated end-to-end: every projection weight
is DBB-tagged (Param.dbb), `constrain()` projects params onto the block
constraint during training, and `compress()` converts them to the
compressed DBBWeight layout for serving (apply_linear then runs the
time-unrolled compressed matmul).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.core.act_sparsity import act_scope
from repro.core.sparse_linear import PruneSchedule
from repro.core.vdbb import DBBFormat, DBBWeight, dbb_encode, dbb_prune
from repro.models.attention import GQAttention, MLAttention
from repro.models.common import (
    Param,
    abstract_params,
    apply_linear,
    dbb_leaves,
    init_params,
    layer_norm,
    param_pspecs,
    rms_norm,
    shard,
    tree_get,
    tree_set,
)
from repro.models.config import ModelConfig
from repro.models.mlp import DenseMLP, MoEMLP
from repro.models.recurrent import RGLRUBlock, RWKV6Block


@dataclasses.dataclass(frozen=True)
class LM:
    cfg: ModelConfig

    # ------------------------------------------------------------- defs
    def _mixer(self, kind):
        c = self.cfg
        if kind == "attn":
            return MLAttention(c) if c.mixer == "mla" else GQAttention(c)
        if kind == "local":
            return GQAttention(c, window=c.local_window)
        if kind == "rec":
            return RGLRUBlock(c)
        if kind == "rwkv":
            return RWKV6Block(c)
        raise ValueError(kind)

    def _mlp(self):
        c = self.cfg
        return MoEMLP(c) if c.is_moe else DenseMLP(c)

    def _norm_def(self):
        c = self.cfg
        d = {"g": Param((c.d_model,), (None,), "ones")}
        if c.norm == "layernorm":
            d["b"] = Param((c.d_model,), (None,), "zeros")
        return d

    def _apply_norm(self, p, x):
        if self.cfg.norm == "layernorm":
            return layer_norm(x, p["g"], p["b"])
        return rms_norm(x, p["g"])

    def _block_defs(self, kind):
        c = self.cfg
        d = {"norm1": self._norm_def(), "mixer": self._mixer(kind).defs()}
        if kind == "rwkv":
            d["norm2"] = self._norm_def()
            return d
        d["norm2"] = self._norm_def()
        d["mlp"] = self._mlp().defs()
        if c.cross_attn:
            d["norm_x"] = self._norm_def()
            d["cross"] = GQAttention(c, cross=True).defs()
        return d

    def defs(self):
        c = self.cfg
        group = {f"b{i}": self._block_defs(k) for i, k in enumerate(c.pattern)}
        stacked = jax.tree_util.tree_map(
            lambda p: dataclasses.replace(
                p, shape=(c.num_groups,) + p.shape, axes=("layers",) + p.axes
            ),
            group,
            is_leaf=lambda x: isinstance(x, Param),
        )
        out = {
            "embed": Param((c.padded_vocab, c.d_model), ("vocab", "embed"), "scaled"),
            "layers": stacked,
            "final_norm": self._norm_def(),
        }
        if c.tail_pattern:
            out["tail"] = {
                f"t{i}": self._block_defs(k) for i, k in enumerate(c.tail_pattern)
            }
        if not c.tie_embeddings:
            head_v = (
                c.num_codebooks * c.codebook_vocab
                if c.frontend == "audio"
                else c.padded_vocab
            )
            out["lm_head"] = Param((c.d_model, head_v), ("embed", "vocab"), "scaled")
        if c.frontend == "audio":
            out["embed"] = Param(
                (c.num_codebooks, c.codebook_vocab, c.d_model),
                (None, "vocab", "embed"),
                "scaled",
            )
        return out

    def init(self, key):
        return init_params(self.defs(), key, self.cfg.param_dtype)

    def abstract(self):
        return abstract_params(self.defs(), self.cfg.param_dtype)

    def pspecs(self, rules: dict):
        return param_pspecs(self.defs(), rules)

    # ------------------------------------------------------- embeddings
    def _embed(self, params, batch):
        c = self.cfg
        from repro.models.common import sharded_embed_lookup

        tok = batch["tokens"]
        if c.frontend == "audio":
            # tok: (B,S,ncb) — sum codebook embeddings (tiny 2048-row tables:
            # plain take, replicated-friendly)
            embs = [
                jnp.take(params["embed"][i], tok[..., i], axis=0)
                for i in range(c.num_codebooks)
            ]
            h = sum(embs).astype(c.compute_dtype)
        else:
            h = sharded_embed_lookup(params["embed"], tok, c.compute_dtype)
        if c.embed_scale:
            h = h * jnp.sqrt(float(c.d_model)).astype(c.compute_dtype)
        if c.frontend == "vision" and "vision_embeds" in batch:
            ve = batch["vision_embeds"].astype(c.compute_dtype)
            h = jax.lax.dynamic_update_slice(h, ve, (0, 0, 0))
        return shard(h, ("batch", "seq", "embed"))

    def _logits(self, params, x):
        c = self.cfg
        if c.tie_embeddings:
            logits = x @ params["embed"].T.astype(x.dtype)
        else:
            logits = apply_linear(x, params["lm_head"],
                                  kernel_mode=c.kernel_mode, name="lm_head")
        if c.logit_softcap:
            logits = jnp.tanh(logits / c.logit_softcap) * c.logit_softcap
        # note: 'seq' (SP) and 'vocab' both map to 'model' — logits keep the
        # vocab shard and replicate seq (bounded: B*S*V/tp elements).
        return shard(logits, ("batch", None, "vocab"))

    # ----------------------------------------------------------- blocks
    def _apply_block(self, kind, p, x, positions, memory):
        """Full-sequence block. Returns (x, cache_for_this_block)."""
        c = self.cfg
        h = self._apply_norm(p["norm1"], x)
        mixer = self._mixer(kind)
        if kind == "rwkv":
            b = x.shape[0]
            zero = jnp.zeros((b, c.d_model), x.dtype)
            y, tm_cache = mixer.time_mix(p["mixer"]["tm"], h, zero)
            x = shard(x + y, ("batch", "seq", "embed"))
            h2 = self._apply_norm(p["norm2"], x)
            y2, cm_shift = mixer.channel_mix(p["mixer"]["cm"], h2, zero)
            x = shard(x + y2, ("batch", "seq", "embed"))
            return x, {**tm_cache, "cm_shift": cm_shift}
        with act_scope("mixer"):
            y, cache = mixer(p["mixer"], h, positions)
        x = shard(x + y, ("batch", "seq", "embed"))
        if c.cross_attn:
            hx = self._apply_norm(p["norm_x"], x)
            with act_scope("cross"):
                yx, xc = GQAttention(c, cross=True)(
                    p["cross"], hx, positions, memory=memory
                )
            x = shard(x + yx, ("batch", "seq", "embed"))
            cache = {"self": cache, "cross": xc}
        with act_scope("mlp"):
            y2 = self._mlp()(p["mlp"], self._apply_norm(p["norm2"], x))
        x = shard(x + y2, ("batch", "seq", "embed"))
        return x, cache

    def _apply_block_decode(self, kind, p, x, cache, pos):
        c = self.cfg
        h = self._apply_norm(p["norm1"], x)
        mixer = self._mixer(kind)
        if kind == "rwkv":
            y, tm_cache = mixer.time_mix_decode(p["mixer"]["tm"], h, cache)
            x = x + y
            h2 = self._apply_norm(p["norm2"], x)
            y2, cm_shift = mixer.channel_mix_decode(p["mixer"]["cm"], h2, cache["cm_shift"])
            return x + y2, {**tm_cache, "cm_shift": cm_shift}
        if c.cross_attn:
            y, new_self = mixer.decode(p["mixer"], h, cache["self"], pos)
            x = x + y
            hx = self._apply_norm(p["norm_x"], x)
            yx, _ = GQAttention(c, cross=True).decode(p["cross"], hx, cache["cross"], pos)
            x = x + yx
            new_cache = {"self": new_self, "cross": cache["cross"]}
        else:
            y, new_cache = mixer.decode(p["mixer"], h, cache, pos)
            x = x + y
        y2 = self._mlp()(p["mlp"], self._apply_norm(p["norm2"], x))
        return x + y2, new_cache

    # ---------------------------------------------------------- forward
    def forward(
        self,
        params,
        batch,
        *,
        return_cache: bool = False,
        collect_act_stats: bool = False,
        act_threshold: float = 0.0,
    ):
        """Full-sequence forward (train / prefill). Returns (logits, cache).

        ``collect_act_stats=True`` (eager-only; DESIGN.md §7) additionally
        returns the per-GEMM ActStats recorded by ``apply_linear``: the
        result becomes ``(logits[, cache], stats)``. While collecting, the
        scan/remat paths are bypassed (their bodies are traced, so there
        would be nothing concrete to measure).
        """
        if collect_act_stats:
            from repro.core.act_sparsity import collect_activations

            with collect_activations(threshold=act_threshold) as col:
                out = self.forward(params, batch, return_cache=return_cache)
            out = out if isinstance(out, tuple) else (out,)
            return (*out, col.stats)
        from repro.core.act_sparsity import collecting

        c = self.cfg
        h = self._embed(params, batch)
        b, s, _ = h.shape
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
        memory = batch.get("memory")
        if memory is not None:
            memory = memory.astype(c.compute_dtype)

        def group_body(x, gp):
            caches = {}
            for i, kind in enumerate(c.pattern):
                with act_scope(f"b{i}"):
                    x, cache = self._apply_block(
                        kind, gp[f"b{i}"], x, positions, memory
                    )
                caches[f"b{i}"] = cache
            return x, caches

        body = group_body
        if not collecting():  # remat/scan trace the body: skip while measuring
            if c.remat == "full":
                body = jax.checkpoint(group_body, prevent_cse=False)
            elif c.remat == "dots":
                body = jax.checkpoint(
                    group_body,
                    policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
                    prevent_cse=False,
                )
        if c.scan_layers and not collecting():
            h, caches = jax.lax.scan(body, h, params["layers"])
        else:
            caches_l = []
            for g in range(c.num_groups):
                gp = jax.tree_util.tree_map(lambda a: a[g], params["layers"])
                with act_scope(f"g{g}"):
                    h, cch = body(h, gp)
                caches_l.append(cch)
            caches = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *caches_l)
        if c.tail_pattern:
            tails = {}
            for i, kind in enumerate(c.tail_pattern):
                with act_scope("tail"), act_scope(f"t{i}"):
                    h, cache = self._apply_block(
                        kind, params["tail"][f"t{i}"], h, positions, memory
                    )
                tails[f"t{i}"] = cache
            caches = {"groups": caches, "tail": tails}
        else:
            caches = {"groups": caches}
        h = self._apply_norm(params["final_norm"], h)
        logits = self._logits(params, h)
        if return_cache:
            return logits, caches
        return logits

    # ------------------------------------------------------------- loss
    def loss(self, params, batch):
        c = self.cfg
        logits = self.forward(params, batch)
        labels = batch["labels"]
        mask = batch.get("loss_mask")
        if c.frontend == "audio":
            bsz, s, _ = logits.shape
            logits = logits.reshape(bsz, s, c.num_codebooks, c.codebook_vocab)
            vocab = c.codebook_vocab
        else:
            vocab = logits.shape[-1]
        lse = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
        iota = jnp.arange(vocab, dtype=labels.dtype)
        onehot = (labels[..., None] == iota).astype(jnp.float32)
        label_logit = jnp.sum(logits.astype(jnp.float32) * onehot, axis=-1)
        nll = lse - label_logit
        if c.frontend == "audio":
            nll = nll.mean(-1)
        if mask is not None:
            nll = nll * mask
            denom = jnp.maximum(mask.sum(), 1.0)
        else:
            denom = float(nll.size)
        loss = nll.sum() / denom
        return loss, {"loss": loss, "nll_mean": loss}

    # ------------------------------------------------------------ cache
    def init_cache(self, batch_size: int, max_len: int):
        c = self.cfg
        dt = c.compute_dtype

        def block_cache(kind):
            if kind == "rwkv":
                return self._mixer(kind).init_cache(batch_size, max_len, dt)
            cc = self._mixer(kind).init_cache(batch_size, max_len, dt)
            if c.cross_attn:
                cc = {
                    "self": cc,
                    "cross": GQAttention(c, cross=True).init_cache(batch_size, max_len, dt),
                }
            return cc

        group = {f"b{i}": block_cache(k) for i, k in enumerate(c.pattern)}
        stacked = jax.tree_util.tree_map(
            lambda a: jnp.broadcast_to(a[None], (c.num_groups,) + a.shape), group
        )
        out = {"groups": stacked}
        if c.tail_pattern:
            out["tail"] = {
                f"t{i}": block_cache(k) for i, k in enumerate(c.tail_pattern)
            }
        return out

    def cache_abstract(self, batch_size: int, max_len: int):
        return jax.tree_util.tree_map(
            lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype),
            jax.eval_shape(lambda: self.init_cache(batch_size, max_len)),
        )

    # ------------------------------------------------------ decode step
    def decode_step(self, params, cache, batch, pos):
        """One-token decode. batch['tokens']: (B,1[,ncb]); pos: scalar."""
        c = self.cfg
        h = self._embed(params, batch)

        def group_body(x, scanned):
            gp, gc = scanned
            new = {}
            for i, kind in enumerate(c.pattern):
                x, nc = self._apply_block_decode(kind, gp[f"b{i}"], x, gc[f"b{i}"], pos)
                new[f"b{i}"] = nc
            return x, new

        if c.scan_layers:
            h, new_groups = jax.lax.scan(group_body, h, (params["layers"], cache["groups"]))
        else:
            outs = []
            for g in range(c.num_groups):
                gp = jax.tree_util.tree_map(lambda a: a[g], params["layers"])
                gc = jax.tree_util.tree_map(lambda a: a[g], cache["groups"])
                h, nc = group_body(h, (gp, gc))
                outs.append(nc)
            new_groups = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *outs)
        new_cache = {"groups": new_groups}
        if c.tail_pattern:
            tails = {}
            for i, kind in enumerate(c.tail_pattern):
                h, nc = self._apply_block_decode(
                    kind, params["tail"][f"t{i}"], h, cache["tail"][f"t{i}"], pos
                )
                tails[f"t{i}"] = nc
            new_cache["tail"] = tails
        h = self._apply_norm(params["final_norm"], h)
        logits = self._logits(params, h)
        return logits, new_cache

    _CACHE_AXES = {
        "k": ("batch", "cache_seq", "kv", None),
        "v": ("batch", "cache_seq", "kv", None),
        "c_kv": ("batch", "cache_seq", None),
        "k_rope": ("batch", "cache_seq", None),
        "h": ("batch", "mlp"),
        "conv": ("batch", None, "mlp"),
        "s": ("batch", None, None, None),
        "shift": ("batch", None),
        "cm_shift": ("batch", None),
    }

    def cache_pspecs(self, rules: dict):
        """PartitionSpec tree matching init_cache structure (key-driven)."""
        from jax.sharding import PartitionSpec as P

        ab = self.cache_abstract(2, 4)
        flat, treedef = jax.tree_util.tree_flatten_with_path(ab)
        specs = []
        for path, leaf in flat:
            name = path[-1].key
            axes = self._CACHE_AXES[name]
            if leaf.ndim == len(axes) + 1:  # stacked over scanned groups
                axes = (None,) + tuple(axes)
            assert leaf.ndim == len(axes), (path, leaf.shape, axes)
            specs.append(P(*(rules.get(a) for a in axes)))
        return jax.tree_util.tree_unflatten(treedef, specs)

    # --------------------------------------------- the paper's technique
    def _dbb_apply(self, w, fmt: DBBFormat, fn):
        """Apply a (K,N)->... DBB op through leading stack dims via vmap."""
        f = fn
        for _ in range(w.ndim - 2):
            f = jax.vmap(f)
        return f(w)

    def constrain(self, params, step=None, schedule: Optional[PruneSchedule] = None):
        """Project every DBB-tagged weight onto the (annealed) constraint."""
        for path, pdef in dbb_leaves(self.defs()):
            fmt = pdef.dbb
            w = tree_get(params, path)
            if not isinstance(w, jnp.ndarray):
                continue  # already compressed
            if schedule is not None and step is not None:
                nnzs = list(range(fmt.nnz, fmt.bz + 1))
                cur = schedule.nnz_at(step, fmt)
                branches = [
                    partial(
                        self._dbb_apply,
                        fmt=dataclasses.replace(fmt, nnz=n),
                        fn=lambda x, n=n: dbb_prune(x, dataclasses.replace(fmt, nnz=n)),
                    )
                    for n in nnzs
                ]
                w = jax.lax.switch(cur - fmt.nnz, branches, w)
            else:
                w = self._dbb_apply(w, fmt, lambda x: dbb_prune(x, fmt))
            params = tree_set(params, path, w)
        return params

    def compress(self, params):
        """Encode DBB-tagged weights into compressed DBBWeight for serving.

        Stacked-layer weights (leading 'layers' dim) are encoded with a
        batched leading axis — lax.scan slices them per layer. 4-D expert
        stacks stay dense-with-zeros (DESIGN.md §5)."""
        for path, pdef in dbb_leaves(self.defs()):
            fmt = pdef.dbb
            w = tree_get(params, path)
            if not isinstance(w, jnp.ndarray) or w.ndim > 3:
                continue
            if w.ndim == 2:
                dw = dbb_encode(w, fmt, prune=True)
            else:
                dw = jax.vmap(lambda x: dbb_encode(x, fmt, prune=True))(w)
            params = tree_set(params, path, dw)
        return params

    def _stat_absmax(self, stats) -> dict:
        """name -> max absmax over the calibration records (a name repeats
        when the same GEMM ran more than once during calibration)."""
        out = {}
        for st in stats or []:
            name = getattr(st, "name", "")
            amax = float(getattr(st, "absmax", 0.0))
            if name and amax > 0.0:
                out[name] = max(out.get(name, 0.0), amax)
        return out

    def _leaf_act_scales(self, path, absmax):
        """Calibrated per-tensor act scale(s) for one dbb leaf, or None.

        Stacked leaves (``('layers', 'b{i}', …)``) look up one scoped name
        per layer group (``g{g}.b{i}.….<leaf>``) and return an (L,) array —
        ``lax.scan`` slices it back to a scalar per layer; tail leaves use
        their dotted path directly. Missing calibration → None (the serving
        path falls back to dynamic quantization).
        """
        from repro.core.quant import QMAX

        if path[0] == "layers":
            suffix = ".".join(path[1:])
            scales = []
            for g in range(self.cfg.num_groups):
                amax = absmax.get(f"g{g}.{suffix}")
                if amax is None:
                    return None
                scales.append(amax / QMAX)
            return jnp.asarray(scales, jnp.float32)
        amax = absmax.get(".".join(path))
        if amax is None:
            return None
        return jnp.float32(amax / QMAX)

    def quantize(self, params, stats=None):
        """INT8-quantize every compressed DBBWeight leaf (DESIGN.md §8/§13).

        ``stats`` is the list returned by
        ``forward(..., collect_act_stats=True)`` run on *compressed* params:
        each leaf whose scoped activation name was calibrated gets a static
        per-tensor act scale stored as a ``<leaf>_aq`` sibling, which
        ``apply_linear`` picks up (and the §9 int8-resident MLP chain keys
        on); uncalibrated leaves serve with dynamic quantization.
        """
        from repro.core.quant import quantize_dbb

        absmax = self._stat_absmax(stats)
        for path, _pdef in dbb_leaves(self.defs()):
            w = tree_get(params, path)
            if not isinstance(w, DBBWeight):
                continue  # dense (never compressed) or already quantized
            qw = quantize_dbb(w) if w.values.ndim == 3 else jax.vmap(quantize_dbb)(w)
            params = tree_set(params, path, qw)
            aq = self._leaf_act_scales(path, absmax)
            if aq is not None:
                params = tree_set(params, path[:-1] + (path[-1] + "_aq",), aq)
        return params

    # ------------------------------------------------------------- plan
    def _tune_gemms(self, params, m, *, tune, cache, top_k, reps):
        """Resolve measured-best tiles for each unique compressed GEMM
        shape in the param tree. ``tiles_for_matmul`` installs results
        into the autotuner's global registry, so the plan's jit trace
        (ops-layer dispatch) picks them up without per-stage pinning."""
        from repro.core.quant import QuantDBBWeight
        from repro.kernels import autotune
        from repro.models.plan import resolve_tune_cache

        cache = resolve_tune_cache(tune, cache)
        seen = set()
        for path, pdef in dbb_leaves(self.defs()):
            w = tree_get(params, path)
            if not hasattr(w, "fmt"):
                continue  # never compressed (e.g. 4-D expert stacks)
            k, n = pdef.shape[-2:]
            dtype = (jnp.int8 if isinstance(w, QuantDBBWeight)
                     else self.cfg.compute_dtype)
            sig = (m, k, n, w.fmt, jnp.dtype(dtype).name)
            if sig in seen:
                continue
            seen.add(sig)
            autotune.tiles_for_matmul(m, k, n, w.fmt, dtype, mode=tune,
                                      cache=cache, top_k=top_k, reps=reps)

    def plan(self, params, *, batch: int, seq: int, tune: str = "cache",
             cache=None, top_k: int = 4, reps: int = 3):
        """Freeze a serving plan for a fixed (batch, seq) shape (§13).

        Stages: ``embed`` → one stage per block (layer groups unrolled:
        ``g{g}.b{i}``, then tail ``t{i}``) → ``head`` (final norm +
        logits). Composition and staleness come from the shared
        :class:`~repro.models.plan.ModelPlan` machinery, exactly like the
        CNN plan. One deviation from the CNN: LM stages carry empty
        ``tiles`` — GEMM tile choices are resolved once up front via the
        autotuner registry (``_tune_gemms``) rather than pinned per stage,
        because a transformer block mixes several GEMMs per stage.

        Raises ``NotImplementedError`` for cross-attention / multimodal
        configs: their blocks need extra per-call inputs (memory, vision
        embeds) that a frozen single-input pipeline cannot bind.
        """
        from repro.models.plan import PlanBuilder

        c = self.cfg
        if c.cross_attn or c.frontend:
            raise NotImplementedError(
                "LM.plan supports decoder-only text models; cross_attn or "
                f"frontend={c.frontend!r} needs per-call side inputs")
        if c.kernel_mode == "pallas" and tune != "off":
            self._tune_gemms(params, batch * seq, tune=tune, cache=cache,
                             top_k=top_k, reps=reps)
        positions = jnp.broadcast_to(
            jnp.arange(seq, dtype=jnp.int32)[None], (batch, seq))
        pb = PlanBuilder(c.name, params, batch=batch,
                         sample_spec=((seq,), "int32"))
        pb.raw("embed", "embed", lambda t: self._embed(params, {"tokens": t}))
        for g in range(c.num_groups):
            gp = jax.tree_util.tree_map(lambda a, _g=g: a[_g],
                                        params["layers"])
            for i, kind in enumerate(c.pattern):
                pb.raw(
                    f"g{g}.b{i}", kind,
                    lambda x, p=gp[f"b{i}"], k=kind:
                        self._apply_block(k, p, x, positions, None)[0],
                )
        for i, kind in enumerate(c.tail_pattern):
            pb.raw(
                f"t{i}", kind,
                lambda x, p=params["tail"][f"t{i}"], k=kind:
                    self._apply_block(k, p, x, positions, None)[0],
            )
        pb.raw("head", "head",
               lambda x: self._logits(
                   params, self._apply_norm(params["final_norm"], x)))
        return pb.build()

    def compressed_abstract(self):
        """ShapeDtypeStruct tree of the *compressed* serving params."""
        return jax.eval_shape(lambda p: self.compress(p), self.abstract())

    def compressed_pspecs(self, rules: dict):
        """PartitionSpecs matching compress() output."""
        from jax.sharding import PartitionSpec as P

        specs = self.pspecs(rules)
        for path, pdef in dbb_leaves(self.defs()):
            if len(pdef.shape) > 3:
                continue
            base = tree_get(specs, path)  # P over (maybe layers,) K, N
            parts = tuple(base)
            if len(pdef.shape) == 2:
                k_ax, n_ax = parts
                vals = P(k_ax, None, n_ax)
                idx = P(k_ax, None, None)
            else:
                l_ax, k_ax, n_ax = parts
                vals = P(l_ax, k_ax, None, n_ax)
                idx = P(l_ax, k_ax, None, None)
            from repro.core.vdbb import DBBWeight

            dw_spec = DBBWeight(vals, idx, pdef.dbb, pdef.shape[-2:])
            specs = tree_set(specs, path, dw_spec)
        return specs
