"""Frozen serving plans (DESIGN.md §10).

A :class:`ModelPlan` is the once-per-model resolution of everything the
serving path would otherwise redo on every call: tuned tile configs
(``repro.kernels.autotune``), epilogue wiring, and the compressed/
quantized weight buffers themselves. Each layer's serving step is staged
into a closure with its parameters *frozen in*, and the whole chain is
jit-compiled once — weights become trace-time constants, so XLA folds
the per-call weight relayout (reshape / index expand / dtype cast) at
compile time and steady-state serving is a single dispatch with zero
per-call tile resolution, re-layout, or retracing.

Plans are immutable (frozen dataclasses) and *pinned to the exact
parameters they were built from*: :func:`params_fingerprint` hashes every
leaf (shapes, dtypes, bytes) plus the tree structure, and
``SparseCNN.apply(params, x, plan=plan)`` raises :class:`StalePlanError`
when the fingerprint no longer matches — e.g. after a re-``quantize()``
with fresh calibration. The hot path (``plan.serve(x)`` / ``plan(x)``)
skips the check; the checked ``apply(..., plan=)`` form is for callers
that still carry params and want the safety net.

A :class:`PlanSet` (DESIGN.md §11) lifts one plan to a serving *bucket
ladder*: each batch-size bucket maps to its own pre-compiled plan, and
``serve(x)`` pads any ragged batch up to the nearest bucket, dispatches
that bucket's frozen plan, and slices the padding back off — so variable
load never retraces and padded serving stays bit-identical to
per-request serving (batch rows are independent through conv/GEMM/GAP;
zero rows contribute nothing to anyone else's output). Every plan counts
its (re)traces, which is what lets the serving tier *prove* the
zero-retrace-after-warmup contract rather than assume it.
"""
from __future__ import annotations

import dataclasses
import hashlib
from types import MappingProxyType
from typing import Any, Callable, Mapping, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


class StalePlanError(RuntimeError):
    """A frozen plan was used with params it was not built from."""


def params_fingerprint(params) -> str:
    """Content hash of a param tree: tree structure (incl. static aux data
    like ``DBBFormat``), every leaf's shape/dtype, and its bytes. Computed
    once at plan build; any later re-quantize / re-compress / re-calibrate
    changes it."""
    h = hashlib.sha1()
    leaves, treedef = jax.tree_util.tree_flatten(params)
    h.update(repr(treedef).encode())
    for leaf in leaves:
        arr = np.asarray(leaf)
        h.update(str(arr.shape).encode())
        h.update(str(arr.dtype).encode())
        h.update(arr.tobytes())
    return h.hexdigest()


@dataclasses.dataclass(frozen=True)
class LayerPlan:
    """One staged serving stage: a name, the resolved tile config (sorted
    (key, value) pairs; empty for reference/XLA paths and the pooling
    stage), and the ``x -> y`` closure with weight buffers frozen in."""

    name: str
    kind: str  # 'conv' | 'linear' | 'pool'
    tiles: Tuple[Tuple[str, int], ...]
    run: Callable[[Any], Any]


@dataclasses.dataclass(frozen=True)
class ModelPlan:
    """Immutable per-model serving plan — build with ``SparseCNN.plan()``.

    ``serve(x)`` (also ``plan(x)``) runs the whole staged chain as one
    jit-compiled program. ``check(params)`` raises :class:`StalePlanError`
    on a fingerprint mismatch.
    """

    model: str
    fingerprint: str
    layers: Tuple[LayerPlan, ...]
    batch: Optional[int] = None  # the batch the plan was staged/tuned for

    def __post_init__(self):
        stages = tuple(l.run for l in self.layers)
        traces = {"count": 0}

        def chain(x):
            traces["count"] += 1  # runs at trace time only, not per dispatch
            for run in stages:
                x = run(x)
            return x

        object.__setattr__(self, "_serve", jax.jit(chain))
        object.__setattr__(self, "_traces", traces)

    def serve(self, x):
        """Steady-state serving: one dispatch, no checks, no params."""
        return self._serve(x)

    @property
    def trace_count(self) -> int:
        """How many times the staged chain has been (re)traced — one per
        distinct (shape, dtype, sharding) this plan has served. The
        serving tier snapshots this after warmup to enforce its
        zero-retrace contract (DESIGN.md §11)."""
        return self._traces["count"]

    def __call__(self, x):
        return self.serve(x)

    def check(self, params) -> None:
        if params_fingerprint(params) != self.fingerprint:
            raise StalePlanError(
                f"plan for {self.model!r} was built from different params "
                "(weights were re-quantized/re-compressed/re-calibrated "
                "after the plan was frozen) — rebuild with model.plan()"
            )

    @property
    def tiles(self) -> dict:
        """Per-layer resolved tile configs (introspection/bench)."""
        return {l.name: dict(l.tiles) for l in self.layers if l.tiles}


# ------------------------------------------------------------------ §11
def make_buckets(max_batch: int, *, dp: int = 1) -> Tuple[int, ...]:
    """The serving bucket ladder: ``dp``-multiple powers of two up to the
    first bucket ≥ ``max_batch`` (e.g. ``make_buckets(8) == (1, 2, 4, 8)``,
    ``make_buckets(6, dp=2) == (2, 4, 8)``). Every bucket is divisible by
    ``dp`` so a padded batch always shards evenly over the data axis of a
    device mesh."""
    if max_batch < 1:
        raise ValueError(f"max_batch must be >= 1, got {max_batch}")
    if dp < 1:
        raise ValueError(f"dp must be >= 1, got {dp}")
    out = [dp]
    while out[-1] < max_batch:
        out.append(out[-1] * 2)
    return tuple(out)


@dataclasses.dataclass(frozen=True)
class PlanSet:
    """A bucket ladder of frozen plans for one model (DESIGN.md §11).

    ``buckets`` is ascending and ``plans[b]`` is the :class:`ModelPlan`
    staged for batch ``b``. ``serve(x)`` handles any leading batch size:
    the batch is chunked at the largest bucket, each chunk is zero-padded
    up to the smallest bucket that fits, the bucket's pre-compiled plan
    runs, and the padding is sliced back off — bit-identical to serving
    each request alone (batch rows are independent end to end), with
    zero retraces once every bucket has been warmed.

    Build with ``SparseCNN.plan_set()``. The set shares its parent
    plans' immutability and params pin (one fingerprint for all
    buckets).
    """

    model: str
    fingerprint: str
    buckets: Tuple[int, ...]
    plans: Mapping[int, "ModelPlan"]

    def __post_init__(self):
        if not self.buckets:
            raise ValueError("PlanSet needs at least one bucket")
        if list(self.buckets) != sorted(set(self.buckets)):
            raise ValueError(f"buckets must be ascending+unique: {self.buckets}")
        if set(self.plans) != set(self.buckets):
            raise ValueError(
                f"plans keyed {sorted(self.plans)} != buckets {self.buckets}"
            )
        object.__setattr__(self, "plans", MappingProxyType(dict(self.plans)))

    # ------------------------------------------------------------ serve
    def bucket_for(self, n: int) -> Optional[int]:
        """Smallest bucket ≥ n, or None when n exceeds the largest bucket
        (``serve`` then chunks at the largest bucket)."""
        for b in self.buckets:
            if b >= n:
                return b
        return None

    def serve(self, x, *, put=None, on_dispatch=None):
        """Bucketed serving of any batch size.

        A numpy ``x`` takes the **host-assembly fast path**: chunk/pad/
        slice run as numpy on the host and the result comes back as
        numpy — only the pre-warmed bucket-shaped plan dispatch ever
        touches the device, so no glue op (pad, slice, concat) can
        trigger a first-occurrence XLA compile mid-traffic. This is the
        path the serving tier dispatches on. A jax ``x`` stays on-device
        end to end and returns jax.

        ``put`` (optional) maps each padded chunk onto devices — the
        serving tier injects ``device_put`` to a mesh's data-axis
        ``NamedSharding`` here. ``on_dispatch(bucket, n_real)`` (optional)
        observes each underlying plan dispatch (stats/bench hook).
        """
        n = x.shape[0]
        if n < 1:
            raise ValueError(f"empty batch: {x.shape}")
        host = isinstance(x, np.ndarray)
        xp = np if host else jnp
        cap = self.buckets[-1]
        outs = []
        i = 0
        while i < n:
            take = min(cap, n - i)
            b = self.bucket_for(take)
            xb = x[i : i + take]
            if take < b:
                pad = [(0, b - take)] + [(0, 0)] * (x.ndim - 1)
                xb = xp.pad(xb, pad)
            if put is not None:
                xb = put(xb)
            if on_dispatch is not None:
                on_dispatch(b, take)
            y = self.plans[b].serve(xb)
            if host:
                y = np.asarray(y)  # block + gather once, slice on the host
            outs.append(y if take == b else y[:take])
            i += take
        return outs[0] if len(outs) == 1 else xp.concatenate(outs, axis=0)

    def __call__(self, x):
        return self.serve(x)

    def warmup(self, sample_shape: Tuple[int, ...], dtype=jnp.float32,
               *, put=None) -> int:
        """Trace+compile every bucket once (``sample_shape`` is one
        sample, no batch dim — e.g. ``(H, W, C)``). Warms the same
        host→device transfer + dispatch signature the host-assembly
        ``serve`` path uses. Returns :attr:`trace_count` afterwards;
        serving any batch size through the same ``put`` after this
        retraces nothing."""
        for b in self.buckets:
            xb = np.zeros((b,) + tuple(sample_shape), dtype)
            self.serve(xb, put=put)
        return self.trace_count

    # ------------------------------------------------------- introspection
    @property
    def trace_count(self) -> int:
        """Total (re)traces across all buckets (zero-retrace contract)."""
        return sum(p.trace_count for p in self.plans.values())

    @property
    def tiles(self) -> dict:
        """Per-bucket per-layer resolved tile configs."""
        return {b: self.plans[b].tiles for b in self.buckets}

    def check(self, params) -> None:
        """Raise :class:`StalePlanError` unless ``params`` still matches
        the params every bucket's plan was frozen from."""
        if params_fingerprint(params) != self.fingerprint:
            raise StalePlanError(
                f"plan set for {self.model!r} was built from different "
                "params (weights were re-quantized/re-compressed/"
                "re-calibrated) — rebuild with model.plan_set()"
            )
