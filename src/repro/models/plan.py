"""Frozen serving plans (DESIGN.md §10).

A :class:`ModelPlan` is the once-per-model resolution of everything the
serving path would otherwise redo on every call: tuned tile configs
(``repro.kernels.autotune``), epilogue wiring, and the compressed/
quantized weight buffers themselves. Each layer's serving step is staged
into a closure with its parameters *frozen in*, and the whole chain is
jit-compiled once — weights become trace-time constants, so XLA folds
the per-call weight relayout (reshape / index expand / dtype cast) at
compile time and steady-state serving is a single dispatch with zero
per-call tile resolution, re-layout, or retracing.

Plans are immutable (frozen dataclasses) and *pinned to the exact
parameters they were built from*: :func:`params_fingerprint` hashes every
leaf (shapes, dtypes, bytes) plus the tree structure, and
``SparseCNN.apply(params, x, plan=plan)`` raises :class:`StalePlanError`
when the fingerprint no longer matches — e.g. after a re-``quantize()``
with fresh calibration. The hot path (``plan.serve(x)`` / ``plan(x)``)
skips the check; the checked ``apply(..., plan=)`` form is for callers
that still carry params and want the safety net.
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Any, Callable, Tuple

import jax
import numpy as np


class StalePlanError(RuntimeError):
    """A frozen plan was used with params it was not built from."""


def params_fingerprint(params) -> str:
    """Content hash of a param tree: tree structure (incl. static aux data
    like ``DBBFormat``), every leaf's shape/dtype, and its bytes. Computed
    once at plan build; any later re-quantize / re-compress / re-calibrate
    changes it."""
    h = hashlib.sha1()
    leaves, treedef = jax.tree_util.tree_flatten(params)
    h.update(repr(treedef).encode())
    for leaf in leaves:
        arr = np.asarray(leaf)
        h.update(str(arr.shape).encode())
        h.update(str(arr.dtype).encode())
        h.update(arr.tobytes())
    return h.hexdigest()


@dataclasses.dataclass(frozen=True)
class LayerPlan:
    """One staged serving stage: a name, the resolved tile config (sorted
    (key, value) pairs; empty for reference/XLA paths and the pooling
    stage), and the ``x -> y`` closure with weight buffers frozen in."""

    name: str
    kind: str  # 'conv' | 'linear' | 'pool'
    tiles: Tuple[Tuple[str, int], ...]
    run: Callable[[Any], Any]


@dataclasses.dataclass(frozen=True)
class ModelPlan:
    """Immutable per-model serving plan — build with ``SparseCNN.plan()``.

    ``serve(x)`` (also ``plan(x)``) runs the whole staged chain as one
    jit-compiled program. ``check(params)`` raises :class:`StalePlanError`
    on a fingerprint mismatch.
    """

    model: str
    fingerprint: str
    layers: Tuple[LayerPlan, ...]

    def __post_init__(self):
        stages = tuple(l.run for l in self.layers)

        def chain(x):
            for run in stages:
                x = run(x)
            return x

        object.__setattr__(self, "_serve", jax.jit(chain))

    def serve(self, x):
        """Steady-state serving: one dispatch, no checks, no params."""
        return self._serve(x)

    def __call__(self, x):
        return self.serve(x)

    def check(self, params) -> None:
        if params_fingerprint(params) != self.fingerprint:
            raise StalePlanError(
                f"plan for {self.model!r} was built from different params "
                "(weights were re-quantized/re-compressed/re-calibrated "
                "after the plan was frozen) — rebuild with model.plan()"
            )

    @property
    def tiles(self) -> dict:
        """Per-layer resolved tile configs (introspection/bench)."""
        return {l.name: dict(l.tiles) for l in self.layers if l.tiles}
