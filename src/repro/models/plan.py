"""Frozen serving plans (DESIGN.md §10).

A :class:`ModelPlan` is the once-per-model resolution of everything the
serving path would otherwise redo on every call: tuned tile configs
(``repro.kernels.autotune``), epilogue wiring, and the compressed/
quantized weight buffers themselves. Each layer's serving step is staged
into a closure with its parameters *frozen in*, and the whole chain is
jit-compiled once — weights become trace-time constants, so XLA folds
the per-call weight relayout (reshape / index expand / dtype cast) at
compile time and steady-state serving is a single dispatch with zero
per-call tile resolution, re-layout, or retracing.

Plans are immutable (frozen dataclasses) and *pinned to the exact
parameters they were built from*: :func:`params_fingerprint` hashes every
leaf (shapes, dtypes, bytes) plus the tree structure, and
``SparseCNN.apply(params, x, plan=plan)`` raises :class:`StalePlanError`
when the fingerprint no longer matches — e.g. after a re-``quantize()``
with fresh calibration. The hot path (``plan.serve(x)`` / ``plan(x)``)
skips the check; the checked ``apply(..., plan=)`` form is for callers
that still carry params and want the safety net.

A :class:`PlanSet` (DESIGN.md §11) lifts one plan to a serving *bucket
ladder*: each batch-size bucket maps to its own pre-compiled plan, and
``serve(x)`` pads any ragged batch up to the nearest bucket, dispatches
that bucket's frozen plan, and slices the padding back off — so variable
load never retraces and padded serving stays bit-identical to
per-request serving (batch rows are independent through conv/GEMM/GAP;
zero rows contribute nothing to anyone else's output). Every plan counts
its (re)traces, which is what lets the serving tier *prove* the
zero-retrace-after-warmup contract rather than assume it.
"""
from __future__ import annotations

import dataclasses
import hashlib
from types import MappingProxyType
from typing import Any, Callable, Mapping, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


class StalePlanError(RuntimeError):
    """A frozen plan was used with params it was not built from."""


def params_fingerprint(params) -> str:
    """Content hash of a param tree: tree structure (incl. static aux data
    like ``DBBFormat``), every leaf's shape/dtype, and its bytes. Computed
    once at plan build; any later re-quantize / re-compress / re-calibrate
    changes it."""
    h = hashlib.sha1()
    leaves, treedef = jax.tree_util.tree_flatten(params)
    h.update(repr(treedef).encode())
    for leaf in leaves:
        arr = np.asarray(leaf)
        h.update(str(arr.shape).encode())
        h.update(str(arr.dtype).encode())
        h.update(arr.tobytes())
    return h.hexdigest()


@dataclasses.dataclass(frozen=True)
class LayerPlan:
    """One staged serving stage: a name, the resolved tile config (sorted
    (key, value) pairs; empty for reference/XLA paths and the pooling
    stage), and the ``x -> y`` closure with weight buffers frozen in."""

    name: str
    kind: str  # 'conv' | 'linear' | 'pool'
    tiles: Tuple[Tuple[str, int], ...]
    run: Callable[[Any], Any]


@dataclasses.dataclass(frozen=True)
class ModelPlan:
    """Immutable per-model serving plan — build with ``SparseCNN.plan()``.

    ``serve(x)`` (also ``plan(x)``) runs the whole staged chain as one
    jit-compiled program. ``check(params)`` raises :class:`StalePlanError`
    on a fingerprint mismatch.
    """

    model: str
    fingerprint: str
    layers: Tuple[LayerPlan, ...]
    batch: Optional[int] = None  # the batch the plan was staged/tuned for
    # One sample's (shape-sans-batch, dtype-name) the plan was staged for,
    # e.g. ((32, 32, 3), 'float32') for a CNN or ((128,), 'int32') for LM
    # prefill. The serving tier validates every request against this at
    # admission (DESIGN.md §14) so malformed requests are rejected alone
    # instead of poisoning a co-batch. None for plans built before the
    # spec was known (validation is then skipped).
    sample_spec: Optional[Tuple[Tuple[int, ...], str]] = None

    def __post_init__(self):
        stages = tuple(l.run for l in self.layers)
        traces = {"count": 0}

        def chain(x):
            traces["count"] += 1  # runs at trace time only, not per dispatch
            for run in stages:
                x = run(x)
            return x

        object.__setattr__(self, "_serve", jax.jit(chain))
        object.__setattr__(self, "_traces", traces)

    def serve(self, x):
        """Steady-state serving: one dispatch, no checks, no params."""
        return self._serve(x)

    @property
    def trace_count(self) -> int:
        """How many times the staged chain has been (re)traced — one per
        distinct (shape, dtype, sharding) this plan has served. The
        serving tier snapshots this after warmup to enforce its
        zero-retrace contract (DESIGN.md §11)."""
        return self._traces["count"]

    def __call__(self, x):
        return self.serve(x)

    def check(self, params) -> None:
        if params_fingerprint(params) != self.fingerprint:
            raise StalePlanError(
                f"plan for {self.model!r} was built from different params "
                "(weights were re-quantized/re-compressed/re-calibrated "
                "after the plan was frozen) — rebuild with model.plan()"
            )

    @property
    def tiles(self) -> dict:
        """Per-layer resolved tile configs (introspection/bench)."""
        return {l.name: dict(l.tiles) for l in self.layers if l.tiles}


# ------------------------------------------------------------------ §11
def make_buckets(max_batch: int, *, dp: int = 1) -> Tuple[int, ...]:
    """The serving bucket ladder: ``dp``-multiple powers of two up to the
    first bucket ≥ ``max_batch`` (e.g. ``make_buckets(8) == (1, 2, 4, 8)``,
    ``make_buckets(6, dp=2) == (2, 4, 8)``). Every bucket is divisible by
    ``dp`` so a padded batch always shards evenly over the data axis of a
    device mesh."""
    if max_batch < 1:
        raise ValueError(f"max_batch must be >= 1, got {max_batch}")
    if dp < 1:
        raise ValueError(f"dp must be >= 1, got {dp}")
    out = [dp]
    while out[-1] < max_batch:
        out.append(out[-1] * 2)
    return tuple(out)


@dataclasses.dataclass(frozen=True)
class PlanSet:
    """A bucket ladder of frozen plans for one model (DESIGN.md §11).

    ``buckets`` is ascending and ``plans[b]`` is the :class:`ModelPlan`
    staged for batch ``b``. ``serve(x)`` handles any leading batch size:
    the batch is chunked at the largest bucket, each chunk is zero-padded
    up to the smallest bucket that fits, the bucket's pre-compiled plan
    runs, and the padding is sliced back off — bit-identical to serving
    each request alone (batch rows are independent end to end), with
    zero retraces once every bucket has been warmed.

    Build with ``SparseCNN.plan_set()``. The set shares its parent
    plans' immutability and params pin (one fingerprint for all
    buckets).
    """

    model: str
    fingerprint: str
    buckets: Tuple[int, ...]
    plans: Mapping[int, "ModelPlan"]
    # shared per-sample admission spec (see ModelPlan.sample_spec);
    # build_plan_set inherits it from the bucket plans.
    sample_spec: Optional[Tuple[Tuple[int, ...], str]] = None

    def __post_init__(self):
        if not self.buckets:
            raise ValueError("PlanSet needs at least one bucket")
        if list(self.buckets) != sorted(set(self.buckets)):
            raise ValueError(f"buckets must be ascending+unique: {self.buckets}")
        if set(self.plans) != set(self.buckets):
            raise ValueError(
                f"plans keyed {sorted(self.plans)} != buckets {self.buckets}"
            )
        object.__setattr__(self, "plans", MappingProxyType(dict(self.plans)))

    # ------------------------------------------------------------ serve
    def bucket_for(self, n: int) -> Optional[int]:
        """Smallest bucket ≥ n, or None when n exceeds the largest bucket
        (``serve`` then chunks at the largest bucket)."""
        for b in self.buckets:
            if b >= n:
                return b
        return None

    def serve(self, x, *, put=None, on_dispatch=None, dispatch=None):
        """Bucketed serving of any batch size.

        A numpy ``x`` takes the **host-assembly fast path**: chunk/pad/
        slice run as numpy on the host and the result comes back as
        numpy — only the pre-warmed bucket-shaped plan dispatch ever
        touches the device, so no glue op (pad, slice, concat) can
        trigger a first-occurrence XLA compile mid-traffic. This is the
        path the serving tier dispatches on. A jax ``x`` stays on-device
        end to end and returns jax.

        ``put`` (optional) maps each padded chunk onto devices — the
        serving tier injects ``device_put`` to a mesh's data-axis
        ``NamedSharding`` here. ``on_dispatch(bucket, n_real)`` (optional)
        observes each underlying plan dispatch (stats/bench hook).
        ``dispatch(bucket, xb)`` (optional) replaces the per-bucket plan
        dispatch itself — the §15 degradation path routes a demoted
        bucket to its ref fallback closure here while chunk/pad/slice
        stay identical.
        """
        n = x.shape[0]
        if n < 1:
            raise ValueError(f"empty batch: {x.shape}")
        host = isinstance(x, np.ndarray)
        xp = np if host else jnp
        cap = self.buckets[-1]
        outs = []
        i = 0
        while i < n:
            take = min(cap, n - i)
            b = self.bucket_for(take)
            xb = x[i : i + take]
            if take < b:
                pad = [(0, b - take)] + [(0, 0)] * (x.ndim - 1)
                xb = xp.pad(xb, pad)
            if put is not None:
                xb = put(xb)
            if on_dispatch is not None:
                on_dispatch(b, take)
            y = (self.plans[b].serve(xb) if dispatch is None
                 else dispatch(b, xb))
            if host:
                y = np.asarray(y)  # block + gather once, slice on the host
            outs.append(y if take == b else y[:take])
            i += take
        return outs[0] if len(outs) == 1 else xp.concatenate(outs, axis=0)

    def __call__(self, x):
        return self.serve(x)

    def warmup(self, sample_shape: Optional[Tuple[int, ...]] = None,
               dtype=jnp.float32, *, put=None) -> int:
        """Trace+compile every bucket once (``sample_shape`` is one
        sample, no batch dim — e.g. ``(H, W, C)``; defaults to the set's
        own :attr:`sample_spec`). Warms the same host→device transfer +
        dispatch signature the host-assembly ``serve`` path uses. Returns
        :attr:`trace_count` afterwards; serving any batch size through
        the same ``put`` after this retraces nothing."""
        if sample_shape is None:
            if self.sample_spec is None:
                raise ValueError(
                    "warmup() needs sample_shape: this plan set carries no "
                    "sample_spec")
            sample_shape, dtype = self.sample_spec
        for b in self.buckets:
            xb = np.zeros((b,) + tuple(sample_shape), dtype)
            self.serve(xb, put=put)
        return self.trace_count

    # ------------------------------------------------------- introspection
    @property
    def trace_count(self) -> int:
        """Total (re)traces across all buckets (zero-retrace contract)."""
        return sum(p.trace_count for p in self.plans.values())

    @property
    def tiles(self) -> dict:
        """Per-bucket per-layer resolved tile configs."""
        return {b: self.plans[b].tiles for b in self.buckets}

    def check(self, params) -> None:
        """Raise :class:`StalePlanError` unless ``params`` still matches
        the params every bucket's plan was frozen from."""
        if params_fingerprint(params) != self.fingerprint:
            raise StalePlanError(
                f"plan set for {self.model!r} was built from different "
                "params (weights were re-quantized/re-compressed/"
                "re-calibrated) — rebuild with model.plan_set()"
            )


# ----------------------------------------------------------------- §15
def fallback_closures(primary: "PlanSet", fallback: "PlanSet", *,
                      verify: bool = True, rtol: float = 0.0) -> dict:
    """Per-bucket degradation closures for the self-healing serving tier
    (DESIGN.md §15): ``{bucket: serve_callable}`` built from a second
    :class:`PlanSet` staged on the reference (gather/interpreter) kernel
    path. When a bucket's compiled (pallas) dispatch persistently fails,
    the server demotes exactly that bucket to its closure here; every
    other bucket keeps the compiled path.

    Bit-compat is **asserted at build time** (``verify=True``): the two
    sets must share the params fingerprint, buckets, and sample spec, and
    every bucket is served a deterministic batch through both paths —
    outputs must match exactly (``rtol=0``, the int8 datapath's integer
    accumulation is bit-identical between ref and pallas) or within
    ``rtol``. The verification pass doubles as the fallback's warmup, so
    a later demotion dispatches an already-compiled closure and adds
    zero mid-traffic traces.
    """
    if primary.fingerprint != fallback.fingerprint:
        raise StalePlanError(
            "fallback plan set was built from different params than the "
            "primary — rebuild both from the same quantized weights")
    if tuple(primary.buckets) != tuple(fallback.buckets):
        raise ValueError(
            f"fallback buckets {fallback.buckets} != primary "
            f"{primary.buckets} — a demoted bucket must keep its ladder")
    if (primary.sample_spec is not None
            and fallback.sample_spec != primary.sample_spec):
        raise ValueError(
            f"fallback sample spec {fallback.sample_spec} != primary "
            f"{primary.sample_spec}")
    if verify:
        if primary.sample_spec is None:
            raise ValueError("bit-compat verification needs a sample_spec")
        shape, dtype = primary.sample_spec
        rng = np.random.default_rng(0)
        for b in primary.buckets:
            xb = rng.standard_normal((b,) + tuple(shape)).astype(dtype)
            yp = np.asarray(primary.plans[b].serve(xb))
            yf = np.asarray(fallback.plans[b].serve(xb))
            if rtol == 0.0:
                np.testing.assert_array_equal(
                    yf, yp,
                    err_msg=f"fallback bucket {b} is not bit-compatible "
                            "with the compiled path")
            else:
                np.testing.assert_allclose(
                    yf, yp, rtol=rtol,
                    err_msg=f"fallback bucket {b} diverges beyond "
                            f"rtol={rtol} from the compiled path")
    return {b: fallback.plans[b].serve for b in fallback.buckets}


# ----------------------------------------------------------------- §13
# Model-agnostic plan staging. SparseCNN.plan/plan_set and LM.plan are
# thin compositions over these — any model family stages per-layer
# closures through a PlanBuilder and inherits fingerprint pinning, tile
# resolution (one TuneCache parse per build), and bucketed PlanSets.


def resolve_tune_cache(tune: str, cache):
    """Parse the on-disk autotune cache once per plan build (``tune='off'``
    skips it). Idempotent: an already-parsed ``TuneCache`` passes through,
    so nested builders (plan_set → plan per bucket) share one parse."""
    if tune == "off":
        return cache
    from repro.kernels.autotune import TuneCache

    if not isinstance(cache, TuneCache):
        cache = TuneCache(cache)
    return cache


class PlanBuilder:
    """Collects staged serving layers into an immutable :class:`ModelPlan`.

    One builder per (model, params, batch): the params fingerprint is
    taken at construction, tuning knobs are normalized once
    (:func:`resolve_tune_cache`), and every :meth:`stage` call receives
    the shared ``tune/cache/top_k/reps`` keywords so per-layer
    ``make_plan`` implementations resolve tiles against the same cache.
    Layers that stage plain closures without tile resolution (pooling,
    norms, whole transformer blocks) use :meth:`raw`.
    """

    def __init__(self, model: str, params, *, batch: Optional[int] = None,
                 tune: str = "cache", cache=None, top_k: int = 4,
                 reps: int = 3,
                 sample_spec: Optional[Tuple[Tuple[int, ...], str]] = None):
        self.model = model
        self.batch = batch
        self.sample_spec = sample_spec
        self.fingerprint = params_fingerprint(params)
        self.tune = tune
        self.cache = resolve_tune_cache(tune, cache)
        self.top_k = top_k
        self.reps = reps
        self._stages: list = []

    @property
    def tune_kw(self) -> dict:
        """The shared tuning keywords every ``make_plan`` receives."""
        return dict(tune=self.tune, cache=self.cache, top_k=self.top_k,
                    reps=self.reps)

    def stage(self, name: str, kind: str, make_plan: Callable, *args, **kw):
        """Stage one layer via its ``make_plan(*args, **kw, **tune_kw)``
        → ``(run, tiles)`` contract. Returns self (chainable)."""
        run, tiles = make_plan(*args, **kw, **self.tune_kw)
        self._stages.append(
            LayerPlan(name, kind, tuple(sorted(tiles.items())), run)
        )
        return self

    def raw(self, name: str, kind: str, run: Callable):
        """Stage a tile-free closure (weights already frozen in)."""
        self._stages.append(LayerPlan(name, kind, (), run))
        return self

    def build(self) -> ModelPlan:
        if not self._stages:
            raise ValueError("PlanBuilder has no stages")
        return ModelPlan(self.model, self.fingerprint, tuple(self._stages),
                         self.batch, self.sample_spec)


def build_plan_set(model: str, params, plan_for_batch: Callable[[int], ModelPlan],
                   *, max_batch: Optional[int] = None, buckets=None,
                   dp: int = 1) -> PlanSet:
    """Bucket-ladder :class:`PlanSet` from a per-batch plan factory.

    Derives/validates the ladder (``make_buckets`` powers of two when
    ``buckets`` is None; every bucket a positive multiple of ``dp``),
    builds one plan per bucket via ``plan_for_batch(b)``, and pins the
    set to ``params``. Model families supply only the factory.
    """
    if buckets is None:
        if max_batch is None:
            raise ValueError("plan set needs max_batch or explicit buckets")
        buckets = make_buckets(max_batch, dp=dp)
    buckets = tuple(sorted({int(b) for b in buckets}))
    bad = [b for b in buckets if b < 1 or b % dp]
    if bad:
        raise ValueError(f"buckets {bad} not positive multiples of dp={dp}")
    plans = {b: plan_for_batch(b) for b in buckets}
    # every bucket stages the same per-sample signature — inherit the
    # admission spec (DESIGN.md §14) from the first plan that carries one
    spec = next(
        (p.sample_spec for p in plans.values() if p.sample_spec is not None),
        None,
    )
    return PlanSet(model, params_fingerprint(params), buckets, plans, spec)
