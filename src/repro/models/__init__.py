from repro.models.config import ModelConfig  # noqa: F401
from repro.models.model import LM  # noqa: F401
