"""SparseCNN — a small CNN inference model on the paper's datapath.

The paper's workload is sparse CNN inference (its Table I/II models are
AlexNet/ResNet-50-class CNNs). This module provides that workload as a
first-class model next to the LM zoo: a VGG-style stack of DBBConv2d
stages (conv → ReLU, stride-2 downsample between stages) closed by global
average pooling and a DBBLinear classifier head.

Same three-phase lifecycle as the LM (train → constrain → compress):
``constrain()`` projects every conv/linear weight onto the DBB constraint,
``compress()`` converts them to the compressed DBBWeight layout, and the
forward pass then runs the fused IM2COL × VDBB conv per layer
(``kernel_mode='pallas'``) or the decode + XLA conv reference path.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core.sparse_conv import DBBConv2d
from repro.core.sparse_linear import DBBLinear, PruneSchedule
from repro.core.vdbb import DBBFormat, DENSE


@dataclasses.dataclass(frozen=True)
class CNNConfig:
    """Static description of a SparseCNN.

    stage_channels: output channels per stage; stage i > 0 downsamples 2×.
    convs_per_stage: conv layers in each stage (first one carries the stride).
    """

    name: str = "sparse-cnn"
    in_channels: int = 3
    image_size: int = 32
    stage_channels: Sequence[int] = (32, 64, 128)
    convs_per_stage: int = 2
    kernel_size: int = 3
    num_classes: int = 10
    dbb: Optional[DBBFormat] = None
    dtype: Any = jnp.float32
    kernel_mode: str = "ref"  # 'ref' | 'pallas'

    @property
    def fmt(self) -> DBBFormat:
        return self.dbb or DENSE

    def param_count(self) -> int:
        total = 0
        for layer in SparseCNN(self).layers():
            if isinstance(layer, DBBConv2d):
                total += layer.kh * layer.kw * layer.in_channels * layer.out_channels
            elif isinstance(layer, DBBLinear):
                total += layer.in_features * layer.out_features
        return total


@dataclasses.dataclass(frozen=True)
class SparseCNN:
    cfg: CNNConfig

    # ------------------------------------------------------------- defs
    def layers(self):
        """Ordered (conv... , linear head) layer modules."""
        c = self.cfg
        out = []
        prev = c.in_channels
        for si, ch in enumerate(c.stage_channels):
            for li in range(c.convs_per_stage):
                stride = 2 if (si > 0 and li == 0) else 1
                # the stem (prev == in_channels) stays dense: C=3 is not
                # bz-blockable, matching the paper's uncompressed first layer.
                fmt = c.fmt if prev % c.fmt.bz == 0 else DENSE
                out.append(
                    DBBConv2d(
                        prev, ch, kernel_size=c.kernel_size, stride=stride,
                        padding="SAME", fmt=fmt, use_bias=True, dtype=c.dtype,
                        kernel_mode=c.kernel_mode,
                    )
                )
                prev = ch
        out.append(
            DBBLinear(
                prev, c.num_classes, fmt=c.fmt, use_bias=True, dtype=c.dtype,
                kernel_mode="ref",  # head GEMM: M=batch, tiny — ref path
            )
        )
        return out

    def init(self, key) -> dict:
        layers = self.layers()
        keys = jax.random.split(key, len(layers))
        return {f"l{i}": m.init(k) for i, (m, k) in enumerate(zip(layers, keys))}

    # ---------------------------------------------------------- forward
    def __call__(self, params: dict, x: jax.Array) -> jax.Array:
        """Inference forward. x: (N, H, W, C) -> logits (N, num_classes)."""
        layers = self.layers()
        for i, m in enumerate(layers[:-1]):
            x = jax.nn.relu(m(params[f"l{i}"], x))
        x = x.mean(axis=(1, 2))  # global average pool
        return layers[-1](params[f"l{len(layers) - 1}"], x)

    # ------------------------------------------- the paper's technique
    def constrain(self, params: dict, step=None, schedule: Optional[PruneSchedule] = None) -> dict:
        out = {}
        for i, m in enumerate(self.layers()):
            out[f"l{i}"] = m.constrain(params[f"l{i}"], step, schedule)
        return out

    def compress(self, params: dict) -> dict:
        out = {}
        for i, m in enumerate(self.layers()):
            out[f"l{i}"] = m.compress_params(params[f"l{i}"])
        return out

    # ------------------------------------------------------------ costs
    def flops(self, batch: int) -> int:
        """Executed MACs*2 under the time-unrolled occupancy model."""
        c = self.cfg
        h = w = c.image_size
        total = 0
        for m in self.layers():
            if isinstance(m, DBBConv2d):
                total += m.flops(batch, h, w)
                h, w = m.out_hw(h, w)
            else:
                total += m.flops(batch)
        return total
