"""SparseCNN — a small CNN inference model on the paper's datapath.

The paper's workload is sparse CNN inference (its Table I/II models are
AlexNet/ResNet-50-class CNNs). This module provides that workload as a
first-class model next to the LM zoo: a VGG-style stack of DBBConv2d
stages (conv → ReLU, stride-2 downsample between stages) closed by global
average pooling and a DBBLinear classifier head.

Same lifecycle as the LM (train → constrain → compress), plus the INT8
serving step: ``constrain()`` projects every conv/linear weight onto the
DBB constraint, ``compress()`` converts them to the compressed DBBWeight
layout, ``quantize()`` (optionally calibrated by the stats from
``apply(collect_act_stats=True)``) converts to the ASIC's INT8 numerics
(DESIGN.md §8), and the forward pass then runs the fused IM2COL × VDBB
conv per layer (``kernel_mode='pallas'``) or the decode + XLA conv
reference path.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core.quant import QuantDBBWeight, quantize
from repro.core.sparse_conv import DBBConv2d
from repro.core.sparse_linear import DBBLinear, PruneSchedule
from repro.core.vdbb import DBBFormat, DENSE
from repro.kernels.core import _pair, default_interpret


@dataclasses.dataclass(frozen=True)
class CNNConfig:
    """Static description of a SparseCNN.

    stage_channels: output channels per stage; stage i > 0 downsamples 2×.
    convs_per_stage: conv layers in each stage (first one carries the stride).
    """

    name: str = "sparse-cnn"
    in_channels: int = 3
    image_size: int = 32
    stage_channels: Sequence[int] = (32, 64, 128)
    convs_per_stage: int = 2
    kernel_size: int = 3
    num_classes: int = 10
    dbb: Optional[DBBFormat] = None
    dtype: Any = jnp.float32
    kernel_mode: str = "ref"  # 'ref' | 'pallas'

    @property
    def fmt(self) -> DBBFormat:
        return self.dbb or DENSE

    def param_count(self) -> int:
        total = 0
        for layer in SparseCNN(self).layers():
            if isinstance(layer, DBBConv2d):
                total += layer.kh * layer.kw * layer.in_channels * layer.out_channels
            elif isinstance(layer, DBBLinear):
                total += layer.in_features * layer.out_features
        return total


@dataclasses.dataclass(frozen=True)
class SparseCNN:
    cfg: CNNConfig

    # ------------------------------------------------------------- defs
    def layers(self):
        """Ordered (conv... , linear head) layer modules."""
        c = self.cfg
        out = []
        prev = c.in_channels
        for si, ch in enumerate(c.stage_channels):
            for li in range(c.convs_per_stage):
                stride = 2 if (si > 0 and li == 0) else 1
                # the stem (prev == in_channels) stays dense: C=3 is not
                # bz-blockable, matching the paper's uncompressed first layer.
                fmt = c.fmt if prev % c.fmt.bz == 0 else DENSE
                out.append(
                    DBBConv2d(
                        prev, ch, kernel_size=c.kernel_size, stride=stride,
                        padding="SAME", fmt=fmt, use_bias=True, dtype=c.dtype,
                        kernel_mode=c.kernel_mode,
                    )
                )
                prev = ch
        out.append(
            DBBLinear(
                prev, c.num_classes, fmt=c.fmt, use_bias=True, dtype=c.dtype,
                # head GEMM follows the model's kernel mode; DBBLinear
                # itself falls back to the reference for tiny M (< the
                # MXU sublane), so small batches never waste a launch.
                kernel_mode=c.kernel_mode,
            )
        )
        return out

    def init(self, key) -> dict:
        layers = self.layers()
        keys = jax.random.split(key, len(layers))
        return {f"l{i}": m.init(k) for i, (m, k) in enumerate(zip(layers, keys))}

    # ---------------------------------------------------------- forward
    def __call__(self, params: dict, x: jax.Array) -> jax.Array:
        """Inference forward. x: (N, H, W, C) -> logits (N, num_classes)."""
        return self.apply(params, x)

    def apply(
        self,
        params: dict,
        x: jax.Array,
        *,
        plan=None,
        collect_act_stats: bool = False,
        act_threshold: float = 0.0,
        intermediates: Optional[list] = None,
    ):
        """Inference forward, optionally measuring activation sparsity.

        With ``collect_act_stats=True`` (eager-only; DESIGN.md §7) returns
        ``(logits, stats)`` where ``stats`` is one
        :class:`repro.core.act_sparsity.ActStats` per layer, measured on
        the activation each layer *reads* (the tensor the IM2COL unit /
        GEMM streams), MAC-weighted for whole-model composition.

        Calibrated quantized params (every compressed layer carrying a
        static ``aq`` act scale) take the **int8-resident** serving chain
        (DESIGN.md §9): each layer is one fused kernel whose epilogue
        requantizes straight to the next layer's int8 codes — no
        standalone fp32 dequant/ReLU/requant passes between compressed
        layers. ``intermediates`` (optional list, eager-only) collects
        each inter-layer activation so callers can assert dtypes.

        ``plan`` (a :class:`repro.models.plan.ModelPlan` from
        :meth:`plan`, DESIGN.md §10) serves through the frozen staged
        chain after checking the plan still matches ``params``
        (:class:`~repro.models.plan.StalePlanError` otherwise); the
        check-free hot path is ``plan.serve(x)`` directly.
        """
        if plan is not None:
            if collect_act_stats or intermediates is not None:
                raise ValueError(
                    "plan serving is the frozen hot path; run without "
                    "plan= to collect stats or intermediates"
                )
            plan.check(params)
            return plan.serve(x)
        layers = self.layers()
        if not collect_act_stats and self._int8_chain_ready(layers, params):
            return self._apply_int8_resident(layers, params, x, intermediates)
        stats = []
        if collect_act_stats:
            from repro.core.act_sparsity import measure_activation

            h, w = x.shape[1], x.shape[2]
        for i, m in enumerate(layers[:-1]):
            if collect_act_stats:
                stats.append(
                    measure_activation(
                        x, name=f"l{i}", threshold=act_threshold,
                        macs=m.flops(x.shape[0], h, w) // 2,
                    )
                )
                h, w = m.out_hw(h, w)
            x = jax.nn.relu(m(params[f"l{i}"], x))
            if intermediates is not None:
                intermediates.append(x)
        x = x.mean(axis=(1, 2))  # global average pool
        head = layers[-1]
        if collect_act_stats:
            stats.append(
                measure_activation(
                    x, name=f"l{len(layers) - 1}", threshold=act_threshold,
                    macs=head.flops(x.shape[0]) // 2,
                )
            )
        logits = head(params[f"l{len(layers) - 1}"], x)
        if collect_act_stats:
            return logits, tuple(stats)
        return logits

    # ----------------------------------- int8-resident serving chain (§9)
    def _int8_chain_ready(self, layers, params: dict) -> bool:
        """True iff serving can run int8-resident end to end: every
        compressed conv after the (possibly fp) stem is quantized with a
        calibrated static ``aq`` (needed both to read int8 codes and as
        the previous layer's requantize target), and the head is
        quantized. Anything else falls back to the per-layer fp path."""
        any_quant = False
        for i, m in enumerate(layers[:-1]):
            p = params.get(f"l{i}", {})
            w = p.get("w")
            if isinstance(w, QuantDBBWeight):
                if "aq" not in p:
                    return False
                any_quant = True
            elif i > 0:  # a mid-chain fp layer would need a dequant pass
                return False
        head = params.get(f"l{len(layers) - 1}", {})
        return any_quant and isinstance(head.get("w"), QuantDBBWeight)

    def _apply_int8_resident(self, layers, params: dict, x: jax.Array,
                             intermediates: Optional[list] = None) -> jax.Array:
        """One fused kernel per layer, int8 activations in between (§9).

        Every compressed conv consumes the previous layer's int8 codes
        and its epilogue (dequant · bias · ReLU · requant at the next
        layer's calibrated scale) emits the next codes straight from the
        accumulator flush. The fp32 stem fuses bias + ReLU + the first
        requantize into its own kernel on the Pallas path (one standalone
        quantize pass on the ref path); the last conv flushes fp32
        (bias + ReLU still fused) into global average pooling, and the
        quantized head GEMM (bias fused) produces the fp32 logits.
        """
        convs, head = layers[:-1], layers[-1]
        n = len(convs)
        for i, m in enumerate(convs):
            p = params[f"l{i}"]
            out_scale = params[f"l{i + 1}"]["aq"] if i + 1 < n else None
            if isinstance(p["w"], QuantDBBWeight):
                x = m.quant_serve(p, x, relu=True, out_scale=out_scale)
            elif m.kernel_mode == "pallas" and out_scale is not None \
                    and not default_interpret():
                # fp stem, one kernel: dense conv with the fused epilogue
                # (compiled backends only — interpret-mode Pallas dense
                # conv is far slower than XLA's native conv on CPU, so
                # there the ref-path conv + standalone quantize wins;
                # DESIGN.md §12)
                from repro.kernels import ops  # deferred: kernels are optional

                x = ops.fused_im2col_conv(
                    x, p["w"], bias=p.get("b"), relu=True, out_scale=out_scale,
                    stride=_pair(m.stride), padding=m.padding,
                )
            else:
                # fp stem, ref path: conv (+bias) · ReLU · one int8
                # quantize at the next layer's calibrated scale — the only
                # standalone fp32 activation pass in the chain.
                x = jax.nn.relu(m(p, x))
                if out_scale is not None:
                    x = quantize(x, out_scale)
            if intermediates is not None:
                intermediates.append(x)
        x = x.mean(axis=(1, 2))  # global average pool (fp32 flush above)
        return head.quant_serve(params[f"l{n}"], x)

    # ------------------------------------------- frozen serving plans (§10)
    def plan(self, params: dict, *, batch: int, tune: str = "cache",
             cache=None, top_k: int = 4, reps: int = 3):
        """Freeze a once-per-model serving plan (DESIGN.md §10).

        Resolves every layer's tuned tile config (autotune registry →
        persistent cache → search when ``tune='search'``; ``'cache'``
        never searches, ``'off'`` keeps pick_tile defaults), stages each
        layer's serving closure with its weight buffers frozen in —
        replicating exactly the path :meth:`apply` takes for these params,
        including the §9 int8-resident chain when calibrated quantized
        params are detected — and jit-compiles the whole chain once.
        Steady-state serving (``plan.serve(x)``) is then a single dispatch
        with zero per-call tile resolution, weight re-layout, or
        retracing. The plan is immutable and pinned to ``params`` by
        content fingerprint; serving through :meth:`apply`'s ``plan=``
        kwarg re-checks that pin.

        ``batch`` fixes the input batch size the plan is staged (and
        tuned) for — other batch shapes still run, but retrace and fall
        back to registry/default tiles.
        """
        from repro.models.plan import PlanBuilder

        layers = self.layers()
        convs, head = layers[:-1], layers[-1]
        fused = self._int8_chain_ready(layers, params)
        c = self.cfg
        h = w = c.image_size
        n = len(convs)
        pb = PlanBuilder(c.name, params, batch=batch, tune=tune, cache=cache,
                         top_k=top_k, reps=reps,
                         sample_spec=((c.image_size, c.image_size,
                                       c.in_channels), "float32"))
        for i, m in enumerate(convs):
            out_scale = None
            if fused and i + 1 < n:
                out_scale = params[f"l{i + 1}"]["aq"]
            pb.stage(f"l{i}", "conv", m.make_plan, params[f"l{i}"],
                     batch=batch, h=h, w=w, relu=True, out_scale=out_scale,
                     fused=fused)
            h, w = m.out_hw(h, w)
        pb.raw("gap", "pool", lambda x: x.mean(axis=(1, 2)))
        pb.stage(f"l{n}", "linear", head.make_plan, params[f"l{n}"],
                 batch=batch, fused=fused)
        return pb.build()

    def plan_set(self, params: dict, *, max_batch: Optional[int] = None,
                 buckets: Optional[Sequence[int]] = None, dp: int = 1,
                 tune: str = "cache", cache=None, top_k: int = 4,
                 reps: int = 3):
        """Freeze a bucketed serving plan set (DESIGN.md §11).

        One :meth:`plan` per batch-size bucket, all sharing the same
        tune cache and params fingerprint. ``buckets`` defaults to the
        power-of-two ladder ``make_buckets(max_batch, dp=dp)``; ``dp``
        (the data-parallel degree the set will be served at) forces
        every bucket to shard evenly over a mesh's data axis. The
        returned :class:`~repro.models.plan.PlanSet` serves any batch
        size retrace-free after warmup: ragged batches pad up to the
        nearest bucket and slice back, bit-identical to per-request
        serving.
        """
        from repro.models.plan import build_plan_set, resolve_tune_cache

        cache = resolve_tune_cache(tune, cache)  # one parse for all buckets
        return build_plan_set(
            self.cfg.name, params,
            lambda b: self.plan(params, batch=b, tune=tune, cache=cache,
                                top_k=top_k, reps=reps),
            max_batch=max_batch, buckets=buckets, dp=dp,
        )

    def fallback_plan_set(self, params: dict, primary, *, verify: bool = True):
        """Per-bucket degradation closures for the §15 self-healing tier:
        re-stage ``primary``'s bucket ladder on the reference
        (gather/integer-oracle) kernel path from the *same* quantized
        params, verify bit-compat per bucket, and return the
        ``{bucket: serve}`` mapping ``CNNServer(fallback=...)`` consumes.
        The params fingerprint is content-based, so the ref restage pins
        to the identical weights — a demoted bucket serves the same
        numbers through a different backend, not a different model."""
        import dataclasses as _dc

        from repro.models.plan import fallback_closures

        ref_model = SparseCNN(_dc.replace(self.cfg, kernel_mode="ref"))
        ref_set = ref_model.plan_set(params, buckets=primary.buckets,
                                     tune="off")
        return fallback_closures(primary, ref_set, verify=verify)

    # ------------------------------------------- the paper's technique
    def constrain(self, params: dict, step=None, schedule: Optional[PruneSchedule] = None) -> dict:
        out = {}
        for i, m in enumerate(self.layers()):
            out[f"l{i}"] = m.constrain(params[f"l{i}"], step, schedule)
        return out

    def compress(self, params: dict) -> dict:
        out = {}
        for i, m in enumerate(self.layers()):
            out[f"l{i}"] = m.compress_params(params[f"l{i}"])
        return out

    def quantize(self, params: dict, stats=None) -> dict:
        """INT8 serving conversion of compressed params (DESIGN.md §8).

        ``stats`` (optional): per-layer calibration :class:`ActStats` from
        ``apply(params, x_cal, collect_act_stats=True)`` — one per layer,
        measured on the activation each layer *reads*, whose ``absmax``
        becomes that layer's static per-tensor activation scale. Without
        stats, activation scales are dynamic (computed per batch). Dense
        layers (the C=3 stem) stay fp32, like the paper's uncompressed
        first layer.
        """
        from repro.core.quant import act_scale_from_stats

        layers = self.layers()
        if stats is not None and len(stats) != len(layers):
            raise ValueError(
                f"calibration stats for {len(stats)} layers, model has {len(layers)}"
            )
        out = {}
        for i, m in enumerate(layers):
            scale = act_scale_from_stats(stats[i]) if stats is not None else None
            out[f"l{i}"] = m.quantize(params[f"l{i}"], act_scale=scale)
        return out

    # ------------------------------------------------------------ costs
    def layer_costs(self, batch: int, *, bits: int = 8, act_bits=None,
                    stats=None, epilogue_fused: bool = False) -> list:
        """Per-conv-layer ``dbb_conv_costs`` dicts for this model.

        ``stats`` (optional): per-layer ActStats from
        ``apply(collect_act_stats=True)`` — layer i's measured activation
        sparsity is recorded into its cost dict, ready for
        ``energy_model.model_workload``. ``bits``/``act_bits`` are the
        operand widths (8 = the INT8 serving path of ``quantize()``);
        ``epilogue_fused`` accounts the §9 fused epilogue (int8 flush, no
        standalone dequant/requant passes). Returns (name, costs, fmt)
        triples.
        """
        from repro.core.vdbb import dbb_conv_costs

        c = self.cfg
        h = w = c.image_size
        out = []
        for i, m in enumerate(self.layers()):
            if not isinstance(m, DBBConv2d):
                continue
            act = stats[i] if stats is not None else None
            out.append(
                (
                    f"l{i}",
                    dbb_conv_costs(
                        batch, h, w, m.in_channels, m.out_channels, m.kh, m.kw,
                        m.fmt, stride=m.stride, padding=m.padding, bits=bits,
                        act_bits=act_bits, act=act, epilogue_fused=epilogue_fused,
                    ),
                    m.fmt,
                )
            )
            h, w = m.out_hw(h, w)
        return out

    def flops(self, batch: int) -> int:
        """Executed MACs*2 under the time-unrolled occupancy model."""
        c = self.cfg
        h = w = c.image_size
        total = 0
        for m in self.layers():
            if isinstance(m, DBBConv2d):
                total += m.flops(batch, h, w)
                h, w = m.out_hw(h, w)
            else:
                total += m.flops(batch)
        return total
