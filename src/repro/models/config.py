"""Unified model configuration covering all 10 assigned architectures."""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax.numpy as jnp

from repro.core.vdbb import DBBFormat


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | hybrid | vlm | audio | ssm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // num_heads

    # mixer selection; hybrids give a per-layer pattern that tiles num_layers
    mixer: str = "gqa"  # gqa | mla | rwkv6
    block_pattern: Tuple[str, ...] = ("attn",)  # attn | local | rec | rwkv
    local_window: int = 2048

    qkv_bias: bool = False
    mlp: str = "swiglu"  # swiglu | gelu
    norm: str = "rmsnorm"  # rmsnorm | layernorm
    rope_theta: float = 1e6
    tie_embeddings: bool = False

    # MoE
    num_experts: int = 0
    top_k: int = 0
    num_shared_experts: int = 0
    moe_capacity_factor: float = 1.0

    # MLA (deepseek-style)
    q_lora_rank: int = 0  # 0 -> dense q projection
    kv_lora_rank: int = 512
    qk_rope_dim: int = 64
    qk_nope_dim: int = 128
    v_head_dim: int = 128

    # recurrent (RG-LRU / RWKV6)
    d_rnn: int = 0  # 0 -> d_model
    conv1d_width: int = 4
    rwkv_head_dim: int = 64
    wkv_chunk: int = 64

    # modality frontends (stubs per assignment spec)
    frontend: Optional[str] = None  # vision | audio | None
    num_vision_tokens: int = 256
    num_codebooks: int = 4
    codebook_vocab: int = 2048
    cross_attn: bool = False
    cross_len: int = 128

    # --- the paper's technique: VDBB weight sparsity ---
    # Applied to every projection GEMM with K % bz == 0. None = dense model.
    dbb: Optional[DBBFormat] = None
    # serve with compressed DBBWeight leaves (bandwidth win at decode)
    serve_compressed: bool = True
    # 'ref' (jnp gather formulation) | 'pallas' (VDBB kernels) — how
    # apply_linear executes compressed/quantized projections (§13)
    kernel_mode: str = "ref"

    embed_scale: bool = False  # multiply embeddings by sqrt(d_model) (gemma)

    # numerics / execution
    param_dtype: Any = jnp.float32
    compute_dtype: Any = jnp.bfloat16
    remat: str = "full"  # none | full | dots
    q_chunk: int = 1024
    scan_layers: bool = True
    logit_softcap: float = 0.0

    # ------------------------------------------------------------------
    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.num_heads)

    @property
    def padded_vocab(self) -> int:
        return _round_up(self.vocab_size, 256)

    @property
    def d_rnn_(self) -> int:
        return self.d_rnn or self.d_model

    @property
    def rwkv_heads(self) -> int:
        return self.d_model // self.rwkv_head_dim

    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    @property
    def pattern(self) -> Tuple[str, ...]:
        if self.mixer == "rwkv6":
            return ("rwkv",)
        return self.block_pattern

    @property
    def num_groups(self) -> int:
        return self.num_layers // len(self.pattern)

    @property
    def tail_pattern(self) -> Tuple[str, ...]:
        """Layers left over when the pattern doesn't tile num_layers."""
        rem = self.num_layers % len(self.pattern)
        return self.pattern[:rem]

    @property
    def sub_quadratic(self) -> bool:
        """True if decode state size is bounded (SSM/hybrid) — such archs
        run the long_500k cell; pure full-attention archs skip it."""
        kinds = set(self.pattern)
        return "attn" not in kinds  # 'local'/'rec'/'rwkv' are all bounded

    # ---- parameter count (for 6ND model-flops accounting) ----
    def param_count(self) -> int:
        import math

        from repro.models.model import LM

        import jax

        defs = LM(self).defs()
        return sum(
            math.prod(p.shape)
            for p in jax.tree_util.tree_leaves(
                defs, is_leaf=lambda x: hasattr(x, "axes")
            )
        )

    def active_param_count(self) -> int:
        """MoE: params touched per token (routed top_k + shared)."""
        total = self.param_count()
        if not self.is_moe:
            return total
        import math

        from repro.models.model import LM

        defs = LM(self).defs()

        def _walk(d, path=()):
            if hasattr(d, "axes"):
                yield path, d
                return
            for k, v in d.items():
                yield from _walk(v, path + (k,))

        routed = sum(
            math.prod(p.shape) for path, p in _walk(defs) if any("we_" in k for k in path)
        )
        active = total - routed + routed * self.top_k // self.num_experts
        return active
