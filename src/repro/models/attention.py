"""Attention mixers: GQA (with bias/RoPE/local windows) and MLA.

Train/prefill paths operate on full sequences with q-chunking (bounded
score tensors); decode paths consume/update a KV cache. MLA decode uses the
absorbed-matmul formulation so the cache stays in the compressed latent.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.common import (
    Param,
    apply_linear,
    linear_def,
    rms_norm,
    rope,
    shard,
)

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Core masked attention (q-chunked)
# ---------------------------------------------------------------------------


def _attend(q, k, v, q_pos, k_pos, *, window: int = 0, kv_valid_len=None):
    """q: (B,Sq,Kv,G,D); k/v: (B,Sk,Kv,D); positions for causal masking.

    Returns (B,Sq,Kv,G,D). fp32 softmax, bf16 matmuls.

    Score/prob tensors carry explicit sharding constraints — without them
    the SPMD partitioner loses the head sharding inside the (rematted)
    q-chunk scan backward and falls back to full replication (measured:
    ~43 GB/layer of involuntary all-gathers on qwen2-72b; EXPERIMENTS §Perf).
    """
    d = q.shape[-1]
    scale = 1.0 / jnp.sqrt(d).astype(jnp.float32)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", q, k).astype(jnp.float32) * scale
    s = shard(s, ("batch", "heads", None, "act_seq", None))
    mask = q_pos[:, None] >= k_pos[None, :]  # causal
    if window:
        mask &= q_pos[:, None] - k_pos[None, :] < window
    if kv_valid_len is not None:
        mask = mask & (k_pos[None, :] < kv_valid_len)
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1).astype(v.dtype)
    p = shard(p, ("batch", "heads", None, "act_seq", None))
    out = jnp.einsum("bhgqk,bkhd->bqhgd", p, v)
    return shard(out, ("batch", "act_seq", "heads", None, None))


def attend_chunked(q, k, v, q_pos, k_pos, *, window=0, q_chunk=1024, kv_valid_len=None):
    b, sq, kvh, g, d = q.shape
    dv = v.shape[-1]  # may differ from q/k dim (MLA: 192 qk vs 128 v)
    if sq <= q_chunk:
        return _attend(q, k, v, q_pos, k_pos, window=window, kv_valid_len=kv_valid_len)
    n = sq // q_chunk
    assert sq % q_chunk == 0, (sq, q_chunk)
    qs = q.reshape(b, n, q_chunk, kvh, g, d).transpose(1, 0, 2, 3, 4, 5)
    qs = shard(qs, (None, "batch", None, "heads", None, None))
    ps = q_pos.reshape(n, q_chunk)

    def body(_, qc):
        qq, pp = qc
        return None, _attend(qq, k, v, pp, k_pos, window=window, kv_valid_len=kv_valid_len)

    _, out = jax.lax.scan(body, None, (qs, ps))
    return out.transpose(1, 0, 2, 3, 4, 5).reshape(b, sq, kvh, g, dv)


# ---------------------------------------------------------------------------
# GQA
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class GQAttention:
    cfg: "ModelConfig"  # noqa: F821
    window: int = 0  # 0 = global causal
    cross: bool = False  # cross-attention (kv from encoder memory, no mask)

    def defs(self):
        c = self.cfg
        hd = c.hd
        dbb = c.dbb
        d = {
            "wq": linear_def(c.d_model, c.num_heads * hd, "embed", "heads", dbb=dbb),
            "wk": linear_def(c.d_model, c.num_kv_heads * hd, "embed", "kv", dbb=dbb),
            "wv": linear_def(c.d_model, c.num_kv_heads * hd, "embed", "kv", dbb=dbb),
            "wo": linear_def(c.num_heads * hd, c.d_model, "heads", "embed", dbb=dbb),
        }
        if c.qkv_bias:
            d["bq"] = Param((c.num_heads * hd,), ("heads",), "zeros")
            d["bk"] = Param((c.num_kv_heads * hd,), ("kv",), "zeros")
            d["bv"] = Param((c.num_kv_heads * hd,), ("kv",), "zeros")
        return d

    # -------------------------------------------------------------- train
    def __call__(self, p, x, positions, memory=None):
        """Full-sequence forward. x: (B,S,d). Returns (out, cache_kv)."""
        c = self.cfg
        hd = c.hd
        b, s, _ = x.shape
        kv_src = memory if self.cross else x
        km = c.kernel_mode
        q = apply_linear(x, p["wq"], p.get("bq"),
                         aq=p.get("wq_aq"), kernel_mode=km, name="wq")
        k = apply_linear(kv_src, p["wk"], p.get("bk"),
                         aq=p.get("wk_aq"), kernel_mode=km, name="wk")
        v = apply_linear(kv_src, p["wv"], p.get("bv"),
                         aq=p.get("wv_aq"), kernel_mode=km, name="wv")
        q = q.reshape(b, s, c.num_heads, hd)
        k = k.reshape(b, kv_src.shape[1], c.num_kv_heads, hd)
        v = v.reshape(b, kv_src.shape[1], c.num_kv_heads, hd)
        if not self.cross:
            q = rope(q, positions, c.rope_theta)
            k = rope(k, positions, c.rope_theta)
        # Expand KV to the full query-head count BEFORE attention: the head
        # dim then shards cleanly on 'model' even when kv_heads < TP (the
        # grouped (kv, g) factorization is unshardable when neither factor
        # divides TP — the source of involuntary replication; §Perf H1).
        k_cache, v_cache = k, v  # cache keeps the compact kv-head layout
        g = c.num_heads // c.num_kv_heads
        if g > 1:
            k = jnp.repeat(k, g, axis=2)
            v = jnp.repeat(v, g, axis=2)
        q = shard(q, ("batch", "act_seq", "heads", None))
        k = shard(k, ("batch", "act_seq", "heads", None))
        v = shard(v, ("batch", "act_seq", "heads", None))
        qg = q.reshape(b, s, c.num_heads, 1, hd)
        if self.cross:
            kp = jnp.zeros((kv_src.shape[1],), jnp.int32)
            qp = jnp.full((s,), 1, jnp.int32)  # attend to all memory
            out = attend_chunked(qg, k, v, qp, kp, q_chunk=c.q_chunk)
        else:
            pos1 = positions[0] if positions.ndim == 2 else positions
            out = attend_chunked(
                qg, k, v, pos1, pos1, window=self.window, q_chunk=c.q_chunk
            )
        out = out.reshape(b, s, c.num_heads * hd)
        y = apply_linear(out, p["wo"],
                         aq=p.get("wo_aq"), kernel_mode=km, name="wo")
        return y, {"k": k_cache, "v": v_cache}

    # ------------------------------------------------------------- decode
    def init_cache(self, batch, max_len, dtype):
        c = self.cfg
        cap = min(self.window, max_len) if self.window else max_len
        if self.cross:
            cap = c.cross_len
        return {
            "k": jnp.zeros((batch, cap, c.num_kv_heads, c.hd), dtype),
            "v": jnp.zeros((batch, cap, c.num_kv_heads, c.hd), dtype),
        }

    def decode(self, p, x, cache, pos):
        """x: (B,1,d); pos: scalar int32 current position. Returns (y, cache)."""
        c = self.cfg
        hd = c.hd
        b = x.shape[0]
        km = c.kernel_mode
        q = apply_linear(x, p["wq"], p.get("bq"),
                         aq=p.get("wq_aq"), kernel_mode=km,
                         name="wq").reshape(b, 1, c.num_heads, hd)
        if self.cross:
            # cross K/V were precomputed at prefill; cache is read-only.
            k, v = cache["k"], cache["v"]
            qg = q.reshape(b, 1, c.num_kv_heads, c.num_heads // c.num_kv_heads, hd)
            kp = jnp.zeros((k.shape[1],), jnp.int32)
            out = _attend(qg, k, v, jnp.ones((1,), jnp.int32), kp)
            y = apply_linear(out.reshape(b, 1, c.num_heads * hd), p["wo"],
                             aq=p.get("wo_aq"), kernel_mode=km, name="wo")
            return y, cache
        posv = jnp.full((b, 1), pos, jnp.int32)
        q = rope(q, posv, c.rope_theta)
        k_new = apply_linear(x, p["wk"], p.get("bk"),
                             aq=p.get("wk_aq"), kernel_mode=km,
                             name="wk").reshape(b, 1, c.num_kv_heads, hd)
        v_new = apply_linear(x, p["wv"], p.get("bv"),
                             aq=p.get("wv_aq"), kernel_mode=km,
                             name="wv").reshape(b, 1, c.num_kv_heads, hd)
        k_new = rope(k_new, posv, c.rope_theta)
        cap = cache["k"].shape[1]
        slot = jnp.mod(pos, cap) if self.window else jnp.minimum(pos, cap - 1)
        k = jax.lax.dynamic_update_slice(cache["k"], k_new.astype(cache["k"].dtype), (0, slot, 0, 0))
        v = jax.lax.dynamic_update_slice(cache["v"], v_new.astype(cache["v"].dtype), (0, slot, 0, 0))
        k = shard(k, ("batch", "cache_seq", "kv", None))
        v = shard(v, ("batch", "cache_seq", "kv", None))
        qg = q.reshape(b, 1, c.num_kv_heads, c.num_heads // c.num_kv_heads, hd)
        if self.window:
            # ring buffer: absolute positions of slots
            base = pos - slot
            kpos = jnp.arange(cap, dtype=jnp.int32)
            kpos = jnp.where(kpos <= slot, base + kpos, base - cap + kpos)
            kpos = jnp.where(kpos < 0, jnp.iinfo(jnp.int32).max, kpos)  # unfilled
        else:
            kpos = jnp.arange(cap, dtype=jnp.int32)
        out = _attend(
            qg, k, v, jnp.full((1,), pos, jnp.int32), kpos,
            window=self.window, kv_valid_len=pos + 1,
        )
        y = apply_linear(out.reshape(b, 1, c.num_heads * hd), p["wo"],
                         aq=p.get("wo_aq"), kernel_mode=km, name="wo")
        return y, {"k": k, "v": v}


# ---------------------------------------------------------------------------
# MLA (multi-head latent attention, deepseek-style)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MLAttention:
    cfg: "ModelConfig"  # noqa: F821

    def defs(self):
        c = self.cfg
        dbb = c.dbb
        qd = c.qk_nope_dim + c.qk_rope_dim
        d = {}
        if c.q_lora_rank:
            d["wq_a"] = linear_def(c.d_model, c.q_lora_rank, "embed", None, dbb=dbb)
            d["q_norm"] = Param((c.q_lora_rank,), (None,), "ones")
            d["wq_b"] = linear_def(c.q_lora_rank, c.num_heads * qd, None, "heads", dbb=dbb)
        else:
            d["wq"] = linear_def(c.d_model, c.num_heads * qd, "embed", "heads", dbb=dbb)
        d["wkv_a"] = linear_def(
            c.d_model, c.kv_lora_rank + c.qk_rope_dim, "embed", None, dbb=dbb
        )
        d["kv_norm"] = Param((c.kv_lora_rank,), (None,), "ones")
        d["wkv_b"] = linear_def(
            c.kv_lora_rank,
            c.num_heads * (c.qk_nope_dim + c.v_head_dim),
            None,
            "heads",
            dbb=dbb,
        )
        d["wo"] = linear_def(c.num_heads * c.v_head_dim, c.d_model, "heads", "embed", dbb=dbb)
        return d

    def _q(self, p, x):
        c = self.cfg
        b, s, _ = x.shape
        km = c.kernel_mode
        qd = c.qk_nope_dim + c.qk_rope_dim
        if c.q_lora_rank:
            qa = apply_linear(x, p["wq_a"], aq=p.get("wq_a_aq"),
                              kernel_mode=km, name="wq_a")
            q = apply_linear(rms_norm(qa, p["q_norm"]), p["wq_b"],
                             aq=p.get("wq_b_aq"), kernel_mode=km, name="wq_b")
        else:
            q = apply_linear(x, p["wq"], aq=p.get("wq_aq"),
                             kernel_mode=km, name="wq")
        return q.reshape(b, s, c.num_heads, qd)

    def __call__(self, p, x, positions, memory=None):
        c = self.cfg
        b, s, _ = x.shape
        q = self._q(p, x)
        km = c.kernel_mode
        q_nope, q_rope = q[..., : c.qk_nope_dim], q[..., c.qk_nope_dim :]
        q_rope = rope(q_rope, positions, c.rope_theta)
        kv_a = apply_linear(x, p["wkv_a"], aq=p.get("wkv_a_aq"),
                            kernel_mode=km, name="wkv_a")
        c_kv = rms_norm(kv_a[..., : c.kv_lora_rank], p["kv_norm"])
        k_rope = rope(
            kv_a[..., c.kv_lora_rank :].reshape(b, s, 1, c.qk_rope_dim),
            positions,
            c.rope_theta,
        )
        kv = apply_linear(c_kv, p["wkv_b"], aq=p.get("wkv_b_aq"),
                          kernel_mode=km, name="wkv_b").reshape(
            b, s, c.num_heads, c.qk_nope_dim + c.v_head_dim
        )
        k_nope, v = kv[..., : c.qk_nope_dim], kv[..., c.qk_nope_dim :]
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope, (b, s, c.num_heads, c.qk_rope_dim))], -1
        )
        qf = jnp.concatenate([q_nope, q_rope], -1)
        qf = shard(qf, ("batch", "act_seq", "heads", None))
        k = shard(k, ("batch", "act_seq", "heads", None))
        pos1 = positions[0] if positions.ndim == 2 else positions
        out = attend_chunked(
            qf[:, :, :, None, :].reshape(b, s, c.num_heads, 1, -1),
            k,
            v,
            pos1,
            pos1,
            q_chunk=c.q_chunk,
        )
        out = out.reshape(b, s, c.num_heads * c.v_head_dim)
        y = apply_linear(out, p["wo"], aq=p.get("wo_aq"),
                         kernel_mode=km, name="wo")
        return y, {"c_kv": c_kv, "k_rope": k_rope[:, :, 0, :]}

    def init_cache(self, batch, max_len, dtype):
        c = self.cfg
        return {
            "c_kv": jnp.zeros((batch, max_len, c.kv_lora_rank), dtype),
            "k_rope": jnp.zeros((batch, max_len, c.qk_rope_dim), dtype),
        }

    def decode(self, p, x, cache, pos):
        """Absorbed-matmul decode: scores and context in the latent space."""
        c = self.cfg
        b = x.shape[0]
        km = c.kernel_mode
        posv = jnp.full((b, 1), pos, jnp.int32)
        q = self._q(p, x)
        q_nope, q_rope = q[..., : c.qk_nope_dim], q[..., c.qk_nope_dim :]
        q_rope = rope(q_rope, posv, c.rope_theta)
        kv_a = apply_linear(x, p["wkv_a"], aq=p.get("wkv_a_aq"),
                            kernel_mode=km, name="wkv_a")
        c_kv_new = rms_norm(kv_a[..., : c.kv_lora_rank], p["kv_norm"])
        k_rope_new = rope(
            kv_a[..., c.kv_lora_rank :].reshape(b, 1, 1, c.qk_rope_dim), posv, c.rope_theta
        )[:, :, 0, :]
        ckv = jax.lax.dynamic_update_slice(
            cache["c_kv"], c_kv_new.astype(cache["c_kv"].dtype), (0, pos, 0)
        )
        krp = jax.lax.dynamic_update_slice(
            cache["k_rope"], k_rope_new.astype(cache["k_rope"].dtype), (0, pos, 0)
        )
        ckv = shard(ckv, ("batch", "cache_seq", None))
        wkv_b = p["wkv_b"]
        if hasattr(wkv_b, "fmt"):  # compressed serving: decode via expanded
            from repro.core.quant import QuantDBBWeight, dequantize_dbb
            from repro.core.vdbb import dbb_decode

            if isinstance(wkv_b, QuantDBBWeight):
                wkv_b = dequantize_dbb(wkv_b)  # fp values, compressed layout
            wkv_b = dbb_decode(wkv_b)
        wkv_b = wkv_b.reshape(c.kv_lora_rank, c.num_heads, c.qk_nope_dim + c.v_head_dim)
        w_uk = wkv_b[..., : c.qk_nope_dim]  # (r, H, nope)
        w_uv = wkv_b[..., c.qk_nope_dim :]  # (r, H, v)
        q_c = jnp.einsum("bqhn,rhn->bqhr", q_nope, w_uk.astype(x.dtype))
        s_lat = jnp.einsum("bqhr,bsr->bhqs", q_c, ckv.astype(x.dtype))
        s_rope = jnp.einsum("bqhp,bsp->bhqs", q_rope, krp.astype(x.dtype))
        scale = 1.0 / jnp.sqrt(c.qk_nope_dim + c.qk_rope_dim)
        s = (s_lat + s_rope).astype(jnp.float32) * scale
        kpos = jnp.arange(ckv.shape[1], dtype=jnp.int32)
        s = jnp.where((kpos <= pos)[None, None, None], s, NEG_INF)
        pr = jax.nn.softmax(s, axis=-1).astype(x.dtype)
        ctx = jnp.einsum("bhqs,bsr->bqhr", pr, ckv.astype(x.dtype))
        out = jnp.einsum("bqhr,rhv->bqhv", ctx, w_uv.astype(x.dtype))
        y = apply_linear(out.reshape(b, 1, c.num_heads * c.v_head_dim), p["wo"],
                         aq=p.get("wo_aq"), kernel_mode=km, name="wo")
        return y, {"c_kv": ckv, "k_rope": krp}
