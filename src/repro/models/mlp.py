"""MLP blocks: dense (SwiGLU / GELU) and Mixture-of-Experts.

MoE uses expert-choice dispatch (experts pick their top-C tokens), which
keeps every tensor dense-shaped and shards cleanly with experts on the
'model' mesh axis (EP). Capacity C = tokens * top_k / E * capacity_factor,
so compute matches token-choice top-k routing. DESIGN.md records this
TPU-idiomatic deviation from deepseek's token-choice router.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.quant import QuantDBBWeight
from repro.models.common import Param, apply_linear, linear_def, shard


@dataclasses.dataclass(frozen=True)
class DenseMLP:
    cfg: "ModelConfig"  # noqa: F821
    d_ff: int = 0  # override (shared experts); 0 -> cfg.d_ff

    @property
    def ff(self):
        return self.d_ff or self.cfg.d_ff

    def defs(self):
        c = self.cfg
        d = {
            "w_up": linear_def(c.d_model, self.ff, "embed", "mlp", dbb=c.dbb),
            "w_down": linear_def(self.ff, c.d_model, "mlp", "embed", dbb=c.dbb),
        }
        if c.mlp == "swiglu":
            d["w_gate"] = linear_def(c.d_model, self.ff, "embed", "mlp", dbb=c.dbb)
        return d

    def __call__(self, p, x):
        c = self.cfg
        km = c.kernel_mode
        up = apply_linear(x, p["w_up"], aq=p.get("w_up_aq"),
                          kernel_mode=km, name="w_up")
        if c.mlp == "swiglu":
            gate = apply_linear(x, p["w_gate"], aq=p.get("w_gate_aq"),
                                kernel_mode=km, name="w_gate")
            up = jax.nn.silu(gate) * up
        else:
            up = jax.nn.gelu(up)
        up = shard(up, ("batch", None, "mlp"))
        # §9 int8-resident chain: when the down projection is quantized and
        # carries a calibrated activation scale, requantize the hidden
        # activation once here and feed the int8 codes straight into the
        # down GEMM — bit-identical to quantizing inside (same scale, same
        # rounding), but the fp hidden tensor never round-trips.
        aq_down = p.get("w_down_aq")
        if isinstance(p.get("w_down"), QuantDBBWeight) and aq_down is not None:
            from repro.core.quant import quantize

            up = quantize(up, aq_down)
        y = apply_linear(up, p["w_down"], aq=aq_down,
                         kernel_mode=km, name="w_down")
        return y.astype(x.dtype)


@dataclasses.dataclass(frozen=True)
class MoEMLP:
    """Routed experts (expert-choice) + optional fused shared experts."""

    cfg: "ModelConfig"  # noqa: F821

    def defs(self):
        c = self.cfg
        e, dm, ff = c.num_experts, c.d_model, c.d_ff
        d = {
            "router": linear_def(dm, e, "embed", None, scale=1.0),
            "we_gate": Param((e, dm, ff), ("experts", "w_embed", None), "scaled"),
            "we_up": Param((e, dm, ff), ("experts", "w_embed", None), "scaled"),
            "we_down": Param((e, ff, dm), ("experts", None, "w_embed"), "scaled"),
        }
        if c.num_shared_experts:
            d["shared"] = DenseMLP(c, d_ff=c.num_shared_experts * c.d_ff).defs()
        return d

    def __call__(self, p, x):
        c = self.cfg
        b, s, dm = x.shape
        if s > 1:
            y = self._grouped(p, x)
        else:
            y = self._global(p, x)  # decode: a handful of tokens
        if c.num_shared_experts:
            from repro.core.act_sparsity import act_scope

            with act_scope("shared"):
                y = y + DenseMLP(c, d_ff=c.num_shared_experts * c.d_ff)(
                    p["shared"], x
                )
        return shard(y, ("batch", "seq", "embed"))

    def _grouped(self, p, x):
        """GShard-style grouped expert-choice: experts pick their top-C
        tokens WITHIN each example, so the dispatch gather stays local to
        the data shard — global routing all-gathers the full token tensor
        (~15 GB/layer on deepseek-v3 train_4k; §Perf H4)."""
        c = self.cfg
        b, s, dm = x.shape
        cap = max(1, int(s * c.top_k * c.moe_capacity_factor) // c.num_experts)
        # leave the SP (seq-sharded) residual: dispatch gathers along seq
        # must be shard-local (else: partial-gather + 15 GB all-reduce)
        x = shard(x, ("batch", None, "embed"))
        logits = apply_linear(x.astype(jnp.float32), p["router"].astype(jnp.float32))
        probs = jax.nn.softmax(logits, axis=-1)  # (b, s, E)
        gates, idx = jax.lax.top_k(probs.transpose(0, 2, 1), cap)  # (b, E, cap)
        # shard the *indices* by expert before the gather so the dispatched
        # tensor is born expert-sharded (never materialized at full E)
        idx = shard(idx, ("batch", "experts", None))
        gates = shard(gates, ("batch", "experts", None))
        disp = jnp.take_along_axis(
            x[:, None, :, :], idx[..., None], axis=2
        )  # (b, E, cap, d)
        disp = shard(disp, ("batch", "experts", None, None))
        h = jnp.einsum("becd,edf->becf", disp, p["we_up"].astype(x.dtype))
        g = jnp.einsum("becd,edf->becf", disp, p["we_gate"].astype(x.dtype))
        h = jax.nn.silu(g) * h
        h = shard(h, ("batch", "experts", None, None))
        out = jnp.einsum("becf,efd->becd", h, p["we_down"].astype(x.dtype))
        out = out * gates[..., None].astype(x.dtype)
        # combine: one-hot-free scatter-add back to sequence positions
        y = jnp.zeros((b, s, dm), x.dtype)
        bidx = jnp.broadcast_to(jnp.arange(b)[:, None, None], idx.shape)
        y = y.at[bidx.reshape(-1), idx.reshape(-1)].add(out.reshape(-1, dm))
        return y

    def _global(self, p, x):
        c = self.cfg
        b, s, dm = x.shape
        t = b * s
        xf = x.reshape(t, dm)
        logits = apply_linear(xf.astype(jnp.float32), p["router"].astype(jnp.float32))
        probs = jax.nn.softmax(logits, axis=-1)  # (t, E)
        cap = max(1, int(t * c.top_k * c.moe_capacity_factor) // c.num_experts)
        gates, idx = jax.lax.top_k(probs.T, cap)  # (E, cap)
        disp = jnp.take(xf, idx.reshape(-1), axis=0).reshape(c.num_experts, cap, dm)
        disp = shard(disp, ("experts", None, None))
        h = jnp.einsum("ecd,edf->ecf", disp, p["we_up"].astype(x.dtype))
        g = jnp.einsum("ecd,edf->ecf", disp, p["we_gate"].astype(x.dtype))
        h = jax.nn.silu(g) * h
        out = jnp.einsum("ecf,efd->ecd", h, p["we_down"].astype(x.dtype))
        out = out * gates[..., None].astype(x.dtype)
        y = jnp.zeros((t, dm), x.dtype).at[idx.reshape(-1)].add(
            out.reshape(c.num_experts * cap, dm)
        )
        return y.reshape(b, s, dm)

    def aux_loss(self, p, x):
        """Load-balance (importance) auxiliary loss: ``E · Σ_e frac_e²``
        where ``frac_e`` is expert e's mean routing probability over the
        batch. Minimized (value 1.0) by a perfectly uniform router — the
        squared-importance loss of Shazeer et al., not an entropy term."""
        logits = apply_linear(
            x.reshape(-1, x.shape[-1]).astype(jnp.float32),
            p["router"].astype(jnp.float32),
        )
        probs = jax.nn.softmax(logits, -1)
        frac = probs.mean(0)
        return jnp.sum(frac * frac) * probs.shape[-1]
