"""Small helpers over jax compiled-artifact introspection APIs."""
from __future__ import annotations


def cost_analysis_dict(compiled) -> dict:
    """Normalize ``Compiled.cost_analysis()`` across jax versions: newer jax
    returns the per-module properties dict directly, older versions (e.g.
    0.4.x) wrap it in a 1-element list."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost
