"""Small helpers over jax compiled-artifact introspection APIs, plus the
shared wall-time measurement harness (``benchmarks/timing.py`` re-exports
it and ``repro.kernels.autotune`` times candidates with it, so benchmark
and autotuner numbers come from one code path)."""
from __future__ import annotations

import statistics
import time


def median_time_us(fn, *args, warmup: int = 1, reps: int = 5) -> float:
    """Median wall time of ``fn(*args)`` in microseconds.

    ``warmup`` un-timed calls absorb compilation/tracing, then ``reps``
    timed calls each wrapped in ``jax.block_until_ready`` (imported lazily
    so this module stays importable without jax for plain-python callers).
    """
    import jax

    for _ in range(max(0, warmup)):
        jax.block_until_ready(fn(*args))
    samples = []
    for _ in range(max(1, reps)):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        samples.append((time.perf_counter() - t0) * 1e6)
    return statistics.median(samples)


def cost_analysis_dict(compiled) -> dict:
    """Normalize ``Compiled.cost_analysis()`` across jax versions: newer jax
    returns the per-module properties dict directly, older versions (e.g.
    0.4.x) wrap it in a 1-element list."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost
