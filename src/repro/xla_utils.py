"""Small helpers over jax compiled-artifact introspection APIs, plus the
shared wall-time measurement harness (``benchmarks/timing.py`` re-exports
it and ``repro.kernels.autotune`` times candidates with it, so benchmark
and autotuner numbers come from one code path).

Measurement statistics (DESIGN.md §12): on a shared/contended host,
scheduling noise is strictly *additive* — a sample is the true cost plus
whatever the OS stole — so the **min** over many repetitions estimates
the true cost far more stably than the median of a few (profiling on a
noisy CPU showed medians of 7 swinging ±70% between batches while mins
of 30 stayed within ±3%). Comparisons between two programs should
additionally be **interleaved** (A, B, A, B, …) so environment drift
cancels out of the ratio: :func:`interleaved_time_us`.
"""
from __future__ import annotations

import statistics
import time

_STATS = ("median", "min", "p25", "mean")


def _reduce(samples, stat: str) -> float:
    if stat == "median":
        return statistics.median(samples)
    if stat == "min":
        return min(samples)
    if stat == "p25":
        s = sorted(samples)
        return s[max(0, (len(s) - 1) // 4)]
    if stat == "mean":
        return statistics.fmean(samples)
    raise ValueError(f"stat must be one of {_STATS}, got {stat!r}")


def time_samples_us(fn, *args, warmup: int = 1, reps: int = 5) -> list:
    """Raw per-call wall-time samples of ``fn(*args)`` in microseconds.

    ``warmup`` un-timed calls absorb compilation/tracing, then ``reps``
    timed calls each wrapped in ``jax.block_until_ready`` (imported lazily
    so this module stays importable without jax for plain-python callers).
    """
    import jax

    for _ in range(max(0, warmup)):
        jax.block_until_ready(fn(*args))
    samples = []
    for _ in range(max(1, reps)):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        samples.append((time.perf_counter() - t0) * 1e6)
    return samples


def median_time_us(fn, *args, warmup: int = 1, reps: int = 5,
                   stat: str = "median") -> float:
    """Wall time of ``fn(*args)`` in microseconds — ``stat`` over ``reps``
    timed calls after ``warmup`` un-timed ones.

    The default statistic stays the median (the historical contract every
    caller was written against); pass ``stat='min'`` with a larger
    ``reps`` for noise-robust gating comparisons (see module docstring).
    """
    return _reduce(time_samples_us(fn, *args, warmup=warmup, reps=reps), stat)


def interleaved_samples_us(fn_a, fn_b, *, warmup: int = 1, reps: int = 5):
    """``(a_samples, b_samples)`` raw µs wall times of two nullary
    callables sampled alternately (A, B, A, B, …), so environment drift
    cancels out of any derived comparison. The sample-level primitive
    under :func:`interleaved_time_us`; use it directly when you also
    need :func:`noise_frac` of the same batch (the regression gates)."""
    import jax

    for _ in range(max(0, warmup)):
        jax.block_until_ready(fn_a())
        jax.block_until_ready(fn_b())
    sa, sb = [], []
    for _ in range(max(1, reps)):
        t0 = time.perf_counter()
        jax.block_until_ready(fn_a())
        sa.append((time.perf_counter() - t0) * 1e6)
        t0 = time.perf_counter()
        jax.block_until_ready(fn_b())
        sb.append((time.perf_counter() - t0) * 1e6)
    return sa, sb


def interleaved_time_us(fn_a, fn_b, *, warmup: int = 1, reps: int = 5,
                        stat: str = "median"):
    """``(a_us, b_us)`` wall times of two nullary callables sampled
    alternately (A, B, A, B, …) — the canonical harness for any paired
    perf claim (winner-vs-default confirmation, fused-vs-unfused gates).

    ``stat='min'`` over many reps is the noise-robust choice for gating
    (additive-noise argument in the module docstring); ``'median'`` is
    kept as the default for the historical ``interleaved_medians`` alias
    in :mod:`repro.kernels.autotune`.
    """
    sa, sb = interleaved_samples_us(fn_a, fn_b, warmup=warmup, reps=reps)
    return _reduce(sa, stat), _reduce(sb, stat)


def noise_frac(samples) -> float:
    """Relative measurement-noise estimate of a sample batch: how far the
    lower quartile sits above the min, ``(p25 - min) / min``. Near 0 on a
    quiet host, large when scheduling noise contaminates even the fast
    samples — the self-calibration term the measured-wall-time regression
    gates widen their margins by (DESIGN.md §12)."""
    lo = min(samples)
    if lo <= 0:
        return 0.0
    return max(0.0, _reduce(samples, "p25") / lo - 1.0)


def cost_analysis_dict(compiled) -> dict:
    """Normalize ``Compiled.cost_analysis()`` across jax versions: newer jax
    returns the per-module properties dict directly, older versions (e.g.
    0.4.x) wrap it in a 1-element list."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost


def hlo_op_breakdown(fn, *args) -> dict:
    """Kernel-launch-level attribution of a jitted program (DESIGN.md §12).

    Compiles ``fn(*args)`` and parses the optimized HLO: per-opcode
    instruction counts, the number of fusion computations and custom
    calls (≈ kernel launches on CPU/GPU backends), plus the normalized
    cost-analysis properties. This is how ``benchmarks/perf/
    profile_fused.py`` shows *where* a wall-time delta between two
    programs comes from without a hardware profiler.
    """
    import collections
    import re

    import jax

    compiled = jax.jit(fn).lower(*args).compile()
    text = compiled.as_text()
    ops: collections.Counter = collections.Counter()
    for line in text.splitlines():
        # instruction lines look like: "  %name = type opcode(...)" or
        # "  ROOT %name = type opcode(...)"
        m = re.match(r"\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*\S+\s+([a-z][\w\-]*)\(", line)
        if m:
            ops[m.group(1)] += 1
    cost = cost_analysis_dict(compiled)
    return {
        "ops": dict(ops),
        "n_instructions": int(sum(ops.values())),
        "n_fusions": int(ops.get("fusion", 0)),
        "n_custom_calls": int(ops.get("custom-call", 0)),
        "bytes_accessed": cost.get("bytes accessed"),
        "flops": cost.get("flops"),
    }
