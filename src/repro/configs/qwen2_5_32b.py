"""Config for --arch qwen2.5-32b (exact assigned shape set)."""
from repro.configs.registry import qwen2_5_32b as config  # noqa: F401
from repro.configs.registry import smoke_config as _smoke


def smoke(sparsity=0.625):
    return _smoke('qwen2.5-32b', sparsity=sparsity)
