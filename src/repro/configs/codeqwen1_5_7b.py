"""Config for --arch codeqwen1.5-7b (exact assigned shape set)."""
from repro.configs.registry import codeqwen1_5_7b as config  # noqa: F401
from repro.configs.registry import smoke_config as _smoke


def smoke(sparsity=0.625):
    return _smoke('codeqwen1.5-7b', sparsity=sparsity)
