from repro.configs.registry import ARCHS, get_config, smoke_config  # noqa: F401
from repro.configs.cnn import (  # noqa: F401
    CNN_ARCHS,
    get_cnn_config,
    smoke_cnn_config,
)
from repro.configs.shapes import SHAPES, cell_runnable, input_specs, make_batch  # noqa: F401
