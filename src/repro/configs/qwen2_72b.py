"""Config for --arch qwen2-72b (exact assigned shape set)."""
from repro.configs.registry import qwen2_72b as config  # noqa: F401
from repro.configs.registry import smoke_config as _smoke


def smoke(sparsity=0.625):
    return _smoke('qwen2-72b', sparsity=sparsity)
