"""CNN inference configs — the paper's native workload, registered
alongside the LM archs (same sparsity knob, same DBB defaults).

``sparsity`` maps to the paper's nominal formats exactly like the LM
registry: 0.625 → 3/8 DBB. ``pattern='matrix'`` (tc kernel mode) is the
TPU co-design default; pass ``pattern=None`` for the paper-faithful
per-column patterns (bw kernel mode). See DESIGN.md §2/§6.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Union

import jax.numpy as jnp

from repro.core.vdbb import DBBFormat
from repro.models.cnn import CNNConfig


def _dbb(sparsity: Optional[Union[str, float]], pattern="matrix") -> Optional[DBBFormat]:
    if sparsity in (None, "dense", 0.0):
        return None
    if isinstance(sparsity, str):
        sparsity = float(sparsity)
    nnz = max(1, min(8, round((1.0 - sparsity) * 8)))
    return DBBFormat(8, nnz, pattern)


def sparse_cnn_tiny(sparsity=0.625, pattern="matrix") -> CNNConfig:
    """CIFAR-scale smoke model: 6 convs, 32×32×3 input."""
    return CNNConfig(
        name="sparse-cnn-tiny", in_channels=3, image_size=32,
        stage_channels=(32, 64, 128), convs_per_stage=2, num_classes=10,
        dbb=_dbb(sparsity, pattern), dtype=jnp.float32,
    )


def sparse_cnn_s(sparsity=0.625, pattern="matrix") -> CNNConfig:
    """ImageNet-tile-scale: 8 convs, 64×64×3 input, VGG-ish widths."""
    return CNNConfig(
        name="sparse-cnn-s", in_channels=3, image_size=64,
        stage_channels=(64, 128, 256, 512), convs_per_stage=2, num_classes=1000,
        dbb=_dbb(sparsity, pattern), dtype=jnp.float32,
    )


CNN_ARCHS = {
    "sparse-cnn-tiny": sparse_cnn_tiny,
    "sparse-cnn-s": sparse_cnn_s,
}


def get_cnn_config(name: str, sparsity=0.625, pattern="matrix") -> CNNConfig:
    return CNN_ARCHS[name](sparsity=sparsity, pattern=pattern)


def smoke_cnn_config(name: str, sparsity=0.625, pattern="matrix") -> CNNConfig:
    """Reduced CPU-runnable variant of the same family."""
    cfg = get_cnn_config(name, sparsity=sparsity, pattern=pattern)
    return dataclasses.replace(
        cfg, image_size=16, stage_channels=tuple(cfg.stage_channels[:2]),
        convs_per_stage=1, num_classes=min(cfg.num_classes, 10),
    )
