"""The 10 assigned architectures (exact configs from the assignment) plus
reduced smoke variants of the same family.

Every config carries the paper's technique as a first-class feature:
``dbb`` defaults to the paper's nominal 3/8 DBB (62.5% weight sparsity)
with MXU-tile-shared patterns (DESIGN.md §2 'tc' mode); pass
sparsity=None/'dense' for the dense baseline used in roofline A/B rows.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Union

import jax.numpy as jnp

from repro.core.vdbb import DBBFormat
from repro.models.config import ModelConfig


def _dbb(sparsity: Optional[Union[str, float]]) -> Optional[DBBFormat]:
    if sparsity in (None, "dense", 0.0):
        return None
    if isinstance(sparsity, str):
        sparsity = float(sparsity)
    nnz = max(1, min(8, round((1.0 - sparsity) * 8)))
    return DBBFormat(8, nnz, "matrix")


_COMMON = dict(param_dtype=jnp.bfloat16, compute_dtype=jnp.bfloat16, remat="full")


def qwen2_72b(sparsity=0.625) -> ModelConfig:
    """[arXiv:2407.10671; hf] GQA kv=8, QKV bias."""
    return ModelConfig(
        name="qwen2-72b", family="dense", num_layers=80, d_model=8192,
        num_heads=64, num_kv_heads=8, d_ff=29568, vocab_size=152064,
        qkv_bias=True, mlp="swiglu", norm="rmsnorm", rope_theta=1e6,
        dbb=_dbb(sparsity), **_COMMON,
    )


def qwen2_5_32b(sparsity=0.625) -> ModelConfig:
    """[hf:Qwen/Qwen2.5-*] GQA kv=8, QKV bias."""
    return ModelConfig(
        name="qwen2.5-32b", family="dense", num_layers=64, d_model=5120,
        num_heads=40, num_kv_heads=8, d_ff=27648, vocab_size=152064,
        qkv_bias=True, mlp="swiglu", norm="rmsnorm", rope_theta=1e6,
        dbb=_dbb(sparsity), **_COMMON,
    )


def codeqwen1_5_7b(sparsity=0.625) -> ModelConfig:
    """[hf:Qwen/CodeQwen1.5-7B] qwen1.5 arch (MHA, bias)."""
    return ModelConfig(
        name="codeqwen1.5-7b", family="dense", num_layers=32, d_model=4096,
        num_heads=32, num_kv_heads=32, d_ff=13440, vocab_size=92416,
        qkv_bias=True, mlp="swiglu", norm="rmsnorm", rope_theta=1e6,
        dbb=_dbb(sparsity), **_COMMON,
    )


def starcoder2_7b(sparsity=0.625) -> ModelConfig:
    """[arXiv:2402.19173; hf] GQA kv=4, RoPE, LayerNorm+GELU."""
    return ModelConfig(
        name="starcoder2-7b", family="dense", num_layers=32, d_model=4608,
        num_heads=36, num_kv_heads=4, d_ff=18432, vocab_size=49152,
        qkv_bias=True, mlp="gelu", norm="layernorm", rope_theta=1e5,
        dbb=_dbb(sparsity), **_COMMON,
    )


def deepseek_v3_671b(sparsity=0.625) -> ModelConfig:
    """[arXiv:2412.19437; hf] MLA, 1 shared + 256 routed top-8 (MTP head
    omitted — DESIGN.md §5)."""
    return ModelConfig(
        name="deepseek-v3-671b", family="moe", num_layers=61, d_model=7168,
        num_heads=128, num_kv_heads=128, d_ff=2048, vocab_size=129280,
        mixer="mla", q_lora_rank=1536, kv_lora_rank=512,
        qk_rope_dim=64, qk_nope_dim=128, v_head_dim=128,
        num_experts=256, top_k=8, num_shared_experts=1,
        mlp="swiglu", norm="rmsnorm", rope_theta=1e4,
        dbb=_dbb(sparsity), **_COMMON,
    )


def moonshot_v1_16b(sparsity=0.625) -> ModelConfig:
    """[hf:moonshotai/Moonlight-16B-A3B] 64e top-6 (+2 shared)."""
    return ModelConfig(
        name="moonshot-v1-16b-a3b", family="moe", num_layers=48, d_model=2048,
        num_heads=16, num_kv_heads=16, d_ff=1408, vocab_size=163840,
        num_experts=64, top_k=6, num_shared_experts=2,
        mlp="swiglu", norm="rmsnorm", rope_theta=5e4,
        dbb=_dbb(sparsity), **_COMMON,
    )


def recurrentgemma_2b(sparsity=0.625) -> ModelConfig:
    """[arXiv:2402.19427; hf] RG-LRU + local attention, 1:2 pattern."""
    return ModelConfig(
        name="recurrentgemma-2b", family="hybrid", num_layers=26, d_model=2560,
        num_heads=10, num_kv_heads=1, head_dim=256, d_ff=7680, vocab_size=256000,
        block_pattern=("rec", "rec", "local"), local_window=2048, d_rnn=2560,
        mlp="swiglu", norm="rmsnorm", rope_theta=1e4,
        tie_embeddings=True, embed_scale=True, logit_softcap=30.0,
        dbb=_dbb(sparsity), **_COMMON,
    )


def internvl2_2b(sparsity=0.625) -> ModelConfig:
    """[arXiv:2404.16821; hf] InternLM2 backbone; InternViT frontend is a
    stub (precomputed patch embeddings via input_specs)."""
    return ModelConfig(
        name="internvl2-2b", family="vlm", num_layers=24, d_model=2048,
        num_heads=16, num_kv_heads=8, d_ff=8192, vocab_size=92553,
        frontend="vision", num_vision_tokens=256,
        mlp="swiglu", norm="rmsnorm", rope_theta=1e6,
        dbb=_dbb(sparsity), **_COMMON,
    )


def musicgen_medium(sparsity=0.625) -> ModelConfig:
    """[arXiv:2306.05284; hf] decoder-only over EnCodec tokens (4 codebooks),
    cross-attention to text memory; EnCodec frontend stubbed."""
    return ModelConfig(
        name="musicgen-medium", family="audio", num_layers=48, d_model=1536,
        num_heads=24, num_kv_heads=24, d_ff=6144, vocab_size=2048,
        frontend="audio", num_codebooks=4, codebook_vocab=2048,
        cross_attn=True, cross_len=128,
        mlp="gelu", norm="layernorm", rope_theta=1e4,
        dbb=_dbb(sparsity), **_COMMON,
    )


def rwkv6_3b(sparsity=0.625) -> ModelConfig:
    """[arXiv:2404.05892; hf] Finch — data-dependent decay, attention-free."""
    return ModelConfig(
        name="rwkv6-3b", family="ssm", num_layers=32, d_model=2560,
        num_heads=40, num_kv_heads=40, d_ff=8960, vocab_size=65536,
        mixer="rwkv6", rwkv_head_dim=64,
        norm="layernorm", dbb=_dbb(sparsity), **_COMMON,
    )


def qwen2_tiny(sparsity=0.625) -> ModelConfig:
    """Scaled-down qwen2 shape for CPU-runnable LM serving demos and the
    §13 plan/bench lane: same block structure (GQA kv-share, QKV bias,
    SwiGLU, RMSNorm), fp32 end-to-end, unscanned layers so a frozen plan
    is structurally identical to forward()."""
    return ModelConfig(
        name="qwen2-tiny", family="dense", num_layers=2, d_model=128,
        num_heads=4, num_kv_heads=2, head_dim=32, d_ff=256, vocab_size=512,
        qkv_bias=True, mlp="swiglu", norm="rmsnorm", rope_theta=1e6,
        q_chunk=64, remat="none", scan_layers=False,
        param_dtype=jnp.float32, compute_dtype=jnp.float32,
        dbb=_dbb(sparsity),
    )


ARCHS = {
    "qwen2-72b": qwen2_72b,
    "qwen2.5-32b": qwen2_5_32b,
    "codeqwen1.5-7b": codeqwen1_5_7b,
    "starcoder2-7b": starcoder2_7b,
    "deepseek-v3-671b": deepseek_v3_671b,
    "moonshot-v1-16b-a3b": moonshot_v1_16b,
    "recurrentgemma-2b": recurrentgemma_2b,
    "internvl2-2b": internvl2_2b,
    "musicgen-medium": musicgen_medium,
    "rwkv6-3b": rwkv6_3b,
    "qwen2-tiny": qwen2_tiny,
}


def get_config(name: str, sparsity=0.625) -> ModelConfig:
    return ARCHS[name](sparsity=sparsity)


# ---------------------------------------------------------------------------
# Reduced smoke variants: same family/blocks, tiny dims, CPU-runnable.
# ---------------------------------------------------------------------------


def smoke_config(name: str, sparsity=0.625) -> ModelConfig:
    cfg = get_config(name, sparsity=sparsity)
    small = dict(
        num_layers=max(2 * len(cfg.pattern), 2) if len(cfg.pattern) > 1 else 2,
        d_model=128,
        d_ff=256,
        vocab_size=512,
        q_chunk=64,
        wkv_chunk=16,
        remat="none",
        local_window=32,
    )
    # keep head structure but small
    if cfg.mixer == "mla":
        small.update(
            num_heads=4, num_kv_heads=4, q_lora_rank=32, kv_lora_rank=32,
            qk_rope_dim=16, qk_nope_dim=16, v_head_dim=16,
        )
    elif cfg.mixer == "rwkv6":
        small.update(num_heads=4, num_kv_heads=4, rwkv_head_dim=32)
    else:
        ratio = max(1, cfg.num_heads // cfg.num_kv_heads)
        small.update(num_heads=4, num_kv_heads=max(1, 4 // ratio), head_dim=32)
    if cfg.is_moe:
        small.update(num_experts=8, top_k=2)
    if cfg.frontend == "vision":
        small.update(num_vision_tokens=8)
    if cfg.cross_attn:
        small.update(cross_len=16)
    # recurrentgemma pattern 3 tiles + 2 tail at 26 layers; smoke keeps a tail
    if len(cfg.pattern) > 1:
        small["num_layers"] = len(cfg.pattern) * 2 + 2
        small["d_rnn"] = 128
    elif cfg.d_rnn:
        small["d_rnn"] = 128
    return dataclasses.replace(cfg, **small)
