"""Config for --arch deepseek-v3-671b (exact assigned shape set)."""
from repro.configs.registry import deepseek_v3_671b as config  # noqa: F401
from repro.configs.registry import smoke_config as _smoke


def smoke(sparsity=0.625):
    return _smoke('deepseek-v3-671b', sparsity=sparsity)
