"""Assigned input-shape sets and input_specs() builders.

Every LM arch is paired with four shapes; decode_*/long_* lower serve_step
(one new token + KV cache of seq_len), train_4k lowers train_step and
prefill_32k lowers the prefill forward. Modality frontends are stubs:
input_specs provides precomputed patch/frame embeddings per the assignment.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig

SHAPES = {
    "train_4k": dict(kind="train", seq_len=4096, global_batch=256),
    "prefill_32k": dict(kind="prefill", seq_len=32768, global_batch=32),
    "decode_32k": dict(kind="decode", seq_len=32768, global_batch=128),
    "long_500k": dict(kind="decode", seq_len=524288, global_batch=1),
}


def cell_runnable(cfg: ModelConfig, shape_name: str) -> tuple[bool, str]:
    """(runnable, reason). long_500k only for bounded-state archs."""
    if shape_name == "long_500k" and not cfg.sub_quadratic:
        return False, "pure full-attention arch: 524k-token dense KV cache is the quadratic-regime artifact this shape excludes (DESIGN.md §5)"
    return True, ""


def _tok_spec(cfg: ModelConfig, b: int, s: int):
    if cfg.frontend == "audio":
        return jax.ShapeDtypeStruct((b, s, cfg.num_codebooks), jnp.int32)
    return jax.ShapeDtypeStruct((b, s), jnp.int32)


def input_specs(cfg: ModelConfig, shape_name: str) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    sh = SHAPES[shape_name]
    b, s = sh["global_batch"], sh["seq_len"]
    kind = sh["kind"]
    if kind in ("train", "prefill"):
        batch = {"tokens": _tok_spec(cfg, b, s)}
        if kind == "train":
            if cfg.frontend == "audio":
                batch["labels"] = jax.ShapeDtypeStruct((b, s, cfg.num_codebooks), jnp.int32)
            else:
                batch["labels"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
            batch["loss_mask"] = jax.ShapeDtypeStruct((b, s), jnp.float32)
        if cfg.frontend == "vision":
            batch["vision_embeds"] = jax.ShapeDtypeStruct(
                (b, cfg.num_vision_tokens, cfg.d_model), jnp.bfloat16
            )
        if cfg.cross_attn:
            batch["memory"] = jax.ShapeDtypeStruct((b, cfg.cross_len, cfg.d_model), jnp.bfloat16)
        return batch
    # decode: one new token, cache of seq_len
    batch = {"tokens": _tok_spec(cfg, b, 1)}
    if cfg.cross_attn:
        batch["memory"] = jax.ShapeDtypeStruct((b, cfg.cross_len, cfg.d_model), jnp.bfloat16)
    return batch


def make_batch(cfg: ModelConfig, *, batch: int, seq: int, key=None, kind="train") -> dict:
    """Concrete random batch (smoke tests / examples)."""
    key = key if key is not None else jax.random.PRNGKey(0)
    ks = jax.random.split(key, 4)
    vocab = cfg.codebook_vocab if cfg.frontend == "audio" else cfg.vocab_size
    tshape = (batch, seq, cfg.num_codebooks) if cfg.frontend == "audio" else (batch, seq)
    out = {"tokens": jax.random.randint(ks[0], tshape, 0, vocab, jnp.int32)}
    if kind == "train":
        out["labels"] = jax.random.randint(ks[1], tshape, 0, vocab, jnp.int32)
        out["loss_mask"] = jnp.ones((batch, seq), jnp.float32)
    if cfg.frontend == "vision" and seq > cfg.num_vision_tokens:
        out["vision_embeds"] = 0.02 * jax.random.normal(
            ks[2], (batch, cfg.num_vision_tokens, cfg.d_model), jnp.float32
        ).astype(jnp.bfloat16)
    if cfg.cross_attn:
        out["memory"] = 0.02 * jax.random.normal(
            ks[3], (batch, cfg.cross_len, cfg.d_model), jnp.float32
        ).astype(jnp.bfloat16)
    return out
