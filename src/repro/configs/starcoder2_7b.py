"""Config for --arch starcoder2-7b (exact assigned shape set)."""
from repro.configs.registry import starcoder2_7b as config  # noqa: F401
from repro.configs.registry import smoke_config as _smoke


def smoke(sparsity=0.625):
    return _smoke('starcoder2-7b', sparsity=sparsity)
