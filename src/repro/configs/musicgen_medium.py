"""Config for --arch musicgen-medium (exact assigned shape set)."""
from repro.configs.registry import musicgen_medium as config  # noqa: F401
from repro.configs.registry import smoke_config as _smoke


def smoke(sparsity=0.625):
    return _smoke('musicgen-medium', sparsity=sparsity)
