"""Config for --arch rwkv6-3b (exact assigned shape set)."""
from repro.configs.registry import rwkv6_3b as config  # noqa: F401
from repro.configs.registry import smoke_config as _smoke


def smoke(sparsity=0.625):
    return _smoke('rwkv6-3b', sparsity=sparsity)
