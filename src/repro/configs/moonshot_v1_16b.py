"""Config for --arch moonshot-v1-16b-a3b (exact assigned shape set)."""
from repro.configs.registry import moonshot_v1_16b as config  # noqa: F401
from repro.configs.registry import smoke_config as _smoke


def smoke(sparsity=0.625):
    return _smoke('moonshot-v1-16b-a3b', sparsity=sparsity)
