"""Config for --arch internvl2-2b (exact assigned shape set)."""
from repro.configs.registry import internvl2_2b as config  # noqa: F401
from repro.configs.registry import smoke_config as _smoke


def smoke(sparsity=0.625):
    return _smoke('internvl2-2b', sparsity=sparsity)
