"""Fault-tolerant checkpointing: atomic, async, elastic, verified.

- Atomic: write to <dir>/tmp.<step>, fsync, rename to <dir>/step_<n>.
  A crash mid-write never corrupts the latest checkpoint.
- Async: `save_async` snapshots arrays to host memory synchronously (cheap)
  and writes in a background thread, overlapping I/O with training.
- Elastic: arrays are stored with their *logical* (global) shapes; `restore`
  takes the target shardings and uses jax.device_put to lay them out on
  whatever mesh the restarted job has — a different pod count reshards
  transparently.
- Self-describing: a manifest.json records the pytree structure; leaves are
  stored in one .npz. DBBWeight leaves round-trip via their pytree flatten.
- Verified (DESIGN.md §15): `save` records a sha256 per leaf (over the
  exact bytes written) plus a digest of the manifest itself; `restore`
  re-hashes on the way in and raises :class:`CorruptCheckpointError` on
  any mismatch, truncation, or missing file — silent garbage never
  reaches a model. ``restore(..., fallback=True)`` walks back to the
  newest step that still verifies (the self-healing reload path).
"""
from __future__ import annotations

import hashlib
import json
import os
import pathlib
import re
import shutil
import tempfile
import threading
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

_SEP = "/"


class CorruptCheckpointError(RuntimeError):
    """A checkpoint failed integrity verification at restore: a leaf or
    manifest digest mismatched, a file is missing/truncated, or the
    archive is unreadable. Typed so the serving lifecycle (DESIGN.md §15)
    can keep the old weights serving and surface the event instead of
    loading garbage."""


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten(tree)
    return flat, treedef


def _leaf_paths(tree_like, n: int):
    """Human-readable tree path per flat leaf index (for error messages);
    falls back to bare indices when path flattening is unavailable."""
    try:
        kflat = jax.tree_util.tree_flatten_with_path(tree_like)[0]
        paths = [jax.tree_util.keystr(kp) for kp, _ in kflat]
        if len(paths) == n:
            return paths
    except Exception:  # noqa: BLE001 — paths are best-effort decoration
        pass
    return [f"[{i}]" for i in range(n)]


def _sha256(a: np.ndarray) -> str:
    return hashlib.sha256(np.ascontiguousarray(a).tobytes()).hexdigest()


def _manifest_digest(manifest: dict) -> str:
    """Digest of the manifest *content* (its own digest field excluded)."""
    body = {k: v for k, v in manifest.items() if k != "manifest_sha256"}
    return hashlib.sha256(
        json.dumps(body, sort_keys=True).encode()).hexdigest()


# Dtypes numpy's npz can't store natively survive as same-width unsigned
# bitcasts (restored through ml_dtypes via the manifest's dtype record).
# int8 is npz-native and passes through untouched — QuantDBBWeight leaves
# (int8 values/indices + fp32 scales) ride the ordinary path and round-trip
# exactly (tests/test_quant.py); int4/uint4 (1 byte per element in
# ml_dtypes' unpacked layout) need the bitcast like the fp8 formats do.
_BITCAST = {
    "bfloat16": np.uint16,
    "float8_e4m3fn": np.uint8,
    "float8_e5m2": np.uint8,
    "int4": np.uint8,
    "uint4": np.uint8,
}


def save(ckpt_dir, step: int, tree, *, extra: Optional[dict] = None) -> pathlib.Path:
    """Synchronous atomic save. Returns the final path."""
    ckpt_dir = pathlib.Path(ckpt_dir)
    ckpt_dir.mkdir(parents=True, exist_ok=True)
    flat, treedef = _flatten(tree)
    host, dtypes = [], []
    for x in flat:
        a = np.asarray(x)
        dtypes.append(str(a.dtype))
        if str(a.dtype) in _BITCAST:  # non-native dtypes survive npz as bits
            a = a.view(_BITCAST[str(a.dtype)])
        host.append(a)
    tmp = pathlib.Path(tempfile.mkdtemp(prefix=f"tmp.{step}.", dir=ckpt_dir))
    try:
        np.savez(tmp / "arrays.npz", **{f"a{i}": a for i, a in enumerate(host)})
        manifest = {
            "step": step,
            "treedef": str(treedef),
            "n_leaves": len(host),
            "dtypes": dtypes,
            # integrity record (§15): one sha256 per leaf over the exact
            # bytes written (post-bitcast), verified by restore()
            "digests": [_sha256(a) for a in host],
            "extra": extra or {},
        }
        manifest["manifest_sha256"] = _manifest_digest(manifest)
        (tmp / "manifest.json").write_text(json.dumps(manifest))
        # fsync directory contents for crash safety
        for f in tmp.iterdir():
            fd = os.open(f, os.O_RDONLY)
            os.fsync(fd)
            os.close(fd)
        final = ckpt_dir / f"step_{step:08d}"
        if final.exists():
            shutil.rmtree(final)
        os.rename(tmp, final)
        return final
    finally:
        if tmp.exists():
            shutil.rmtree(tmp, ignore_errors=True)


class AsyncCheckpointer:
    """Snapshot-to-host synchronously; persist in a background thread."""

    def __init__(self, ckpt_dir, keep: int = 3):
        self.ckpt_dir = pathlib.Path(ckpt_dir)
        self.keep = keep
        self._thread: Optional[threading.Thread] = None

    def save_async(self, step: int, tree, *, extra=None):
        self.wait()
        flat, treedef = _flatten(tree)
        host = [np.asarray(x) for x in flat]  # device->host copy happens here
        snapshot = jax.tree_util.tree_unflatten(treedef, host)

        def work():
            save(self.ckpt_dir, step, snapshot, extra=extra)
            self._gc()

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = sorted(list_steps(self.ckpt_dir))
        for s in steps[: -self.keep]:
            shutil.rmtree(self.ckpt_dir / f"step_{s:08d}", ignore_errors=True)


def list_steps(ckpt_dir) -> list:
    ckpt_dir = pathlib.Path(ckpt_dir)
    if not ckpt_dir.exists():
        return []
    out = []
    for p in ckpt_dir.iterdir():
        m = re.fullmatch(r"step_(\d+)", p.name)
        if m and (p / "manifest.json").exists():
            out.append(int(m.group(1)))
    return sorted(out)


def latest_step(ckpt_dir) -> Optional[int]:
    steps = list_steps(ckpt_dir)
    return steps[-1] if steps else None


def read_verified(ckpt_dir, *, step: Optional[int] = None):
    """Read and integrity-check one checkpoint; no model tree required.

    Returns ``(manifest, raw_leaves)`` — the leaves as written (still
    bitcast for npz-hostile dtypes). Raises :class:`CorruptCheckpointError`
    on a missing/unreadable file, a manifest whose own digest mismatches,
    a wrong leaf count, or any leaf whose sha256 differs from the one
    recorded at save. Checkpoints written before digests existed verify
    structurally only (no digest record to check against).
    """
    ckpt_dir = pathlib.Path(ckpt_dir)
    step = step if step is not None else latest_step(ckpt_dir)
    if step is None:
        raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    d = ckpt_dir / f"step_{step:08d}"
    try:
        manifest = json.loads((d / "manifest.json").read_text())
    except (OSError, ValueError) as e:
        raise CorruptCheckpointError(
            f"step {step}: manifest.json unreadable: {e}") from e
    recorded = manifest.get("manifest_sha256")
    if recorded is not None and recorded != _manifest_digest(manifest):
        raise CorruptCheckpointError(
            f"step {step}: manifest digest mismatch (manifest edited or "
            "truncated after save)")
    n = manifest.get("n_leaves")
    if not isinstance(n, int) or n < 0:
        raise CorruptCheckpointError(
            f"step {step}: manifest has no usable n_leaves ({n!r})")
    try:
        with np.load(d / "arrays.npz") as data:
            # materialize every leaf inside the try: npz reads lazily, so
            # a truncated archive may only fail at member access
            raw = [np.asarray(data[f"a{i}"]) for i in range(n)]
    except CorruptCheckpointError:
        raise
    except Exception as e:  # noqa: BLE001 — missing/truncated/unreadable
        raise CorruptCheckpointError(
            f"step {step}: arrays.npz unreadable ({type(e).__name__}: {e})"
        ) from e
    digests = manifest.get("digests")
    if digests is not None:
        if len(digests) != len(raw):
            raise CorruptCheckpointError(
                f"step {step}: {len(digests)} digests for {len(raw)} leaves")
        for i, (a, want) in enumerate(zip(raw, digests)):
            if _sha256(a) != want:
                raise CorruptCheckpointError(
                    f"step {step}: leaf {i} sha256 mismatch — checkpoint "
                    "bytes differ from what save() recorded")
    return manifest, raw


def restore(ckpt_dir, tree_like, *, step: Optional[int] = None, shardings=None,
            fallback: bool = False):
    """Restore into the structure of ``tree_like``.

    shardings: optional matching pytree of jax.sharding.Sharding — arrays are
    device_put with these (elastic reshard on a new mesh). Without it, plain
    host arrays are returned.

    Every read is integrity-verified (:func:`read_verified`);
    :class:`CorruptCheckpointError` is raised on any mismatch/truncation.
    ``fallback=True`` (opt-in) walks back from the requested step to the
    newest step that still verifies instead of failing — the restored
    manifest's ``step`` tells the caller which one actually loaded.
    """
    ckpt_dir = pathlib.Path(ckpt_dir)
    step = step if step is not None else latest_step(ckpt_dir)
    if step is None:
        raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    if not fallback:
        manifest, raw = read_verified(ckpt_dir, step=step)
    else:
        candidates = [s for s in reversed(list_steps(ckpt_dir)) if s <= step]
        first_err: Optional[CorruptCheckpointError] = None
        manifest = raw = None
        for s in candidates:
            try:
                manifest, raw = read_verified(ckpt_dir, step=s)
                break
            except CorruptCheckpointError as e:
                first_err = first_err or e
        if manifest is None:
            raise CorruptCheckpointError(
                f"no verifiable checkpoint under {ckpt_dir} (tried "
                f"{candidates}); first failure: {first_err}")
    step = manifest["step"]
    flat_like, treedef = _flatten(tree_like)
    assert manifest["n_leaves"] == len(flat_like), (
        manifest["n_leaves"],
        len(flat_like),
        "checkpoint/model structure mismatch",
    )
    import ml_dtypes

    flat = []
    for i in range(len(flat_like)):
        a = raw[i]
        dt = manifest.get("dtypes", [None] * len(flat_like))[i]
        if dt in _BITCAST:
            a = a.view(getattr(ml_dtypes, dt))
        flat.append(a)
    paths = _leaf_paths(tree_like, len(flat_like))
    for i, (a, ref) in enumerate(zip(flat, flat_like)):
        if hasattr(ref, "shape") and tuple(a.shape) != tuple(ref.shape):
            raise ValueError(
                f"leaf {i} ({paths[i]}) at step {step}: "
                f"ckpt {a.shape} vs model {ref.shape}")
    if shardings is not None:
        flat_sh = jax.tree_util.tree_leaves(shardings)
        flat = [
            jax.device_put(a.astype(ref.dtype) if hasattr(ref, "dtype") else a, s)
            for a, ref, s in zip(flat, flat_like, flat_sh)
        ]
    else:
        flat = [
            jnp.asarray(a, dtype=getattr(ref, "dtype", None))
            for a, ref in zip(flat, flat_like)
        ]
    return jax.tree_util.tree_unflatten(treedef, flat), manifest
