"""Training launcher.

Single-host CPU runs use reduced (smoke) configs directly; on a TPU pod the
same entry point builds the production mesh and shards params/optimizer via
the arch's sharding rules. Auto-resumes from the latest checkpoint.

  PYTHONPATH=src python -m repro.launch.train --arch qwen2-72b --smoke \
      --steps 50 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse

import jax

from repro.configs import get_config, smoke_config
from repro.core.sparse_linear import PruneSchedule
from repro.data.pipeline import DataConfig
from repro.models.common import sharding_rules
from repro.models.model import LM
from repro.optim.adamw import OptConfig
from repro.sharding.rules import make_rules
from repro.train.loop import LoopConfig, Trainer


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true", help="reduced config (CPU)")
    ap.add_argument("--sparsity", type=float, default=0.625)
    ap.add_argument("--dense", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--grad-compression", action="store_true")
    ap.add_argument("--prune-anneal-steps", type=int, default=0)
    ap.add_argument("--distributed", action="store_true",
                    help="build the production mesh and shard (TPU pods)")
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args(argv)

    sparsity = None if args.dense else args.sparsity
    cfg = (smoke_config if args.smoke else get_config)(args.arch, sparsity=sparsity)
    model = LM(cfg)
    opt = OptConfig(
        peak_lr=args.lr,
        warmup_steps=max(args.steps // 20, 5),
        decay_steps=args.steps,
        grad_compression=args.grad_compression,
    )
    data = DataConfig(seq_len=args.seq_len, global_batch=args.global_batch)
    loop = LoopConfig(
        total_steps=args.steps, ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every
    )
    sched = (
        PruneSchedule(0, args.prune_anneal_steps) if args.prune_anneal_steps else None
    )

    if args.distributed:
        from jax.sharding import NamedSharding

        from repro.launch.mesh import make_production_mesh, tp_degree

        mesh = make_production_mesh(multi_pod=args.multi_pod)
        rules = make_rules(cfg, tp=tp_degree(mesh), multi_pod=args.multi_pod, mode="train")
        pspecs = model.pspecs(rules)
        shardings = jax.tree_util.tree_map(lambda s: NamedSharding(mesh, s), pspecs)
        with mesh, sharding_rules(rules, mesh):
            trainer = Trainer(model, opt, data, loop, sched,
                              jit_kwargs=dict(in_shardings=None))
            trainer.run()
    else:
        trainer = Trainer(model, opt, data, loop, sched)
        params, _, history = trainer.run()
        if len(history) >= 2:
            print(f"loss: {history[0][1]:.3f} -> {history[-1][1]:.3f}")
        return history


if __name__ == "__main__":
    main()
