"""Batched serving driver: prefill + decode loop with VDBB-compressed
weights — the paper's bandwidth win applied where TPU decode is most
weight-bandwidth-bound.

  PYTHONPATH=src python -m repro.launch.serve --arch codeqwen1.5-7b --smoke \
      --batch 4 --prompt-len 32 --gen 16

CNN archs serve through a **frozen plan** (DESIGN.md §10): INT8
quantization is calibrated, every layer's tuned tile config + staged
weight buffers are resolved once by ``SparseCNN.plan()``, and the timed
loop runs the single-dispatch ``plan.serve`` hot path. ``--no-plan``
serves the unplanned per-call path for comparison; ``--tune search``
runs the tile autotuner at plan-build time (persisted in the autotune
cache, so repeat launches are search-free).

  PYTHONPATH=src python -m repro.launch.serve --arch sparse-cnn-tiny --smoke \
      --batch 4 --steps 16 --tune search
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs import CNN_ARCHS, get_cnn_config, get_config, make_batch, \
    smoke_cnn_config, smoke_config
from repro.models.model import LM
from repro.train.step import make_prefill, make_serve_step


def generate(model: LM, params, prompt_batch, *, gen_len: int, max_len: int):
    """Greedy batched generation. Returns (tokens, steps/s)."""
    cfg = model.cfg
    prefill = jax.jit(make_prefill(model))
    step_fn = jax.jit(make_serve_step(model))
    b = prompt_batch["tokens"].shape[0]
    plen = prompt_batch["tokens"].shape[1]
    logits, caches = prefill(params, prompt_batch)

    # pad the prefill cache out to max_len capacity
    def pad_to_cap(a):
        if a.ndim >= 3 and a.shape[-3] == plen:
            pad = [(0, 0)] * a.ndim
            pad[-3] = (0, max_len - plen)
            return jnp.pad(a, pad)
        if a.ndim >= 2 and a.shape[-2] == plen and a.shape[-1] != plen:
            pad = [(0, 0)] * a.ndim
            pad[-2] = (0, max_len - plen)
            return jnp.pad(a, pad)
        return a

    cache = jax.tree_util.tree_map(pad_to_cap, caches)
    tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    if cfg.frontend == "audio":
        tok = jnp.broadcast_to(tok[..., None] % cfg.codebook_vocab, (b, 1, cfg.num_codebooks))
    out = [tok]
    t0 = time.time()
    for i in range(gen_len - 1):
        step = {"tokens": tok}
        if cfg.cross_attn and "memory" in prompt_batch:
            step["memory"] = prompt_batch["memory"]
        logits, cache = step_fn(params, cache, step, jnp.int32(plen + i))
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        if cfg.frontend == "audio":
            tok = jnp.broadcast_to(tok[..., None] % cfg.codebook_vocab, (b, 1, cfg.num_codebooks))
        out.append(tok)
    dt = time.time() - t0
    toks = jnp.concatenate(out, axis=1)
    return toks, (gen_len - 1) / max(dt, 1e-9)


def serve_cnn(args):
    """INT8 CNN serving through a frozen plan (DESIGN.md §10)."""
    from repro.models.cnn import SparseCNN

    cfgf = smoke_cnn_config if args.smoke else get_cnn_config
    sparsity = None if args.dense else args.sparsity
    cfg = dataclasses.replace(
        cfgf(args.arch, sparsity=sparsity), kernel_mode="pallas"
    )
    model = SparseCNN(cfg)
    params = model.compress(model.init(jax.random.PRNGKey(0)))
    xb = jax.random.normal(
        jax.random.PRNGKey(1),
        (args.batch, cfg.image_size, cfg.image_size, cfg.in_channels),
    )
    _, stats = model.apply(params, xb, collect_act_stats=True)
    qparams = model.quantize(params, stats)
    print(f"[serve] {cfg.name}: INT8-calibrated, nnz={cfg.fmt.nnz}/{cfg.fmt.bz}")
    if args.plan:
        plan = model.plan(qparams, batch=args.batch, tune=args.tune)
        tiles = plan.tiles
        print(f"[serve] frozen plan: {len(plan.layers)} stages, "
              f"tuned tiles for {len(tiles)} layers ({args.tune})")
        step = plan.serve
    else:
        print("[serve] unplanned per-call path (--no-plan)")
        step = lambda xb: model.apply(qparams, xb)  # noqa: E731
    from repro.xla_utils import median_time_us  # the shared bench/tuner harness

    logits = step(xb)
    us = median_time_us(step, xb, warmup=1, reps=args.steps)
    print(f"served batches of {args.batch} ({logits.shape} logits) at "
          f"{1e6 / max(us, 1e-9):.2f} steps/s (median of {args.steps})")
    return logits


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--sparsity", type=float, default=0.625)
    ap.add_argument("--dense", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--steps", type=int, default=16,
                    help="timed forward passes (CNN serving)")
    ap.add_argument("--plan", action=argparse.BooleanOptionalAction, default=True,
                    help="CNN: serve through a frozen plan (--no-plan = per-call path)")
    ap.add_argument("--tune", choices=("off", "cache", "search"), default="cache",
                    help="CNN plan tile resolution: autotune cache hits only "
                         "(default), full search, or pick_tile defaults")
    args = ap.parse_args(argv)

    if args.arch in CNN_ARCHS:
        return serve_cnn(args)

    sparsity = None if args.dense else args.sparsity
    cfg = (smoke_config if args.smoke else get_config)(args.arch, sparsity=sparsity)
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    if cfg.dbb is not None and cfg.serve_compressed:
        params = model.compress(params)
        print("[serve] weights compressed to VDBB layout "
              f"(nnz={cfg.dbb.nnz}/{cfg.dbb.bz})")
    prompt = make_batch(cfg, batch=args.batch, seq=args.prompt_len, kind="serve")
    toks, rate = generate(
        model, params, prompt, gen_len=args.gen, max_len=args.prompt_len + args.gen
    )
    print(f"generated {toks.shape} tokens at {rate:.2f} steps/s")
    return toks


if __name__ == "__main__":
    main()
