"""Batched serving driver: prefill + decode loop with VDBB-compressed
weights — the paper's bandwidth win applied where TPU decode is most
weight-bandwidth-bound.

  PYTHONPATH=src python -m repro.launch.serve --arch codeqwen1.5-7b --smoke \
      --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, make_batch, smoke_config
from repro.models.model import LM
from repro.train.step import make_prefill, make_serve_step


def generate(model: LM, params, prompt_batch, *, gen_len: int, max_len: int):
    """Greedy batched generation. Returns (tokens, steps/s)."""
    cfg = model.cfg
    prefill = jax.jit(make_prefill(model))
    step_fn = jax.jit(make_serve_step(model))
    b = prompt_batch["tokens"].shape[0]
    plen = prompt_batch["tokens"].shape[1]
    logits, caches = prefill(params, prompt_batch)

    # pad the prefill cache out to max_len capacity
    def pad_to_cap(a):
        if a.ndim >= 3 and a.shape[-3] == plen:
            pad = [(0, 0)] * a.ndim
            pad[-3] = (0, max_len - plen)
            return jnp.pad(a, pad)
        if a.ndim >= 2 and a.shape[-2] == plen and a.shape[-1] != plen:
            pad = [(0, 0)] * a.ndim
            pad[-2] = (0, max_len - plen)
            return jnp.pad(a, pad)
        return a

    cache = jax.tree_util.tree_map(pad_to_cap, caches)
    tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    if cfg.frontend == "audio":
        tok = jnp.broadcast_to(tok[..., None] % cfg.codebook_vocab, (b, 1, cfg.num_codebooks))
    out = [tok]
    t0 = time.time()
    for i in range(gen_len - 1):
        step = {"tokens": tok}
        if cfg.cross_attn and "memory" in prompt_batch:
            step["memory"] = prompt_batch["memory"]
        logits, cache = step_fn(params, cache, step, jnp.int32(plen + i))
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        if cfg.frontend == "audio":
            tok = jnp.broadcast_to(tok[..., None] % cfg.codebook_vocab, (b, 1, cfg.num_codebooks))
        out.append(tok)
    dt = time.time() - t0
    toks = jnp.concatenate(out, axis=1)
    return toks, (gen_len - 1) / max(dt, 1e-9)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--sparsity", type=float, default=0.625)
    ap.add_argument("--dense", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args(argv)

    sparsity = None if args.dense else args.sparsity
    cfg = (smoke_config if args.smoke else get_config)(args.arch, sparsity=sparsity)
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    if cfg.dbb is not None and cfg.serve_compressed:
        params = model.compress(params)
        print("[serve] weights compressed to VDBB layout "
              f"(nnz={cfg.dbb.nnz}/{cfg.dbb.bz})")
    prompt = make_batch(cfg, batch=args.batch, seq=args.prompt_len, kind="serve")
    toks, rate = generate(
        model, params, prompt, gen_len=args.gen, max_len=args.prompt_len + args.gen
    )
    print(f"generated {toks.shape} tokens at {rate:.2f} steps/s")
    return toks


if __name__ == "__main__":
    main()
