"""Batched serving driver: prefill + decode loop with VDBB-compressed
weights — the paper's bandwidth win applied where TPU decode is most
weight-bandwidth-bound.

  PYTHONPATH=src python -m repro.launch.serve --arch codeqwen1.5-7b --smoke \
      --batch 4 --prompt-len 32 --gen 16

CNN archs serve through a **frozen plan** (DESIGN.md §10): INT8
quantization is calibrated, every layer's tuned tile config + staged
weight buffers are resolved once by ``SparseCNN.plan()``, and the timed
loop runs the single-dispatch ``plan.serve`` hot path. ``--no-plan``
serves the unplanned path — jitted once, so the comparison measures the
plan's staging win, not python dispatch overhead; ``--tune search``
runs the tile autotuner at plan-build time (persisted in the autotune
cache, so repeat launches are search-free).

  PYTHONPATH=src python -m repro.launch.serve --arch sparse-cnn-tiny --smoke \
      --batch 4 --steps 16 --tune search

``--server`` runs the **continuous-batching tier** (DESIGN.md §11)
instead of a fixed-batch loop: a bucketed plan set (1/2/…/--max-batch),
the request queue + micro-batcher of ``repro.launch.server``, and a
Poisson load generator at ``--rate`` requests/s (default: auto-picked
at ~50% of measured capacity). The server runs under the §15
``Supervisor`` (crash → supervised restart with requeue), and
``--reload-every N`` hot-reloads the weights from a checksummed
checkpoint every N requests — an atomic plan swap mid-traffic. Reports
p50/p99 latency, sustained throughput, aggregation shape, supervisor
state (restarts / requeued / reloads / demoted buckets / health), and
the zero-retrace check:

  PYTHONPATH=src python -m repro.launch.serve --arch sparse-cnn-tiny --smoke \
      --server --max-batch 8 --max-wait-ms 5 --requests 64 --reload-every 24

``--lm-plan`` serves LM prefill through the same frozen-plan machinery
(DESIGN.md §13): compress → calibrate → INT8-quantize → ``LM.plan()``,
with a bit-identity check against the jitted unplanned forward:

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-tiny --lm-plan \
      --batch 2 --prompt-len 32
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs import CNN_ARCHS, get_cnn_config, get_config, make_batch, \
    smoke_cnn_config, smoke_config
from repro.models.model import LM
from repro.train.step import make_prefill, make_serve_step


def generate(model: LM, params, prompt_batch, *, gen_len: int, max_len: int):
    """Greedy batched generation. Returns (tokens, steps/s)."""
    cfg = model.cfg
    prefill = jax.jit(make_prefill(model))
    step_fn = jax.jit(make_serve_step(model))
    b = prompt_batch["tokens"].shape[0]
    plen = prompt_batch["tokens"].shape[1]
    logits, caches = prefill(params, prompt_batch)

    # pad the prefill cache out to max_len capacity
    def pad_to_cap(a):
        if a.ndim >= 3 and a.shape[-3] == plen:
            pad = [(0, 0)] * a.ndim
            pad[-3] = (0, max_len - plen)
            return jnp.pad(a, pad)
        if a.ndim >= 2 and a.shape[-2] == plen and a.shape[-1] != plen:
            pad = [(0, 0)] * a.ndim
            pad[-2] = (0, max_len - plen)
            return jnp.pad(a, pad)
        return a

    cache = jax.tree_util.tree_map(pad_to_cap, caches)
    tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    if cfg.frontend == "audio":
        tok = jnp.broadcast_to(tok[..., None] % cfg.codebook_vocab, (b, 1, cfg.num_codebooks))
    out = [tok]
    t0 = time.time()
    for i in range(gen_len - 1):
        step = {"tokens": tok}
        if cfg.cross_attn and "memory" in prompt_batch:
            step["memory"] = prompt_batch["memory"]
        logits, cache = step_fn(params, cache, step, jnp.int32(plen + i))
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        if cfg.frontend == "audio":
            tok = jnp.broadcast_to(tok[..., None] % cfg.codebook_vocab, (b, 1, cfg.num_codebooks))
        out.append(tok)
    dt = time.time() - t0
    toks = jnp.concatenate(out, axis=1)
    return toks, (gen_len - 1) / max(dt, 1e-9)


def serve_cnn(args):
    """INT8 CNN serving through a frozen plan (DESIGN.md §10)."""
    from repro.models.cnn import SparseCNN

    cfgf = smoke_cnn_config if args.smoke else get_cnn_config
    sparsity = None if args.dense else args.sparsity
    cfg = dataclasses.replace(
        cfgf(args.arch, sparsity=sparsity), kernel_mode="pallas"
    )
    model = SparseCNN(cfg)
    params = model.compress(model.init(jax.random.PRNGKey(0)))
    xb = jax.random.normal(
        jax.random.PRNGKey(1),
        (args.batch, cfg.image_size, cfg.image_size, cfg.in_channels),
    )
    _, stats = model.apply(params, xb, collect_act_stats=True)
    qparams = model.quantize(params, stats)
    print(f"[serve] {cfg.name}: INT8-calibrated, nnz={cfg.fmt.nnz}/{cfg.fmt.bz}")
    if args.server:
        return serve_cnn_continuous(args, model, qparams, xb)
    if args.plan:
        plan = model.plan(qparams, batch=args.batch, tune=args.tune)
        tiles = plan.tiles
        print(f"[serve] frozen plan: {len(plan.layers)} stages, "
              f"tuned tiles for {len(tiles)} layers ({args.tune})")
        step = plan.serve
    else:
        # jitted once: the comparison vs --plan then measures what plans
        # save (staging, weight folding, tile pinning), not retrace/
        # python-dispatch overhead the unplanned path would otherwise pay
        # on every timed call.
        print("[serve] unplanned path, jitted once (--no-plan)")
        step = jax.jit(lambda xb: model.apply(qparams, xb))
    from repro.xla_utils import median_time_us  # the shared bench/tuner harness

    logits = step(xb)
    us = median_time_us(step, xb, warmup=1, reps=args.steps)
    print(f"served batches of {args.batch} ({logits.shape} logits) at "
          f"{1e6 / max(us, 1e-9):.2f} steps/s (median of {args.steps})")
    return logits


def serve_cnn_continuous(args, model, qparams, xpool):
    """The §11 serving tier under a Poisson load (``--server``), with the
    §14 robustness knobs: bounded admission (``--max-queue`` /
    ``--shed``), per-request deadlines (``--deadline-ms``), and a
    client-side timeout derived from the server's own deadline/max-wait
    config + measured bucket time (no hardcoded constant). Per-request
    failures (shed, expired, faulted) are tallied into the summary
    instead of crashing the run on the first bad future.

    The server runs under the §15 :class:`Supervisor`: a dispatcher
    crash restarts it (requeuing undispatched requests) instead of
    failing the run, and ``--reload-every N`` exercises the hot-reload
    path live — the quantized weights are checkpointed (checksummed) at
    startup and every N requests the supervisor restores, verifies,
    rebuilds, and atomically swaps the plan set mid-traffic."""
    from repro.launch.server import CNNServer, Overloaded, ServerCrashed, \
        auto_rate, poisson_arrivals
    from repro.launch.supervisor import Supervisor

    sample_shape = xpool.shape[1:]
    plan_set = model.plan_set(qparams, max_batch=args.max_batch, tune=args.tune)
    print(f"[serve] plan set: buckets {plan_set.buckets} ({args.tune}), "
          f"max-wait {args.max_wait_ms}ms, max-queue {args.max_queue} "
          f"({args.shed})")
    rate = args.rate
    if rate is None:
        rate, bucket_us = auto_rate(plan_set, sample_shape)
        print(f"[serve] auto rate: {rate:.1f} rps "
              f"(~50% of capacity; largest bucket {bucket_us:.0f}us)")
    arrivals = poisson_arrivals(rate, args.requests, seed=0)
    # clients hand the server host data: a jax slice per submit would
    # enqueue onto the same device stream the serving batches run on
    import numpy as np

    pool = np.asarray(xpool)
    deadline_s = args.deadline_ms / 1e3 if args.deadline_ms else None
    srv = CNNServer(plan_set, max_wait_ms=args.max_wait_ms,
                    max_queue=args.max_queue, shed=args.shed)
    # reload plans resolve tiles from the autotune cache the first build
    # populated — a live reload must never block on a tile search
    retune = "cache" if args.tune == "search" else args.tune
    sup = Supervisor(
        srv,
        rebuild=lambda tree: model.plan_set(
            tree, max_batch=args.max_batch, tune=retune),
        template=qparams,
    )
    ckpt_dir = None
    if args.reload_every:
        import tempfile

        from repro.checkpoint.store import save as ckpt_save

        ckpt_dir = tempfile.mkdtemp(prefix="serve-ckpt-")
        ckpt_save(ckpt_dir, 1, qparams)
        print(f"[serve] hot-reload every {args.reload_every} requests from "
              f"checksummed checkpoint at {ckpt_dir}")
    results, failures = [], {}
    with sup:
        sup.warmup(sample_shape)
        futures = []
        t0 = time.monotonic()
        for i, t_arr in enumerate(arrivals):
            lag = t_arr - (time.monotonic() - t0)
            if lag > 0:
                time.sleep(lag)
            if ckpt_dir is not None and i and i % args.reload_every == 0:
                step, fp = sup.reload(ckpt_dir)
                print(f"[serve] hot reload @req {i}: step {step}, plan "
                      f"{fp[:12]} swapped mid-traffic")
            try:
                futures.append(
                    sup.submit(pool[i % pool.shape[0]][None],
                               deadline_s=deadline_s))
            except Overloaded as e:  # shed — the run keeps going
                failures["Overloaded"] = failures.get("Overloaded", 0) + 1
                futures.append(None)
                if failures["Overloaded"] == 1:
                    print(f"[serve] shedding (retry-after "
                          f"{e.retry_after_s * 1e3:.1f}ms)")
            except ServerCrashed:  # restart gap — tally, keep offering
                failures["ServerCrashed"] = failures.get("ServerCrashed", 0) + 1
                futures.append(None)
        # derived from max_wait + backlog x measured bucket time —
        # replaces the old hardcoded f.result(timeout=120)
        timeout_s = sup.request_timeout_s()
        for f in futures:
            if f is None:
                results.append(None)
                continue
            try:
                results.append(f.result(timeout=timeout_s))
            except Exception as e:  # noqa: BLE001 — tally, don't crash the run
                failures[type(e).__name__] = failures.get(type(e).__name__, 0) + 1
                results.append(None)
        health = sup.health()
    sup.stats.assert_accounting()
    s = sup.stats.summary()
    print(f"[serve] {s['completed']}/{s['offered']} requests in {s['batches']} "
          f"batches {s['bucket_counts']} (padded_frac {s['padded_frac']})")
    if failures:
        tally = ", ".join(f"{k} x{v}" for k, v in sorted(failures.items()))
        print(f"[serve] per-request failures: {tally} "
              f"(shed_rate {s['shed_rate']}, expired {s['expired']}, "
              f"failed {s['failed']})")
    demoted = health.get("demoted", {})
    print(f"[serve] supervisor: restarts {s['restarts']}  "
          f"requeued {s['requeued']}  reloads {s['reloads']}  "
          f"demoted buckets {sorted(demoted) if demoted else 'none'}")
    print(f"[serve] p50 {s['p50_us']:.0f}us  p99 {s['p99_us']:.0f}us  "
          f"goodput {s['throughput_rps']:.1f} rps  "
          f"client timeout {timeout_s:.1f}s (derived)  "
          f"retraces after warmup: {sup.retraces_after_warmup}  "
          f"health: {health['status']}")
    return results


def serve_lm_plan(args):
    """LM prefill served through a frozen ModelPlan (DESIGN.md §13):
    compress → calibrate → INT8-quantize → plan, then a bit-identity
    check against the jitted unplanned forward and a timed comparison."""
    sparsity = None if args.dense else args.sparsity
    cfg = (smoke_config if args.smoke else get_config)(args.arch, sparsity=sparsity)
    if cfg.dbb is None:
        raise SystemExit("--lm-plan needs a DBB config (drop --dense)")
    model = LM(cfg)
    params = model.compress(model.init(jax.random.PRNGKey(0)))
    batch = make_batch(cfg, batch=args.batch, seq=args.prompt_len, kind="serve")
    tokens = batch["tokens"]
    _, stats = model.forward(params, batch, collect_act_stats=True)
    qparams = model.quantize(params, stats)
    print(f"[serve] {cfg.name}: INT8-calibrated VDBB LM "
          f"(nnz={cfg.dbb.nnz}/{cfg.dbb.bz}, kernel_mode={cfg.kernel_mode})")
    plan = model.plan(qparams, batch=args.batch, seq=args.prompt_len,
                      tune=args.tune)
    print(f"[serve] frozen plan: {len(plan.layers)} stages ({args.tune})")
    # the §14 admission check guards the LM path too: token batches are
    # validated against the plan's sample spec before any dispatch
    from repro.launch.server import validate_request

    for row in tokens:
        validate_request(row[None], plan.sample_spec)
    ref = jax.jit(lambda t: model.forward(qparams, {"tokens": t}))
    bit = bool((plan(tokens) == ref(tokens)).all())
    print(f"[serve] plan vs unplanned forward bit-identical: {bit}")
    from repro.xla_utils import median_time_us

    plan_us = median_time_us(plan.serve, tokens, warmup=1, reps=args.steps)
    ref_us = median_time_us(ref, tokens, warmup=1, reps=args.steps)
    print(f"[serve] prefill ({args.batch}x{args.prompt_len}): plan "
          f"{plan_us:.0f}us vs unplanned {ref_us:.0f}us")
    return bit


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--sparsity", type=float, default=0.625)
    ap.add_argument("--dense", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--steps", type=int, default=16,
                    help="timed forward passes (CNN serving)")
    ap.add_argument("--plan", action=argparse.BooleanOptionalAction, default=True,
                    help="CNN: serve through a frozen plan (--no-plan = per-call path)")
    ap.add_argument("--tune", choices=("off", "cache", "search"), default="cache",
                    help="CNN plan tile resolution: autotune cache hits only "
                         "(default), full search, or pick_tile defaults")
    ap.add_argument("--lm-plan", action="store_true",
                    help="LM: serve prefill through a frozen ModelPlan "
                         "(DESIGN §13) instead of the decode loop")
    ap.add_argument("--server", action="store_true",
                    help="CNN: continuous-batching tier (DESIGN §11) under a "
                         "Poisson load instead of a fixed-batch loop")
    ap.add_argument("--max-batch", type=int, default=8,
                    help="server: aggregation cap = largest plan bucket")
    ap.add_argument("--max-wait-ms", type=float, default=5.0,
                    help="server: max queueing delay before a partial batch "
                         "dispatches")
    ap.add_argument("--requests", type=int, default=64,
                    help="server: load-generator request count")
    ap.add_argument("--rate", type=float, default=None,
                    help="server: offered load in requests/s "
                         "(default: ~50%% of measured capacity)")
    ap.add_argument("--max-queue", type=int, default=None,
                    help="server: admission bound — pending requests beyond "
                         "this are shed per --shed (default: unbounded)")
    ap.add_argument("--shed", choices=("reject", "block"), default="reject",
                    help="server: overload policy at --max-queue — reject "
                         "(typed Overloaded with retry-after) or block "
                         "(backpressure the submitter)")
    ap.add_argument("--deadline-ms", type=float, default=None,
                    help="server: per-request deadline; requests that "
                         "cannot be served in time fail with "
                         "DeadlineExceeded instead of wasting a dispatch")
    ap.add_argument("--reload-every", type=int, default=None,
                    help="server: checkpoint the quantized weights at "
                         "startup and hot-reload them (verify → rebuild → "
                         "atomic plan swap, DESIGN §15) every N requests "
                         "mid-traffic")
    args = ap.parse_args(argv)

    if args.arch in CNN_ARCHS:
        return serve_cnn(args)
    if args.lm_plan:
        return serve_lm_plan(args)

    sparsity = None if args.dense else args.sparsity
    cfg = (smoke_config if args.smoke else get_config)(args.arch, sparsity=sparsity)
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    if cfg.dbb is not None and cfg.serve_compressed:
        params = model.compress(params)
        print("[serve] weights compressed to VDBB layout "
              f"(nnz={cfg.dbb.nnz}/{cfg.dbb.bz})")
    prompt = make_batch(cfg, batch=args.batch, seq=args.prompt_len, kind="serve")
    toks, rate = generate(
        model, params, prompt, gen_len=args.gen, max_len=args.prompt_len + args.gen
    )
    print(f"generated {toks.shape} tokens at {rate:.2f} steps/s")
    return toks


if __name__ == "__main__":
    main()
