from repro.launch.mesh import make_production_mesh, make_test_mesh, tp_degree  # noqa: F401
from repro.launch.server import CNNServer, MicroBatcher, auto_rate, \
    burst_arrivals, poisson_arrivals  # noqa: F401
