from repro.launch.mesh import make_production_mesh, make_test_mesh, tp_degree  # noqa: F401
