"""Deterministic fault injection for the serving tier (DESIGN.md §14).

The chaos suite (``tests/test_faults.py``, ``benchmarks/bench_serve.py``)
never monkeypatches server internals: :class:`FaultInjector` is installed
through the three hook seams ``CNNServer`` exposes via its ``faults=``
parameter, and every injector is deterministic — poison targets are
registered by content digest, slow/kill faults fire on configured
dispatch ordinals — so a chaos run replays exactly.

Hook seams (called by the dispatcher thread):

- ``on_tick(n_items)`` — once per dispatcher loop iteration that has
  work to process, *before* any batching. Raising here simulates a
  dispatcher **crash** (not a dispatch error): the server's supervision
  must fail every pending future with ``ServerCrashed``.
- ``pre_dispatch(pendings)`` — before a batch is assembled. Raising
  :class:`FaultInjected` here simulates a **plan exception**; because the
  server re-runs the hook on every bisected sub-batch, a registered
  poison request re-raises all the way down to its lone dispatch, which
  is exactly how a real deterministic poison input behaves.
- ``pre_serve(pendings, xb) -> xb`` — after host assembly, before the
  bucket dispatch. This seam injects **slow plans** (``slow_s`` sleep,
  driving deadline/overload scenarios).
- ``post_serve(pendings, y) -> y`` — after the bucket dispatch, before
  per-request scatter. This seam injects **NaN activations** into the
  logits rows of nan-poisoned requests. It has to live *past* the
  datapath: NaN request *inputs* are already rejected at admission, and
  a NaN smuggled into the batch would be clipped finite by the int8
  requantize chain — so a numeric fault is simulated where one would
  surface, and only the server's per-request output check can isolate
  it.

:func:`bad_input` builds the malformed *request* side of the suite:
wrong-shape / wrong-dtype / non-finite arrays that admission validation
(``validate_request``) must reject alone.
"""
from __future__ import annotations

import hashlib
import time
from typing import List, Optional

import numpy as np


class FaultInjected(RuntimeError):
    """The typed error every injector raises — chaos tests assert that
    exactly the poisoned future carries exactly this."""


def bad_input(kind: str, sample_shape, *, dtype=np.float32, n: int = 1,
              seed: int = 0) -> np.ndarray:
    """A deterministic malformed request for admission-validation tests.

    ``kind``: ``'shape'`` (one trailing dim off by one), ``'rank'``
    (missing a dim), ``'dtype'`` (float64 instead of the spec dtype),
    ``'nan'`` / ``'inf'`` (spec-shaped but non-finite). All are built
    from a seeded RNG so reruns submit byte-identical poison.
    """
    rng = np.random.default_rng(seed)
    shape = (n,) + tuple(sample_shape)
    if kind == "shape":
        shape = shape[:-1] + (shape[-1] + 1,)
        return rng.standard_normal(shape).astype(dtype)
    if kind == "rank":
        return rng.standard_normal(shape[:-1]).astype(dtype)
    if kind == "dtype":
        return rng.standard_normal(shape).astype(
            np.float64 if np.dtype(dtype) != np.float64 else np.float32)
    if kind in ("nan", "inf"):
        x = rng.standard_normal(shape).astype(dtype)
        x[tuple(0 for _ in shape)] = np.nan if kind == "nan" else np.inf
        return x
    raise ValueError(f"unknown bad_input kind {kind!r}")


def _digest(x) -> str:
    a = np.ascontiguousarray(np.asarray(x))
    h = hashlib.sha1()
    h.update(str(a.shape).encode())
    h.update(str(a.dtype).encode())
    h.update(a.tobytes())
    return h.hexdigest()


class FaultInjector:
    """Deterministic hook bundle for ``CNNServer(faults=...)``.

    >>> inj = FaultInjector(slow_s=0.05)
    >>> poison = inj.poison(xpool[2:3])           # dispatch-time raise
    >>> nanpoison = inj.poison(xpool[3:4], mode="nan")  # NaN activations
    >>> srv = CNNServer(plan_set, faults=inj)

    Parameters
    ----------
    slow_s:
        Sleep injected into every ``pre_serve`` (a uniformly slow plan —
        drives deadline-expiry and overload scenarios).
    kill_after_dispatches:
        After this many dispatches have run, the next dispatcher tick
        with pending work raises (a dispatcher kill, exercising
        ``ServerCrashed`` supervision). ``None`` disables.
    """

    def __init__(self, *, slow_s: float = 0.0,
                 kill_after_dispatches: Optional[int] = None):
        self.slow_s = float(slow_s)
        self.kill_after_dispatches = kill_after_dispatches
        self.dispatches = 0          # pre_serve invocations observed
        self.faults_fired = 0        # poison/kill raises delivered
        self._poison = {}            # content digest -> 'raise' | 'nan'

    # ------------------------------------------------------ poison API
    def poison(self, x, mode: str = "raise"):
        """Register ``x`` (one request's array) as poison and return it
        unchanged. ``mode='raise'`` makes any batch containing it fail at
        ``pre_dispatch`` (a plan exception); ``mode='nan'`` corrupts its
        logits rows with NaN at ``post_serve`` (NaN activations — past
        admission and the int8 datapath, so only the server's
        per-request output check can isolate it)."""
        if mode not in ("raise", "nan"):
            raise ValueError(f"mode must be 'raise' or 'nan', got {mode!r}")
        self._poison[_digest(x)] = mode
        return x

    def is_poisoned(self, x, mode: str = "raise") -> bool:
        return self._poison.get(_digest(x)) == mode

    # ------------------------------------------------- server hook seams
    def on_tick(self, n_items: int) -> None:
        if (self.kill_after_dispatches is not None
                and self.dispatches >= self.kill_after_dispatches
                and n_items > 0):
            self.faults_fired += 1
            raise FaultInjected(
                f"dispatcher killed after {self.dispatches} dispatches")

    def pre_dispatch(self, pendings: List) -> None:
        hit = [p for p in pendings if self.is_poisoned(p.x, "raise")]
        if hit:
            self.faults_fired += 1
            raise FaultInjected(
                f"plan exception: {len(hit)} poisoned request(s) in a "
                f"batch of {len(pendings)}")

    def pre_serve(self, pendings: List, xb: np.ndarray) -> np.ndarray:
        self.dispatches += 1
        if self.slow_s > 0:
            time.sleep(self.slow_s)
        return xb

    def post_serve(self, pendings: List, y: np.ndarray) -> np.ndarray:
        off = 0
        for p in pendings:
            if self.is_poisoned(p.x, "nan"):
                self.faults_fired += 1
                y = np.array(y)  # copy-on-poison: never mutate shared output
                y[off : off + p.n] = np.nan
            off += p.n
        return y
