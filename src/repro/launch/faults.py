"""Deterministic fault injection for the serving tier (DESIGN.md §14).

The chaos suite (``tests/test_faults.py``, ``benchmarks/bench_serve.py``)
never monkeypatches server internals: :class:`FaultInjector` is installed
through the three hook seams ``CNNServer`` exposes via its ``faults=``
parameter, and every injector is deterministic — poison targets are
registered by content digest, slow/kill faults fire on configured
dispatch ordinals — so a chaos run replays exactly.

Hook seams (called by the dispatcher thread):

- ``on_tick(n_items)`` — once per dispatcher loop iteration that has
  work to process, *before* any batching. Raising here simulates a
  dispatcher **crash** (not a dispatch error): the server's supervision
  must fail every pending future with ``ServerCrashed``. A ``kills``
  budget bounds how many times the kill fires, so a supervised restart
  (DESIGN.md §15) can recover deterministically instead of crash-looping.
- ``on_restart(restarts)`` — called by the :class:`Supervisor` after it
  brings the dispatcher back up; the injector records the count so chaos
  tests can assert the restart actually happened through supervision.
- ``pre_bucket(bucket)`` — immediately before a *compiled* bucket plan
  dispatch (never before the ref fallback). ``fail_bucket`` registers a
  persistent per-bucket backend fault here: the compiled path for that
  bucket keeps raising until ``heal_bucket``, which is exactly the shape
  of a broken pallas lowering — the server must demote the bucket to its
  ref fallback and a later recovery probe re-promotes once healed.
- ``pre_dispatch(pendings)`` — before a batch is assembled. Raising
  :class:`FaultInjected` here simulates a **plan exception**; because the
  server re-runs the hook on every bisected sub-batch, a registered
  poison request re-raises all the way down to its lone dispatch, which
  is exactly how a real deterministic poison input behaves.
- ``pre_serve(pendings, xb) -> xb`` — after host assembly, before the
  bucket dispatch. This seam injects **slow plans** (``slow_s`` sleep,
  driving deadline/overload scenarios).
- ``post_serve(pendings, y) -> y`` — after the bucket dispatch, before
  per-request scatter. This seam injects **NaN activations** into the
  logits rows of nan-poisoned requests. It has to live *past* the
  datapath: NaN request *inputs* are already rejected at admission, and
  a NaN smuggled into the batch would be clipped finite by the int8
  requantize chain — so a numeric fault is simulated where one would
  surface, and only the server's per-request output check can isolate
  it.

:func:`bad_input` builds the malformed *request* side of the suite:
wrong-shape / wrong-dtype / non-finite arrays that admission validation
(``validate_request``) must reject alone. :func:`corrupt_checkpoint`
writes targeted, deterministic damage (bit-flip / truncation / manifest
edit / missing file) into an on-disk checkpoint so the §15 integrity
verification is exercised against real corruption, not mocks.
"""
from __future__ import annotations

import hashlib
import json
import pathlib
import time
from typing import List, Optional

import numpy as np


class FaultInjected(RuntimeError):
    """The typed error every injector raises — chaos tests assert that
    exactly the poisoned future carries exactly this."""


def bad_input(kind: str, sample_shape, *, dtype=np.float32, n: int = 1,
              seed: int = 0) -> np.ndarray:
    """A deterministic malformed request for admission-validation tests.

    ``kind``: ``'shape'`` (one trailing dim off by one), ``'rank'``
    (missing a dim), ``'dtype'`` (float64 instead of the spec dtype),
    ``'nan'`` / ``'inf'`` (spec-shaped but non-finite). All are built
    from a seeded RNG so reruns submit byte-identical poison.
    """
    rng = np.random.default_rng(seed)
    shape = (n,) + tuple(sample_shape)
    if kind == "shape":
        shape = shape[:-1] + (shape[-1] + 1,)
        return rng.standard_normal(shape).astype(dtype)
    if kind == "rank":
        return rng.standard_normal(shape[:-1]).astype(dtype)
    if kind == "dtype":
        return rng.standard_normal(shape).astype(
            np.float64 if np.dtype(dtype) != np.float64 else np.float32)
    if kind in ("nan", "inf"):
        x = rng.standard_normal(shape).astype(dtype)
        x[tuple(0 for _ in shape)] = np.nan if kind == "nan" else np.inf
        return x
    raise ValueError(f"unknown bad_input kind {kind!r}")


def corrupt_checkpoint(ckpt_dir, *, step: Optional[int] = None,
                       mode: str = "flip", seed: int = 0) -> pathlib.Path:
    """Write targeted, deterministic damage into an on-disk checkpoint
    (the §15 integrity corpus). Returns the damaged step directory.

    ``mode``:
      - ``'flip'``      — flip one seeded byte in ``arrays.npz`` (a leaf
        or archive byte: either way the sha256 record catches it),
      - ``'truncate'``  — cut ``arrays.npz`` to half length (torn write),
      - ``'manifest'``  — edit a manifest field without re-digesting,
      - ``'missing'``   — delete ``arrays.npz`` entirely.

    All four must surface as ``CorruptCheckpointError`` at restore —
    never silent garbage.
    """
    from repro.checkpoint.store import latest_step

    ckpt_dir = pathlib.Path(ckpt_dir)
    step = step if step is not None else latest_step(ckpt_dir)
    if step is None:
        raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    d = ckpt_dir / f"step_{step:08d}"
    arrays = d / "arrays.npz"
    if mode == "flip":
        raw = bytearray(arrays.read_bytes())
        # skip the zip local-file header; flip inside the payload
        i = 64 + np.random.default_rng(seed).integers(max(len(raw) - 128, 1))
        raw[int(i)] ^= 0xFF
        arrays.write_bytes(bytes(raw))
    elif mode == "truncate":
        raw = arrays.read_bytes()
        arrays.write_bytes(raw[: len(raw) // 2])
    elif mode == "manifest":
        mf = d / "manifest.json"
        manifest = json.loads(mf.read_text())
        manifest["n_leaves"] = int(manifest.get("n_leaves", 0)) + 1
        mf.write_text(json.dumps(manifest))  # digest left stale on purpose
    elif mode == "missing":
        arrays.unlink()
    else:
        raise ValueError(f"unknown corrupt_checkpoint mode {mode!r}")
    return d


def _digest(x) -> str:
    a = np.ascontiguousarray(np.asarray(x))
    h = hashlib.sha1()
    h.update(str(a.shape).encode())
    h.update(str(a.dtype).encode())
    h.update(a.tobytes())
    return h.hexdigest()


class FaultInjector:
    """Deterministic hook bundle for ``CNNServer(faults=...)``.

    >>> inj = FaultInjector(slow_s=0.05)
    >>> poison = inj.poison(xpool[2:3])           # dispatch-time raise
    >>> nanpoison = inj.poison(xpool[3:4], mode="nan")  # NaN activations
    >>> srv = CNNServer(plan_set, faults=inj)

    Parameters
    ----------
    slow_s:
        Sleep injected into every ``pre_serve`` (a uniformly slow plan —
        drives deadline-expiry and overload scenarios).
    kill_after_dispatches:
        After this many dispatches have run, the next dispatcher tick
        with pending work raises (a dispatcher kill, exercising
        ``ServerCrashed`` supervision). ``None`` disables.
    kills:
        Budget on how many dispatcher kills fire in total (``None`` =
        unlimited, the §14 behavior). ``kills=1`` models a transient
        crash a supervised restart recovers from; unlimited models a
        crash loop the circuit breaker must arrest.
    """

    def __init__(self, *, slow_s: float = 0.0,
                 kill_after_dispatches: Optional[int] = None,
                 kills: Optional[int] = None):
        self.slow_s = float(slow_s)
        self.kill_after_dispatches = kill_after_dispatches
        self.kills = kills
        self.kills_fired = 0         # dispatcher kills delivered
        self.restarts = 0            # supervisor restarts observed
        self.dispatches = 0          # pre_serve invocations observed
        self.faults_fired = 0        # poison/kill raises delivered
        self.bucket_faults_fired = 0  # pre_bucket raises delivered
        self._poison = {}            # content digest -> 'raise' | 'nan'
        self._bad_buckets = {}       # bucket -> remaining raises (None=inf)

    # ------------------------------------------------------ poison API
    def poison(self, x, mode: str = "raise"):
        """Register ``x`` (one request's array) as poison and return it
        unchanged. ``mode='raise'`` makes any batch containing it fail at
        ``pre_dispatch`` (a plan exception); ``mode='nan'`` corrupts its
        logits rows with NaN at ``post_serve`` (NaN activations — past
        admission and the int8 datapath, so only the server's
        per-request output check can isolate it)."""
        if mode not in ("raise", "nan"):
            raise ValueError(f"mode must be 'raise' or 'nan', got {mode!r}")
        self._poison[_digest(x)] = mode
        return x

    def is_poisoned(self, x, mode: str = "raise") -> bool:
        return self._poison.get(_digest(x)) == mode

    # ----------------------------------------------- per-bucket faults
    def fail_bucket(self, bucket: int, *, times: Optional[int] = None):
        """Register a persistent backend fault on one bucket's *compiled*
        plan: every ``pre_bucket(bucket)`` raises until ``times`` raises
        have fired (``None`` = until :meth:`heal_bucket`). The ref
        fallback path never consults this seam, which is the point — a
        broken pallas lowering doesn't break the interpreter path."""
        self._bad_buckets[int(bucket)] = times

    def heal_bucket(self, bucket: int) -> None:
        """Clear a bucket's persistent fault (the backend was fixed):
        the server's next recovery probe on the compiled path succeeds
        and re-promotes the bucket."""
        self._bad_buckets.pop(int(bucket), None)

    # ------------------------------------------------- server hook seams
    def on_tick(self, n_items: int) -> None:
        if (self.kill_after_dispatches is not None
                and self.dispatches >= self.kill_after_dispatches
                and n_items > 0
                and (self.kills is None or self.kills_fired < self.kills)):
            self.faults_fired += 1
            self.kills_fired += 1
            raise FaultInjected(
                f"dispatcher killed after {self.dispatches} dispatches")

    def on_restart(self, restarts: int) -> None:
        """Supervisor seam: records each completed restart (chaos tests
        assert the recovery path really went through supervision)."""
        self.restarts = int(restarts)

    def pre_bucket(self, bucket: int) -> None:
        left = self._bad_buckets.get(int(bucket), 0)
        if left is None or left > 0:
            if left is not None:
                self._bad_buckets[int(bucket)] = left - 1
                if left - 1 <= 0:
                    self._bad_buckets.pop(int(bucket), None)
            self.faults_fired += 1
            self.bucket_faults_fired += 1
            raise FaultInjected(
                f"backend fault on compiled bucket-{bucket} dispatch")

    def pre_dispatch(self, pendings: List) -> None:
        hit = [p for p in pendings if self.is_poisoned(p.x, "raise")]
        if hit:
            self.faults_fired += 1
            raise FaultInjected(
                f"plan exception: {len(hit)} poisoned request(s) in a "
                f"batch of {len(pendings)}")

    def pre_serve(self, pendings: List, xb: np.ndarray) -> np.ndarray:
        self.dispatches += 1
        if self.slow_s > 0:
            time.sleep(self.slow_s)
        return xb

    def post_serve(self, pendings: List, y: np.ndarray) -> np.ndarray:
        off = 0
        for p in pendings:
            if self.is_poisoned(p.x, "nan"):
                self.faults_fired += 1
                y = np.array(y)  # copy-on-poison: never mutate shared output
                y[off : off + p.n] = np.nan
            off += p.n
        return y
