import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this proves the distribution config is coherent — shardings
propagate, collectives are legal, compile-time memory fits — and records
memory_analysis / cost_analysis / per-collective byte counts for the
roofline report (EXPERIMENTS.md §Dry-run / §Roofline).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-72b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--sparsity 0.625]

Results cache to benchmarks/results/dryrun/<cell>.json; --force recomputes.
"""
import argparse
import json
import pathlib
import re
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import SHAPES, cell_runnable, get_config, input_specs
from repro.core.sparse_linear import PruneSchedule
from repro.launch.mesh import make_production_mesh, tp_degree
from repro.models.common import sharding_rules
from repro.models.model import LM
from repro.optim.adamw import OptConfig, init_state
from repro.sharding.rules import attn_mode, make_rules
from repro.train.step import make_prefill, make_serve_step, make_train_step
from repro.xla_utils import cost_analysis_dict  # re-export: tests use dr.cost_analysis_dict

RESULTS_DIR = pathlib.Path(__file__).resolve().parents[3] / "benchmarks" / "results" / "dryrun"

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"(\w+\[[^\]]*\](?:, \w+\[[^\]]*\])*\)?)?\s*"  # unused; kept simple below
)
_SHAPE_RE = re.compile(r"(f64|f32|f16|bf16|f8e4m3fn|f8e5m2|s64|u64|s32|u32|s16|u16|s8|u8|pred|c64|c128)\[([0-9,]*)\]")

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all", "collective-permute")


def _shape_bytes(text: str, tpu_equiv: bool = False) -> int:
    """Sum bytes of every shape in text. With tpu_equiv, f32/f64 count at
    2 bytes: the CPU backend's float-normalization pass upcasts every bf16
    dot/collective to f32 (verified: all JAX-level tensors are bf16), an
    artifact a TPU build does not have — see EXPERIMENTS.md §Method."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        sz = _DTYPE_BYTES[dt]
        if tpu_equiv and dt in ("f64", "f32"):
            sz = 2
        total += n * sz
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Sum result-shape bytes of every collective op in the optimized HLO.

    The module is the per-device SPMD program, so these are bytes per chip;
    the roofline multiplies by chips for the global wire volume.
    """
    out = {k: 0 for k in COLLECTIVES}
    equiv = {k: 0 for k in COLLECTIVES}
    counts = {k: 0 for k in COLLECTIVES}
    for line in hlo_text.splitlines():
        s = line.strip()
        # result-defining lines look like: %name = TYPE[...] op-name(...)
        m = re.match(r"%?[\w.\-]+ = (.*?) (\w[\w\-]*)\(", s)
        if not m:
            continue
        opname = m.group(2)
        for c in COLLECTIVES:
            if opname == c or opname.startswith(c + "-"):
                out[c] += _shape_bytes(m.group(1))
                equiv[c] += _shape_bytes(m.group(1), tpu_equiv=True)
                counts[c] += 1
                break
    return {"bytes": out, "counts": counts, "total_bytes": sum(out.values()),
            "tpu_equiv_total_bytes": sum(equiv.values())}


def _shardings(mesh, pspecs):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), pspecs,
        is_leaf=lambda x: isinstance(x, P),
    )


def _lower(cfg, shape_name, mesh, rules, *, seq_len=None, global_batch=None):
    """Lower + compile the cell's step function for ``cfg``. Returns compiled."""
    sh = dict(SHAPES[shape_name])
    if seq_len:
        sh["seq_len"] = seq_len
    if global_batch:
        sh["global_batch"] = global_batch
    kind = sh["kind"]
    model = LM(cfg)
    import repro.configs.shapes as shp

    # build specs for (possibly overridden) shape
    b, s = sh["global_batch"], sh["seq_len"]
    if kind in ("train", "prefill"):
        batch_specs = {"tokens": shp._tok_spec(cfg, b, s)}
        if kind == "train":
            if cfg.frontend == "audio":
                batch_specs["labels"] = jax.ShapeDtypeStruct((b, s, cfg.num_codebooks), jnp.int32)
            else:
                batch_specs["labels"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
            batch_specs["loss_mask"] = jax.ShapeDtypeStruct((b, s), jnp.float32)
        if cfg.frontend == "vision":
            batch_specs["vision_embeds"] = jax.ShapeDtypeStruct(
                (b, cfg.num_vision_tokens, cfg.d_model), jnp.bfloat16)
        if cfg.cross_attn:
            batch_specs["memory"] = jax.ShapeDtypeStruct((b, cfg.cross_len, cfg.d_model), jnp.bfloat16)
    else:
        batch_specs = {"tokens": shp._tok_spec(cfg, b, 1)}
        if cfg.cross_attn:
            batch_specs["memory"] = jax.ShapeDtypeStruct((b, cfg.cross_len, cfg.d_model), jnp.bfloat16)
    dp = rules["batch"]

    def batch_pspec(leaf_name, leaf):
        if leaf_name == "tokens" and kind != "decode":
            extra = ("model",) + (None,) * (leaf.ndim - 2)
            return P(dp, *extra)  # seq-sharded tokens feed the SP residual
        return P(dp, *([None] * (leaf.ndim - 1)))

    batch_shardings = {
        k: NamedSharding(mesh, batch_pspec(k, v)) for k, v in batch_specs.items()
    }

    with mesh, sharding_rules(rules, mesh):
        if kind == "train":
            params_ab = model.abstract()
            params_sh = _shardings(mesh, model.pspecs(rules))
            opt_ab = jax.eval_shape(lambda p: init_state(p, OptConfig()), params_ab)
            opt_specs = {
                "m": model.pspecs(rules),
                "v": model.pspecs(rules),
                "count": P(),
            }
            if "master" in opt_ab:
                opt_specs["master"] = model.pspecs(rules)
            opt_sh = _shardings(mesh, opt_specs)
            step_ab = jax.ShapeDtypeStruct((), jnp.int32)
            fn = make_train_step(model, OptConfig(), PruneSchedule(0, 1000))
            jfn = jax.jit(
                fn,
                in_shardings=(params_sh, opt_sh, batch_shardings, NamedSharding(mesh, P())),
                out_shardings=(params_sh, opt_sh, None),
                donate_argnums=(0, 1),
            )
            lowered = jfn.lower(params_ab, opt_ab, batch_specs, step_ab)
        elif kind == "prefill":
            if cfg.serve_compressed and cfg.dbb is not None:
                params_ab = model.compressed_abstract()
                params_sh = _shardings(mesh, model.compressed_pspecs(rules))
            else:
                params_ab = model.abstract()
                params_sh = _shardings(mesh, model.pspecs(rules))
            fn = make_prefill(model)
            jfn = jax.jit(fn, in_shardings=(params_sh, batch_shardings))
            lowered = jfn.lower(params_ab, batch_specs)
        else:  # decode
            if cfg.serve_compressed and cfg.dbb is not None:
                params_ab = model.compressed_abstract()
                params_sh = _shardings(mesh, model.compressed_pspecs(rules))
            else:
                params_ab = model.abstract()
                params_sh = _shardings(mesh, model.pspecs(rules))
            cache_ab = model.cache_abstract(b, sh["seq_len"])
            cache_sh = _shardings(mesh, model.cache_pspecs(rules))
            fn = make_serve_step(model)
            jfn = jax.jit(
                fn,
                in_shardings=(params_sh, cache_sh, batch_shardings, NamedSharding(mesh, P())),
                out_shardings=(NamedSharding(mesh, P(dp, None, "model")), cache_sh),
                donate_argnums=(1,),
            )
            pos_ab = jax.ShapeDtypeStruct((), jnp.int32)
            lowered = jfn.lower(params_ab, cache_ab, batch_specs, pos_ab)
        compiled = lowered.compile()
    return compiled


def _cost_record(compiled):
    cost = cost_analysis_dict(compiled)
    coll = collective_bytes(compiled.as_text())
    return {
        "flops": cost.get("flops"),
        "bytes_accessed": cost.get("bytes accessed"),
        "transcendentals": cost.get("transcendentals"),
        "collectives": coll,
    }


def micro_extrapolate(cfg, shape_name, mesh, rules) -> dict:
    """Exact per-device roofline terms via unrolled micro-compiles.

    XLA's HLO cost analysis counts while-loop (lax.scan) bodies ONCE, not
    per trip — so the full scanned program under-reports FLOPs/bytes by
    ~num_groups x. We unroll 1 and 2 pattern-groups (cheap compiles),
    take the per-group delta, and extrapolate:
        total(L) = base + delta * (num_groups + tail/len(pattern)).
    """
    import dataclasses as dc

    pat = len(cfg.pattern)
    c1 = dc.replace(cfg, num_layers=pat, scan_layers=False)
    c2 = dc.replace(cfg, num_layers=2 * pat, scan_layers=False)
    r1 = _cost_record(_lower(c1, shape_name, mesh, rules))
    r2 = _cost_record(_lower(c2, shape_name, mesh, rules))
    groups_eff = cfg.num_groups + len(cfg.tail_pattern) / pat

    def extrap(f1, f2):
        delta = f2 - f1
        return f1 + delta * (groups_eff - 1), delta

    flops, flops_g = extrap(r1["flops"], r2["flops"])
    bytes_, bytes_g = extrap(r1["bytes_accessed"], r2["bytes_accessed"])
    coll, coll_g = extrap(
        r1["collectives"]["total_bytes"], r2["collectives"]["total_bytes"]
    )
    coll_eq, _ = extrap(
        r1["collectives"]["tpu_equiv_total_bytes"],
        r2["collectives"]["tpu_equiv_total_bytes"],
    )
    coll_kinds = {
        k: r1["collectives"]["bytes"][k]
        + (r2["collectives"]["bytes"][k] - r1["collectives"]["bytes"][k])
        * (groups_eff - 1)
        for k in r1["collectives"]["bytes"]
    }
    return {
        "method": "unrolled micro-compile extrapolation (L=1,2 pattern groups)",
        "per_device_flops": flops,
        "per_device_bytes": bytes_,
        "per_device_collective_bytes": coll,
        "per_device_collective_bytes_tpu_equiv": coll_eq,
        "collective_bytes_by_kind": coll_kinds,
        "per_group_flops": flops_g,
        "per_group_bytes": bytes_g,
        "per_group_collective_bytes": coll_g,
        "l1": r1,
        "l2": r2,
    }


def lower_cell(arch: str, shape_name: str, *, multi_pod: bool, sparsity=0.625,
               micro: bool = True, cfg=None):
    """Build + lower + compile one cell. Returns the result record."""
    sh = SHAPES[shape_name]
    cfg = cfg or get_config(arch, sparsity=sparsity)
    ok, reason = cell_runnable(cfg, shape_name)
    if not ok:
        return {"arch": arch, "shape": shape_name, "multi_pod": multi_pod,
                "sparsity": sparsity, "status": "skipped", "reason": reason}
    mesh = make_production_mesh(multi_pod=multi_pod)
    tp = tp_degree(mesh)
    kind = sh["kind"]
    rules = make_rules(cfg, tp=tp, multi_pod=multi_pod, mode=kind)
    # batch must divide the DP extent; replicate otherwise (long_500k b=1)
    dp_size = 1
    for ax in (rules["batch"] if isinstance(rules["batch"], tuple) else (rules["batch"],)):
        dp_size *= mesh.shape[ax]
    if sh["global_batch"] % dp_size != 0:
        rules = dict(rules, batch=None)

    t0 = time.time()
    compiled = _lower(cfg, shape_name, mesh, rules)
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = cost_analysis_dict(compiled)
    coll = collective_bytes(compiled.as_text())
    rec = {
        "arch": arch, "shape": shape_name, "multi_pod": multi_pod,
        "sparsity": sparsity, "status": "ok", "kind": kind,
        "attn_mode": attn_mode(cfg, tp),
        "mesh": dict(zip(mesh.axis_names, [int(mesh.shape[a]) for a in mesh.axis_names])),
        "chips": int(len(mesh.devices.flat)),
        "compile_s": round(t_compile, 1),
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "generated_code_bytes": getattr(mem, "generated_code_size_in_bytes", None),
        },
        "cost": {
            "flops": cost.get("flops"),
            "bytes_accessed": cost.get("bytes accessed"),
            "transcendentals": cost.get("transcendentals"),
        },
        "collectives": coll,
        "hlo_caveat": "cost_analysis counts lax.scan bodies once; see 'micro' for extrapolated true per-step costs",
    }
    if micro and cfg.scan_layers:
        try:
            rec["micro"] = micro_extrapolate(cfg, shape_name, mesh, rules)
        except Exception as e:  # noqa: BLE001
            rec["micro"] = {"status": "error", "error": f"{type(e).__name__}: {e}"}
    return rec


def cell_key(arch, shape, multi_pod, sparsity):
    pod = "pod2" if multi_pod else "pod1"
    return f"{arch}__{shape}__{pod}__s{sparsity}"


def run_and_save(arch, shape, *, multi_pod, sparsity=0.625, force=False, micro=True):
    RESULTS_DIR.mkdir(parents=True, exist_ok=True)
    key = cell_key(arch, shape, multi_pod, sparsity)
    out = RESULTS_DIR / f"{key}.json"
    if out.exists() and not force:
        rec = json.loads(out.read_text())
        print(f"[cached] {key}: {rec['status']}")
        return rec
    print(f"[run] {key} ...", flush=True)
    try:
        rec = lower_cell(arch, shape, multi_pod=multi_pod, sparsity=sparsity, micro=micro)
    except Exception as e:  # noqa: BLE001 — record the failure for triage
        rec = {"arch": arch, "shape": shape, "multi_pod": multi_pod,
               "sparsity": sparsity, "status": "error",
               "error": f"{type(e).__name__}: {e}",
               "traceback": traceback.format_exc()[-4000:]}
    out.write_text(json.dumps(rec, indent=1))
    print(f"  -> {rec['status']}"
          + (f" compile={rec.get('compile_s')}s" if rec["status"] == "ok" else
             f" ({rec.get('reason', rec.get('error', ''))[:120]})"), flush=True)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES) + [None])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--sparsity", default=0.625, type=float)
    ap.add_argument("--dense", action="store_true")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    from repro.configs import ARCHS

    sparsity = None if args.dense else args.sparsity
    archs = [args.arch] if args.arch else list(ARCHS)
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    n_ok = n_skip = n_err = 0
    for mp in meshes:
        for a in archs:
            for s in shapes:
                rec = run_and_save(a, s, multi_pod=mp, sparsity=sparsity,
                                   force=args.force, micro=not mp)
                n_ok += rec["status"] == "ok"
                n_skip += rec["status"] == "skipped"
                n_err += rec["status"] == "error"
    print(f"done: {n_ok} ok, {n_skip} skipped, {n_err} errors")
    raise SystemExit(1 if n_err else 0)


if __name__ == "__main__":
    main()
