"""Continuous-batching CNN serving tier (DESIGN.md §11, §14).

The pipeline is **admission → queue → bucketer → (sharded) frozen-plan
dispatch**:

- :class:`CNNServer` owns a thread-safe request queue. ``submit(x)``
  (``x``: ``(n, H, W, C)``, any ``n ≥ 1``) returns a
  ``concurrent.futures.Future`` that resolves to that request's logits.
- A dispatcher thread aggregates requests with :class:`MicroBatcher`:
  flush as soon as ``max_batch`` samples are pending, or when the oldest
  pending request has waited ``max_wait_ms`` — the classic
  latency/throughput knob pair of a continuous-batching server.
- Each aggregated batch is served through a
  :class:`~repro.models.plan.PlanSet`: pad up to the nearest batch-size
  bucket, dispatch that bucket's pre-compiled frozen plan, slice the
  padding off, and scatter the per-request slices back into the futures.
  Because every bucket was compiled at warmup, sustained variable load
  runs **zero retraces** — a contract the server *measures* (plans count
  their traces) rather than assumes, and bit-identical to serving every
  request alone (batch rows are independent end to end).
- With a device mesh (``mesh=``, e.g. ``launch.mesh.make_production_mesh``
  / ``make_test_mesh``), each padded bucket is placed with the batch-axis
  ``NamedSharding`` from ``sharding.rules.cnn_serve_rules`` +
  ``data_pspec`` before dispatch, so the plan's jit partitions the batch
  data-parallel across the 'data' (and 'pod') axes; every bucket is a
  multiple of the DP degree by construction (``make_buckets(dp=)``), so
  the padded batch always shards evenly and each device runs the same
  staged program on its shard.

The robustness layer (DESIGN.md §14) makes the tier degrade gracefully
instead of being fast only on the happy path:

- **Admission control**: ``max_queue`` bounds in-system samples. Over
  it, ``shed='reject'`` raises :class:`Overloaded` (carrying a
  retry-after derived from the *measured* bucket service time) and
  ``shed='block'`` applies backpressure. Every request is validated
  against the plan set's per-sample spec (shape / dtype / finiteness)
  at ``submit`` — a malformed request is rejected alone
  (:class:`InvalidRequest`) instead of poisoning a co-batch.
- **Deadlines**: ``submit(x, deadline_s=...)``. The dispatcher subtracts
  the measured service estimate when computing flush deadlines (so a
  tight-deadline request flushes early enough to make it) and fails
  already-expired requests with :class:`DeadlineExceeded` *before*
  wasting a bucket dispatch on them.
- **Blast-radius isolation**: when a batch dispatch raises, the batch is
  **bisected** — each half re-dispatches independently (each half pads
  to an already-warmed bucket, so isolation adds zero retraces) until
  exactly the poison request carries the exception and every innocent
  co-batched request completes with logits bit-identical to a
  fault-free run. Non-finite logits fail only the offending request
  (:class:`NumericalFault`), not its batch.
- **Supervision**: a dispatcher *crash* (not just a dispatch error)
  fails every pending future with :class:`ServerCrashed` instead of
  stranding waiters; :meth:`CNNServer.health` reports
  ready/degraded/stopped; :meth:`CNNServer.stop` takes a drain
  ``timeout_s``; restarting after ``stop()`` resets the run's stats so
  the accounting identity and the zero-retrace snapshot stay valid.
- **Fault hooks**: ``faults=`` installs a deterministic injector
  (:class:`repro.launch.faults.FaultInjector`) at four seams —
  ``on_tick`` (dispatcher kill), ``pre_dispatch`` (plan exception),
  ``pre_serve`` (slow plan), ``post_serve`` (NaN activations) — so the
  chaos suite never monkeypatches internals.

:class:`ServerStats` closes the books on every offered sample:
``completed + rejected + failed + expired == offered`` is an asserted
invariant once the server has stopped.

The load-generator helpers (:func:`poisson_arrivals`,
:func:`burst_arrivals`) live here too so ``benchmarks/bench_serve.py``
and ``repro.launch.serve --server`` drive identical traffic shapes.
"""
from __future__ import annotations

import dataclasses
import queue as _queue
import threading
import time
from concurrent.futures import Future
from typing import Callable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np


# ------------------------------------------------------- typed failures
class ServeError(RuntimeError):
    """Base of every typed serving-tier failure (DESIGN.md §14)."""


class InvalidRequest(ServeError, ValueError):
    """Rejected at admission: the request does not match the plan's
    per-sample spec (shape / dtype / finiteness) or is structurally
    malformed. Fails only the offending request — it never reaches a
    co-batch."""


class Overloaded(ServeError):
    """Shed at admission: the bounded queue is full (``shed='reject'``).

    ``retry_after_s`` estimates when capacity frees up, derived from the
    measured bucket service time and the current backlog depth."""

    def __init__(self, msg: str, *, retry_after_s: float):
        super().__init__(msg)
        self.retry_after_s = retry_after_s


class DeadlineExceeded(ServeError):
    """The request's ``deadline_s`` passed while it was still queued; it
    was failed before wasting a bucket dispatch."""


class NumericalFault(ServeError):
    """This request's logits came back non-finite; co-batched requests
    were unaffected (batch rows are independent)."""


class ServerCrashed(ServeError):
    """The dispatcher thread itself died; pending futures are failed
    with this instead of stranding their waiters."""


# ------------------------------------------------------------- load gen
def poisson_arrivals(rate_rps: float, n: int, *, seed: int = 0) -> np.ndarray:
    """``n`` arrival offsets (seconds, ascending from ~0) of a Poisson
    process at ``rate_rps`` requests/s — the memoryless steady-traffic
    model; inter-arrival gaps are iid exponential."""
    if rate_rps <= 0:
        raise ValueError(f"rate_rps must be > 0, got {rate_rps}")
    rng = np.random.default_rng(seed)
    return np.cumsum(rng.exponential(1.0 / rate_rps, size=n))


def burst_arrivals(n: int, *, burst: int, gap_s: float,
                   start: float = 0.0) -> np.ndarray:
    """``n`` arrival offsets in back-to-back bursts of ``burst`` requests
    (all at the same instant) separated by ``gap_s`` seconds — the
    worst case for a batcher: idle, then a queue-depth spike."""
    if burst < 1:
        raise ValueError(f"burst must be >= 1, got {burst}")
    return np.asarray([start + (i // burst) * gap_s for i in range(n)])


# ----------------------------------------------------------- validation
def validate_request(x, sample_spec: Tuple[Tuple[int, ...], str],
                     *, check_finite: bool = True) -> None:
    """Admission-time request validation against a plan's per-sample spec
    (``(shape_sans_batch, dtype_name)`` — see ``ModelPlan.sample_spec``).

    Raises :class:`InvalidRequest` on shape or dtype mismatch, and — for
    floating inputs — on any non-finite value, so a NaN/Inf request is
    rejected alone instead of poisoning every co-batched request's
    output. Shared by ``CNNServer.submit`` and the LM plan CLI path.
    """
    shape, dtype = sample_spec
    if tuple(x.shape[1:]) != tuple(shape):
        raise InvalidRequest(
            f"request sample shape {tuple(x.shape[1:])} != plan spec "
            f"{tuple(shape)}")
    if np.dtype(x.dtype) != np.dtype(dtype):
        raise InvalidRequest(
            f"request dtype {np.dtype(x.dtype).name} != plan spec {dtype}")
    if check_finite and np.issubdtype(np.dtype(dtype), np.floating):
        if not np.isfinite(np.asarray(x)).all():
            raise InvalidRequest("request contains non-finite values")


# ------------------------------------------------------------ batching
@dataclasses.dataclass
class _Pending:
    """One queued request: its samples, arrival stamp, result future,
    and (optionally) the absolute monotonic deadline it must meet."""

    x: jax.Array
    n: int
    arrival: float
    future: Future
    deadline: Optional[float] = None


class MicroBatcher:
    """Pure aggregation logic (no threads, injectable clock — unit-testable).

    Accumulates pending requests until either ``max_batch`` samples are
    waiting (flush immediately) or the oldest has waited ``max_wait_s``
    (flush what's there). Requests are never split across batches: a
    request that would overflow the current batch flushes the batch
    first; a single request larger than ``max_batch`` becomes its own
    batch (``PlanSet.serve`` chunks it at the largest bucket).

    Per-request deadlines tighten the flush time: :meth:`deadline`
    returns the earlier of the max-wait flush and the tightest pending
    request deadline *minus the caller's service estimate* — queue wait
    is subtracted from the budget, so a request with a deadline flushes
    early enough to still complete in time rather than expiring in the
    batcher.
    """

    def __init__(self, max_batch: int, max_wait_s: float):
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        if max_wait_s < 0:
            raise ValueError(f"max_wait_s must be >= 0, got {max_wait_s}")
        self.max_batch = max_batch
        self.max_wait_s = max_wait_s
        self._pending: List[_Pending] = []
        self._count = 0

    def __len__(self) -> int:
        return self._count

    def add(self, p: _Pending) -> List[List[_Pending]]:
        """Queue one request; return the batches (0, 1 or 2) it flushed."""
        out = []
        if self._pending and self._count + p.n > self.max_batch:
            out.append(self.take())
        self._pending.append(p)
        self._count += p.n
        if self._count >= self.max_batch:
            out.append(self.take())
        return out

    def deadline(self, service_est_s: float = 0.0) -> Optional[float]:
        """Absolute time the pending set must flush by: oldest arrival +
        max-wait, tightened by any request deadline less the expected
        service time (``service_est_s``, the dispatcher's measured
        bucket-time estimate)."""
        if not self._pending:
            return None
        dl = self._pending[0].arrival + self.max_wait_s
        for p in self._pending:
            if p.deadline is not None:
                dl = min(dl, p.deadline - service_est_s)
        return dl

    def due(self, now: float, service_est_s: float = 0.0) -> bool:
        dl = self.deadline(service_est_s)
        return dl is not None and now >= dl

    def take(self) -> List[_Pending]:
        """Flush everything pending (the dispatcher's max-wait path)."""
        batch, self._pending, self._count = self._pending, [], 0
        return batch


# --------------------------------------------------------------- stats
@dataclasses.dataclass
class ServerStats:
    """Counters a serving run accumulates (read after ``stop()``).

    All request counters are in **samples**. Every offered sample ends
    in exactly one terminal bucket — the accounting identity
    ``completed + rejected + failed + expired == submitted`` (asserted
    by :meth:`assert_accounting` once the server has stopped):

    - ``completed``: served, future resolved with logits.
    - ``rejected``: shed at admission (:class:`Overloaded` under the
      ``reject`` policy) or failed validation (:class:`InvalidRequest`).
    - ``expired``: missed its deadline while queued
      (:class:`DeadlineExceeded`), failed before any dispatch.
    - ``failed``: a dispatch/output fault (poison request, plan
      exception, :class:`NumericalFault`, :class:`ServerCrashed`) or
      cancelled by a non-draining/timed-out ``stop()``.
    """

    submitted: int = 0
    completed: int = 0
    rejected: int = 0
    failed: int = 0
    expired: int = 0
    batches: int = 0
    served_samples: int = 0
    padded_samples: int = 0
    bucket_counts: dict = dataclasses.field(default_factory=dict)
    latencies_s: list = dataclasses.field(default_factory=list)
    first_arrival: Optional[float] = None
    last_done: Optional[float] = None
    warmup_traces: int = 0
    # --- §15 lifecycle counters. The accounting identity spans restarts:
    # a supervised restart keeps these books open (start(fresh_stats=
    # False)), so a sample submitted before a crash and requeued across
    # it is still offered once and lands in exactly one terminal bucket.
    restarts: int = 0     # supervised dispatcher restarts survived
    requeued: int = 0     # samples re-enqueued across a restart
    reloads: int = 0      # hot plan-set swaps (Supervisor.reload)
    demotions: int = 0    # buckets demoted to the ref fallback path
    promotions: int = 0   # buckets re-promoted by a recovery probe

    @property
    def accounted(self) -> int:
        return self.completed + self.rejected + self.failed + self.expired

    def accounting_ok(self) -> bool:
        """The identity every stopped run must satisfy: each offered
        sample landed in exactly one terminal counter."""
        return self.accounted == self.submitted

    def assert_accounting(self) -> None:
        assert self.accounting_ok(), (
            f"accounting identity violated: completed {self.completed} + "
            f"rejected {self.rejected} + failed {self.failed} + expired "
            f"{self.expired} = {self.accounted} != offered {self.submitted}")

    def summary(self) -> dict:
        """p50/p99 latency (µs) of completed requests, goodput
        (requests/s over first-arrival → last-completion), shed rate,
        terminal counters, aggregation shape."""
        lat_us = np.asarray(self.latencies_s, dtype=np.float64) * 1e6
        span = (
            (self.last_done - self.first_arrival)
            if self.completed and self.last_done is not None else 0.0
        )
        return {
            "offered": self.submitted,
            "completed": self.completed,
            "rejected": self.rejected,
            "failed": self.failed,
            "expired": self.expired,
            "accounting_ok": self.accounting_ok(),
            "batches": self.batches,
            "p50_us": round(float(np.percentile(lat_us, 50)), 1) if len(lat_us) else None,
            "p99_us": round(float(np.percentile(lat_us, 99)), 1) if len(lat_us) else None,
            "mean_us": round(float(lat_us.mean()), 1) if len(lat_us) else None,
            "throughput_rps": round(self.completed / span, 2) if span > 0 else None,
            "shed_rate": round(self.rejected / self.submitted, 4)
            if self.submitted else 0.0,
            "bucket_counts": {str(k): v for k, v in sorted(self.bucket_counts.items())},
            "padded_frac": round(self.padded_samples / self.served_samples, 4)
            if self.served_samples else 0.0,
            "restarts": self.restarts,
            "requeued": self.requeued,
            "reloads": self.reloads,
            "demotions": self.demotions,
            "promotions": self.promotions,
        }


# --------------------------------------------------------------- server
_STOP = object()


class CNNServer:
    """Continuous-batching front end over a frozen :class:`PlanSet`.

    >>> plan_set = model.plan_set(qparams, max_batch=8, tune="cache")
    >>> with CNNServer(plan_set, max_wait_ms=5.0, max_queue=64) as srv:
    ...     srv.warmup()                      # buckets from the plan spec
    ...     fut = srv.submit(x1, deadline_s=0.2)   # x1: (1, 32, 32, 3)
    ...     logits = fut.result(timeout=srv.request_timeout_s())
    >>> srv.stats.summary()["p99_us"], srv.retraces_after_warmup  # -> ..., 0

    ``mesh=`` turns on data-parallel dispatch: padded buckets are placed
    with the ``cnn_serve_rules`` batch-axis ``NamedSharding`` before the
    plan runs (``multi_pod=`` selects the ('pod','data') axes). Build
    the plan set with ``dp=mesh data size`` so every bucket shards
    evenly.

    Robustness knobs (DESIGN.md §14): ``max_queue`` bounds admitted
    in-system samples (None = unbounded), ``shed`` picks the overload
    policy (``'reject'`` raises :class:`Overloaded` with a measured
    retry-after; ``'block'`` backpressures the submitting thread),
    ``validate`` checks every request against the plan's sample spec at
    admission, ``check_outputs`` fails individual requests whose logits
    come back non-finite, and ``faults`` installs a deterministic
    injector (``repro.launch.faults``) for chaos testing.

    The dispatcher blocks each batch to completion before resolving its
    futures, so a request's measured latency (arrival → result ready)
    includes queueing, padding, dispatch, and device time — what a
    client would see. One batch is in flight at a time; jax's own async
    dispatch still overlaps host-side aggregation of the next batch with
    device compute of the current one.
    """

    def __init__(self, plan_set, *, max_batch: Optional[int] = None,
                 max_wait_ms: float = 5.0, mesh=None, multi_pod: bool = False,
                 max_queue: Optional[int] = None, shed: str = "reject",
                 validate: bool = True, check_outputs: bool = True,
                 faults=None, fallback=None, demote_after: int = 2,
                 probe_every: Optional[int] = 4, on_crash=None):
        if shed not in ("reject", "block"):
            raise ValueError(f"shed must be 'reject' or 'block', got {shed!r}")
        if max_queue is not None and max_queue < 1:
            raise ValueError(f"max_queue must be >= 1, got {max_queue}")
        if demote_after < 1:
            raise ValueError(f"demote_after must be >= 1, got {demote_after}")
        if probe_every is not None and probe_every < 2:
            raise ValueError(f"probe_every must be >= 2, got {probe_every}")
        self.plan_set = plan_set
        self.max_batch = int(max_batch or plan_set.buckets[-1])
        self.max_wait_s = float(max_wait_ms) / 1e3
        self.max_queue = max_queue
        self.shed = shed
        self.stats = ServerStats()
        self._validate = validate
        self._check_outputs = check_outputs
        self._faults = faults
        # §15 degradation: per-bucket ref-fallback closures (see
        # models.plan.fallback_closures), demotion threshold in
        # consecutive compiled-dispatch faults, and the recovery-probe
        # period (every Nth dispatch on a demoted bucket retries the
        # compiled path; None disables probing).
        self._fallback = dict(fallback) if fallback is not None else None
        self._demote_after = int(demote_after)
        self._probe_every = probe_every
        self._strikes: dict = {}     # bucket -> consecutive compiled faults
        self._demoted: dict = {}     # bucket -> {'reason', 'dispatches'}
        # §15 supervision: when set, a dispatcher crash hands its
        # admitted-but-undispatched requests to this callback
        # (on_crash(exc, pendings)) instead of failing them — the
        # Supervisor requeues them across the restart. Requests inside a
        # dispatch at crash time always fail typed (at-most-once).
        self.on_crash = on_crash
        self._inflight: dict = {}    # id(p) -> p, dispatcher thread only
        self._put = None
        if mesh is not None:
            from jax.sharding import NamedSharding

            from repro.sharding.rules import cnn_serve_rules, data_pspec

            spec = data_pspec(cnn_serve_rules(multi_pod=multi_pod))
            sharding = NamedSharding(mesh, spec)
            self._put = lambda xb: jax.device_put(xb, sharding)
        self._batcher = MicroBatcher(self.max_batch, self.max_wait_s)
        self._q: _queue.Queue = _queue.Queue()
        self._thread: Optional[threading.Thread] = None
        self._lock = threading.Lock()
        self._space = threading.Condition(self._lock)  # blocks shed='block'
        self._abandon = threading.Event()  # stop(timeout_s=) gave up draining
        self._closed = False
        self._crashed: Optional[BaseException] = None
        self._degraded = False          # last dispatch hit a fault
        self._depth = 0                 # admitted samples not yet resolved
        self._bucket_time_s: Optional[float] = None  # EMA of serve time
        self._ran = False

    # ------------------------------------------------------- lifecycle
    def start(self, *, fresh_stats: bool = True) -> "CNNServer":
        """Start the dispatcher. ``fresh_stats=False`` is the supervised
        restart path (DESIGN.md §15): the run's books stay open so the
        accounting identity spans the restart — a sample submitted before
        the crash and requeued across it is offered once and terminates
        once. The default resets the run (the §14 operator-restart
        contract: fresh books, re-baselined traces)."""
        if self._thread is not None:
            raise RuntimeError("server already started")
        if self._ran:
            # Restart after stop(): stale stats would double-count the
            # accounting identity and a stale warmup snapshot would
            # corrupt the zero-retrace contract — reset the run and
            # re-baseline traces at the plan set's current count (the
            # buckets stay compiled, so no re-warmup is required).
            keep: List[_Pending] = []
            while True:  # stale sentinels (e.g. stop() after a crash)
                try:
                    item = self._q.get_nowait()
                except _queue.Empty:
                    break
                if isinstance(item, _Pending):
                    # requeued across the restart (§15): the supervisor
                    # re-enqueues crash-stranded requests *before* the new
                    # dispatcher thread exists, so an immediate re-crash
                    # can never lose them mid-handoff.
                    keep.append(item)
            for p in keep:
                self._q.put(p)
            if fresh_stats:
                self.stats = ServerStats()
                self.stats.warmup_traces = self.plan_set.trace_count
            self._batcher = MicroBatcher(self.max_batch, self.max_wait_s)
            with self._lock:
                self._crashed = None
                self._degraded = False
                self._depth = sum(p.n for p in keep)
        self._ran = True
        self._abandon.clear()
        self._closed = False
        self._thread = threading.Thread(
            target=self._loop, name="cnn-serve-dispatch", daemon=True
        )
        self._thread.start()
        return self

    def stop(self, *, drain: bool = True, timeout_s: Optional[float] = None) -> None:
        """Stop the dispatcher; ``drain=True`` (default) serves whatever
        is still queued first, so every submitted future resolves.
        ``timeout_s`` bounds the drain: past it, remaining requests are
        cancelled (their waiters get ``CancelledError``, never a hang)."""
        if self._thread is None:
            return
        with self._lock:
            self._closed = True  # reject new submits racing the sentinel
            self._q.put((_STOP, drain))
            self._space.notify_all()  # wake blocked submitters to fail fast
        self._thread.join(timeout_s)
        if self._thread.is_alive():
            self._abandon.set()  # drain loop cancels the rest and exits
            self._thread.join()
        self._thread = None

    def __enter__(self) -> "CNNServer":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    # ------------------------------------------------------- hot path
    def warmup(self, sample_shape: Optional[Sequence[int]] = None,
               dtype=jnp.float32) -> int:
        """Compile every bucket (through the mesh sharding, when set),
        seed the measured service-time estimate with one timed
        largest-bucket dispatch, and snapshot the trace count — the
        baseline of the zero-retrace contract
        (:attr:`retraces_after_warmup`). ``sample_shape`` defaults to
        the plan set's own sample spec."""
        if sample_shape is None and self.plan_set.sample_spec is not None:
            sample_shape, dtype = self.plan_set.sample_spec
        self.plan_set.warmup(tuple(sample_shape), dtype, put=self._put)
        cap = self.plan_set.buckets[-1]
        xb = np.zeros((cap,) + tuple(sample_shape), dtype)
        t0 = time.monotonic()
        self.plan_set.serve(xb, put=self._put)  # warmed: no new trace
        self._note_service_time(time.monotonic() - t0)
        self.stats.warmup_traces = self.plan_set.trace_count
        return self.stats.warmup_traces

    @property
    def retraces_after_warmup(self) -> int:
        return self.plan_set.trace_count - self.stats.warmup_traces

    def submit(self, x, *, deadline_s: Optional[float] = None) -> Future:
        """Enqueue one request (``x``: ``(n, ...)`` with ``n ≥ 1``
        samples, numpy preferred — jax inputs are copied to host at
        dispatch); returns the future of its ``(n, num_classes)`` logits
        as numpy, already computed when the future resolves.

        ``deadline_s`` (relative seconds) bounds total time-in-system:
        a request still queued past it fails with
        :class:`DeadlineExceeded` before any dispatch. Raises
        :class:`InvalidRequest` on spec validation failure and
        :class:`Overloaded` when the bounded queue sheds (both typed,
        both counted against the accounting identity)."""
        if x.ndim < 2 or x.shape[0] < 1:
            raise InvalidRequest(
                f"request must be (n, ...) with n >= 1: {x.shape}")
        n = int(x.shape[0])
        now = time.monotonic()
        with self._lock:
            if self._crashed is not None:
                raise ServerCrashed(
                    f"server crashed: {self._crashed!r} (restart with start())")
            if self._thread is None or self._closed:
                raise RuntimeError(
                    "server is not running (use `with CNNServer(...)`)")
            self.stats.submitted += n  # offered, whatever happens next
            if self.stats.first_arrival is None:
                self.stats.first_arrival = now
        try:
            if deadline_s is not None and deadline_s <= 0:
                raise InvalidRequest(f"deadline_s must be > 0: {deadline_s}")
            if self._validate and self.plan_set.sample_spec is not None:
                validate_request(x, self.plan_set.sample_spec)
        except InvalidRequest:
            with self._lock:
                self.stats.rejected += n  # rejected alone — no co-batch harm
            raise
        fut: Future = Future()
        p = _Pending(x=x, n=n, arrival=now, future=fut,
                     deadline=None if deadline_s is None else now + deadline_s)
        with self._lock:
            if self.max_queue is not None and self._depth + n > self.max_queue:
                if self.shed == "reject":
                    self.stats.rejected += n
                    raise Overloaded(
                        f"queue full ({self._depth}/{self.max_queue} samples)",
                        retry_after_s=self._retry_after_locked())
                while (self._depth + n > self.max_queue
                       and not self._closed and self._crashed is None):
                    self._space.wait()
                if self._closed or self._crashed is not None:
                    self.stats.rejected += n
                    raise RuntimeError("server stopped while backpressured")
            self._depth += n
            self._q.put(p)  # inside the lock: nothing can trail a crash drain
        return fut

    def serve_batch(self, x):
        """Synchronous bucketed serve (no queue): pad → bucket plan →
        slice, through the mesh sharding when set. The dispatcher and
        direct callers (tests/bench baselines) share this one path,
        including the §15 per-bucket demotion routing."""
        return self.plan_set.serve(x, put=self._put, on_dispatch=self._record,
                                   dispatch=self._bucket_dispatch)

    def requeue(self, pendings: List[_Pending]) -> int:
        """Re-enqueue requests a crash handed back (``on_crash``) after a
        supervised restart — the §15 at-most-once path for requests that
        were admitted but never inside a dispatch. They are *not*
        re-counted as submitted (their offer already happened); the
        ``requeued`` counter keeps the cross-restart books exact. Returns
        the number of samples requeued.

        Callable on a running server *or* on a reaped one (after
        ``stop()``, before the restarting ``start()``) — the supervisor
        uses the latter so the requests sit in the queue before the new
        dispatcher thread exists, closing the window where an immediate
        re-crash could lose them mid-handoff."""
        total = 0
        with self._lock:
            if self._thread is not None and (self._closed
                                             or self._crashed is not None):
                raise RuntimeError(
                    "cannot requeue into a crashed/closing server "
                    "(reap the dispatcher with stop() first)")
            for p in pendings:
                self.stats.requeued += p.n
                self._depth += p.n
                total += p.n
                self._q.put(p)
        return total

    def fail_pending(self, pendings: List[_Pending], exc: Exception) -> None:
        """Terminal-fail requests a crash handed back — the Supervisor's
        path when the circuit breaker keeps the server down. Books stay
        exact (each sample lands in ``failed``)."""
        for p in pendings:
            self._fail(p, exc, kind="failed")

    def cancel_pending(self, pendings: List[_Pending]) -> None:
        """Cancel requests a crash handed back — the Supervisor's path
        when ``stop()`` lands during restart backoff. Waiters get
        ``CancelledError`` (typed, never a hang); books stay exact."""
        for p in pendings:
            self._cancel(p)

    def swap_plan_set(self, new_set, *, fallback=None) -> None:
        """Atomically replace the serving :class:`PlanSet` (the §15 hot
        reload). The dispatcher reads ``plan_set`` once per batch, so the
        swap lands *between* bucket dispatches: in-flight batches finish
        on the old plans (still alive, still compiled), every later batch
        dispatches the new ones — zero dropped or hung requests. The
        caller must pass an already-warmed set (``Supervisor.reload``
        warms off the dispatcher thread); the trace baseline re-anchors
        at the new set's count so the zero-retrace contract carries over.
        Demotion state and fallback closures are rebuilt per swap (they
        are pinned to the old weights)."""
        if tuple(new_set.buckets) != tuple(self.plan_set.buckets):
            raise ValueError(
                f"swap buckets {new_set.buckets} != serving ladder "
                f"{self.plan_set.buckets}")
        if (self.plan_set.sample_spec is not None
                and new_set.sample_spec != self.plan_set.sample_spec):
            raise ValueError(
                f"swap sample spec {new_set.sample_spec} != admission "
                f"contract {self.plan_set.sample_spec}")
        with self._lock:
            self.plan_set = new_set
            self.stats.warmup_traces = new_set.trace_count
            self.stats.reloads += 1
            self._fallback = dict(fallback) if fallback is not None else None
            self._strikes.clear()
            self._demoted.clear()

    # ------------------------------------------- §15 bucket degradation
    def _bucket_dispatch(self, b: int, xb):
        """Per-bucket dispatch with kernel-fallback demotion: a healthy
        bucket runs its compiled plan; ``demote_after`` consecutive
        compiled-dispatch faults demote the bucket to its ref fallback
        closure (requests keep completing — bit-compatible by
        construction); every ``probe_every``-th dispatch on a demoted
        bucket retries the compiled path and re-promotes on success."""
        with self._lock:
            dem = self._demoted.get(b)
            probe = False
            if dem is not None:
                dem["dispatches"] += 1
                probe = (self._probe_every is not None
                         and dem["dispatches"] % self._probe_every == 0)
        if dem is not None and not probe:
            return self._fallback[b](xb)
        try:
            if self._faults is not None:
                self._faults.pre_bucket(b)  # compiled-backend fault seam
            y = self.plan_set.plans[b].serve(xb)
        except Exception as e:  # noqa: BLE001 — strike, demote, or bubble
            if dem is not None:  # failed probe: stay demoted, keep serving
                return self._fallback[b](xb)
            if self._strike(b, e):
                return self._fallback[b](xb)  # demoted now: rescue the batch
            raise  # pre-demotion: bisect isolation handles the batch
        if dem is not None:
            self._promote(b)
        else:
            with self._lock:
                self._strikes.pop(b, None)  # a clean dispatch resets strikes
        return y

    def _strike(self, b: int, exc: Exception) -> bool:
        """One compiled-dispatch fault against bucket ``b``; demotes at
        the threshold when a fallback closure exists. True = demoted."""
        with self._lock:
            if b in self._demoted:
                return False
            k = self._strikes.get(b, 0) + 1
            self._strikes[b] = k
            if (self._fallback is not None and b in self._fallback
                    and k >= self._demote_after):
                self._demoted[b] = {
                    "reason": f"{type(exc).__name__}: {exc}",
                    "dispatches": 0,
                }
                self._strikes.pop(b, None)
                self.stats.demotions += 1
                return True
        return False

    def _promote(self, b: int) -> None:
        with self._lock:
            if self._demoted.pop(b, None) is not None:
                self._strikes.pop(b, None)
                self.stats.promotions += 1

    def demoted_buckets(self) -> dict:
        """``{bucket: reason}`` for buckets serving on the ref fallback."""
        with self._lock:
            return {b: d["reason"] for b, d in sorted(self._demoted.items())}

    # ---------------------------------------------------------- health
    def health(self) -> dict:
        """Liveness snapshot: ``status`` is ``'ready'`` (dispatching,
        last dispatch clean, queue below capacity), ``'degraded'``
        (running, but the last dispatch hit a fault, the queue is at
        capacity and shedding, or a bucket is demoted to its ref
        fallback — ``demoted`` carries ``{bucket: reason}``), or
        ``'stopped'`` (never started, stopped, or crashed — ``crashed``
        distinguishes)."""
        with self._lock:
            running = (self._thread is not None and not self._closed
                       and self._crashed is None)
            at_capacity = (self.max_queue is not None
                           and self._depth >= self.max_queue)
            demoted = {b: d["reason"] for b, d in sorted(self._demoted.items())}
            if not running:
                status = "stopped"
            elif self._degraded or at_capacity or demoted:
                status = "degraded"
            else:
                status = "ready"
            return {
                "status": status,
                "crashed": self._crashed is not None,
                "queue_depth": self._depth,
                "max_queue": self.max_queue,
                "service_estimate_s": self._bucket_time_s,
                "demoted": demoted,
            }

    def service_estimate_s(self) -> Optional[float]:
        """EMA of measured bucket dispatch time (seeded by warmup)."""
        with self._lock:
            return self._bucket_time_s

    def request_timeout_s(self, *, slack_buckets: float = 8.0,
                          floor_s: float = 5.0) -> float:
        """Client-side ``Future.result`` timeout derived from the
        server's own config instead of a hardcoded constant: worst-case
        backlog ahead (``max_queue`` when bounded, else the current
        depth) in buckets plus ``slack_buckets``, at the measured bucket
        time, plus the max-wait — floored so an unwarmed server still
        gets a sane value."""
        with self._lock:
            bt = self._bucket_time_s
            depth = self.max_queue if self.max_queue is not None else self._depth
        bt = bt if bt is not None else 1.0
        buckets = -(-max(depth, 0) // self.max_batch) + slack_buckets
        return max(floor_s, self.max_wait_s + buckets * bt)

    # ------------------------------------------------------- internals
    def _retry_after_locked(self) -> float:
        """Overload retry-after: backlog depth in buckets × measured
        bucket time (max-wait floor when nothing is measured yet)."""
        bt = self._bucket_time_s or self.max_wait_s
        buckets_ahead = max(1, -(-self._depth // self.max_batch))
        return self.max_wait_s + buckets_ahead * bt

    def _note_service_time(self, dt: float) -> None:
        with self._lock:
            bt = self._bucket_time_s
            self._bucket_time_s = dt if bt is None else 0.8 * bt + 0.2 * dt

    def _record(self, bucket: int, n_real: int) -> None:
        self.stats.batches += 1
        self.stats.served_samples += bucket
        self.stats.padded_samples += bucket - n_real
        self.stats.bucket_counts[bucket] = self.stats.bucket_counts.get(bucket, 0) + 1

    def _loop(self) -> None:
        try:
            self._loop_inner()
        except BaseException as e:  # noqa: BLE001 — supervised: fail futures
            self._crash(e)

    def _loop_inner(self) -> None:
        stop = None
        while stop is None:
            timeout = None
            est = self._bucket_time_s or 0.0
            dl = self._batcher.deadline(est)
            if dl is not None:
                timeout = max(0.0, dl - time.monotonic())
            try:
                items = [self._q.get(timeout=timeout)]
            except _queue.Empty:
                items = []  # max-wait expired with nothing new queued
            # Greedily drain whatever arrived while the last batch was in
            # flight: a backlog coalesces into full buckets here instead
            # of degenerating into max-wait-expired singles.
            while True:
                try:
                    items.append(self._q.get_nowait())
                except _queue.Empty:
                    break
            if self._faults is not None and items:
                try:
                    self._faults.on_tick(len(items))  # dispatcher-kill seam
                except BaseException:
                    for it in items:  # keep them failable by _crash
                        self._q.put(it)
                    raise
            for item in items:
                if isinstance(item, tuple) and item[0] is _STOP:
                    # submit() rejects after _closed, so nothing trails
                    # the sentinel — finish feeding what preceded it.
                    stop = item
                    continue
                for batch in self._batcher.add(item):
                    self._dispatch(batch)
            if stop is None and self._batcher.due(time.monotonic(), est):
                self._dispatch(self._batcher.take())
        remainder = self._batcher.take()
        if stop[1]:  # drain: serve what's left so every future resolves
            while remainder and not self._abandon.is_set():
                take, nn = [], 0
                while remainder and (not take
                                     or nn + remainder[0].n <= self.max_batch):
                    p = remainder.pop(0)
                    take.append(p)
                    nn += p.n
                self._dispatch(take)
        for p in remainder:  # non-drain or abandoned drain: cancel
            self._cancel(p)

    def _dispatch(self, batch: List[_Pending]) -> None:
        """Expire what already missed its deadline — *before* wasting a
        bucket dispatch — then run the survivors."""
        if self._abandon.is_set():  # stop(timeout_s=) gave up: cancel, fast
            for p in batch:
                self._cancel(p)
            return
        now = time.monotonic()
        live = []
        for p in batch:
            if p.deadline is not None and now >= p.deadline:
                self._fail(p, DeadlineExceeded(
                    f"deadline missed by {now - p.deadline:.4f}s after "
                    f"{now - p.arrival:.4f}s queued (never dispatched)"),
                    kind="expired")
            else:
                live.append(p)
        if live:
            # At-most-once bookkeeping (§15): everything past this line
            # is "inside a dispatch" — if the dispatcher dies before a
            # request reaches a terminal outcome, _crash fails it typed
            # instead of handing it to the supervisor for a requeue (a
            # re-execution could double side effects / double-serve).
            for p in live:
                self._inflight[id(p)] = p
            self._run(live)
            self._inflight.clear()

    def _run(self, batch: List[_Pending]) -> None:
        try:
            if self._faults is not None:
                self._faults.pre_dispatch(batch)  # plan-exception seam
            # Host-side assembly (numpy): concatenating/padding/slicing k
            # request arrays as jax ops would XLA-compile a fresh glue op
            # per (k, sizes) signature mid-traffic — a latency spike the
            # warmed bucket plans exist to avoid. As numpy it is a
            # memcpy, and serve_batch's host fast path keeps it that way
            # end to end (the only device work is the bucket dispatch).
            xs = [np.asarray(p.x) for p in batch]
            xb = xs[0] if len(xs) == 1 else np.concatenate(xs, axis=0)
            if self._faults is not None:
                xb = self._faults.pre_serve(batch, xb)  # slow/NaN seam
            t0 = time.monotonic()
            y = self.serve_batch(xb)  # numpy in -> numpy out, completed
            self._note_service_time(time.monotonic() - t0)
            if self._faults is not None:
                y = self._faults.post_serve(batch, y)  # NaN-activation seam
        except Exception as e:  # noqa: BLE001 — isolate, don't kill the loop
            if len(batch) == 1:
                self._fail(p=batch[0], exc=e, kind="failed")
                return
            # Blast-radius isolation: bisect. Each half pads up to an
            # already-warmed bucket, so innocent co-batched requests
            # complete bit-identically to a fault-free run (batch rows
            # are independent) with zero new traces, and recursion pins
            # the exception on exactly the poison request(s).
            mid = (len(batch) + 1) // 2
            self._run(batch[:mid])
            self._run(batch[mid:])
            return
        done = time.monotonic()
        off = 0
        clean = True
        for p in batch:
            yp = y[off : off + p.n]
            off += p.n
            if (self._check_outputs
                    and np.issubdtype(np.asarray(yp).dtype, np.floating)
                    and not np.isfinite(yp).all()):
                # fail only the offending request — its co-batch is fine
                self._fail(p, NumericalFault(
                    f"non-finite logits for request of {p.n} sample(s)"),
                    kind="failed")
                clean = False
            else:
                self._complete(p, yp, done)
        if clean:
            with self._lock:
                self._degraded = False  # a clean batch clears the flag

    # ----------------------------------------------- terminal outcomes
    def _complete(self, p: _Pending, y, done: float) -> None:
        self._inflight.pop(id(p), None)
        with self._lock:
            self.stats.latencies_s.append(done - p.arrival)
            self.stats.completed += p.n
            self.stats.last_done = done
            self._depth -= p.n
            self._space.notify_all()
        try:
            p.future.set_result(y)
        except Exception:  # cancelled by a racing stop(): already terminal
            pass

    def _fail(self, p: _Pending, exc: Exception, kind: str) -> None:
        self._inflight.pop(id(p), None)
        with self._lock:
            setattr(self.stats, kind, getattr(self.stats, kind) + p.n)
            if kind == "failed":
                self._degraded = True
            self._depth -= p.n
            self._space.notify_all()
        try:
            p.future.set_exception(exc)
        except Exception:
            pass

    def _cancel(self, p: _Pending) -> None:
        self._inflight.pop(id(p), None)
        with self._lock:
            self.stats.failed += p.n  # never served; the identity closes
            self._depth -= p.n
            self._space.notify_all()
        p.future.cancel()  # waiters get CancelledError, never a hang

    def _crash(self, exc: BaseException) -> None:
        """Supervision: the dispatcher died — fail every pending future
        with :class:`ServerCrashed` instead of stranding their waiters.
        ``submit`` raises the same from then on (until a restart).

        §15 split: requests *inside a dispatch* at crash time always fail
        typed here (at-most-once — a requeue could silently re-execute
        them), while admitted-but-undispatched requests are handed to the
        ``on_crash`` callback (the Supervisor requeues them across the
        restart) when one is installed, and failed typed otherwise."""
        with self._lock:
            self._crashed = exc
            self._closed = True
            self._space.notify_all()
        err = ServerCrashed(f"dispatcher crashed: {exc!r}")
        err.__cause__ = exc if isinstance(exc, Exception) else None
        inflight = list(self._inflight.values())
        self._inflight.clear()
        for p in inflight:  # at-most-once: never silently re-executed
            self._fail(p, err, kind="failed")
        stranded = self._batcher.take()
        while True:  # submit() enqueues under the lock: nothing can trail
            try:
                item = self._q.get_nowait()
            except _queue.Empty:
                break
            if isinstance(item, tuple) and item[0] is _STOP:
                continue
            stranded.append(item)
        cb = self.on_crash
        if cb is not None and stranded:
            # the undispatched stay pending: depth still counts them, and
            # the supervisor either requeues them (stats.requeued) or
            # fails them itself when the circuit breaker holds the server
            # down. A callback error must never strand a waiter.
            try:
                cb(exc, stranded)
                return
            except Exception:  # noqa: BLE001 — fall through to typed fail
                pass
        elif cb is not None:
            try:
                cb(exc, [])
                return
            except Exception:  # noqa: BLE001
                pass
        for p in stranded:
            self._fail(p, err, kind="failed")


def auto_rate(plan_set, sample_shape: Sequence[int], *, utilization: float = 0.5,
              dtype=jnp.float32, put=None, reps: int = 5) -> Tuple[float, float]:
    """Pick an offered load from measured capacity: times the largest
    bucket's plan (median of ``reps``) and returns ``(rate_rps,
    bucket_us)`` where ``rate_rps = utilization × bucket/bucket_time`` —
    so load runs are self-calibrating across hosts instead of hardcoding
    a requests/s that is idle on one machine and overload on another."""
    from repro.xla_utils import median_time_us

    cap = plan_set.buckets[-1]
    xb = jnp.zeros((cap,) + tuple(sample_shape), dtype)
    if put is not None:
        xb = put(xb)
    us = median_time_us(plan_set.plans[cap].serve, xb, warmup=1, reps=reps)
    return utilization * cap / (us / 1e6), us
