"""Continuous-batching CNN serving tier (DESIGN.md §11).

The pipeline is **queue → bucketer → (sharded) frozen-plan dispatch**:

- :class:`CNNServer` owns a thread-safe request queue. ``submit(x)``
  (``x``: ``(n, H, W, C)``, any ``n ≥ 1``) returns a
  ``concurrent.futures.Future`` that resolves to that request's logits.
- A dispatcher thread aggregates requests with :class:`MicroBatcher`:
  flush as soon as ``max_batch`` samples are pending, or when the oldest
  pending request has waited ``max_wait_ms`` — the classic
  latency/throughput knob pair of a continuous-batching server.
- Each aggregated batch is served through a
  :class:`~repro.models.plan.PlanSet`: pad up to the nearest batch-size
  bucket, dispatch that bucket's pre-compiled frozen plan, slice the
  padding off, and scatter the per-request slices back into the futures.
  Because every bucket was compiled at warmup, sustained variable load
  runs **zero retraces** — a contract the server *measures* (plans count
  their traces) rather than assumes, and bit-identical to serving every
  request alone (batch rows are independent end to end).
- With a device mesh (``mesh=``, e.g. ``launch.mesh.make_production_mesh``
  / ``make_test_mesh``), each padded bucket is placed with the batch-axis
  ``NamedSharding`` from ``sharding.rules.cnn_serve_rules`` +
  ``data_pspec`` before dispatch, so the plan's jit partitions the batch
  data-parallel across the 'data' (and 'pod') axes; every bucket is a
  multiple of the DP degree by construction (``make_buckets(dp=)``), so
  the padded batch always shards evenly and each device runs the same
  staged program on its shard.

The load-generator helpers (:func:`poisson_arrivals`,
:func:`burst_arrivals`) live here too so ``benchmarks/bench_serve.py``
and ``repro.launch.serve --server`` drive identical traffic shapes.
"""
from __future__ import annotations

import dataclasses
import queue as _queue
import threading
import time
from concurrent.futures import Future
from typing import Callable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np


# ------------------------------------------------------------- load gen
def poisson_arrivals(rate_rps: float, n: int, *, seed: int = 0) -> np.ndarray:
    """``n`` arrival offsets (seconds, ascending from ~0) of a Poisson
    process at ``rate_rps`` requests/s — the memoryless steady-traffic
    model; inter-arrival gaps are iid exponential."""
    if rate_rps <= 0:
        raise ValueError(f"rate_rps must be > 0, got {rate_rps}")
    rng = np.random.default_rng(seed)
    return np.cumsum(rng.exponential(1.0 / rate_rps, size=n))


def burst_arrivals(n: int, *, burst: int, gap_s: float,
                   start: float = 0.0) -> np.ndarray:
    """``n`` arrival offsets in back-to-back bursts of ``burst`` requests
    (all at the same instant) separated by ``gap_s`` seconds — the
    worst case for a batcher: idle, then a queue-depth spike."""
    if burst < 1:
        raise ValueError(f"burst must be >= 1, got {burst}")
    return np.asarray([start + (i // burst) * gap_s for i in range(n)])


# ------------------------------------------------------------ batching
@dataclasses.dataclass
class _Pending:
    """One queued request: its samples, arrival stamp, result future."""

    x: jax.Array
    n: int
    arrival: float
    future: Future


class MicroBatcher:
    """Pure aggregation logic (no threads, injectable clock — unit-testable).

    Accumulates pending requests until either ``max_batch`` samples are
    waiting (flush immediately) or the oldest has waited ``max_wait_s``
    (flush what's there). Requests are never split across batches: a
    request that would overflow the current batch flushes the batch
    first; a single request larger than ``max_batch`` becomes its own
    batch (``PlanSet.serve`` chunks it at the largest bucket).
    """

    def __init__(self, max_batch: int, max_wait_s: float):
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        if max_wait_s < 0:
            raise ValueError(f"max_wait_s must be >= 0, got {max_wait_s}")
        self.max_batch = max_batch
        self.max_wait_s = max_wait_s
        self._pending: List[_Pending] = []
        self._count = 0

    def __len__(self) -> int:
        return self._count

    def add(self, p: _Pending) -> List[List[_Pending]]:
        """Queue one request; return the batches (0, 1 or 2) it flushed."""
        out = []
        if self._pending and self._count + p.n > self.max_batch:
            out.append(self.take())
        self._pending.append(p)
        self._count += p.n
        if self._count >= self.max_batch:
            out.append(self.take())
        return out

    def deadline(self) -> Optional[float]:
        """Absolute time the oldest pending request must flush by."""
        if not self._pending:
            return None
        return self._pending[0].arrival + self.max_wait_s

    def due(self, now: float) -> bool:
        dl = self.deadline()
        return dl is not None and now >= dl

    def take(self) -> List[_Pending]:
        """Flush everything pending (the dispatcher's max-wait path)."""
        batch, self._pending, self._count = self._pending, [], 0
        return batch


# --------------------------------------------------------------- stats
@dataclasses.dataclass
class ServerStats:
    """Counters a serving run accumulates (read after ``stop()``)."""

    submitted: int = 0
    completed: int = 0
    batches: int = 0
    served_samples: int = 0
    padded_samples: int = 0
    bucket_counts: dict = dataclasses.field(default_factory=dict)
    latencies_s: list = dataclasses.field(default_factory=list)
    first_arrival: Optional[float] = None
    last_done: Optional[float] = None
    warmup_traces: int = 0

    def summary(self) -> dict:
        """p50/p99 latency (µs), sustained throughput (requests/s over
        first-arrival → last-completion), aggregation shape."""
        lat_us = np.asarray(self.latencies_s, dtype=np.float64) * 1e6
        span = (
            (self.last_done - self.first_arrival)
            if self.completed and self.last_done is not None else 0.0
        )
        return {
            "offered": self.submitted,
            "completed": self.completed,
            "batches": self.batches,
            "p50_us": round(float(np.percentile(lat_us, 50)), 1) if len(lat_us) else None,
            "p99_us": round(float(np.percentile(lat_us, 99)), 1) if len(lat_us) else None,
            "mean_us": round(float(lat_us.mean()), 1) if len(lat_us) else None,
            "throughput_rps": round(self.completed / span, 2) if span > 0 else None,
            "bucket_counts": {str(k): v for k, v in sorted(self.bucket_counts.items())},
            "padded_frac": round(self.padded_samples / self.served_samples, 4)
            if self.served_samples else 0.0,
        }


# --------------------------------------------------------------- server
_STOP = object()


class CNNServer:
    """Continuous-batching front end over a frozen :class:`PlanSet`.

    >>> plan_set = model.plan_set(qparams, max_batch=8, tune="cache")
    >>> with CNNServer(plan_set, max_wait_ms=5.0) as srv:
    ...     srv.warmup((32, 32, 3))
    ...     fut = srv.submit(x1)          # x1: (1, 32, 32, 3)
    ...     logits = fut.result()
    >>> srv.stats.summary()["p99_us"], srv.retraces_after_warmup  # -> ..., 0

    ``mesh=`` turns on data-parallel dispatch: padded buckets are placed
    with the ``cnn_serve_rules`` batch-axis ``NamedSharding`` before the
    plan runs (``multi_pod=`` selects the ('pod','data') axes). Build
    the plan set with ``dp=mesh data size`` so every bucket shards
    evenly.

    The dispatcher blocks each batch to completion before resolving its
    futures, so a request's measured latency (arrival → result ready)
    includes queueing, padding, dispatch, and device time — what a
    client would see. One batch is in flight at a time; jax's own async
    dispatch still overlaps host-side aggregation of the next batch with
    device compute of the current one.
    """

    def __init__(self, plan_set, *, max_batch: Optional[int] = None,
                 max_wait_ms: float = 5.0, mesh=None, multi_pod: bool = False):
        self.plan_set = plan_set
        self.max_batch = int(max_batch or plan_set.buckets[-1])
        self.max_wait_s = float(max_wait_ms) / 1e3
        self.stats = ServerStats()
        self._put = None
        if mesh is not None:
            from jax.sharding import NamedSharding

            from repro.sharding.rules import cnn_serve_rules, data_pspec

            spec = data_pspec(cnn_serve_rules(multi_pod=multi_pod))
            sharding = NamedSharding(mesh, spec)
            self._put = lambda xb: jax.device_put(xb, sharding)
        self._batcher = MicroBatcher(self.max_batch, self.max_wait_s)
        self._q: _queue.Queue = _queue.Queue()
        self._thread: Optional[threading.Thread] = None
        self._lock = threading.Lock()
        self._closed = False

    # ------------------------------------------------------- lifecycle
    def start(self) -> "CNNServer":
        if self._thread is not None:
            raise RuntimeError("server already started")
        self._closed = False
        self._thread = threading.Thread(
            target=self._loop, name="cnn-serve-dispatch", daemon=True
        )
        self._thread.start()
        return self

    def stop(self, *, drain: bool = True) -> None:
        """Stop the dispatcher; ``drain=True`` (default) serves whatever
        is still queued first, so every submitted future resolves."""
        if self._thread is None:
            return
        with self._lock:
            self._closed = True  # reject new submits racing the sentinel
        self._q.put((_STOP, drain))
        self._thread.join()
        self._thread = None

    def __enter__(self) -> "CNNServer":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    # ------------------------------------------------------- hot path
    def warmup(self, sample_shape: Sequence[int], dtype=jnp.float32) -> int:
        """Compile every bucket (through the mesh sharding, when set) and
        snapshot the trace count — the baseline of the zero-retrace
        contract (:attr:`retraces_after_warmup`)."""
        self.plan_set.warmup(tuple(sample_shape), dtype, put=self._put)
        self.stats.warmup_traces = self.plan_set.trace_count
        return self.stats.warmup_traces

    @property
    def retraces_after_warmup(self) -> int:
        return self.plan_set.trace_count - self.stats.warmup_traces

    def submit(self, x) -> Future:
        """Enqueue one request (``x``: ``(n, ...)`` with ``n ≥ 1``
        samples, numpy preferred — jax inputs are copied to host at
        dispatch); returns the future of its ``(n, num_classes)`` logits
        as numpy, already computed when the future resolves."""
        if x.ndim < 2 or x.shape[0] < 1:
            raise ValueError(f"request must be (n, ...) with n >= 1: {x.shape}")
        fut: Future = Future()
        p = _Pending(x=x, n=int(x.shape[0]), arrival=time.monotonic(), future=fut)
        with self._lock:
            if self._thread is None or self._closed:
                raise RuntimeError("server is not running (use `with CNNServer(...)`)")
            self.stats.submitted += p.n
            if self.stats.first_arrival is None:
                self.stats.first_arrival = p.arrival
        self._q.put(p)
        return fut

    def serve_batch(self, x):
        """Synchronous bucketed serve (no queue): pad → bucket plan →
        slice, through the mesh sharding when set. The dispatcher and
        direct callers (tests/bench baselines) share this one path."""
        return self.plan_set.serve(x, put=self._put, on_dispatch=self._record)

    # ------------------------------------------------------- internals
    def _record(self, bucket: int, n_real: int) -> None:
        self.stats.batches += 1
        self.stats.served_samples += bucket
        self.stats.padded_samples += bucket - n_real
        self.stats.bucket_counts[bucket] = self.stats.bucket_counts.get(bucket, 0) + 1

    def _loop(self) -> None:
        stop = None
        while stop is None:
            timeout = None
            dl = self._batcher.deadline()
            if dl is not None:
                timeout = max(0.0, dl - time.monotonic())
            try:
                items = [self._q.get(timeout=timeout)]
            except _queue.Empty:
                items = []  # max-wait expired with nothing new queued
            # Greedily drain whatever arrived while the last batch was in
            # flight: a backlog coalesces into full buckets here instead
            # of degenerating into max-wait-expired singles.
            while True:
                try:
                    items.append(self._q.get_nowait())
                except _queue.Empty:
                    break
            for item in items:
                if isinstance(item, tuple) and item[0] is _STOP:
                    # submit() rejects after _closed, so nothing trails
                    # the sentinel — finish feeding what preceded it.
                    stop = item
                    continue
                for batch in self._batcher.add(item):
                    self._dispatch(batch)
            if stop is None and self._batcher.due(time.monotonic()):
                self._dispatch(self._batcher.take())
        remainder = self._batcher.take()
        if stop[1]:  # drain: serve what's left so every future resolves
            if remainder:
                self._dispatch(remainder)
        else:
            for p in remainder:
                p.future.cancel()

    def _dispatch(self, batch: List[_Pending]) -> None:
        try:
            # Host-side assembly (numpy): concatenating/padding/slicing k
            # request arrays as jax ops would XLA-compile a fresh glue op
            # per (k, sizes) signature mid-traffic — a latency spike the
            # warmed bucket plans exist to avoid. As numpy it is a
            # memcpy, and serve_batch's host fast path keeps it that way
            # end to end (the only device work is the bucket dispatch).
            xs = [np.asarray(p.x) for p in batch]
            xb = xs[0] if len(xs) == 1 else np.concatenate(xs, axis=0)
            y = self.serve_batch(xb)  # numpy in -> numpy out, completed
        except Exception as e:  # noqa: BLE001 — fail the requests, not the loop
            for p in batch:
                p.future.set_exception(e)
            return
        done = time.monotonic()
        off = 0
        for p in batch:
            p.future.set_result(y[off : off + p.n])
            off += p.n
            self.stats.latencies_s.append(done - p.arrival)
            self.stats.completed += p.n
        self.stats.last_done = done


def auto_rate(plan_set, sample_shape: Sequence[int], *, utilization: float = 0.5,
              dtype=jnp.float32, put=None, reps: int = 5) -> Tuple[float, float]:
    """Pick an offered load from measured capacity: times the largest
    bucket's plan (median of ``reps``) and returns ``(rate_rps,
    bucket_us)`` where ``rate_rps = utilization × bucket/bucket_time`` —
    so load runs are self-calibrating across hosts instead of hardcoding
    a requests/s that is idle on one machine and overload on another."""
    from repro.xla_utils import median_time_us

    cap = plan_set.buckets[-1]
    xb = jnp.zeros((cap,) + tuple(sample_shape), dtype)
    if put is not None:
        xb = put(xb)
    us = median_time_us(plan_set.plans[cap].serve, xb, warmup=1, reps=reps)
    return utilization * cap / (us / 1e6), us
