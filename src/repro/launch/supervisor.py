"""Self-healing serving lifecycle: the §15 supervision layer.

:class:`Supervisor` owns a :class:`~repro.launch.server.CNNServer` and
keeps it serving through the failures the §14 request layer cannot
absorb — the dispatcher process itself dying, the weights on disk going
bad, a compiled kernel path breaking:

- **Supervised restart.** A dispatcher crash hands its
  admitted-but-undispatched requests back through the server's
  ``on_crash`` seam; the supervisor restarts the dispatcher after a
  bounded exponential backoff with deterministic jitter and *requeues*
  them — their futures resolve after the restart as if nothing happened.
  Requests that were inside a dispatch at crash time fail typed
  (``ServerCrashed``): at-most-once, never silently re-executed. The
  restarted server keeps the same books (``start(fresh_stats=False)``),
  so ``completed+rejected+failed+expired == offered`` holds across every
  restart, with ``restarts``/``requeued`` counting the journey.
- **Crash-loop circuit breaker.** More than ``max_restarts`` crashes
  inside ``window_s`` opens the breaker: the server stays down,
  ``health()`` reports ``'failed'`` with the reason, and the requests
  from the final crash fail typed instead of looping forever.
- **Hot reload** (:meth:`reload`). Restore a checkpoint through the §15
  integrity verification (``CorruptCheckpointError`` on any damage —
  the old plan keeps serving), rebuild quantize→plan *off* the
  dispatcher thread (reusing the tune cache and the serving
  ``sample_spec`` contract), warm the new buckets, then swap the
  ``PlanSet`` atomically between bucket dispatches — zero dropped or
  hung requests. A ``StalePlanError`` after a weight refresh is thereby
  a recoverable event: rebuild through ``reload`` instead of dying.
- **Degradation** rides the server's per-bucket kernel fallback
  (``fallback=`` / ``demote_after`` / ``probe_every``); the supervisor
  surfaces demoted buckets in :meth:`health` and rebuilds the fallback
  closures on reload via ``fallback_builder``.

The clock and RNG are injectable so the backoff/breaker logic is
unit-testable without real sleeps (the §14 ``MicroBatcher`` style); the
blocking waits go through ``threading.Event`` so :meth:`stop` — which is
idempotent — interrupts a backoff immediately instead of hanging, and
cancels any crash-stranded futures typed.
"""
from __future__ import annotations

import random
import threading
import time
from typing import Callable, List, Optional

from repro.launch.server import CNNServer, ServerCrashed


class Supervisor:
    """Restart/reload/degradation lifecycle around one ``CNNServer``.

    >>> srv = CNNServer(plan_set, max_wait_ms=5.0)
    >>> sup = Supervisor(srv, rebuild=lambda tree: model.plan_set(tree,
    ...                  max_batch=8, tune="cache"), template=qparams)
    >>> with sup:
    ...     sup.warmup()
    ...     fut = sup.submit(x)            # delegates to the server
    ...     sup.reload(ckpt_dir)           # hot swap, zero dropped
    >>> sup.health()["status"], sup.stats.restarts

    Parameters
    ----------
    server:
        The ``CNNServer`` to own. Its ``on_crash`` seam is claimed.
    max_restarts / window_s:
        Circuit breaker: more than ``max_restarts`` crashes within a
        sliding ``window_s`` → stay down, ``health() == 'failed'``.
    backoff_s / backoff_max_s / jitter:
        Restart delay: ``min(backoff_max_s, backoff_s * 2**(n-1))``
        stretched by up to ``jitter`` fraction of seeded randomness —
        bounded, and deterministic for a given seed.
    rebuild:
        ``params_tree -> PlanSet`` for :meth:`reload` (quantize→plan;
        reuse the tune cache inside the closure so reloads never
        re-search).
    template:
        A params pytree with the checkpoint's structure (what
        ``checkpoint.store.restore`` restores into).
    fallback_builder:
        Optional ``PlanSet -> {bucket: serve}`` rebuilding the §15
        degradation closures for freshly reloaded weights.
    """

    def __init__(self, server: CNNServer, *, max_restarts: int = 5,
                 window_s: float = 30.0, backoff_s: float = 0.05,
                 backoff_max_s: float = 2.0, jitter: float = 0.25,
                 rebuild: Optional[Callable] = None, template=None,
                 fallback_builder: Optional[Callable] = None,
                 seed: int = 0, clock: Callable[[], float] = time.monotonic):
        if max_restarts < 1:
            raise ValueError(f"max_restarts must be >= 1, got {max_restarts}")
        if backoff_s < 0 or backoff_max_s < backoff_s:
            raise ValueError(
                f"need 0 <= backoff_s <= backoff_max_s, got "
                f"{backoff_s}/{backoff_max_s}")
        self._srv = server
        server.on_crash = self._on_crash
        self.max_restarts = max_restarts
        self.window_s = float(window_s)
        self.backoff_s = float(backoff_s)
        self.backoff_max_s = float(backoff_max_s)
        self.jitter = float(jitter)
        self._rng = random.Random(seed)
        self._clock = clock
        self._rebuild = rebuild
        self._template = template
        self._fallback_builder = fallback_builder
        self.reload_failures = 0
        self._lock = threading.Lock()
        self._crash_evt = threading.Event()  # a crash awaits the monitor
        self._wake = threading.Event()       # stop() interrupts backoff
        self._pending: Optional[tuple] = None  # (exc, stranded pendings)
        self._crash_times: List[float] = []
        self._restarting = False
        self._failed_reason: Optional[str] = None
        self._stopped = False
        self._monitor: Optional[threading.Thread] = None

    # ------------------------------------------------------- lifecycle
    def start(self) -> "Supervisor":
        if self._monitor is not None:
            raise RuntimeError("supervisor already started")
        self._stopped = False
        self._failed_reason = None
        self._wake.clear()
        self._crash_evt.clear()
        self._srv.start()  # fresh books for the supervised run
        self._monitor = threading.Thread(
            target=self._monitor_loop, name="cnn-serve-supervisor",
            daemon=True)
        self._monitor.start()
        return self

    def stop(self, *, drain: bool = True,
             timeout_s: Optional[float] = None) -> None:
        """Idempotent shutdown: interrupts any restart backoff (no hang),
        cancels crash-stranded futures typed (``CancelledError``), then
        stops the server (draining by default)."""
        with self._lock:
            self._stopped = True
        self._wake.set()
        self._crash_evt.set()  # unblock an idle monitor
        mon, self._monitor = self._monitor, None
        if mon is not None:
            mon.join()
        with self._lock:
            pending, self._pending = self._pending, None
        if pending is not None:  # crash arrived but monitor never took it
            self._srv.cancel_pending(pending[1])
        self._srv.stop(drain=drain, timeout_s=timeout_s)

    def __enter__(self) -> "Supervisor":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    # --------------------------------------------------- restart logic
    def _on_crash(self, exc: BaseException, stranded: list) -> None:
        """Server seam (runs on the dying dispatcher thread): park the
        crash + its undispatched requests for the monitor and return
        immediately."""
        with self._lock:
            self._pending = (exc, list(stranded))
            self._restarting = True
        self._crash_evt.set()

    def _next_backoff(self, attempt: int) -> float:
        """Bounded exponential backoff with deterministic jitter:
        ``min(backoff_max_s, backoff_s * 2**(attempt-1))`` stretched by
        up to ``jitter`` fraction. ``attempt`` is 1-based."""
        base = min(self.backoff_max_s, self.backoff_s * 2 ** max(attempt - 1, 0))
        return base * (1.0 + self.jitter * self._rng.random())

    def _breaker_open(self, now: float) -> bool:
        """Crash-loop circuit breaker: True when the crash just recorded
        is the ``max_restarts + 1``-th inside the sliding window."""
        self._crash_times = [t for t in self._crash_times
                             if now - t <= self.window_s]
        return len(self._crash_times) > self.max_restarts

    def _monitor_loop(self) -> None:
        while True:
            self._crash_evt.wait()
            with self._lock:
                if self._stopped:
                    return
                self._crash_evt.clear()
                taken, self._pending = self._pending, None
            if taken is None:
                continue
            exc, stranded = taken
            now = self._clock()
            self._crash_times.append(now)
            if self._breaker_open(now):
                reason = (f"crash loop: {len(self._crash_times)} crashes "
                          f"within {self.window_s}s (last: {exc!r}) — "
                          "circuit breaker open, staying down")
                err = ServerCrashed(reason)
                err.__cause__ = exc if isinstance(exc, Exception) else None
                with self._lock:
                    self._failed_reason = reason
                    self._restarting = False
                self._srv.fail_pending(stranded, err)
                continue  # stay alive for stop(); server stays down
            delay = self._next_backoff(len(self._crash_times))
            if self._wake.wait(delay):  # stop() landed during backoff
                self._srv.cancel_pending(stranded)
                return
            try:
                self._srv.stop(drain=False)  # reap the dead dispatcher thread
                if stranded:
                    # requeue BEFORE the new dispatcher thread exists: an
                    # immediate re-crash then re-strands them through
                    # on_crash instead of losing them mid-handoff
                    self._srv.requeue(stranded)
                self._srv.start(fresh_stats=False)
                with self._lock:
                    self._srv.stats.restarts += 1
                    self._restarting = False
                faults = getattr(self._srv, "_faults", None)
                if faults is not None and hasattr(faults, "on_restart"):
                    faults.on_restart(self._srv.stats.restarts)
            except Exception as e:  # noqa: BLE001 — restart itself failed
                reason = f"restart failed: {e!r}"
                err = ServerCrashed(reason)
                err.__cause__ = e
                with self._lock:
                    self._failed_reason = reason
                    self._restarting = False
                self._srv.fail_pending(stranded, err)

    # ------------------------------------------------------ hot reload
    def reload(self, ckpt_dir, *, step: Optional[int] = None,
               fallback: bool = False):
        """Verified checkpoint restore → rebuild → warm → atomic swap.

        Everything up to the swap runs on the *caller's* thread: the
        dispatcher keeps serving the old plan throughout, and any
        failure — :class:`~repro.checkpoint.store.CorruptCheckpointError`
        from verification, a rebuild/warmup error, a sample-spec
        mismatch — leaves the old plan serving (the swap never happens)
        and re-raises typed. ``fallback=True`` walks back to the newest
        verifiable checkpoint step. Returns ``(step, fingerprint)`` of
        what is now serving."""
        if self._rebuild is None or self._template is None:
            raise RuntimeError(
                "reload needs Supervisor(rebuild=..., template=...)")
        from repro.checkpoint.store import restore

        old = self._srv.plan_set
        try:
            tree, manifest = restore(ckpt_dir, self._template, step=step,
                                     fallback=fallback)
            new_set = self._rebuild(tree)
            if (old.sample_spec is not None
                    and new_set.sample_spec != old.sample_spec):
                raise ValueError(
                    f"reloaded plan sample spec {new_set.sample_spec} != "
                    f"serving admission contract {old.sample_spec}")
            # warm every bucket off the dispatcher thread so the swap
            # lands pre-compiled (zero mid-traffic traces)
            new_set.warmup(put=getattr(self._srv, "_put", None))
            fb = (self._fallback_builder(new_set)
                  if self._fallback_builder is not None else None)
            self._srv.swap_plan_set(new_set, fallback=fb)
        except Exception:
            with self._lock:
                self.reload_failures += 1
            raise  # old plan still serving — reload is all-or-nothing
        return manifest["step"], new_set.fingerprint

    # ------------------------------------------------------ delegation
    @property
    def server(self) -> CNNServer:
        return self._srv

    @property
    def stats(self):
        """The supervised run's books — one ``ServerStats`` spanning
        every restart (``assert_accounting`` stays exact)."""
        return self._srv.stats

    @property
    def restarts(self) -> int:
        return self._srv.stats.restarts

    @property
    def retraces_after_warmup(self) -> int:
        return self._srv.retraces_after_warmup

    def submit(self, x, **kw):
        return self._srv.submit(x, **kw)

    def warmup(self, *a, **kw):
        return self._srv.warmup(*a, **kw)

    def request_timeout_s(self, **kw) -> float:
        return self._srv.request_timeout_s(**kw)

    def health(self) -> dict:
        """The server's §14 snapshot extended with the §15 lifecycle:
        ``'restarting'`` while a crash is between backoff and restart,
        ``'failed'`` (+ ``reason``) once the circuit breaker opens, plus
        the ``restarts``/``requeued`` counters and demoted buckets."""
        base = self._srv.health()
        with self._lock:
            failed = self._failed_reason
            restarting = self._restarting
            stopped = self._stopped
        if failed is not None:
            base["status"] = "failed"
            base["reason"] = failed
        elif restarting:
            base["status"] = "restarting"
        elif stopped and self._monitor is None:
            base["status"] = "stopped"
        base["restarts"] = self._srv.stats.restarts
        base["requeued"] = self._srv.stats.requeued
        base["reloads"] = self._srv.stats.reloads
        base["reload_failures"] = self.reload_failures
        return base
