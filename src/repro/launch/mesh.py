"""Production mesh factory.

A function (not module-level constant) so importing never touches jax
device state. Single pod: 16x16 = 256 chips (data, model). Multi-pod:
2 x 16 x 16 = 512 chips with a leading 'pod' axis (pure DP across the
slower inter-pod links — DCN-friendly).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh for CPU integration tests (requires host-device override)."""
    return jax.make_mesh(shape, axes)


def tp_degree(mesh) -> int:
    return mesh.shape["model"]
