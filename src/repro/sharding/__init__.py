from repro.sharding.rules import attn_mode, data_pspec, make_rules  # noqa: F401
