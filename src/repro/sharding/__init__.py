from repro.sharding.rules import attn_mode, cnn_serve_rules, data_pspec, \
    make_rules  # noqa: F401
