"""Logical-axis -> mesh-axis rules, derived per architecture.

Axes (see models/common.py):
  batch     -> DP over ('pod','data') / ('data',)
  seq       -> 'model' (sequence-parallel residual stream; bounds remat-saved
               activations at scale)
  act_seq   -> block-internal sequence: 'model' only in context-parallel
               attention mode (neither kv nor q heads divisible by TP)
  heads/kv  -> 'model' when divisible by TP
  mlp/experts/vocab -> 'model'
  cache_seq -> decode KV-cache seq: 'model' when heads can't shard

Selection (recorded per arch in EXPERIMENTS.md SDry-run):
  kv_heads %% tp == 0  -> classic head-sharded TP (kv+q heads on 'model')
  num_heads %% tp == 0 -> q-head-sharded TP, KV replicated across 'model'
  otherwise            -> context parallelism (shard q sequence)
"""
from __future__ import annotations

from typing import Optional

from repro.models.config import ModelConfig


def attn_mode(cfg: ModelConfig, tp: int) -> str:
    if cfg.mixer == "rwkv6":
        return "feature"  # projections TP'd as features; WKV data-parallel
    if cfg.mixer == "mla":
        return "kv_sharded" if cfg.num_heads % tp == 0 else "context"
    if cfg.num_kv_heads % tp == 0:
        return "kv_sharded"
    if cfg.num_heads % tp == 0:
        return "q_sharded"
    return "context"


def make_rules(
    cfg: ModelConfig,
    *,
    tp: int = 16,
    multi_pod: bool = False,
    mode: str = "train",  # train | prefill | decode
) -> dict:
    dp = ("pod", "data") if multi_pod else ("data",)
    am = attn_mode(cfg, tp)
    rules = {
        "batch": dp,
        "embed": None,
        # ZeRO-3/FSDP: weight feature dims shard over 'data' during training
        # (params+optimizer fully sharded: TP x FSDP); serving keeps weights
        # replicated across 'data' for per-step latency.
        "w_embed": "data" if mode == "train" else None,
        "layers": None,
        "vocab": "model",
        "mlp": "model",
        "experts": "model",
        "seq": "model" if mode != "decode" else None,
        "act_seq": None,
        "heads": None,
        "kv": None,
        "cache_seq": None,
    }
    if am in ("kv_sharded", "feature"):
        rules["heads"] = "model"
        rules["kv"] = "model" if am == "kv_sharded" else None
    elif am == "q_sharded":
        rules["heads"] = "model"
    else:  # context parallel
        rules["act_seq"] = "model" if mode != "decode" else None
    if mode == "decode":
        # cache layout: shard kv heads when possible, else the cache seq dim
        if am in ("kv_sharded",) and cfg.mixer != "mla":
            rules["cache_seq"] = None
        elif cfg.mixer == "mla":
            rules["cache_seq"] = None  # latent cache is head-free; replicate
        elif am == "q_sharded":
            rules["cache_seq"] = None  # KV replicated (few kv heads, cheap)
        else:
            rules["cache_seq"] = "model"
        # MoE decode: tiny token count; keep experts sharded
    return rules


def cnn_serve_rules(*, multi_pod: bool = False) -> dict:
    """Batch-only rules for the CNN serving tier (DESIGN.md §11): the
    aggregated batch data-parallels over ('data',) — or ('pod','data')
    across pods — while weights stay replicated per device, because
    inside a frozen plan they are trace-time constants each device's
    staged executable already carries."""
    return {"batch": ("pod", "data") if multi_pod else ("data",)}


def data_pspec(rules):
    from jax.sharding import PartitionSpec as P

    return P(rules["batch"])
