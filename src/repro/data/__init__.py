from repro.data.pipeline import DataConfig, Prefetcher, SyntheticTokens  # noqa: F401
