"""Deterministic synthetic token pipeline — per-host sharded, resumable.

Production posture: the pipeline is a pure function of (seed, step, host
slice), so restart/elastic-reshard reproduce the exact stream with no
state files; the checkpoint only stores the step counter. A background
prefetch thread keeps ``batches_ahead`` batches ready (straggler hiding).

The synthetic stream is a mixture of Zipf-distributed tokens with shifted
copies, giving next-token structure a model can actually learn (used by
the convergence tests and examples).
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Iterator, Optional

import jax
import numpy as np

from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seq_len: int = 256
    global_batch: int = 8
    seed: int = 1234
    zipf_a: float = 1.3
    copy_period: int = 7  # t ~ t-copy_period correlation -> learnable
    batches_ahead: int = 2
    host_index: int = 0
    host_count: int = 1


class SyntheticTokens:
    """Stateless-by-construction data source: batch(step) is pure."""

    def __init__(self, cfg: ModelConfig, dcfg: DataConfig):
        self.cfg = cfg
        self.dcfg = dcfg
        assert dcfg.global_batch % dcfg.host_count == 0
        self.local_batch = dcfg.global_batch // dcfg.host_count
        self.vocab = cfg.codebook_vocab if cfg.frontend == "audio" else cfg.vocab_size

    def batch(self, step: int) -> dict:
        d = self.dcfg
        rng = np.random.default_rng(
            np.random.SeedSequence([d.seed, step, d.host_index])
        )
        b, s = self.local_batch, d.seq_len
        shape = (b, s + 1, self.cfg.num_codebooks) if self.cfg.frontend == "audio" else (b, s + 1)
        z = rng.zipf(d.zipf_a, size=shape)
        toks = np.minimum(z, self.vocab - 1).astype(np.int32)
        # plant copy structure: token[t] = token[t-p] on even phases
        p = d.copy_period
        toks[:, p::p] = toks[:, : toks.shape[1] - p : p]
        out = {
            "tokens": toks[:, :-1],
            "labels": toks[:, 1:],
            "loss_mask": np.ones((b, s), np.float32),
        }
        if self.cfg.frontend == "vision":
            out["vision_embeds"] = (
                0.02 * rng.standard_normal((b, self.cfg.num_vision_tokens, self.cfg.d_model))
            ).astype(np.float32)
        if self.cfg.cross_attn:
            out["memory"] = (
                0.02 * rng.standard_normal((b, self.cfg.cross_len, self.cfg.d_model))
            ).astype(np.float32)
        return out

    def iterate(self, start_step: int = 0) -> Iterator[dict]:
        step = start_step
        while True:
            yield self.batch(step)
            step += 1


class Prefetcher:
    """Background-thread prefetch of the (CPU-bound) batch synthesis."""

    def __init__(self, source: SyntheticTokens, start_step: int = 0, depth: Optional[int] = None):
        self.source = source
        self.q: queue.Queue = queue.Queue(maxsize=depth or source.dcfg.batches_ahead)
        self._stop = threading.Event()
        self._step = start_step
        self._thread = threading.Thread(target=self._work, daemon=True)
        self._thread.start()

    def _work(self):
        step = self._step
        while not self._stop.is_set():
            try:
                self.q.put((step, self.source.batch(step)), timeout=0.2)
                step += 1
            except queue.Full:
                continue

    def next(self):
        return self.q.get()

    def stop(self):
        self._stop.set()
        self._thread.join(timeout=2)
