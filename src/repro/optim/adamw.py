"""AdamW + cosine schedule + global-norm clipping + optional int8
error-feedback gradient compression — pure-pytree, pjit-friendly.

The compression hook mirrors the paper's theme (compress right before the
expensive wire): DP gradient all-reduce bytes shrink 4x (fp32->int8) with
an error-feedback residual keeping convergence. Under pjit the all-reduce
is emitted by XLA inside autodiff, so the quantize/dequantize pair brackets
the optimizer boundary; the shard_map variant in train/loop.py places it
on the wire explicitly for the small-mesh integration test.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    decay_steps: int = 10_000
    min_lr_frac: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    grad_compression: bool = False  # int8 + error feedback


def schedule(step, cfg: OptConfig):
    step = jnp.asarray(step, jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    prog = jnp.clip(
        (step - cfg.warmup_steps) / jnp.maximum(cfg.decay_steps - cfg.warmup_steps, 1),
        0.0,
        1.0,
    )
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.peak_lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def init_state(params, cfg: OptConfig):
    zeros = lambda p: jax.tree_util.tree_map(
        lambda x: jnp.zeros(x.shape, jnp.float32), p
    )
    st = {"m": zeros(params), "v": zeros(params), "count": jnp.zeros((), jnp.int32)}
    if cfg.grad_compression:
        st["ef"] = zeros(params)  # error-feedback residual
    # Mixed precision: when params live in bf16 (so FSDP all-gathers move
    # half the bytes), the fp32 master copy lives HERE, fully sharded and
    # never gathered (§Perf H3b).
    if any(x.dtype != jnp.float32 for x in jax.tree_util.tree_leaves(params)):
        st["master"] = jax.tree_util.tree_map(
            lambda x: x.astype(jnp.float32), params
        )
    return st


def _global_norm(tree):
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree_util.tree_leaves(tree))
    )


def _compress_ef(g, ef):
    """int8 quantize with error feedback. Returns (dequantized g, new ef)."""
    t = g.astype(jnp.float32) + ef
    scale = jnp.maximum(jnp.max(jnp.abs(t)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(t / scale), -127, 127).astype(jnp.int8)
    deq = q.astype(jnp.float32) * scale
    return deq, t - deq


def apply_updates(params, grads, state, step, cfg: OptConfig):
    """One AdamW step. Returns (params, state, metrics)."""
    gnorm = _global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-12))
    grads = jax.tree_util.tree_map(lambda g: g.astype(jnp.float32) * scale, grads)
    if cfg.grad_compression:
        pairs = jax.tree_util.tree_map(_compress_ef, grads, state["ef"])
        grads = jax.tree_util.tree_map(lambda p: p[0], pairs, is_leaf=lambda x: isinstance(x, tuple))
        new_ef = jax.tree_util.tree_map(lambda p: p[1], pairs, is_leaf=lambda x: isinstance(x, tuple))
    lr = schedule(step, cfg)
    cnt = state["count"] + 1
    b1c = 1 - cfg.b1 ** cnt.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** cnt.astype(jnp.float32)

    def upd(p, g, m, v, master):
        m2 = cfg.b1 * m + (1 - cfg.b1) * g
        v2 = cfg.b2 * v + (1 - cfg.b2) * g * g
        mh = m2 / b1c
        vh = v2 / b2c
        step_ = mh / (jnp.sqrt(vh) + cfg.eps)
        ref = master if master is not None else p.astype(jnp.float32)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            step_ = step_ + cfg.weight_decay * ref
        new_master = ref - lr * step_
        return new_master.astype(p.dtype), m2, v2, new_master

    flat_p, tdef = jax.tree_util.tree_flatten(params)
    flat_g = jax.tree_util.tree_leaves(grads)
    flat_m = jax.tree_util.tree_leaves(state["m"])
    flat_v = jax.tree_util.tree_leaves(state["v"])
    flat_ma = (
        jax.tree_util.tree_leaves(state["master"])
        if "master" in state
        else [None] * len(flat_p)
    )
    out = [
        upd(p, g, m, v, ma)
        for p, g, m, v, ma in zip(flat_p, flat_g, flat_m, flat_v, flat_ma)
    ]
    new_p = jax.tree_util.tree_unflatten(tdef, [o[0] for o in out])
    new_m = jax.tree_util.tree_unflatten(tdef, [o[1] for o in out])
    new_v = jax.tree_util.tree_unflatten(tdef, [o[2] for o in out])
    new_state = {"m": new_m, "v": new_v, "count": cnt}
    if "master" in state:
        new_state["master"] = jax.tree_util.tree_unflatten(tdef, [o[3] for o in out])
    if cfg.grad_compression:
        new_state["ef"] = new_ef
    return new_p, new_state, {"grad_norm": gnorm, "lr": lr}
