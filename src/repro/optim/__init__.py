from repro.optim.adamw import OptConfig, apply_updates, init_state, schedule  # noqa: F401
