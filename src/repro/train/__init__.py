from repro.train.loop import LoopConfig, Trainer  # noqa: F401
from repro.train.step import make_prefill, make_serve_step, make_train_step  # noqa: F401
