"""Fault-tolerant training loop.

- auto-resume: restores the latest atomic checkpoint (params + optimizer +
  data step) on start; a killed/preempted job relaunches and continues.
- preemption: SIGTERM/SIGINT trigger a final synchronous checkpoint before
  exit (the cluster analogue of a maintenance-event handler).
- async checkpointing overlaps persistence with training; the data pipeline
  prefetches on a host thread (straggler hiding).
- elastic: restore() reshard-on-load via target shardings, so the same
  checkpoint resumes on a different mesh.
"""
from __future__ import annotations

import dataclasses
import signal
import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import store
from repro.core.sparse_linear import PruneSchedule
from repro.data.pipeline import DataConfig, Prefetcher, SyntheticTokens
from repro.models.model import LM
from repro.optim.adamw import OptConfig, init_state
from repro.train.step import make_train_step


@dataclasses.dataclass
class LoopConfig:
    total_steps: int = 100
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 50
    log_every: int = 10
    keep: int = 3


class Trainer:
    def __init__(
        self,
        model: LM,
        opt_cfg: OptConfig,
        data_cfg: DataConfig,
        loop_cfg: LoopConfig,
        prune_schedule: Optional[PruneSchedule] = None,
        jit_kwargs: Optional[dict] = None,
    ):
        self.model = model
        self.opt_cfg = opt_cfg
        self.data_cfg = data_cfg
        self.loop = loop_cfg
        self.source = SyntheticTokens(model.cfg, data_cfg)
        self.step_fn = jax.jit(
            make_train_step(model, opt_cfg, prune_schedule), **(jit_kwargs or {})
        )
        self.ckpt = (
            store.AsyncCheckpointer(loop_cfg.ckpt_dir, keep=loop_cfg.keep)
            if loop_cfg.ckpt_dir
            else None
        )
        self._preempted = False

    # ------------------------------------------------------------------
    def init_or_resume(self, key=None):
        key = key if key is not None else jax.random.PRNGKey(0)
        params = self.model.init(key)
        if self.model.cfg.dbb is not None:
            params = self.model.constrain(params)
        opt_state = init_state(params, self.opt_cfg)
        start = 0
        if self.loop.ckpt_dir and store.latest_step(self.loop.ckpt_dir) is not None:
            (params, opt_state), manifest = store.restore(
                self.loop.ckpt_dir, (params, opt_state)
            )
            start = manifest["step"] + 1
            print(f"[resume] from step {manifest['step']}")
        return params, opt_state, start

    def _install_signal_handlers(self):
        def handler(signum, frame):
            self._preempted = True

        for sig in (signal.SIGTERM, signal.SIGINT):
            try:
                signal.signal(sig, handler)
            except ValueError:
                pass  # non-main thread (tests)

    # ------------------------------------------------------------------
    def run(self, params=None, opt_state=None, start_step=None, key=None):
        if params is None:
            params, opt_state, start_step = self.init_or_resume(key)
        self._install_signal_handlers()
        pf = Prefetcher(self.source, start_step=start_step)
        history = []
        t0 = time.time()
        try:
            for _ in range(start_step, self.loop.total_steps):
                step, batch = pf.next()
                params, opt_state, metrics = self.step_fn(
                    params, opt_state, batch, jnp.int32(step)
                )
                if step % self.loop.log_every == 0 or step == self.loop.total_steps - 1:
                    loss = float(metrics["loss"])
                    history.append((step, loss))
                    rate = (step - start_step + 1) / (time.time() - t0)
                    print(f"step {step:6d} loss {loss:.4f} ({rate:.2f} it/s)", flush=True)
                if self.ckpt and (
                    (step > 0 and step % self.loop.ckpt_every == 0) or self._preempted
                ):
                    self.ckpt.save_async(step, (params, opt_state))
                if self._preempted:
                    print(f"[preempt] flushed checkpoint at step {step}; exiting")
                    break
        finally:
            pf.stop()
            if self.ckpt:
                self.ckpt.wait()
        return params, opt_state, history
