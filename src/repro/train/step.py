"""Step functions lowered by the launcher and the dry-run.

train_step:  loss -> grads -> AdamW -> DBB constraint projection (the
             paper's magnitude pruning, applied as projected SGD).
prefill:     full-sequence forward returning (last-token logits, cache).
serve_step:  one-token decode against a KV cache, with compressed (VDBB)
             weights when cfg.serve_compressed.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.sparse_linear import PruneSchedule
from repro.models.model import LM
from repro.optim.adamw import OptConfig, apply_updates


def make_train_step(model: LM, opt_cfg: OptConfig, schedule: Optional[PruneSchedule] = None):
    def train_step(params, opt_state, batch, step):
        (loss, metrics), grads = jax.value_and_grad(model.loss, has_aux=True)(
            params, batch
        )
        params, opt_state, opt_metrics = apply_updates(
            params, grads, opt_state, step, opt_cfg
        )
        # The paper's technique: project weights back onto the DBB bound
        # (magnitude pruning within each block), optionally annealed.
        if model.cfg.dbb is not None:
            params = model.constrain(params, step, schedule)
        metrics = {**metrics, **opt_metrics, "step": step}
        return params, opt_state, metrics

    return train_step


def make_prefill(model: LM):
    def prefill(params, batch):
        logits, cache = model.forward(params, batch, return_cache=True)
        return logits[:, -1:, :], cache

    return prefill


def make_serve_step(model: LM):
    def serve_step(params, cache, batch, pos):
        return model.decode_step(params, cache, batch, pos)

    return serve_step
