"""Empirical tile autotuner + persistent config cache (DESIGN.md §10).

The paper's headline numbers come from a *design-space search* over
interrelated tiling/reuse parameters (§V — the same methodology as S2TA
and the original Systolic Tensor Array DSE): enumerate the candidate
design points, prune with an analytic cost model, and measure what
survives. This module is that loop applied to the software datapath's own
free parameters — the Pallas launch tiles ``(bm, bn, kb)`` for the matmul
kernels and ``(bf, tile_h, tile_w)`` for the fused convs:

1. **enumerate** valid candidates per (kernel kind, launch signature) —
   matmul M/N tiles may be non-divisors thanks to the ops-layer
   pad-to-tile path; K-block and conv tiles stay exact divisors;
2. **prune** with the analytic roofline model (compute vs HBM traffic
   from ``dbb_gemm_costs``/``dbb_conv_costs``, tile-revisit factors, and
   a per-grid-step overhead term), keeping the top-K;
3. **measure** the survivors (plus the ``pick_tile`` default, always)
   with the shared ``block_until_ready`` median-of-k harness
   (``repro.xla_utils.median_time_us`` — the same code path
   ``benchmarks/timing.py`` uses, so tuner and benchmark numbers are
   comparable); the measured-best config wins;
4. **persist** winners in a versioned on-disk JSON cache keyed by
   (backend, kernel kind, shape signature), so repeat runs and CI are
   search-free, and **install** them into the ``kernels.core`` registry
   that the kernel entry points consult for default tiles.

``SparseCNN.plan()`` drives this once per model to build a frozen serving
plan (``repro.models.plan``); steady-state serving then does zero
per-call tile resolution.
"""
from __future__ import annotations

import dataclasses
import json
import os
import pathlib
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.energy_model import TPU_V5E
from repro.core.quant import dynamic_act_scale, quantize, quantize_dbb
from repro.core.vdbb import (
    DBBFormat,
    DENSE,
    dbb_encode,
    dbb_encode_conv,
    dbb_gemm_costs,
)
from repro.kernels import core, ops
from repro.xla_utils import interleaved_time_us, median_time_us

CACHE_VERSION = 1

# Roofline constants for the analytic pruning model. Absolute numbers do
# not matter (only the candidate ranking does). The machine balance
# defaults to the shared TPU-v5e constants in the energy model, plus a
# per-grid-step overhead term that penalizes pathologically fine grids
# (which is also what dominates interpret-mode timing on CPU) — but the
# per-backend *measured* calibration (``repro.kernels.calibrate``,
# DESIGN.md §12) overrides all three once fitted, so the pruning ranking
# tracks the machine the search actually runs on.
_PEAK_MACS = TPU_V5E["peak_bf16_flops"] / 2
_HBM_BW = TPU_V5E["hbm_bw"]
_STEP_OVERHEAD_S = 2e-6


# ---------------------------------------------------------------------------
# Persistent cache
# ---------------------------------------------------------------------------


def default_cache_path() -> pathlib.Path:
    env = os.environ.get("REPRO_AUTOTUNE_CACHE")
    if env:
        return pathlib.Path(env)
    return pathlib.Path.home() / ".cache" / "repro" / "autotune.json"


def cache_key(kind: str, sig: tuple, backend: Optional[str] = None) -> str:
    """Deterministic cache key: ``backend|kind|sig...`` — measured configs
    never cross backends (a CPU interpret-mode winner is meaningless on
    TPU), kernels, or launch shapes."""
    backend = backend or jax.default_backend()
    return f"{backend}|{kind}|" + "x".join(str(s) for s in sig)


class TuneCache:
    """Versioned on-disk JSON cache of measured-best tile configs.

    A version mismatch (or an unreadable file) invalidates the whole
    cache — entries are measurements, not correctness data, so silently
    dropping them is always safe.
    """

    def __init__(self, path=None):
        self.path = pathlib.Path(path) if path is not None else default_cache_path()
        self.entries: dict = {}
        # per-backend roofline calibration (repro.kernels.calibrate,
        # DESIGN.md §12) rides in the same file under its own
        # CALIBRATION_VERSION, invalidated independently of tile entries
        self.calibration: dict = {}
        self.load()

    def load(self) -> None:
        self.entries = {}
        self.calibration = {}
        try:
            data = json.loads(self.path.read_text())
        except (OSError, ValueError):
            return
        if not isinstance(data, dict) or data.get("version") != CACHE_VERSION:
            return  # version mismatch: invalidate, re-search on demand
        self.entries = dict(data.get("entries", {}))
        cal = data.get("calibration", {})
        self.calibration = dict(cal) if isinstance(cal, dict) else {}

    def get(self, key: str) -> Optional[dict]:
        return self.entries.get(key)

    def put(self, key: str, entry: dict) -> None:
        self.entries[key] = entry

    def save(self) -> None:
        import tempfile

        self.path.parent.mkdir(parents=True, exist_ok=True)
        # unique temp name: concurrent writers must not interleave into the
        # same staging file (last atomic rename wins, never a torn file)
        fd, tmp = tempfile.mkstemp(dir=self.path.parent,
                                   prefix=self.path.name + ".")
        with os.fdopen(fd, "w") as f:
            f.write(json.dumps(
                {"version": CACHE_VERSION, "entries": self.entries,
                 "calibration": self.calibration},
                indent=2, sort_keys=True,
            ))
        os.replace(tmp, self.path)


def _as_cache(cache) -> TuneCache:
    return cache if isinstance(cache, TuneCache) else TuneCache(cache)


def clear_op_caches() -> None:
    """Drop the jit caches of the ops entry points, so the next call
    re-resolves default tiles against the current registry state.
    (``core.set_tuned``/``core.clear_tuned`` already do this through the
    registered invalidation hook; this is the manual escape hatch.)"""
    ops._drop_jit_caches()


def install(kind: str, sig: tuple, tiles: dict) -> None:
    """Install a tile config into the kernel-core registry. The registry
    invalidates the ops jit caches itself on any actual change (and skips
    the invalidation for identical re-installs, e.g. cache replays), so
    already-traced default-tile launches re-consult it."""
    core.set_tuned(kind, sig, tiles)


# ---------------------------------------------------------------------------
# Candidate enumeration
# ---------------------------------------------------------------------------


def _spread(vals, keep: int):
    """At most ``keep`` values, evenly spread, endpoints always kept."""
    vals = sorted(set(vals))
    if len(vals) <= keep:
        return vals
    if keep <= 1:
        return [vals[-1]]  # the largest tile (fewest grid steps)
    step = (len(vals) - 1) / (keep - 1)
    return sorted({vals[round(i * step)] for i in range(keep)})


def _divisors(dim: int):
    return [d for d in range(1, dim + 1) if dim % d == 0]


def _mn_tile_pool(dim: int, default: int, keep: int = 5):
    """M/N tile candidates: powers of two (pad-to-tile makes non-divisors
    legal), useful divisors, the whole dimension, and the pick_tile
    default."""
    pool = {d for d in (8, 16, 32, 64, 128, 256, 512) if d <= dim}
    pool |= {d for d in _divisors(dim) if d >= max(2, default // 8)}
    pool.add(dim)
    pool.add(core.pick_tile(dim, default))
    return _spread(pool, keep)


def matmul_candidates(m: int, k: int, n: int, fmt: DBBFormat, keep: int = 5):
    """Valid ``(bm, bn, kb)`` dicts for one compressed-matmul launch."""
    nb = k // fmt.bz
    kbs = _spread([d for d in _divisors(nb)], 4)
    out = []
    for bm in _mn_tile_pool(m, 128, keep):
        for bn in _mn_tile_pool(n, 256, keep):
            for kb in kbs:
                out.append({"bm": bm, "bn": bn, "kb": kb})
    return out


def conv_candidates(ho: int, wo: int, f: int, keep: int = 4):
    """Valid ``(bf, tile_h, tile_w)`` dicts — conv tiles stay exact
    divisors (spatial geometry and the F BlockSpec have no pad path)."""
    bfs = _spread([d for d in _divisors(f) if d >= min(8, f)] or [f], keep)
    ths = _spread(_divisors(ho), 3)
    tws = _spread(_divisors(wo), 3)
    return [{"bf": bf, "tile_h": th, "tile_w": tw}
            for bf in bfs for th in ths for tw in tws]


def default_matmul_tiles(m: int, k: int, n: int, fmt: DBBFormat, tc: bool) -> dict:
    """What the untuned ``pick_tile`` path resolves to (the baseline every
    search measures against)."""
    bm, _ = core.pick_tile_padded(m, 128)
    bn, _ = core.pick_tile_padded(n, 256)
    kb = core.pick_tile(k // fmt.bz, 16 if tc else 8)
    return {"bm": bm, "bn": bn, "kb": kb}


def default_conv_tiles(ho: int, wo: int, f: int) -> dict:
    return {"bf": core.pick_tile(f, 128), "tile_h": ho, "tile_w": wo}


# ---------------------------------------------------------------------------
# Analytic pruning model (roofline over the §5/§6 cost accounting)
# ---------------------------------------------------------------------------


def matmul_cost_terms(m: int, k: int, n: int, fmt: DBBFormat, tiles: dict,
                      itemsize: float = 4.0) -> tuple:
    """``(executed_macs, hbm_bytes, grid_steps)`` of one OS matmul launch
    under a tile config — the three roofline terms, shared by the modeled
    cost below and the calibration fit (``repro.kernels.calibrate``).

    A tiles are re-read once per N tile, the compressed weight stream once
    per M tile (output-stationary dataflow); padded candidates are charged
    their wasted compute.
    """
    bm, bn, kb = tiles["bm"], tiles["bn"], tiles["kb"]
    mp = -(-m // bm) * bm
    n_pad = -(-n // bn) * bn
    nb = max(k // fmt.bz, 1)
    grid = (mp // bm) * (n_pad // bn) * max(nb // kb, 1)
    c = dbb_gemm_costs(m, k, n, fmt, bits=int(8 * itemsize),
                       act_bits=int(8 * itemsize))
    act = c["act_bytes"] * (n_pad // bn) * (mp / m)
    wt = c["weight_bytes"] * (mp // bm)
    out = m * n * 4
    macs = c["executed_macs"] * ((mp * n_pad) / (m * n))
    return macs, act + wt + out, grid


def conv_cost_terms(batch: int, ho: int, wo: int, c_in: int, f: int,
                    kh: int, kw: int, sh: int, sw: int, fmt: DBBFormat,
                    tiles: dict, itemsize: float = 4.0) -> tuple:
    """Conv twin of :func:`matmul_cost_terms`."""
    bf, bh, bw = tiles["bf"], tiles["tile_h"], tiles["tile_w"]
    th, tw = ho // bh, wo // bw
    bh_in = (bh - 1) * sh + kh
    bw_in = (bw - 1) * sw + kw
    spatial = batch * th * tw
    grid = spatial * (f // bf) * kh * kw
    g = dbb_gemm_costs(batch * ho * wo, kh * kw * c_in, f, fmt,
                       bits=int(8 * itemsize), act_bits=int(8 * itemsize))
    act = spatial * bh_in * bw_in * c_in * itemsize * (f // bf)
    wt = g["weight_bytes"] * spatial
    out = batch * ho * wo * f * 4
    return g["executed_macs"], act + wt + out, grid


def _resolve_cal(cal):
    """The calibration the modeled costs run under: an explicit
    :class:`repro.kernels.calibrate.Calibration`, else this backend's
    active/cached/default one (lazy import — no cycle)."""
    if cal is not None:
        return cal
    from repro.kernels import calibrate

    return calibrate.get_calibration()


def modeled_matmul_cost(m: int, k: int, n: int, fmt: DBBFormat, tiles: dict,
                        itemsize: float = 4.0, cal=None) -> float:
    """Modeled seconds for one OS matmul launch under a tile config:
    ``max(compute, memory) + grid · step_overhead`` with the per-backend
    calibrated machine constants (DESIGN.md §12)."""
    cal = _resolve_cal(cal)
    macs, bytes_, grid = matmul_cost_terms(m, k, n, fmt, tiles, itemsize)
    return (max(macs / cal.peak_macs, bytes_ / cal.hbm_bw)
            + grid * cal.step_overhead_s)


def modeled_conv_cost(batch: int, ho: int, wo: int, c_in: int, f: int,
                      kh: int, kw: int, sh: int, sw: int, fmt: DBBFormat,
                      tiles: dict, itemsize: float = 4.0, cal=None) -> float:
    """Modeled seconds for one fused-conv launch under a tile config."""
    cal = _resolve_cal(cal)
    macs, bytes_, grid = conv_cost_terms(batch, ho, wo, c_in, f, kh, kw,
                                         sh, sw, fmt, tiles, itemsize)
    return (max(macs / cal.peak_macs, bytes_ / cal.hbm_bw)
            + grid * cal.step_overhead_s)


# ---------------------------------------------------------------------------
# Search
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TuneResult:
    """Outcome of one tuning query (searched, or replayed from cache)."""

    kind: str
    sig: tuple
    tiles: dict            # measured-best config
    measured_us: float     # its median wall time
    default_tiles: dict    # the pick_tile baseline
    default_us: float      # baseline median wall time (same harness/run)
    modeled_best_us: float     # best modeled cost over all candidates
    modeled_default_us: float  # modeled cost of the baseline
    n_candidates: int
    source: str            # 'search' | 'cache'

    @property
    def speedup(self) -> float:
        return self.default_us / max(self.measured_us, 1e-9)


# A searched winner must beat the default by this factor in the interleaved
# confirmation pass, or it is demoted back to the default — noisy shared-CPU
# measurements must never persist a config that is really a tie or a loss.
CONFIRM_MARGIN = 1.05


def interleaved_medians(fn_a, fn_b, *, warmup: int = 1, reps: int = 5,
                        stat: str = "median"):
    """Wall times (us) of two nullary callables sampled alternately
    (A, B, A, B, …), so environment drift cancels out of the comparison —
    the harness for winner-vs-default confirmation and for benchmarks.
    Delegates to the canonical :func:`repro.xla_utils.interleaved_time_us`
    (one code path for tuner, calibration, and benchmark comparisons);
    ``stat='min'`` over generous reps is the noise-robust gating choice."""
    return interleaved_time_us(fn_a, fn_b, warmup=warmup, reps=reps, stat=stat)


def _search(kind, sig, candidates, cost_fn, build, default_tiles, *,
            top_k, reps, warmup, cache, save):
    cands = [dict(t) for t in candidates]
    if default_tiles not in cands:
        cands.append(dict(default_tiles))
    ranked = sorted(cands, key=cost_fn)
    survivors = ranked[: max(1, top_k)]
    if default_tiles not in survivors:
        survivors.append(default_tiles)  # the baseline is always measured
    timed = [(median_time_us(build(t), warmup=warmup, reps=reps), t)
             for t in survivors]
    best_us, best = min(timed, key=lambda p: p[0])
    default_us = next(us for us, t in timed if t == default_tiles)
    if best != default_tiles:
        # confirmation pass: the apparent winner must replicate its win
        # head-to-head against the default, beyond the noise margin
        b_us, d_us = interleaved_medians(
            build(best), build(default_tiles), warmup=1, reps=max(reps, 3)
        )
        if b_us * CONFIRM_MARGIN <= d_us:
            best_us, default_us = b_us, d_us
        else:
            best, best_us, default_us = dict(default_tiles), d_us, d_us
    res = TuneResult(
        kind=kind, sig=sig, tiles=best, measured_us=best_us,
        default_tiles=default_tiles, default_us=default_us,
        modeled_best_us=cost_fn(ranked[0]) * 1e6,
        modeled_default_us=cost_fn(default_tiles) * 1e6,
        n_candidates=len(cands), source="search",
    )
    install(kind, sig, best)
    if cache is not None:
        cache.put(cache_key(kind, sig), _entry(res))
        if save:
            cache.save()
    return res


def _entry(res: TuneResult) -> dict:
    return {
        "tiles": res.tiles, "measured_us": res.measured_us,
        "default_tiles": res.default_tiles, "default_us": res.default_us,
        "modeled_best_us": res.modeled_best_us,
        "modeled_default_us": res.modeled_default_us,
        "n_candidates": res.n_candidates,
    }


def _from_entry(kind, sig, e: dict) -> TuneResult:
    return TuneResult(
        kind=kind, sig=sig, tiles=dict(e["tiles"]),
        measured_us=e["measured_us"], default_tiles=dict(e["default_tiles"]),
        default_us=e["default_us"], modeled_best_us=e["modeled_best_us"],
        modeled_default_us=e["modeled_default_us"],
        n_candidates=e["n_candidates"], source="cache",
    )


def _matmul_kind(fmt: DBBFormat, n: int) -> str:
    return core.KIND_MATMUL_TC if fmt.group_size(n) == n else core.KIND_MATMUL_BW


def tune_matmul(m: int, k: int, n: int, fmt: DBBFormat, *,
                dtype=jnp.float32, top_k: int = 4, reps: int = 3,
                warmup: int = 1, keep: int = 5, cache=None, save: bool = True,
                force: bool = False, seed: int = 0) -> TuneResult:
    """Measured-best ``(bm, bn, kb)`` for one compressed-matmul launch.

    Cache hits skip the search entirely (``force=True`` re-measures); the
    winner is installed into the kernel-core registry either way, so
    subsequent default-tile ``ops.vdbb_matmul``/``ops.quant_matmul`` calls
    at this signature use it.
    """
    kind = _matmul_kind(fmt, n)
    sig = core.matmul_sig(m, k, n, fmt.bz, fmt.nnz, dtype)
    cache = _as_cache(cache)
    if not force:
        hit = cache.get(cache_key(kind, sig))
        if hit is not None:
            install(kind, sig, hit["tiles"])
            return _from_entry(kind, sig, hit)
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    a = jax.random.normal(k1, (m, k), jnp.float32)
    dw = dbb_encode(jax.random.normal(k2, (k, n), jnp.float32), fmt, prune=True)
    if jnp.dtype(dtype) == jnp.int8:
        a = quantize(a, dynamic_act_scale(a))
        dw = quantize_dbb(dw).as_dbb()
    elif jnp.dtype(dtype) != jnp.float32:
        a = a.astype(dtype)
        dw = dataclasses.replace(dw, values=dw.values.astype(dtype))
    itemsize = float(jnp.dtype(dtype).itemsize)

    def build(t):
        return lambda: ops.vdbb_matmul(a, dw, bm=t["bm"], bn=t["bn"], kb=t["kb"])

    from repro.kernels import calibrate

    cal = calibrate.get_calibration(cache=cache)  # per-backend pruning (§12)
    return _search(
        kind, sig, matmul_candidates(m, k, n, fmt, keep=keep),
        lambda t: modeled_matmul_cost(m, k, n, fmt, t, itemsize, cal=cal),
        build, default_matmul_tiles(m, k, n, fmt, kind == core.KIND_MATMUL_TC),
        top_k=top_k, reps=reps, warmup=warmup, cache=cache, save=save,
    )


def tune_conv(batch: int, h: int, w: int, c: int, f: int, kh: int, kw: int,
              fmt: Optional[DBBFormat] = None, *, stride=1, padding="SAME",
              dtype=jnp.float32, top_k: int = 4, reps: int = 3,
              warmup: int = 1, keep: int = 4, cache=None, save: bool = True,
              force: bool = False, seed: int = 0) -> TuneResult:
    """Measured-best ``(bf, tile_h, tile_w)`` for one fused-conv launch.

    ``fmt=None`` tunes the dense im2col kernel; a sparse format tunes the
    fused IM2COL × VDBB kernel in its tc/bw mode.
    """
    (sh, sw), _, (ho, wo) = core.conv_geometry(h, w, kh, kw, stride, padding)
    if fmt is None:
        kind = core.KIND_CONV_DENSE
        sig = core.conv_sig(batch, ho, wo, c, f, kh, kw, sh, sw, 0, 0, dtype)
    else:
        kind = (core.KIND_CONV_TC if fmt.group_size(f) == f
                else core.KIND_CONV_BW)
        sig = core.conv_sig(batch, ho, wo, c, f, kh, kw, sh, sw,
                            fmt.bz, fmt.nnz, dtype)
    cache = _as_cache(cache)
    if not force:
        hit = cache.get(cache_key(kind, sig))
        if hit is not None:
            install(kind, sig, hit["tiles"])
            return _from_entry(kind, sig, hit)
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    x = jax.random.normal(k1, (batch, h, w, c), jnp.float32)
    w4 = jax.random.normal(k2, (kh, kw, c, f), jnp.float32)
    if jnp.dtype(dtype) == jnp.int8:
        x = quantize(x, dynamic_act_scale(x))
    elif jnp.dtype(dtype) != jnp.float32:
        x = x.astype(dtype)
        w4 = w4.astype(dtype)
    if fmt is None:
        wd = w4 if jnp.dtype(dtype) != jnp.int8 else quantize(
            w4, dynamic_act_scale(w4))

        def build(t):
            return lambda: ops.fused_im2col_conv(
                x, wd, stride=stride, padding=padding, bf=t["bf"],
                tile_h=t["tile_h"], tile_w=t["tile_w"])
    else:
        dw = dbb_encode_conv(jax.random.normal(k2, (kh, kw, c, f), jnp.float32),
                             fmt, prune=True)
        if jnp.dtype(dtype) == jnp.int8:
            dw = quantize_dbb(dw).as_dbb()

        def build(t):
            return lambda: ops.sparse_conv(
                x, dw, kh, kw, stride=stride, padding=padding, bf=t["bf"],
                tile_h=t["tile_h"], tile_w=t["tile_w"])

    itemsize = float(jnp.dtype(dtype).itemsize)
    mfmt = fmt or DENSE

    from repro.kernels import calibrate

    cal = calibrate.get_calibration(cache=cache)  # per-backend pruning (§12)
    return _search(
        kind, sig, conv_candidates(ho, wo, f, keep=keep),
        lambda t: modeled_conv_cost(batch, ho, wo, c, f, kh, kw, sh, sw,
                                    mfmt, t, itemsize, cal=cal),
        build, default_conv_tiles(ho, wo, f),
        top_k=top_k, reps=reps, warmup=warmup, cache=cache, save=save,
    )


# ---------------------------------------------------------------------------
# Plan-time resolution (registry → cache → optional search)
# ---------------------------------------------------------------------------


def tiles_for_matmul(m, k, n, fmt, dtype, *, mode: str = "cache", cache=None,
                     top_k: int = 4, reps: int = 3) -> dict:
    """Resolve tiles for a matmul launch under a tuning ``mode``:
    ``'off'`` (pick_tile defaults), ``'cache'`` (registry/cache hits only,
    never search), ``'search'`` (search on miss and persist)."""
    if mode == "off":
        return {}
    kind = _matmul_kind(fmt, n)
    sig = core.matmul_sig(m, k, n, fmt.bz, fmt.nnz, dtype)
    t = core.lookup_tiles(kind, sig)
    if t:
        return dict(t)
    cache = _as_cache(cache)
    hit = cache.get(cache_key(kind, sig))
    if hit is not None:
        install(kind, sig, hit["tiles"])
        return dict(hit["tiles"])
    if mode != "search":
        return {}
    return dict(tune_matmul(m, k, n, fmt, dtype=dtype, top_k=top_k,
                            reps=reps, cache=cache).tiles)


def tiles_for_conv(batch, h, w, c, f, kh, kw, fmt, dtype, *, stride=1,
                   padding="SAME", mode: str = "cache", cache=None,
                   top_k: int = 4, reps: int = 3) -> dict:
    """Conv twin of :func:`tiles_for_matmul` (``fmt=None`` = dense kernel)."""
    if mode == "off":
        return {}
    (sh, sw), _, (ho, wo) = core.conv_geometry(h, w, kh, kw, stride, padding)
    if fmt is None:
        kind, bz, nnz = core.KIND_CONV_DENSE, 0, 0
    else:
        kind = core.KIND_CONV_TC if fmt.group_size(f) == f else core.KIND_CONV_BW
        bz, nnz = fmt.bz, fmt.nnz
    sig = core.conv_sig(batch, ho, wo, c, f, kh, kw, sh, sw, bz, nnz, dtype)
    t = core.lookup_tiles(kind, sig)
    if t:
        return dict(t)
    cache = _as_cache(cache)
    hit = cache.get(cache_key(kind, sig))
    if hit is not None:
        install(kind, sig, hit["tiles"])
        return dict(hit["tiles"])
    if mode != "search":
        return {}
    return dict(tune_conv(batch, h, w, c, f, kh, kw, fmt, stride=stride,
                          padding=padding, dtype=dtype, top_k=top_k,
                          reps=reps, cache=cache).tiles)
