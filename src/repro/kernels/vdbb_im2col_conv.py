"""Fused IM2COL × VDBB Pallas kernel — the paper's datapath, end-to-end.

This is the composition the paper's headline numbers come from: the
hardware IM2COL unit expands the activation stream *after* SRAM and feeds
it straight into the VDBB sparse tensor array. The TPU analogue fuses both
in-VMEM transforms in one kernel:

  HBM reads:  raw activation tile (once, + tile halo)  ×  compressed
              weight stream (nnz/bz of dense bytes)
  in VMEM:    shifted-view im2col tap (the IM2COL unit)
              → DBB gather (tc) or scatter-expand (bw) (the VDBB mux)
  compute:    MXU matmuls at nnz/bz occupancy (tc) or dense (bw)

The conv weight (kh, kw, C, F) is DBB-compressed along K = kh·kw·C with
C % bz == 0, so every bz-block lies inside a single kernel tap and the
tap (dy, dx) — the innermost grid axis — streams exactly its own C/bz
compressed blocks per step. Geometry, tiling, and the output-stationary
accumulator all come from :mod:`repro.kernels.core` (DESIGN.md §6).

Both pattern-sharing modes are provided, mirroring ``vdbb_matmul``:
``vdbb_im2col_conv_tc`` (group-shared patterns, compressed-K compute) and
``vdbb_im2col_conv_bw`` (paper-faithful per-column patterns, in-VMEM
expand). ``kernels.ops.sparse_conv`` dispatches on the weight's format.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.vdbb import DBBFormat, DBBWeight
from repro.kernels import core
from repro.kernels.im2col_conv import conv_out_spec, plan_conv
from repro.kernels.vdbb_matmul import dbb_expand_block


def _conv_weight_geometry(dw: DBBWeight, kh: int, kw: int):
    """Validate and split the compressed-K layout: K = kh·kw·C, C % bz == 0."""
    k, f = dw.shape
    bz = dw.fmt.bz
    if k % (kh * kw) != 0:
        raise ValueError(f"K={k} not divisible by kh*kw={kh * kw}")
    c = k // (kh * kw)
    if c % bz != 0:
        raise ValueError(
            f"C={c} not divisible by bz={bz}: a DBB block would straddle "
            "kernel taps, which the fused conv kernel does not support"
        )
    return c, f, c // bz


# ---------------------------------------------------------------------------
# tc mode: shifted view -> gather-compressed-K -> dense MXU dot
# ---------------------------------------------------------------------------


def _vdbb_conv_tc_kernel(
    x_ref, v_ref, idx_ref, *rest, bz, nnz, kw, sh, sw, bh, bw, ep=None
):
    """Grid: (N·th·tw, F/bf, kh·kw). x: (1, bh_in, bw_in, C);
    v: (1, cb·nnz, bf); idx: (1, cb, nnz) int32; ``rest`` carries the
    optional (1, bf) fp32 epilogue rows named by the static ``ep``
    (scale/bias/out_scale — DESIGN.md §9)."""
    flush, o_ref, acc_ref = core.split_epilogue(ep, rest)
    t = pl.program_id(2)
    patch = core.conv_patch(x_ref[0], t // kw, t % kw, bh=bh, bw=bw, sh=sh, sw=sw)
    c = patch.shape[-1]
    cb = c // bz
    pref = core.acc_dtype_for(patch.dtype)  # int32 for int8 operands
    a = patch.reshape(bh * bw, cb, bz)
    idx = idx_ref[0]  # (cb, nnz)
    # The activation mux: one-hot gather A[:, b, idx[b, j]] -> compressed K.
    onehot = jax.nn.one_hot(idx, bz, dtype=a.dtype)  # (cb, nnz, bz)
    ac = jax.lax.dot_general(
        a,
        onehot,
        dimension_numbers=(((2,), (2,)), ((1,), (0,))),
        preferred_element_type=pref,
    )  # (cb, bh*bw, nnz)
    ac = ac.transpose(1, 0, 2).reshape(bh * bw, cb * nnz).astype(a.dtype)
    contrib = jax.lax.dot(
        ac, v_ref[0].astype(a.dtype), preferred_element_type=pref
    )
    core.os_accumulate(acc_ref, o_ref, contrib, grid_axis=2, **flush)


# ---------------------------------------------------------------------------
# bw mode: shifted view -> in-VMEM scatter-expand -> dense MXU dot
# ---------------------------------------------------------------------------


def _vdbb_conv_bw_kernel(
    x_ref, v_ref, idx_ref, *rest, bz, nnz, kw, sh, sw, bh, bw, ep=None
):
    """Grid: (N·th·tw, F/bf, kh·kw). x: (1, bh_in, bw_in, C);
    v/idx: (1, cb·nnz, bf) — per-column patterns; ``rest`` carries the
    optional (1, bf) fp32 epilogue rows named by ``ep`` (DESIGN.md §9)."""
    flush, o_ref, acc_ref = core.split_epilogue(ep, rest)
    t = pl.program_id(2)
    patch = core.conv_patch(x_ref[0], t // kw, t % kw, bh=bh, bw=bw, sh=sh, sw=sw)
    bf = o_ref.shape[-1]
    cb = patch.shape[-1] // bz
    v = v_ref[0].reshape(cb, nnz, bf)
    idx = idx_ref[0].reshape(cb, nnz, bf)
    wd = dbb_expand_block(v, idx, bz)  # (C, bf), the "late mux"
    contrib = jax.lax.dot(
        patch,
        wd.astype(patch.dtype),
        preferred_element_type=core.acc_dtype_for(patch.dtype),
    )
    core.os_accumulate(acc_ref, o_ref, contrib, grid_axis=2, **flush)


# ---------------------------------------------------------------------------
# host wrappers
# ---------------------------------------------------------------------------


def _tuned_conv_defaults(kind, x, fmt, kh, kw, f, stride, padding,
                         bf, tile_h, tile_w):
    """Fill default conv tiles from the autotune registry (measured-best
    configs installed by ``repro.kernels.autotune``); explicit requests
    pass through untouched."""
    if bf is not None or tile_h is not None or tile_w is not None:
        return bf, tile_h, tile_w
    n, h, w = x.shape[0], x.shape[1], x.shape[2]
    c = x.shape[3]
    (sh, sw), _, (ho, wo) = core.conv_geometry(h, w, kh, kw, stride, padding)
    sig = core.conv_sig(n, ho, wo, c, f, kh, kw, sh, sw, fmt.bz, fmt.nnz, x.dtype)
    return core.tuned_conv_tiles(kind, sig, ho, wo, f)


def _launch(kernel, x, operands, wspecs, fmt, kh, kw, *, stride, padding, bf,
            tile_h, tile_w, out_dtype, interpret, scales=None, bias=None,
            relu=False, out_scale=None):
    n = x.shape[0]
    f = operands[0].shape[-1]
    xt, g = plan_conv(x, kh, kw, stride=stride, padding=padding,
                      tile_h=tile_h, tile_w=tile_w)
    grid = (n * g["th"] * g["tw"], f // bf, kh * kw)
    acc_dtype = core.acc_dtype_for(x.dtype)  # int32 on the int8 path
    ep, e_ops, e_specs, out_dtype = core.epilogue_plan(
        f, bf, scales=scales, bias=bias, relu=relu, out_scale=out_scale,
        acc_dtype=acc_dtype, in_dtype=x.dtype, out_dtype=out_dtype,
    )
    operands = (*operands, *e_ops)
    wspecs = [*wspecs, *e_specs]
    return pl.pallas_call(
        functools.partial(
            kernel, bz=fmt.bz, nnz=fmt.nnz, kw=kw,
            sh=g["sh"], sw=g["sw"], bh=g["bh"], bw=g["bw"], ep=ep,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, g["bh_in"], g["bw_in"], g["c"]), lambda p, j, t: (p, 0, 0, 0)),
            *wspecs,
        ],
        out_specs=conv_out_spec(g, bf),
        out_shape=jax.ShapeDtypeStruct((n, g["ho"], g["wo"], f), out_dtype),
        scratch_shapes=[pltpu.VMEM((g["bh"] * g["bw"], bf), acc_dtype)],
        interpret=core.resolve_interpret(interpret),
    )(xt, *operands)


def vdbb_im2col_conv_tc(
    x: jax.Array,
    values: jax.Array,
    indices: jax.Array,
    fmt: DBBFormat,
    kh: int,
    kw: int,
    *,
    scales: jax.Array | None = None,
    bias: jax.Array | None = None,
    relu: bool = False,
    out_scale=None,
    stride=1,
    padding="SAME",
    bf: int | None = None,
    tile_h: int | None = None,
    tile_w: int | None = None,
    out_dtype=None,
    interpret: bool | None = True,
) -> jax.Array:
    """Fused sparse conv, group-shared patterns. x: (N, H, W, C);
    values: (nb, nnz, F); indices: (nb, nnz) with nb = kh·kw·C/bz.
    int8 operands accumulate in exact int32; ``scales`` (F,) / ``bias``
    (F,) / ``relu`` / ``out_scale`` fuse the layer epilogue into the
    accumulator flush (DESIGN.md §9; out int8 when requantizing)."""
    nb, nnz, f = values.shape
    c = nb * fmt.bz // (kh * kw)
    cb = c // fmt.bz
    bf, tile_h, tile_w = _tuned_conv_defaults(
        core.KIND_CONV_TC, x, fmt, kh, kw, f, stride, padding, bf, tile_h, tile_w
    )
    bf = core.resolve_or_pick(f, bf, 128, "bf")
    v = values.reshape(kh * kw, cb * nnz, f)
    idx = indices.astype(jnp.int32).reshape(kh * kw, cb, nnz)
    wspecs = [
        pl.BlockSpec((1, cb * nnz, bf), lambda p, j, t: (t, 0, j)),
        pl.BlockSpec((1, cb, nnz), lambda p, j, t: (t, 0, 0)),
    ]
    return _launch(
        _vdbb_conv_tc_kernel, x, (v, idx), wspecs, fmt, kh, kw,
        stride=stride, padding=padding, bf=bf, tile_h=tile_h, tile_w=tile_w,
        out_dtype=out_dtype, interpret=interpret, scales=scales, bias=bias,
        relu=relu, out_scale=out_scale,
    )


def vdbb_im2col_conv_bw(
    x: jax.Array,
    values: jax.Array,
    indices: jax.Array,
    fmt: DBBFormat,
    kh: int,
    kw: int,
    *,
    scales: jax.Array | None = None,
    bias: jax.Array | None = None,
    relu: bool = False,
    out_scale=None,
    stride=1,
    padding="SAME",
    bf: int | None = None,
    tile_h: int | None = None,
    tile_w: int | None = None,
    out_dtype=None,
    interpret: bool | None = True,
) -> jax.Array:
    """Fused sparse conv, per-column patterns. values/indices: (nb, nnz, F).
    int8 + epilogue as in :func:`vdbb_im2col_conv_tc`."""
    nb, nnz, f = values.shape
    c = nb * fmt.bz // (kh * kw)
    cb = c // fmt.bz
    bf, tile_h, tile_w = _tuned_conv_defaults(
        core.KIND_CONV_BW, x, fmt, kh, kw, f, stride, padding, bf, tile_h, tile_w
    )
    bf = core.resolve_or_pick(f, bf, 128, "bf")
    v = values.reshape(kh * kw, cb * nnz, f)
    idx = indices.astype(jnp.int32).reshape(kh * kw, cb * nnz, f)
    wspecs = [
        pl.BlockSpec((1, cb * nnz, bf), lambda p, j, t: (t, 0, j)),
        pl.BlockSpec((1, cb * nnz, bf), lambda p, j, t: (t, 0, j)),
    ]
    return _launch(
        _vdbb_conv_bw_kernel, x, (v, idx), wspecs, fmt, kh, kw,
        stride=stride, padding=padding, bf=bf, tile_h=tile_h, tile_w=tile_w,
        out_dtype=out_dtype, interpret=interpret, scales=scales, bias=bias,
        relu=relu, out_scale=out_scale,
    )


def vdbb_im2col_conv(
    x: jax.Array,
    dw: DBBWeight,
    kh: int,
    kw: int,
    **kw_args,
) -> jax.Array:
    """Fused sparse conv over a compressed DBBWeight; dispatches tc vs bw
    on the weight's pattern-sharing mode (like ``ops.vdbb_matmul``)."""
    c, f, cb = _conv_weight_geometry(dw, kh, kw)
    if x.shape[-1] != c:
        raise ValueError(f"x has C={x.shape[-1]} but weight encodes C={c}")
    g = dw.fmt.group_size(f)
    if g == f:
        return vdbb_im2col_conv_tc(
            x, dw.values, dw.indices[:, :, 0], dw.fmt, kh, kw, **kw_args
        )
    idx = jnp.repeat(dw.indices, g, axis=2) if g > 1 else dw.indices
    return vdbb_im2col_conv_bw(x, dw.values, idx, dw.fmt, kh, kw, **kw_args)
