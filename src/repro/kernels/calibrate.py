"""Per-backend calibration of the roofline cost model (DESIGN.md §12).

The autotuner prunes its candidate search with an analytic roofline model
(``repro.kernels.autotune.modeled_matmul_cost`` / ``modeled_conv_cost``)
whose machine-balance constants were, before this module, the *static*
TPU-v5e datasheet numbers regardless of where the code actually runs. On
CPU (interpret-mode Pallas) the per-grid-step overhead is ~25× the
assumed 2µs, so the model's candidate ranking — and therefore which
configs ever get measured — was anchored to the wrong machine. The
paper's §V design-space evaluation is credible precisely because every
modeled number is validated against implementation measurements; this
module is the software analog of that validation loop:

1. **probe** — launch a small fixed set of compressed-matmul kernels
   whose tile configs spread the three cost terms (executed MACs, HBM
   bytes, grid steps) across an order of magnitude each, timed with the
   shared noise-robust harness (``min`` over interleaved-style repeated
   samples, ``repro.xla_utils.time_samples_us``);
2. **fit** — least-squares the linear surrogate
   ``t ≈ macs/peak + bytes/bw + steps·overhead`` (coefficients clamped
   non-negative; unidentifiable terms fall back to the datasheet
   defaults) — :func:`fit_calibration` is pure and unit-testable;
3. **persist** — the fitted :class:`Calibration` is stored per backend in
   the same versioned autotune cache file (its own
   ``CALIBRATION_VERSION`` invalidates independently of tile entries),
   so repeat runs and CI are fit-free;
4. **consult** — ``modeled_matmul_cost``/``modeled_conv_cost`` resolve
   the active calibration (installed → cached → default) on every call,
   so the pruning ranking is per-backend measured, not assumed.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.energy_model import TPU_V5E
from repro.core.vdbb import DBBFormat, dbb_encode
from repro.xla_utils import time_samples_us

CALIBRATION_VERSION = 1

# Datasheet fallbacks (the pre-§12 static constants): machine balance from
# the shared TPU-v5e numbers in the energy model, per-grid-step overhead a
# compiled-backend estimate. Absolute values only matter for ranking.
DEFAULT_PEAK_MACS = TPU_V5E["peak_bf16_flops"] / 2
DEFAULT_HBM_BW = TPU_V5E["hbm_bw"]
DEFAULT_STEP_OVERHEAD_S = 2e-6


@dataclasses.dataclass(frozen=True)
class Calibration:
    """Fitted (or default) roofline constants for one backend."""

    backend: str
    peak_macs: float        # effective MAC/s
    hbm_bw: float           # effective bytes/s
    step_overhead_s: float  # per-grid-step launch/dispatch overhead
    residual: float = 0.0   # rms relative fit error over the probe set
    source: str = "default"  # 'default' | 'fit' | 'cache'


def default_calibration(backend: Optional[str] = None) -> Calibration:
    return Calibration(
        backend=backend or jax.default_backend(),
        peak_macs=DEFAULT_PEAK_MACS,
        hbm_bw=DEFAULT_HBM_BW,
        step_overhead_s=DEFAULT_STEP_OVERHEAD_S,
    )


# ---------------------------------------------------------------------------
# Cache entry (lives inside the autotune TuneCache file, own version)
# ---------------------------------------------------------------------------


def to_entry(cal: Calibration) -> dict:
    return {
        "version": CALIBRATION_VERSION,
        "backend": cal.backend,
        "peak_macs": cal.peak_macs,
        "hbm_bw": cal.hbm_bw,
        "step_overhead_s": cal.step_overhead_s,
        "residual": cal.residual,
    }


def from_entry(entry: dict) -> Optional[Calibration]:
    """Parse a cached calibration entry; None on version mismatch or any
    non-finite/non-positive constant (measurements, not correctness data —
    silently dropping them is always safe)."""
    import math

    if not isinstance(entry, dict) or entry.get("version") != CALIBRATION_VERSION:
        return None
    try:
        vals = [float(entry[k]) for k in ("peak_macs", "hbm_bw", "step_overhead_s")]
    except (KeyError, TypeError, ValueError):
        return None
    if not all(math.isfinite(v) and v > 0 for v in vals):
        return None
    return Calibration(
        backend=str(entry.get("backend", "")),
        peak_macs=vals[0], hbm_bw=vals[1], step_overhead_s=vals[2],
        residual=float(entry.get("residual", 0.0)), source="cache",
    )


# In-process installed calibrations, one per backend (the fast path the
# cost model reads; `calibrate()` and `set_active` write it).
_ACTIVE: dict = {}


def set_active(cal: Calibration) -> None:
    _ACTIVE[cal.backend] = cal


def clear_active() -> None:
    _ACTIVE.clear()


def get_calibration(backend: Optional[str] = None, cache=None) -> Calibration:
    """Active → cached → default, never None. ``cache`` is a
    ``repro.kernels.autotune.TuneCache`` (or a path for one); pass the
    search's cache so tuning and calibration share one file."""
    backend = backend or jax.default_backend()
    hit = _ACTIVE.get(backend)
    if hit is not None:
        return hit
    if cache is not None:
        from repro.kernels.autotune import TuneCache

        if not isinstance(cache, TuneCache):
            cache = TuneCache(cache)
        cal = from_entry(cache.calibration.get(backend))
        if cal is not None:
            return cal
    return default_calibration(backend)


# ---------------------------------------------------------------------------
# Probe set
# ---------------------------------------------------------------------------

_PROBE_FMT = DBBFormat(8, 3, "matrix")

# (m, k, n, tiles) — tile configs chosen to spread grid-step count (1 →
# 128) and traffic/compute volume an order of magnitude each, so the three
# coefficients of the linear surrogate are separately identifiable.
PROBES = (
    (64, 256, 128, {"bm": 64, "bn": 128, "kb": 32}),   # 1 step
    (64, 256, 128, {"bm": 64, "bn": 128, "kb": 8}),    # 4 steps
    (64, 256, 128, {"bm": 64, "bn": 128, "kb": 2}),    # 16 steps
    (64, 256, 128, {"bm": 32, "bn": 64, "kb": 8}),     # 16 steps, retiled
    (64, 256, 128, {"bm": 16, "bn": 32, "kb": 4}),     # 128 steps
    (128, 512, 256, {"bm": 128, "bn": 256, "kb": 64}),  # 1 big step
    (128, 512, 256, {"bm": 64, "bn": 128, "kb": 16}),   # 16 big steps
)


def measure_probes(*, reps: int = 9, warmup: int = 1) -> list:
    """Measure the probe set: ``[{macs, bytes, steps, t_s}, ...]`` with
    ``t_s`` the min-of-k wall time (noise-robust, see xla_utils)."""
    from repro.kernels import ops
    from repro.kernels.autotune import matmul_cost_terms

    out = []
    for m, k, n, tiles in PROBES:
        k1, k2 = jax.random.split(jax.random.PRNGKey(0))
        a = jax.random.normal(k1, (m, k), jnp.float32)
        dw = dbb_encode(jax.random.normal(k2, (k, n), jnp.float32),
                        _PROBE_FMT, prune=True)
        fn = lambda a=a, dw=dw, t=tiles: ops.vdbb_matmul(a, dw, **t)
        t_us = min(time_samples_us(fn, warmup=warmup, reps=reps))
        macs, bytes_, steps = matmul_cost_terms(m, k, n, _PROBE_FMT, tiles, 4.0)
        out.append({"macs": macs, "bytes": bytes_, "steps": steps,
                    "t_s": t_us * 1e-6})
    return out


def fit_calibration(probes, backend: Optional[str] = None) -> Calibration:
    """Fit the linear surrogate ``t ≈ macs/peak + bytes/bw + steps·ovh``
    to measured probes (pure — unit-testable with synthetic probes).

    Plain least squares, then negative coefficients are zeroed and the
    remaining columns refit (one active-set pass); a zeroed /
    unidentifiable term keeps its datasheet default, so the returned
    constants are always finite and positive.
    """
    import numpy as np

    backend = backend or jax.default_backend()
    X = np.array([[p["macs"], p["bytes"], p["steps"]] for p in probes], float)
    t = np.array([p["t_s"] for p in probes], float)
    if len(probes) < 3 or not np.all(np.isfinite(X)) or not np.all(np.isfinite(t)):
        return default_calibration(backend)
    active = [0, 1, 2]
    coef = np.zeros(3)
    for _ in range(3):  # at most 3 columns can drop
        c, *_ = np.linalg.lstsq(X[:, active], t, rcond=None)
        if np.all(c >= 0):
            coef[:] = 0.0
            coef[active] = c
            break
        active = [a for a, ci in zip(active, c) if ci >= 0]
        if not active:
            return default_calibration(backend)
    pred = X @ coef
    with np.errstate(divide="ignore", invalid="ignore"):
        rel = (pred - t) / np.where(t > 0, t, 1.0)
    residual = float(np.sqrt(np.mean(rel**2)))
    d = default_calibration(backend)
    return Calibration(
        backend=backend,
        peak_macs=1.0 / coef[0] if coef[0] > 0 else d.peak_macs,
        hbm_bw=1.0 / coef[1] if coef[1] > 0 else d.hbm_bw,
        step_overhead_s=coef[2] if coef[2] > 0 else d.step_overhead_s,
        residual=residual,
        source="fit",
    )


def calibrate(cache=None, *, reps: int = 9, warmup: int = 1,
              force: bool = False, save: bool = True) -> Calibration:
    """Resolve (or measure) this backend's calibration and install it.

    Cache hits skip the probe run entirely (``force=True`` re-measures);
    the result lands in the in-process active table either way, so every
    subsequent ``modeled_*_cost`` call — and therefore the autotuner's
    pruning — uses it.
    """
    from repro.kernels.autotune import TuneCache

    if not isinstance(cache, TuneCache):
        cache = TuneCache(cache)
    backend = jax.default_backend()
    if not force:
        hit = from_entry(cache.calibration.get(backend))
        if hit is not None:
            set_active(hit)
            return hit
    cal = fit_calibration(measure_probes(reps=reps, warmup=warmup), backend)
    set_active(cal)
    cache.calibration[backend] = to_entry(cal)
    if save:
        cache.save()
    return cal


def main() -> None:
    """``python -m repro.kernels.calibrate`` — fit and persist."""
    cal = calibrate(force=True)
    print(f"backend={cal.backend} source={cal.source}")
    print(f"  peak_macs       {cal.peak_macs:.3e} MAC/s")
    print(f"  hbm_bw          {cal.hbm_bw:.3e} B/s")
    print(f"  step_overhead   {cal.step_overhead_s * 1e6:.2f} us/step")
    print(f"  rms rel residual {cal.residual:.3f}")


if __name__ == "__main__":
    main()
