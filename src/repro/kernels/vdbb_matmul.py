"""Pallas TPU kernel for the time-unrolled VDBB sparse matmul.

Two modes, mirroring DESIGN.md §2:

* ``tc`` (tile-coupled / group-shared patterns, ``fmt.group == 'matrix'``):
  the activation "mux" of the paper's S8DP1 lane becomes an in-VMEM one-hot
  contraction that builds a *compressed-K* activation tile; the MAC stream
  becomes a dense MXU matmul over K_c = K·nnz/bz. FLOPs *and* HBM weight
  bytes scale with nnz/bz, at full MXU utilization for any nnz — the
  "constant utilization, variable occupancy" property.

* ``bw`` (paper-faithful per-column patterns): compressed weights are
  expanded to a dense block inside VMEM right before the dot (the analogue
  of the mux sitting right before the MAC). HBM weight traffic scales with
  nnz/bz; compute stays dense. This is the variant that matches the ASIC's
  storage format bit-for-bit.

Both kernels are built on :mod:`repro.kernels.core` — the shared
output-stationary VMEM accumulator with the K-block grid dimension
innermost (the systolic array's output-stationary dataflow).

Both accept int8 operands (the ASIC's native precision, DESIGN.md §8):
integer inputs switch the whole pipeline — one-hot mux, MXU dots, OS
accumulator — to exact int32 arithmetic. The full layer epilogue fuses
into the accumulator flush (DESIGN.md §9): per-output-column ``scales``
(dequantization, int32 → fp32 · scale), ``bias``, ``relu``, and
``out_scale`` (requantize-to-int8 at the next layer's activation scale) —
exactly where the hardware's requantizer sits, so a whole serving layer
is one kernel with no standalone fp32 passes after it. Without any
epilogue the raw int32 accumulator is returned.

Tiling taxonomy (paper's A×B×C_M×N → BlockSpec): bm×bn is the TPE array
footprint (output tile), bz=B is the block size, kb is how many blocks
stream per grid step. MXU alignment wants bm, bn multiples of 128 and
kb·nnz (tc) / kb·bz (bw) multiples of the lane width on real hardware;
interpret mode (CPU validation) accepts any shapes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.vdbb import DBBFormat
from repro.kernels import core


def _check_compressed_operands(a, values, fmt):
    m, k = a.shape
    nb, nnz, n = values.shape
    if nb * fmt.bz != k:
        raise ValueError(f"K={k} != nb*bz = {nb}*{fmt.bz}")
    if nnz != fmt.nnz:
        raise ValueError(f"values nnz={nnz} != fmt.nnz={fmt.nnz}")
    return m, k, nb, n


# ---------------------------------------------------------------------------
# tc mode: gather-compressed-K (group-shared pattern)
# ---------------------------------------------------------------------------


def _vdbb_tc_kernel(a_ref, v_ref, idx_ref, *rest, bz, nnz, kb, ep=None):
    """Grid: (M/bm, N/bn, NB/kb). a: (bm, kb*bz); v: (kb*nnz, bn);
    idx: (kb, nnz) int32; acc: (bm, bn) f32/i32 VMEM scratch; ``rest``
    carries the optional (1, bn) fp32 epilogue rows named by the static
    ``ep`` (scale/bias/out_scale — DESIGN.md §9)."""
    flush, o_ref, acc_ref = core.split_epilogue(ep, rest)
    bm = a_ref.shape[0]
    pref = core.acc_dtype_for(a_ref.dtype)  # int32 for int8 operands
    a = a_ref[...].reshape(bm, kb, bz)
    idx = idx_ref[...]  # (kb, nnz)
    # The activation mux: one-hot gather A[:, k, idx[k, j]] -> (bm, kb, nnz).
    onehot = jax.nn.one_hot(idx, bz, dtype=a.dtype)  # (kb, nnz, bz)
    ac = jax.lax.dot_general(
        a,
        onehot,
        dimension_numbers=(((2,), (2,)), ((1,), (0,))),
        preferred_element_type=pref,
    )  # (kb, bm, nnz)
    # exact cast back: gathered values are the original int8/float operands
    ac = ac.transpose(1, 0, 2).reshape(bm, kb * nnz).astype(a.dtype)
    contrib = jax.lax.dot(
        ac, v_ref[...].astype(a.dtype), preferred_element_type=pref
    )
    core.os_accumulate(acc_ref, o_ref, contrib, grid_axis=2, **flush)


def vdbb_matmul_tc(
    a: jax.Array,
    values: jax.Array,
    indices: jax.Array,
    fmt: DBBFormat,
    *,
    scales: jax.Array | None = None,
    bias: jax.Array | None = None,
    relu: bool = False,
    out_scale=None,
    bm: int | None = None,
    bn: int | None = None,
    kb: int | None = None,
    out_dtype=None,
    interpret: bool = True,
) -> jax.Array:
    """A (M, K) × compressed W -> (M, N). values: (nb, nnz, N);
    indices: (nb, nnz) int (pattern shared across N). int8 operands
    accumulate in exact int32; ``scales`` (N,) / ``bias`` (N,) / ``relu``
    / ``out_scale`` (scalar or (N,)) fuse the layer epilogue into the
    accumulator flush (DESIGN.md §9; out int8 when requantizing). Default
    tiles fall back to the largest dividing size (``core.pick_tile``)."""
    m, k, nb, n = _check_compressed_operands(a, values, fmt)
    bz, nnz = fmt.bz, fmt.nnz
    tuned = {}
    if bm is None and bn is None and kb is None:
        tuned = core.lookup_tiles(
            core.KIND_MATMUL_TC, core.matmul_sig(m, k, n, bz, nnz, a.dtype)
        ) or {}
    bm = core.resolve_or_pick(m, bm, 128, "bm", tuned=tuned.get("bm"))
    bn = core.resolve_or_pick(n, bn, 256, "bn", tuned=tuned.get("bn"))
    kb = core.resolve_or_pick(nb, kb, 16, "kb", tuned=tuned.get("kb"))
    v2 = values.reshape(nb * nnz, n)
    idx = indices.astype(jnp.int32)
    acc_dtype = core.acc_dtype_for(a.dtype)
    ep, e_ops, e_specs, out_dtype = core.epilogue_plan(
        n, bn, scales=scales, bias=bias, relu=relu, out_scale=out_scale,
        acc_dtype=acc_dtype, in_dtype=a.dtype, out_dtype=out_dtype,
    )
    return core.os_matmul_call(
        functools.partial(_vdbb_tc_kernel, bz=bz, nnz=nnz, kb=kb, ep=ep),
        (a, v2, idx, *e_ops),
        m=m,
        n=n,
        bm=bm,
        bn=bn,
        k_steps=nb // kb,
        in_specs=[
            pl.BlockSpec((bm, kb * bz), lambda i, j, s: (i, s)),
            pl.BlockSpec((kb * nnz, bn), lambda i, j, s: (s, j)),
            pl.BlockSpec((kb, nnz), lambda i, j, s: (s, 0)),
            *e_specs,
        ],
        out_dtype=out_dtype,
        acc_dtype=acc_dtype,
        interpret=interpret,
    )


# ---------------------------------------------------------------------------
# bw mode: in-VMEM expand (paper-faithful per-column pattern)
# ---------------------------------------------------------------------------


def dbb_expand_block(v, idx, bz):
    """In-VMEM scatter-expand of a compressed (kb, nnz, bn) block to dense
    (kb*bz, bn) — the "late mux" right before the MAC:
    wd[k, i, n] = sum_j [idx[k, j, n] == i] * v[k, j, n].

    Dtype-preserving (int8 stays int8: positions within a block-column are
    distinct, so each output element receives at most one non-zero)."""
    kb, nnz, bn = v.shape
    i_iota = jax.lax.broadcasted_iota(jnp.int32, (kb, bz, nnz, bn), 1)
    sel = (idx[:, None, :, :] == i_iota).astype(v.dtype)
    wd = (sel * v[:, None, :, :]).sum(axis=2).astype(v.dtype)  # (kb, bz, bn)
    return wd.reshape(kb * bz, bn)


def _vdbb_bw_kernel(a_ref, v_ref, idx_ref, *rest, bz, nnz, kb, ep=None):
    """Grid: (M/bm, N/bn, NB/kb). a: (bm, kb*bz); v: (kb*nnz, bn);
    idx: (kb*nnz, bn) int32 — per-column patterns; ``rest`` carries the
    optional (1, bn) fp32 epilogue rows named by ``ep`` (DESIGN.md §9)."""
    flush, o_ref, acc_ref = core.split_epilogue(ep, rest)
    bn = o_ref.shape[1]
    v = v_ref[...].reshape(kb, nnz, bn)
    idx = idx_ref[...].reshape(kb, nnz, bn)
    wd = dbb_expand_block(v, idx, bz)
    contrib = jax.lax.dot(
        a_ref[...],
        wd.astype(a_ref.dtype),
        preferred_element_type=core.acc_dtype_for(a_ref.dtype),
    )
    core.os_accumulate(acc_ref, o_ref, contrib, grid_axis=2, **flush)


def vdbb_matmul_bw(
    a: jax.Array,
    values: jax.Array,
    indices: jax.Array,
    fmt: DBBFormat,
    *,
    scales: jax.Array | None = None,
    bias: jax.Array | None = None,
    relu: bool = False,
    out_scale=None,
    bm: int | None = None,
    bn: int | None = None,
    kb: int | None = None,
    out_dtype=None,
    interpret: bool = True,
) -> jax.Array:
    """A (M, K) × compressed W -> (M, N). values/indices: (nb, nnz, N).
    int8 + epilogue (``scales``/``bias``/``relu``/``out_scale``) as in
    :func:`vdbb_matmul_tc`."""
    m, k, nb, n = _check_compressed_operands(a, values, fmt)
    bz, nnz = fmt.bz, fmt.nnz
    tuned = {}
    if bm is None and bn is None and kb is None:
        tuned = core.lookup_tiles(
            core.KIND_MATMUL_BW, core.matmul_sig(m, k, n, bz, nnz, a.dtype)
        ) or {}
    bm = core.resolve_or_pick(m, bm, 128, "bm", tuned=tuned.get("bm"))
    bn = core.resolve_or_pick(n, bn, 256, "bn", tuned=tuned.get("bn"))
    kb = core.resolve_or_pick(nb, kb, 8, "kb", tuned=tuned.get("kb"))
    v2 = values.reshape(nb * nnz, n)
    idx2 = indices.astype(jnp.int32).reshape(nb * nnz, n)
    acc_dtype = core.acc_dtype_for(a.dtype)
    ep, e_ops, e_specs, out_dtype = core.epilogue_plan(
        n, bn, scales=scales, bias=bias, relu=relu, out_scale=out_scale,
        acc_dtype=acc_dtype, in_dtype=a.dtype, out_dtype=out_dtype,
    )
    return core.os_matmul_call(
        functools.partial(_vdbb_bw_kernel, bz=bz, nnz=nnz, kb=kb, ep=ep),
        (a, v2, idx2, *e_ops),
        m=m,
        n=n,
        bm=bm,
        bn=bn,
        k_steps=nb // kb,
        in_specs=[
            pl.BlockSpec((bm, kb * bz), lambda i, j, s: (i, s)),
            pl.BlockSpec((kb * nnz, bn), lambda i, j, s: (s, j)),
            pl.BlockSpec((kb * nnz, bn), lambda i, j, s: (s, j)),
            *e_specs,
        ],
        out_dtype=out_dtype,
        acc_dtype=acc_dtype,
        interpret=interpret,
    )
