"""Pallas TPU kernel for the time-unrolled VDBB sparse matmul.

Two modes, mirroring DESIGN.md §2:

* ``tc`` (tile-coupled / group-shared patterns, ``fmt.group == 'matrix'``):
  the activation "mux" of the paper's S8DP1 lane becomes an in-VMEM one-hot
  contraction that builds a *compressed-K* activation tile; the MAC stream
  becomes a dense MXU matmul over K_c = K·nnz/bz. FLOPs *and* HBM weight
  bytes scale with nnz/bz, at full MXU utilization for any nnz — the
  "constant utilization, variable occupancy" property.

* ``bw`` (paper-faithful per-column patterns): compressed weights are
  expanded to a dense block inside VMEM right before the dot (the analogue
  of the mux sitting right before the MAC). HBM weight traffic scales with
  nnz/bz; compute stays dense. This is the variant that matches the ASIC's
  storage format bit-for-bit.

Both kernels are built on :mod:`repro.kernels.core` — the shared
output-stationary VMEM accumulator with the K-block grid dimension
innermost (the systolic array's output-stationary dataflow).

Both accept int8 operands (the ASIC's native precision, DESIGN.md §8):
integer inputs switch the whole pipeline — one-hot mux, MXU dots, OS
accumulator — to exact int32 arithmetic, and the optional per-output-column
``scales`` operand fuses the dequantization into the accumulator flush
(int32 → fp32 · scale), which is where the hardware's requantizer sits.
Without ``scales`` the raw int32 accumulator is returned.

Tiling taxonomy (paper's A×B×C_M×N → BlockSpec): bm×bn is the TPE array
footprint (output tile), bz=B is the block size, kb is how many blocks
stream per grid step. MXU alignment wants bm, bn multiples of 128 and
kb·nnz (tc) / kb·bz (bw) multiples of the lane width on real hardware;
interpret mode (CPU validation) accepts any shapes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.vdbb import DBBFormat
from repro.kernels import core


def _check_compressed_operands(a, values, fmt):
    m, k = a.shape
    nb, nnz, n = values.shape
    if nb * fmt.bz != k:
        raise ValueError(f"K={k} != nb*bz = {nb}*{fmt.bz}")
    if nnz != fmt.nnz:
        raise ValueError(f"values nnz={nnz} != fmt.nnz={fmt.nnz}")
    return m, k, nb, n


# ---------------------------------------------------------------------------
# tc mode: gather-compressed-K (group-shared pattern)
# ---------------------------------------------------------------------------


def _split_refs(rest):
    """(s_ref | None, o_ref, acc_ref) — the optional dequant-scales operand
    rides last in the input list when present (quantized path)."""
    if len(rest) == 3:
        return rest
    return (None, *rest)


def _vdbb_tc_kernel(a_ref, v_ref, idx_ref, *rest, bz, nnz, kb):
    """Grid: (M/bm, N/bn, NB/kb). a: (bm, kb*bz); v: (kb*nnz, bn);
    idx: (kb, nnz) int32; acc: (bm, bn) f32/i32 VMEM scratch; optional
    s: (1, bn) fp32 dequant scales (int8 path)."""
    s_ref, o_ref, acc_ref = _split_refs(rest)
    bm = a_ref.shape[0]
    pref = core.acc_dtype_for(a_ref.dtype)  # int32 for int8 operands
    a = a_ref[...].reshape(bm, kb, bz)
    idx = idx_ref[...]  # (kb, nnz)
    # The activation mux: one-hot gather A[:, k, idx[k, j]] -> (bm, kb, nnz).
    onehot = jax.nn.one_hot(idx, bz, dtype=a.dtype)  # (kb, nnz, bz)
    ac = jax.lax.dot_general(
        a,
        onehot,
        dimension_numbers=(((2,), (2,)), ((1,), (0,))),
        preferred_element_type=pref,
    )  # (kb, bm, nnz)
    # exact cast back: gathered values are the original int8/float operands
    ac = ac.transpose(1, 0, 2).reshape(bm, kb * nnz).astype(a.dtype)
    contrib = jax.lax.dot(
        ac, v_ref[...].astype(a.dtype), preferred_element_type=pref
    )
    scale = s_ref[...] if s_ref is not None else None
    core.os_accumulate(acc_ref, o_ref, contrib, grid_axis=2, scale=scale)


def _quant_operands(a, scales, out_dtype, bn):
    """Resolve the int8-path extras: accumulator dtype, default out dtype
    (fp32 with fused dequant, raw int32 without), and the (1, N) scales
    operand + BlockSpec to append when ``scales`` is given."""
    acc = core.acc_dtype_for(a.dtype)
    if scales is not None:
        ops = [scales.astype(jnp.float32).reshape(1, -1)]
        specs = [pl.BlockSpec((1, bn), lambda i, j, s: (0, j))]
        out = out_dtype or jnp.float32
    else:
        ops, specs = [], []
        out = out_dtype or (jnp.int32 if acc == jnp.int32 else a.dtype)
    return acc, out, ops, specs


def vdbb_matmul_tc(
    a: jax.Array,
    values: jax.Array,
    indices: jax.Array,
    fmt: DBBFormat,
    *,
    scales: jax.Array | None = None,
    bm: int = 128,
    bn: int = 256,
    kb: int = 16,
    out_dtype=None,
    interpret: bool = True,
) -> jax.Array:
    """A (M, K) × compressed W -> (M, N). values: (nb, nnz, N);
    indices: (nb, nnz) int (pattern shared across N). int8 operands
    accumulate in exact int32; ``scales`` (N,) fuses dequantization into
    the accumulator flush (out fp32)."""
    m, k, nb, n = _check_compressed_operands(a, values, fmt)
    bz, nnz = fmt.bz, fmt.nnz
    bm = core.resolve_tile(m, bm, "bm")
    bn = core.resolve_tile(n, bn, "bn")
    kb = core.resolve_tile(nb, kb, "kb")
    v2 = values.reshape(nb * nnz, n)
    idx = indices.astype(jnp.int32)
    acc_dtype, out_dtype, s_ops, s_specs = _quant_operands(a, scales, out_dtype, bn)
    return core.os_matmul_call(
        functools.partial(_vdbb_tc_kernel, bz=bz, nnz=nnz, kb=kb),
        (a, v2, idx, *s_ops),
        m=m,
        n=n,
        bm=bm,
        bn=bn,
        k_steps=nb // kb,
        in_specs=[
            pl.BlockSpec((bm, kb * bz), lambda i, j, s: (i, s)),
            pl.BlockSpec((kb * nnz, bn), lambda i, j, s: (s, j)),
            pl.BlockSpec((kb, nnz), lambda i, j, s: (s, 0)),
            *s_specs,
        ],
        out_dtype=out_dtype,
        acc_dtype=acc_dtype,
        interpret=interpret,
    )


# ---------------------------------------------------------------------------
# bw mode: in-VMEM expand (paper-faithful per-column pattern)
# ---------------------------------------------------------------------------


def dbb_expand_block(v, idx, bz):
    """In-VMEM scatter-expand of a compressed (kb, nnz, bn) block to dense
    (kb*bz, bn) — the "late mux" right before the MAC:
    wd[k, i, n] = sum_j [idx[k, j, n] == i] * v[k, j, n].

    Dtype-preserving (int8 stays int8: positions within a block-column are
    distinct, so each output element receives at most one non-zero)."""
    kb, nnz, bn = v.shape
    i_iota = jax.lax.broadcasted_iota(jnp.int32, (kb, bz, nnz, bn), 1)
    sel = (idx[:, None, :, :] == i_iota).astype(v.dtype)
    wd = (sel * v[:, None, :, :]).sum(axis=2).astype(v.dtype)  # (kb, bz, bn)
    return wd.reshape(kb * bz, bn)


def _vdbb_bw_kernel(a_ref, v_ref, idx_ref, *rest, bz, nnz, kb):
    """Grid: (M/bm, N/bn, NB/kb). a: (bm, kb*bz); v: (kb*nnz, bn);
    idx: (kb*nnz, bn) int32 — per-column patterns; optional s: (1, bn)
    fp32 dequant scales (int8 path)."""
    s_ref, o_ref, acc_ref = _split_refs(rest)
    bn = o_ref.shape[1]
    v = v_ref[...].reshape(kb, nnz, bn)
    idx = idx_ref[...].reshape(kb, nnz, bn)
    wd = dbb_expand_block(v, idx, bz)
    contrib = jax.lax.dot(
        a_ref[...],
        wd.astype(a_ref.dtype),
        preferred_element_type=core.acc_dtype_for(a_ref.dtype),
    )
    scale = s_ref[...] if s_ref is not None else None
    core.os_accumulate(acc_ref, o_ref, contrib, grid_axis=2, scale=scale)


def vdbb_matmul_bw(
    a: jax.Array,
    values: jax.Array,
    indices: jax.Array,
    fmt: DBBFormat,
    *,
    scales: jax.Array | None = None,
    bm: int = 128,
    bn: int = 256,
    kb: int = 8,
    out_dtype=None,
    interpret: bool = True,
) -> jax.Array:
    """A (M, K) × compressed W -> (M, N). values/indices: (nb, nnz, N).
    int8 operands accumulate in exact int32; ``scales`` (N,) fuses
    dequantization into the accumulator flush (out fp32)."""
    m, k, nb, n = _check_compressed_operands(a, values, fmt)
    bz, nnz = fmt.bz, fmt.nnz
    bm = core.resolve_tile(m, bm, "bm")
    bn = core.resolve_tile(n, bn, "bn")
    kb = core.resolve_tile(nb, kb, "kb")
    v2 = values.reshape(nb * nnz, n)
    idx2 = indices.astype(jnp.int32).reshape(nb * nnz, n)
    acc_dtype, out_dtype, s_ops, s_specs = _quant_operands(a, scales, out_dtype, bn)
    return core.os_matmul_call(
        functools.partial(_vdbb_bw_kernel, bz=bz, nnz=nnz, kb=kb),
        (a, v2, idx2, *s_ops),
        m=m,
        n=n,
        bm=bm,
        bn=bn,
        k_steps=nb // kb,
        in_specs=[
            pl.BlockSpec((bm, kb * bz), lambda i, j, s: (i, s)),
            pl.BlockSpec((kb * nnz, bn), lambda i, j, s: (s, j)),
            pl.BlockSpec((kb * nnz, bn), lambda i, j, s: (s, j)),
            *s_specs,
        ],
        out_dtype=out_dtype,
        acc_dtype=acc_dtype,
        interpret=interpret,
    )
