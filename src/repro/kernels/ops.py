"""Jit'd public wrappers around the Pallas kernels with mode dispatch.

``interpret`` defaults to True unless a real TPU backend is present, so the
same call sites validate on CPU and run compiled on TPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.vdbb import DBBFormat, DBBWeight
from repro.kernels import im2col_conv as _im2col
from repro.kernels import vdbb_matmul as _vm


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("bm", "bn", "kb", "interpret"))
def vdbb_matmul(
    a: jax.Array,
    w: DBBWeight,
    *,
    bm: int = 128,
    bn: int = 256,
    kb: int = 8,
    interpret: bool | None = None,
) -> jax.Array:
    """A (M, K) @ compressed DBB W (K, N) -> (M, N). Dispatches tc vs bw on
    the weight's pattern-sharing mode."""
    interpret = _default_interpret() if interpret is None else interpret
    n = w.shape[1]
    if w.fmt.group_size(n) == n:
        return _vm.vdbb_matmul_tc(
            a, w.values, w.indices[:, :, 0], w.fmt, bm=bm, bn=bn, kb=kb, interpret=interpret
        )
    if w.fmt.group_size(n) != 1:
        # grouped-but-not-matrix: expand indices per column, use bw kernel.
        idx = jnp.repeat(w.indices, w.fmt.group_size(n), axis=2)
        return _vm.vdbb_matmul_bw(a, w.values, idx, w.fmt, bm=bm, bn=bn, kb=kb, interpret=interpret)
    return _vm.vdbb_matmul_bw(
        a, w.values, w.indices, w.fmt, bm=bm, bn=bn, kb=kb, interpret=interpret
    )


@functools.partial(jax.jit, static_argnames=("bf", "interpret"))
def fused_im2col_conv(
    x: jax.Array, w: jax.Array, *, bf: int = 128, interpret: bool | None = None
) -> jax.Array:
    """Fused im2col+GEMM 'SAME' stride-1 conv (NHWC / HWIO)."""
    interpret = _default_interpret() if interpret is None else interpret
    return _im2col.im2col_conv(x, w, bf=bf, interpret=interpret)
