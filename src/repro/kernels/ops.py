"""Jit'd public wrappers around the Pallas kernels with mode dispatch.

``interpret`` defaults to True unless a real TPU backend is present (see
kernels/core.py), so the same call sites validate on CPU and run compiled
on TPU.

Dtype dispatch (DESIGN.md §8): the same entry points accept fp32/bf16 or
int8 operands. Integer operands run the int8 datapath — exact int32 OS
accumulation — and return the raw int32 accumulator; the quantized
end-to-end path (`quant_matmul` / `quant_conv`) additionally quantizes the
fp activation per-tensor and fuses the dequantization into the accumulator
flush via the kernels' ``scales`` operand.

Epilogue fusion (DESIGN.md §9): every entry point takes ``bias=``,
``relu=`` and ``out_scale=`` and folds them into the accumulator flush —
one kernel per layer. ``out_scale`` (the *next* layer's activation scale)
requantizes the flush to int8, so inter-layer activations stay
int8-resident; the quantized entry points also accept an **int8** input
(already-quantized codes from the previous layer's epilogue) together
with its ``act_scale``, skipping the per-layer quantize pass entirely.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.quant import QuantDBBWeight, resolve_quant_input
from repro.core.vdbb import DBBFormat, DBBWeight
from repro.kernels import core
from repro.kernels import im2col_conv as _im2col
from repro.kernels import vdbb_im2col_conv as _vconv
from repro.kernels import vdbb_matmul as _vm


def _default_interpret() -> bool:
    return core.default_interpret()


def _pad_epilogue_row(v, n, n_pad, fill=0.0):
    """Pad a per-output-column epilogue vector out to the padded N (scalars
    broadcast unchanged; ``fill`` must be non-zero for ``out_scale`` so the
    sliced-away columns never divide by zero)."""
    if v is None:
        return None
    v = jnp.asarray(v, jnp.float32)
    if v.ndim == 0:
        return v
    return jnp.pad(v.reshape(-1), (0, n_pad - n), constant_values=fill)


def _matmul_dispatch(a, w, scales, bm, bn, kb, interpret, *, bias=None,
                     relu=False, out_scale=None):
    """tc vs bw on the weight's pattern-sharing mode (shared by the fp,
    raw-int8 and quantized entry points).

    Tile resolution is permissive here (the ops layer): default tiles come
    from the autotune registry when a measured-best config is installed for
    this launch signature, and explicit/tuned ``bm``/``bn`` that do not
    divide M/N take the pad-to-tile path — the ragged edge is zero-padded
    and sliced back off, which is exact (padded rows/columns contribute
    nothing; padded ``out_scale`` columns divide by 1 and are discarded).
    ``kb`` stays an exact divisor of the K-block count. The kernel-level
    wrappers keep the strict divisibility contract.
    """
    m, k = a.shape
    n = w.shape[1]
    fmt = w.fmt
    g = fmt.group_size(n)
    tc = g == n
    kind = core.KIND_MATMUL_TC if tc else core.KIND_MATMUL_BW
    if bm is None and bn is None and kb is None:
        tuned = core.lookup_tiles(
            kind, core.matmul_sig(m, k, n, fmt.bz, fmt.nnz, a.dtype)
        ) or {}
        bm, bn, kb = tuned.get("bm"), tuned.get("bn"), tuned.get("kb")
        if kb is not None and (k // fmt.bz) % kb != 0:
            kb = None  # a tuned K tile must divide exactly; fall back
    bm, mp = core.pad_tile(m, bm, 128)
    bn, n_pad = core.pad_tile(n, bn, 256)
    if mp != m:
        a = jnp.pad(a, ((0, mp - m), (0, 0)))
    values = w.values
    if tc:
        idx = w.indices[:, :, 0]
    elif g != 1:
        # grouped-but-not-matrix: expand indices per column, use bw kernel.
        idx = jnp.repeat(w.indices, g, axis=2)
    else:
        idx = w.indices
    if n_pad != n:
        values = jnp.pad(values, ((0, 0), (0, 0), (0, n_pad - n)))
        if not tc:
            idx = jnp.pad(idx, ((0, 0), (0, 0), (0, n_pad - n)))
        scales = _pad_epilogue_row(scales, n, n_pad)
        bias = _pad_epilogue_row(bias, n, n_pad)
        out_scale = _pad_epilogue_row(out_scale, n, n_pad, fill=1.0)
    kw = dict(scales=scales, bias=bias, relu=relu, out_scale=out_scale,
              bm=bm, bn=bn, kb=kb, interpret=interpret)
    fn = _vm.vdbb_matmul_tc if tc else _vm.vdbb_matmul_bw
    y = fn(a, values, idx, fmt, **kw)
    if mp != m or n_pad != n:
        y = y[:m, :n]
    return y


@functools.partial(jax.jit, static_argnames=("relu", "bm", "bn", "kb", "interpret"))
def vdbb_matmul(
    a: jax.Array,
    w: DBBWeight,
    *,
    bias: jax.Array | None = None,
    relu: bool = False,
    out_scale=None,
    bm: int | None = None,
    bn: int | None = None,
    kb: int | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """A (M, K) @ compressed DBB W (K, N) -> (M, N). Dispatches tc vs bw on
    the weight's pattern-sharing mode, and on operand dtype: int8 operands
    run the int32-accumulator datapath and return the raw int32
    accumulator (quantized end-to-end: :func:`quant_matmul`). ``bias`` /
    ``relu`` / ``out_scale`` fuse the fp epilogue into the flush
    (DESIGN.md §9; int8 out when requantizing)."""
    interpret = _default_interpret() if interpret is None else interpret
    return _matmul_dispatch(a, w, None, bm, bn, kb, interpret, bias=bias,
                            relu=relu, out_scale=out_scale)


@functools.partial(jax.jit, static_argnames=("relu", "bm", "bn", "kb", "interpret"))
def quant_matmul(
    x: jax.Array,
    qw: QuantDBBWeight,
    act_scale: jax.Array | None = None,
    *,
    bias: jax.Array | None = None,
    relu: bool = False,
    out_scale=None,
    bm: int | None = None,
    bn: int | None = None,
    kb: int | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """X (M, K) × int8-quantized compressed W -> fp32 (M, N), or int8 when
    ``out_scale`` is given.

    ``x`` may be fp (quantized per-tensor with ``act_scale`` from
    calibration, or dynamically when None) or already int8 (the previous
    layer's requantized codes; ``act_scale`` then required). The whole
    epilogue — dequant (``act_scale · w_scale[n]``), ``bias``, ``relu``,
    requantize at ``out_scale`` — runs fused on the accumulator flush
    (DESIGN.md §9), so one call is one kernel with zero standalone fp32
    passes.
    """
    interpret = _default_interpret() if interpret is None else interpret
    xq, s_a = resolve_quant_input(x, act_scale)
    scales = s_a * qw.scales
    return _matmul_dispatch(xq, qw.as_dbb(), scales, bm, bn, kb, interpret,
                            bias=bias, relu=relu, out_scale=out_scale)


def sparse_matmul(
    a: jax.Array,
    w: DBBWeight,
    *,
    act_fmt: DBBFormat | None = None,
    **kw,
) -> jax.Array:
    """:func:`vdbb_matmul` with optional structural activation gating.

    ``act_fmt`` (DESIGN.md §7) projects the activations onto the
    block-wise top-|x| DBB constraint (pattern shared across the M tile)
    before the kernel — the activation-side twin of the weight format,
    typically ``act_fmt(measure_activation(a))`` from
    :mod:`repro.core.act_sparsity`. Pruned activations flow through the
    tc kernel's compressed-K contraction unchanged.
    """
    if act_fmt is not None:
        from repro.core.act_sparsity import act_dbb_prune

        a = act_dbb_prune(a, act_fmt)
    return vdbb_matmul(a, w, **kw)


@functools.partial(
    jax.jit,
    static_argnames=("relu", "stride", "padding", "bf", "tile_h", "tile_w", "interpret"),
)
def fused_im2col_conv(
    x: jax.Array,
    w: jax.Array,
    *,
    bias: jax.Array | None = None,
    relu: bool = False,
    out_scale=None,
    stride=1,
    padding="SAME",
    bf: int | None = None,
    tile_h: int | None = None,
    tile_w: int | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """Fused im2col+GEMM conv (NHWC / HWIO), dense weights. ``bias`` /
    ``relu`` / ``out_scale`` fuse the layer epilogue into the flush
    (DESIGN.md §9) — with ``out_scale`` the fp32 stem of an int8-resident
    model emits int8 directly."""
    interpret = _default_interpret() if interpret is None else interpret
    return _im2col.im2col_conv(
        x, w, bias=bias, relu=relu, out_scale=out_scale, stride=stride,
        padding=padding, bf=bf, tile_h=tile_h, tile_w=tile_w, interpret=interpret,
    )


@functools.partial(
    jax.jit,
    static_argnames=("kh", "kw", "relu", "stride", "padding", "bf", "tile_h", "tile_w", "interpret"),
)
def sparse_conv(
    x: jax.Array,
    w: DBBWeight,
    kh: int,
    kw: int,
    *,
    bias: jax.Array | None = None,
    relu: bool = False,
    out_scale=None,
    stride=1,
    padding="SAME",
    bf: int | None = None,
    tile_h: int | None = None,
    tile_w: int | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """Fused IM2COL × VDBB sparse conv over a compressed DBB conv weight
    (K = kh·kw·C along the reduction). Dispatches tc vs bw on the weight's
    pattern-sharing mode — the paper's full datapath in one call. int8
    operands return the raw int32 accumulator (quantized end-to-end:
    :func:`quant_conv`); ``bias`` / ``relu`` / ``out_scale`` fuse the fp
    epilogue into the flush (DESIGN.md §9; int8 out when requantizing)."""
    interpret = _default_interpret() if interpret is None else interpret
    return _vconv.vdbb_im2col_conv(
        x, w, kh, kw, bias=bias, relu=relu, out_scale=out_scale,
        stride=stride, padding=padding, bf=bf, tile_h=tile_h, tile_w=tile_w,
        interpret=interpret,
    )


@functools.partial(
    jax.jit,
    static_argnames=("kh", "kw", "relu", "stride", "padding", "bf", "tile_h", "tile_w", "interpret"),
)
def quant_conv(
    x: jax.Array,
    qw: QuantDBBWeight,
    kh: int,
    kw: int,
    act_scale: jax.Array | None = None,
    *,
    bias: jax.Array | None = None,
    relu: bool = False,
    out_scale=None,
    stride=1,
    padding="SAME",
    bf: int | None = None,
    tile_h: int | None = None,
    tile_w: int | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """NHWC × int8-quantized compressed conv weight -> fp32 NHWC, or int8
    NHWC when ``out_scale`` is given.

    The conv twin of :func:`quant_matmul`: fp input is quantized
    per-tensor (calibrated ``act_scale`` or dynamic); int8 input is the
    previous layer's requantized codes (int8-resident chaining, zero-
    padding is exact under the symmetric scheme). Dequantization, bias,
    ReLU and the requantize at ``out_scale`` all fuse into the
    accumulator flush — one kernel per conv layer (DESIGN.md §9).
    """
    interpret = _default_interpret() if interpret is None else interpret
    xq, s_a = resolve_quant_input(x, act_scale)
    return _vconv.vdbb_im2col_conv(
        xq, qw.as_dbb(), kh, kw, scales=s_a * qw.scales, bias=bias, relu=relu,
        out_scale=out_scale, stride=stride, padding=padding, bf=bf,
        tile_h=tile_h, tile_w=tile_w, interpret=interpret,
    )


def _drop_jit_caches() -> None:
    """Drop every entry point's jit cache. Registered with the kernel core
    as the tuned-registry invalidation hook: default-tile traces capture
    registry lookups at trace time, so any registry change must force a
    retrace (DESIGN.md §10)."""
    for f in (vdbb_matmul, quant_matmul, fused_im2col_conv, sparse_conv,
              quant_conv):
        clear = getattr(f, "clear_cache", None)
        if callable(clear):
            try:
                clear()
            except Exception:  # noqa: BLE001 — cache drop is best-effort
                pass


core.register_invalidation_hook(_drop_jit_caches)
