"""Jit'd public wrappers around the Pallas kernels with mode dispatch.

``interpret`` defaults to True unless a real TPU backend is present (see
kernels/core.py), so the same call sites validate on CPU and run compiled
on TPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.vdbb import DBBFormat, DBBWeight
from repro.kernels import core
from repro.kernels import im2col_conv as _im2col
from repro.kernels import vdbb_im2col_conv as _vconv
from repro.kernels import vdbb_matmul as _vm


def _default_interpret() -> bool:
    return core.default_interpret()


@functools.partial(jax.jit, static_argnames=("bm", "bn", "kb", "interpret"))
def vdbb_matmul(
    a: jax.Array,
    w: DBBWeight,
    *,
    bm: int = 128,
    bn: int = 256,
    kb: int = 8,
    interpret: bool | None = None,
) -> jax.Array:
    """A (M, K) @ compressed DBB W (K, N) -> (M, N). Dispatches tc vs bw on
    the weight's pattern-sharing mode."""
    interpret = _default_interpret() if interpret is None else interpret
    n = w.shape[1]
    if w.fmt.group_size(n) == n:
        return _vm.vdbb_matmul_tc(
            a, w.values, w.indices[:, :, 0], w.fmt, bm=bm, bn=bn, kb=kb, interpret=interpret
        )
    if w.fmt.group_size(n) != 1:
        # grouped-but-not-matrix: expand indices per column, use bw kernel.
        idx = jnp.repeat(w.indices, w.fmt.group_size(n), axis=2)
        return _vm.vdbb_matmul_bw(a, w.values, idx, w.fmt, bm=bm, bn=bn, kb=kb, interpret=interpret)
    return _vm.vdbb_matmul_bw(
        a, w.values, w.indices, w.fmt, bm=bm, bn=bn, kb=kb, interpret=interpret
    )


def sparse_matmul(
    a: jax.Array,
    w: DBBWeight,
    *,
    act_fmt: DBBFormat | None = None,
    **kw,
) -> jax.Array:
    """:func:`vdbb_matmul` with optional structural activation gating.

    ``act_fmt`` (DESIGN.md §7) projects the activations onto the
    block-wise top-|x| DBB constraint (pattern shared across the M tile)
    before the kernel — the activation-side twin of the weight format,
    typically ``act_fmt(measure_activation(a))`` from
    :mod:`repro.core.act_sparsity`. Pruned activations flow through the
    tc kernel's compressed-K contraction unchanged.
    """
    if act_fmt is not None:
        from repro.core.act_sparsity import act_dbb_prune

        a = act_dbb_prune(a, act_fmt)
    return vdbb_matmul(a, w, **kw)


@functools.partial(
    jax.jit,
    static_argnames=("stride", "padding", "bf", "tile_h", "tile_w", "interpret"),
)
def fused_im2col_conv(
    x: jax.Array,
    w: jax.Array,
    *,
    stride=1,
    padding="SAME",
    bf: int = 128,
    tile_h: int | None = None,
    tile_w: int | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """Fused im2col+GEMM conv (NHWC / HWIO), dense weights."""
    interpret = _default_interpret() if interpret is None else interpret
    return _im2col.im2col_conv(
        x, w, stride=stride, padding=padding, bf=bf,
        tile_h=tile_h, tile_w=tile_w, interpret=interpret,
    )


@functools.partial(
    jax.jit,
    static_argnames=("kh", "kw", "stride", "padding", "bf", "tile_h", "tile_w", "interpret"),
)
def sparse_conv(
    x: jax.Array,
    w: DBBWeight,
    kh: int,
    kw: int,
    *,
    stride=1,
    padding="SAME",
    bf: int = 128,
    tile_h: int | None = None,
    tile_w: int | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """Fused IM2COL × VDBB sparse conv over a compressed DBB conv weight
    (K = kh·kw·C along the reduction). Dispatches tc vs bw on the weight's
    pattern-sharing mode — the paper's full datapath in one call."""
    interpret = _default_interpret() if interpret is None else interpret
    return _vconv.vdbb_im2col_conv(
        x, w, kh, kw, stride=stride, padding=padding, bf=bf,
        tile_h=tile_h, tile_w=tile_w, interpret=interpret,
    )
