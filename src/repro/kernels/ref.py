"""Pure-jnp oracles for every kernel in this package."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.vdbb import (  # noqa: F401  (re-exported oracles)
    DBBFormat,
    DBBWeight,
    dbb_decode,
    dbb_matmul_gather_ref,
    dbb_matmul_ref,
)


def vdbb_matmul_ref(a: jax.Array, values: jax.Array, indices: jax.Array, fmt: DBBFormat):
    """Oracle shared by tc and bw kernels: expand-to-dense then matmul.

    values: (nb, nnz, N); indices: (nb, nnz) [tc, shared pattern] or
    (nb, nnz, N) [bw, per-column].
    """
    import dataclasses

    nb, nnz, n = values.shape
    if indices.ndim == 2:
        indices = jnp.broadcast_to(indices[:, :, None], (nb, nnz, n))
    # decode with per-column semantics regardless of the sharing mode the
    # kernel used (shared patterns are just repeated columns).
    fmt_pc = dataclasses.replace(fmt, group=None)
    dw = DBBWeight(values, indices.astype(jnp.int8), fmt_pc, (nb * fmt.bz, n))
    return jnp.matmul(a, dbb_decode(dw).astype(a.dtype))


def im2col_explicit(x: jax.Array, kh: int, kw: int) -> jax.Array:
    """Explicit im2col producing the duplicated (N, H, W, kh*kw*C) tensor —
    the memory-footprint blow-up the hardware unit avoids."""
    n, h, w, c = x.shape
    ph, pw = kh // 2, kw // 2
    xp = jnp.pad(x, ((0, 0), (ph, ph), (pw, pw), (0, 0)))
    cols = [
        xp[:, dy : dy + h, dx : dx + w, :] for dy in range(kh) for dx in range(kw)
    ]
    return jnp.concatenate(cols, axis=-1)


def im2col_conv_ref(x: jax.Array, w: jax.Array) -> jax.Array:
    """Conv as explicit im2col + GEMM (the baseline the kernel beats)."""
    kh, kw, c, f = w.shape
    cols = im2col_explicit(x, kh, kw)  # (N, H, W, kh*kw*C)
    return jnp.einsum(
        "nhwk,kf->nhwf", cols, w.transpose(0, 1, 2, 3).reshape(kh * kw * c, f)
    ).astype(x.dtype)


def conv_lax_ref(x: jax.Array, w: jax.Array) -> jax.Array:
    """XLA native conv oracle (NHWC, HWIO, SAME, stride 1)."""
    return jax.lax.conv_general_dilated(
        x,
        w,
        window_strides=(1, 1),
        padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    ).astype(x.dtype)
