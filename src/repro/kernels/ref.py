"""Pure-jnp oracles for every kernel in this package."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.core import _pair
from repro.core.vdbb import (  # noqa: F401  (re-exported oracles)
    DBBFormat,
    DBBWeight,
    dbb_decode,
    dbb_decode_conv,
    dbb_encode_conv,
    dbb_matmul_gather_ref,
    dbb_matmul_ref,
)


def vdbb_matmul_ref(a: jax.Array, values: jax.Array, indices: jax.Array, fmt: DBBFormat):
    """Oracle shared by tc and bw kernels: expand-to-dense then matmul.

    values: (nb, nnz, N); indices: (nb, nnz) [tc, shared pattern] or
    (nb, nnz, N) [bw, per-column].
    """
    import dataclasses

    nb, nnz, n = values.shape
    if indices.ndim == 2:
        indices = jnp.broadcast_to(indices[:, :, None], (nb, nnz, n))
    # decode with per-column semantics regardless of the sharing mode the
    # kernel used (shared patterns are just repeated columns).
    fmt_pc = dataclasses.replace(fmt, group=None)
    dw = DBBWeight(values, indices.astype(jnp.int8), fmt_pc, (nb * fmt.bz, n))
    return jnp.matmul(a, dbb_decode(dw).astype(a.dtype))


def vdbb_matmul_int_ref(a: jax.Array, values: jax.Array, indices: jax.Array,
                        fmt: DBBFormat) -> jax.Array:
    """Integer oracle for the int8 tc/bw kernels: expand the int8 compressed
    weight to dense and accumulate in exact int32 — the raw OS accumulator
    the hardware produces before requantization (DESIGN.md §8).

    a: (M, K) int8; values: (nb, nnz, N) int8; indices as in
    :func:`vdbb_matmul_ref`. Returns (M, N) int32, bit-exact.
    """
    import dataclasses

    nb, nnz, n = values.shape
    if indices.ndim == 2:
        indices = jnp.broadcast_to(indices[:, :, None], (nb, nnz, n))
    fmt_pc = dataclasses.replace(fmt, group=None)
    dw = DBBWeight(values, indices.astype(jnp.int8), fmt_pc, (nb * fmt.bz, n))
    return jnp.matmul(a.astype(jnp.int32), dbb_decode(dw).astype(jnp.int32))


def quant_epilogue_ref(acc: jax.Array, scale, *, bias=None, relu=False,
                       out_scale=None) -> jax.Array:
    """Integer-oracle layer epilogue (DESIGN.md §9): the exact fp32 ops the
    kernels fuse into the accumulator flush, in dataflow order —
    dequantize → bias → ReLU → requantize-to-int8.

    ``acc``: raw int32 OS accumulator (last axis = output channels);
    ``scale``: fused dequant ``act_scale · w_scale[n]``, broadcast on the
    last axis; ``out_scale``: the next layer's activation scale — when
    given the result is int8 codes in ±127, bit-exact against the fused
    kernels. Without it the fp32 epilogue output is returned.
    """
    y = acc.astype(jnp.float32) * scale
    if bias is not None:
        y = y + bias.astype(jnp.float32)
    if relu:
        y = jnp.maximum(y, 0.0)
    if out_scale is not None:
        # ±127 == quant.QMAX == kernels.core.QMAX (the symmetric int8 range)
        return jnp.clip(jnp.round(y / out_scale), -127, 127).astype(jnp.int8)
    return y


def im2col_explicit(x: jax.Array, kh: int, kw: int, *, stride=1, padding="SAME") -> jax.Array:
    """Explicit im2col producing the duplicated (N, Ho, Wo, kh*kw*C) tensor —
    the memory-footprint blow-up the hardware unit avoids."""
    from repro.kernels.core import conv_geometry

    n, h, w, c = x.shape
    (sh, sw), (ph, pw), (ho, wo) = conv_geometry(h, w, kh, kw, stride, padding)
    xp = jnp.pad(x, ((0, 0), ph, pw, (0, 0)))
    cols = [
        xp[:, dy : dy + (ho - 1) * sh + 1 : sh, dx : dx + (wo - 1) * sw + 1 : sw, :]
        for dy in range(kh)
        for dx in range(kw)
    ]
    return jnp.concatenate(cols, axis=-1)


def im2col_conv_ref(x: jax.Array, w: jax.Array, *, stride=1, padding="SAME") -> jax.Array:
    """Conv as explicit im2col + GEMM (the baseline the kernel beats)."""
    kh, kw, c, f = w.shape
    cols = im2col_explicit(x, kh, kw, stride=stride, padding=padding)
    return jnp.einsum("nhwk,kf->nhwf", cols, w.reshape(kh * kw * c, f)).astype(x.dtype)


def conv_lax_ref(x: jax.Array, w: jax.Array, *, stride=1, padding="SAME") -> jax.Array:
    """XLA native conv oracle (NHWC, HWIO)."""
    return jax.lax.conv_general_dilated(
        x,
        w,
        window_strides=_pair(stride),
        padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    ).astype(x.dtype)


def sparse_conv_ref(x: jax.Array, dw: DBBWeight, kh: int, kw: int, *, stride=1,
                    padding="SAME") -> jax.Array:
    """Oracle for the fused IM2COL × VDBB kernel: decode the compressed conv
    weight to dense (kh, kw, C, F) and run the XLA conv."""
    w4 = dbb_decode_conv(dw, kh, kw).astype(x.dtype)
    return conv_lax_ref(x, w4, stride=stride, padding=padding)


def sparse_conv_int_ref(x: jax.Array, dw: DBBWeight, kh: int, kw: int, *,
                        stride=1, padding="SAME") -> jax.Array:
    """Integer oracle for the int8 fused conv kernels: dtype-preserving
    explicit im2col (pad/slice/concat) + exact int32 GEMM over the decoded
    int8 weight. x: (N, H, W, C) int8; returns (N, Ho, Wo, F) int32."""
    cols = im2col_explicit(x, kh, kw, stride=stride, padding=padding)
    n, ho, wo, kk = cols.shape
    w2 = dbb_decode(dw).astype(jnp.int32)  # (K, F)
    acc = jnp.matmul(cols.reshape(-1, kk).astype(jnp.int32), w2)
    return acc.reshape(n, ho, wo, -1)
