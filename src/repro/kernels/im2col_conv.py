"""Fused IM2COL + GEMM Pallas kernel — the paper's "bandwidth magnifier".

The paper's hardware IM2COL unit sits *after* SRAM, expanding the activation
stream 3× right before the datapath so the SRAM never stores or re-reads the
im2col-duplicated pixels. The TPU-native analogue: read the raw (H, W, C)
activation tile from HBM exactly once into VMEM and materialize the im2col
expansion only as *shifted views* feeding the MXU — the conv becomes
kh·kw shifted (HW, C)×(C, F) matmuls accumulated output-stationary.

HBM activation traffic: H·W·C  (vs kh·kw·H·W·C for explicit im2col+GEMM,
i.e. 9× less for 3×3 — the paper reports 3× average SRAM-read reduction for
their 6×2 line buffer; a full-tile VMEM buffer does strictly better).

Layout: NHWC input (pre-padded), HWIO weights, stride 1.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _im2col_conv_kernel(x_ref, w_ref, o_ref, acc_ref, *, kh, kw, ho, wo):
    """Grid: (N, F/bf). x: (1, ho+kh-1, wo+kw-1, C); w: (kh, kw, C, bf)."""
    c = x_ref.shape[-1]
    bf = o_ref.shape[-1]
    acc_ref[...] = jnp.zeros_like(acc_ref)
    x = x_ref[0]
    # In-VMEM im2col: kh*kw shifted views, each a dense (ho*wo, C) x (C, bf)
    # MXU matmul. The expansion never touches HBM.
    for dy in range(kh):
        for dx in range(kw):
            patch = x[dy : dy + ho, dx : dx + wo, :].reshape(ho * wo, c)
            acc_ref[...] += jax.lax.dot(
                patch,
                w_ref[dy, dx],
                preferred_element_type=jnp.float32,
            )
    o_ref[...] = acc_ref[...].reshape(1, ho, wo, bf).astype(o_ref.dtype)


def im2col_conv(
    x: jax.Array,
    w: jax.Array,
    *,
    bf: int = 128,
    interpret: bool = True,
) -> jax.Array:
    """'SAME'-padded stride-1 conv. x: (N, H, W, C); w: (kh, kw, C, F)."""
    n, h, wd, c = x.shape
    kh, kw, wc, f = w.shape
    assert wc == c and kh % 2 == 1 and kw % 2 == 1
    ph, pw = kh // 2, kw // 2
    xp = jnp.pad(x, ((0, 0), (ph, ph), (pw, pw), (0, 0)))
    bf = min(bf, f)
    assert f % bf == 0
    grid = (n, f // bf)
    return pl.pallas_call(
        functools.partial(_im2col_conv_kernel, kh=kh, kw=kw, ho=h, wo=wd),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, h + kh - 1, wd + kw - 1, c), lambda i, j: (i, 0, 0, 0)),
            pl.BlockSpec((kh, kw, c, bf), lambda i, j: (0, 0, 0, j)),
        ],
        out_specs=pl.BlockSpec((1, h, wd, bf), lambda i, j: (i, 0, 0, j)),
        out_shape=jax.ShapeDtypeStruct((n, h, wd, f), x.dtype),
        scratch_shapes=[pltpu.VMEM((h * wd, bf), jnp.float32)],
        interpret=interpret,
    )(xp, w)
