"""Fused IM2COL + GEMM Pallas kernel — the paper's "bandwidth magnifier".

The paper's hardware IM2COL unit sits *after* SRAM, expanding the activation
stream 3× right before the datapath so the SRAM never stores or re-reads the
im2col-duplicated pixels. The TPU-native analogue: read the raw (H, W, C)
activation tile from HBM exactly once into VMEM and materialize the im2col
expansion only as *shifted views* feeding the MXU — the conv becomes
kh·kw shifted (HW, C)×(C, F) matmuls accumulated output-stationary.

HBM activation traffic: H·W·C  (vs kh·kw·H·W·C for explicit im2col+GEMM,
i.e. 9× less for 3×3 — the paper reports 3× average SRAM-read reduction for
their 6×2 line buffer; a full-tile VMEM buffer does strictly better).

Layout: NHWC input, HWIO weights. Strides, even kernels, SAME/VALID/
explicit padding and spatial H×W output tiling (bounded VMEM for large
feature maps) are all supported; geometry and the shifted-view tap come
from :mod:`repro.kernels.core` (DESIGN.md §6). The kernel tap (dy, dx) is
the innermost grid axis, so the shared output-stationary accumulator
pattern applies unchanged.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import core


def plan_conv(x, kh, kw, *, stride, padding, tile_h=None, tile_w=None):
    """Host-side conv planning shared by the dense and VDBB fused kernels.

    Pads ``x`` to the exact input footprint, extracts halo'd spatial tiles
    (no-op when untiled), and returns ``(tiles, geom)`` where geom carries
    every static the kernels and BlockSpecs need.
    """
    n, h, w, c = x.shape
    (sh, sw), (ph, pw), (ho, wo) = core.conv_geometry(h, w, kh, kw, stride, padding)
    bh = core.resolve_tile(ho, tile_h or ho, "tile_h")
    bw = core.resolve_tile(wo, tile_w or wo, "tile_w")
    th, tw = ho // bh, wo // bw
    need_h = (ho - 1) * sh + kh
    need_w = (wo - 1) * sw + kw
    xp = jnp.pad(
        x,
        (
            (0, 0),
            (ph[0], max(ph[1], need_h - h - ph[0])),
            (pw[0], max(pw[1], need_w - w - pw[0])),
            (0, 0),
        ),
    )[:, :need_h, :need_w, :]
    xt = core.extract_conv_tiles(xp, bh=bh, bw=bw, sh=sh, sw=sw, kh=kh, kw=kw, th=th, tw=tw)
    geom = dict(
        n=n, c=c, ho=ho, wo=wo, sh=sh, sw=sw, bh=bh, bw=bw, th=th, tw=tw,
        bh_in=(bh - 1) * sh + kh, bw_in=(bw - 1) * sw + kw, kh=kh, kw=kw,
    )
    return xt, geom


def conv_out_spec(geom, bf):
    """Output BlockSpec: one (1, bh, bw, bf) tile of the (N, Ho, Wo, F) map."""
    th, tw = geom["th"], geom["tw"]
    return pl.BlockSpec(
        (1, geom["bh"], geom["bw"], bf),
        lambda p, j, t: (p // (th * tw), (p % (th * tw)) // tw, p % tw, j),
    )


def _im2col_conv_kernel(x_ref, w_ref, *rest, kw, sh, sw, bh, bw, ep=None):
    """Grid: (N·th·tw, F/bf, kh·kw). x: (1, bh_in, bw_in, C); w: (1, C, bf).
    One kernel tap per innermost grid step — the shifted-view im2col;
    ``rest`` carries the optional (1, bf) fp32 epilogue rows named by the
    static ``ep`` (scale/bias/out_scale — DESIGN.md §9)."""
    flush, o_ref, acc_ref = core.split_epilogue(ep, rest)
    t = pl.program_id(2)
    patch = core.conv_patch(x_ref[0], t // kw, t % kw, bh=bh, bw=bw, sh=sh, sw=sw)
    contrib = jax.lax.dot(
        patch,
        w_ref[0].astype(patch.dtype),
        preferred_element_type=core.acc_dtype_for(patch.dtype),
    )
    core.os_accumulate(acc_ref, o_ref, contrib, grid_axis=2, **flush)


def im2col_conv(
    x: jax.Array,
    w: jax.Array,
    *,
    scales: jax.Array | None = None,
    bias: jax.Array | None = None,
    relu: bool = False,
    out_scale=None,
    stride=1,
    padding="SAME",
    bf: int | None = None,
    tile_h: int | None = None,
    tile_w: int | None = None,
    interpret: bool | None = True,
) -> jax.Array:
    """Fused im2col conv. x: (N, H, W, C); w: (kh, kw, C, F). The optional
    epilogue (``scales``/``bias``/``relu``/``out_scale``, DESIGN.md §9)
    fuses the layer's bias + ReLU + requantize-to-int8 into the flush, so
    even the fp32 stem of an int8-resident model is one kernel."""
    n, h, wd, c = x.shape
    kh, kw, wc, f = w.shape
    if wc != c:
        raise ValueError(f"channel mismatch: x has {c}, w has {wc}")
    if bf is None and tile_h is None and tile_w is None:
        (sh, sw), _, (ho, wo) = core.conv_geometry(h, wd, kh, kw, stride, padding)
        sig = core.conv_sig(n, ho, wo, c, f, kh, kw, sh, sw, 0, 0, x.dtype)
        bf, tile_h, tile_w = core.tuned_conv_tiles(core.KIND_CONV_DENSE, sig, ho, wo, f)
    xt, g = plan_conv(x, kh, kw, stride=stride, padding=padding, tile_h=tile_h, tile_w=tile_w)
    bf = core.resolve_or_pick(f, bf, 128, "bf")
    w3 = w.reshape(kh * kw, c, f)
    grid = (n * g["th"] * g["tw"], f // bf, kh * kw)
    acc_dtype = core.acc_dtype_for(x.dtype)  # int32 on the int8 path (§8)
    ep, e_ops, e_specs, out_dtype = core.epilogue_plan(
        f, bf, scales=scales, bias=bias, relu=relu, out_scale=out_scale,
        acc_dtype=acc_dtype, in_dtype=x.dtype,
    )
    return pl.pallas_call(
        functools.partial(
            _im2col_conv_kernel, kw=kw, sh=g["sh"], sw=g["sw"], bh=g["bh"],
            bw=g["bw"], ep=ep,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, g["bh_in"], g["bw_in"], c), lambda p, j, t: (p, 0, 0, 0)),
            pl.BlockSpec((1, c, bf), lambda p, j, t: (t, 0, j)),
            *e_specs,
        ],
        out_specs=conv_out_spec(g, bf),
        out_shape=jax.ShapeDtypeStruct((n, g["ho"], g["wo"], f), out_dtype),
        scratch_shapes=[pltpu.VMEM((g["bh"] * g["bw"], bf), acc_dtype)],
        interpret=core.resolve_interpret(interpret),
    )(xt, w3, *e_ops)
