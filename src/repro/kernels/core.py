"""Shared kernel-core for every Pallas kernel in this package.

All kernels in this repo are instances of one scheme — the systolic
array's *output-stationary* dataflow (DESIGN.md §2, §6):

* an accumulator tile (fp32, or exact int32 on the int8 operand path —
  DESIGN.md §8) lives in VMEM scratch for the lifetime of one output tile;
* the reduction (K) dimension is the *innermost* grid axis, so the
  accumulator is initialized on the first K step and flushed to the
  output ref on the last;
* every other grid axis picks an output tile.

This module owns that plumbing once: the init/accumulate/store pattern
(:func:`os_accumulate`), the fused flush epilogue (:class:`Epilogue` /
:func:`epilogue_plan` / :func:`split_epilogue` — dequant scale, bias, ReLU,
requantize-to-int8, all executed once where the hardware's requantizer
sits, DESIGN.md §9), K-innermost grid construction and the fp32 VMEM
scratch + output BlockSpec boilerplate (:func:`os_matmul_call`), tile-size
resolution (:func:`resolve_tile` strict / :func:`pick_tile` permissive),
and interpret-mode dispatch (:func:`default_interpret` — kernels validate
in interpret mode on CPU and compile unchanged on TPU).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

QMAX = 127  # symmetric int8 clip range for the requantize epilogue
            # (mirrors repro.core.quant.QMAX; kernels.core deliberately
            # keeps zero repro-internal imports)


def default_interpret() -> bool:
    """Interpret (CPU validation) unless a real TPU backend is present."""
    return jax.default_backend() != "tpu"


def resolve_interpret(interpret: bool | None) -> bool:
    return default_interpret() if interpret is None else bool(interpret)


def resolve_tile(dim: int, tile: int, name: str = "tile") -> int:
    """Clamp a requested tile size to the dimension and check divisibility."""
    t = min(tile, dim)
    if t <= 0 or dim % t != 0:
        raise ValueError(f"{name}={tile} does not tile dimension {dim}")
    return t


def pick_tile(dim: int, tile: int) -> int:
    """Largest divisor of ``dim`` that is <= ``tile`` — the permissive
    fallback for *default* tile sizes, so odd CNN shapes (e.g. M = N·Ho·Wo
    not a multiple of 128) work without hand-tuned tiles at every call
    site. Explicit tile requests keep :func:`resolve_tile`'s strict
    divisibility contract.

    When no usable divisor exists near the default (e.g. a prime dim),
    a sub-sublane tile would launch a pathological 1-wide grid; the whole
    dimension becomes one tile instead — correct everywhere, and far
    better than t=1 on real hardware. Dimensions too large for a single
    VMEM tile *and* without divisors still want an explicit tile.
    """
    t = max(1, min(tile, dim))
    while dim % t:
        t -= 1
    if t < 8 <= dim:
        return dim
    return t


def resolve_or_pick(dim: int, tile, default: int, name: str,
                    tuned: int | None = None) -> int:
    """``tile`` is None → the ``tuned`` size from the autotune registry when
    it divides, else :func:`pick_tile` of the default; otherwise the strict
    :func:`resolve_tile` (an explicit request that does not divide is still
    a caller error)."""
    if tile is None:
        if tuned is not None and 0 < tuned <= dim and dim % tuned == 0:
            return int(tuned)
        return pick_tile(dim, default)
    return resolve_tile(dim, tile, name)


def pick_tile_padded(dim: int, tile: int) -> tuple:
    """``(t, padded_dim)`` — :func:`pick_tile` when it lands on a usable
    divisor; otherwise the requested tile with the ragged edge zero-padded
    (the caller pads the operand to ``padded_dim`` and slices the result).

    This is the fix for the divisor-fallback pathology: a dimension like
    2·p (p prime) has no divisor near the default, and :func:`pick_tile`'s
    whole-dimension fallback builds one enormous VMEM tile. Padding to the
    requested tile keeps the grid shape sane at the cost of (padded-dim)/dim
    wasted compute — exact everywhere, since padded rows/columns are zero.
    """
    t = pick_tile(dim, tile)
    if tile // 4 <= t <= 2 * tile or t == dim <= 2 * tile:
        return t, dim
    t = min(tile, dim)
    return t, -(-dim // t) * t


def pad_tile(dim: int, tile, default: int) -> tuple:
    """Permissive ops-level tile resolution with a zero-pad escape hatch.

    ``(t, padded_dim)``: None → :func:`pick_tile_padded` of the default;
    an explicit tile is clamped to the dimension, and one that does not
    divide pads the ragged edge instead of raising (so autotuner
    candidates are not restricted to exact divisors). Kernel-level
    wrappers keep :func:`resolve_tile`'s strict contract; only the
    ``ops.*`` entry points pad-and-slice.
    """
    if tile is None:
        return pick_tile_padded(dim, default)
    t = max(1, min(int(tile), dim))
    return t, -(-dim // t) * t


# ---------------------------------------------------------------------------
# Tuned-tile registry (populated by repro.kernels.autotune; kernels.core
# deliberately keeps zero repro-internal imports, so the registry is a plain
# dict the autotuner writes into and the kernel entry points read from)
# ---------------------------------------------------------------------------

KIND_MATMUL_TC = "matmul_tc"
KIND_MATMUL_BW = "matmul_bw"
KIND_CONV_TC = "conv_tc"
KIND_CONV_BW = "conv_bw"
KIND_CONV_DENSE = "conv_dense"

_TUNED: dict = {}


def matmul_sig(m: int, k: int, n: int, bz: int, nnz: int, dtype) -> tuple:
    """Shape signature of one matmul-shaped launch (kernel kind carried
    separately): everything tile validity and performance depend on."""
    return (int(m), int(k), int(n), int(bz), int(nnz), str(jnp.dtype(dtype)))


def conv_sig(n: int, ho: int, wo: int, c: int, f: int, kh: int, kw: int,
             sh: int, sw: int, bz: int, nnz: int, dtype) -> tuple:
    """Shape signature of one fused-conv launch (``bz = nnz = 0`` for the
    dense kernel). Output geometry (ho, wo) subsumes the padding mode."""
    return (int(n), int(ho), int(wo), int(c), int(f), int(kh), int(kw),
            int(sh), int(sw), int(bz), int(nnz), str(jnp.dtype(dtype)))


def lookup_tiles(kind: str, sig: tuple) -> Optional[dict]:
    """Measured-best tile config for (kind, sig), or None when untuned."""
    return _TUNED.get((kind, sig))


def tuned_conv_tiles(kind: str, sig: tuple, ho: int, wo: int, f: int) -> tuple:
    """``(bf, tile_h, tile_w)`` from the registry, each component used only
    when it divides its dimension (conv spatial/F tiles stay exact — the
    pad-and-slice escape hatch is matmul-only); None components fall back
    to the callers' defaults."""
    t = lookup_tiles(kind, sig) or {}

    def ok(v, dim):
        return int(v) if v and dim % int(v) == 0 else None

    return ok(t.get("bf"), f), ok(t.get("tile_h"), ho), ok(t.get("tile_w"), wo)


_INVALIDATION_HOOKS: list = []


def register_invalidation_hook(fn) -> None:
    """Register a callback fired whenever the tuned registry changes.

    Jitted entry points consult the registry only at *trace* time, so a
    registry change must drop their jit caches or live traces keep stale
    tile choices. kernels.core keeps zero repro-internal imports, so the
    ops layer injects its cache-drop here at import.
    """
    if fn not in _INVALIDATION_HOOKS:
        _INVALIDATION_HOOKS.append(fn)


def _invalidate_tuned_consumers() -> None:
    for fn in _INVALIDATION_HOOKS:
        try:
            fn()
        except Exception:  # noqa: BLE001 — cache drop is best-effort
            pass


def set_tuned(kind: str, sig: tuple, tiles: dict) -> None:
    """Install a tuned config; registering an *unchanged* entry is a no-op
    (live traces already use it), anything else invalidates the consumers'
    jit caches so the next call re-consults the registry."""
    entry = {k: int(v) for k, v in tiles.items() if v is not None}
    key = (kind, sig)
    if _TUNED.get(key) == entry:
        return
    _TUNED[key] = entry
    _invalidate_tuned_consumers()


def clear_tuned() -> None:
    if _TUNED:
        _TUNED.clear()
        _invalidate_tuned_consumers()


def acc_dtype_for(operand_dtype) -> jnp.dtype:
    """Accumulator dtype for an operand dtype: exact int32 for integer
    (int8) operands, fp32 otherwise — the two accumulators the hardware
    datapath has (DESIGN.md §8)."""
    if jnp.issubdtype(operand_dtype, jnp.integer):
        return jnp.dtype(jnp.int32)
    return jnp.dtype(jnp.float32)


@dataclasses.dataclass(frozen=True)
class Epilogue:
    """Static plan of the fused accumulator-flush epilogue (DESIGN.md §9).

    Flags name which fused operands ride after the compute operands — in
    (scale, bias, out_scale) order, each a (1, N) fp32 row — plus the
    static ReLU flag. Built host-side by :func:`epilogue_plan`, consumed
    kernel-side by :func:`split_epilogue`; hashable, so it threads into
    kernels via ``functools.partial``.
    """

    has_scale: bool = False
    has_bias: bool = False
    relu: bool = False
    has_out_scale: bool = False

    @property
    def n_operands(self) -> int:
        return int(self.has_scale) + int(self.has_bias) + int(self.has_out_scale)


def epilogue_plan(n: int, bn: int, *, scales=None, bias=None, relu=False,
                  out_scale=None, acc_dtype, in_dtype, out_dtype=None):
    """Resolve the fused-epilogue request into kernel-launch pieces.

    Returns ``(ep, operands, specs, out_dtype)``: the static
    :class:`Epilogue` (None when nothing was requested), the (1, n) fp32
    operand rows (a scalar ``out_scale`` broadcasts across N) with their
    (1, bn) BlockSpecs indexed on the N grid axis, and the resolved output
    dtype — int8 when requantizing, fp32 when scale/bias/ReLU touch the
    accumulator, else the raw accumulator dtype (the pre-epilogue default).
    """
    ep = Epilogue(scales is not None, bias is not None, bool(relu),
                  out_scale is not None)
    operands, specs = [], []
    spec = pl.BlockSpec((1, bn), lambda i, j, s: (0, j))
    for v, present in ((scales, ep.has_scale), (bias, ep.has_bias),
                       (out_scale, ep.has_out_scale)):
        if present:
            row = jnp.asarray(v, jnp.float32).reshape(1, -1)
            operands.append(jnp.broadcast_to(row, (1, n)))
            specs.append(spec)
    if out_dtype is None:
        if ep.has_out_scale:
            out_dtype = jnp.int8
        elif ep.has_scale or ep.has_bias:
            out_dtype = jnp.float32  # dequant/bias move the tile to fp32
        elif acc_dtype == jnp.dtype(jnp.int32):
            out_dtype = jnp.int32  # raw (or relu-only) int32 stays exact
        else:
            out_dtype = in_dtype
    if not (ep.n_operands or ep.relu):
        ep = None
    return ep, operands, specs, out_dtype


def split_epilogue(ep: Epilogue | None, rest):
    """Split a kernel's trailing refs into flush kwargs + (o_ref, acc_ref).

    ``rest`` is ``[*epilogue_refs, o_ref, acc_ref]`` with the epilogue
    refs in (scale, bias, out_scale) order, exactly as
    :func:`epilogue_plan` appended them. Returns ``(flush, o_ref,
    acc_ref)`` where ``flush`` feeds straight into
    ``os_accumulate(..., **flush)``.
    """
    n = ep.n_operands if ep is not None else 0
    refs = list(rest[:n])
    o_ref, acc_ref = rest[n], rest[n + 1]
    flush = dict(
        scale=refs.pop(0)[...] if ep is not None and ep.has_scale else None,
        bias=refs.pop(0)[...] if ep is not None and ep.has_bias else None,
        relu=ep is not None and ep.relu,
    )
    flush["out_scale"] = (
        refs.pop(0)[...] if ep is not None and ep.has_out_scale else None
    )
    return flush, o_ref, acc_ref


def os_accumulate(acc_ref, o_ref, contribution, *, grid_axis: int, scale=None,
                  bias=None, relu: bool = False, out_scale=None):
    """Output-stationary accumulation step.

    Zeroes ``acc_ref`` on the first step of the reduction grid axis
    (``grid_axis``, the innermost one), adds ``contribution`` (fp32 or
    int32, matching the scratch), and flushes to ``o_ref`` on the last
    step. ``contribution`` must have ``acc_ref``'s shape; ``o_ref`` may
    have a different (same-size) shape — e.g. a conv output tile with
    leading batch dim — and the accumulator is reshaped on store.

    The optional epilogue (DESIGN.md §9) runs once on the flush, in
    dataflow order — exactly where the hardware's requantizer sits:

    * ``scale`` (fp32, broadcastable, e.g. a (1, bn) per-output-column
      row): dequantization — the int32 accumulator becomes fp32 · scale.
    * ``bias`` (fp32 row): per-output-channel bias add.
    * ``relu`` (static): clamp at zero.
    * ``out_scale`` (fp32 row): requantize-to-int8 — the next layer's
      activation scale; the store clips round(acc / out_scale) into
      ±QMAX so inter-layer activations stay int8-resident.
    """

    @pl.when(pl.program_id(grid_axis) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += contribution

    @pl.when(pl.program_id(grid_axis) == pl.num_programs(grid_axis) - 1)
    def _store():
        acc = acc_ref[...]
        if scale is not None:
            acc = acc.astype(jnp.float32) * scale
        if bias is not None:
            acc = acc.astype(jnp.float32) + bias
        if relu:
            acc = jnp.maximum(acc, jnp.zeros((), acc.dtype))
        if out_scale is not None:
            acc = jnp.clip(jnp.round(acc.astype(jnp.float32) / out_scale),
                           -QMAX, QMAX)
        o_ref[...] = acc.reshape(o_ref.shape).astype(o_ref.dtype)


# ---------------------------------------------------------------------------
# Conv geometry (shared by the dense and VDBB fused im2col conv kernels)
# ---------------------------------------------------------------------------


def _pair(v):
    if isinstance(v, int):
        return (v, v)
    a, b = v
    return (int(a), int(b))


def conv_geometry(h: int, w: int, kh: int, kw: int, stride, padding):
    """Resolve stride / padding / output size for a 2-D conv.

    ``stride``: int or (sh, sw). ``padding``: 'SAME' | 'VALID' |
    ((top, bottom), (left, right)). Returns
    ``((sh, sw), ((pt, pb), (pl, pr)), (ho, wo))`` with XLA's SAME
    convention (extra padding goes at the end).
    """
    sh, sw = _pair(stride)

    def one(dim, k, s, pad):
        if pad == "SAME":
            o = -(-dim // s)
            total = max((o - 1) * s + k - dim, 0)
            return (total // 2, total - total // 2), o
        if pad == "VALID":
            if dim < k:
                raise ValueError(f"VALID conv: dim {dim} < kernel {k}")
            return (0, 0), (dim - k) // s + 1
        lo, hi = pad
        return (int(lo), int(hi)), (dim + lo + hi - k) // s + 1

    if isinstance(padding, str):
        padding = padding.upper()
        if padding not in ("SAME", "VALID"):
            raise ValueError(f"padding must be 'SAME', 'VALID', or explicit pairs; got {padding!r}")
        (ph, ho), (pw, wo) = one(h, kh, sh, padding), one(w, kw, sw, padding)
    else:
        (ph, ho), (pw, wo) = one(h, kh, sh, padding[0]), one(w, kw, sw, padding[1])
    if ho < 1 or wo < 1:
        raise ValueError(f"empty conv output {(ho, wo)}")
    return (sh, sw), (ph, pw), (ho, wo)


def extract_conv_tiles(xp: jax.Array, *, bh, bw, sh, sw, kh, kw, th, tw):
    """Gather overlapping spatial input tiles (with halo) for a tiled conv.

    ``xp``: padded (N, Hp, Wp, C). Each output tile is bh×bw output pixels;
    its input footprint is ``bh_in × bw_in = ((bh-1)sh+kh) × ((bw-1)sw+kw)``.
    Returns ``(N·th·tw, bh_in, bw_in, C)``. Only the halo (kh-sh rows /
    kw-sw cols per tile seam) is duplicated in HBM — the raw activation
    tile is still read ~once, unlike the kh·kw× blow-up of explicit im2col.
    """
    n, hp, wp, c = xp.shape
    bh_in = (bh - 1) * sh + kh
    bw_in = (bw - 1) * sw + kw
    if th == 1 and tw == 1:
        return xp
    rows = (jnp.arange(th) * (bh * sh))[:, None] + jnp.arange(bh_in)[None]
    cols = (jnp.arange(tw) * (bw * sw))[:, None] + jnp.arange(bw_in)[None]
    t = jnp.take(xp, rows.reshape(-1), axis=1).reshape(n, th, bh_in, wp, c)
    t = jnp.take(t, cols.reshape(-1), axis=3).reshape(n, th, bh_in, tw, bw_in, c)
    return t.transpose(0, 1, 3, 2, 4, 5).reshape(n * th * tw, bh_in, bw_in, c)


def conv_patch(x: jax.Array, dy, dx, *, bh, bw, sh, sw):
    """In-VMEM shifted (strided) view of one kernel tap — the IM2COL unit.

    ``x``: (bh_in, bw_in, C) input tile already resident in VMEM; ``dy, dx``
    may be traced scalars (tap index from ``pl.program_id``). Returns the
    (bh·bw, C) activation matrix for that tap without materializing the
    kh·kw-duplicated im2col tensor anywhere.
    """
    c = x.shape[-1]
    hs = (bh - 1) * sh + 1
    ws = (bw - 1) * sw + 1
    patch = jax.lax.dynamic_slice(x, (dy, dx, 0), (hs, ws, c))
    if sh > 1 or sw > 1:
        patch = jax.lax.slice(patch, (0, 0, 0), (hs, ws, c), (sh, sw, 1))
    return patch.reshape(bh * bw, c)


def os_matmul_call(
    kernel,
    operands: Sequence[jax.Array],
    *,
    m: int,
    n: int,
    bm: int,
    bn: int,
    k_steps: int,
    in_specs: Sequence[pl.BlockSpec],
    out_dtype,
    acc_dtype=jnp.float32,
    interpret: bool | None = None,
):
    """Launch an output-stationary (M, N) matmul-shaped kernel.

    Builds the K-innermost grid ``(m//bm, n//bn, k_steps)``, the ``(bm, bn)``
    output BlockSpec and the VMEM accumulator scratch (fp32, or int32 for
    the int8 operand path — ``acc_dtype``), and invokes ``pl.pallas_call``.
    The kernel receives ``(*operand_refs, o_ref, acc_ref)`` and is expected
    to compute one K-step contribution and hand it to :func:`os_accumulate`
    with ``grid_axis=2``.
    """
    grid = (m // bm, n // bn, k_steps)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=list(in_specs),
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, s: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), acc_dtype)],
        interpret=resolve_interpret(interpret),
    )(*operands)
