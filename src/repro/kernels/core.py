"""Shared kernel-core for every Pallas kernel in this package.

All kernels in this repo are instances of one scheme — the systolic
array's *output-stationary* dataflow (DESIGN.md §2, §6):

* an accumulator tile (fp32, or exact int32 on the int8 operand path —
  DESIGN.md §8) lives in VMEM scratch for the lifetime of one output tile;
* the reduction (K) dimension is the *innermost* grid axis, so the
  accumulator is initialized on the first K step and flushed to the
  output ref on the last;
* every other grid axis picks an output tile.

This module owns that plumbing once: the init/accumulate/store pattern
(:func:`os_accumulate`), K-innermost grid construction and the fp32 VMEM
scratch + output BlockSpec boilerplate (:func:`os_matmul_call`), tile-size
resolution (:func:`resolve_tile`), and interpret-mode dispatch
(:func:`default_interpret` — kernels validate in interpret mode on CPU and
compile unchanged on TPU).
"""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def default_interpret() -> bool:
    """Interpret (CPU validation) unless a real TPU backend is present."""
    return jax.default_backend() != "tpu"


def resolve_interpret(interpret: bool | None) -> bool:
    return default_interpret() if interpret is None else bool(interpret)


def resolve_tile(dim: int, tile: int, name: str = "tile") -> int:
    """Clamp a requested tile size to the dimension and check divisibility."""
    t = min(tile, dim)
    if t <= 0 or dim % t != 0:
        raise ValueError(f"{name}={tile} does not tile dimension {dim}")
    return t


def acc_dtype_for(operand_dtype) -> jnp.dtype:
    """Accumulator dtype for an operand dtype: exact int32 for integer
    (int8) operands, fp32 otherwise — the two accumulators the hardware
    datapath has (DESIGN.md §8)."""
    if jnp.issubdtype(operand_dtype, jnp.integer):
        return jnp.dtype(jnp.int32)
    return jnp.dtype(jnp.float32)


def os_accumulate(acc_ref, o_ref, contribution, *, grid_axis: int, scale=None):
    """Output-stationary accumulation step.

    Zeroes ``acc_ref`` on the first step of the reduction grid axis
    (``grid_axis``, the innermost one), adds ``contribution`` (fp32 or
    int32, matching the scratch), and flushes to ``o_ref`` on the last
    step. ``contribution`` must have ``acc_ref``'s shape; ``o_ref`` may
    have a different (same-size) shape — e.g. a conv output tile with
    leading batch dim — and the accumulator is reshaped on store.

    ``scale`` (optional, fp32, broadcastable to the accumulator tile —
    e.g. a (1, bn) per-output-column row) is the dequantization fused into
    the flush: the int32 accumulator is multiplied once per output element
    exactly where the hardware's requantizer sits (DESIGN.md §8).
    """

    @pl.when(pl.program_id(grid_axis) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += contribution

    @pl.when(pl.program_id(grid_axis) == pl.num_programs(grid_axis) - 1)
    def _store():
        acc = acc_ref[...]
        if scale is not None:
            acc = acc.astype(jnp.float32) * scale
        o_ref[...] = acc.reshape(o_ref.shape).astype(o_ref.dtype)


# ---------------------------------------------------------------------------
# Conv geometry (shared by the dense and VDBB fused im2col conv kernels)
# ---------------------------------------------------------------------------


def _pair(v):
    if isinstance(v, int):
        return (v, v)
    a, b = v
    return (int(a), int(b))


def conv_geometry(h: int, w: int, kh: int, kw: int, stride, padding):
    """Resolve stride / padding / output size for a 2-D conv.

    ``stride``: int or (sh, sw). ``padding``: 'SAME' | 'VALID' |
    ((top, bottom), (left, right)). Returns
    ``((sh, sw), ((pt, pb), (pl, pr)), (ho, wo))`` with XLA's SAME
    convention (extra padding goes at the end).
    """
    sh, sw = _pair(stride)

    def one(dim, k, s, pad):
        if pad == "SAME":
            o = -(-dim // s)
            total = max((o - 1) * s + k - dim, 0)
            return (total // 2, total - total // 2), o
        if pad == "VALID":
            if dim < k:
                raise ValueError(f"VALID conv: dim {dim} < kernel {k}")
            return (0, 0), (dim - k) // s + 1
        lo, hi = pad
        return (int(lo), int(hi)), (dim + lo + hi - k) // s + 1

    if isinstance(padding, str):
        padding = padding.upper()
        if padding not in ("SAME", "VALID"):
            raise ValueError(f"padding must be 'SAME', 'VALID', or explicit pairs; got {padding!r}")
        (ph, ho), (pw, wo) = one(h, kh, sh, padding), one(w, kw, sw, padding)
    else:
        (ph, ho), (pw, wo) = one(h, kh, sh, padding[0]), one(w, kw, sw, padding[1])
    if ho < 1 or wo < 1:
        raise ValueError(f"empty conv output {(ho, wo)}")
    return (sh, sw), (ph, pw), (ho, wo)


def extract_conv_tiles(xp: jax.Array, *, bh, bw, sh, sw, kh, kw, th, tw):
    """Gather overlapping spatial input tiles (with halo) for a tiled conv.

    ``xp``: padded (N, Hp, Wp, C). Each output tile is bh×bw output pixels;
    its input footprint is ``bh_in × bw_in = ((bh-1)sh+kh) × ((bw-1)sw+kw)``.
    Returns ``(N·th·tw, bh_in, bw_in, C)``. Only the halo (kh-sh rows /
    kw-sw cols per tile seam) is duplicated in HBM — the raw activation
    tile is still read ~once, unlike the kh·kw× blow-up of explicit im2col.
    """
    n, hp, wp, c = xp.shape
    bh_in = (bh - 1) * sh + kh
    bw_in = (bw - 1) * sw + kw
    if th == 1 and tw == 1:
        return xp
    rows = (jnp.arange(th) * (bh * sh))[:, None] + jnp.arange(bh_in)[None]
    cols = (jnp.arange(tw) * (bw * sw))[:, None] + jnp.arange(bw_in)[None]
    t = jnp.take(xp, rows.reshape(-1), axis=1).reshape(n, th, bh_in, wp, c)
    t = jnp.take(t, cols.reshape(-1), axis=3).reshape(n, th, bh_in, tw, bw_in, c)
    return t.transpose(0, 1, 3, 2, 4, 5).reshape(n * th * tw, bh_in, bw_in, c)


def conv_patch(x: jax.Array, dy, dx, *, bh, bw, sh, sw):
    """In-VMEM shifted (strided) view of one kernel tap — the IM2COL unit.

    ``x``: (bh_in, bw_in, C) input tile already resident in VMEM; ``dy, dx``
    may be traced scalars (tap index from ``pl.program_id``). Returns the
    (bh·bw, C) activation matrix for that tap without materializing the
    kh·kw-duplicated im2col tensor anywhere.
    """
    c = x.shape[-1]
    hs = (bh - 1) * sh + 1
    ws = (bw - 1) * sw + 1
    patch = jax.lax.dynamic_slice(x, (dy, dx, 0), (hs, ws, c))
    if sh > 1 or sw > 1:
        patch = jax.lax.slice(patch, (0, 0, 0), (hs, ws, c), (sh, sw, 1))
    return patch.reshape(bh * bw, c)


def os_matmul_call(
    kernel,
    operands: Sequence[jax.Array],
    *,
    m: int,
    n: int,
    bm: int,
    bn: int,
    k_steps: int,
    in_specs: Sequence[pl.BlockSpec],
    out_dtype,
    acc_dtype=jnp.float32,
    interpret: bool | None = None,
):
    """Launch an output-stationary (M, N) matmul-shaped kernel.

    Builds the K-innermost grid ``(m//bm, n//bn, k_steps)``, the ``(bm, bn)``
    output BlockSpec and the VMEM accumulator scratch (fp32, or int32 for
    the int8 operand path — ``acc_dtype``), and invokes ``pl.pallas_call``.
    The kernel receives ``(*operand_refs, o_ref, acc_ref)`` and is expected
    to compute one K-step contribution and hand it to :func:`os_accumulate`
    with ``grid_axis=2``.
    """
    grid = (m // bm, n // bn, k_steps)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=list(in_specs),
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, s: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), acc_dtype)],
        interpret=resolve_interpret(interpret),
    )(*operands)
