"""Variable Density-Bound Block (VDBB) sparsity — functional core.

Faithful functional model of the paper's VDBB scheme (Liu, Whatmough,
Mattina 2020): weight matrices are blocked along the reduction dimension K
in blocks of ``bz`` (paper uses 8); each block of each output column holds
at most ``nnz`` non-zero values. Blocks are stored compressed as the nnz
values plus positional indices (the hardware stores a BZ-bit bitmask; we
store int8 positions, which carries identical information).

Two pattern-sharing modes (see DESIGN.md §2):

* ``group=None`` — paper-faithful: each output column has an independent
  pattern per block (the ASIC muxes activations per MAC lane). On TPU this
  yields an HBM-bandwidth win (compressed weight storage) but dense compute.
* ``group=g``   — TPU co-design: all columns within a group of ``g`` share
  one pattern per K-block, so activations can be gathered once per group
  and the matmul runs over the *compressed* K dimension: FLOPs and bytes
  both scale with nnz/bz on the MXU. ``group='matrix'`` shares across all N.

All functions are pure and jit-safe; shapes are static.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

DEFAULT_BZ = 8


# ---------------------------------------------------------------------------
# Format descriptor
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class DBBFormat:
    """Static description of a density-bound-block format.

    Attributes:
      bz:    block size along the reduction (K) dimension.
      nnz:   density bound — max non-zeros per block (1..bz). nnz == bz is
             dense (the VDBB hardware supports it natively; so do we).
      group: pattern-sharing group along N. None = per-column (paper);
             int g = shared across g columns; 'matrix' = shared across N.
    """

    bz: int = DEFAULT_BZ
    nnz: int = DEFAULT_BZ
    group: Optional[Union[int, str]] = None

    def __post_init__(self):
        if not (1 <= self.nnz <= self.bz):
            raise ValueError(f"nnz must be in [1, bz]; got {self.nnz}/{self.bz}")

    @property
    def density(self) -> float:
        return self.nnz / self.bz

    @property
    def sparsity(self) -> float:
        return 1.0 - self.density

    @property
    def is_dense(self) -> bool:
        return self.nnz == self.bz

    def group_size(self, n: int) -> int:
        if self.group is None:
            return 1
        if self.group == "matrix":
            return n
        return int(self.group)

    def compression_ratio(self, bits: int = 8) -> float:
        """Paper §II-A: compressed size = bits*NNZ + BZ per block."""
        return (bits * self.bz) / (bits * self.nnz + self.bz)


DENSE = DBBFormat()


# ---------------------------------------------------------------------------
# Pruning masks
# ---------------------------------------------------------------------------


def _check_blockable(k: int, fmt: DBBFormat):
    if k % fmt.bz != 0:
        raise ValueError(f"K={k} not divisible by block size bz={fmt.bz}")


def dbb_mask(w: jax.Array, fmt: DBBFormat) -> jax.Array:
    """Boolean mask keeping the top-|w| ``nnz`` entries of every DBB block.

    ``w`` has shape (K, N); blocks run along K. With pattern sharing, the
    block score is the sum of |w| across the group (magnitude pruning at
    group granularity), mirroring the paper's magnitude-based DBB pruning
    (§V-A) under the co-designed constraint.
    """
    k, n = w.shape
    _check_blockable(k, fmt)
    if fmt.is_dense:
        return jnp.ones_like(w, dtype=bool)
    nb = k // fmt.bz
    g = fmt.group_size(n)
    if n % g != 0:
        raise ValueError(f"N={n} not divisible by group={g}")
    # (nb, bz, ng) scores; top-nnz positions per (block, group) via top_k so
    # tie-breaking is identical to dbb_encode.
    scores = jnp.abs(w).reshape(nb, fmt.bz, n // g, g).sum(axis=-1)
    _, idx = jax.lax.top_k(scores.transpose(0, 2, 1), fmt.nnz)  # (nb, ng, nnz)
    keep = (
        jax.nn.one_hot(idx, fmt.bz, dtype=jnp.int32).sum(axis=2) > 0
    )  # (nb, ng, bz)
    keep = keep.transpose(0, 2, 1)  # (nb, bz, ng)
    keep = jnp.repeat(keep[:, :, :, None], g, axis=3).reshape(nb, fmt.bz, n)
    return keep.reshape(k, n)


def dbb_prune(w: jax.Array, fmt: DBBFormat) -> jax.Array:
    """Magnitude-prune ``w`` to satisfy the DBB constraint (zero the rest)."""
    return jnp.where(dbb_mask(w, fmt), w, jnp.zeros_like(w))


def satisfies_dbb(w: jax.Array, fmt: DBBFormat) -> jax.Array:
    """True iff every block of every column has <= nnz non-zeros."""
    k, n = w.shape
    _check_blockable(k, fmt)
    nz = (w.reshape(k // fmt.bz, fmt.bz, n) != 0).sum(axis=1)
    return jnp.all(nz <= fmt.nnz)


# ---------------------------------------------------------------------------
# Compressed representation
# ---------------------------------------------------------------------------


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class DBBWeight:
    """Compressed DBB weight.

    values:  (nb, nnz, N)  — non-zero values, zero-padded if a block has
             fewer than nnz non-zeros (paper §II-A: "blocks that have less
             than NNZ non-zero elements will include one or more zeros").
    indices: (nb, nnz, NG) int8 — intra-block positions in [0, bz).
             NG = N / group_size (1 column group per entry).
    fmt:     static DBBFormat.
    shape:   static dense shape (K, N).
    """

    values: jax.Array
    indices: jax.Array
    fmt: DBBFormat
    shape: tuple

    def tree_flatten(self):
        return (self.values, self.indices), (self.fmt, self.shape)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], children[1], aux[0], aux[1])

    @property
    def dtype(self):
        return self.values.dtype

    def nbytes_compressed(self) -> int:
        """Stored bytes: values + bitmask (bz bits per block-group)."""
        vb = int(np.prod(self.values.shape)) * self.values.dtype.itemsize
        nb, _, ng = self.indices.shape
        mask_bits = nb * ng * self.fmt.bz
        return vb + mask_bits // 8

    def nbytes_dense(self) -> int:
        return int(np.prod(self.shape)) * self.values.dtype.itemsize


def dbb_encode(w: jax.Array, fmt: DBBFormat, *, prune: bool = False) -> DBBWeight:
    """Compress a DBB-constrained dense (K, N) matrix.

    If ``prune`` is True the matrix is magnitude-pruned to the constraint
    first; otherwise it must already satisfy it (checked under jit via
    where-zeroing: values outside the top-nnz pattern are dropped).
    """
    k, n = w.shape
    _check_blockable(k, fmt)
    if prune:
        w = dbb_prune(w, fmt)
    nb = k // fmt.bz
    g = fmt.group_size(n)
    ng = n // g
    wb = w.reshape(nb, fmt.bz, ng, g)
    scores = jnp.abs(wb).sum(axis=-1)  # (nb, bz, ng)
    # top-nnz positions, sorted ascending by position (stable streaming order
    # — the time-unrolled hardware consumes non-zeros in positional order).
    _, idx = jax.lax.top_k(scores.transpose(0, 2, 1), fmt.nnz)  # (nb, ng, nnz)
    idx = jnp.sort(idx, axis=-1)
    idx = idx.transpose(0, 2, 1)  # (nb, nnz, ng)
    # gather values: (nb, nnz, ng, g)
    vals = jnp.take_along_axis(wb, idx[:, :, :, None], axis=1)
    vals = vals.reshape(nb, fmt.nnz, n)
    return DBBWeight(vals, idx.astype(jnp.int8), fmt, (k, n))


def dbb_decode(dw: DBBWeight) -> jax.Array:
    """Expand a compressed DBB weight back to dense (K, N).

    Uses the one-hot contraction that the Pallas kernel also uses as its
    in-VMEM "scatter" (DESIGN.md §2): dense[b*bz+i, n] = Σ_j 1[idx=i]·val.
    """
    k, n = dw.shape
    fmt = dw.fmt
    nb = k // fmt.bz
    g = fmt.group_size(n)
    ng = n // g
    onehot = jax.nn.one_hot(dw.indices.astype(jnp.int32), fmt.bz, dtype=dw.values.dtype)
    # onehot: (nb, nnz, ng, bz); values: (nb, nnz, ng, g)
    vals = dw.values.reshape(nb, fmt.nnz, ng, g)
    dense = jnp.einsum("bjgi,bjgc->bigc", onehot, vals)
    return dense.reshape(k, n)


def dbb_encode_conv(w: jax.Array, fmt: DBBFormat, *, prune: bool = False) -> DBBWeight:
    """Compress a conv weight (kh, kw, C, F) along K = kh·kw·C.

    With C % bz == 0 every DBB block lies inside a single kernel tap, which
    is what the fused IM2COL × VDBB kernel streams (kernels/vdbb_im2col_conv).
    """
    kh, kw, c, f = w.shape
    return dbb_encode(w.reshape(kh * kw * c, f), fmt, prune=prune)


def dbb_decode_conv(dw: DBBWeight, kh: int, kw: int) -> jax.Array:
    """Expand a compressed conv weight back to dense (kh, kw, C, F)."""
    k, f = dw.shape
    return dbb_decode(dw).reshape(kh, kw, k // (kh * kw), f)


# ---------------------------------------------------------------------------
# Reference sparse matmuls (pure jnp oracles; kernels/ref.py re-exports)
# ---------------------------------------------------------------------------


def dbb_matmul_ref(a: jax.Array, dw: DBBWeight, *, precision=None) -> jax.Array:
    """A @ decode(W). Oracle for both kernel modes."""
    w = dbb_decode(dw).astype(a.dtype)
    return jnp.matmul(a, w, precision=precision)


def dbb_matmul_gather_ref(a: jax.Array, dw: DBBWeight) -> jax.Array:
    """Compressed-K formulation (group-shared patterns only).

    Ac[m, b, j] = A[m, b*bz + idx[b, j]]  (the activation "mux")
    out = Ac.reshape(M, nb*nnz) @ values.reshape(nb*nnz, N)

    FLOPs = 2·M·(K·nnz/bz)·N — the time-unrolled occupancy model: cycles
    per block == nnz, at constant utilization.
    """
    fmt = dw.fmt
    k, n = dw.shape
    if fmt.group_size(n) != n:
        raise ValueError("gather formulation requires group='matrix'")
    nb = k // fmt.bz
    m = a.shape[0]
    ab = a.reshape(m, nb, fmt.bz)
    idx = dw.indices[:, :, 0].astype(jnp.int32)  # (nb, nnz)
    ac = jnp.take_along_axis(ab, idx.T[None].transpose(0, 2, 1), axis=2)
    # ac: (m, nb, nnz)
    return jnp.matmul(
        ac.reshape(m, nb * fmt.nnz),
        dw.values.reshape(nb * fmt.nnz, n).astype(a.dtype),
    )


# ---------------------------------------------------------------------------
# Cost accounting (feeds the energy model & roofline)
# ---------------------------------------------------------------------------


def _act_sparsity_frac(act) -> Optional[float]:
    """Scalar activation sparsity from a float or an ActStats-like object
    (duck-typed on ``.sparsity`` to avoid a core ↔ act_sparsity cycle)."""
    if act is None:
        return None
    return float(getattr(act, "sparsity", act))


def dbb_gemm_costs(m: int, k: int, n: int, fmt: DBBFormat, bits: int = 8,
                   *, act=None, act_bits: Optional[int] = None,
                   out_bits: int = 32, epilogue_fused: bool = False) -> dict:
    """Analytic cost of one M×K×N GEMM under VDBB, paper-style accounting.

    'cycles' follows the time-unrolled occupancy: nnz cycles per block
    instead of bz. 'weight_bytes' is the compressed stream (values+mask).

    ``bits`` / ``act_bits`` are the operand widths (weight / activation;
    ``act_bits`` defaults to ``bits``): 8 is the ASIC's INT8 datapath
    (DESIGN.md §8), 16 models a bf16 run of the same kernels — int8 halves
    every operand stream relative to bf16. ``out_bits`` is the accumulator
    flush width (32 for both the int32 and fp32 accumulators).

    ``epilogue_fused`` (DESIGN.md §9) accounts the layer epilogue's
    placement, assuming the standard serving-layer epilogue (bias + ReLU,
    plus requantization on the int8 path — what `SparseCNN` layers run;
    a bare GEMM with no epilogue should ignore ``epilogue_bytes``):
    fused, the requantizer sits on the accumulator flush, so the output
    stream is ``act_bits`` wide (int8 straight to the next layer) and
    ``epilogue_bytes`` is 0; unfused, the flush is ``out_bits`` wide and
    ``epilogue_bytes`` charges the standalone bias/ReLU pass over the
    full fp32 tensor plus — only when ``act_bits < out_bits`` — the
    requant/cast pass to the next layer's operand width. That is the
    traffic the fusion deletes.

    ``act`` (optional) is the layer's activation sparsity — a scalar or a
    measured :class:`repro.core.act_sparsity.ActStats`. When given, the
    dict carries ``act_sparsity`` (``act_measured=True`` for stats objects),
    ``gated_mac_frac`` (executed MACs whose activation operand is zero —
    the clock-gating opportunity of paper §IV-A2) and ``act_nonzero_bytes``
    (the zero-skipped activation stream a compressed format would move);
    otherwise the paper's 50% assumption is recorded with
    ``act_measured=False``.
    """
    act_bits = bits if act_bits is None else act_bits
    nb, rem = divmod(k, fmt.bz)
    if rem and not fmt.is_dense:
        raise ValueError(f"K={k} not divisible by block size bz={fmt.bz}")
    dense_macs = m * k * n
    eff_macs = dense_macs  # effective (useful) ops, paper counts these
    # actually executed; a trailing partial block (dense formats only, e.g.
    # the C=3 stem) runs — and stores — its rem positions uncompressed.
    hw_macs = m * (nb * fmt.nnz + rem) * n
    wbytes = (nb * (fmt.nnz * bits + fmt.bz) + rem * (bits + 1)) * n / 8
    abytes = m * k * act_bits / 8
    if epilogue_fused:
        obytes = m * n * act_bits / 8  # flush at the next layer's width
        epi_bytes = 0
    else:
        obytes = m * n * out_bits / 8  # int32/fp32 accumulator flush
        # standalone epilogue passes over the fp32 activation tensor:
        # bias/ReLU (read + write fp32), plus — only when the next layer's
        # operand is narrower than the accumulator — a requant/cast pass
        # (read fp32 + write the act_bits-wide stream). A pure-fp32 model
        # has no requant pass and is charged none.
        epi_bytes = m * n * (4 + 4)
        if act_bits < out_bits:
            epi_bytes += m * n * (4 + act_bits / 8)
        epi_bytes = int(epi_bytes)
    act_sp = _act_sparsity_frac(act)
    measured = hasattr(act, "sparsity")
    if act_sp is None:
        act_sp = 0.5  # the paper's nominal assumption (Table IV/V)
    return dict(
        dense_macs=dense_macs,
        effective_ops=2 * eff_macs,
        executed_macs=hw_macs,
        speedup=fmt.bz / fmt.nnz,
        weight_bits=bits,
        act_bits=act_bits,
        weight_bytes=int(wbytes),
        act_bytes=int(abytes),
        out_bytes=int(obytes),
        epilogue_fused=epilogue_fused,
        epilogue_bytes=epi_bytes,
        weight_compression=fmt.compression_ratio(bits),
        act_sparsity=act_sp,
        act_measured=measured,
        gated_mac_frac=act_sp,
        act_nonzero_bytes=int(abytes * (1.0 - act_sp)),
    )


def dbb_conv_costs(
    n: int,
    h: int,
    w: int,
    c: int,
    f: int,
    kh: int,
    kw: int,
    fmt: DBBFormat,
    *,
    stride=1,
    padding="SAME",
    bits: int = 8,
    act_bits: Optional[int] = None,
    im2col_unit: bool = True,
    act=None,
    epilogue_fused: bool = False,
) -> dict:
    """Analytic cost of one NHWC conv under VDBB + hardware IM2COL.

    ``act``: this layer's activation sparsity (scalar or measured
    ``ActStats``), forwarded to :func:`dbb_gemm_costs`; ``bits`` /
    ``act_bits`` are the weight / activation operand widths (int8 vs bf16
    streams), and ``epilogue_fused`` the epilogue placement (DESIGN.md
    §9), also forwarded.

    The conv is the M×K×N GEMM with M = n·ho·wo, K = kh·kw·c, N = f
    (exactly what the fused kernel executes), composed with the IM2COL
    placement choice for the *activation* stream:

      im2col_unit=True  — expansion after the memory: the datapath reads
                          the raw n·h·w·c tile once (the paper's unit;
                          kernels/vdbb_im2col_conv's HBM behaviour).
      im2col_unit=False — expansion before the memory: the stored im2col
                          tensor is read, M·K bytes (the baseline).

    ``im2col_magnification`` is the ratio of the two — the "bandwidth
    magnifier"; ``combined_reduction`` composes it with the nnz/bz weight
    compression, the paper's headline composition.
    """
    sh, sw = (stride, stride) if isinstance(stride, int) else stride
    from repro.kernels.core import conv_geometry  # single source of truth

    _, _, (ho, wo) = conv_geometry(h, w, kh, kw, (sh, sw), padding)
    m, k = n * ho * wo, kh * kw * c
    costs = dbb_gemm_costs(m, k, f, fmt, bits, act=act, act_bits=act_bits,
                           epilogue_fused=epilogue_fused)
    act_bits = costs["act_bits"]
    raw_act = n * h * w * c * act_bits / 8
    expanded_act = m * k * act_bits / 8
    magnification = expanded_act / raw_act
    costs.update(
        out_hw=(ho, wo),
        act_bytes_raw=int(raw_act),
        act_bytes_expanded=int(expanded_act),
        act_bytes=int(raw_act if im2col_unit else expanded_act),
        act_nonzero_bytes=int(
            (raw_act if im2col_unit else expanded_act) * (1.0 - costs["act_sparsity"])
        ),
        im2col_magnification=magnification,
        dense_weight_bytes=int(k * f * bits / 8),
        combined_reduction=magnification * costs["speedup"],
    )
    return costs
