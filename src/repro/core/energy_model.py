"""Analytic area / power / throughput model of the VDBB accelerator.

Reproduces the paper's evaluation artifacts (Table III reuse formulas,
Table IV component breakdown, Table V headline efficiencies, Fig 9/10
design space, Fig 12 sparsity scaling) from a component-level model.

Calibration. The paper reports, for the pareto design 4x8x8_4x8 VDBB+IM2C
at nominal 4 TOPS / 1 GHz / 16nm (Table IV, 3/8 DBB, 50% act sparsity):

    STA 318 mW / 0.732 mm2,  W-SRAM 78.5 mW / 0.54 mm2,
    A-SRAM 31.0 mW (93.0 w/o IM2COL) / 2.16 mm2,
    4x M33 50.5 mW / 0.30 mm2,  IM2COL 10.0 mW / 0.01 mm2.

Table V gives effective TOPS/W at weight sparsity {50, 62.5, 75, 87.5}% =
{16.8, 21.9, 31.3, 55.7}. Inverting (effective TOPS = 4 * bz/nnz) yields
total power {476, 487, 511, 574} mW — an almost exact linear function of
the speedup s = bz/nnz:  P(s) = 443 + 16.4*s mW, whose constant term equals
STA + W-SRAM + MCU (447 mW) and whose linear term at s=8/3 equals
A-SRAM + IM2COL (41 mW). I.e. the *activation stream* is the only component
whose per-cycle bandwidth scales with speedup; weight stream and datapath
are constant per cycle — precisely the paper's "constant utilization,
variable occupancy" claim. The model below encodes exactly that structure.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

from repro.core.vdbb import DBBFormat

# ---------------------------------------------------------------------------
# Calibrated component constants (16nm, 1 GHz, from Table IV)
# ---------------------------------------------------------------------------

REF = dict(  # pareto design 4x8x8_4x8 VDBB IM2C
    A=4, B=8, C=8, M=4, N=8,
    sta_mw=318.0, sta_mm2=0.732,
    wsram_mw=78.5, wsram_mm2=0.54,
    asram_mw=31.0, asram_mw_noim2c=93.0, asram_mm2=2.16,
    mcu_mw_each=50.5 / 4, mcu_mm2_each=0.30 / 4,
    im2col_mw=10.0, im2col_mm2=0.01,
    ref_speedup=8.0 / 3.0,      # 3/8 DBB
    ref_act_sparsity=0.5,
)

# Fraction of STA power that is *not* gateable by activation-sparsity clock
# gating (clock tree, registers, control). Chosen so Fig 12(b)'s 80%-act
# curves sit visibly above the 50% ones without exceeding them by >20%.
STA_UNGATEABLE_FRAC = 0.45

# Relative datapath unit costs (normalized to one INT8 MAC = 1.0).
# A 4:1 INT8 mux is "significantly less than a MAC" (paper SIV-A2).
UNIT = dict(mac=1.0, acc_reg_bit=0.055, opr_reg_bit=0.035, mux4=0.18, mux8=0.28)

# The paper states the 4x8x8_4x8 VDBB design is "nominal 4 TOPS" although
# A*C*M*N = 1024 MACs = 2.048 TOPS; we calibrate a x2 MAC-equivalence factor
# for the time-unrolled lanes (consistent with *both* 65nm Table V rows and
# the iso-throughput normalization of Fig 9, where the 1x1x1_32x64 baseline
# and the DBB 4x8x4_4x8 design are also 2048 MACs).
VDBB_MAC_FACTOR = 2

# 65nm scaling (paper also reports a 65nm implementation at 0.5 GHz).
# energy_scale solved from Table V: 62.5% row gives 5.46 TOPS eff / 1.95
# TOPS/W -> 2.80 W = P16(s=8/3) * scale * 0.5 -> scale = 11.47; the 75% row
# then predicts 2.80 TOPS/W exactly as published. area_scale from TOPS/mm2.
TECH = {
    "16nm": dict(freq_ghz=1.0, energy_scale=1.0, area_scale=1.0),
    "65nm": dict(freq_ghz=0.5, energy_scale=12.11, area_scale=8.93),
}


def _act_frac(act) -> float:
    """Scalar activation sparsity from a float or an ActStats-like object
    (single source of truth: ``vdbb._act_sparsity_frac``). Every
    ``act_sparsity=`` parameter below accepts either."""
    from repro.core.vdbb import _act_sparsity_frac

    return _act_sparsity_frac(0.5 if act is None else act)


@dataclasses.dataclass(frozen=True)
class STAConfig:
    """An A x B x C _ M x N systolic tensor array design point.

    mode: 'dense' | 'dbb' (fixed NNZ at design time) | 'vdbb' (time unrolled)
    """

    A: int = 4
    B: int = 8
    C: int = 8
    M: int = 4
    N: int = 8
    mode: str = "vdbb"
    hw_nnz: int = 4          # only for mode='dbb' (e.g. 4/8 fixed)
    im2col: bool = True
    act_cg: bool = True
    tech: str = "16nm"

    # ---------------- Table III formulas ----------------
    @property
    def bz(self) -> int:
        return self.B

    @property
    def macs_per_tpe(self) -> int:
        if self.mode == "dense":
            return self.A * self.B * self.C
        if self.mode == "dbb":
            return self.A * self.hw_nnz * self.C
        return self.A * self.C  # vdbb: single-MAC S8DP1 units

    @property
    def accs_per_tpe(self) -> int:
        return self.A * self.C

    @property
    def oprs_per_tpe(self) -> int:
        if self.mode == "dense":
            return self.B * (self.A + self.C)
        if self.mode == "dbb":
            return self.A * self.B + self.hw_nnz * self.C
        return self.A * self.B + 1 * self.C  # n=1 weight element per cycle

    @property
    def muxes_per_tpe(self) -> int:
        if self.mode == "dense":
            return 0
        return self.macs_per_tpe  # one activation mux per (S)MAC

    @property
    def total_macs(self) -> int:
        """MAC-equivalents for throughput accounting (see VDBB_MAC_FACTOR)."""
        f = VDBB_MAC_FACTOR if self.mode == "vdbb" else 1
        return f * self.macs_per_tpe * self.M * self.N

    def inter_tpe_reuse(self) -> float:
        a, c, m, n = self.A, self.C, self.M, self.N
        b = {"dense": self.B, "dbb": self.hw_nnz, "vdbb": 1}[self.mode]
        return (a * b * c * m * n) / (a * self.B * m + c * b * n)

    def intra_tpe_reuse(self) -> float:
        a, c = self.A, self.C
        b = {"dense": self.B, "dbb": self.hw_nnz, "vdbb": 1}[self.mode]
        return (a * b * c) / (a * self.B + b * c)

    # ---------------- throughput ----------------
    def peak_tops(self) -> float:
        """Nominal dense-equivalent TOPS (2 ops per executed MAC).

        All modes can run dense GEMM at this rate (a fixed-DBB datapath
        processes a bz-block in bz/hw_nnz passes with all MACs busy), so
        this is the iso-throughput normalization the paper uses in Fig 9.
        """
        freq = TECH[self.tech]["freq_ghz"]
        return 2 * self.total_macs * freq * 1e9 / 1e12

    def effective_tops(self, fmt: DBBFormat) -> float:
        """Effective throughput for a model with weight format ``fmt``.

        Fig 12(a) behaviour: dense SA ignores sparsity; fixed DBB gives a
        step at its design point (less-sparse models fall back to dense,
        sparser ones are capped); VDBB scales continuously as bz/nnz.
        """
        dense_tops = self.peak_tops()
        if self.mode == "dense":
            return dense_tops
        if self.mode == "dbb":
            if fmt.nnz > self.hw_nnz:
                return dense_tops  # dense fallback, no benefit (paper SII-D)
            return dense_tops * self.B / self.hw_nnz
        return dense_tops * self.B / fmt.nnz

    def speedup(self, fmt: DBBFormat) -> float:
        if self.mode == "vdbb":
            return self.B / fmt.nnz
        if self.mode == "dbb":
            return self.B / self.hw_nnz if fmt.nnz <= self.hw_nnz else 1.0
        return 1.0

    def _n_mcu(self) -> int:
        """Paper SIV-D: 2 MCUs for 2 TOPS peak, 4 for 4 TOPS, 8 for 16 TOPS."""
        p = self.peak_tops()
        if p <= 2.5:
            return 2
        if p <= 8.0:
            return 4
        return 8

    # ---------------- power ----------------
    def _datapath_cost_units(self) -> float:
        """Relative datapath cost (MACs + registers + muxes) per TPE."""
        mux = UNIT["mux8"] if self.B == 8 else UNIT["mux4"]
        return (
            self.macs_per_tpe * UNIT["mac"]
            + self.accs_per_tpe * 32 * UNIT["acc_reg_bit"]
            + self.oprs_per_tpe * 8 * UNIT["opr_reg_bit"]
            + self.muxes_per_tpe * mux
        )

    def _ref_datapath_cost_units(self) -> float:
        r = STAConfig(A=REF["A"], B=REF["B"], C=REF["C"], M=REF["M"], N=REF["N"], mode="vdbb")
        return r._datapath_cost_units() * r.M * r.N

    def power_mw(self, fmt: DBBFormat, act_sparsity=0.5) -> float:
        """Total power for a model with weight format fmt.

        ``act_sparsity``: scalar or a measured ``ActStats`` (per-layer
        zero fraction of the activations actually streamed; DESIGN.md §7).
        """
        act_sparsity = _act_frac(act_sparsity)
        t = TECH[self.tech]
        s = self.speedup(fmt)
        # STA power scales with datapath cost; act-CG gates the gateable
        # fraction proportionally to activation sparsity.
        gate = 1.0
        if self.act_cg:
            base = STA_UNGATEABLE_FRAC + (1 - STA_UNGATEABLE_FRAC) * (1 - act_sparsity)
            ref = STA_UNGATEABLE_FRAC + (1 - STA_UNGATEABLE_FRAC) * (1 - REF["ref_act_sparsity"])
            gate = base / ref
        sta = REF["sta_mw"] * gate * (
            self._datapath_cost_units() * self.M * self.N / self._ref_datapath_cost_units()
        )
        # Weight stream: constant per cycle (compressed stream, the VDBB
        # invariant). Dense/fixed designs read proportionally more bits.
        wsram = REF["wsram_mw"]
        if self.mode == "dense":
            wsram = REF["wsram_mw"] * (8.0 / 3.0)  # uncompressed vs 3/8 ref stream
        # Activation stream scales with speedup (blocks retire faster).
        asram_ref = REF["asram_mw"] if self.im2col else REF["asram_mw_noim2c"]
        asram = asram_ref * (s / REF["ref_speedup"])
        im2c = (REF["im2col_mw"] * (s / REF["ref_speedup"])) if self.im2col else 0.0
        mcu = REF["mcu_mw_each"] * self._n_mcu()
        return (sta + wsram + asram + im2c + mcu) * t["energy_scale"] * (
            t["freq_ghz"] / TECH["16nm"]["freq_ghz"]
        )

    # ---------------- area ----------------
    def area_mm2(self) -> float:
        t = TECH[self.tech]
        sta = REF["sta_mm2"] * (
            self._datapath_cost_units() * self.M * self.N / self._ref_datapath_cost_units()
        )
        area = (
            sta
            + REF["wsram_mm2"]
            + REF["asram_mm2"]
            + REF["mcu_mm2_each"] * self._n_mcu()
            + (REF["im2col_mm2"] if self.im2col else 0.0)
        )
        return area * t["area_scale"]

    # ---------------- headline metrics ----------------
    def tops_per_w(self, fmt: DBBFormat, act_sparsity=0.5) -> float:
        """Effective TOPS/W; ``act_sparsity`` is a scalar or ``ActStats``."""
        return self.effective_tops(fmt) / (self.power_mw(fmt, act_sparsity) / 1e3)

    def tops_per_mm2(self, fmt: DBBFormat) -> float:
        return self.effective_tops(fmt) / self.area_mm2()


# Paper Table V rows for the proposed design (for assertions in tests/bench).
PAPER_TABLE_V_16NM = {  # weight sparsity -> (TOPS/W, TOPS/mm2)
    0.5: (16.8, 2.13),
    0.625: (21.9, 2.85),
    0.75: (31.3, 4.29),
    0.875: (55.7, 8.52),
}
PAPER_TABLE_V_65NM = {0.75: (2.80, 0.26), 0.625: (1.95, 0.17)}

PARETO_DESIGN = STAConfig(A=4, B=8, C=8, M=4, N=8, mode="vdbb", im2col=True)


def conv_workload(design: STAConfig, costs: dict, fmt: DBBFormat,
                  act_sparsity=None) -> dict:
    """Map one conv layer (``dbb_conv_costs`` dict) onto an STA design point.

    Cycles follow the time-unrolled occupancy (executed MACs over the
    array's MAC-equivalents per cycle); energy is power × time at the
    design's calibrated operating point. The activation stream uses the
    raw-tile bytes when the design has the IM2COL unit and the expanded
    im2col bytes otherwise — the two placements of Fig 8.

    ``act_sparsity``: scalar or measured ``ActStats`` for this layer;
    when None it falls back to the sparsity recorded in ``costs`` (set by
    ``dbb_conv_costs(act=...)``), then to the paper's 0.5 assumption.
    """
    if act_sparsity is None:
        act_sparsity = costs.get("act_sparsity", 0.5)
    act_sparsity = _act_frac(act_sparsity)
    t = TECH[design.tech]
    # plain-GEMM cost dicts (dbb_gemm_costs) have no im2col placement split
    act_bytes = (
        costs.get("act_bytes_raw", costs["act_bytes"])
        if design.im2col
        else costs.get("act_bytes_expanded", costs["act_bytes"])
    )
    wbytes = costs["weight_bytes"] if design.mode != "dense" else costs["dense_weight_bytes"]
    # the §9 epilogue placement recorded in the cost dict: a fused epilogue
    # flushes at the next layer's operand width with zero standalone
    # passes; unfused charges the dequant/bias/ReLU/requant round trips.
    obytes = costs.get("out_bytes", 0)
    epi_bytes = costs.get("epilogue_bytes", 0)
    # mode-aware occupancy: a dense SA runs all dense MACs; fixed DBB is
    # capped at its design point; only VDBB tracks the model's nnz/bz
    # (same dispatch as speedup()/effective_tops()).
    cycles = costs["dense_macs"] / max(design.total_macs * design.speedup(fmt), 1)
    time_s = cycles / (t["freq_ghz"] * 1e9)
    power_w = design.power_mw(fmt, act_sparsity) / 1e3
    return dict(
        cycles=cycles,
        time_s=time_s,
        energy_j=power_w * time_s,
        act_bytes=int(act_bytes),
        weight_bytes=int(wbytes),
        out_bytes=int(obytes),
        epilogue_bytes=int(epi_bytes),
        epilogue_fused=bool(costs.get("epilogue_fused", False)),
        hbm_bytes_total=int(act_bytes + wbytes + obytes + epi_bytes),
        sram_reads_saved=costs.get("im2col_magnification", 1.0) if design.im2col else 1.0,
        effective_tops=costs["effective_ops"] / max(time_s, 1e-30) / 1e12,
        act_sparsity=act_sparsity,
        effective_ops=costs["effective_ops"],
    )


def model_workload(design: STAConfig, layers) -> dict:
    """Compose per-layer workloads over a whole model (DESIGN.md §7).

    ``layers``: iterable of (costs, fmt, act_sparsity) triples — one per
    GEMM/conv layer, where ``costs`` is a ``dbb_gemm_costs``/
    ``dbb_conv_costs`` dict and ``act_sparsity`` is that layer's measured
    ``ActStats`` (or a scalar, or None to use what ``costs`` recorded).

    Returns whole-model totals: energy/time sums, effective TOPS/W from
    the summed effective ops over the summed energy (the honest Fig 12
    composition — each layer runs at its *own* measured activation
    sparsity), plus the executed-MAC-weighted mean activation sparsity.
    """
    layers = list(layers)
    per_layer = [conv_workload(design, c, f, a) for c, f, a in layers]
    if not per_layer:
        raise ValueError("model_workload() of empty layer list")
    time_s = sum(w["time_s"] for w in per_layer)
    energy = sum(w["energy_j"] for w in per_layer)
    eff_ops = sum(w["effective_ops"] for w in per_layer)
    weights = [c["executed_macs"] for c, _, _ in layers]
    wsum = float(sum(weights)) or 1.0
    mean_act = sum(w["act_sparsity"] * m for w, m in zip(per_layer, weights)) / wsum
    return dict(
        layers=per_layer,
        time_s=time_s,
        energy_j=energy,
        effective_tops=eff_ops / max(time_s, 1e-30) / 1e12,
        tops_per_w=eff_ops / 1e12 / max(energy, 1e-30),
        mean_act_sparsity=mean_act,
    )

# TPU v5e roofline constants (used by benchmarks/roofline.py; kept here so
# the energy model and the roofline report share one source of truth).
TPU_V5E = dict(
    peak_bf16_flops=197e12,   # per chip
    hbm_bw=819e9,             # bytes/s per chip
    ici_bw=50e9,              # bytes/s per link (~per-direction)
)


def fmt_for_sparsity(sparsity: float, bz: int = 8) -> DBBFormat:
    nnz = round((1.0 - sparsity) * bz)
    return DBBFormat(bz=bz, nnz=max(1, min(bz, nnz)))
