"""Core of the reproduction: VDBB sparsity + accelerator analytic models."""
from repro.core.vdbb import (  # noqa: F401
    DBBFormat,
    DBBWeight,
    DENSE,
    dbb_conv_costs,
    dbb_decode,
    dbb_decode_conv,
    dbb_encode,
    dbb_encode_conv,
    dbb_gemm_costs,
    dbb_mask,
    dbb_matmul_gather_ref,
    dbb_matmul_ref,
    dbb_prune,
    satisfies_dbb,
)
from repro.core.act_sparsity import (  # noqa: F401
    ActStats,
    act_dbb_decode,
    act_dbb_encode,
    act_dbb_mask,
    act_dbb_prune,
    act_fmt,
    block_nnz_histogram,
    collect_activations,
    combine,
    measure_activation,
    record_activation,
    zero_fraction,
)
from repro.core.quant import (  # noqa: F401
    QMAX,
    QuantDBBWeight,
    act_scale_from_stats,
    dequantize,
    dequantize_dbb,
    dynamic_act_scale,
    quant_conv_ref,
    quant_matmul_ref,
    quantize,
    quantize_dbb,
    weight_scales,
)
from repro.core.sparse_linear import DBBLinear, PruneSchedule  # noqa: F401
from repro.core.sparse_conv import DBBConv2d  # noqa: F401
from repro.core.energy_model import (  # noqa: F401
    PARETO_DESIGN,
    PAPER_TABLE_V_16NM,
    PAPER_TABLE_V_65NM,
    STAConfig,
    TPU_V5E,
    conv_workload,
    fmt_for_sparsity,
    model_workload,
)
