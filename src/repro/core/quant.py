"""INT8 quantization for the VDBB datapath (DESIGN.md §8).

The ASIC the paper evaluates is an INT8 machine — every Table IV/V number
and every `energy_model.UNIT` cost is normalized to one INT8 MAC — so the
functional model gets the same numerics: int8 operands into the MACs, an
int32 output-stationary accumulator, and a dequantization at the
accumulator flush. This module owns the number format; the kernels
(`repro.kernels`) own the int8 datapath it feeds.

Scheme (standard symmetric / zero-point-free, the hardware-friendly choice):

* **Weights** — per-output-channel symmetric:
  ``scale[n] = max|W[:, n]| / 127``, ``Wq = round(W / scale)`` in
  ``[-127, 127]``. Quantization rides the *compressed* `DBBWeight` layout:
  :class:`QuantDBBWeight` keeps the (nb, nnz, N) int8 values next to the
  unchanged int8 position indices, so the compressed stream the kernels
  read is bytes-per-value 1 instead of 4 — the paper's storage format
  bit-for-bit (int8 values + positions).

* **Activations** — per-tensor symmetric, calibrated from the PR-2
  activation-statistics pipeline: ``measure_activation`` records the
  tensor's ``absmax``, and :func:`act_scale_from_stats` turns the stats
  collected by ``SparseCNN.apply(collect_act_stats=True)`` into the static
  scale ``absmax / 127``. Without calibration, :func:`dynamic_act_scale`
  computes the scale from the live batch (dynamic quantization).

* **Accumulation** — exact int32 (int8·int8 products summed over K;
  overflow-free for K < 2^31/127² ≈ 133k). The float result is recovered
  on the accumulator flush as ``acc_int32 · (act_scale · w_scale[n])`` —
  one fused multiply per output element, exactly where the hardware's
  requantizer sits.

All functions are pure and jit-safe. The integer references here
(:func:`quant_matmul_ref`, :func:`quant_conv_ref`) are the oracles the
int8 Pallas kernels are tested bit-exactly against.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.vdbb import (
    DBBFormat,
    DBBWeight,
    dbb_decode,
)

QMAX = 127  # symmetric int8: [-127, 127]; -128 unused so negation is safe


# ---------------------------------------------------------------------------
# Scales
# ---------------------------------------------------------------------------


def weight_scales(values: jax.Array) -> jax.Array:
    """Per-output-channel symmetric scales from compressed (nb, nnz, N)
    values (all non-zeros are present in the compressed layout, so the
    per-column max over it equals the dense per-column max)."""
    amax = jnp.max(jnp.abs(values.astype(jnp.float32)), axis=(0, 1))  # (N,)
    return jnp.maximum(amax, 1e-12) / QMAX


def dynamic_act_scale(x: jax.Array) -> jax.Array:
    """Per-tensor symmetric scale from the live batch (dynamic quant)."""
    return jnp.maximum(jnp.max(jnp.abs(x.astype(jnp.float32))), 1e-12) / QMAX


def resolve_quant_input(x: jax.Array, act_scale):
    """(int8 codes, scale) for a quantized serving path — the single
    entry-side rule shared by `kernels.ops` and the layers' `quant_serve`:
    fp input is quantized per-tensor (calibrated ``act_scale`` or dynamic
    when None); an **int8** input is already the previous layer's
    requantized codes (int8-resident chaining, DESIGN.md §9) and must
    come with the static scale it was quantized at."""
    if x.dtype == jnp.int8:
        if act_scale is None:
            raise ValueError(
                "int8-resident input needs its activation scale: pass the "
                "calibrated act_scale the codes were quantized with"
            )
        return x, act_scale
    s_a = dynamic_act_scale(x) if act_scale is None else act_scale
    return quantize(x, s_a), s_a


def act_scale_from_stats(stats) -> float:
    """Static per-tensor scale from calibration :class:`ActStats` —
    the measure→gate→account pipeline doubles as the calibration pass
    (``SparseCNN.apply(collect_act_stats=True)`` records ``absmax``)."""
    amax = float(getattr(stats, "absmax"))
    if not amax > 0.0:
        raise ValueError(f"calibration stats carry no absmax: {stats!r}")
    return amax / QMAX


# ---------------------------------------------------------------------------
# Quantize / dequantize
# ---------------------------------------------------------------------------


def quantize(x: jax.Array, scale) -> jax.Array:
    """Symmetric round-to-nearest int8: clip(round(x / scale)) in ±QMAX.
    ``scale`` broadcasts (scalar for activations, (N,) for weights)."""
    q = jnp.round(x.astype(jnp.float32) / scale)
    return jnp.clip(q, -QMAX, QMAX).astype(jnp.int8)


def dequantize(q: jax.Array, scale) -> jax.Array:
    return q.astype(jnp.float32) * scale


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class QuantDBBWeight:
    """INT8-quantized compressed DBB weight.

    values:  (nb, nnz, N) int8 — quantized non-zeros, same layout as
             ``DBBWeight.values``.
    indices: (nb, nnz, NG) int8 — intra-block positions, unchanged.
    scales:  (N,) fp32 — per-output-channel dequantization scales.
    fmt / shape: static, as on :class:`DBBWeight`.
    """

    values: jax.Array
    indices: jax.Array
    scales: jax.Array
    fmt: DBBFormat
    shape: tuple

    def tree_flatten(self):
        return (self.values, self.indices, self.scales), (self.fmt, self.shape)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], children[1], children[2], aux[0], aux[1])

    @property
    def dtype(self):
        return self.values.dtype

    def as_dbb(self) -> DBBWeight:
        """The int8 compressed weight viewed as a plain DBBWeight (what the
        dtype-dispatching kernels consume; scales ride separately)."""
        return DBBWeight(self.values, self.indices, self.fmt, self.shape)

    def nbytes_compressed(self) -> int:
        """Stored bytes: int8 values + bitmask + fp32 scales."""
        vb = int(np.prod(self.values.shape))  # 1 byte per value
        nb, _, ng = self.indices.shape
        mask_bits = nb * ng * self.fmt.bz
        return vb + mask_bits // 8 + int(np.prod(self.scales.shape)) * 4


def quantize_dbb(dw: DBBWeight) -> QuantDBBWeight:
    """Symmetric per-output-channel quantization of a compressed weight."""
    if jnp.issubdtype(dw.values.dtype, jnp.integer):
        raise ValueError(f"weight already integer: {dw.values.dtype}")
    scales = weight_scales(dw.values)
    qvals = quantize(dw.values, scales[None, None, :])
    return QuantDBBWeight(qvals, dw.indices, scales, dw.fmt, dw.shape)


def dequantize_dbb(qw: QuantDBBWeight) -> DBBWeight:
    """fp32 DBBWeight carrying the (lossy) round-tripped values."""
    vals = dequantize(qw.values, qw.scales[None, None, :])
    return DBBWeight(vals, qw.indices, qw.fmt, qw.shape)


# ---------------------------------------------------------------------------
# Integer references (oracles for the int8 kernels; pure jnp)
# ---------------------------------------------------------------------------


def int_matmul_ref(aq: jax.Array, wq_dense: jax.Array) -> jax.Array:
    """Exact int32 GEMM of int8 operands — the accumulator the hardware
    (and the Pallas int8 kernels) produce before requantization."""
    return jnp.matmul(aq.astype(jnp.int32), wq_dense.astype(jnp.int32))


def quant_matmul_ref(aq: jax.Array, qw: QuantDBBWeight, act_scale) -> jax.Array:
    """int8 A × quantized compressed W → fp32, via the decoded dense int8
    weight: int32-exact accumulate, dequant on the (conceptual) flush."""
    acc = int_matmul_ref(aq, dbb_decode(qw.as_dbb()))
    return acc.astype(jnp.float32) * (act_scale * qw.scales)[None, :]


def quant_matmul_gather_ref(
    aq: jax.Array, qw: QuantDBBWeight, act_scale
) -> jax.Array:
    """Compressed-K int8 matmul (group='matrix' only) — the quantized twin
    of :func:`repro.core.vdbb.dbb_matmul_gather_ref`.

    The int8 activation blocks are gathered ("muxed") down to the nnz
    positions the shared block pattern keeps, then contracted against the
    (nb·nnz, N) int8 value stream with exact int32 accumulation. Integer
    sums are order-independent, so this is bit-identical to
    :func:`quant_matmul_ref` while never materializing the dense weight.
    """
    fmt = qw.fmt
    k, n = qw.shape
    if fmt.group_size(n) != n:
        raise ValueError("gather formulation requires group='matrix'")
    nb = k // fmt.bz
    m = aq.shape[0]
    ab = aq.reshape(m, nb, fmt.bz)
    idx = qw.indices[:, :, 0].astype(jnp.int32)  # (nb, nnz)
    ac = jnp.take_along_axis(ab, idx.T[None].transpose(0, 2, 1), axis=2)
    acc = jnp.matmul(  # (m, nb*nnz) x (nb*nnz, n), exact int32
        ac.reshape(m, nb * fmt.nnz).astype(jnp.int32),
        qw.values.reshape(nb * fmt.nnz, n).astype(jnp.int32),
    )
    return acc.astype(jnp.float32) * (act_scale * qw.scales)[None, :]


def quant_conv_ref(
    xq: jax.Array, qw: QuantDBBWeight, kh: int, kw: int, act_scale,
    *, stride=1, padding="SAME",
) -> jax.Array:
    """int8 NHWC conv oracle: the exact-int32 accumulator of
    ``kernels.ref.sparse_conv_int_ref`` + dequant. Matches the fused int8
    conv kernels."""
    from repro.kernels.ref import sparse_conv_int_ref

    acc = sparse_conv_int_ref(xq, qw.as_dbb(), kh, kw, stride=stride, padding=padding)
    return acc.astype(jnp.float32) * (act_scale * qw.scales)[None, None, None, :]
