"""DBBLinear — the paper's technique as a first-class model layer.

Training: weights are dense arrays kept *projected* onto the DBB constraint
(magnitude top-nnz per block) by `constrain()` — applied after optimizer
updates, mirroring the paper's magnitude-based DBB-aware pruning (§V-A).
A progressive schedule anneals nnz from bz down to the target.

Serving: `compress_params()` converts the dense weight to the compressed
DBBWeight layout; the forward pass then runs the compressed matmul
(Pallas kernel on TPU, jnp reference elsewhere), consuming nnz/bz of the
dense weight bandwidth — the VDBB win. `quantize()` (DESIGN.md §8)
further converts compressed params to the ASIC's INT8 numerics: int8
values + per-output-channel scales (`QuantDBBWeight`), per-tensor
activation quantization (calibrated or dynamic), exact int32
accumulation, dequantization at the accumulator flush.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.core.quant import (
    QuantDBBWeight,
    dynamic_act_scale,
    int_matmul_ref,
    quant_matmul_ref,
    quantize as quantize_array,
    quantize_dbb,
    resolve_quant_input,
)
from repro.core.vdbb import (
    DBBFormat,
    DBBWeight,
    DENSE,
    dbb_decode,
    dbb_encode,
    dbb_matmul_gather_ref,
    dbb_prune,
)


@dataclasses.dataclass(frozen=True)
class PruneSchedule:
    """Linear anneal of nnz from bz to target between begin and end steps."""

    begin_step: int = 0
    end_step: int = 1
    constrain_every: int = 1  # re-project every k steps (1 = every step)

    def nnz_at(self, step: int, fmt: DBBFormat) -> jax.Array:
        """Traced-safe current density bound (int32 scalar)."""
        frac = jnp.clip(
            (step - self.begin_step) / max(self.end_step - self.begin_step, 1), 0.0, 1.0
        )
        cur = jnp.round(fmt.bz - frac * (fmt.bz - fmt.nnz)).astype(jnp.int32)
        return cur


@dataclasses.dataclass(frozen=True)
class DBBLinear:
    """y = x @ W (+ b); W is (in_features, out_features), DBB along K=in."""

    in_features: int
    out_features: int
    fmt: DBBFormat = DENSE
    use_bias: bool = False
    dtype: Any = jnp.float32
    kernel_mode: str = "ref"  # 'ref' | 'pallas' (serving path choice)

    def init(self, key) -> dict:
        scale = 1.0 / (self.in_features**0.5)
        w = scale * jax.random.truncated_normal(
            key, -2, 2, (self.in_features, self.out_features), self.dtype
        )
        if not self.fmt.is_dense:
            w = dbb_prune(w, self.fmt)
        p = {"w": w}
        if self.use_bias:
            p["b"] = jnp.zeros((self.out_features,), self.dtype)
        return p

    # ------------------------------------------------------------------
    def __call__(self, params: dict, x: jax.Array) -> jax.Array:
        w = params["w"]
        if isinstance(w, QuantDBBWeight):
            y = self._quantized_matmul(x, w, params.get("aq"))
        elif isinstance(w, DBBWeight):
            y = self._compressed_matmul(x, w)
        else:
            y = jnp.matmul(x, w.astype(x.dtype))
        if self.use_bias:
            y = y + params["b"].astype(y.dtype)
        return y

    def _use_pallas(self, m: int) -> bool:
        """Pallas serving path, with the tiny-M reference fallback: below
        the MXU sublane (8 rows) a Pallas launch wastes the array, so the
        classifier-head-sized GEMMs stay on the jnp reference."""
        return self.kernel_mode == "pallas" and m >= 8

    def _compressed_matmul(self, x: jax.Array, w: DBBWeight) -> jax.Array:
        lead = x.shape[:-1]
        x2 = x.reshape(-1, x.shape[-1])
        if self._use_pallas(x2.shape[0]):
            from repro.kernels import ops  # deferred: kernels are optional

            y2 = ops.vdbb_matmul(x2, w)
        elif w.fmt.group_size(w.shape[1]) == w.shape[1]:
            y2 = dbb_matmul_gather_ref(x2, w)
        else:
            y2 = jnp.matmul(x2, dbb_decode(w).astype(x.dtype))
        return y2.reshape(*lead, self.out_features)

    def _quantized_matmul(self, x: jax.Array, qw: QuantDBBWeight, aq) -> jax.Array:
        """INT8 serving matmul: per-tensor act quant (calibrated ``aq`` or
        dynamic), int8 kernel / integer reference, fp32 out (bias after)."""
        lead = x.shape[:-1]
        x2 = x.reshape(-1, x.shape[-1])
        s_a = dynamic_act_scale(x2) if aq is None else aq
        if self._use_pallas(x2.shape[0]):
            from repro.kernels import ops  # deferred: kernels are optional

            y2 = ops.quant_matmul(x2, qw, s_a)
        else:
            y2 = quant_matmul_ref(quantize_array(x2, s_a), qw, s_a)
        return y2.reshape(*lead, self.out_features)

    def quant_serve(self, params: dict, x: jax.Array, *, relu: bool = False,
                    out_scale=None, bm=None, bn=None, kb=None) -> jax.Array:
        """One-kernel INT8 serving GEMM with the fused epilogue (§9).

        Mirrors :meth:`DBBConv2d.quant_serve`: int8 GEMM, dequant, bias,
        optional ReLU and requantize at ``out_scale`` in a single kernel
        (Pallas) or one integer-oracle + ``quant_epilogue_ref`` pass (ref
        mode / tiny-M fallback). ``x`` may be fp or int8-resident codes
        (the latter requires a calibrated ``aq``). ``bm``/``bn``/``kb``
        pin explicit launch tiles (the §10 frozen-plan path); None keeps
        the registry/pick defaults.
        """
        qw = params["w"]
        aq = params.get("aq")
        b = params.get("b")
        lead = x.shape[:-1]
        x2 = x.reshape(-1, x.shape[-1])
        if self._use_pallas(x2.shape[0]):
            from repro.kernels import ops  # deferred: kernels are optional

            y2 = ops.quant_matmul(x2, qw, aq, bias=b, relu=relu,
                                  out_scale=out_scale, bm=bm, bn=bn, kb=kb)
        else:
            from repro.kernels.ref import quant_epilogue_ref

            xq, s_a = resolve_quant_input(x2, aq)
            acc = int_matmul_ref(xq, dbb_decode(qw.as_dbb()))
            y2 = quant_epilogue_ref(
                acc, s_a * qw.scales, bias=b, relu=relu, out_scale=out_scale
            )
        return y2.reshape(*lead, self.out_features)

    # ------------------------------------------------------- frozen plans
    def make_plan(self, params: dict, *, batch: int, relu: bool = False,
                  out_scale=None, fused: bool = False, tune: str = "cache",
                  cache=None, top_k: int = 4, reps: int = 3):
        """Stage this layer's serving step once (DESIGN.md §10); the GEMM
        twin of :meth:`DBBConv2d.make_plan`. ``batch`` is the GEMM's M
        (the tiny-M reference fallback applies, so classifier-head-sized
        plans carry no tiles). Returns ``(run, tiles)``."""
        from repro.kernels.core import pick_tile, pick_tile_padded

        wp = params["w"]
        quant = isinstance(wp, QuantDBBWeight)
        tiled = self._use_pallas(batch) and isinstance(wp, (DBBWeight, QuantDBBWeight))
        if tiled:
            nb, rem = divmod(self.in_features, wp.fmt.bz)
            if rem:
                raise ValueError(
                    f"DBBLinear.make_plan: in_features={self.in_features} is "
                    f"not a multiple of the DBB block size bz={wp.fmt.bz} "
                    f"(ragged K has no compressed-block layout; pad K or "
                    f"serve with kernel_mode='ref')")
        tiles: dict = {}
        if tiled and tune != "off":
            from repro.kernels import autotune  # deferred: kernels optional

            tiles = autotune.tiles_for_matmul(
                batch, self.in_features, self.out_features, wp.fmt,
                jnp.int8 if quant else self.dtype,
                mode=tune, cache=cache, top_k=top_k, reps=reps,
            )
        if tiled and not tiles:
            # freeze the pick_tile defaults explicitly, so the staged
            # closure never depends on ambient registry state at trace time
            tc = wp.fmt.group_size(self.out_features) == self.out_features
            tiles = {"bm": pick_tile_padded(batch, 128)[0],
                     "bn": pick_tile_padded(self.out_features, 256)[0],
                     "kb": pick_tile(self.in_features // wp.fmt.bz,
                                     16 if tc else 8)}
        if quant and fused:
            def run(x):
                return self.quant_serve(params, x, relu=relu,
                                        out_scale=out_scale, **tiles)
        elif tiled:
            from repro.kernels import ops  # deferred: kernels are optional

            # mirror __call__'s GEMM → +bias order, tiles pinned in
            def run(x):
                lead = x.shape[:-1]
                x2 = x.reshape(-1, x.shape[-1])
                if quant:
                    y2 = ops.quant_matmul(x2, wp, params.get("aq"), **tiles)
                else:
                    y2 = ops.vdbb_matmul(x2, wp, **tiles)
                y = y2.reshape(*lead, self.out_features)
                if self.use_bias and "b" in params:
                    y = y + params["b"].astype(y.dtype)
                if relu:
                    y = jax.nn.relu(y)
                if out_scale is not None:
                    y = quantize_array(y, out_scale)
                return y
        else:
            # reference path (incl. the tiny-M fallback): __call__ applies
            # the bias itself
            def run(x):
                y = self(params, x)
                if relu:
                    y = jax.nn.relu(y)
                if out_scale is not None:  # mirror the conv twin's fallback
                    y = quantize_array(y, out_scale)
                return y
        return run, tiles

    # ------------------------------------------------------------------
    def constrain(self, params: dict, step=None, schedule: Optional[PruneSchedule] = None) -> dict:
        """Project the dense weight onto the (possibly annealed) constraint."""
        if self.fmt.is_dense or isinstance(params["w"], (DBBWeight, QuantDBBWeight)):
            return params
        if schedule is None or step is None:
            w = dbb_prune(params["w"], self.fmt)
        else:
            # anneal: switch between per-nnz masks with a traced nnz.
            cur = schedule.nnz_at(step, self.fmt)
            branches = [
                lambda w, n=n: dbb_prune(
                    w, dataclasses.replace(self.fmt, nnz=n)
                )
                for n in range(self.fmt.nnz, self.fmt.bz + 1)
            ]
            w = jax.lax.switch(cur - self.fmt.nnz, branches, params["w"])
        return dict(params, w=w)

    def compress_params(self, params: dict) -> dict:
        if self.fmt.is_dense or isinstance(params["w"], (DBBWeight, QuantDBBWeight)):
            return params
        return dict(params, w=dbb_encode(params["w"], self.fmt, prune=True))

    def quantize(self, params: dict, act_scale=None) -> dict:
        """Convert compressed params to the INT8 serving layout (§8).

        ``act_scale``: static per-tensor activation scale from calibration
        (``quant.act_scale_from_stats``); None keeps activation
        quantization dynamic (scale from each live batch). Dense
        (non-compressed) layers are returned unchanged — they stay fp, like
        the paper's uncompressed stem.
        """
        w = params["w"]
        if isinstance(w, QuantDBBWeight):  # already int8: re-calibrate only
            if act_scale is None:
                return params
            return dict(params, aq=jnp.asarray(act_scale, jnp.float32))
        if not isinstance(w, DBBWeight):  # dense layer stays fp
            return params
        out = dict(params, w=quantize_dbb(w))
        if act_scale is not None:
            out["aq"] = jnp.asarray(act_scale, jnp.float32)
        return out

    def param_specs(self, k_axis: str, n_axis: str) -> dict:
        """Logical sharding axes for dense or compressed layouts."""
        spec = {"w": (k_axis, n_axis)}
        if self.use_bias:
            spec["b"] = (n_axis,)
        return spec

    def flops(self, batch: int) -> int:
        """Executed MACs*2 under the time-unrolled occupancy model."""
        k_eff = (self.in_features // self.fmt.bz) * self.fmt.nnz
        return 2 * batch * k_eff * self.out_features
