"""Training-time DBB pruning utilities operating on whole param pytrees.

The paper's recipe (§V-A): start from a dense (pre)trained model, apply
magnitude-based DBB-aware pruning progressively (~20 epochs), then fine
tune with the mask fixed. Here that is expressed as a projection applied
inside `train_step` after the optimizer update, driven by a PruneSchedule.

The model zoo tags each DBB-constrained weight leaf by constructing it via
DBBLinear; `tree_constrain` walks a parallel tree of (module, sub-params).
To keep things simple and pjit-friendly, models expose
`constrain_fn(params, step) -> params` built from their module tree.
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.core.sparse_linear import DBBLinear, PruneSchedule
from repro.core.vdbb import DBBFormat, dbb_mask, dbb_prune, satisfies_dbb


def global_dbb_stats(params, fmts: dict) -> dict:
    """Fraction of weights zero / constraint satisfaction per tagged leaf.

    fmts: {path_str: (DBBFormat, leaf_array)} — produced by the model's
    `dbb_leaves(params)` helper.
    """
    out = {}
    for path, (fmt, w) in fmts.items():
        nz = jnp.mean((w != 0).astype(jnp.float32))
        out[path] = dict(
            density=float(nz),
            target_density=fmt.density,
            satisfied=bool(satisfies_dbb(w, fmt)),
        )
    return out


def make_constrain_fn(
    modules_with_paths,
    schedule: Optional[PruneSchedule] = None,
) -> Callable:
    """Build f(params, step)->params projecting every DBBLinear weight.

    modules_with_paths: list of (getter, setter, DBBLinear) where getter
    extracts the module's sub-params dict from the full tree and setter
    writes it back (functional).
    """

    def constrain(params, step):
        for getter, setter, mod in modules_with_paths:
            sub = getter(params)
            sub = mod.constrain(sub, step, schedule)
            params = setter(params, sub)
        return params

    return constrain


def prune_tree_to_dbb(params, fmt: DBBFormat, min_k: Optional[int] = None):
    """Blanket-prune every rank-2 leaf whose K dim is blockable (utility for
    experiments/ablations; production models use per-layer formats)."""

    def prune_leaf(w):
        if (
            isinstance(w, jax.Array)
            and w.ndim == 2
            and w.shape[0] % fmt.bz == 0
            and (min_k is None or w.shape[0] >= min_k)
        ):
            return dbb_prune(w, fmt)
        return w

    return jax.tree_util.tree_map(prune_leaf, params)
