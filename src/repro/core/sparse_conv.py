"""DBBConv2d — the paper's technique on its native workload, CNN layers.

Mirrors :class:`repro.core.sparse_linear.DBBLinear` end-to-end:

Training: the dense (kh, kw, C, F) weight is kept *projected* onto the DBB
constraint along K = kh·kw·C (magnitude top-nnz per bz-block) by
``constrain()``, with the same progressive nnz anneal.

Serving: ``compress_params()`` converts to the compressed DBBWeight layout;
the forward pass then runs the fused IM2COL × VDBB conv — Pallas kernel in
``kernel_mode='pallas'`` (kernels/vdbb_im2col_conv), decode + XLA conv as
the reference path — consuming nnz/bz of the dense weight bandwidth while
reading the raw (un-im2col'd) activation tile.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.core.quant import (
    QuantDBBWeight,
    dynamic_act_scale,
    quant_conv_ref,
    quantize as quantize_array,
    quantize_dbb,
    resolve_quant_input,
)
from repro.core.sparse_linear import PruneSchedule
from repro.core.vdbb import (
    DBBFormat,
    DBBWeight,
    DENSE,
    dbb_decode_conv,
    dbb_encode_conv,
    dbb_prune,
)
from repro.kernels.core import _pair  # stride/kernel-size normalizer (no cycle:
                                      # kernels.core has no repro-internal imports)


@dataclasses.dataclass(frozen=True)
class DBBConv2d:
    """y = conv2d(x, W) (+ b); x NHWC, W (kh, kw, C, F), DBB along K=kh·kw·C."""

    in_channels: int
    out_channels: int
    kernel_size: Any = 3  # int or (kh, kw)
    stride: Any = 1
    padding: Any = "SAME"
    fmt: DBBFormat = DENSE
    use_bias: bool = False
    dtype: Any = jnp.float32
    kernel_mode: str = "ref"  # 'ref' | 'pallas' (serving path choice)

    def __post_init__(self):
        if not self.fmt.is_dense and self.in_channels % self.fmt.bz != 0:
            raise ValueError(
                f"in_channels={self.in_channels} not divisible by bz="
                f"{self.fmt.bz}: DBB blocks must not straddle kernel taps"
            )

    @property
    def kh(self) -> int:
        return _pair(self.kernel_size)[0]

    @property
    def kw(self) -> int:
        return _pair(self.kernel_size)[1]

    def init(self, key) -> dict:
        kh, kw = self.kh, self.kw
        fan_in = kh * kw * self.in_channels
        scale = 1.0 / (fan_in**0.5)
        w = scale * jax.random.truncated_normal(
            key, -2, 2, (kh, kw, self.in_channels, self.out_channels), self.dtype
        )
        if not self.fmt.is_dense:
            w = self._project(w, self.fmt)
        p = {"w": w}
        if self.use_bias:
            p["b"] = jnp.zeros((self.out_channels,), self.dtype)
        return p

    # ------------------------------------------------------------------
    def _project(self, w4: jax.Array, fmt: DBBFormat) -> jax.Array:
        kh, kw, c, f = w4.shape
        return dbb_prune(w4.reshape(kh * kw * c, f), fmt).reshape(w4.shape)

    def __call__(self, params: dict, x: jax.Array) -> jax.Array:
        w = params["w"]
        if isinstance(w, QuantDBBWeight):
            y = self._quantized_conv(x, w, params.get("aq"))
        elif isinstance(w, DBBWeight):
            y = self._compressed_conv(x, w)
        else:
            y = jax.lax.conv_general_dilated(
                x,
                w.astype(x.dtype),
                window_strides=_pair(self.stride),
                padding=self.padding,
                dimension_numbers=("NHWC", "HWIO", "NHWC"),
            )
        if self.use_bias:
            y = y + params["b"].astype(y.dtype)
        return y

    def _compressed_conv(self, x: jax.Array, w: DBBWeight) -> jax.Array:
        if self.kernel_mode == "pallas":
            from repro.kernels import ops  # deferred: kernels are optional

            return ops.sparse_conv(
                x, w, self.kh, self.kw, stride=_pair(self.stride), padding=self.padding
            )
        w4 = dbb_decode_conv(w, self.kh, self.kw).astype(x.dtype)
        return jax.lax.conv_general_dilated(
            x,
            w4,
            window_strides=_pair(self.stride),
            padding=self.padding,
            dimension_numbers=("NHWC", "HWIO", "NHWC"),
        )

    def _quantized_conv(self, x: jax.Array, qw: QuantDBBWeight, aq) -> jax.Array:
        """INT8 serving conv: per-tensor act quant (calibrated ``aq`` or
        dynamic), int8 fused kernel / integer reference, fp32 out."""
        s_a = dynamic_act_scale(x) if aq is None else aq
        if self.kernel_mode == "pallas":
            from repro.kernels import ops  # deferred: kernels are optional

            return ops.quant_conv(
                x, qw, self.kh, self.kw, s_a,
                stride=_pair(self.stride), padding=self.padding,
            )
        return quant_conv_ref(
            quantize_array(x, s_a), qw, self.kh, self.kw, s_a,
            stride=_pair(self.stride), padding=self.padding,
        )

    def quant_serve(self, params: dict, x: jax.Array, *, relu: bool = False,
                    out_scale=None, bf=None, tile_h=None,
                    tile_w=None) -> jax.Array:
        """One-kernel INT8 serving conv with the fused epilogue (§9).

        The whole layer — int8 conv, dequant, bias (from ``params``),
        optional ReLU, optional requantize at ``out_scale`` (the *next*
        layer's calibrated activation scale) — is a single kernel call
        (Pallas) or a single integer-oracle + :func:`quant_epilogue_ref`
        pass (ref mode). ``x`` may be fp (quantized at the calibrated
        ``aq`` or dynamically) or already int8-resident codes from the
        previous layer's epilogue (requires a calibrated ``aq``). Returns
        int8 codes when ``out_scale`` is given, fp32 otherwise.
        ``bf``/``tile_h``/``tile_w`` pin explicit launch tiles (the §10
        frozen-plan path); None keeps the registry/pick defaults.
        """
        qw = params["w"]
        aq = params.get("aq")
        b = params.get("b")
        if self.kernel_mode == "pallas":
            from repro.kernels import ops  # deferred: kernels are optional

            return ops.quant_conv(
                x, qw, self.kh, self.kw, aq, bias=b, relu=relu,
                out_scale=out_scale, stride=_pair(self.stride),
                padding=self.padding, bf=bf, tile_h=tile_h, tile_w=tile_w,
            )
        from repro.kernels.ref import quant_epilogue_ref, sparse_conv_int_ref

        xq, s_a = resolve_quant_input(x, aq)
        acc = sparse_conv_int_ref(
            xq, qw.as_dbb(), self.kh, self.kw,
            stride=_pair(self.stride), padding=self.padding,
        )
        return quant_epilogue_ref(
            acc, s_a * qw.scales, bias=b, relu=relu, out_scale=out_scale
        )

    # ------------------------------------------------------- frozen plans
    def make_plan(self, params: dict, *, batch: int, h: int, w: int,
                  relu: bool = False, out_scale=None, fused: bool = False,
                  tune: str = "cache", cache=None, top_k: int = 4,
                  reps: int = 3):
        """Stage this layer's serving step once (DESIGN.md §10).

        Resolves the tuned tile config for this exact launch signature
        (autotune registry → persistent cache → optional search, per
        ``tune`` ∈ {'off', 'cache', 'search'}) and returns ``(run,
        tiles)``: ``run`` is an ``x -> y`` closure with the weight buffers
        frozen in that replicates exactly the path ``SparseCNN.apply``
        takes for these params (``fused=True`` = the §9 int8-resident
        chain step, so a plan built from calibrated quantized params is
        bit-identical to the unplanned chain); ``tiles`` is the resolved
        config (empty on reference/XLA paths).
        """
        from repro.kernels.core import conv_geometry, default_interpret, pick_tile

        wp = params["w"]
        pallas = self.kernel_mode == "pallas"
        quant = isinstance(wp, QuantDBBWeight)
        compressed = isinstance(wp, DBBWeight)
        # fp stem fuses only on compiled backends — interpret-mode Pallas
        # dense conv loses badly to XLA's native conv, and the chain in
        # SparseCNN.apply makes the same call, keeping plan == apply
        # bit-identical (DESIGN.md §12)
        stem_fused = fused and pallas and out_scale is not None and not (
            quant or compressed) and not default_interpret()
        tiled = pallas and (quant or compressed or stem_fused)
        tiles: dict = {}
        if tiled and tune != "off":
            from repro.kernels import autotune  # deferred: kernels optional

            tiles = autotune.tiles_for_conv(
                batch, h, w, self.in_channels, self.out_channels, self.kh,
                self.kw, wp.fmt if (quant or compressed) else None,
                jnp.int8 if quant else self.dtype, stride=_pair(self.stride),
                padding=self.padding, mode=tune, cache=cache, top_k=top_k,
                reps=reps,
            )
        if tiled and not tiles:
            # freeze the pick_tile defaults explicitly, so the staged
            # closure never depends on ambient registry state at trace time
            _, _, (ho, wo) = conv_geometry(h, w, self.kh, self.kw,
                                           self.stride, self.padding)
            tiles = {"bf": pick_tile(self.out_channels, 128),
                     "tile_h": ho, "tile_w": wo}
        if quant and fused:
            def run(x):
                return self.quant_serve(params, x, relu=relu,
                                        out_scale=out_scale, **tiles)
        elif stem_fused:
            from repro.kernels import ops  # deferred: kernels are optional

            def run(x):
                return ops.fused_im2col_conv(
                    x, params["w"], bias=params.get("b"), relu=relu,
                    out_scale=out_scale, stride=_pair(self.stride),
                    padding=self.padding, **tiles,
                )
        elif tiled:
            from repro.kernels import ops  # deferred: kernels are optional

            # mirror __call__'s kernel → +bias order, with the tiles pinned
            # into the closure (never read from the ambient registry)
            def run(x):
                if quant:
                    y = ops.quant_conv(
                        x, wp, self.kh, self.kw, params.get("aq"),
                        stride=_pair(self.stride), padding=self.padding,
                        **tiles,
                    )
                else:
                    y = ops.sparse_conv(
                        x, wp, self.kh, self.kw, stride=_pair(self.stride),
                        padding=self.padding, **tiles,
                    )
                if self.use_bias and "b" in params:
                    y = y + params["b"].astype(y.dtype)
                if relu:
                    y = jax.nn.relu(y)
                if out_scale is not None:
                    y = quantize_array(y, out_scale)
                return y
        else:
            # reference/XLA path: __call__ applies the bias itself
            def run(x):
                y = self(params, x)
                if relu:
                    y = jax.nn.relu(y)
                if out_scale is not None:
                    y = quantize_array(y, out_scale)
                return y
        return run, tiles

    # ------------------------------------------------------------------
    def constrain(self, params: dict, step=None, schedule: Optional[PruneSchedule] = None) -> dict:
        """Project the dense weight onto the (possibly annealed) constraint."""
        if self.fmt.is_dense or isinstance(params["w"], (DBBWeight, QuantDBBWeight)):
            return params
        if schedule is None or step is None:
            w = self._project(params["w"], self.fmt)
        else:
            cur = schedule.nnz_at(step, self.fmt)
            branches = [
                lambda w, n=n: self._project(w, dataclasses.replace(self.fmt, nnz=n))
                for n in range(self.fmt.nnz, self.fmt.bz + 1)
            ]
            w = jax.lax.switch(cur - self.fmt.nnz, branches, params["w"])
        return dict(params, w=w)

    def compress_params(self, params: dict) -> dict:
        if self.fmt.is_dense or isinstance(params["w"], (DBBWeight, QuantDBBWeight)):
            return params
        return dict(params, w=dbb_encode_conv(params["w"], self.fmt, prune=True))

    def quantize(self, params: dict, act_scale=None) -> dict:
        """Convert compressed params to the INT8 serving layout (§8);
        same contract as :meth:`DBBLinear.quantize` (dense layers — the
        stem — stay fp, like the paper's uncompressed first layer)."""
        w = params["w"]
        if isinstance(w, QuantDBBWeight):  # already int8: re-calibrate only
            if act_scale is None:
                return params
            return dict(params, aq=jnp.asarray(act_scale, jnp.float32))
        if not isinstance(w, DBBWeight):  # dense layer stays fp
            return params
        out = dict(params, w=quantize_dbb(w))
        if act_scale is not None:
            out["aq"] = jnp.asarray(act_scale, jnp.float32)
        return out

    # ------------------------------------------------------------------
    def out_hw(self, h: int, w: int) -> tuple:
        from repro.kernels.core import conv_geometry

        _, _, (ho, wo) = conv_geometry(h, w, self.kh, self.kw, self.stride, self.padding)
        return ho, wo

    def flops(self, batch: int, h: int, w: int) -> int:
        """Executed MACs*2 under the time-unrolled occupancy model."""
        ho, wo = self.out_hw(h, w)
        k = self.kh * self.kw * self.in_channels
        k_eff = (k // self.fmt.bz) * self.fmt.nnz
        return 2 * batch * ho * wo * k_eff * self.out_channels
