"""Activation-sparsity subsystem: measure → gate → account (DESIGN.md §7).

The paper's headline efficiencies compose three effects — weight sparsity
(VDBB), *activation* sparsity (zero-operand clock gating, §IV-A2), and data
reuse (IM2COL). The weight side is modeled structurally (`vdbb.py`); this
module gives the activation side the same first-class treatment instead of
a free-floating ``act_sparsity=0.5`` scalar:

* **measure** — :func:`measure_activation` is a pure-jnp statistics pass
  over any intermediate activation: exact zero fraction (what the hardware
  clock-gates on), a threshold variant (|x| <= t, what threshold gating
  would buy), and the per-bz-block occupancy histogram that says which DBB
  density bound the activations *themselves* would satisfy.
  :class:`ActStats` carries the result plus a MAC weight so per-layer stats
  compose over a whole model (:func:`combine`).

* **gate** — :func:`act_dbb_prune` / :func:`act_dbb_encode` apply the
  paper's DBB structure to the *activation* K-blocks (block-wise top-nnz,
  pattern shared across the M tile — the tc co-design constraint), reusing
  the `vdbb.py` machinery verbatim on the transposed tile. A structurally
  pruned activation runs through the tc kernel's compressed-K contraction
  unchanged, so the contraction can shrink with *measured* activation
  density (:func:`act_fmt` picks the bound from an :class:`ActStats`).

* **account** — `dbb_gemm_costs`/`dbb_conv_costs` take ``act=ActStats`` and
  `energy_model.power_mw`/`tops_per_w`/`conv_workload` accept an
  :class:`ActStats` anywhere they accepted a scalar (duck-typed on
  ``.sparsity``), and `energy_model.model_workload` composes per-layer
  (costs, fmt, stats) triples into whole-model energy.

Collection is wired into the model lifecycle: ``SparseCNN.apply(...,
collect_act_stats=True)`` measures every conv/head input explicitly, and
``LM.forward(..., collect_act_stats=True)`` records every ``apply_linear``
input through the thread-local collector below. The collector silently
skips traced values, so collection must run eagerly (the LM forward
automatically falls back to the unrolled, remat-free path while a
collector is installed).
"""
from __future__ import annotations

import contextlib
import dataclasses
import math
import threading
from typing import Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core.vdbb import (
    DBBFormat,
    DBBWeight,
    DEFAULT_BZ,
    dbb_decode,
    dbb_encode,
    dbb_mask,
)


# ---------------------------------------------------------------------------
# Measurement (pure jnp)
# ---------------------------------------------------------------------------


def zero_fraction(x: jax.Array) -> jax.Array:
    """Exact fraction of zero entries — what zero-operand clock gating sees."""
    return jnp.mean((x == 0).astype(jnp.float32))


def near_zero_fraction(x: jax.Array, threshold: float) -> jax.Array:
    """Fraction with |x| <= threshold — what threshold gating would gate."""
    return jnp.mean((jnp.abs(x) <= threshold).astype(jnp.float32))


def block_nnz_counts(x: jax.Array, bz: int = DEFAULT_BZ) -> jax.Array:
    """Non-zeros per bz-block along the feature (last) dim: (..., K/bz) int32.

    Requires the feature dim to be bz-blockable (K % bz == 0), same as the
    weight-side constraint in `vdbb.py`.
    """
    k = x.shape[-1]
    if k % bz != 0:
        raise ValueError(f"feature dim K={k} not divisible by bz={bz}")
    xb = x.reshape(*x.shape[:-1], k // bz, bz)
    return (xb != 0).sum(axis=-1).astype(jnp.int32)


def block_nnz_histogram(x: jax.Array, bz: int = DEFAULT_BZ) -> jax.Array:
    """Histogram over per-block occupancy: (bz+1,) counts of blocks with
    0..bz non-zeros. Bin b is how many activation K-blocks a DBB bound of
    nnz=b would hold exactly; the CDF answers "what nnz covers p% of blocks".
    """
    counts = block_nnz_counts(x, bz).reshape(-1)
    return (counts[:, None] == jnp.arange(bz + 1)[None, :]).sum(axis=0)


@dataclasses.dataclass(frozen=True)
class ActStats:
    """Per-layer activation statistics (host floats; safe to hash/print).

    ``sparsity`` (== ``zero_frac``) is what the energy model's clock gating
    consumes; anywhere the cost layer accepted a scalar activation sparsity
    it now also accepts an ``ActStats`` (duck-typed on this property).
    ``macs`` weights this layer in whole-model composition (:func:`combine`).
    """

    name: str = ""
    shape: tuple = ()
    numel: int = 0
    zero_frac: float = 0.0
    near_zero_frac: float = 0.0
    threshold: float = 0.0
    bz: int = DEFAULT_BZ
    block_nnz_mean: float = float("nan")  # NaN when K % bz != 0
    macs: int = 0
    absmax: float = 0.0  # max |x|: the INT8 calibration range (DESIGN.md §8)

    @property
    def sparsity(self) -> float:
        return self.zero_frac

    @property
    def density(self) -> float:
        return 1.0 - self.zero_frac

    def __repr__(self):  # compact: shows up in benchmark tables
        return (
            f"ActStats({self.name or '?'} {self.shape} zero={self.zero_frac:.3f}"
            f" |x|<={self.threshold:g}={self.near_zero_frac:.3f}"
            f" blk_nnz={self.block_nnz_mean:.2f}/{self.bz})"
        )


def measure_activation(
    x: jax.Array,
    *,
    name: str = "",
    threshold: float = 0.0,
    bz: int = DEFAULT_BZ,
    macs: int = 0,
) -> ActStats:
    """Measure one activation tensor into an :class:`ActStats` (host floats).

    Must be called on a concrete array (eager / outside jit) — the result
    is a plain dataclass, not a pytree.
    """
    zf = float(zero_fraction(x))
    nf = float(near_zero_fraction(x, threshold)) if threshold > 0 else zf
    if x.shape[-1] % bz == 0:
        bnm = float(jnp.mean(block_nnz_counts(x, bz).astype(jnp.float32)))
    else:
        bnm = float("nan")
    return ActStats(
        name=name, shape=tuple(x.shape), numel=int(x.size), zero_frac=zf,
        near_zero_frac=nf, threshold=threshold, bz=bz, block_nnz_mean=bnm,
        macs=int(macs), absmax=float(jnp.max(jnp.abs(x))),
    )


def combine(stats: Sequence[ActStats], name: str = "combined") -> ActStats:
    """MAC-weighted aggregate of per-layer stats (numel-weighted fallback).

    MAC weighting is the energy-relevant composition: a layer's activation
    stream is read once per executed MAC row, so its sparsity matters in
    proportion to the compute it feeds.
    """
    if not stats:
        raise ValueError("combine() of empty stats")
    weights = [s.macs for s in stats]
    if not any(weights):
        weights = [s.numel for s in stats]
    total = float(sum(weights)) or 1.0
    wavg = lambda f: sum(f(s) * w for s, w in zip(stats, weights)) / total
    bnms = [(s, w) for s, w in zip(stats, weights) if not math.isnan(s.block_nnz_mean)]
    bnm_total = float(sum(w for _, w in bnms))
    return ActStats(
        name=name,
        shape=(),
        numel=sum(s.numel for s in stats),
        zero_frac=wavg(lambda s: s.zero_frac),
        near_zero_frac=wavg(lambda s: s.near_zero_frac),
        threshold=stats[0].threshold,
        bz=stats[0].bz,
        block_nnz_mean=(
            sum(s.block_nnz_mean * w for s, w in bnms) / bnm_total
            if bnms else float("nan")
        ),
        macs=sum(s.macs for s in stats),
        absmax=max(s.absmax for s in stats),  # calibration range is a max
    )


# ---------------------------------------------------------------------------
# Structural activation pruning (gate) — vdbb.py machinery on the M tile
# ---------------------------------------------------------------------------


def _act_fmt_matrix(fmt: DBBFormat) -> DBBFormat:
    """The tile-shared pattern constraint: one pattern per K-block across
    the whole M tile (the tc co-design; group='matrix' on the transpose)."""
    return dataclasses.replace(fmt, group="matrix")


def act_dbb_mask(x: jax.Array, fmt: DBBFormat) -> jax.Array:
    """Boolean keep-mask for block-wise top-nnz activation pruning.

    ``x`` is (..., K) with DBB blocks along the feature dim; the kept
    pattern is shared across all leading (M) dims — scored by the summed
    |x| over the tile, exactly `dbb_mask` on the transposed tile.
    """
    k = x.shape[-1]
    x2 = x.reshape(-1, k)
    mask_t = dbb_mask(x2.T, _act_fmt_matrix(fmt))  # (K, M)
    return mask_t.T.reshape(x.shape)


def act_dbb_prune(x: jax.Array, fmt: DBBFormat) -> jax.Array:
    """Project activations onto the DBB constraint (block-wise top-nnz,
    tile-shared pattern). The result feeds the tc kernel unchanged — its
    compressed-K gather only ever reads the surviving positions."""
    if fmt.is_dense:
        return x
    return jnp.where(act_dbb_mask(x, fmt), x, jnp.zeros_like(x))


def act_dbb_encode(x: jax.Array, fmt: DBBFormat) -> DBBWeight:
    """Compress a (M, K) activation tile along K via `dbb_encode` on the
    transpose (pattern shared across M). ``dbb_decode(...).T`` round-trips
    bit-exactly to :func:`act_dbb_prune` of the same tile."""
    if x.ndim != 2:
        raise ValueError(f"activation tile must be (M, K); got {x.shape}")
    return dbb_encode(x.T, _act_fmt_matrix(fmt), prune=True)


def act_dbb_decode(ax: DBBWeight) -> jax.Array:
    """Expand compressed activations back to the dense (M, K) tile."""
    return dbb_decode(ax).T


def act_fmt(stats: ActStats, bz: Optional[int] = None) -> DBBFormat:
    """DBB bound the measured activation density supports: the smallest
    nnz whose density covers the measured non-zero fraction (conservative
    ceil, clamped to [1, bz]); pattern-shared for the tc contraction.
    ``bz`` defaults to the block size the stats were measured with."""
    bz = stats.bz if bz is None else bz
    nnz = math.ceil((1.0 - stats.sparsity) * bz - 1e-9)
    return DBBFormat(bz=bz, nnz=max(1, min(bz, nnz)), group="matrix")


# ---------------------------------------------------------------------------
# Collection (thread-local, eager-only)
# ---------------------------------------------------------------------------


class ActCollector:
    """Accumulates :class:`ActStats` recorded during a forward pass."""

    def __init__(self, bz: int = DEFAULT_BZ, threshold: float = 0.0):
        self.bz = bz
        self.threshold = threshold
        self.stats: list[ActStats] = []

    def add(self, x: jax.Array, name: str = "", macs: int = 0):
        self.stats.append(
            measure_activation(
                x, name=name or f"act{len(self.stats)}",
                threshold=self.threshold, bz=self.bz, macs=macs,
            )
        )

    def combined(self, name: str = "combined") -> ActStats:
        return combine(self.stats, name)


_CTX = threading.local()


def collecting() -> bool:
    """True while a collector is installed (models switch to eager paths)."""
    return getattr(_CTX, "collector", None) is not None


@contextlib.contextmanager
def collect_activations(bz: int = DEFAULT_BZ, threshold: float = 0.0):
    """Install a collector so :func:`record_activation` accumulates stats.

    Nested use shadows the outer collector. Traced values (under jit/scan)
    are skipped silently — run the forward eagerly to collect.
    """
    col = ActCollector(bz=bz, threshold=threshold)
    prev = getattr(_CTX, "collector", None)
    _CTX.collector = col
    try:
        yield col
    finally:
        _CTX.collector = prev


def record_activation(x: jax.Array, name: str = "", macs: int = 0):
    """Record ``x`` into the active collector; no-op without one or when
    ``x`` is a tracer (jit/scan — nothing concrete to measure)."""
    col: Optional[ActCollector] = getattr(_CTX, "collector", None)
    if col is None or isinstance(x, jax.core.Tracer):
        return
    col.add(x, name=name, macs=macs)


# ---------------------------------------------------------------------------
# Hierarchical activation names (calibration addressing, DESIGN.md §13)
# ---------------------------------------------------------------------------


@contextlib.contextmanager
def act_scope(name: str):
    """Push a name segment onto the thread-local scope stack.

    Models wrap structural units (layer groups, blocks, sub-modules) so a
    leaf recorded as ``wq`` lands in the stats as e.g. ``g0.b1.mixer.wq`` —
    the stable address :func:`repro.models.model.LM.quantize` uses to match
    calibration stats back to the param leaf that produced them.
    """
    stack = getattr(_CTX, "scope", None)
    if stack is None:
        stack = _CTX.scope = []
    stack.append(name)
    try:
        yield
    finally:
        stack.pop()


def scoped(name: str = "") -> str:
    """The current dotted scope joined with ``name`` (may be empty)."""
    stack = getattr(_CTX, "scope", None) or []
    parts = list(stack) + ([name] if name else [])
    return ".".join(parts)
